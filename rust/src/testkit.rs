//! Fuzz-case toolkit (S18): the seed-deterministic random-case generator
//! behind `tests/differential_fuzz.rs` — no external crates, offline
//! builds only.
//!
//! Two pieces:
//!
//! * [`XorShift64`] — a tiny xorshift64* parameter RNG. Deliberately a
//!   *different* generator family from the workloads' [`Pcg64`], so the
//!   fuzz harness's shape/mask/policy draws can never collide with the
//!   seeded experiment streams, and a failing case replays from one
//!   `u64` seed.
//! * [`FuzzCase`] / [`fuzz_case`] — one random attention problem drawn
//!   from the paper's own generator families (Eqs. 17–18 uniform/hybrid
//!   regimes): random shapes, GQA splits, block sizes, masks
//!   (`None | Causal | Padded` incl. zero-length heads), and β policies
//!   (uniform grid picks, per-head tables, broadcast, β = 0 FA2
//!   degradation). The Q/K/V data itself is drawn through
//!   [`Distribution::matrix`] on a [`Pcg64`] stream keyed by the case
//!   seed, keeping the amplitude/bias regimes byte-compatible with the
//!   paper's generators.
//!
//! The harness side (oracle comparison, paged fixtures, pooled vs
//! sequential) lives in the integration test; this module only *builds*
//! cases, so unit tests, benches and future property suites can draw
//! from the same distribution.

use crate::attention::{
    Allocation, AttentionRequest, AttnMask, BetaPolicy, KvPageSource, PageId,
};
use crate::tensor::Matrix;
use crate::workloads::{Distribution, Pcg64};

/// xorshift64* — 8 bytes of state, full 2⁶⁴−1 period, good enough to
/// scatter fuzz parameters. Not for numerics (the data matrices come
/// from [`Pcg64`]).
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Seed the generator; 0 is remapped (xorshift has a fixed point at
    /// zero state).
    pub fn new(seed: u64) -> XorShift64 {
        XorShift64 {
            state: if seed == 0 { 0x9e37_79b9_7f4a_7c15 } else { seed },
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). `n` must be non-zero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] (inclusive).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick one element of a non-empty slice.
    #[inline]
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// In-memory [`KvPageSource`] for paged-view test fixtures — the one
/// shared implementation behind the paged≡dense bit-equality pins (the
/// fuzz harness and the hot-path checksum goldens both scatter through
/// here, so the fixture's layout can never drift from the trait's
/// contract in one copy only).
pub struct FixturePool {
    page_tokens: usize,
    width: usize,
    pages: Vec<Vec<f32>>,
}

impl KvPageSource for FixturePool {
    fn page_tokens(&self) -> usize {
        self.page_tokens
    }
    fn row_width(&self) -> usize {
        self.width
    }
    fn page_data(&self, id: PageId) -> &[f32] {
        &self.pages[id as usize]
    }
}

/// Scatter a dense matrix into pages of `page_tokens` rows; the unused
/// tail of the last page is NaN-poisoned, so any kernel read past
/// `len_tokens` corrupts a bit-equality comparison instead of passing
/// silently. Pick a `page_tokens` that does not divide the KV length so
/// block gathers straddle page boundaries.
pub fn paged_fixture(m: &Matrix, page_tokens: usize) -> (FixturePool, Vec<PageId>) {
    assert!(page_tokens > 0, "paged_fixture needs non-empty pages");
    let n_pages = m.rows.div_ceil(page_tokens);
    let mut pages = vec![vec![f32::NAN; page_tokens * m.cols]; n_pages];
    for r in 0..m.rows {
        let pg = r / page_tokens;
        let off = (r % page_tokens) * m.cols;
        pages[pg][off..off + m.cols].copy_from_slice(m.row(r));
    }
    (
        FixturePool {
            page_tokens,
            width: m.cols,
            pages,
        },
        (0..n_pages as PageId).collect(),
    )
}

/// Bit-pattern view of a matrix — NaN-safe equality (identical NaNs
/// compare equal by bits where `f32` equality would not).
pub fn matrix_bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|x| x.to_bits()).collect()
}

/// The data-regime class a fuzz case was drawn from. `Benign` cases keep
/// the paper's small-bias/small-amplitude regime where every allocation's
/// RMSE envelope is meaningful; `Hot` cases push bias and outlier
/// amplitude into (and past) the 8-bit overflow region, exercising the
/// finite-or-reported-overflow property instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FuzzRegime {
    Benign,
    Hot,
}

/// One drawn attention problem: the request skeleton (Q/K/V, mask, blocks,
/// β policy — everything except the allocation, which the harness loops
/// over) plus the knobs the harness's checks condition on.
pub struct FuzzCase {
    /// The replay seed this case was drawn from (printed on failure).
    pub seed: u64,
    pub regime: FuzzRegime,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub s1: usize,
    pub s2: usize,
    pub d: usize,
    pub dist: Distribution,
    /// Request with `Allocation::Fa32` installed; rebind per allocation
    /// with [`AttentionRequest::with_alloc`].
    pub req: AttentionRequest,
}

/// β candidates the per-head policy draws from: the paper's Table 3 grid
/// picks plus the β = 0 FA2 degradation.
const FUZZ_BETAS: [f64; 4] = [0.0, 0.9375, 0.968994, 0.984497];

/// Draw one case from `seed`. Deterministic: the same seed rebuilds the
/// identical case forever — the failure messages of the differential
/// harness print it as the replay handle.
pub fn fuzz_case(seed: u64) -> FuzzCase {
    let mut r = XorShift64::new(seed);

    // Shapes: decode-shaped (s1 = 1), small, and medium rows all appear;
    // s2 deliberately not a multiple of the block sizes most of the time
    // (ragged tails), and head dims cover the α = √d spread.
    let n_kv_heads = *r.pick(&[1usize, 2]);
    let group = *r.pick(&[1usize, 2, 4]);
    let n_heads = n_kv_heads * group;
    let s1 = *r.pick(&[1usize, 2, 7, 16, 24, 33]);
    let s2 = r.range(1, 64);
    let d = *r.pick(&[4usize, 8, 16]);
    let bs1 = *r.pick(&[8usize, 16, 32]);
    let bs2 = *r.pick(&[8usize, 16, 32]);

    // Data regime (the paper's Eq. 17/18 families). Benign keeps every
    // allocation inside its envelope; hot drives the 8-bit rows past 448
    // (and occasionally FP16 toward pressure) on purpose.
    let (regime, dist) = if r.chance(0.7) {
        let x0 = r.uniform(-1.5, 1.5);
        if r.chance(0.75) {
            (FuzzRegime::Benign, Distribution::Uniform { x0, am: r.uniform(0.25, 2.0) })
        } else {
            (
                FuzzRegime::Benign,
                Distribution::Hybrid { x0, am: r.uniform(1.0, 4.0), p: 0.01 },
            )
        }
    } else {
        let x0 = r.uniform(-12.0, 12.0);
        if r.chance(0.5) {
            (FuzzRegime::Hot, Distribution::Uniform { x0, am: r.uniform(0.25, 4.0) })
        } else {
            (
                FuzzRegime::Hot,
                Distribution::Hybrid { x0, am: r.uniform(4.0, 20.0), p: 0.01 },
            )
        }
    };

    // Mask: dense, causal, or right-padded (broadcast or per-head lens,
    // zero-length heads included — the empty-softmax edge).
    let mask = match r.below(4) {
        0 | 1 => {
            if r.chance(0.5) {
                AttnMask::None
            } else {
                AttnMask::Causal
            }
        }
        2 => {
            // Bias toward the empty-softmax edge: zero-length prefixes
            // are rare under a uniform draw but load-bearing (fully
            // masked heads must yield zeros, never NaN).
            let len = if r.chance(0.15) { 0 } else { r.range(0, s2) };
            AttnMask::Padded(vec![len])
        }
        _ => AttnMask::Padded(
            (0..n_heads)
                .map(|_| if r.chance(0.1) { 0 } else { r.range(0, s2) })
                .collect(),
        ),
    };

    // β policy: uniform paper grid, per-head table (full or broadcast),
    // or the β = 0 degradation. Only the PASA rows consume it, but every
    // request carries it — the policy must be inert elsewhere.
    let policy = match r.below(4) {
        0 => BetaPolicy::Uniform(crate::attention::PAPER_BETA),
        1 => BetaPolicy::Uniform(*r.pick(&FUZZ_BETAS)),
        2 => BetaPolicy::PerHead(vec![*r.pick(&FUZZ_BETAS[1..])]),
        _ => BetaPolicy::PerHead((0..n_heads).map(|_| *r.pick(&FUZZ_BETAS[1..])).collect()),
    };

    // Data: Pcg64 streams keyed off the xorshift state, through the
    // paper's generators — one stream per query head, one per KV head.
    // V is always drawn benign, mirroring the resonance generator (whose
    // V is N(0, 1)): the overflow mechanism under test lives in the
    // score GEMM Q·Kᵀ, and a hot V would instead overflow the *PV*
    // store — a separate, uninstrumented site that the 448 boundary
    // makes trivially reachable and that would turn every hot case into
    // an unreportable NaN.
    let v_dist = Distribution::Uniform {
        x0: r.uniform(-1.0, 1.0),
        am: r.uniform(0.5, 2.0),
    };
    let data_seed = r.next_u64();
    let mut req = AttentionRequest::new(Allocation::Fa32);
    for kvh in 0..n_kv_heads {
        let mut rng = Pcg64::new(data_seed, 0x8000 + kvh as u64);
        req = req.with_kv_head(dist.matrix(s2, d, &mut rng), v_dist.matrix(s2, d, &mut rng));
    }
    for h in 0..n_heads {
        let mut rng = Pcg64::new(data_seed, h as u64);
        req = req.with_query_head(dist.matrix(s1, d, &mut rng));
    }
    let req = req
        .with_mask(mask)
        .with_policy(policy)
        .with_blocks(bs1, bs2)
        .with_fp16_inputs();

    FuzzCase {
        seed,
        regime,
        n_heads,
        n_kv_heads,
        s1,
        s2,
        d,
        dist,
        req,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift_is_deterministic_and_nonconstant() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        let av: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(av, bv);
        assert!(av.windows(2).any(|w| w[0] != w[1]));
        let mut c = XorShift64::new(43);
        assert_ne!(av[0], c.next_u64());
        // Zero seed is remapped, not a fixed point.
        let mut z = XorShift64::new(0);
        let first = z.next_u64();
        assert_ne!(first, 0);
        assert_ne!(first, z.next_u64());
    }

    #[test]
    fn xorshift_ranges_respect_bounds() {
        let mut r = XorShift64::new(7);
        for _ in 0..2000 {
            let x = r.range(3, 9);
            assert!((3..=9).contains(&x));
            let u = r.uniform(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&u));
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
        // All range values are reachable.
        let mut seen = [false; 7];
        let mut r = XorShift64::new(11);
        for _ in 0..500 {
            seen[r.range(3, 9) - 3] = true;
        }
        assert!(seen.iter().all(|&s| s), "range(3, 9) missed a value");
    }

    #[test]
    fn paged_fixture_round_trips_and_poisons_the_tail() {
        use crate::attention::KvView;
        // 10 rows into 4-token pages: 3 pages, last page half NaN.
        let m = Matrix::from_vec(10, 3, (0..30).map(|i| i as f32).collect());
        let (pool, ids) = paged_fixture(&m, 4);
        assert_eq!(ids.len(), 3);
        let view = KvView::paged(&ids, &pool, 10);
        assert_eq!(matrix_bits(&view.to_matrix()), matrix_bits(&m));
        // The tail beyond the valid rows really is poisoned.
        assert!(pool.page_data(ids[2])[2 * 3..].iter().all(|x| x.is_nan()));
    }

    #[test]
    fn fuzz_cases_are_replayable_and_valid() {
        for seed in [1u64, 2, 0xdead_beef, u64::MAX] {
            let a = fuzz_case(seed);
            let b = fuzz_case(seed);
            assert_eq!(a.req.q.len(), b.req.q.len(), "seed {seed}");
            for h in 0..a.req.q.len() {
                assert_eq!(a.req.q[h].data, b.req.q[h].data, "seed {seed} head {h}");
            }
            assert_eq!(a.req.mask, b.req.mask, "seed {seed}");
            assert_eq!(a.req.policy, b.req.policy, "seed {seed}");
            assert!(
                a.req.validate().is_ok(),
                "seed {seed}: generated an invalid request: {:?}",
                a.req.validate()
            );
            assert_eq!(a.n_heads, a.req.n_heads());
            assert_eq!(a.n_kv_heads, a.req.n_kv_heads());
        }
    }

    #[test]
    fn fuzz_distribution_covers_the_feature_space() {
        // Over a few hundred seeds the generator must exercise every
        // mask kind, both regimes, GQA splits, decode shapes and both
        // policy families — otherwise the "fuzz per allocation" claim is
        // silently hollow.
        let (mut none, mut causal, mut padded) = (0, 0, 0);
        let (mut benign, mut hot) = (0, 0);
        let (mut gqa, mut decode, mut per_head, mut zero_len) = (0, 0, 0, 0);
        for seed in 0..400u64 {
            let c = fuzz_case(seed);
            match &c.req.mask {
                AttnMask::None => none += 1,
                AttnMask::Causal => causal += 1,
                AttnMask::Padded(lens) => {
                    padded += 1;
                    if lens.iter().any(|&l| l == 0) {
                        zero_len += 1;
                    }
                }
            }
            match c.regime {
                FuzzRegime::Benign => benign += 1,
                FuzzRegime::Hot => hot += 1,
            }
            if c.n_heads > c.n_kv_heads {
                gqa += 1;
            }
            if c.s1 == 1 {
                decode += 1;
            }
            if matches!(c.req.policy, BetaPolicy::PerHead(_)) {
                per_head += 1;
            }
        }
        for (what, n) in [
            ("mask=none", none),
            ("mask=causal", causal),
            ("mask=padded", padded),
            ("regime=benign", benign),
            ("regime=hot", hot),
            ("gqa split", gqa),
            ("decode shape", decode),
            ("per-head policy", per_head),
        ] {
            assert!(n >= 10, "{what}: only {n}/400 cases");
        }
        assert!(zero_len >= 5, "zero-length heads: only {zero_len}/400 cases");
    }
}
