//! Experiment harness (S16): regenerates every table and figure of the
//! paper's evaluation. Each experiment prints the same rows/series the
//! paper reports; EXPERIMENTS.md records paper-vs-measured shape checks.
//!
//! Index (see DESIGN.md §4):
//!   table1  — data-format ranges            (paper Table 1)
//!   table3  — invariance under β            (paper Table 3)
//!   table4  — NaN percentages               (paper Table 4)
//!   fig5    — shifting reduces mean+amplitude (paper Fig. 5)
//!   fig6    — resonance categories          (paper Fig. 6)
//!   fig7    — center-line Q/K distributions (paper Fig. 7)
//!   fig9a/b — RMSE sweeps, uniform          (paper Fig. 9)
//!   fig10a/b— RMSE sweeps, hybrid           (paper Fig. 10)
//!   fig11..14 — cloud-map ranges, Qwen2/SVD (paper Figs. 11–14)
//!   guard_rescue — pre-emptive vs adaptive guard: rescue rate / replay
//!                  cost over ramped resonance traces (extension)

pub mod cloudmap;
pub mod guard_rescue;
pub mod resonance_demo;
pub mod rmse_sweep;
pub mod shifting_stats;
pub mod tables;

use anyhow::{bail, Result};

/// Common options for the experiment harness.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    /// Heads per random benchmark case (paper: 16; fewer is faster with
    /// identical per-head distribution).
    pub heads: usize,
    /// Sequence length for random benchmarks (paper: 1280).
    pub seq: usize,
    /// Head dim for random benchmarks (paper: 128).
    pub dim: usize,
    /// Model-trace sequence divisor (1 = the paper's full 5676/9216).
    pub trace_scale: usize,
    pub seed: u64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            heads: 4,
            seq: 1280,
            dim: 128,
            trace_scale: 4,
            seed: 42,
        }
    }
}

/// Run one experiment by id; returns the printed report.
pub fn run(id: &str, opts: &ExpOptions) -> Result<String> {
    Ok(match id {
        "table1" => tables::table1(),
        "table3" => tables::table3(),
        "table4" => tables::table4(opts),
        "fig5" => shifting_stats::fig5(opts),
        "fig6" => resonance_demo::fig6(opts),
        "fig7" => cloudmap::fig7(opts),
        "fig9a" => rmse_sweep::fig9a(opts),
        "fig9b" => rmse_sweep::fig9b(opts),
        "fig10a" => rmse_sweep::fig10a(opts),
        "fig10b" => rmse_sweep::fig10b(opts),
        "fig11" => cloudmap::fig_cloud("qwen2-7b", false, opts),
        "fig12" => cloudmap::fig_cloud("svd-img2vid", false, opts),
        "fig13" => cloudmap::fig_cloud("qwen2-7b", true, opts),
        "fig14" => cloudmap::fig_cloud("svd-img2vid", true, opts),
        "guard_rescue" => guard_rescue::guard_rescue(opts),
        "all" => {
            let mut out = String::new();
            for id in ALL_EXPERIMENTS {
                out.push_str(&run(id, opts)?);
                out.push('\n');
            }
            out
        }
        _ => bail!("unknown experiment id {id}; known: {ALL_EXPERIMENTS:?}"),
    })
}

pub const ALL_EXPERIMENTS: [&str; 15] = [
    "table1", "table3", "table4", "fig5", "fig6", "fig7", "fig9a", "fig9b", "fig10a", "fig10b",
    "fig11", "fig12", "fig13", "fig14", "guard_rescue",
];
