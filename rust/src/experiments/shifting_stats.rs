//! Figure 5: applying the shifting matrix M reduces both the average
//! value and the amplitude of the attention score matrix.

use super::ExpOptions;
use crate::attention::{preprocess_k, shifting_matrix, PAPER_BETA};
use crate::numerics::{finite_mean, finite_range, Format};
use crate::tensor::{matmul_nt, GemmPrecision};
use crate::workloads::{gen_case, Distribution, Pcg64};

/// For a set of distributions, report range/mean of S = QKᵀ/α before and
/// after the PASA shift.
pub fn fig5(opts: &ExpOptions) -> String {
    let mut out = String::from(
        "# Fig 5 — Reduction of Average Value and Amplitude with PASA\n\
         | distribution | S range (before) | S mean (before) | S' range (after) | S' mean (after) |\n",
    );
    let dists = [
        Distribution::Uniform { x0: 20.0, am: 0.5 },
        Distribution::Uniform { x0: -10.0, am: 2.0 },
        Distribution::Hybrid {
            x0: 15.0,
            am: 20.0,
            p: 0.001,
        },
    ];
    let s2 = 128;
    for dist in dists {
        let mut rng = Pcg64::new(opts.seed, 7);
        let case = gen_case(dist, 256, s2, opts.dim, &mut rng);
        let c = crate::attention::to_fp16_inputs(&case);
        let alpha = (opts.dim as f64).sqrt();
        // Before: S/α computed exactly.
        let s = matmul_nt(&c.q, &c.k, GemmPrecision::F32);
        let scaled: Vec<f32> = s.data.iter().map(|&x| x / alpha as f32).collect();
        let (lo0, hi0) = finite_range(&scaled);
        let m0 = finite_mean(&scaled);
        // After: K' = M·K then S' = Q·K'ᵀ.
        let m = shifting_matrix(s2, alpha, PAPER_BETA, Format::F16);
        let kp = preprocess_k(&c.k, &m, GemmPrecision::ACC32_STORE16);
        let sp = matmul_nt(&c.q, &kp, GemmPrecision::F32);
        let (lo1, hi1) = finite_range(&sp.data);
        let m1 = finite_mean(&sp.data);
        out.push_str(&format!(
            "| {} | [{lo0:.1}, {hi0:.1}] | {m0:.2} | [{lo1:.2}, {hi1:.2}] | {m1:.4} |\n",
            dist.label()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shift_reduces_both_mean_and_amplitude() {
        // Recreate the fig5 computation and assert the reduction holds for
        // the biased uniform case (the paper's headline claim).
        let opts = ExpOptions {
            dim: 64,
            ..Default::default()
        };
        let mut rng = Pcg64::new(1, 7);
        let case = gen_case(Distribution::Uniform { x0: 20.0, am: 0.5 }, 128, 128, opts.dim, &mut rng);
        let c = crate::attention::to_fp16_inputs(&case);
        let alpha = (opts.dim as f64).sqrt();
        let s = matmul_nt(&c.q, &c.k, GemmPrecision::F32);
        let scaled: Vec<f32> = s.data.iter().map(|&x| x / alpha as f32).collect();
        let m = shifting_matrix(128, alpha, PAPER_BETA, Format::F16);
        let kp = preprocess_k(&c.k, &m, GemmPrecision::ACC32_STORE16);
        let sp = matmul_nt(&c.q, &kp, GemmPrecision::F32);
        let (lo0, hi0) = finite_range(&scaled);
        let (lo1, hi1) = finite_range(&sp.data);
        // The shift removes the K-mean component; the Q-side row spread
        // remains, so the amplitude shrinks but does not vanish.
        assert!(hi1 - lo1 < 0.8 * (hi0 - lo0), "amplitude not reduced");
        assert!(
            finite_mean(&sp.data).abs() < 0.05 * finite_mean(&scaled).abs(),
            "mean not collapsed"
        );
    }
}
