//! Guard-policy experiment: rescue rate vs replay cost across the
//! resonance trace generators (the paper's Qwen2 / SVD overflow stand-ins).
//!
//! Setup: each trace's Q side is ramped linearly over `STEPS` steps, so
//! the raw score peak sweeps from benign, through the pre-overflow
//! *pressure* window, past the FP16 boundary — the serving trajectory the
//! pre-emptive guard is built for. Every policy walks the same ramp with
//! its own [`Guard`], consuming kernel telemetry exactly like the engine:
//!
//! * **replays** — steps run twice (FA tripped, PASA replayed): the
//!   latency cost of reacting *after* damage;
//! * **damaged** — steps whose served output still carried overflow
//!   events or non-finite values: the accuracy cost of no guard;
//! * **rescued** — steps where the FA16-32 counterfactual overflows but
//!   the served output is clean: the benefit;
//! * **pinned@** — the ramp step at which the policy pinned PASA.
//!
//! The pre-emptive rows pin inside the pressure window, so they reach the
//! overflow region already on PASA: same rescue rate as Adaptive, zero
//! replays.

use super::ExpOptions;
use crate::attention::{Allocation, AttentionRequest};
use crate::coordinator::{Guard, GuardPolicy, GuardSignal};
use crate::workloads::{all_traces, AttentionCase};

/// Ramp steps per trace.
const STEPS: usize = 8;

/// The policies compared (name, policy).
fn policies() -> Vec<(&'static str, GuardPolicy)> {
    vec![
        ("always-fa16_32", GuardPolicy::AlwaysFa16),
        ("adaptive", GuardPolicy::Adaptive),
        (
            "preemptive(0.5)",
            GuardPolicy::Preemptive {
                score_limit_frac: 0.5,
            },
        ),
        (
            "preemptive(0.75)",
            GuardPolicy::Preemptive {
                score_limit_frac: 0.75,
            },
        ),
        ("always-pasa", GuardPolicy::AlwaysPasa),
    ]
}

/// One policy's tallies over a ramp.
#[derive(Clone, Debug, Default)]
pub struct RescueRow {
    pub replays: usize,
    pub damaged: usize,
    pub rescued: usize,
    pub pinned_at: Option<usize>,
    /// Allocation the guard ended the ramp on — shows how far down the
    /// fallback chain the walk stepped (e.g. an FP8 start that the Pasa8
    /// shift rescued ends on "pasa8", never abandoning the 8-bit
    /// envelope). Empty only before a walk.
    pub final_alloc: &'static str,
}

/// Scale a case's Q side by `r` (scores scale linearly in Q).
fn scaled(case: &AttentionCase, r: f32) -> AttentionCase {
    let mut c = case.clone();
    for v in &mut c.q.data {
        *v *= r;
    }
    c
}

/// Walk one ramp under one policy, consuming kernel telemetry like the
/// serving engine. `cf_overflow[t]` is the counterfactual of the ramp's
/// *starting* allocation: would step `t` have overflowed the fast path?
pub fn walk_ramp(
    policy: GuardPolicy,
    steps: &[AttentionCase],
    cf_overflow: &[bool],
) -> RescueRow {
    walk_ramp_from(policy, Allocation::Fa16_32, steps, cf_overflow)
}

/// [`walk_ramp`] rooted at an explicit starting allocation: the guard
/// walks that allocation's fallback chain (`fp8 → pasa8 → pasa` for an
/// FP8 start), replaying a tripped step under each next stage exactly
/// like the engine's rescue loop.
pub fn walk_ramp_from(
    policy: GuardPolicy,
    start: Allocation,
    steps: &[AttentionCase],
    cf_overflow: &[bool],
) -> RescueRow {
    let mut guard = Guard::new(policy).with_start(start);
    let mut row = RescueRow::default();
    for (t, c) in steps.iter().enumerate() {
        let alloc = Allocation::parse(guard.allocation()).expect("guard maps to the lab");
        let req = AttentionRequest::from_case(c, alloc).with_fp16_inputs();
        let mut out = req.run();
        let mut sig = GuardSignal::from_attention(&out);
        let was_pinned = guard.is_pinned();
        // Replays walk the chain until the signal is clean or the chain
        // is exhausted (bounded: observe_signal sticks at the last stage).
        while guard.observe_signal(&sig) {
            row.replays += 1;
            let rescue = Allocation::parse(guard.allocation()).expect("guard maps to the lab");
            out = req.clone().with_alloc(rescue).run();
            sig = GuardSignal::from_attention(&out);
        }
        if guard.is_pinned() && !was_pinned {
            row.pinned_at = Some(t);
        }
        if !sig.is_clean(1.0) {
            row.damaged += 1;
        } else if cf_overflow[t] {
            row.rescued += 1;
        }
    }
    row.final_alloc = guard.allocation();
    row
}

/// Build the ramp (shared across policies) and its FA16-32 counterfactual.
pub fn build_ramp(case: &AttentionCase) -> (Vec<AttentionCase>, Vec<bool>) {
    build_ramp_for(case, Allocation::Fa16_32)
}

/// [`build_ramp`] with the counterfactual taken against an explicit fast
/// path (the FP8 row for the 8-bit chain study).
pub fn build_ramp_for(
    case: &AttentionCase,
    cf_alloc: Allocation,
) -> (Vec<AttentionCase>, Vec<bool>) {
    let steps: Vec<AttentionCase> = (0..STEPS)
        .map(|t| scaled(case, (t + 1) as f32 / STEPS as f32))
        .collect();
    let cf = counterfactual_overflow(&steps, cf_alloc);
    (steps, cf)
}

/// Would each ramp step overflow under `alloc`? (One unguarded run per
/// step — the "no guard" baseline a rescue is measured against.)
pub fn counterfactual_overflow(steps: &[AttentionCase], alloc: Allocation) -> Vec<bool> {
    steps
        .iter()
        .map(|c| {
            let out = AttentionRequest::from_case(c, alloc).with_fp16_inputs().run();
            !GuardSignal::from_attention(&out).is_clean(1.0)
        })
        .collect()
}

/// The experiment report: one table per trace.
pub fn guard_rescue(opts: &ExpOptions) -> String {
    let mut out = String::from(
        "# guard_rescue — rescue rate vs replay cost, ramped resonance traces\n",
    );
    let s = (opts.seq / 10).clamp(48, 256);
    for trace in all_traces(opts.trace_scale) {
        let mut spec = trace.spec.clone();
        spec.s1 = s;
        spec.s2 = s;
        let (steps, cf) = build_ramp(&spec.generate(opts.seed));
        let overflow_steps = cf.iter().filter(|&&b| b).count();
        out.push_str(&format!(
            "\n## {} (s={s}, d={}, {} of {STEPS} ramp steps overflow FA16-32)\n",
            trace.name, spec.d, overflow_steps
        ));
        out.push_str("| policy | pinned@ | replays | damaged | rescued | final |\n");
        for (name, policy) in policies() {
            let r = walk_ramp(policy, &steps, &cf);
            out.push_str(&format!(
                "| {name} | {} | {} | {} | {}/{overflow_steps} | {} |\n",
                r.pinned_at.map_or("-".into(), |t| t.to_string()),
                r.replays,
                r.damaged,
                r.rescued,
                r.final_alloc
            ));
        }
        // The 8-bit chain study: the same ramp started on the FP8 row,
        // counterfactual taken against FP8's own 448 boundary. The guard
        // walks fp8 → pasa8 → pasa; the `final` column shows whether the
        // Pasa8 shift held the 8-bit envelope or the walk had to abandon
        // it for FP16 PASA.
        let cf8 = counterfactual_overflow(&steps, Allocation::Fp8);
        let overflow8 = cf8.iter().filter(|&&b| b).count();
        out.push_str(&format!(
            "### fp8 start ({overflow8} of {STEPS} ramp steps overflow the 448 boundary)\n"
        ));
        out.push_str("| policy | pinned@ | replays | damaged | rescued | final |\n");
        for (name, policy) in [
            ("adaptive", GuardPolicy::Adaptive),
            (
                "preemptive(0.75)",
                GuardPolicy::Preemptive {
                    score_limit_frac: 0.75,
                },
            ),
        ] {
            let r = walk_ramp_from(policy, Allocation::Fp8, &steps, &cf8);
            out.push_str(&format!(
                "| {name} | {} | {} | {} | {}/{overflow8} | {} |\n",
                r.pinned_at.map_or("-".into(), |t| t.to_string()),
                r.replays,
                r.damaged,
                r.rescued,
                r.final_alloc
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::qwen2_overflow_trace;

    #[test]
    fn preemptive_rescues_without_replays_where_adaptive_replays() {
        // The acceptance shape: on a ramp that crosses the FP16 boundary,
        // Adaptive pays >= 1 replay for a clean stream; Preemptive(0.5)
        // pins inside the pressure window — zero replays, zero damage,
        // same rescues. AlwaysFa16 takes the damage.
        let mut spec = qwen2_overflow_trace(16).spec;
        spec.s1 = 48;
        spec.s2 = 48;
        let (steps, cf) = build_ramp(&spec.generate(3));
        let overflow_steps = cf.iter().filter(|&&b| b).count();
        assert!(overflow_steps >= 1, "ramp premise: the tail must overflow");
        assert!(!cf[0], "ramp premise: the first step must be benign");

        let adaptive = walk_ramp(GuardPolicy::Adaptive, &steps, &cf);
        assert!(adaptive.replays >= 1, "adaptive must replay the trip step");
        assert_eq!(adaptive.damaged, 0, "replay must clean the stream");

        let pre = walk_ramp(
            GuardPolicy::Preemptive {
                score_limit_frac: 0.5,
            },
            &steps,
            &cf,
        );
        assert_eq!(pre.replays, 0, "pressure pin must avoid every replay");
        assert_eq!(pre.damaged, 0);
        assert_eq!(pre.rescued, overflow_steps, "same rescues as adaptive");
        assert!(
            pre.pinned_at.unwrap() <= adaptive.pinned_at.unwrap(),
            "preemptive must pin no later than adaptive"
        );

        let fa = walk_ramp(GuardPolicy::AlwaysFa16, &steps, &cf);
        assert_eq!(fa.damaged, overflow_steps, "unguarded FA takes the damage");
        assert_eq!(fa.replays, 0);
    }

    #[test]
    fn fp8_start_rescues_within_the_8bit_envelope() {
        // A bias-dominated ramp whose raw scores cross the 448 boundary
        // (S ≈ 2.2²·128 ≈ 620 at full scale) but sit far inside FP16: the
        // plain FP8 row poisons the tail steps, and the adaptive walk
        // from an FP8 start must rescue them under *Pasa8* — the shift
        // collapses the bias well below 448, so the chain never has to
        // abandon the 8-bit envelope for FP16 PASA.
        use crate::workloads::{gen_case, Distribution, Pcg64};
        let mut rng = Pcg64::new(17, 0);
        let case = gen_case(
            Distribution::Uniform { x0: 2.2, am: 0.25 },
            48,
            48,
            128,
            &mut rng,
        );
        let (steps, cf8) = build_ramp_for(&case, Allocation::Fp8);
        let overflow8 = cf8.iter().filter(|&&b| b).count();
        assert!(overflow8 >= 1, "ramp premise: the tail must cross 448");
        assert!(!cf8[0], "ramp premise: the first step must be benign");
        // Premise: the same ramp never troubles the FP16 fast path.
        let cf16 = counterfactual_overflow(&steps, Allocation::Fa16_32);
        assert!(cf16.iter().all(|&b| !b), "ramp must stay inside FP16");

        let r = walk_ramp_from(GuardPolicy::Adaptive, Allocation::Fp8, &steps, &cf8);
        assert!(r.replays >= 1, "the 448 trip must replay");
        assert_eq!(r.damaged, 0, "the chain must clean the stream");
        assert_eq!(r.rescued, overflow8, "every tripped step rescued");
        assert_eq!(
            r.final_alloc, "pasa8",
            "the shift must hold the 8-bit envelope — escalating to \
             {:?} means the chain abandoned E4M3 unnecessarily",
            r.final_alloc
        );
        assert!(r.pinned_at.is_some());
    }

    #[test]
    fn fp8_start_escalates_to_fp16_pasa_when_the_shift_is_not_enough() {
        // Amplitude-dominated, zero-mean data: the pseudo-average is ≈ 0,
        // so the shift removes nothing — S' ≈ S/α. With am = 30 at d = 128
        // the score fluctuations reach several thousand after the 1/α
        // folding (σ ≈ am²/3·√d ≈ 3.4k pre-fold, peak ≈ 1.2k post-fold):
        // past 448 but far inside FP16. The FP8 start must therefore walk
        // the whole chain — fp8 trips, pasa8's shifted store still trips,
        // and only full FP16 PASA finishes the ramp clean.
        use crate::workloads::{gen_case, Distribution, Pcg64};
        let mut rng = Pcg64::new(23, 0);
        let case = gen_case(
            Distribution::Uniform { x0: 0.0, am: 30.0 },
            48,
            48,
            128,
            &mut rng,
        );
        let (steps, cf8) = build_ramp_for(&case, Allocation::Fp8);
        assert!(cf8.iter().any(|&b| b), "ramp premise: 448 must trip");
        // Premise: FP16 holds the whole ramp.
        let cf16 = counterfactual_overflow(&steps, Allocation::Fa16_32);
        assert!(cf16.iter().all(|&b| !b), "ramp must stay inside FP16");
        let r = walk_ramp_from(GuardPolicy::Adaptive, Allocation::Fp8, &steps, &cf8);
        assert_eq!(
            r.final_alloc, "pasa",
            "amplitude (not bias) exceeds what the 448 envelope can hold"
        );
        assert_eq!(r.damaged, 0, "the full chain must still clean the stream");
        assert!(r.replays >= 2, "stepping the whole chain costs two replays");
    }

    #[test]
    fn report_renders_all_policies() {
        let opts = ExpOptions {
            heads: 1,
            seq: 480,
            dim: 64,
            trace_scale: 32,
            seed: 5,
        };
        let rep = guard_rescue(&opts);
        for name in ["always-fa16_32", "adaptive", "preemptive(0.5)", "always-pasa"] {
            assert!(rep.contains(name), "missing row {name}");
        }
        assert!(rep.contains("qwen2-7b"));
        assert!(rep.contains("svd-img2vid"));
    }
}
