//! Guard-policy experiment: rescue rate vs replay cost across the
//! resonance trace generators (the paper's Qwen2 / SVD overflow stand-ins).
//!
//! Setup: each trace's Q side is ramped linearly over `STEPS` steps, so
//! the raw score peak sweeps from benign, through the pre-overflow
//! *pressure* window, past the FP16 boundary — the serving trajectory the
//! pre-emptive guard is built for. Every policy walks the same ramp with
//! its own [`Guard`], consuming kernel telemetry exactly like the engine:
//!
//! * **replays** — steps run twice (FA tripped, PASA replayed): the
//!   latency cost of reacting *after* damage;
//! * **damaged** — steps whose served output still carried overflow
//!   events or non-finite values: the accuracy cost of no guard;
//! * **rescued** — steps where the FA16-32 counterfactual overflows but
//!   the served output is clean: the benefit;
//! * **pinned@** — the ramp step at which the policy pinned PASA.
//!
//! The pre-emptive rows pin inside the pressure window, so they reach the
//! overflow region already on PASA: same rescue rate as Adaptive, zero
//! replays.

use super::ExpOptions;
use crate::attention::{Allocation, AttentionRequest};
use crate::coordinator::{Guard, GuardPolicy, GuardSignal};
use crate::workloads::{all_traces, AttentionCase};

/// Ramp steps per trace.
const STEPS: usize = 8;

/// The policies compared (name, policy).
fn policies() -> Vec<(&'static str, GuardPolicy)> {
    vec![
        ("always-fa16_32", GuardPolicy::AlwaysFa16),
        ("adaptive", GuardPolicy::Adaptive),
        (
            "preemptive(0.5)",
            GuardPolicy::Preemptive {
                score_limit_frac: 0.5,
            },
        ),
        (
            "preemptive(0.75)",
            GuardPolicy::Preemptive {
                score_limit_frac: 0.75,
            },
        ),
        ("always-pasa", GuardPolicy::AlwaysPasa),
    ]
}

/// One policy's tallies over a ramp.
#[derive(Clone, Debug, Default)]
pub struct RescueRow {
    pub replays: usize,
    pub damaged: usize,
    pub rescued: usize,
    pub pinned_at: Option<usize>,
}

/// Scale a case's Q side by `r` (scores scale linearly in Q).
fn scaled(case: &AttentionCase, r: f32) -> AttentionCase {
    let mut c = case.clone();
    for v in &mut c.q.data {
        *v *= r;
    }
    c
}

/// Walk one ramp under one policy, consuming kernel telemetry like the
/// serving engine. `cf_overflow[t]` is the FA16-32 counterfactual: would
/// step `t` have overflowed the fast path?
pub fn walk_ramp(
    policy: GuardPolicy,
    steps: &[AttentionCase],
    cf_overflow: &[bool],
) -> RescueRow {
    let mut guard = Guard::new(policy);
    let mut row = RescueRow::default();
    for (t, c) in steps.iter().enumerate() {
        let alloc = Allocation::parse(guard.allocation()).expect("guard maps to the lab");
        let req = AttentionRequest::from_case(c, alloc).with_fp16_inputs();
        let mut out = req.run();
        let mut sig = GuardSignal::from_attention(&out);
        let was_pinned = guard.is_pinned();
        if guard.observe_signal(&sig) {
            row.replays += 1;
            out = req.with_alloc(Allocation::Pasa16).run();
            sig = GuardSignal::from_attention(&out);
        }
        if guard.is_pinned() && !was_pinned {
            row.pinned_at = Some(t);
        }
        if !sig.is_clean(1.0) {
            row.damaged += 1;
        } else if cf_overflow[t] {
            row.rescued += 1;
        }
    }
    row
}

/// Build the ramp (shared across policies) and its FA16-32 counterfactual.
pub fn build_ramp(case: &AttentionCase) -> (Vec<AttentionCase>, Vec<bool>) {
    let steps: Vec<AttentionCase> = (0..STEPS)
        .map(|t| scaled(case, (t + 1) as f32 / STEPS as f32))
        .collect();
    let cf: Vec<bool> = steps
        .iter()
        .map(|c| {
            let out = AttentionRequest::from_case(c, Allocation::Fa16_32)
                .with_fp16_inputs()
                .run();
            !GuardSignal::from_attention(&out).is_clean(1.0)
        })
        .collect();
    (steps, cf)
}

/// The experiment report: one table per trace.
pub fn guard_rescue(opts: &ExpOptions) -> String {
    let mut out = String::from(
        "# guard_rescue — rescue rate vs replay cost, ramped resonance traces\n",
    );
    let s = (opts.seq / 10).clamp(48, 256);
    for trace in all_traces(opts.trace_scale) {
        let mut spec = trace.spec.clone();
        spec.s1 = s;
        spec.s2 = s;
        let (steps, cf) = build_ramp(&spec.generate(opts.seed));
        let overflow_steps = cf.iter().filter(|&&b| b).count();
        out.push_str(&format!(
            "\n## {} (s={s}, d={}, {} of {STEPS} ramp steps overflow FA16-32)\n",
            trace.name, spec.d, overflow_steps
        ));
        out.push_str("| policy | pinned@ | replays | damaged | rescued |\n");
        for (name, policy) in policies() {
            let r = walk_ramp(policy, &steps, &cf);
            out.push_str(&format!(
                "| {name} | {} | {} | {} | {}/{overflow_steps} |\n",
                r.pinned_at.map_or("-".into(), |t| t.to_string()),
                r.replays,
                r.damaged,
                r.rescued
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::qwen2_overflow_trace;

    #[test]
    fn preemptive_rescues_without_replays_where_adaptive_replays() {
        // The acceptance shape: on a ramp that crosses the FP16 boundary,
        // Adaptive pays >= 1 replay for a clean stream; Preemptive(0.5)
        // pins inside the pressure window — zero replays, zero damage,
        // same rescues. AlwaysFa16 takes the damage.
        let mut spec = qwen2_overflow_trace(16).spec;
        spec.s1 = 48;
        spec.s2 = 48;
        let (steps, cf) = build_ramp(&spec.generate(3));
        let overflow_steps = cf.iter().filter(|&&b| b).count();
        assert!(overflow_steps >= 1, "ramp premise: the tail must overflow");
        assert!(!cf[0], "ramp premise: the first step must be benign");

        let adaptive = walk_ramp(GuardPolicy::Adaptive, &steps, &cf);
        assert!(adaptive.replays >= 1, "adaptive must replay the trip step");
        assert_eq!(adaptive.damaged, 0, "replay must clean the stream");

        let pre = walk_ramp(
            GuardPolicy::Preemptive {
                score_limit_frac: 0.5,
            },
            &steps,
            &cf,
        );
        assert_eq!(pre.replays, 0, "pressure pin must avoid every replay");
        assert_eq!(pre.damaged, 0);
        assert_eq!(pre.rescued, overflow_steps, "same rescues as adaptive");
        assert!(
            pre.pinned_at.unwrap() <= adaptive.pinned_at.unwrap(),
            "preemptive must pin no later than adaptive"
        );

        let fa = walk_ramp(GuardPolicy::AlwaysFa16, &steps, &cf);
        assert_eq!(fa.damaged, overflow_steps, "unguarded FA takes the damage");
        assert_eq!(fa.replays, 0);
    }

    #[test]
    fn report_renders_all_policies() {
        let opts = ExpOptions {
            heads: 1,
            seq: 480,
            dim: 64,
            trace_scale: 32,
            seed: 5,
        };
        let rep = guard_rescue(&opts);
        for name in ["always-fa16_32", "adaptive", "preemptive(0.5)", "always-pasa"] {
            assert!(rep.contains(name), "missing row {name}");
        }
        assert!(rep.contains("qwen2-7b"));
        assert!(rep.contains("svd-img2vid"));
    }
}
