//! Tables 1, 3 and 4 of the paper.

use super::ExpOptions;
use crate::attention::{beta, Allocation, AttentionRequest};
use crate::numerics::{nan_percentage, Format};
use crate::workloads::{gen_multihead, Distribution};

/// Table 1: range and precision for the data formats.
pub fn table1() -> String {
    let mut out = String::from(
        "# Table 1 — Range and Precision for Different Data Formats\n\
         | Format | Precision | Overflow Boundary |\n",
    );
    for fmt in [Format::F8E4M3, Format::F16, Format::Bf16, Format::F32] {
        out.push_str(&format!(
            "| {} | {:.3e} | {:.5e} |\n",
            fmt.name(),
            fmt.eps(),
            fmt.overflow_boundary()
        ));
    }
    out
}

/// Table 3: invariance parameters under initial vs optimized β (FP16,
/// n = 128 — the paper's setting).
pub fn table3() -> String {
    let mut out = String::from(
        "# Table 3 — Invariance under Initial and Optimized beta (FP16, n=128)\n\
         | init beta | Inva | Inva1 | rel err | opt beta | Inva | Inva1 | rel err |\n",
    );
    for row in beta::table3(128, Format::F16) {
        out.push_str(&format!(
            "| {:.6} | {:.4} | {:.4} | {:.2}% | {:.6} | {:.4} | {:.4} | {:.2}% |\n",
            row.initial_beta,
            row.inva_initial,
            row.inva1_initial,
            100.0 * row.rel_err_initial,
            row.optimized_beta,
            row.inva_optimized,
            row.inva1_optimized,
            100.0 * row.rel_err_optimized,
        ));
    }
    out
}

/// Table 4: NaN percentages of the FA(FP16-FP32) output for the paper's
/// six overflow cases (uniform & hybrid distributions).
pub fn table4(opts: &ExpOptions) -> String {
    let cases = [
        ("Uniform", Distribution::Uniform { x0: 30.0, am: 0.5 }),
        ("Uniform", Distribution::Uniform { x0: 20.0, am: 15.0 }),
        ("Uniform", Distribution::Uniform { x0: 20.0, am: 20.0 }),
        (
            "Hybrid",
            Distribution::Hybrid {
                x0: 30.0,
                am: 10.0,
                p: 0.001,
            },
        ),
        (
            "Hybrid",
            Distribution::Hybrid {
                x0: 20.0,
                am: 50.0,
                p: 0.001,
            },
        ),
        (
            "Hybrid",
            Distribution::Hybrid {
                x0: 20.0,
                am: 100.0,
                p: 0.001,
            },
        ),
    ];
    let mut out = String::from(
        "# Table 4 — NaN Percentages of FA(FP16-FP32) Output\n\
         | # | Distribution | x0 | Am | NaN % | overflow? |\n",
    );
    for (i, (kind, dist)) in cases.iter().enumerate() {
        let mh = gen_multihead(*dist, opts.heads, opts.seq, opts.dim, opts.seed + i as u64);
        let req = AttentionRequest::from_multihead(&mh, Allocation::Fa16_32).with_fp16_inputs();
        let res = req.run();
        let mut nan_total = 0.0;
        let mut n = 0usize;
        for o in &res.heads {
            nan_total += nan_percentage(&o.data) * o.data.len() as f64 / 100.0;
            n += o.data.len();
        }
        let pct = 100.0 * nan_total / n as f64;
        let (x0, am) = match dist {
            Distribution::Uniform { x0, am } => (*x0, *am),
            Distribution::Hybrid { x0, am, .. } => (*x0, *am),
        };
        out.push_str(&format!(
            "| {} | {kind} | {x0} | {am} | {pct:.2}% | {} |\n",
            i + 1,
            if pct > 0.0 { "YES" } else { "no" }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_paper_rows() {
        let t = table1();
        assert!(t.contains("FP16"));
        assert!(t.contains("6.55040e4"), "{t}");
        assert!(t.contains("4.48000e2"), "{t}");
    }

    #[test]
    fn table3_optimized_error_is_zero() {
        let t = table3();
        // every optimized rel-err column reads 0.00%
        let zero_cols = t.matches("| 0.00% |\n").count();
        assert_eq!(zero_cols, 6, "table:\n{t}");
    }

    #[test]
    fn table4_overflow_pattern_matches_paper() {
        // The paper's six cases all overflow at (1, 16, 1280, 128); at the
        // reduced test size the low-probability-outlier cases (2, 5: tiny
        // NaN percentages of 0.12%/0.04% in the paper) may not trigger,
        // so require the strong cases and the 100% saturation of case 1.
        let opts = ExpOptions {
            heads: 1,
            seq: 640,
            dim: 128,
            ..Default::default()
        };
        let t = table4(&opts);
        assert!(t.matches("YES").count() >= 4, "table:\n{t}");
        let line1 = t.lines().find(|l| l.starts_with("| 1 |")).unwrap();
        assert!(line1.contains("100.00%"), "{line1}");
        let line4 = t.lines().find(|l| l.starts_with("| 4 |")).unwrap();
        assert!(line4.contains("100.00%"), "{line4}");
    }
}
