//! Figures 9 and 10: relative-RMSE comparison of the three precision
//! allocations (FA-FP32, FA-FP16/FP32, PASA-FP16) over the random
//! benchmark distributions. Each distribution becomes one multi-head
//! [`AttentionRequest`] (the paper's (1, 16, 1280, 128) tensor); the
//! kernels fan heads out over threads internally.

use super::ExpOptions;
use crate::attention::{Allocation, AttentionRequest, KernelRegistry};
use crate::numerics::relative_rmse;
use crate::workloads::{gen_multihead, Distribution};

/// RMSE (mean over heads) for one allocation on one distribution;
/// NaN if any head overflowed (the paper plots a "NAN" marker).
pub fn rmse_for(dist: Distribution, alloc: Allocation, opts: &ExpOptions) -> f64 {
    let mh = gen_multihead(dist, opts.heads, opts.seq, opts.dim, opts.seed);
    let req = AttentionRequest::from_multihead(&mh, alloc).with_fp16_inputs();
    let golden = KernelRegistry::naive().forward(&req);
    let out = req.run();
    let errs: Vec<f64> = out
        .heads
        .iter()
        .zip(&golden.heads)
        .map(|(o, g)| relative_rmse(&o.data, &g.data))
        .collect();
    if errs.iter().any(|e| e.is_nan()) {
        f64::NAN
    } else {
        errs.iter().sum::<f64>() / errs.len() as f64
    }
}

const ALLOCS: [Allocation; 3] = [Allocation::Fa32, Allocation::Fa16_32, Allocation::Pasa16];

fn sweep(title: &str, dists: &[(f64, Distribution)], xlabel: &str, opts: &ExpOptions) -> String {
    let mut out = format!("# {title}\n| {xlabel} | FA(FP32) | FA(FP16-FP32) | PASA(FP16) |\n");
    for (x, dist) in dists {
        let mut row = format!("| {x} |");
        for alloc in ALLOCS {
            let e = rmse_for(*dist, alloc, opts);
            if e.is_nan() {
                row.push_str(" NAN |");
            } else {
                row.push_str(&format!(" {e:.3e} |"));
            }
        }
        out.push_str(&row);
        out.push('\n');
    }
    out
}

/// Fig. 9(a): uniform, Am = 0.5 fixed, mean x0 swept.
pub fn fig9a(opts: &ExpOptions) -> String {
    let xs = [0.0, 1.0, 5.0, 10.0, 20.0, 30.0];
    let dists: Vec<(f64, Distribution)> = xs
        .iter()
        .map(|&x0| (x0, Distribution::Uniform { x0, am: 0.5 }))
        .collect();
    sweep(
        "Fig 9(a) — RMSE, uniform, Am=0.5, varying mean x0",
        &dists,
        "x0",
        opts,
    )
}

/// Fig. 9(b): uniform, x0 = 20 fixed, amplitude Am swept.
pub fn fig9b(opts: &ExpOptions) -> String {
    let ams = [0.5, 1.0, 5.0, 10.0, 15.0, 20.0];
    let dists: Vec<(f64, Distribution)> = ams
        .iter()
        .map(|&am| (am, Distribution::Uniform { x0: 20.0, am }))
        .collect();
    sweep(
        "Fig 9(b) — RMSE, uniform, x0=20, varying amplitude Am",
        &dists,
        "Am",
        opts,
    )
}

/// Fig. 10(a): hybrid normal–Bernoulli, Am = 10 fixed, x0 swept.
pub fn fig10a(opts: &ExpOptions) -> String {
    let xs = [0.0, 1.0, 5.0, 10.0, 20.0, 30.0];
    let dists: Vec<(f64, Distribution)> = xs
        .iter()
        .map(|&x0| {
            (
                x0,
                Distribution::Hybrid {
                    x0,
                    am: 10.0,
                    p: 0.001,
                },
            )
        })
        .collect();
    sweep(
        "Fig 10(a) — RMSE, hybrid, Am=10, varying mean x0",
        &dists,
        "x0",
        opts,
    )
}

/// Fig. 10(b): hybrid, x0 = 20 fixed, Am swept.
pub fn fig10b(opts: &ExpOptions) -> String {
    let ams = [10.0, 20.0, 50.0, 100.0];
    let dists: Vec<(f64, Distribution)> = ams
        .iter()
        .map(|&am| {
            (
                am,
                Distribution::Hybrid {
                    x0: 20.0,
                    am,
                    p: 0.001,
                },
            )
        })
        .collect();
    sweep(
        "Fig 10(b) — RMSE, hybrid, x0=20, varying amplitude Am",
        &dists,
        "Am",
        opts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_opts() -> ExpOptions {
        ExpOptions {
            heads: 1,
            seq: 256,
            dim: 128,
            trace_scale: 16,
            seed: 3,
        }
    }

    #[test]
    fn fig9a_shape_matches_paper() {
        // Paper: overflow (NaN) appears at x0=30 only for FA(FP16-FP32);
        // PASA and FA(FP32) never overflow; PASA beats FA16-32 on biased
        // data but is behind FA(FP32).
        let opts = fast_opts();
        let x30 = Distribution::Uniform { x0: 30.0, am: 0.5 };
        assert!(rmse_for(x30, Allocation::Fa16_32, &opts).is_nan());
        let p = rmse_for(x30, Allocation::Pasa16, &opts);
        let f32e = rmse_for(x30, Allocation::Fa32, &opts);
        assert!(!p.is_nan() && !f32e.is_nan());
        assert!(f32e < p, "FA32 {f32e} should beat PASA {p}");
        let x10 = Distribution::Uniform { x0: 10.0, am: 0.5 };
        let e_fa = rmse_for(x10, Allocation::Fa16_32, &opts);
        let e_p = rmse_for(x10, Allocation::Pasa16, &opts);
        assert!(e_p < e_fa, "PASA {e_p} should beat FA16-32 {e_fa} at x0=10");
    }

    #[test]
    fn fig10b_overflow_at_large_amplitude() {
        // Paper: hybrid x0=20 overflows FA(FP16-FP32) for Am >= ~20.
        let opts = fast_opts();
        let big = Distribution::Hybrid {
            x0: 20.0,
            am: 100.0,
            p: 0.001,
        };
        assert!(rmse_for(big, Allocation::Fa16_32, &opts).is_nan());
        assert!(!rmse_for(big, Allocation::Pasa16, &opts).is_nan());
    }

    #[test]
    fn fp8_allocation_rmse_sweep_vs_f32_golden() {
        // The Allocation::Fp8 validation sweep: in the small-score regime
        // the E4M3 score store tracks the f32 golden within its (coarse,
        // eps = 6.25e-2) envelope — an order looser than the FP16 paths,
        // but finite and bounded.
        let opts = fast_opts();
        for x0 in [0.0, 0.25] {
            let dist = Distribution::Uniform { x0, am: 0.5 };
            let e = rmse_for(dist, Allocation::Fp8, &opts);
            assert!(!e.is_nan(), "x0={x0}: FP8 overflowed in the benign regime");
            assert!(e < 0.3, "x0={x0}: FP8 rmse {e} beyond the E4M3 envelope");
            // Sanity: the same data is far tighter under FP16 scores.
            let e16 = rmse_for(dist, Allocation::Fa16_32, &opts);
            assert!(e16 < 0.05, "x0={x0}: FA16-32 rmse {e16}");
        }
    }

    #[test]
    fn fp8_overflow_site_is_448_not_65504() {
        // Scores near 512 sit comfortably inside FP16 but past E4M3's 448:
        // the FP8 row must poison exactly where its own boundary says,
        // while FA16-32 sails through.
        let opts = fast_opts();
        let dist = Distribution::Uniform { x0: 2.0, am: 0.25 };
        assert!(
            rmse_for(dist, Allocation::Fp8, &opts).is_nan(),
            "S ≈ 2²·128 = 512 > 448 must overflow the E4M3 store"
        );
        assert!(!rmse_for(dist, Allocation::Fa16_32, &opts).is_nan());
        assert!(!rmse_for(dist, Allocation::Pasa16, &opts).is_nan());
    }

    #[test]
    fn pasa8_rescues_the_fp8_overflow_site() {
        // The Pasa8 overflow-site twin (the tentpole's acceptance case):
        // the *very same* staged S ≈ 512 distribution that poisons the
        // plain FP8 row above runs finite under Pasa8 — the
        // pseudo-average shift collapses the bias before the E4M3 store —
        // with zero pre-store overflow events and RMSE ≤ 0.3 against the
        // f32 golden.
        let opts = fast_opts();
        let dist = Distribution::Uniform { x0: 2.0, am: 0.25 };
        // Premise (same staging as the FP8 test): the unshifted E4M3
        // store poisons.
        assert!(rmse_for(dist, Allocation::Fp8, &opts).is_nan());

        let mh = gen_multihead(dist, opts.heads, opts.seq, opts.dim, opts.seed);
        let req = AttentionRequest::from_multihead(&mh, Allocation::Pasa8).with_fp16_inputs();
        let out = req.run();
        assert!(!out.overflowed(), "Pasa8 must survive the 448 site");
        assert_eq!(out.overflow_events(), 0, "zero pre-store events required");
        assert!(
            out.max_abs_score() < 448.0,
            "shifted store peak {} must sit inside E4M3",
            out.max_abs_score()
        );
        assert_eq!(out.score_boundary, 448.0);
        let golden = KernelRegistry::naive().forward(&req);
        for h in 0..out.heads.len() {
            let e = relative_rmse(&out.heads[h].data, &golden.heads[h].data);
            assert!(e <= 0.3, "head {h}: Pasa8 rmse {e} beyond the acceptance bound");
        }
    }
}
