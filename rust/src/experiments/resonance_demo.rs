//! Figure 6: the two resonance categories — phase coincidence (large
//! positive scores) and 180°-shift (large negative scores).

use super::ExpOptions;
use crate::attention::raw_scores_f32;
use crate::numerics::finite_range;
use crate::workloads::{ResonanceCategory, ResonanceSpec};

fn spec(cat: ResonanceCategory, opts: &ExpOptions) -> ResonanceSpec {
    ResonanceSpec {
        s1: 128,
        s2: 128,
        d: opts.dim,
        wavelength: 8.0,
        amp_q: 12.0,
        amp_k: 12.0,
        bias_q: 0.0,
        bias_k: 0.0,
        noise: 0.5,
        category: cat,
        participation: 1.0,
        flip_fraction: 0.0,
        flip_amp_scale: 0.0,
    }
}

/// Demonstrate both categories, printing the score ranges and the
/// coherent-amplification prediction amp_q·amp_k·d/2.
pub fn fig6(opts: &ExpOptions) -> String {
    let mut out = String::from(
        "# Fig 6 — Resonance Categories in Attention Calculation\n\
         | category | phase lag | predicted peak | S range | dominant sign |\n",
    );
    for (cat, lag) in [
        (ResonanceCategory::AntiPhase, "180 deg"),
        (ResonanceCategory::InPhase, "0 deg"),
    ] {
        let sp = spec(cat, opts);
        let case = sp.generate(opts.seed);
        // Raw-score probe only — no kernel dispatch, so the lab's free
        // instrumentation helper is the right altitude (clone-free).
        let s = raw_scores_f32(&case);
        let (lo, hi) = finite_range(&s.data);
        let sign = if lo.abs() > hi.abs() {
            "negative (cat 1)"
        } else {
            "positive (cat 2)"
        };
        out.push_str(&format!(
            "| {cat:?} | {lag} | {:.0} | [{lo:.0}, {hi:.0}] | {sign} |\n",
            sp.predicted_peak()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul_nt, GemmPrecision};

    #[test]
    fn categories_have_opposite_dominant_signs() {
        let opts = ExpOptions::default();
        let anti = spec(ResonanceCategory::AntiPhase, &opts).generate(1);
        let inph = spec(ResonanceCategory::InPhase, &opts).generate(1);
        let sa = matmul_nt(&anti.q, &anti.k, GemmPrecision::F32);
        let si = matmul_nt(&inph.q, &inph.k, GemmPrecision::F32);
        let (alo, ahi) = finite_range(&sa.data);
        let (ilo, ihi) = finite_range(&si.data);
        assert!(alo.abs() > ahi.abs(), "anti-phase should be negative-dominant");
        assert!(ihi.abs() > ilo.abs(), "in-phase should be positive-dominant");
    }

    #[test]
    fn predicted_peak_is_right_order() {
        let opts = ExpOptions::default();
        let sp = spec(ResonanceCategory::InPhase, &opts);
        let case = sp.generate(2);
        let s = matmul_nt(&case.q, &case.k, GemmPrecision::F32);
        let (_lo, hi) = finite_range(&s.data);
        let pred = sp.predicted_peak();
        assert!(
            hi as f64 > 0.3 * pred && (hi as f64) < 3.0 * pred,
            "peak {hi} vs predicted {pred}"
        );
    }
}
