//! Figures 7 and 11–14: distribution/range statistics of the model-shaped
//! overflow traces (Qwen2, SVD-IMG2VID substitutes), before and after the
//! PASA preprocessing.

use super::ExpOptions;
use crate::attention::{preprocess_k, shifting_matrix, PAPER_BETA};
use crate::numerics::{finite_range, Format};
use crate::tensor::{matmul_nt, GemmPrecision, Matrix};
use crate::workloads::{all_traces, TraceSpec};

fn trace_by_name(name: &str, scale: usize) -> Option<TraceSpec> {
    all_traces(scale).into_iter().find(|t| t.name == name)
}

/// Fig. 7: center-line sampling of Q and K along head and sequence dims
/// for the SVD trace — oscillation along the head dim, bias along the
/// sequence dim, and the post-PASA collapse.
pub fn fig7(opts: &ExpOptions) -> String {
    let t = trace_by_name("svd-img2vid", opts.trace_scale.max(4)).unwrap();
    let case = t.generate(opts.seed);
    let mid_row = case.k.rows / 2;
    let mid_col = case.k.cols / 2;
    let head_line: Vec<f32> = (0..case.k.cols.min(16))
        .map(|j| case.k.at(mid_row, j))
        .collect();
    let seq_line: Vec<f32> = (0..8).map(|i| case.k.at(i * case.k.rows / 8, mid_col)).collect();
    // Post-PASA K'.
    let alpha = (case.k.cols as f64).sqrt();
    let bs = 128.min(case.k.rows);
    let m = shifting_matrix(bs, alpha, PAPER_BETA, Format::F16);
    let kp0 = preprocess_k(&case.k.rows_slice(0, bs), &m, GemmPrecision::ACC32_STORE16);
    let head_line_p: Vec<f32> = (0..kp0.cols.min(16)).map(|j| kp0.at(bs / 2, j)).collect();
    let fmt = |v: &[f32]| -> String {
        v.iter().map(|x| format!("{x:.2}")).collect::<Vec<_>>().join(", ")
    };
    format!(
        "# Fig 7 — Center-line Q/K Distribution (SVD-IMG2VID trace)\n\
         K along head dim (oscillation):   [{}]\n\
         K along seq dim (shared bias):    [{}]\n\
         K' along head dim (post-PASA):    [{}]\n\
         K range before: {:?}  after: {:?}\n",
        fmt(&head_line),
        fmt(&seq_line),
        fmt(&head_line_p),
        finite_range(&case.k.data),
        finite_range(&kp0.data),
    )
}

/// Figures 11–14: min/max cloud-map ranges for Q, K (figs 11–12) and the
/// raw vs PASA-preprocessed score matrices (figs 13–14), compared against
/// the paper's reported ranges.
pub fn fig_cloud(name: &str, scores: bool, opts: &ExpOptions) -> String {
    let t = trace_by_name(name, opts.trace_scale).unwrap();
    let case = t.generate(opts.seed);
    let c = crate::attention::to_fp16_inputs(&case);
    let alpha = (c.k.cols as f64).sqrt();
    if !scores {
        let (qlo, qhi) = finite_range(&c.q.data);
        let (klo, khi) = finite_range(&c.k.data);
        let m = shifting_matrix(128, alpha, PAPER_BETA, Format::F16);
        let kp = preprocess_blocks(&c.k, &m, 128);
        let (plo, phi) = finite_range(&kp.data);
        return format!(
            "# Fig 11/12 — Q/K Cloud-map Ranges ({name}, shape {:?})\n\
             | tensor | measured range | paper range |\n\
             | Q | [{qlo:.2}, {qhi:.2}] | (not reported) |\n\
             | K | [{klo:.2}, {khi:.2}] | [{:.2}, {:.2}] |\n\
             | K' (post-PASA) | [{plo:.3}, {phi:.3}] | reduced ~25-30x |\n",
            t.full_shape, t.paper_k_range.0, t.paper_k_range.1,
        );
    }
    // Score matrices: raw S vs preprocessed S' (per-block shift).
    let b16 = Format::F16.overflow_boundary() as f32;
    let s = matmul_nt(&c.q, &c.k, GemmPrecision::F32);
    let (slo, shi) = finite_range(&s.data);
    let m = shifting_matrix(128, alpha, PAPER_BETA, Format::F16);
    let kp = preprocess_blocks(&c.k, &m, 128);
    let sp = matmul_nt(&c.q, &kp, GemmPrecision::ACC32_STORE16);
    let (plo, phi) = finite_range(&sp.data);
    let fp16_ok = plo > -b16 && phi < b16;
    format!(
        "# Fig 13/14 — Score Matrix Ranges ({name})\n\
         | matrix | measured range | paper range | fits FP16? |\n\
         | S = QK^T (raw) | [{slo:.0}, {shi:.0}] | [{:.0}, {:.0}] | {} |\n\
         | S' (post-PASA) | [{plo:.1}, {phi:.1}] | [{:.0}, {:.0}] | {} |\n",
        t.paper_s_range.0,
        t.paper_s_range.1,
        if slo > -b16 && shi < b16 { "yes" } else { "NO (overflow)" },
        t.paper_s_range_pasa.0,
        t.paper_s_range_pasa.1,
        if fp16_ok { "yes" } else { "NO" },
    )
}

/// Apply M per 128-row block of K (ragged tail gets its own M).
fn preprocess_blocks(k: &Matrix, m128: &Matrix, bs: usize) -> Matrix {
    let alpha = (k.cols as f64).sqrt();
    let mut out = Matrix::zeros(k.rows, k.cols);
    let mut r0 = 0;
    while r0 < k.rows {
        let r1 = (r0 + bs).min(k.rows);
        let kb = k.rows_slice(r0, r1);
        let kp = if r1 - r0 == bs {
            preprocess_k(&kb, m128, GemmPrecision::ACC32_STORE16)
        } else {
            let mt = shifting_matrix(r1 - r0, alpha, PAPER_BETA, Format::F16);
            preprocess_k(&kb, &mt, GemmPrecision::ACC32_STORE16)
        };
        for (i, r) in (r0..r1).enumerate() {
            out.row_mut(r).copy_from_slice(kp.row(i));
        }
        r0 = r1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_opts() -> ExpOptions {
        ExpOptions {
            trace_scale: 16,
            seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn qwen2_scores_overflow_then_fit_after_pasa() {
        let opts = fast_opts();
        let rep = fig_cloud("qwen2-7b", true, &opts);
        assert!(rep.contains("NO (overflow)"), "{rep}");
        // the post-PASA row must fit
        let last = rep.lines().last().unwrap();
        assert!(last.contains("| yes |"), "{rep}");
    }

    #[test]
    fn svd_scores_overflow_then_fit_after_pasa() {
        let opts = fast_opts();
        let rep = fig_cloud("svd-img2vid", true, &opts);
        assert!(rep.contains("NO (overflow)"), "{rep}");
        let last = rep.lines().last().unwrap();
        assert!(last.contains("| yes |"), "{rep}");
    }

    #[test]
    fn k_range_collapses() {
        let opts = fast_opts();
        let rep = fig_cloud("qwen2-7b", false, &opts);
        assert!(rep.contains("K'"), "{rep}");
    }

    #[test]
    fn fig7_reports_lines() {
        let rep = fig7(&fast_opts());
        assert!(rep.contains("K along head dim"));
        assert!(rep.contains("post-PASA"));
    }
}
