//! Persistent worker pool (S15): the fan-out substrate of the attention
//! hot path.
//!
//! The kernels' multi-head execution used to spawn-and-join one OS thread
//! per head per forward (`thread::scope`), which made thread churn — not
//! the math — the dominant cost of decode-shaped requests and a real tax
//! on prefill. This module replaces that with one **lazily-initialized,
//! process-wide pool** of `available_parallelism() − 1` workers (the
//! submitting thread always participates, so total concurrency equals the
//! core count). Work arrives as a *batch* of indexed tiles; workers and
//! the submitter claim tile indices from a shared atomic cursor — simple
//! work stealing: whoever is free takes the next (head × Q-block) tile,
//! so a straggler head no longer serializes the whole forward.
//!
//! Determinism contract: [`WorkerPool::run_tiles`] executes `f(t)` exactly
//! once for every `t < total`, with tiles writing disjoint outputs. The
//! lab's tiles are pure functions of their inputs, so pooled execution is
//! bit-identical to the sequential fallback ([`set_parallel`]`(false)`,
//! the goldens' test hook) by construction.
//!
//! Sizing knob: `PASA_POOL_THREADS=<n>` caps the pool (0 ⇒ fully
//! sequential). Read once at first use.
//!
//! Nesting is allowed and deadlock-free: a worker that submits a nested
//! batch (e.g. an engine slot tile whose attention fans out per head)
//! drives its own batch to completion before waiting, so progress never
//! depends on another thread being idle.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// One submitted fan-out: a lifetime-erased tile closure plus claim and
/// completion state. See `run_tiles` for the safety argument that keeps
/// the erased borrow sound.
struct Batch {
    /// The tile body. Erased to `'static`; only ever invoked while the
    /// submitting `run_tiles` frame is alive.
    job: &'static (dyn Fn(usize) + Sync),
    /// Next unclaimed tile index (may overshoot `total`; claims at or
    /// past `total` are no-ops).
    next: AtomicUsize,
    total: usize,
    /// Count of *finished* tiles; guarded so completion can be awaited.
    finished: Mutex<usize>,
    done_cv: Condvar,
    /// First tile panic's payload, re-raised on the submitter so the
    /// original message survives (the `join().unwrap()` semantics the
    /// per-head `thread::scope` used to provide).
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
}

/// Lock a mutex, ignoring poison: pool state stays consistent under
/// panics (counters are plain integers, the queue holds `Arc`s), and the
/// panic payload is re-raised on the submitter anyway — propagating the
/// poison here would just turn one tile panic into a wedged pool.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Batch {
    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.total
    }

    /// Claim-and-run tiles until none remain unclaimed. Panics inside a
    /// tile are caught and recorded so the submitter can re-raise them
    /// instead of wedging the completion count.
    // lint: hot-path — tile claim/finish bookkeeping; runs once per tile.
    fn work(&self) {
        loop {
            let t = self.next.fetch_add(1, Ordering::Relaxed);
            if t >= self.total {
                return;
            }
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| (self.job)(t))) {
                let mut p = lock_ignore_poison(&self.panic);
                if p.is_none() {
                    *p = Some(payload);
                }
            }
            let mut done = lock_ignore_poison(&self.finished);
            *done += 1;
            // Notify on *every* completion, not only the last: a
            // cancelled batch (see `BatchGuard`) waits for its *claimed*
            // count, which can be any value below `total`.
            self.done_cv.notify_all();
        }
    }
    // lint: end-hot-path
}

/// Scope guard keeping the `'static`-erased job borrow sound: created
/// before the batch becomes visible to workers and dropped before
/// `run_tiles` returns — **on unwind too**. Drop (a) cancels the claim
/// cursor so no worker starts another tile, (b) blocks until every
/// already-claimed tile has finished, and (c) dequeues the batch. After
/// that, no thread can ever invoke `job` again, so the borrow never
/// escapes the submitting stack frame even if the submitter panics.
struct BatchGuard<'a> {
    batch: &'a Arc<Batch>,
    shared: &'a Shared,
}

impl Drop for BatchGuard<'_> {
    fn drop(&mut self) {
        // Cancel: saturate the claim cursor. `prev` is how many claims
        // were handed out before the cancel; each claim below `total`
        // runs exactly one tile and bumps `finished`, so waiting for
        // `finished >= min(prev, total)` drains every in-flight tile.
        // On the normal path the cursor is already past `total`
        // (the submitter's own `work()` ran it dry), so `claimed ==
        // total` and this is the plain completion wait.
        let prev = self.batch.next.fetch_max(self.batch.total, Ordering::SeqCst);
        let claimed = prev.min(self.batch.total);
        {
            let mut done = lock_ignore_poison(&self.batch.finished);
            while *done < claimed {
                done = self
                    .batch
                    .done_cv
                    .wait(done)
                    .unwrap_or_else(|e| e.into_inner());
            }
        }
        let mut q = lock_ignore_poison(&self.shared.queue);
        q.retain(|b| !Arc::ptr_eq(b, self.batch));
    }
}

struct Shared {
    /// Batches with unclaimed tiles. Submitters push, everyone claims,
    /// exhausted entries are pruned by waiting workers and by the
    /// submitter on completion.
    queue: Mutex<Vec<Arc<Batch>>>,
    work_cv: Condvar,
}

/// The shared tile-execution pool. Obtain via [`global`].
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: usize,
}

impl WorkerPool {
    fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            work_cv: Condvar::new(),
        });
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("pasa-worker-{i}"))
                .spawn(move || worker_loop(sh))
                .expect("spawn pool worker");
        }
        WorkerPool { shared, workers }
    }

    /// Number of background workers (the submitter is the `+1`).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute `f(0..total)`, each index exactly once, across the pool
    /// and the calling thread; returns when every tile has finished.
    /// Panics (on the caller) if any tile panicked. Falls back to an
    /// in-order sequential loop when the pool has no workers, there is
    /// only one tile, or [`set_parallel`]`(false)` is in effect.
    pub fn run_tiles<F: Fn(usize) + Sync>(&self, total: usize, f: F) {
        if total == 0 {
            return;
        }
        if self.workers == 0 || total == 1 || !parallel_enabled() {
            for t in 0..total {
                f(t);
            }
            return;
        }
        let job: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the erased borrow outlives every use, on every exit
        // path. Lifetime argument: (1) `job` is only invoked by `work()`,
        // which claims indices strictly below `total` from the cursor;
        // (2) a `BatchGuard` is armed *before* the batch becomes visible
        // to any worker, and its Drop — which runs before this frame is
        // torn down even if `work()` or a pool lock panics — saturates
        // the cursor (no new claims) and blocks until every claimed tile
        // has finished; (3) therefore when this frame exits, no thread
        // holds or can re-acquire a path to `job`: a worker still holding
        // the `Arc<Batch>` observes an exhausted cursor and never
        // dereferences the closure again.
        let job: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(job) };
        let batch = Arc::new(Batch {
            job,
            next: AtomicUsize::new(0),
            total,
            finished: Mutex::new(0),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        // Armed before publication: unwinding past this point cancels the
        // batch and drains in-flight tiles instead of leaking `job`.
        let guard = BatchGuard {
            batch: &batch,
            shared: &self.shared,
        };
        {
            let mut q = lock_ignore_poison(&self.shared.queue);
            q.push(Arc::clone(&batch));
        }
        self.shared.work_cv.notify_all();
        // The submitter works its own batch: guarantees progress even if
        // every worker is busy elsewhere (and makes nesting safe).
        batch.work();
        // Normal path: the cursor is exhausted, so the guard's drop is
        // exactly the old "wait for finished == total, then dequeue".
        drop(guard);
        if let Some(payload) = lock_ignore_poison(&batch.panic).take() {
            resume_unwind(payload);
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let batch = {
            let mut q = lock_ignore_poison(&shared.queue);
            loop {
                if let Some(b) = q.iter().find(|b| !b.exhausted()) {
                    break Arc::clone(b);
                }
                q.retain(|b| !b.exhausted());
                q = shared.work_cv.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        batch.work();
    }
}

/// Pool width: `PASA_POOL_THREADS` if set (0 ⇒ sequential), otherwise
/// `available_parallelism`.
fn configured_parallelism() -> usize {
    if let Ok(s) = std::env::var("PASA_POOL_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            return n;
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// The process-wide pool, spawned on first use.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(configured_parallelism().saturating_sub(1)))
}

static PARALLEL: AtomicBool = AtomicBool::new(true);

/// Test hook: force [`WorkerPool::run_tiles`] into its in-order
/// sequential fallback (`false`) or restore pooled execution (`true`).
/// The bit-identity goldens run both modes and assert equal checksums.
///
/// The mode is **process-global**: tests that toggle it must hold
/// [`test_mode_guard`] across the toggle-and-compare sequence, or a
/// concurrently running test's toggle can silently change which mode a
/// "sequential baseline" actually executed in (the outputs stay
/// bit-identical either way — that's the invariant — but the comparison
/// would stop discriminating).
pub fn set_parallel(enabled: bool) {
    PARALLEL.store(enabled, Ordering::SeqCst);
}

/// Serializes tests that toggle [`set_parallel`] within one process.
/// Poisoning is ignored: a panicked holder's assertion failure is its
/// own test's problem, not a reason to abort the others.
pub fn test_mode_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// Whether pooled execution is currently enabled (see [`set_parallel`]).
pub fn parallel_enabled() -> bool {
    PARALLEL.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_tile_runs_exactly_once() {
        let pool = global();
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        pool.run_tiles(hits.len(), |t| {
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        for (t, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "tile {t}");
        }
    }

    #[test]
    fn sequential_fallback_matches_pooled_sum() {
        let _mode = test_mode_guard();
        let pool = global();
        let run = |tiles: usize| {
            let acc = AtomicU64::new(0);
            pool.run_tiles(tiles, |t| {
                acc.fetch_add((t as u64 + 1) * (t as u64 + 1), Ordering::Relaxed);
            });
            acc.load(Ordering::Relaxed)
        };
        let pooled = run(100);
        set_parallel(false);
        let sequential = run(100);
        set_parallel(true);
        assert_eq!(pooled, sequential);
        assert_eq!(pooled, (1..=100u64).map(|x| x * x).sum::<u64>());
    }

    #[test]
    fn nested_submission_completes() {
        let pool = global();
        let acc = AtomicU64::new(0);
        pool.run_tiles(8, |_| {
            pool.run_tiles(8, |t| {
                acc.fetch_add(t as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(acc.load(Ordering::Relaxed), 8 * 28);
    }

    #[test]
    fn tile_panic_propagates_to_the_submitter() {
        let pool = global();
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run_tiles(4, |t| {
                if t == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "tile panic must reach the submitter");
        // The pool must remain usable afterwards.
        let acc = AtomicU64::new(0);
        pool.run_tiles(4, |t| {
            acc.fetch_add(t as u64, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), 6);
    }
}
