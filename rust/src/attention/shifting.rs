//! The shifting matrix M (S4) — paper Eq. (10)–(12) and Theorem 2.1.
//!
//! ```text
//! M = I/α − β·J/(α·s₂)
//! ```
//!
//! Applying M to the right of Kᵀ subtracts β× the pseudo-average of K along
//! the sequence dimension *and* applies the static 1/α scaling, in a single
//! batched GEMM that runs on the matrix engine — the paper's replacement
//! for the vector-unit mean-subtract of SageAttention.

use crate::numerics::Format;
use crate::tensor::{matmul_nn, GemmPrecision, Matrix};

/// Build M ∈ R^{n×n} for block size `n`, head-dim scale α = √d, rounded to
/// `fmt` (Algorithm 1: M's precision is FP16).
pub fn shifting_matrix(n: usize, alpha: f64, beta: f64, fmt: Format) -> Matrix {
    let diag = fmt.fl((1.0 - beta / n as f64) / alpha) as f32;
    let off = fmt.fl(-beta / (n as f64 * alpha)) as f32;
    let mut m = Matrix::full(n, n, off);
    for i in 0..n {
        m.set(i, i, diag);
    }
    m
}

/// Theorem 2.1: for M = I − λJ (n×n, λ·n ≠ 1), M⁻¹ = I + λ/(1−λn)·J.
/// Returned in f32 for verification/tests.
pub fn shifting_inverse(n: usize, lambda: f64) -> Matrix {
    assert!(
        (1.0 - lambda * n as f64).abs() > 1e-12,
        "shifting matrix is singular at λ·n = 1"
    );
    let off = (lambda / (1.0 - lambda * n as f64)) as f32;
    let mut m = Matrix::full(n, n, off);
    for i in 0..n {
        m.set(i, i, 1.0 + off);
    }
    m
}

/// Preprocess one KV block: K'_j = M·K_j (equivalently K'ᵀ = Kᵀ·M since M
/// is symmetric) — Algorithm 1 line 6, a batched GEMM on the matrix
/// engine. `gemm` controls the engine's accumulate/store precision.
pub fn preprocess_k(k_block: &Matrix, m: &Matrix, gemm: GemmPrecision) -> Matrix {
    assert_eq!(m.rows, k_block.rows, "M size must match the KV block rows");
    matmul_nn(m, k_block, gemm)
}

/// The *effective* recovery invariant of a rounded shifting matrix.
///
/// Writing the stored matrix as M_fp = a'I − b'J (a' = diag + b'), the
/// mean-leakage-free recovery constant is c_eff = b'n/(a' − b'n): adding
/// c_eff·rowmean(S') to S' = S·M_fp reproduces a'·S + (per-row constant),
/// i.e. the true scores up to the common temperature a'α ≈ 1 and a shift
/// softmax ignores. This generalizes the paper's Eq. 20 (whose a, b omit
/// the α folding of Eq. 10) and makes the correction exact for *any*
/// rounded M — including ragged tail blocks of a different width, where
/// the paper's fixed Inva = β/(1−β) leaves an O(1) aliasing error in the
/// exponent (see DESIGN.md §PASA-deviations and the regression tests).
pub fn effective_invariant(m: &Matrix) -> f32 {
    let n = m.rows;
    if n == 1 {
        return 0.0;
    }
    let off = -(m.at(0, 1) as f64);
    if off == 0.0 {
        return 0.0; // β = 0: PASA degrades to FA2, no correction
    }
    let a = m.at(0, 0) as f64 + off;
    let bn = off * n as f64;
    (bn / (a - bn)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::rowmean;

    #[test]
    fn m_subtracts_scaled_mean() {
        // K' = M·K must equal (K − β·K̄)/α where K̄ broadcasts the
        // per-column mean over rows (Eq. 11).
        let n = 8;
        let d = 4;
        let alpha = (d as f64).sqrt();
        let beta = 0.9375; // exact in FP16 — no rounding noise in this test
        let m = shifting_matrix(n, alpha, beta, Format::F32);
        let mut k = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                k.set(i, j, (i * d + j) as f32 * 0.25 - 3.0);
            }
        }
        let kp = preprocess_k(&k, &m, GemmPrecision::F32);
        // column means of K
        let kt = k.transpose();
        let col_means = rowmean(&kt, Format::F32);
        for i in 0..n {
            for j in 0..d {
                let expect = (k.at(i, j) - beta as f32 * col_means[j]) / alpha as f32;
                assert!(
                    (kp.at(i, j) - expect).abs() < 1e-5,
                    "({i},{j}): {} vs {expect}",
                    kp.at(i, j)
                );
            }
        }
    }

    #[test]
    fn theorem_2_1_inverse() {
        // M = I − λJ, M⁻¹ = I + λ/(1−λn)J; their product must be I.
        let n = 6;
        let lambda = 0.984497 / n as f64;
        let mut m = Matrix::full(n, n, -lambda as f32);
        for i in 0..n {
            m.set(i, i, 1.0 - lambda as f32);
        }
        let minv = shifting_inverse(n, lambda);
        let prod = matmul_nn(&m, &minv, GemmPrecision::F32);
        for i in 0..n {
            for j in 0..n {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (prod.at(i, j) - expect).abs() < 1e-5,
                    "({i},{j}) = {}",
                    prod.at(i, j)
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn inverse_rejects_lambda_n_equal_one() {
        // Theorem 2.1's condition: λ·n = 1 (β = 1) has no inverse.
        shifting_inverse(4, 0.25);
    }

    #[test]
    fn fp16_rounding_changes_effective_beta() {
        // Appendix A's premise: (1 − β/s₂) and (−β/s₂) are not exactly
        // representable, so the rounded M encodes a slightly different β.
        let n = 128;
        let m_exact = shifting_matrix(n, 1.0, 0.99, Format::F32);
        let m_fp16 = shifting_matrix(n, 1.0, 0.99, Format::F16);
        assert_ne!(m_exact.at(0, 1), m_fp16.at(0, 1));
        // ... while the paper's optimized β = 0.9375 at α=1 survives:
        // β/n = 0.9375/128 = 0.00732421875 = 15·2⁻11 exact in FP16.
        let a = shifting_matrix(n, 1.0, 0.9375, Format::F32);
        let b = shifting_matrix(n, 1.0, 0.9375, Format::F16);
        assert_eq!(a.at(0, 1), b.at(0, 1));
        assert_eq!(a.at(0, 0), b.at(0, 0));
    }

    #[test]
    fn shift_reduces_mean_and_amplitude() {
        // Fig. 5: applying M collapses both the bias and the amplitude of
        // a biased K block.
        use crate::numerics::{finite_mean, finite_range};
        use crate::workloads::{Distribution, Pcg64};
        let n = 128;
        let d = 32;
        let mut rng = Pcg64::new(3, 0);
        let k = Distribution::Uniform { x0: 20.0, am: 0.5 }.matrix(n, d, &mut rng);
        let m = shifting_matrix(n, (d as f64).sqrt(), PAPER_BETA_LOCAL, Format::F16);
        let kp = preprocess_k(&k, &m, GemmPrecision::F32);
        let (lo0, hi0) = finite_range(&k.data);
        let (lo1, hi1) = finite_range(&kp.data);
        assert!(hi1 - lo1 < (hi0 - lo0), "amplitude not reduced");
        assert!(finite_mean(&kp.data).abs() < 0.1 * finite_mean(&k.data).abs());
    }

    const PAPER_BETA_LOCAL: f64 = 0.984497;
}
