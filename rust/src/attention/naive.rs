//! Golden-reference standard attention (§1.1's four steps) in full
//! precision — the `O_Golden` of the paper's RMSE metric (Eq. 19), with
//! prefix-mask support (causal / padded) so masked Flash/PASA runs have an
//! exact reference. A fully-masked query row is defined to produce a zero
//! output row (softmax over the empty set must not NaN).

use super::request::{HeadMask, HeadStats, KvView};
use super::workspace::{reset_vec, with_workspace};
use crate::numerics::Format;
use crate::tensor::{matmul_nt, matmul_nt_stats, GemmPrecision, GemmStats, Matrix};
use crate::workloads::AttentionCase;

/// O = softmax(Q·Kᵀ/α)·V with f32 GEMMs and f64-carried softmax.
pub fn naive_attention_f32(case: &AttentionCase) -> Matrix {
    naive_head(&case.q, &case.k, &case.v, HeadMask::None).0
}

/// Masked golden reference: query row `i` attends to the visible KV
/// prefix of `mask`; fully-masked rows yield zeros.
pub fn naive_attention_masked_f32(case: &AttentionCase, mask: HeadMask) -> Matrix {
    naive_head(&case.q, &case.k, &case.v, mask).0
}

/// Per-head golden kernel: f32 scores, f64 softmax and f64 P·V
/// accumulation over the visible prefix. Stats instrument the raw scores
/// against the FP16 boundary ("would a low-precision store overflow
/// here"), restricted to the visible region.
pub(crate) fn naive_head(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    mask: HeadMask,
) -> (Matrix, HeadStats) {
    naive_head_kv(q, KvView::Dense(k), KvView::Dense(v), mask)
}

/// View-based golden core. The reference is deliberately unblocked, so a
/// paged operand is gathered once into a dense `(len_tokens × d)` matrix —
/// still `O(len_tokens)`, never `O(max_seq)` — while dense views borrow
/// straight through with no copy. The bounded per-row scratch (the
/// visibility counts and f64 softmax buffers) comes from the thread
/// workspace; the unbounded (s1 × s2) score matrix deliberately does not
/// (see the comment below).
pub(crate) fn naive_head_kv(
    q: &Matrix,
    kview: KvView<'_>,
    vview: KvView<'_>,
    mask: HeadMask,
) -> (Matrix, HeadStats) {
    let k_owned: Matrix;
    let k: &Matrix = match kview {
        KvView::Dense(m) => m,
        _ => {
            k_owned = kview.to_matrix();
            &k_owned
        }
    };
    let v_owned: Matrix;
    let v: &Matrix = match vview {
        KvView::Dense(m) => m,
        _ => {
            v_owned = vview.to_matrix();
            &v_owned
        }
    };
    let (s1, d) = q.shape();
    let s2 = k.rows;
    let alpha = (d as f64).sqrt();
    let mut gstats = GemmStats::default();
    let mut out = Matrix::zeros(s1, v.cols);
    with_workspace(|ws| {
        mask.visible_rows_into(0, s1, s1, s2, &mut ws.vis);
        // The full (s1 × s2) score matrix stays a *local* allocation: the
        // golden reference is unblocked, and parking an arbitrarily large
        // buffer in the immortal thread workspace would pin the
        // largest-ever golden run's memory for process lifetime. Only the
        // bounded scratch (vis, per-row f64 buffers) uses the arena.
        let s = matmul_nt_stats(
            q,
            k,
            GemmPrecision::F32,
            Some(&ws.vis),
            Format::F16.overflow_boundary() as f32,
            &mut gstats,
        );
        reset_vec(&mut ws.p64, s2, 0.0);
        reset_vec(&mut ws.acc64, v.cols, 0.0);
        for i in 0..s1 {
            let n = ws.vis[i];
            if n == 0 {
                continue; // fully masked: zero row by definition
            }
            let row = s.row(i);
            let mut mx = f64::NEG_INFINITY;
            for &x in &row[..n] {
                mx = mx.max(x as f64 / alpha);
            }
            let mut sum = 0.0f64;
            for j in 0..n {
                let e = (row[j] as f64 / alpha - mx).exp();
                ws.p64[j] = e;
                sum += e;
            }
            ws.acc64.fill(0.0);
            for j in 0..n {
                let w = ws.p64[j] / sum;
                let vr = v.row(j);
                for (a, &vx) in ws.acc64.iter_mut().zip(vr) {
                    *a += w * vx as f64;
                }
            }
            let dst = out.row_mut(i);
            for (o, &a) in dst.iter_mut().zip(&ws.acc64) {
                *o = a as f32;
            }
        }
    });
    let stats = HeadStats::finish(gstats, &out);
    (out, stats)
}

/// The raw attention score matrix S = Q·Kᵀ (pre-scaling) in f32 — used by
/// the overflow studies (the paper's instrumentation checks max |S| against
/// 65504 at exactly this point).
pub fn raw_scores_f32(case: &AttentionCase) -> Matrix {
    matmul_nt(&case.q, &case.k, GemmPrecision::F32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{gen_case, Distribution, Pcg64};

    #[test]
    fn rows_are_convex_combinations() {
        let mut rng = Pcg64::new(5, 0);
        let c = gen_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 16, 24, 8, &mut rng);
        let o = naive_attention_f32(&c);
        assert_eq!(o.shape(), (16, 8));
        // Each output row lies within the convex hull of V's rows:
        for j in 0..8 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in 0..24 {
                lo = lo.min(c.v.at(r, j));
                hi = hi.max(c.v.at(r, j));
            }
            for i in 0..16 {
                let x = o.at(i, j);
                assert!(x >= lo - 1e-4 && x <= hi + 1e-4, "({i},{j})={x} not in [{lo},{hi}]");
            }
        }
    }

    #[test]
    fn uniform_value_rows_pass_through() {
        // If all V rows are identical, attention output equals that row
        // regardless of the scores.
        let mut rng = Pcg64::new(6, 0);
        let mut c = gen_case(Distribution::Uniform { x0: 5.0, am: 2.0 }, 8, 12, 4, &mut rng);
        for r in 0..12 {
            for j in 0..4 {
                c.v.set(r, j, (j as f32) - 1.5);
            }
        }
        let o = naive_attention_f32(&c);
        for i in 0..8 {
            for j in 0..4 {
                assert!((o.at(i, j) - ((j as f32) - 1.5)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn softmax_translation_invariance() {
        // Eq. (9): adding a constant row vector to K's contribution leaves
        // the output unchanged (softmax translation invariance).
        let mut rng = Pcg64::new(7, 0);
        let c = gen_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 8, 16, 4, &mut rng);
        let o1 = naive_attention_f32(&c);
        // Shift every K row by the same vector k0 — scores change by a
        // row-constant Q·k0ᵀ, softmax unchanged.
        let mut c2 = c.clone();
        let k0 = [0.5f32, -1.0, 2.0, 0.25];
        for r in 0..16 {
            for j in 0..4 {
                c2.k.set(r, j, c2.k.at(r, j) - k0[j]);
            }
        }
        let o2 = naive_attention_f32(&c2);
        for (a, b) in o1.data.iter().zip(&o2.data) {
            assert!((a - b).abs() < 2e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn causal_mask_first_row_copies_first_value_row() {
        // Square causal: row 0 sees only KV position 0, so its output is
        // exactly V's row 0.
        let mut rng = Pcg64::new(8, 0);
        let c = gen_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 8, 8, 4, &mut rng);
        let o = naive_attention_masked_f32(&c, HeadMask::Causal);
        for j in 0..4 {
            assert!((o.at(0, j) - c.v.at(0, j)).abs() < 1e-6, "col {j}");
        }
        // And the last row matches the unmasked reference's last row.
        let dense = naive_attention_f32(&c);
        for j in 0..4 {
            assert!((o.at(7, j) - dense.at(7, j)).abs() < 1e-6, "col {j}");
        }
    }

    #[test]
    fn fully_masked_rows_are_zero_not_nan() {
        let mut rng = Pcg64::new(9, 0);
        let c = gen_case(Distribution::Uniform { x0: 1.0, am: 1.0 }, 6, 10, 4, &mut rng);
        let o = naive_attention_masked_f32(&c, HeadMask::Prefix(0));
        assert!(o.data.iter().all(|&x| x == 0.0));
        // Prefix mask ignores the padding region entirely.
        let o3 = naive_attention_masked_f32(&c, HeadMask::Prefix(3));
        let truncated = AttentionCase {
            q: c.q.clone(),
            k: c.k.rows_slice(0, 3),
            v: c.v.rows_slice(0, 3),
        };
        let golden = naive_attention_f32(&truncated);
        for (a, b) in o3.data.iter().zip(&golden.data) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn masked_stats_ignore_invisible_scores() {
        // Huge keys hidden behind a Prefix mask must not report overflow.
        let mut rng = Pcg64::new(10, 0);
        let mut c = gen_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 4, 8, 64, &mut rng);
        for r in 4..8 {
            for j in 0..64 {
                c.k.set(r, j, 3.0e4);
                c.q.set(r % 4, j, 1.0);
            }
        }
        let (_, masked) = naive_head(&c.q, &c.k, &c.v, HeadMask::Prefix(4));
        assert_eq!(masked.overflow_events, 0, "masked overflow leaked");
        let (_, dense) = naive_head(&c.q, &c.k, &c.v, HeadMask::None);
        assert!(dense.overflow_events > 0, "premise: padding would overflow");
    }
}
