//! Golden-reference standard attention (§1.1's four steps) in full
//! precision — the `O_Golden` of the paper's RMSE metric (Eq. 19).

use crate::tensor::{matmul_nn, matmul_nt, ops, GemmPrecision, Matrix};
use crate::workloads::AttentionCase;

/// O = softmax(Q·Kᵀ/α)·V with f32 GEMMs and f64-carried softmax.
pub fn naive_attention_f32(case: &AttentionCase) -> Matrix {
    let d = case.head_dim();
    let alpha = (d as f64).sqrt() as f32;
    let s = matmul_nt(&case.q, &case.k, GemmPrecision::F32);
    let scaled = ops::scale(&s, 1.0 / alpha, crate::numerics::Format::F32);
    let p = ops::softmax_rows_f32(&scaled);
    matmul_nn(&p, &case.v, GemmPrecision::F32)
}

/// The raw attention score matrix S = Q·Kᵀ (pre-scaling) in f32 — used by
/// the overflow studies (the paper's instrumentation checks max |S| against
/// 65504 at exactly this point).
pub fn raw_scores_f32(case: &AttentionCase) -> Matrix {
    matmul_nt(&case.q, &case.k, GemmPrecision::F32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{gen_case, Distribution, Pcg64};

    #[test]
    fn rows_are_convex_combinations() {
        let mut rng = Pcg64::new(5, 0);
        let c = gen_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 16, 24, 8, &mut rng);
        let o = naive_attention_f32(&c);
        assert_eq!(o.shape(), (16, 8));
        // Each output row lies within the convex hull of V's rows:
        for j in 0..8 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in 0..24 {
                lo = lo.min(c.v.at(r, j));
                hi = hi.max(c.v.at(r, j));
            }
            for i in 0..16 {
                let x = o.at(i, j);
                assert!(x >= lo - 1e-4 && x <= hi + 1e-4, "({i},{j})={x} not in [{lo},{hi}]");
            }
        }
    }

    #[test]
    fn uniform_value_rows_pass_through() {
        // If all V rows are identical, attention output equals that row
        // regardless of the scores.
        let mut rng = Pcg64::new(6, 0);
        let mut c = gen_case(Distribution::Uniform { x0: 5.0, am: 2.0 }, 8, 12, 4, &mut rng);
        for r in 0..12 {
            for j in 0..4 {
                c.v.set(r, j, (j as f32) - 1.5);
            }
        }
        let o = naive_attention_f32(&c);
        for i in 0..8 {
            for j in 0..4 {
                assert!((o.at(i, j) - ((j as f32) - 1.5)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn softmax_translation_invariance() {
        // Eq. (9): adding a constant row vector to K's contribution leaves
        // the output unchanged (softmax translation invariance).
        let mut rng = Pcg64::new(7, 0);
        let c = gen_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 8, 16, 4, &mut rng);
        let o1 = naive_attention_f32(&c);
        // Shift every K row by the same vector k0 — scores change by a
        // row-constant Q·k0ᵀ, softmax unchanged.
        let mut c2 = c.clone();
        let k0 = [0.5f32, -1.0, 2.0, 0.25];
        for r in 0..16 {
            for j in 0..4 {
                c2.k.set(r, j, c2.k.at(r, j) - k0[j]);
            }
        }
        let o2 = naive_attention_f32(&c2);
        for (a, b) in o1.data.iter().zip(&o2.data) {
            assert!((a - b).abs() < 2e-4, "{a} vs {b}");
        }
    }
}
