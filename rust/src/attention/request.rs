//! The unified attention request/response types (S4b).
//!
//! An [`AttentionRequest`] is the single currency between the engine layers
//! and the attention lab: it carries Q/K/V for `n_heads` query heads over
//! `n_kv_heads` KV heads (MQA/GQA via the standard head-group mapping), an
//! [`AttnMask`], the tiling block sizes, PASA's β and the precision
//! [`Allocation`]. Kernels return an [`AttentionOutput`]: per-head output
//! matrices plus per-head [`HeadStats`] — max |S| before store rounding and
//! the overflow-event count at the paper's instrumentation point — which
//! feed the coordinator's overflow guard instead of logits-only NaN
//! sniffing.

use super::config::{Allocation, AttentionConfig, BlockSizes};
use super::kernel::KernelRegistry;
use super::policy::BetaPolicy;
use crate::numerics::Format;
use crate::tensor::{matmul_nt, GemmPrecision, GemmStats, Matrix};
use crate::workloads::{AttentionCase, MultiHeadCase};

/// Identifier of one page in a paged KV arena (mirrors the coordinator's
/// `kv_cache::PageId` — both are plain `u32` indices into the same pool).
pub type PageId = u32;

/// Anything that can hand out fixed-size KV pages by id. The coordinator's
/// `KvPool` implements this; the attention lab depends only on the trait so
/// the kernel layer stays below the serving layer.
///
/// A page holds `page_tokens()` consecutive token rows of `row_width()`
/// f32 each, row-major.
pub trait KvPageSource: Sync {
    /// Token rows per page.
    fn page_tokens(&self) -> usize;
    /// Floats per token row.
    fn row_width(&self) -> usize;
    /// The raw page data: `page_tokens() * row_width()` floats.
    fn page_data(&self, id: PageId) -> &[f32];

    // lint: hot-path — per-page row gather of the KV block sweep.
    /// Gather `take` token rows of page `id`, starting at in-page row
    /// `off` and windowed to columns `[col0, col0 + cols)`, into `out`
    /// rows `out_row0..out_row0 + take`.
    ///
    /// The default reads the f32 view from [`Self::page_data`], hoisting
    /// the page's row range into one slice up front so the per-row copies
    /// index into an already-bounds-checked panel. Byte-backed pools
    /// (E4M3 KV storage) override this to fuse dequantization into the
    /// gather instead of materializing an f32 page.
    fn gather_rows(
        &self,
        id: PageId,
        off: usize,
        take: usize,
        col0: usize,
        cols: usize,
        out: &mut Matrix,
        out_row0: usize,
    ) {
        let w = self.row_width();
        let src = &self.page_data(id)[off * w..(off + take) * w];
        for t in 0..take {
            let srow = &src[t * w + col0..t * w + col0 + cols];
            out.row_mut(out_row0 + t).copy_from_slice(srow);
        }
    }
    // lint: end-hot-path
}

/// A borrowed view of one KV operand (the K *or* V of one KV head): either
/// a dense matrix or a page-table walk over a paged pool. This is the
/// tentpole abstraction of the paged-KV attention path: the inner kernels
/// iterate KV *blocks* through [`KvView::block`], so a paged decode step
/// gathers `O(len_tokens)` rows page-by-page and never assembles a dense
/// `(max_seq, W)` buffer.
///
/// `len_tokens` doubles as the implicit `Prefix` mask: rows past it —
/// including the stale tail of the last page — are simply not part of the
/// view, so they can never enter a softmax or PASA's pseudo-average.
#[derive(Clone, Copy)]
pub enum KvView<'a> {
    /// A dense `(s2 × d)` matrix (the classic in-memory operand).
    Dense(&'a Matrix),
    /// A paged operand: `len_tokens` valid rows scattered across `pages`
    /// of `pool`, optionally restricted to the column window
    /// `[col0, col0 + cols)` of each `row_width()`-wide row (per-head
    /// slicing of a packed multi-head cache row).
    Paged {
        pages: &'a [PageId],
        pool: &'a dyn KvPageSource,
        len_tokens: usize,
        /// First column of the per-row window.
        col0: usize,
        /// Width of the per-row window.
        cols: usize,
    },
}

impl<'a> KvView<'a> {
    /// Full-width paged view over `len_tokens` rows.
    pub fn paged(pages: &'a [PageId], pool: &'a dyn KvPageSource, len_tokens: usize) -> KvView<'a> {
        let cols = pool.row_width();
        KvView::Paged {
            pages,
            pool,
            len_tokens,
            col0: 0,
            cols,
        }
    }

    /// Restrict a paged view to the column window `[c0, c0 + n)` — the
    /// per-head slice of a packed `(len, n_kv_heads·d)` cache row. Dense
    /// views are returned unchanged (slice them before wrapping).
    pub fn col_window(self, c0: usize, n: usize) -> KvView<'a> {
        match self {
            KvView::Dense(m) => {
                assert!(c0 == 0 && n == m.cols, "col_window on a dense view");
                KvView::Dense(m)
            }
            KvView::Paged {
                pages,
                pool,
                len_tokens,
                col0,
                cols,
            } => {
                assert!(c0 + n <= cols, "column window out of range");
                KvView::Paged {
                    pages,
                    pool,
                    len_tokens,
                    col0: col0 + c0,
                    cols: n,
                }
            }
        }
    }

    /// Number of valid token rows.
    pub fn rows(&self) -> usize {
        match *self {
            KvView::Dense(m) => m.rows,
            KvView::Paged { len_tokens, .. } => len_tokens,
        }
    }

    /// Width of each row.
    pub fn cols(&self) -> usize {
        match *self {
            KvView::Dense(m) => m.cols,
            KvView::Paged { cols, .. } => cols,
        }
    }

    pub fn is_paged(&self) -> bool {
        matches!(self, KvView::Paged { .. })
    }

    /// Truncate a *paged* view to its first `n` rows without copying (the
    /// page table is simply read less far). Returns `None` for dense
    /// views — those are truncated by slicing the matrix (one copy), which
    /// is what the pre-view kernels did.
    pub fn truncated(&self, n: usize) -> Option<KvView<'a>> {
        match *self {
            KvView::Dense(_) => None,
            KvView::Paged {
                pages,
                pool,
                len_tokens,
                col0,
                cols,
            } => Some(KvView::Paged {
                pages,
                pool,
                len_tokens: len_tokens.min(n),
                col0,
                cols,
            }),
        }
    }

    /// Materialize rows `[r0, r1)` as a dense matrix — the block gather of
    /// the kernels' KV sweep. Dense views copy the slice (exactly what the
    /// pre-view kernels did with `rows_slice`); paged views walk the page
    /// table and copy page-by-page, clamped to `len_tokens`.
    pub fn block(&self, r0: usize, r1: usize) -> Matrix {
        match *self {
            // Dense: one copy straight off the source rows.
            KvView::Dense(m) => m.rows_slice(r0, r1),
            KvView::Paged { .. } => {
                let mut out = Matrix::zeros(r1.saturating_sub(r0), self.cols());
                self.block_into(r0, r1, &mut out);
                out
            }
        }
    }

    /// Buffer-reusing [`Self::block`]: the gather of the zero-allocation
    /// hot path — `out` is reshaped in place, so a warm workspace buffer
    /// absorbs every KV block of the sweep without touching the heap.
    pub fn block_into(&self, r0: usize, r1: usize, out: &mut Matrix) {
        match *self {
            // Dense: one hoisted slice copy straight off the source rows —
            // no per-row bounds re-check (`copy_rows_from` is a single
            // `extend_from_slice` of the whole row range).
            KvView::Dense(m) => out.copy_rows_from(m, r0, r1),
            KvView::Paged {
                pages,
                pool,
                len_tokens,
                col0,
                cols,
            } => {
                assert!(r0 <= r1 && r1 <= len_tokens, "paged block out of range");
                let pt = pool.page_tokens();
                out.reshape(r1 - r0, cols); // every row fully copied below
                let mut r = r0;
                while r < r1 {
                    let pg = r / pt;
                    let off = r % pt;
                    // Rows available in this page before the block (or the
                    // page) ends.
                    let take = (pt - off).min(r1 - r);
                    pool.gather_rows(pages[pg], off, take, col0, cols, out, r - r0);
                    r += take;
                }
            }
        }
    }

    /// Materialize the whole view as a dense `(rows × cols)` matrix.
    pub fn to_matrix(&self) -> Matrix {
        self.block(0, self.rows())
    }
}

/// One KV head's operand pair for the view-based kernel entry.
#[derive(Clone, Copy)]
pub struct KvPair<'a> {
    pub k: KvView<'a>,
    pub v: KvView<'a>,
}

/// Attention masking modes of the request.
///
/// All variants resolve per head to a *prefix* visibility rule (each query
/// row sees KV positions `0..visible`), which covers the serving workloads
/// of the paper's evaluation: dense bidirectional heads (video diffusion),
/// causal decoding heads (Qwen2) and right-padded batched sequences.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AttnMask {
    /// Every query attends to every KV position.
    None,
    /// Causal: query `i` (aligned to the *end* of the KV sequence, the
    /// decoding convention) sees KV positions `0..=i + s2 − s1`.
    Causal,
    /// Right-padded sequences: per-head valid KV lengths. One entry
    /// broadcasts to all heads; otherwise one entry per query head.
    Padded(Vec<usize>),
}

impl AttnMask {
    pub fn label(&self) -> &'static str {
        match self {
            AttnMask::None => "none",
            AttnMask::Causal => "causal",
            AttnMask::Padded(_) => "padded",
        }
    }

    /// Resolve the mask for one query head. A one-entry `Padded` mask
    /// broadcasts to every head; otherwise the head indexes its own entry
    /// — a mismatched mask (e.g. 3 lengths for 8 heads) is a hard error,
    /// never a silent reuse of the last length.
    pub fn for_head(&self, h: usize) -> HeadMask {
        match self {
            AttnMask::None => HeadMask::None,
            AttnMask::Causal => HeadMask::Causal,
            AttnMask::Padded(lens) => {
                assert!(!lens.is_empty(), "Padded mask needs at least one length");
                if lens.len() == 1 {
                    HeadMask::Prefix(lens[0])
                } else {
                    assert!(
                        h < lens.len(),
                        "Padded mask has {} lengths but head {h} was requested \
                         (need 1 length or one per query head)",
                        lens.len()
                    );
                    HeadMask::Prefix(lens[h])
                }
            }
        }
    }
}

/// One head's resolved visibility rule: each query row sees a prefix of
/// the KV sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadMask {
    None,
    Causal,
    /// Only KV positions `0..len` are valid (right padding beyond).
    Prefix(usize),
}

impl HeadMask {
    /// Number of visible KV positions for query row `i` of an (s1 × s2)
    /// head. Causal aligns queries to the end of the KV sequence, so with
    /// s1 == s2 row `i` sees `i + 1` positions; rows can be fully masked
    /// (0 visible) only when s1 > s2 or under a zero-length prefix.
    #[inline]
    pub fn visible(&self, i: usize, s1: usize, s2: usize) -> usize {
        match *self {
            HeadMask::None => s2,
            HeadMask::Causal => (i + 1 + s2).saturating_sub(s1).min(s2),
            HeadMask::Prefix(l) => l.min(s2),
        }
    }

    /// Per-row visible counts for query rows `[i0, i1)`.
    pub fn visible_rows(&self, i0: usize, i1: usize, s1: usize, s2: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.visible_rows_into(i0, i1, s1, s2, &mut out);
        out
    }

    /// Buffer-reusing [`Self::visible_rows`] (hot-path form).
    pub fn visible_rows_into(
        &self,
        i0: usize,
        i1: usize,
        s1: usize,
        s2: usize,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        out.extend((i0..i1).map(|i| self.visible(i, s1, s2)));
    }

    pub fn is_none(&self) -> bool {
        matches!(self, HeadMask::None)
    }
}

/// Per-head numerical telemetry from one kernel forward pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct HeadStats {
    /// Max |S| at the paper's instrumentation point: the score GEMM's
    /// pre-store value (for PASA this is the *shifted* score — the
    /// magnitude the hardware actually stores).
    pub max_abs_score: f32,
    /// Pre-store score values beyond the overflow boundary the kernel
    /// instruments against (FP16's 65504 for low-precision stores).
    pub overflow_events: usize,
    /// Non-finite values in the head's final output (the paper's INF/NaN
    /// poisoning signature).
    pub nonfinite_outputs: usize,
}

impl HeadStats {
    /// Close out a head: fold the GEMM telemetry with an output scan.
    pub fn finish(gemm: GemmStats, out: &Matrix) -> HeadStats {
        HeadStats {
            max_abs_score: gemm.max_abs,
            overflow_events: gemm.overflow_events,
            nonfinite_outputs: out.data.iter().filter(|x| !x.is_finite()).count(),
        }
    }
}

/// Result of one kernel forward pass: per-head outputs and telemetry.
#[derive(Clone, Debug)]
pub struct AttentionOutput {
    pub heads: Vec<Matrix>,
    pub stats: Vec<HeadStats>,
    /// The overflow boundary of the format S was stored in — what the
    /// per-head `overflow_events` were instrumented against (65504 for
    /// the FP16 allocations, 448 for FP8-E4M3, f32::MAX for Fa32; the
    /// golden reference instruments against FP16). Carried so the guard
    /// compares score *pressure* against the active allocation's limit
    /// instead of a hardcoded constant.
    pub score_boundary: f32,
}

impl AttentionOutput {
    /// Consume a single-head output (panics on an empty result).
    pub fn single(mut self) -> Matrix {
        assert!(!self.heads.is_empty(), "empty AttentionOutput");
        self.heads.swap_remove(0)
    }

    /// Any non-finite value in any head's output.
    pub fn overflowed(&self) -> bool {
        self.stats.iter().any(|s| s.nonfinite_outputs > 0)
    }

    /// Total pre-store overflow events across heads.
    pub fn overflow_events(&self) -> usize {
        self.stats.iter().map(|s| s.overflow_events).sum()
    }

    /// Largest pre-store |S| across heads.
    pub fn max_abs_score(&self) -> f32 {
        self.stats
            .iter()
            .fold(0.0f32, |m, s| m.max(s.max_abs_score))
    }

    /// Total non-finite output elements across heads.
    pub fn nonfinite_outputs(&self) -> usize {
        self.stats.iter().map(|s| s.nonfinite_outputs).sum()
    }
}

/// A batched, masked, multi-head attention problem — the single entry
/// point into every kernel. Build one with the constructors below, refine
/// it builder-style, then dispatch with [`AttentionRequest::run`] (or hand
/// it to a specific [`super::kernel::AttentionKernel`]).
#[derive(Clone, Debug)]
pub struct AttentionRequest {
    /// Query matrices, one per head: (s1 × d).
    pub q: Vec<Matrix>,
    /// Key matrices, one per KV head: (s2 × d). `q.len()` must be a
    /// multiple of `k.len()` (GQA/MQA head grouping).
    pub k: Vec<Matrix>,
    /// Value matrices, one per KV head: (s2 × dv).
    pub v: Vec<Matrix>,
    pub mask: AttnMask,
    /// Precision allocation, tiling and the uniform-β fallback.
    pub cfg: AttentionConfig,
    /// How PASA's β is assigned across query heads. Kept in lockstep with
    /// `cfg.beta` by the builders: [`Self::with_beta`] sets both, and a
    /// `Uniform` policy always mirrors the scalar — so the free-function
    /// kernels (which read `cfg.beta`) and the policy-resolving kernel
    /// layer can never disagree.
    pub policy: BetaPolicy,
}

impl AttentionRequest {
    /// Empty request; add heads with [`Self::with_head`] /
    /// [`Self::with_query_head`] + [`Self::with_kv_head`].
    pub fn new(alloc: Allocation) -> AttentionRequest {
        let cfg = AttentionConfig::new(alloc);
        AttentionRequest {
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            mask: AttnMask::None,
            policy: BetaPolicy::Uniform(cfg.beta),
            cfg,
        }
    }

    /// Single-head request from a workload case.
    pub fn from_case(case: &AttentionCase, alloc: Allocation) -> AttentionRequest {
        Self::from_case_cfg(case, AttentionConfig::new(alloc))
    }

    /// Single-head request carrying an explicit legacy config.
    pub fn from_case_cfg(case: &AttentionCase, cfg: AttentionConfig) -> AttentionRequest {
        AttentionRequest {
            q: vec![case.q.clone()],
            k: vec![case.k.clone()],
            v: vec![case.v.clone()],
            mask: AttnMask::None,
            policy: BetaPolicy::Uniform(cfg.beta),
            cfg,
        }
    }

    /// Multi-head request from a workload benchmark case. Padded cases
    /// (non-empty `kv_lens`) get an [`AttnMask::Padded`] automatically.
    pub fn from_multihead(mh: &MultiHeadCase, alloc: Allocation) -> AttentionRequest {
        let mask = if mh.kv_lens.is_empty() {
            AttnMask::None
        } else {
            AttnMask::Padded(mh.kv_lens.clone())
        };
        let cfg = AttentionConfig::new(alloc);
        AttentionRequest {
            q: mh.q.clone(),
            k: mh.k.clone(),
            v: mh.v.clone(),
            mask,
            policy: BetaPolicy::Uniform(cfg.beta),
            cfg,
        }
    }

    /// Append one MHA head (its own K/V).
    pub fn with_head(mut self, q: Matrix, k: Matrix, v: Matrix) -> Self {
        self.q.push(q);
        self.k.push(k);
        self.v.push(v);
        self
    }

    /// Append a query head that shares an existing KV head (GQA/MQA).
    pub fn with_query_head(mut self, q: Matrix) -> Self {
        self.q.push(q);
        self
    }

    /// Append one KV head.
    pub fn with_kv_head(mut self, k: Matrix, v: Matrix) -> Self {
        self.k.push(k);
        self.v.push(v);
        self
    }

    pub fn with_mask(mut self, mask: AttnMask) -> Self {
        self.mask = mask;
        self
    }

    pub fn with_blocks(mut self, s1: usize, s2: usize) -> Self {
        self.cfg.blocks = BlockSizes { s1, s2 };
        self
    }

    /// Set a uniform β (scalar and policy stay in lockstep).
    pub fn with_beta(mut self, beta: f64) -> Self {
        self.cfg.beta = beta;
        self.policy = BetaPolicy::Uniform(beta);
        self
    }

    /// Install a β policy; a `Uniform` policy also updates the legacy
    /// scalar `cfg.beta` so both views of the request agree.
    pub fn with_policy(mut self, policy: BetaPolicy) -> Self {
        if let BetaPolicy::Uniform(b) = policy {
            self.cfg.beta = b;
        }
        self.policy = policy;
        self
    }

    /// Rebind the precision allocation (e.g. to replay a request under
    /// PASA after a guard trip).
    pub fn with_alloc(mut self, alloc: Allocation) -> Self {
        self.cfg.alloc = alloc;
        self
    }

    pub fn with_strict_fp16_accum(mut self, strict: bool) -> Self {
        self.cfg.strict_fp16_accum = strict;
        self
    }

    /// Round Q/K/V onto the FP16 grid (the model's storage format — the
    /// paper's premise that inputs are within low-precision range).
    pub fn with_fp16_inputs(mut self) -> Self {
        for m in self
            .q
            .iter_mut()
            .chain(self.k.iter_mut())
            .chain(self.v.iter_mut())
        {
            m.round_to(Format::F16);
        }
        self
    }

    pub fn n_heads(&self) -> usize {
        self.q.len()
    }

    pub fn n_kv_heads(&self) -> usize {
        self.k.len()
    }

    /// Query heads per KV head.
    pub fn group_size(&self) -> usize {
        self.n_heads() / self.n_kv_heads().max(1)
    }

    /// KV head serving query head `h` — the workloads layer's
    /// [`crate::workloads::gqa_kv_head`] contiguous grouping.
    pub fn kv_head_for(&self, h: usize) -> usize {
        crate::workloads::gqa_kv_head(h, self.n_heads(), self.n_kv_heads())
    }

    pub fn seq_q(&self) -> usize {
        self.q.first().map_or(0, |m| m.rows)
    }

    pub fn seq_kv(&self) -> usize {
        self.k.first().map_or(0, |m| m.rows)
    }

    pub fn head_dim(&self) -> usize {
        self.q.first().map_or(0, |m| m.cols)
    }

    /// Materialize query head `h` with its mapped KV head as a standalone
    /// single-head case (the GQA equivalence tests go through this).
    pub fn head_case(&self, h: usize) -> AttentionCase {
        let kv = self.kv_head_for(h);
        AttentionCase {
            q: self.q[h].clone(),
            k: self.k[kv].clone(),
            v: self.v[kv].clone(),
        }
    }

    /// Resolved mask for query head `h`.
    pub fn mask_for_head(&self, h: usize) -> HeadMask {
        self.mask.for_head(h)
    }

    /// β for query head `h`, resolved from the request's [`BetaPolicy`]
    /// against the KV block width and the allocation's score format.
    pub fn beta_for(&self, h: usize) -> f64 {
        self.policy
            .resolve(h, self.cfg.blocks.s2, self.cfg.alloc.score_fmt())
    }

    /// The per-head kernel config: the request's config with β resolved
    /// for head `h` — what the kernel layer hands the inner cores, so
    /// they keep consuming one scalar β each. Under a `Uniform` policy
    /// this is bit-identical to `cfg`.
    pub fn head_cfg(&self, h: usize) -> AttentionConfig {
        let mut c = self.cfg;
        c.beta = self.beta_for(h);
        c
    }

    /// Per-head configs for every query head, with head-invariant
    /// policies (`Uniform`, `Solved`) resolved **once** and reused — a
    /// `Solved` policy costs one fixed-point solve per request, not one
    /// per head. This is what the kernels call before fan-out.
    pub fn head_cfgs(&self) -> Vec<AttentionConfig> {
        match &self.policy {
            BetaPolicy::PerHead(_) => (0..self.n_heads()).map(|h| self.head_cfg(h)).collect(),
            _ => vec![self.head_cfg(0); self.n_heads()],
        }
    }

    /// Raw (unshifted, unmasked) score matrix S = Q·Kᵀ of head `h` in f32
    /// — the paper's instrumentation quantity.
    pub fn raw_scores_f32(&self, h: usize) -> Matrix {
        matmul_nt(&self.q[h], &self.k[self.kv_head_for(h)], GemmPrecision::F32)
    }

    /// Structural validation; kernels call this before fan-out. Checks
    /// the owned K/V head lists line up, then applies the shared shape
    /// rules via [`Self::validate_kv`] over dense views — one rule set
    /// for both the owned and the view-based entry points.
    pub fn validate(&self) -> Result<(), String> {
        if self.k.len() != self.v.len() {
            return Err(format!(
                "request needs matching K/V heads, got {} K and {} V",
                self.k.len(),
                self.v.len()
            ));
        }
        self.validate_kv(&self.kv_pairs())
    }

    /// Dense views over this request's own K/V heads — what the default
    /// [`super::kernel::AttentionKernel::forward`] feeds the view-based
    /// kernel cores.
    pub fn kv_pairs(&self) -> Vec<KvPair<'_>> {
        self.k
            .iter()
            .zip(&self.v)
            .map(|(k, v)| KvPair {
                k: KvView::Dense(k),
                v: KvView::Dense(v),
            })
            .collect()
    }

    /// Structural validation of a request whose K/V come from external
    /// views (`kv` replaces `self.k`/`self.v`, which may be empty). The
    /// same rules as [`Self::validate`], expressed over view shapes.
    pub fn validate_kv(&self, kv: &[KvPair<'_>]) -> Result<(), String> {
        if self.q.is_empty() {
            return Err("request has no query heads".into());
        }
        if kv.is_empty() {
            return Err("request has no KV views".into());
        }
        if self.q.len() % kv.len() != 0 {
            return Err(format!(
                "{} query heads not divisible by {} KV views",
                self.q.len(),
                kv.len()
            ));
        }
        let (s1, d) = self.q[0].shape();
        let s2 = kv[0].k.rows();
        let dv = kv[0].v.cols();
        if s2 == 0 {
            return Err("empty KV view".into());
        }
        for (i, m) in self.q.iter().enumerate() {
            if m.shape() != (s1, d) {
                return Err(format!("query head {i} shape {:?} != ({s1}, {d})", m.shape()));
            }
        }
        for (i, pair) in kv.iter().enumerate() {
            if pair.k.rows() != s2 || pair.k.cols() != d {
                return Err(format!(
                    "KV view {i}: K is ({}, {}), expected ({s2}, {d})",
                    pair.k.rows(),
                    pair.k.cols()
                ));
            }
            if pair.v.rows() != s2 || pair.v.cols() != dv {
                return Err(format!(
                    "KV view {i}: V is ({}, {}), expected ({s2}, {dv})",
                    pair.v.rows(),
                    pair.v.cols()
                ));
            }
        }
        if let AttnMask::Padded(lens) = &self.mask {
            if lens.len() != 1 && lens.len() != self.q.len() {
                return Err(format!(
                    "Padded mask has {} lengths for {} heads (need 1 or one per head)",
                    lens.len(),
                    self.q.len()
                ));
            }
            if let Some(&bad) = lens.iter().find(|&&l| l > s2) {
                return Err(format!("Padded length {bad} exceeds KV length {s2}"));
            }
        }
        if self.cfg.blocks.s1 == 0 || self.cfg.blocks.s2 == 0 {
            return Err("zero block size".into());
        }
        self.policy
            .validate(self.q.len(), self.cfg.blocks.s2, self.cfg.alloc.score_fmt())?;
        Ok(())
    }

    /// KV view serving query head `h` under the same contiguous GQA
    /// grouping as [`Self::kv_head_for`], against an external view list.
    pub fn kv_pair_for<'a>(&self, kv: &[KvPair<'a>], h: usize) -> KvPair<'a> {
        kv[crate::workloads::gqa_kv_head(h, self.q.len(), kv.len())]
    }

    /// Dispatch through the [`KernelRegistry`] on this request's
    /// allocation — the one-line entry point.
    pub fn run(&self) -> AttentionOutput {
        KernelRegistry::get(self.cfg.alloc).forward(self)
    }

    /// Dispatch with external K/V views (dense or paged) replacing the
    /// request's own K/V — the serving engine's paged-decode entry point.
    pub fn run_with_kv(&self, kv: &[KvPair<'_>]) -> AttentionOutput {
        KernelRegistry::get(self.cfg.alloc).forward_kv(self, kv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{gen_case, Distribution, Pcg64};

    fn case(s1: usize, s2: usize, d: usize, seed: u64) -> AttentionCase {
        let mut rng = Pcg64::new(seed, 0);
        gen_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, s1, s2, d, &mut rng)
    }

    #[test]
    fn mask_visibility_rules() {
        // Square causal: row i sees i+1 positions.
        assert_eq!(HeadMask::Causal.visible(0, 8, 8), 1);
        assert_eq!(HeadMask::Causal.visible(7, 8, 8), 8);
        // Decoding alignment: 1 query over 8 KV sees everything.
        assert_eq!(HeadMask::Causal.visible(0, 1, 8), 8);
        // s1 > s2: early rows are fully masked.
        assert_eq!(HeadMask::Causal.visible(0, 8, 4), 0);
        assert_eq!(HeadMask::Causal.visible(7, 8, 4), 4);
        assert_eq!(HeadMask::None.visible(3, 8, 16), 16);
        assert_eq!(HeadMask::Prefix(5).visible(3, 8, 16), 5);
        assert_eq!(HeadMask::Prefix(50).visible(3, 8, 16), 16);
    }

    #[test]
    fn padded_mask_broadcasts_and_indexes() {
        let broadcast = AttnMask::Padded(vec![7]);
        assert_eq!(broadcast.for_head(0), HeadMask::Prefix(7));
        assert_eq!(broadcast.for_head(5), HeadMask::Prefix(7));
        let per_head = AttnMask::Padded(vec![3, 9]);
        assert_eq!(per_head.for_head(1), HeadMask::Prefix(9));
    }

    #[test]
    #[should_panic(expected = "Padded mask has 3 lengths but head 5")]
    fn mismatched_padded_mask_panics_instead_of_clamping() {
        // Regression (PR 2): a 3-entry mask on 8 heads used to silently
        // reuse the last length for heads 3..8.
        let m = AttnMask::Padded(vec![3, 9, 4]);
        let _ = m.for_head(5);
    }

    /// In-memory page source for view tests (3 tokens/page, width 4).
    struct MockPool {
        pages: Vec<Vec<f32>>,
    }

    impl KvPageSource for MockPool {
        fn page_tokens(&self) -> usize {
            3
        }
        fn row_width(&self) -> usize {
            4
        }
        fn page_data(&self, id: PageId) -> &[f32] {
            &self.pages[id as usize]
        }
    }

    /// Scatter a dense (rows × 4) matrix into pages of 3 rows; the last
    /// page's unused tail is poisoned to prove views never read past
    /// `len_tokens`.
    fn paged_fixture(m: &Matrix) -> (MockPool, Vec<PageId>) {
        assert_eq!(m.cols, 4);
        let n_pages = m.rows.div_ceil(3);
        let mut pages = vec![vec![f32::NAN; 3 * 4]; n_pages];
        for r in 0..m.rows {
            pages[r / 3][(r % 3) * 4..(r % 3 + 1) * 4].copy_from_slice(m.row(r));
        }
        let ids = (0..n_pages as PageId).collect();
        (MockPool { pages }, ids)
    }

    #[test]
    fn paged_view_matches_dense_blocks() {
        let m = Matrix::from_vec(7, 4, (0..28).map(|i| i as f32).collect());
        let (pool, ids) = paged_fixture(&m);
        let view = KvView::paged(&ids, &pool, 7);
        assert_eq!(view.rows(), 7);
        assert_eq!(view.cols(), 4);
        assert!(view.is_paged());
        assert_eq!(view.to_matrix().data, m.data);
        // Blocks straddling page boundaries (pages hold 3 rows).
        for (r0, r1) in [(0, 3), (2, 6), (1, 7), (6, 7), (4, 4)] {
            assert_eq!(view.block(r0, r1).data, m.rows_slice(r0, r1).data, "[{r0},{r1})");
        }
        // Dense views are the identity wrapper.
        let dv = KvView::Dense(&m);
        assert_eq!(dv.block(2, 6).data, m.rows_slice(2, 6).data);
        assert!(dv.truncated(3).is_none());
    }

    #[test]
    fn paged_view_len_tokens_hides_the_page_tail() {
        // 5 valid rows in 2 pages (page 2 rows 5.. are NaN-poisoned).
        let m = Matrix::from_vec(5, 4, (0..20).map(|i| i as f32).collect());
        let (pool, ids) = paged_fixture(&m);
        let view = KvView::paged(&ids, &pool, 5);
        assert_eq!(view.rows(), 5);
        let out = view.to_matrix();
        assert!(out.data.iter().all(|x| x.is_finite()), "read past len_tokens");
        // Truncation shortens the walk for free.
        let t = view.truncated(2).unwrap();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.to_matrix().data, m.rows_slice(0, 2).data);
    }

    #[test]
    fn paged_col_window_selects_one_head() {
        let m = Matrix::from_vec(6, 4, (0..24).map(|i| i as f32).collect());
        let (pool, ids) = paged_fixture(&m);
        let view = KvView::paged(&ids, &pool, 6).col_window(2, 2);
        assert_eq!(view.cols(), 2);
        let out = view.to_matrix();
        for r in 0..6 {
            assert_eq!(out.row(r), &m.row(r)[2..4], "row {r}");
        }
    }

    #[test]
    fn run_with_kv_dense_views_bit_match_owned_run() {
        // The two dispatch paths share the same cores: running a request
        // through run() and through run_with_kv(dense views) must agree
        // bit for bit, for every allocation.
        let c = case(24, 24, 8, 9);
        for alloc in Allocation::all() {
            let req = AttentionRequest::from_case(&c, alloc)
                .with_blocks(16, 16)
                .with_fp16_inputs();
            let owned = req.run();
            let viewed = req.run_with_kv(&req.kv_pairs());
            assert_eq!(owned.heads[0].data, viewed.heads[0].data, "{}", alloc.name());
            assert_eq!(
                owned.stats[0].overflow_events,
                viewed.stats[0].overflow_events
            );
        }
    }

    #[test]
    fn gqa_head_mapping() {
        let c = case(8, 8, 4, 1);
        let mut req = AttentionRequest::new(Allocation::Fa32)
            .with_kv_head(c.k.clone(), c.v.clone())
            .with_kv_head(c.k.clone(), c.v.clone());
        for _ in 0..8 {
            req = req.with_query_head(c.q.clone());
        }
        assert_eq!(req.n_heads(), 8);
        assert_eq!(req.n_kv_heads(), 2);
        assert_eq!(req.group_size(), 4);
        assert_eq!(req.kv_head_for(0), 0);
        assert_eq!(req.kv_head_for(3), 0);
        assert_eq!(req.kv_head_for(4), 1);
        assert_eq!(req.kv_head_for(7), 1);
        assert!(req.validate().is_ok());
    }

    #[test]
    fn request_mapping_agrees_with_workload_mapping() {
        // MultiHeadCase and AttentionRequest each implement the
        // contiguous GQA head-group mapping; pin them to each other so
        // the convention cannot silently diverge.
        use crate::workloads::gen_gqa_multihead;
        let dist = Distribution::Uniform { x0: 0.0, am: 1.0 };
        let mh = gen_gqa_multihead(dist, 8, 2, 16, 16, 4, 9);
        let req = AttentionRequest::from_multihead(&mh, Allocation::Fa32);
        for h in 0..8 {
            assert_eq!(req.kv_head_for(h), mh.kv_head_for(h), "head {h}");
            assert_eq!(
                req.head_case(h).k.data,
                mh.head_case(h).k.data,
                "head {h} case"
            );
        }
    }

    #[test]
    fn validation_catches_bad_requests() {
        assert!(AttentionRequest::new(Allocation::Fa32).validate().is_err());
        let c = case(8, 8, 4, 2);
        // 3 query heads over 2 KV heads: not divisible.
        let req = AttentionRequest::new(Allocation::Fa32)
            .with_kv_head(c.k.clone(), c.v.clone())
            .with_kv_head(c.k.clone(), c.v.clone())
            .with_query_head(c.q.clone())
            .with_query_head(c.q.clone())
            .with_query_head(c.q.clone());
        assert!(req.validate().is_err());
        // Padded length beyond the KV sequence.
        let req = AttentionRequest::from_case(&c, Allocation::Fa32)
            .with_mask(AttnMask::Padded(vec![99]));
        assert!(req.validate().is_err());
        // Wrong number of padded lengths.
        let req = AttentionRequest::from_case(&c, Allocation::Fa32)
            .with_mask(AttnMask::Padded(vec![2, 3]));
        assert!(req.validate().is_err());
    }

    #[test]
    fn builder_carries_config() {
        let c = case(8, 8, 4, 3);
        let req = AttentionRequest::from_case(&c, Allocation::Pasa16)
            .with_blocks(32, 16)
            .with_beta(0.9375)
            .with_strict_fp16_accum(false)
            .with_mask(AttnMask::Causal);
        assert_eq!(req.cfg.alloc, Allocation::Pasa16);
        assert_eq!(req.cfg.blocks.s1, 32);
        assert_eq!(req.cfg.blocks.s2, 16);
        assert_eq!(req.cfg.beta, 0.9375);
        assert_eq!(req.policy, BetaPolicy::Uniform(0.9375));
        assert_eq!(req.mask, AttnMask::Causal);
        let req = req.with_alloc(Allocation::Fa32);
        assert_eq!(req.cfg.alloc, Allocation::Fa32);
    }

    #[test]
    fn beta_policy_resolves_per_head_and_validates() {
        let c = case(8, 8, 4, 5);
        let mut req = AttentionRequest::new(Allocation::Pasa16)
            .with_kv_head(c.k.clone(), c.v.clone())
            .with_query_head(c.q.clone())
            .with_query_head(c.q.clone());
        // Default: uniform paper β, head_cfg bit-identical to cfg.
        assert_eq!(req.beta_for(0), req.cfg.beta);
        assert_eq!(req.head_cfg(1).beta, req.cfg.beta);
        // Per-head table resolves per head; head_cfg carries it through.
        req = req.with_policy(BetaPolicy::PerHead(vec![0.9375, 0.96875]));
        assert!(req.validate().is_ok());
        assert_eq!(req.beta_for(0), 0.9375);
        assert_eq!(req.head_cfg(1).beta, 0.96875);
        // Wrong-length table is a validation error, not a clamp.
        let bad = req.clone().with_policy(BetaPolicy::PerHead(vec![0.9; 5]));
        assert!(bad.validate().is_err());
        // A Uniform policy keeps cfg.beta in lockstep.
        let uni = req.with_policy(BetaPolicy::Uniform(0.5));
        assert_eq!(uni.cfg.beta, 0.5);
        assert_eq!(uni.beta_for(1), 0.5);
    }

    #[test]
    fn pasa8_request_carries_the_448_boundary_telemetry() {
        // The request layer's half of the Pasa8 plumbing: the allocation's
        // E4M3 score format drives both the output's score_boundary and
        // the block width/format the β policy resolves against — no layer
        // hardcodes 65504.
        let c = case(32, 32, 8, 6);
        let req = AttentionRequest::from_case(&c, Allocation::Pasa8)
            .with_blocks(16, 16)
            .with_fp16_inputs();
        assert_eq!(req.cfg.alloc.score_fmt(), Format::F8E4M3);
        assert!(req.validate().is_ok());
        let out = req.run();
        assert_eq!(out.score_boundary, 448.0);
        assert!(!out.overflowed(), "benign case must stay finite under Pasa8");
        assert_eq!(out.overflow_events(), 0);
        // The same request rebound to the FP16 PASA row reports 65504.
        assert_eq!(req.with_alloc(Allocation::Pasa16).run().score_boundary, 65504.0);
    }

    #[test]
    fn fp16_input_rounding_is_on_grid() {
        let c = case(16, 16, 8, 4);
        let req = AttentionRequest::from_case(&c, Allocation::Fa16_32).with_fp16_inputs();
        assert!(req.q[0].is_on_grid(Format::F16));
        assert!(req.k[0].is_on_grid(Format::F16));
        assert!(req.v[0].is_on_grid(Format::F16));
    }
}
