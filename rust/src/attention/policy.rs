//! The precision-policy layer (S17): per-head β as a first-class,
//! observable policy instead of one hardcoded scalar.
//!
//! The paper solves a *single* optimal β from the accuracy condition
//! (Eq. 16/20/22) and shares it across every head. But the condition's
//! inputs — the block width n, the storage format's rounding, and the
//! score amplitude the shift must absorb — are all per-head quantities,
//! and the kernel telemetry ([`HeadStats::max_abs_score`]) already
//! measures the last one at the paper's own instrumentation point. A
//! [`BetaPolicy`] closes that loop:
//!
//! * [`BetaPolicy::Uniform`] — the paper's regime: one β for every head
//!   (the default, bit-identical to the pre-policy kernels);
//! * [`BetaPolicy::PerHead`] — an explicit per-head β table, e.g. the
//!   output of the autotune pass;
//! * [`BetaPolicy::Solved`] — solve the optimal accuracy condition at
//!   dispatch time from a β₀ seed, against either the canonical FP16
//!   rounding or the active allocation's score format.
//!
//! The autotune pass ([`BetaPolicy::autotune`]) maps each head's observed
//! pre-store |S| peak onto the paper's β₀ grid (1 − 2⁻ᵖ, p ∈ 4..=6 — the
//! initials of Table 3) via [`beta0_for_pressure`], then runs every pick
//! through [`solve_optimal_beta`] so the rounded invariant is exact.
//! Hotter heads get a stronger shift; benign heads keep the mildest grid
//! β (0.9375, which is *exactly* representable in FP16 — Appendix A).
//!
//! Requests carry the policy ([`crate::attention::AttentionRequest`]'s
//! `policy` field); the kernels resolve it per head before fan-out, so
//! the inner cores still see one scalar β each and the GQA `K' = M·K`
//! sharing keys on (KV head, β) pairs.

use super::beta::{solve_optimal_beta, PAPER_BETA};
use super::request::{AttentionOutput, HeadStats};
use crate::numerics::Format;

/// How PASA's β is assigned across the query heads of one request.
#[derive(Clone, Debug, PartialEq)]
pub enum BetaPolicy {
    /// One β shared by every head (the paper's regime).
    Uniform(f64),
    /// Explicit per-head β table: one entry per query head, or a single
    /// entry broadcast to all heads (mirroring `AttnMask::Padded`).
    PerHead(Vec<f64>),
    /// Solve the optimal accuracy condition at resolution time from the
    /// seed `beta0`. With `per_format` the solve rounds against the
    /// active allocation's score format; otherwise against the canonical
    /// FP16 grid the shifting matrix is stored in.
    Solved { beta0: f64, per_format: bool },
}

impl Default for BetaPolicy {
    fn default() -> Self {
        BetaPolicy::Uniform(PAPER_BETA)
    }
}

/// Largest grid exponent p of the β₀ candidates 1 − 2⁻ᵖ for a score
/// format. The paper's grid stops at p = 6 (its strongest Table 3
/// initial) — enough when the overflow boundary is FP16's 65504, where
/// the residual budget boundary/64 = 1023.5 absorbs any in-range peak at
/// (1 − β) = 2⁻⁶. Boundaries *tighter* than FP16 (E4M3's 448, budget
/// 448/64 = 7) re-derive the grid: the candidates extend to p = 9 so a
/// peak of a few thousand still finds a β whose residual (1 − β)·|S|
/// fits the envelope. Every extended initial stays on the good side of
/// the solver's fixed-point pole (≈ 0.9999 in FP16 — pinned by a test).
pub fn beta0_grid_max_p(fmt: Format) -> i32 {
    if fmt.overflow_boundary() < Format::F16.overflow_boundary() {
        9
    } else {
        6
    }
}

/// Pick the grid β₀ for an observed pre-shift score peak: the smallest
/// 1 − 2⁻ᵖ (p starting at the paper's mildest initial, p = 4) whose
/// post-shift residual (1 − β)·|S|ₘₐₓ fits within 1/64 of the format's
/// overflow boundary. Unpressured heads keep the mildest grid β (0.9375,
/// exact in FP16); peaks beyond the grid's reach saturate at the
/// format's strongest candidate ([`beta0_grid_max_p`] — the paper's
/// 1 − 2⁻⁶ for FP16-scale boundaries, 1 − 2⁻⁹ for the E4M3 envelope).
pub fn beta0_for_pressure(max_abs_score: f64, fmt: Format) -> f64 {
    let margin = fmt.overflow_boundary() / 64.0;
    let p_max = beta0_grid_max_p(fmt);
    let mut p: i32 = 4;
    while p < p_max && max_abs_score * 2f64.powi(-p) > margin {
        p += 1;
    }
    1.0 - 2f64.powi(-p)
}

/// One solved β per observed per-head score peak: grid pick via
/// [`beta0_for_pressure`], then the optimal accuracy condition at block
/// width `n` under the rounding of `tp` (which is both the residual
/// budget's boundary and the solver's carrier — the FP16 workflow).
pub fn autotune_betas(max_scores: &[f32], n: usize, tp: Format) -> Vec<f64> {
    autotune_betas_bounded(max_scores, n, tp, tp)
}

/// Boundary-aware autotune: the β₀ grid pick scales its residual budget
/// to `boundary_fmt`'s overflow boundary (448 for the E4M3 rows), while
/// the Table 3 fixed-point solve still rounds against `tp` — the format
/// the shifting matrix M is *stored* in, FP16 in Algorithm 1 regardless
/// of where S lands. Pasa8's autotune is therefore
/// `autotune_betas_bounded(peaks, n, Format::F16, Format::F8E4M3)`:
/// FP16 invariant exactness, 448-scaled shift strength.
pub fn autotune_betas_bounded(
    max_scores: &[f32],
    n: usize,
    tp: Format,
    boundary_fmt: Format,
) -> Vec<f64> {
    max_scores
        .iter()
        .map(|&s| {
            let b0 = beta0_for_pressure(s as f64, boundary_fmt);
            solve_optimal_beta(b0, n, tp, 1e-10, 500).beta
        })
        .collect()
}

/// The fixed-point solver's rounding carrier for a score format: the
/// shifting matrix M is stored FP16 regardless of where S lands
/// (Algorithm 1's annotation — exactly why `AttentionConfig::kprep_gemm`
/// clamps the K' store too), so sub-FP16 score formats clamp to FP16
/// here. The E4M3 grid (eps 2⁻⁴) cannot even represent β/n and would
/// wreck — or fail to converge — the Table 3 solve a
/// `Solved { per_format: true }` policy runs under the Pasa8/Fp8 rows.
fn solver_carrier(fmt: Format) -> Format {
    if fmt == Format::F8E4M3 {
        Format::F16
    } else {
        fmt
    }
}

impl BetaPolicy {
    /// β for query head `head`, under KV block width `n` and score
    /// format `fmt` (both only consulted by [`BetaPolicy::Solved`];
    /// sub-FP16 formats clamp to the FP16 solver carrier — see
    /// `solver_carrier`).
    pub fn resolve(&self, head: usize, n: usize, fmt: Format) -> f64 {
        match self {
            BetaPolicy::Uniform(b) => *b,
            BetaPolicy::PerHead(v) => {
                if v.len() == 1 {
                    v[0]
                } else {
                    assert!(
                        head < v.len(),
                        "PerHead policy has {} betas but head {head} was requested",
                        v.len()
                    );
                    v[head]
                }
            }
            BetaPolicy::Solved { beta0, per_format } => {
                let tp = if *per_format {
                    solver_carrier(fmt)
                } else {
                    Format::F16
                };
                let s = solve_optimal_beta(*beta0, n, tp, 1e-10, 500);
                // The solver reports non-convergence (e.g. a β₀ at the
                // fixed-point pole near 1) instead of silently returning
                // the seed; dispatching that seed would run the kernels
                // with a near-singular shifting matrix, so fail loudly.
                assert!(
                    s.converged,
                    "Solved beta policy did not converge from beta0 {beta0} at n {n} \
                     (residual {:.3e} after {} iterations)",
                    s.residual, s.iterations
                );
                s.beta
            }
        }
    }

    /// The autotune pass: per-head β table from observed kernel telemetry
    /// (one [`HeadStats`] per query head), fed through the Table 3 solver.
    pub fn autotune(stats: &[HeadStats], n: usize, tp: Format) -> BetaPolicy {
        Self::autotune_bounded(stats, n, tp, tp)
    }

    /// Boundary-aware [`Self::autotune`]: the residual budget scales to
    /// `boundary_fmt`'s overflow boundary while the solver keeps rounding
    /// against `tp` (the shifting matrix's FP16 storage). This is the
    /// Pasa8 workflow — `autotune_bounded(stats, n, Format::F16,
    /// Format::F8E4M3)` solves shifts strong enough for the 448 envelope.
    pub fn autotune_bounded(
        stats: &[HeadStats],
        n: usize,
        tp: Format,
        boundary_fmt: Format,
    ) -> BetaPolicy {
        let peaks: Vec<f32> = stats.iter().map(|s| s.max_abs_score).collect();
        BetaPolicy::PerHead(autotune_betas_bounded(&peaks, n, tp, boundary_fmt))
    }

    /// Autotune straight off a probe run's [`AttentionOutput`].
    pub fn autotune_from(out: &AttentionOutput, n: usize, tp: Format) -> BetaPolicy {
        Self::autotune(&out.stats, n, tp)
    }

    pub fn is_uniform(&self) -> bool {
        matches!(self, BetaPolicy::Uniform(_))
    }

    /// Resolve a `Solved` policy into the concrete `Uniform` β it solves
    /// to (other variants pass through unchanged) — the install-time
    /// path: solve once when the policy is configured (e.g. on
    /// `LabModel::beta_policy`) instead of on every kernel forward, and
    /// get a pole seed back as an error instead of a dispatch panic.
    pub fn resolved(&self, n: usize, fmt: Format) -> Result<BetaPolicy, String> {
        match self {
            BetaPolicy::Solved { beta0, per_format } => {
                let tp = if *per_format {
                    solver_carrier(fmt)
                } else {
                    Format::F16
                };
                let s = solve_optimal_beta(*beta0, n, tp, 1e-10, 500);
                if !s.converged {
                    return Err(format!(
                        "Solved beta policy did not converge from beta0 {beta0} at n {n} \
                         (residual {:.3e} after {} iterations)",
                        s.residual, s.iterations
                    ));
                }
                Ok(BetaPolicy::Uniform(s.beta))
            }
            other => Ok(other.clone()),
        }
    }

    /// Structural validation against a request's head count, KV block
    /// width `n` and score format `fmt`: every β must lie in [0, 1)
    /// (β = 0 legally degrades PASA to FA2, β = 1 makes the shifting
    /// matrix singular — Theorem 2.1's λ·n = 1 condition), and a `Solved`
    /// seed must actually converge — a seed at the fixed-point pole is a
    /// normal validation error here, never a mid-forward panic.
    pub fn validate(&self, n_heads: usize, n: usize, fmt: Format) -> Result<(), String> {
        let check = |b: f64, what: &str| -> Result<(), String> {
            if !(0.0..1.0).contains(&b) || !b.is_finite() {
                return Err(format!("{what} beta {b} outside [0, 1)"));
            }
            Ok(())
        };
        match self {
            BetaPolicy::Uniform(b) => check(*b, "Uniform"),
            BetaPolicy::PerHead(v) => {
                if v.is_empty() {
                    return Err("PerHead policy has no betas".into());
                }
                if v.len() != 1 && v.len() != n_heads {
                    return Err(format!(
                        "PerHead policy has {} betas for {n_heads} heads (need 1 or one per head)",
                        v.len()
                    ));
                }
                for &b in v {
                    check(b, "PerHead")?;
                }
                Ok(())
            }
            BetaPolicy::Solved { beta0, .. } => {
                check(*beta0, "Solved seed")?;
                self.resolved(n, fmt).map(|_| ())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::beta::PAPER_BETAS;

    #[test]
    fn pressure_grid_matches_paper_initials() {
        // Benign, warm and hot peaks land on the paper's three initials.
        assert_eq!(beta0_for_pressure(10.0, Format::F16), 0.9375);
        assert_eq!(beta0_for_pressure(25_600.0, Format::F16), 1.0 - 2f64.powi(-5));
        assert_eq!(beta0_for_pressure(230_000.0, Format::F16), 1.0 - 2f64.powi(-6));
        // Monotone in the peak.
        let mut last = 0.0;
        for s in [1.0, 1e3, 3e4, 1e5, 1e6] {
            let b = beta0_for_pressure(s, Format::F16);
            assert!(b >= last, "beta0 not monotone at peak {s}");
            last = b;
        }
    }

    #[test]
    fn e4m3_boundary_rederives_the_grid() {
        // The 448 boundary scales the residual budget to 448/64 = 7 and
        // extends the grid to p = 9. A 512-scale peak — benign under the
        // FP16 budget — now needs 1 − 2⁻⁷; kilo-scale peaks saturate at
        // the extended strongest candidate 1 − 2⁻⁹.
        let f8 = Format::F8E4M3;
        assert_eq!(beta0_grid_max_p(Format::F16), 6);
        assert_eq!(beta0_grid_max_p(Format::Bf16), 6);
        assert_eq!(beta0_grid_max_p(f8), 9);
        assert_eq!(beta0_for_pressure(10.0, f8), 0.9375);
        assert_eq!(beta0_for_pressure(512.0, f8), 1.0 - 2f64.powi(-7));
        assert_eq!(beta0_for_pressure(3000.0, f8), 1.0 - 2f64.powi(-9));
        assert_eq!(beta0_for_pressure(1e6, f8), 1.0 - 2f64.powi(-9));
        // The very same 512 peak keeps the mildest β under FP16's budget.
        assert_eq!(beta0_for_pressure(512.0, Format::F16), 0.9375);
        // Monotone in the peak under the tight boundary too.
        let mut last = 0.0;
        for s in [1.0, 50.0, 500.0, 5e3, 5e4, 5e5] {
            let b = beta0_for_pressure(s, f8);
            assert!(b >= last, "beta0 not monotone at peak {s}");
            last = b;
        }
    }

    #[test]
    fn extended_grid_initials_solve_and_converge() {
        // Every extended candidate (p = 7..=9) must pass the Table 3
        // fixed-point solve under the FP16 carrier — they all sit on the
        // good side of the ≈ 0.9999 pole.
        use crate::attention::beta::{ideal_invariant, practical_invariant};
        for p in 4..=9 {
            let b0 = 1.0 - 2f64.powi(-p);
            let s = solve_optimal_beta(b0, 128, Format::F16, 1e-10, 500);
            assert!(s.converged, "p={p}: initial {b0} did not converge");
            assert!(s.beta > 0.9 && s.beta < 1.0, "p={p}: solved {}", s.beta);
            let i = ideal_invariant(s.beta);
            let i1 = practical_invariant(s.beta, 128, Format::F16);
            assert!(((i - i1) / i).abs() < 1e-9, "p={p}: invariance error");
        }
    }

    #[test]
    fn bounded_autotune_solves_stronger_shifts_for_the_448_envelope() {
        // One peak, two budgets: under FP16 the 512 peak keeps the mild
        // 0.9375; under the E4M3 boundary the same peak solves a strictly
        // stronger β — and the solve itself still rounds against FP16 (the
        // shifting matrix's storage), so the invariant stays exact.
        let peaks = [512.0f32];
        let f16 = autotune_betas_bounded(&peaks, 128, Format::F16, Format::F16);
        let f8 = autotune_betas_bounded(&peaks, 128, Format::F16, Format::F8E4M3);
        assert!((f16[0] - 0.9375).abs() < 5e-6);
        assert!(f8[0] > f16[0], "448 budget must shift harder: {f8:?}");
        assert_eq!(
            autotune_betas(&peaks, 128, Format::F16),
            f16,
            "unbounded autotune is the tp-bounded special case"
        );
    }

    #[test]
    fn autotuned_betas_are_the_solved_paper_values() {
        // The three grid picks solve to Table 3's optimized βs.
        let betas = autotune_betas(&[10.0, 25_600.0, 230_000.0], 128, Format::F16);
        for (b, expect) in betas.iter().zip(&PAPER_BETAS) {
            assert!((b - expect).abs() < 5e-6, "{b} vs {expect}");
        }
    }

    #[test]
    fn resolve_covers_all_variants() {
        assert_eq!(BetaPolicy::Uniform(0.5).resolve(3, 128, Format::F16), 0.5);
        let per = BetaPolicy::PerHead(vec![0.1, 0.2, 0.3]);
        assert_eq!(per.resolve(1, 128, Format::F16), 0.2);
        // One entry broadcasts.
        let bc = BetaPolicy::PerHead(vec![0.7]);
        assert_eq!(bc.resolve(5, 128, Format::F16), 0.7);
        // Solved matches the direct solver call.
        let sol = BetaPolicy::Solved {
            beta0: 1.0 - 2f64.powi(-6),
            per_format: false,
        };
        let direct = solve_optimal_beta(1.0 - 2f64.powi(-6), 128, Format::F16, 1e-10, 500).beta;
        assert_eq!(sol.resolve(0, 128, Format::F16), direct);
        // per_format consults the passed score format instead of FP16.
        let solf = BetaPolicy::Solved {
            beta0: 1.0 - 2f64.powi(-6),
            per_format: true,
        };
        let bf = solve_optimal_beta(1.0 - 2f64.powi(-6), 128, Format::Bf16, 1e-10, 500).beta;
        assert_eq!(solf.resolve(0, 128, Format::Bf16), bf);
    }

    #[test]
    #[should_panic(expected = "PerHead policy has 2 betas but head 4")]
    fn per_head_out_of_range_panics() {
        BetaPolicy::PerHead(vec![0.9, 0.95]).resolve(4, 128, Format::F16);
    }

    #[test]
    #[should_panic(expected = "did not converge")]
    fn solved_policy_refuses_an_unconverged_seed() {
        // β₀ = 0.9999 sits at the FP16 fixed-point pole (the solver keeps
        // the seed and reports converged = false); resolving it must fail
        // loudly instead of shipping the near-singular β to the kernels.
        BetaPolicy::Solved {
            beta0: 0.9999,
            per_format: false,
        }
        .resolve(0, 128, Format::F16);
    }

    #[test]
    fn validation_rules() {
        let v = |p: &BetaPolicy, heads: usize| p.validate(heads, 128, Format::F16);
        assert!(v(&BetaPolicy::Uniform(0.984497), 8).is_ok());
        assert!(v(&BetaPolicy::Uniform(0.0), 8).is_ok()); // FA2 degradation
        assert!(v(&BetaPolicy::Uniform(1.0), 8).is_err()); // singular M
        assert!(v(&BetaPolicy::Uniform(-0.1), 8).is_err());
        assert!(v(&BetaPolicy::Uniform(f64::NAN), 8).is_err());
        assert!(v(&BetaPolicy::PerHead(vec![]), 2).is_err());
        assert!(v(&BetaPolicy::PerHead(vec![0.9]), 8).is_ok()); // broadcast
        assert!(v(&BetaPolicy::PerHead(vec![0.9; 8]), 8).is_ok());
        assert!(v(&BetaPolicy::PerHead(vec![0.9; 3]), 8).is_err());
        let solved = |beta0: f64| BetaPolicy::Solved {
            beta0,
            per_format: false,
        };
        assert!(v(&solved(0.99), 4).is_ok());
        assert!(v(&solved(1.5), 4).is_err());
        // A seed at the FP16 fixed-point pole is a *validation* error —
        // callers learn before dispatch, not via a mid-forward panic.
        assert!(v(&solved(0.9999), 4).is_err());
    }

    #[test]
    fn solved_policy_clamps_e4m3_to_the_fp16_solver_carrier() {
        // A per-format Solved policy under an E4M3 score format (the
        // Pasa8/Fp8 rows) must solve on the FP16 grid — M is stored FP16
        // regardless of where S lands, and the E4M3 grid cannot represent
        // β/n. The resolved β is therefore identical to the FP16 solve,
        // and never a mid-forward panic.
        let sol = BetaPolicy::Solved {
            beta0: 1.0 - 2f64.powi(-6),
            per_format: true,
        };
        let f16 = solve_optimal_beta(1.0 - 2f64.powi(-6), 128, Format::F16, 1e-10, 500).beta;
        assert_eq!(sol.resolve(0, 128, Format::F8E4M3), f16);
        assert_eq!(
            sol.resolved(128, Format::F8E4M3).unwrap(),
            BetaPolicy::Uniform(f16)
        );
        assert!(sol.validate(4, 128, Format::F8E4M3).is_ok());
        // Bf16 (which has its own sane grid) still solves per-format.
        let bf = solve_optimal_beta(1.0 - 2f64.powi(-6), 128, Format::Bf16, 1e-10, 500).beta;
        assert_eq!(sol.resolve(0, 128, Format::Bf16), bf);
    }

    #[test]
    fn resolved_maps_solved_to_the_concrete_uniform() {
        // Install-time resolution: Solved collapses to Uniform(solved β),
        // other variants pass through; the pole seed surfaces as Err.
        let solved = BetaPolicy::Solved {
            beta0: 1.0 - 2f64.powi(-6),
            per_format: false,
        };
        let expect = solve_optimal_beta(1.0 - 2f64.powi(-6), 128, Format::F16, 1e-10, 500).beta;
        assert_eq!(
            solved.resolved(128, Format::F16).unwrap(),
            BetaPolicy::Uniform(expect)
        );
        let uni = BetaPolicy::Uniform(0.9375);
        assert_eq!(uni.resolved(128, Format::F16).unwrap(), uni);
        let pole = BetaPolicy::Solved {
            beta0: 0.9999,
            per_format: false,
        };
        assert!(pole.resolved(128, Format::F16).is_err());
    }

    #[test]
    fn default_is_the_paper_beta() {
        assert_eq!(BetaPolicy::default(), BetaPolicy::Uniform(PAPER_BETA));
    }
}
