//! Flash Attention 2 (§1.1, Eqs. 1–8) under each precision allocation of
//! Figs. 1–3 (S4), with prefix-mask support and pre-store overflow
//! telemetry.
//!
//! The block loop is the paper's: for each Q block i sweep the KV blocks j,
//! maintaining the online (m, l, O) triplet. Precision emulation:
//! * GEMMs run at `cfg.gemm()` (FP32 acc; store FP32 for Fa32, FP16
//!   otherwise — the FP16 store of S is the overflow site),
//! * the static scaling, softmax and online update run at
//!   `cfg.alloc.vector_fmt()` (FP32 for Fa32/Fa16_32, FP16 for Fa16).
//!
//! Overflow semantics follow the store format: FP16 S elements beyond
//! ±65504 become ±inf and `exp(inf − inf) = NaN` poisons the row; E4M3
//! (which has no infinity) stores past-448 elements as NaN directly —
//! both are exactly the paper's INF/NaN failure mode, and both are
//! *reported* by the pre-store telemetry. Masking never changes that:
//! masked score positions are skipped on the matrix engine and get
//! exactly zero softmax weight through the prefix-aware fused ops
//! (`scale_rowmax_prefix` / `exp_sub_rowbias_prefix_rowsum_into` — never
//! a −inf sentinel pushed through a store format that may not represent
//! it); fully-masked query rows produce zero output rows rather than
//! NaN; and KV blocks past every row's visible prefix are skipped
//! outright (the flash-causal tiling win).
//!
//! ## Hot-path layout
//!
//! The per-Q-block core is [`flash_q_block`]: it runs entirely out of a
//! thread-local [`AttnWorkspace`] (gathers, S/P/PV blocks, online state)
//! through the fused `tensor::ops` kernels and the GEMM `_into` entries,
//! so the KV sweep performs zero heap allocations once the workspace is
//! warm. Q blocks are independent (each owns its online state and output
//! rows), which is what lets the kernel layer fan (head × Q-block) tiles
//! onto the persistent worker pool — sequential and pooled execution are
//! bit-identical because they run this exact function per tile.

use super::config::AttentionConfig;
use super::request::{HeadMask, HeadStats, KvView};
use super::workspace::{reset_vec, with_workspace, AttnWorkspace};
use crate::tensor::{
    matmul_nn_into, matmul_nt_prefix_into, matmul_nt_stats_into, ops, GemmStats, Matrix,
};
use crate::workloads::AttentionCase;

/// FA2 forward pass for one (unmasked) head — legacy single-head entry.
pub fn flash_attention(case: &AttentionCase, cfg: &AttentionConfig) -> Matrix {
    flash_head(&case.q, &case.k, &case.v, HeadMask::None, cfg).0
}

/// Masked FA2 forward pass for one head over dense K/V — thin wrapper
/// around the view-based core [`flash_head_kv`].
pub fn flash_head(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    mask: HeadMask,
    cfg: &AttentionConfig,
) -> (Matrix, HeadStats) {
    flash_head_kv(q, KvView::Dense(k), KvView::Dense(v), mask, cfg)
}

/// Masked FA2 forward pass for one head over [`KvView`] operands, with
/// telemetry. This drives [`flash_q_block`] over the head's Q blocks
/// sequentially; [`super::kernel::FlashKernel`] fans the same per-block
/// core out as (head × Q-block) tiles. The KV sweep gathers one block at
/// a time through the view, so a paged operand is walked page-by-page —
/// `O(len_tokens)` rows touched per pass, never a dense `(max_seq, W)`
/// assembly.
pub fn flash_head_kv(
    q: &Matrix,
    k: KvView<'_>,
    v: KvView<'_>,
    mask: HeadMask,
    cfg: &AttentionConfig,
) -> (Matrix, HeadStats) {
    let s1_total = q.rows;
    let mut out = Matrix::zeros(s1_total, v.cols());
    let oc = out.cols;
    let mut gstats = GemmStats::default();
    with_workspace(|ws| {
        let mut i0 = 0;
        while i0 < s1_total {
            let i1 = (i0 + cfg.blocks.s1).min(s1_total);
            let out_rows = &mut out.data[i0 * oc..i1 * oc];
            let gs = flash_q_block(q, k, v, mask, cfg, i0, i1, out_rows, ws);
            gstats.merge(&gs);
            i0 = i1;
        }
    });
    let stats = HeadStats::finish(gstats, &out);
    (out, stats)
}

// lint: hot-path — the FA2 tile body; allocation-free given a warm
// workspace (pinned by rust/tests/alloc_discipline.rs).
/// One Q block of the FA2 forward: rows `[i0, i1)` of `q` against the
/// full KV sweep, writing the finished output rows into `out_rows`
/// (`(i1 − i0) × dv`, row-major) and returning the block's pre-store
/// score telemetry. Pure in its inputs and allocation-free given a warm
/// [`AttnWorkspace`] — the tile unit of the worker-pool fan-out.
#[allow(clippy::too_many_arguments)]
pub(crate) fn flash_q_block(
    q: &Matrix,
    k: KvView<'_>,
    v: KvView<'_>,
    mask: HeadMask,
    cfg: &AttentionConfig,
    i0: usize,
    i1: usize,
    out_rows: &mut [f32],
    ws: &mut AttnWorkspace,
) -> GemmStats {
    let (s1_total, d) = q.shape();
    let s2_total = k.rows();
    let alpha = (d as f64).sqrt() as f32;
    let inv_alpha = 1.0 / alpha;
    let bs = cfg.blocks;
    let vfmt = cfg.alloc.vector_fmt();
    let sfmt = cfg.alloc.score_fmt();
    let gemm = cfg.gemm();
    let boundary = gemm.store.overflow_boundary() as f32;
    let mut gstats = GemmStats::default();

    let rows = i1 - i0;
    let dv = v.cols();
    debug_assert_eq!(out_rows.len(), rows * dv);
    let qi = q.rows_ref(i0, i1);

    // Visible KV prefix per query row; prefix masks are monotone in i,
    // so the last row bounds the block sweep.
    mask.visible_rows_into(i0, i1, s1_total, s2_total, &mut ws.vis);
    let max_vis = *ws.vis.last().unwrap();

    // Online state: m starts at −inf (Eq. 4's identity element),
    // l at 0, O at 0.
    reset_vec(&mut ws.m, rows, f32::NEG_INFINITY);
    reset_vec(&mut ws.l, rows, 0.0);
    ws.oi.reset(rows, dv);

    let mut j0 = 0;
    while j0 < s2_total {
        if j0 >= max_vis {
            break; // every remaining KV block is invisible to this Q block
        }
        let j1 = (j0 + bs.s2).min(s2_total);
        k.block_into(j0, j1, &mut ws.kj);
        v.block_into(j0, j1, &mut ws.vj);
        debug_assert_eq!(ws.kj.cols, d, "gathered K panel width != head_dim");
        debug_assert_eq!(ws.vj.cols, dv, "gathered V panel width != head_dim");
        let width = j1 - j0;
        ws.bvis.clear();
        ws.bvis
            .extend(ws.vis.iter().map(|&t| t.saturating_sub(j0).min(width)));

        // Eq. (1): S = Q_i·K_jᵀ — the matrix-engine GEMM; the store
        // format decides whether |S| > the boundary overflows. Masked
        // columns are skipped (never touch the matrix engine); the
        // prefix-aware softmax ops below give them exactly zero weight,
        // so the fill value is never consumed — crucially, it is never
        // pushed through a store format that can't represent −inf (E4M3
        // would round it to NaN and poison the whole row).
        let fully_visible = ws.bvis.iter().all(|&b| b == width);
        if fully_visible {
            matmul_nt_stats_into(qi, &ws.kj, gemm, None, boundary, &mut gstats, &mut ws.s);
            // Eq. (2) + Eq. (4): static scaling S/α in the score format
            // (inf/α = inf), fused with m_j's row max — one pass over S.
            ops::scale_rowmax(&mut ws.s, inv_alpha, sfmt, &mut ws.row_m);
        } else {
            matmul_nt_prefix_into(
                qi,
                &ws.kj,
                gemm,
                &ws.bvis,
                f32::NEG_INFINITY,
                boundary,
                &mut gstats,
                &mut ws.s,
            );
            ops::scale_rowmax_prefix(&mut ws.s, inv_alpha, sfmt, &ws.bvis, &mut ws.row_m);
        }
        ws.m_new.clear();
        ws.m_new
            .extend(ws.m.iter().zip(&ws.row_m).map(|(&a, &b)| a.max(b)));

        // Eq. (5) + Eq. (6) rowsum: P = exp(S − m) — attenuator, never
        // overflows — with its row sums accumulated in the same pass;
        // masked positions hold exactly zero weight.
        if fully_visible {
            ops::exp_sub_rowbias_rowsum_into(&ws.s, &ws.m_new, vfmt, &mut ws.p, &mut ws.row_l);
        } else {
            ops::exp_sub_rowbias_prefix_rowsum_into(
                &ws.s, &ws.m_new, &ws.bvis, vfmt, &mut ws.p, &mut ws.row_l,
            );
        }

        // Eq. (6): l = exp(m_{j−1} − m_j)·l + rowsum(P).
        ws.decay.clear();
        ws.decay.extend(
            ws.m
                .iter()
                .zip(&ws.m_new)
                .map(|(&a, &b)| vfmt.round((a - b).exp())),
        );
        for r in 0..rows {
            ws.l[r] = vfmt.round(vfmt.round(ws.decay[r] * ws.l[r]) + ws.row_l[r]);
        }

        // Eq. (7): O = exp(m_{j−1} − m_j)·O + P·V_j.
        matmul_nn_into(ws.p.as_rows_ref(), &ws.vj, gemm, &mut ws.pv);
        ops::scale_add_rows(&mut ws.oi, &ws.decay, &ws.pv, vfmt);

        std::mem::swap(&mut ws.m, &mut ws.m_new);
        j0 = j1;
    }

    // Eq. (8): O_i = O_i / l, written straight into the head's output
    // rows. Fully-masked rows (vis == 0, l == 0) are zero by definition —
    // the online state never saw a score, so 0/0 here is a masking
    // artifact, not a data overflow.
    ops::div_rows_masked_into(&ws.oi, &ws.l, &ws.vis, vfmt, out_rows);
    gstats
}
// lint: end-hot-path

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::config::Allocation;
    use crate::attention::naive::{naive_attention_f32, naive_attention_masked_f32};
    use crate::numerics::{has_overflow, relative_rmse, Format};
    use crate::workloads::{gen_case, Distribution, Pcg64};

    fn rounded_case(dist: Distribution, s: usize, d: usize, seed: u64) -> AttentionCase {
        let mut rng = Pcg64::new(seed, 0);
        let mut c = gen_case(dist, s, s, d, &mut rng);
        c.q.round_to(Format::F16);
        c.k.round_to(Format::F16);
        c.v.round_to(Format::F16);
        c
    }

    #[test]
    fn fa32_matches_naive_closely() {
        let c = rounded_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 200, 32, 1);
        let golden = naive_attention_f32(&c);
        let cfg = AttentionConfig::new(Allocation::Fa32).with_blocks(64, 64);
        let o = flash_attention(&c, &cfg);
        let e = relative_rmse(&o.data, &golden.data);
        assert!(e < 1e-5, "rmse {e}");
    }

    #[test]
    fn block_size_does_not_change_math() {
        let c = rounded_case(Distribution::Uniform { x0: 2.0, am: 1.0 }, 150, 16, 2);
        let a = flash_attention(&c, &AttentionConfig::new(Allocation::Fa32).with_blocks(32, 32));
        let b = flash_attention(&c, &AttentionConfig::new(Allocation::Fa32).with_blocks(150, 150));
        let e = relative_rmse(&a.data, &b.data);
        assert!(e < 1e-5, "rmse {e}");
    }

    #[test]
    fn ragged_tail_blocks_handled() {
        // 100 is not a multiple of 64 — tail blocks of 36.
        let c = rounded_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 100, 8, 3);
        let golden = naive_attention_f32(&c);
        let o = flash_attention(&c, &AttentionConfig::new(Allocation::Fa32).with_blocks(64, 64));
        assert!(relative_rmse(&o.data, &golden.data) < 1e-5);
    }

    #[test]
    fn repeated_calls_reuse_the_workspace_bit_identically() {
        // Workspace reuse must be invisible: the second call runs on warm
        // (dirty) buffers and must reproduce the first call bit for bit,
        // across shapes that exercise ragged tails and masks. Compare bit
        // patterns, not f32 values: masked FP8 rows are NaN by design
        // (E4M3FN has no −inf sentinel) and NaN != NaN would blind a
        // value-level comparison.
        let bits = |m: &Matrix| m.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        for &(s, d, blocks) in &[(100usize, 16usize, 64usize), (96, 8, 32)] {
            let c = rounded_case(Distribution::Uniform { x0: 3.0, am: 1.0 }, s, d, 17);
            for alloc in [Allocation::Fa16_32, Allocation::Fa16, Allocation::Fp8] {
                let cfg = AttentionConfig::new(alloc).with_blocks(blocks, blocks);
                let (first, st1) = flash_head(&c.q, &c.k, &c.v, HeadMask::Causal, &cfg);
                let (second, st2) = flash_head(&c.q, &c.k, &c.v, HeadMask::Causal, &cfg);
                assert_eq!(bits(&first), bits(&second), "{} s={s}", alloc.name());
                assert_eq!(st1.overflow_events, st2.overflow_events);
                assert_eq!(st1.max_abs_score, st2.max_abs_score);
                assert_eq!(st1.nonfinite_outputs, st2.nonfinite_outputs);
            }
        }
    }

    #[test]
    fn fa16_32_overflows_on_large_mean() {
        // Fig. 9(a)'s x0 = 30 point: uniform mean 30 at d=128 makes
        // S ≈ 30·30·128 = 115200 > 65504 — the FP16 store overflows and
        // the output is poisoned with NaN.
        let c = rounded_case(Distribution::Uniform { x0: 30.0, am: 0.5 }, 256, 128, 4);
        let (o, stats) = flash_head(
            &c.q,
            &c.k,
            &c.v,
            HeadMask::None,
            &AttentionConfig::new(Allocation::Fa16_32),
        );
        assert!(has_overflow(&o.data), "expected NaN/inf in output");
        assert!(stats.overflow_events > 0);
        assert!(stats.max_abs_score > 65504.0);
        assert!(stats.nonfinite_outputs > 0);
        // While FA(FP32) sails through:
        let o32 = flash_attention(&c, &AttentionConfig::new(Allocation::Fa32));
        assert!(!has_overflow(&o32.data));
    }

    #[test]
    fn fa16_accuracy_degrades_but_works_on_small_data() {
        let c = rounded_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 128, 64, 5);
        let golden = naive_attention_f32(&c);
        let o = flash_attention(&c, &AttentionConfig::new(Allocation::Fa16));
        assert!(!has_overflow(&o.data));
        let e = relative_rmse(&o.data, &golden.data);
        assert!(e < 5e-2, "rmse {e}");
        assert!(e > 1e-6, "suspiciously exact for full FP16");
    }

    #[test]
    fn causal_mask_matches_masked_naive_across_blockings() {
        let c = rounded_case(Distribution::Uniform { x0: 1.0, am: 1.0 }, 100, 16, 6);
        let golden = naive_attention_masked_f32(&c, HeadMask::Causal);
        for &(s1, s2) in &[(32usize, 32usize), (64, 64), (100, 100), (64, 32)] {
            let cfg = AttentionConfig::new(Allocation::Fa32).with_blocks(s1, s2);
            let (o, _) = flash_head(&c.q, &c.k, &c.v, HeadMask::Causal, &cfg);
            let e = relative_rmse(&o.data, &golden.data);
            assert!(e < 1e-5, "blocks ({s1},{s2}): rmse {e}");
        }
    }

    #[test]
    fn masking_rescues_a_poisoned_padding_region() {
        // Keys in the padding region are huge; unmasked FA16-32 dies,
        // prefix-masked FA16-32 matches the truncated reference.
        let mut c = rounded_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 128, 64, 7);
        for r in 96..128 {
            for j in 0..64 {
                c.k.set(r, j, 3.0e4);
            }
        }
        let cfg = AttentionConfig::new(Allocation::Fa16_32).with_blocks(64, 64);
        let (dense, dense_stats) = flash_head(&c.q, &c.k, &c.v, HeadMask::None, &cfg);
        assert!(has_overflow(&dense.data), "premise: padding poisons");
        assert!(dense_stats.overflow_events > 0);
        let (masked, masked_stats) = flash_head(&c.q, &c.k, &c.v, HeadMask::Prefix(96), &cfg);
        assert!(!has_overflow(&masked.data));
        assert_eq!(masked_stats.overflow_events, 0);
        let golden = naive_attention_masked_f32(&c, HeadMask::Prefix(96));
        let e = relative_rmse(&masked.data, &golden.data);
        assert!(e < 5e-2, "rmse {e}");
    }

    #[test]
    fn masked_fp8_rows_stay_finite_and_match_naive() {
        // Regression for the E4M3 mask fix: the old path filled masked
        // score positions with −inf, which E4M3 (no infinity) rounded to
        // NaN — every causally masked row came out poisoned with *clean*
        // telemetry. The prefix-aware fused ops must keep masked FP8
        // finite on benign data, with zero overflow events, inside the
        // E4M3 envelope of the masked golden.
        let c = rounded_case(Distribution::Uniform { x0: 0.0, am: 0.5 }, 64, 8, 9);
        let cfg = AttentionConfig::new(Allocation::Fp8).with_blocks(32, 32);
        for mask in [HeadMask::Causal, HeadMask::Prefix(40)] {
            let (o, stats) = flash_head(&c.q, &c.k, &c.v, mask, &cfg);
            assert!(
                !has_overflow(&o.data),
                "{mask:?}: masked FP8 must stay finite on benign data"
            );
            assert_eq!(stats.overflow_events, 0, "{mask:?}");
            assert_eq!(stats.nonfinite_outputs, 0, "{mask:?}");
            let golden = naive_attention_masked_f32(&c, mask);
            let e = relative_rmse(&o.data, &golden.data);
            assert!(e < 0.3, "{mask:?}: rmse {e} beyond the E4M3 envelope");
        }
        // The FP16 masked path is bit-unchanged by the prefix ops: pin it
        // against the masked golden at the FP16 envelope.
        let cfg16 = AttentionConfig::new(Allocation::Fa16_32).with_blocks(32, 32);
        let (o, _) = flash_head(&c.q, &c.k, &c.v, HeadMask::Causal, &cfg16);
        let golden = naive_attention_masked_f32(&c, HeadMask::Causal);
        assert!(relative_rmse(&o.data, &golden.data) < 5e-2);
    }

    #[test]
    fn fully_masked_rows_are_zero() {
        let c = rounded_case(Distribution::Uniform { x0: 1.0, am: 0.5 }, 64, 16, 8);
        let cfg = AttentionConfig::new(Allocation::Fa16_32).with_blocks(32, 32);
        let (o, stats) = flash_head(&c.q, &c.k, &c.v, HeadMask::Prefix(0), &cfg);
        assert!(o.data.iter().all(|&x| x == 0.0), "empty softmax must be 0");
        assert_eq!(stats.nonfinite_outputs, 0);
        assert_eq!(stats.overflow_events, 0);
    }
}
