//! Attention lab (S4): the paper's algorithm and every baseline, under
//! bit-exact precision emulation.
//!
//! Entry point: [`run_attention`] dispatches an [`AttentionConfig`] over a
//! single-head [`crate::workloads::AttentionCase`]; inputs are rounded to
//! the FP16 grid first (models store activations in half precision — the
//! paper's premise that "input tensors are within the normal range of low
//! precision formats").

pub mod beta;
pub mod config;
pub mod flash;
pub mod naive;
pub mod pasa;
pub mod shifting;

pub use beta::{solve_optimal_beta, PAPER_BETA, PAPER_BETAS};
pub use config::{Allocation, AttentionConfig, BlockSizes};
pub use flash::flash_attention;
pub use naive::{naive_attention_f32, raw_scores_f32};
pub use pasa::pasa_attention;
pub use shifting::{preprocess_k, shifting_inverse, shifting_matrix};

use crate::numerics::Format;
use crate::tensor::Matrix;
use crate::workloads::AttentionCase;

/// Round a case's Q/K/V onto the FP16 grid (the model's storage format).
pub fn to_fp16_inputs(case: &AttentionCase) -> AttentionCase {
    let mut c = case.clone();
    c.q.round_to(Format::F16);
    c.k.round_to(Format::F16);
    c.v.round_to(Format::F16);
    c
}

/// Run one attention configuration over a case with FP16-gridded inputs.
pub fn run_attention(case: &AttentionCase, cfg: &AttentionConfig) -> Matrix {
    match cfg.alloc {
        Allocation::Pasa16 => pasa_attention(case, cfg),
        _ => flash_attention(case, cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::relative_rmse;
    use crate::workloads::{gen_case, Distribution, Pcg64};

    #[test]
    fn dispatch_covers_all_allocations() {
        let mut rng = Pcg64::new(1, 0);
        let c = to_fp16_inputs(&gen_case(
            Distribution::Uniform { x0: 0.0, am: 1.0 },
            96,
            96,
            16,
            &mut rng,
        ));
        let golden = naive_attention_f32(&c);
        for alloc in Allocation::all() {
            let cfg = AttentionConfig::new(alloc).with_blocks(32, 32);
            let o = run_attention(&c, &cfg);
            assert_eq!(o.shape(), golden.shape());
            let e = relative_rmse(&o.data, &golden.data);
            assert!(e < 5e-2, "{}: rmse {e}", alloc.name());
        }
    }
}
