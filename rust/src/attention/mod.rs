//! Attention lab (S4): the paper's algorithm and every baseline, under
//! bit-exact precision emulation, behind one kernel API.
//!
//! The single entry point is the [`AttentionRequest`] → [`AttentionKernel`]
//! → [`AttentionOutput`] pipeline:
//!
//! * build an [`AttentionRequest`] (builder-style) carrying Q/K/V for
//!   `n_heads` query heads over `n_kv_heads` KV heads (MQA/GQA via the
//!   head-group mapping), an [`AttnMask`] (`None | Causal | Padded`),
//!   block sizes, PASA's β — as a [`BetaPolicy`] (uniform, per-head
//!   table, or solved at dispatch from the Table 3 condition) — and the
//!   precision [`Allocation`];
//! * fetch the kernel from [`KernelRegistry::get`] — the crate's only
//!   allocation dispatch — or call [`AttentionRequest::run`];
//! * read per-head outputs and overflow telemetry (max |S| before store
//!   rounding, overflow-event counts) off the [`AttentionOutput`], which
//!   is what the coordinator's adaptive guard consumes.
//!
//! Inputs are conventionally rounded to the FP16 grid first
//! ([`AttentionRequest::with_fp16_inputs`] / [`to_fp16_inputs`]) — models
//! store activations in half precision, the paper's premise that "input
//! tensors are within the normal range of low precision formats".
//!
//! The per-head inner kernels remain available as free functions
//! ([`flash_attention`], [`pasa_attention`], [`naive_attention_f32`] and
//! their masked variants) for single-head studies and goldens.
//!
//! ## Hot path
//!
//! The inner loops run out of per-thread scratch arenas
//! ([`workspace::AttnWorkspace`]) through fused in-place `tensor::ops`
//! kernels — zero heap allocations per KV block after warm-up — and
//! multi-head forwards fan out as (head × Q-block) tiles over the
//! persistent [`crate::pool::WorkerPool`]. Both are bit-transparent:
//! pooled, sequential, warm-rerun and paged execution produce identical
//! bits (pinned by the `integration_hotpath` checksum goldens and the
//! `alloc_discipline` counting-allocator test).
//!
//! ## Paged K/V views
//!
//! K/V operands reach the kernels through [`KvView`]: either
//! `Dense(&Matrix)` or `Paged { pages, pool, len_tokens, .. }`, where the
//! pool is any [`KvPageSource`] (the serving coordinator's `KvPool`
//! implements it). The flash/PASA cores iterate KV *blocks* through
//! [`KvView::block`], so a paged operand is gathered page-by-page —
//! `O(len_tokens)` rows touched per forward, never a dense
//! `(max_seq, W)` assembly — and PASA's shared `K' = M·K` preprocessing
//! runs per page-block gather. A paged view's `len_tokens` acts as the
//! `Prefix` mask: stale page tails beyond it are simply outside the view,
//! so they can never enter a softmax or the pseudo-average. Build a
//! request with query heads only and dispatch with
//! [`AttentionRequest::run_with_kv`] (or a kernel's
//! [`AttentionKernel::forward_kv`]); the dense
//! [`AttentionRequest::run`] path wraps the owned K/V in dense views and
//! runs the *same* cores, which is why paged and dense execution are
//! bit-identical by construction.

pub mod beta;
pub mod config;
pub mod flash;
pub mod kernel;
pub mod naive;
pub mod pasa;
pub mod policy;
pub mod request;
pub mod shifting;
pub mod workspace;

pub use beta::{solve_optimal_beta, BetaSolve, PAPER_BETA, PAPER_BETAS};
pub use config::{Allocation, AttentionConfig, BlockSizes};
pub use policy::{
    autotune_betas, autotune_betas_bounded, beta0_for_pressure, beta0_grid_max_p, BetaPolicy,
};
pub use flash::{flash_attention, flash_head, flash_head_kv};
pub use kernel::{AttentionKernel, FlashKernel, KernelRegistry, NaiveKernel, PasaKernel};
pub use naive::{naive_attention_f32, naive_attention_masked_f32, raw_scores_f32};
pub use pasa::{pasa_attention, pasa_head, pasa_head_kv, pasa_preprocess, pasa_preprocess_kv, PasaPre};
pub use request::{
    AttentionOutput, AttentionRequest, AttnMask, HeadMask, HeadStats, KvPageSource, KvPair, KvView,
    PageId,
};
pub use shifting::{preprocess_k, shifting_inverse, shifting_matrix};
pub use workspace::{with_workspace, AttnWorkspace};

use crate::numerics::Format;
use crate::workloads::AttentionCase;

/// Round a case's Q/K/V onto the FP16 grid (the model's storage format).
pub fn to_fp16_inputs(case: &AttentionCase) -> AttentionCase {
    let mut c = case.clone();
    c.q.round_to(Format::F16);
    c.k.round_to(Format::F16);
    c.v.round_to(Format::F16);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::relative_rmse;
    use crate::workloads::{gen_case, Distribution, Pcg64};

    #[test]
    fn registry_dispatch_covers_all_allocations() {
        let mut rng = Pcg64::new(1, 0);
        let c = gen_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 96, 96, 16, &mut rng);
        let req = AttentionRequest::from_case(&c, Allocation::Fa32)
            .with_blocks(32, 32)
            .with_fp16_inputs();
        let golden = KernelRegistry::naive().forward(&req);
        for alloc in Allocation::all() {
            let out = req.clone().with_alloc(alloc).run();
            assert_eq!(out.heads[0].shape(), golden.heads[0].shape());
            let e = relative_rmse(&out.heads[0].data, &golden.heads[0].data);
            assert!(e < 5e-2, "{}: rmse {e}", alloc.name());
        }
    }

    #[test]
    fn case_cfg_entry_point_is_registry_dispatch() {
        // `AttentionRequest::from_case_cfg(..).run()` is the single-head
        // entry point that replaced the removed `run_attention` shim; pin
        // it bitwise to an explicit `KernelRegistry::get` dispatch so the
        // convenience path can never drift from the registry path.
        let mut rng = Pcg64::new(2, 0);
        let c = to_fp16_inputs(&gen_case(
            Distribution::Uniform { x0: 1.0, am: 1.0 },
            64,
            64,
            16,
            &mut rng,
        ));
        for alloc in Allocation::all() {
            let cfg = AttentionConfig::new(alloc).with_blocks(32, 32);
            let via_run = AttentionRequest::from_case_cfg(&c, cfg).run().single();
            let req = AttentionRequest::from_case_cfg(&c, cfg);
            let via_registry = KernelRegistry::get(alloc).forward(&req);
            assert_eq!(via_run.data, via_registry.heads[0].data, "{}", alloc.name());
        }
    }
}
