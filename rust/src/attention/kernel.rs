//! The [`AttentionKernel`] trait and its implementations (S4b).
//!
//! Every attention path in the repo — the full-precision golden reference,
//! Flash Attention under the Figs. 1–3 precision allocations, and PASA —
//! implements one trait method, `forward(&AttentionRequest)`. Multi-head
//! execution fans the per-head inner kernels out over OS threads (the
//! bit-exact emulation is CPU-bound), and PASA shares each KV head's
//! shifted K' blocks across its GQA query group, so the β-shift GEMM is
//! paid once per KV head rather than once per query head.
//!
//! [`KernelRegistry::get`] is the *only* allocation dispatch in the crate:
//! callers pick a precision `Allocation`, the registry hands back the
//! kernel, and every workload shape (masked, GQA, batched) runs through
//! the exact same code path per kernel.

use super::config::Allocation;
use super::flash::flash_head;
use super::naive::naive_head;
use super::pasa::{pasa_head, pasa_preprocess, PasaPre};
use super::request::{AttentionOutput, AttentionRequest, AttnMask, HeadMask, HeadStats};
use crate::tensor::Matrix;

/// A forward-only attention kernel over [`AttentionRequest`]s.
pub trait AttentionKernel: Sync {
    fn name(&self) -> &'static str;
    fn forward(&self, req: &AttentionRequest) -> AttentionOutput;
}

/// Fan a per-head computation out over OS threads, one per head —
/// mirroring the experiment harness's historical thread-per-head layout.
fn fanout_heads<F>(n: usize, f: F) -> (Vec<Matrix>, Vec<HeadStats>)
where
    F: Fn(usize) -> (Matrix, HeadStats) + Sync,
{
    if n <= 1 {
        return (0..n).map(&f).unzip();
    }
    let results: Vec<(Matrix, HeadStats)> = std::thread::scope(|scope| {
        let fref = &f;
        let handles: Vec<_> = (0..n).map(|h| scope.spawn(move || fref(h))).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    results.into_iter().unzip()
}

/// Full-precision golden reference (the `O_Golden` of Eq. 19): f32 GEMMs,
/// f64-carried masked softmax. Its stats instrument the *raw* scores
/// against the FP16 boundary — "would a low-precision store have
/// overflowed here".
pub struct NaiveKernel;

impl AttentionKernel for NaiveKernel {
    fn name(&self) -> &'static str {
        "naive-f32"
    }

    fn forward(&self, req: &AttentionRequest) -> AttentionOutput {
        req.validate().expect("invalid AttentionRequest");
        let (heads, stats) = fanout_heads(req.n_heads(), |h| {
            let kv = req.kv_head_for(h);
            naive_head(&req.q[h], &req.k[kv], &req.v[kv], req.mask_for_head(h))
        });
        AttentionOutput { heads, stats }
    }
}

/// Flash Attention 2 under the precision allocation carried by the
/// request (Fa32 / Fa16_32 / Fa16 — Figs. 1–3).
pub struct FlashKernel;

impl AttentionKernel for FlashKernel {
    fn name(&self) -> &'static str {
        "flash"
    }

    fn forward(&self, req: &AttentionRequest) -> AttentionOutput {
        req.validate().expect("invalid AttentionRequest");
        let (heads, stats) = fanout_heads(req.n_heads(), |h| {
            let kv = req.kv_head_for(h);
            flash_head(&req.q[h], &req.k[kv], &req.v[kv], req.mask_for_head(h), &req.cfg)
        });
        AttentionOutput { heads, stats }
    }
}

/// PASA (Algorithm 1): fully-FP16 flash attention with pseudo-average
/// shifting. The K' = M·K preprocessing is computed once per KV head and
/// shared by the whole GQA query group; padded requests preprocess only
/// the valid KV prefix so padding garbage never leaks into the
/// pseudo-average.
pub struct PasaKernel;

impl AttentionKernel for PasaKernel {
    fn name(&self) -> &'static str {
        "pasa"
    }

    fn forward(&self, req: &AttentionRequest) -> AttentionOutput {
        req.validate().expect("invalid AttentionRequest");
        match &req.mask {
            AttnMask::Padded(_) => {
                // Per-head valid lengths: shift only the valid KV prefix.
                // Preprocessing is still shared — once per distinct
                // (KV head, valid length) pair, so a GQA group with a
                // broadcast length pays the K' GEMM once, not per head.
                let padded_len = |h: usize| {
                    let kv = req.kv_head_for(h);
                    match req.mask_for_head(h) {
                        HeadMask::Prefix(l) => l.min(req.k[kv].rows),
                        _ => unreachable!("Padded mask resolves to Prefix"),
                    }
                };
                let mut pres: Vec<((usize, usize), PasaPre)> = Vec::new();
                for h in 0..req.n_heads() {
                    let key = (req.kv_head_for(h), padded_len(h));
                    if key.1 > 0 && !pres.iter().any(|(k, _)| *k == key) {
                        let kt = req.k[key.0].rows_slice(0, key.1);
                        pres.push((key, pasa_preprocess(&kt, &req.cfg)));
                    }
                }
                let (heads, stats) = fanout_heads(req.n_heads(), |h| {
                    let kv = req.kv_head_for(h);
                    let len = padded_len(h);
                    if len == 0 {
                        // Empty visible set: softmax over nothing is
                        // defined as zero attention output, not NaN.
                        let out = Matrix::zeros(req.q[h].rows, req.v[kv].cols);
                        return (out, HeadStats::default());
                    }
                    let pre = &pres.iter().find(|(k, _)| *k == (kv, len)).unwrap().1;
                    let vt = req.v[kv].rows_slice(0, len);
                    pasa_head(&req.q[h], &vt, pre, HeadMask::None, &req.cfg)
                });
                AttentionOutput { heads, stats }
            }
            _ => {
                // Shared preprocessing per KV head (GQA groups reuse K').
                let pres: Vec<PasaPre> = req
                    .k
                    .iter()
                    .map(|k| pasa_preprocess(k, &req.cfg))
                    .collect();
                let (heads, stats) = fanout_heads(req.n_heads(), |h| {
                    let kv = req.kv_head_for(h);
                    pasa_head(&req.q[h], &req.v[kv], &pres[kv], req.mask_for_head(h), &req.cfg)
                });
                AttentionOutput { heads, stats }
            }
        }
    }
}

static NAIVE: NaiveKernel = NaiveKernel;
static FLASH: FlashKernel = FlashKernel;
static PASA: PasaKernel = PasaKernel;

/// Allocation → kernel. The single construction-time dispatch point.
pub struct KernelRegistry;

impl KernelRegistry {
    /// Kernel implementing the given precision allocation. The three FA
    /// allocations share [`FlashKernel`] (the allocation itself carries
    /// the format table); PASA has its own kernel.
    pub fn get(alloc: Allocation) -> &'static dyn AttentionKernel {
        match alloc {
            Allocation::Pasa16 => &PASA,
            Allocation::Fa32 | Allocation::Fa16_32 | Allocation::Fa16 => &FLASH,
        }
    }

    /// The full-precision golden reference (not an `Allocation` — it is
    /// the metric's denominator, not a candidate).
    pub fn naive() -> &'static dyn AttentionKernel {
        &NAIVE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::relative_rmse;
    use crate::workloads::{gen_case, Distribution, Pcg64};

    fn single(seed: u64) -> AttentionRequest {
        let mut rng = Pcg64::new(seed, 0);
        let c = gen_case(Distribution::Uniform { x0: 0.5, am: 1.0 }, 96, 96, 16, &mut rng);
        AttentionRequest::from_case(&c, Allocation::Fa32).with_fp16_inputs()
    }

    #[test]
    fn registry_covers_every_allocation() {
        assert_eq!(KernelRegistry::get(Allocation::Pasa16).name(), "pasa");
        for alloc in [Allocation::Fa32, Allocation::Fa16_32, Allocation::Fa16] {
            assert_eq!(KernelRegistry::get(alloc).name(), "flash");
        }
        assert_eq!(KernelRegistry::naive().name(), "naive-f32");
    }

    #[test]
    fn run_dispatches_on_request_allocation() {
        let req = single(1);
        let golden = KernelRegistry::naive().forward(&req);
        for alloc in Allocation::all() {
            let out = req.clone().with_alloc(alloc).run();
            assert_eq!(out.heads.len(), 1);
            assert_eq!(out.heads[0].shape(), golden.heads[0].shape());
            let e = relative_rmse(&out.heads[0].data, &golden.heads[0].data);
            assert!(e < 5e-2, "{}: rmse {e}", alloc.name());
        }
    }

    #[test]
    fn multihead_fanout_matches_per_head_runs() {
        // A 4-head MHA request must equal four independent single-head
        // runs, bit for bit (thread fan-out is pure).
        let mut rng = Pcg64::new(7, 0);
        let dist = Distribution::Uniform { x0: 2.0, am: 1.0 };
        let mut req = AttentionRequest::new(Allocation::Fa16_32);
        for _ in 0..4 {
            let c = gen_case(dist, 64, 64, 16, &mut rng);
            req = req.with_head(c.q, c.k, c.v);
        }
        let req = req.with_fp16_inputs().with_blocks(32, 32);
        let out = req.run();
        assert_eq!(out.heads.len(), 4);
        for h in 0..4 {
            let sub = AttentionRequest::from_case_cfg(&req.head_case(h), req.cfg);
            let solo = sub.run();
            assert_eq!(out.heads[h].data, solo.heads[0].data, "head {h}");
            assert_eq!(
                out.stats[h].overflow_events,
                solo.stats[0].overflow_events,
                "head {h} stats"
            );
        }
    }

    #[test]
    fn stats_flag_overflow_before_output_poisoning() {
        // Fig. 9(a) x0=30: FA16-32 overflows; the stats must report both
        // the pre-store magnitude and the poisoned output.
        let mut rng = Pcg64::new(4, 0);
        let c = gen_case(Distribution::Uniform { x0: 30.0, am: 0.5 }, 256, 256, 128, &mut rng);
        let req = AttentionRequest::from_case(&c, Allocation::Fa16_32).with_fp16_inputs();
        let out = req.run();
        assert!(out.overflowed());
        assert!(out.overflow_events() > 0);
        assert!(out.max_abs_score() > 65504.0);
        // PASA on the same request: clean stats end to end.
        let p = req.with_alloc(Allocation::Pasa16).run();
        assert!(!p.overflowed());
        assert_eq!(p.overflow_events(), 0);
        assert!(p.max_abs_score() < 65504.0);
    }
}
