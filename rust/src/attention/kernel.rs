//! The [`AttentionKernel`] trait and its implementations (S4b).
//!
//! Every attention path in the repo — the full-precision golden reference,
//! Flash Attention under the Figs. 1–3 precision allocations, and PASA —
//! implements one trait method, `forward(&AttentionRequest)`.
//!
//! Multi-head execution fans out over the persistent
//! [`crate::pool::WorkerPool`] instead of spawning one OS thread per head
//! per call: the flash and PASA kernels tile the work as **(head ×
//! Q-block)** units — Q blocks own their complete online state, so tiles
//! are independent and any idle worker can steal the next one — while the
//! golden reference fans whole heads. Decode-shaped requests (`s1 = 1`,
//! one tile per head) batch all heads of a step into a single pool
//! submission rather than running them sequentially, which is what the
//! serving engine's per-step latency rides on. Sequential and pooled
//! execution are bit-identical (tiles are pure, write disjoint rows and
//! merge commutative stats); `pool::set_parallel(false)` is the test hook
//! that pins it.
//!
//! PASA shares each KV head's shifted K' blocks across its GQA query
//! group, so the β-shift GEMM is paid once per KV head rather than once
//! per query head.
//!
//! [`KernelRegistry::get`] is the *only* allocation dispatch in the crate:
//! callers pick a precision `Allocation`, the registry hands back the
//! kernel, and every workload shape (masked, GQA, batched) runs through
//! the exact same code path per kernel.

use super::config::{Allocation, AttentionConfig};
use super::flash::flash_q_block;
use super::naive::naive_head_kv;
use super::pasa::{pasa_head_kv, pasa_preprocess_kv, pasa_q_block, PasaPre};
use super::request::{
    AttentionOutput, AttentionRequest, AttnMask, HeadMask, HeadStats, KvPair, KvView,
};
use super::workspace::with_workspace;
use crate::numerics::Format;
use crate::pool;
use crate::tensor::{GemmStats, Matrix};
use std::sync::Mutex;

/// A forward-only attention kernel over [`AttentionRequest`]s.
///
/// The primary entry is [`Self::forward_kv`], which takes the K/V operands
/// as [`super::request::KvView`]s (dense or paged); [`Self::forward`] is
/// the owned-request convenience that wraps the request's own K/V heads in
/// dense views — both run the exact same per-head cores, so the paged path
/// is bit-identical to the dense path by construction.
pub trait AttentionKernel: Sync {
    fn name(&self) -> &'static str;

    /// Forward over the request's own (dense, owned) K/V heads. The shape
    /// rules are checked once, inside `forward_kv`; only the owned-list
    /// pairing (K count == V count) is asserted here, since views can't
    /// express that mismatch.
    fn forward(&self, req: &AttentionRequest) -> AttentionOutput {
        assert_eq!(
            req.k.len(),
            req.v.len(),
            "request needs matching K/V heads"
        );
        self.forward_kv(req, &req.kv_pairs())
    }

    /// Forward with external K/V views standing in for the request's K/V
    /// (which may be empty). `kv` has one entry per KV head; query heads
    /// map onto it with the contiguous GQA grouping.
    fn forward_kv(&self, req: &AttentionRequest, kv: &[KvPair<'_>]) -> AttentionOutput;
}

/// Fan a whole-head computation out as worker-pool tiles, one per head.
/// The per-head fn is pure, so pooled execution is bit-identical to the
/// single-tile inline path.
fn fanout_heads<F>(n: usize, f: F) -> (Vec<Matrix>, Vec<HeadStats>)
where
    F: Fn(usize) -> (Matrix, HeadStats) + Sync,
{
    if n <= 1 {
        return (0..n).map(&f).unzip();
    }
    let slots: Vec<Mutex<Option<(Matrix, HeadStats)>>> = (0..n).map(|_| Mutex::new(None)).collect();
    pool::global().run_tiles(n, |h| {
        *slots[h].lock().unwrap() = Some(f(h));
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("head tile ran"))
        .unzip()
}

/// Row-range writer shared across tiles of one head's output matrix.
/// Tiles of a head partition its Q rows, so writes never overlap. Debug
/// builds *check* the partition claim: every tile's `[i0, i1)` row claim
/// is recorded and asserted disjoint from all earlier claims on the same
/// head before the raw slice is formed.
struct SharedRows {
    ptr: *mut f32,
    cols: usize,
    /// Row intervals handed out so far (debug builds only): the runtime
    /// witness of the "tiles partition the rows" safety argument.
    #[cfg(debug_assertions)]
    claims: Mutex<Vec<(usize, usize)>>,
}

impl SharedRows {
    /// Record a tile's half-open row claim `[i0, i1)` and assert it does
    /// not overlap any interval already claimed on this head. No-op in
    /// release builds.
    #[cfg_attr(not(debug_assertions), allow(unused_variables))]
    fn claim_rows(&self, i0: usize, i1: usize) {
        #[cfg(debug_assertions)]
        {
            let mut claims = self.claims.lock().unwrap();
            for &(a, b) in claims.iter() {
                assert!(
                    i1 <= a || i0 >= b,
                    "SharedRows claim [{i0}, {i1}) overlaps an existing tile claim [{a}, {b})"
                );
            }
            claims.push((i0, i1));
        }
    }
}

// SAFETY: `SharedRows` is only ever dereferenced for disjoint row ranges
// (one tile per (head, Q-block) — debug builds assert the disjointness
// via `claim_rows`), and the owning matrices outlive the fan-out, which
// blocks until every tile completed; sending the raw pointer to a worker
// therefore never outlives or aliases the allocation it points into.
unsafe impl Send for SharedRows {}
// SAFETY: shared access is sound for the same reason sending is: every
// dereference targets a distinct row range, so concurrent tiles never
// touch the same memory through a `&SharedRows`.
unsafe impl Sync for SharedRows {}

/// Fan a per-Q-block computation out as (head × Q-block) worker-pool
/// tiles. `f(h, i0, i1, out_rows)` fills the head's output rows `[i0,
/// i1)` and returns the tile's GEMM telemetry; per-head stats merge
/// commutatively (max of maxima, sum of events), so the merged result is
/// bit-identical to a sequential sweep regardless of tile order.
fn fanout_q_tiles<F>(n_heads: usize, s1: usize, bs1: usize, dv: usize, f: F) -> (Vec<Matrix>, Vec<HeadStats>)
where
    F: Fn(usize, usize, usize, &mut [f32]) -> GemmStats + Sync,
{
    let mut outs: Vec<Matrix> = (0..n_heads).map(|_| Matrix::zeros(s1, dv)).collect();
    let mut tiles: Vec<(usize, usize, usize)> = Vec::new();
    for h in 0..n_heads {
        let mut i0 = 0;
        while i0 < s1 {
            let i1 = (i0 + bs1).min(s1);
            tiles.push((h, i0, i1));
            i0 = i1;
        }
    }
    let stats: Vec<Mutex<GemmStats>> =
        (0..n_heads).map(|_| Mutex::new(GemmStats::default())).collect();
    if tiles.len() <= 1 {
        for &(h, i0, i1) in &tiles {
            let gs = f(h, i0, i1, &mut outs[h].data[i0 * dv..i1 * dv]);
            stats[h].lock().unwrap().merge(&gs);
        }
    } else {
        let shared: Vec<SharedRows> = outs
            .iter_mut()
            .map(|m| SharedRows {
                ptr: m.data.as_mut_ptr(),
                cols: m.cols,
                #[cfg(debug_assertions)]
                claims: Mutex::new(Vec::new()),
            })
            .collect();
        let tiles_ref = &tiles;
        let shared_ref = &shared;
        pool::global().run_tiles(tiles_ref.len(), |t| {
            let (h, i0, i1) = tiles_ref[t];
            let sh = &shared_ref[h];
            sh.claim_rows(i0, i1);
            // SAFETY: see `SharedRows` — tiles partition each head's rows
            // (asserted by `claim_rows` in debug builds), so this slice
            // aliases no other tile's slice, and the owning matrix lives
            // until `run_tiles` returns.
            let rows = unsafe {
                std::slice::from_raw_parts_mut(sh.ptr.add(i0 * sh.cols), (i1 - i0) * sh.cols)
            };
            let gs = f(h, i0, i1, rows);
            stats[h].lock().unwrap().merge(&gs);
        });
    }
    let head_stats: Vec<HeadStats> = outs
        .iter()
        .zip(stats)
        .map(|(o, st)| HeadStats::finish(st.into_inner().unwrap(), o))
        .collect();
    (outs, head_stats)
}

/// Full-precision golden reference (the `O_Golden` of Eq. 19): f32 GEMMs,
/// f64-carried masked softmax. Its stats instrument the *raw* scores
/// against the FP16 boundary — "would a low-precision store have
/// overflowed here".
pub struct NaiveKernel;

impl AttentionKernel for NaiveKernel {
    fn name(&self) -> &'static str {
        "naive-f32"
    }

    fn forward_kv(&self, req: &AttentionRequest, kv: &[KvPair<'_>]) -> AttentionOutput {
        req.validate_kv(kv).expect("invalid AttentionRequest");
        let (heads, stats) = fanout_heads(req.n_heads(), |h| {
            let pair = req.kv_pair_for(kv, h);
            naive_head_kv(&req.q[h], pair.k, pair.v, req.mask_for_head(h))
        });
        AttentionOutput {
            heads,
            stats,
            // The golden instruments raw scores against the FP16 boundary
            // ("would a low-precision store have overflowed here").
            score_boundary: Format::F16.overflow_boundary() as f32,
        }
    }
}

/// Flash Attention 2 under the precision allocation carried by the
/// request (Fa32 / Fa16_32 / Fa16 — Figs. 1–3 — plus the Fp8 row, which
/// is the same code path with E4M3 kernel constants from the config
/// table). Each head consumes its resolved per-head config (β is unused
/// by FA, but the resolution keeps the head-config contract uniform
/// across kernels).
pub struct FlashKernel;

impl AttentionKernel for FlashKernel {
    fn name(&self) -> &'static str {
        "flash"
    }

    fn forward_kv(&self, req: &AttentionRequest, kv: &[KvPair<'_>]) -> AttentionOutput {
        req.validate_kv(kv).expect("invalid AttentionRequest");
        let cfgs = req.head_cfgs();
        let s1 = req.q[0].rows;
        let dv = kv[0].v.cols();
        let (heads, stats) = fanout_q_tiles(
            req.n_heads(),
            s1,
            req.cfg.blocks.s1,
            dv,
            |h: usize, i0: usize, i1: usize, out_rows: &mut [f32]| {
                let pair = req.kv_pair_for(kv, h);
                with_workspace(|ws| {
                    flash_q_block(
                        &req.q[h],
                        pair.k,
                        pair.v,
                        req.mask_for_head(h),
                        &cfgs[h],
                        i0,
                        i1,
                        out_rows,
                        ws,
                    )
                })
            },
        );
        AttentionOutput {
            heads,
            stats,
            score_boundary: req.cfg.gemm().store.overflow_boundary() as f32,
        }
    }
}

/// PASA (Algorithm 1): fully-FP16 flash attention with pseudo-average
/// shifting. The request's β policy is resolved per head before fan-out;
/// the K' = M·K preprocessing is computed once per distinct (KV head, β)
/// pair — a uniform policy shares K' across the whole GQA query group
/// exactly as before — and padded requests preprocess only the valid KV
/// prefix so padding garbage never leaks into the pseudo-average.
pub struct PasaKernel;

impl AttentionKernel for PasaKernel {
    fn name(&self) -> &'static str {
        "pasa"
    }

    fn forward_kv(&self, req: &AttentionRequest, kv: &[KvPair<'_>]) -> AttentionOutput {
        req.validate_kv(kv).expect("invalid AttentionRequest");
        let n_kv = kv.len();
        let kv_head_for = |h: usize| crate::workloads::gqa_kv_head(h, req.n_heads(), n_kv);
        // Resolve the β policy up front (head-invariant policies solve
        // once); the inner cores keep seeing one scalar β each. K'
        // preprocessing depends on β, so sharing keys on (KV head, β): a
        // `Uniform` policy collapses back to one K' GEMM per KV head —
        // bit-identical to the pre-policy kernel — while per-head βs
        // within a GQA group each get their own M·K.
        let cfgs: Vec<AttentionConfig> = req.head_cfgs();
        let score_boundary = req.cfg.gemm().store.overflow_boundary() as f32;
        match &req.mask {
            AttnMask::Padded(_) => {
                // Per-head valid lengths: shift only the valid KV prefix.
                // Preprocessing is still shared — once per distinct
                // (KV head, valid length, β) triple, so a GQA group with a
                // broadcast length pays the K' GEMM once, not per head.
                // Paged views truncate for free (shorter page-table walk);
                // dense views are sliced once, as before. Fan-out stays at
                // head granularity here: each head runs against its own
                // truncated view.
                let padded_len = |h: usize| {
                    let kvh = kv_head_for(h);
                    match req.mask_for_head(h) {
                        HeadMask::Prefix(l) => l.min(kv[kvh].k.rows()),
                        _ => unreachable!("Padded mask resolves to Prefix"),
                    }
                };
                let mut pres: Vec<((usize, usize, u64), PasaPre)> = Vec::new();
                for h in 0..req.n_heads() {
                    let key = (kv_head_for(h), padded_len(h), cfgs[h].beta.to_bits());
                    if key.1 > 0 && !pres.iter().any(|(k, _)| *k == key) {
                        let kview = kv[key.0].k;
                        let pre = match kview.truncated(key.1) {
                            Some(tv) => pasa_preprocess_kv(tv, &cfgs[h]),
                            None => {
                                let kt = kview.block(0, key.1);
                                pasa_preprocess_kv(KvView::Dense(&kt), &cfgs[h])
                            }
                        };
                        pres.push((key, pre));
                    }
                }
                let (heads, stats) = fanout_heads(req.n_heads(), |h| {
                    let kvh = kv_head_for(h);
                    let len = padded_len(h);
                    if len == 0 {
                        // Empty visible set: softmax over nothing is
                        // defined as zero attention output, not NaN.
                        let out = Matrix::zeros(req.q[h].rows, kv[kvh].v.cols());
                        return (out, HeadStats::default());
                    }
                    let key = (kvh, len, cfgs[h].beta.to_bits());
                    let pre = &pres.iter().find(|(k, _)| *k == key).unwrap().1;
                    let vview = kv[kvh].v;
                    match vview.truncated(len) {
                        Some(tv) => pasa_head_kv(&req.q[h], tv, pre, HeadMask::None, &cfgs[h]),
                        None => {
                            let vt = vview.block(0, len);
                            pasa_head_kv(&req.q[h], KvView::Dense(&vt), pre, HeadMask::None, &cfgs[h])
                        }
                    }
                });
                AttentionOutput {
                    heads,
                    stats,
                    score_boundary,
                }
            }
            AttnMask::None | AttnMask::Causal => {
                // Shared preprocessing per (KV head, β) pair (GQA groups
                // with one β reuse K' exactly as before), then (head ×
                // Q-block) tiles over the pool.
                let mut pres: Vec<((usize, u64), PasaPre)> = Vec::new();
                for h in 0..req.n_heads() {
                    let key = (kv_head_for(h), cfgs[h].beta.to_bits());
                    if !pres.iter().any(|(k, _)| *k == key) {
                        let pre = pasa_preprocess_kv(kv[key.0].k, &cfgs[h]);
                        pres.push((key, pre));
                    }
                }
                let s1 = req.q[0].rows;
                let dv = kv[0].v.cols();
                let (heads, stats) = fanout_q_tiles(
                    req.n_heads(),
                    s1,
                    req.cfg.blocks.s1,
                    dv,
                    |h: usize, i0: usize, i1: usize, out_rows: &mut [f32]| {
                        let kvh = kv_head_for(h);
                        let key = (kvh, cfgs[h].beta.to_bits());
                        let pre = &pres.iter().find(|(k, _)| *k == key).unwrap().1;
                        with_workspace(|ws| {
                            pasa_q_block(
                                &req.q[h],
                                kv[kvh].v,
                                pre,
                                req.mask_for_head(h),
                                &cfgs[h],
                                i0,
                                i1,
                                out_rows,
                                ws,
                            )
                        })
                    },
                );
                AttentionOutput {
                    heads,
                    stats,
                    score_boundary,
                }
            }
        }
    }
}

static NAIVE: NaiveKernel = NaiveKernel;
static FLASH: FlashKernel = FlashKernel;
static PASA: PasaKernel = PasaKernel;

/// Allocation → kernel. The single construction-time dispatch point.
pub struct KernelRegistry;

impl KernelRegistry {
    /// Kernel implementing the given precision allocation. The FA
    /// allocations share [`FlashKernel`] (the allocation itself carries
    /// the format table); the shifted rows — `Pasa16` and `Pasa8`, the
    /// same pseudo-average-shift cores with E4M3 kernel constants for the
    /// latter — share [`PasaKernel`].
    pub fn get(alloc: Allocation) -> &'static dyn AttentionKernel {
        match alloc {
            Allocation::Pasa16 | Allocation::Pasa8 => &PASA,
            // Fp8 is the same flash code path with E4M3 constants from the
            // allocation table — a config row, not a new kernel.
            Allocation::Fa32 | Allocation::Fa16_32 | Allocation::Fa16 | Allocation::Fp8 => &FLASH,
        }
    }

    /// The full-precision golden reference (not an `Allocation` — it is
    /// the metric's denominator, not a candidate).
    pub fn naive() -> &'static dyn AttentionKernel {
        &NAIVE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::relative_rmse;
    use crate::workloads::{gen_case, Distribution, Pcg64};

    fn single(seed: u64) -> AttentionRequest {
        let mut rng = Pcg64::new(seed, 0);
        let c = gen_case(Distribution::Uniform { x0: 0.5, am: 1.0 }, 96, 96, 16, &mut rng);
        AttentionRequest::from_case(&c, Allocation::Fa32).with_fp16_inputs()
    }

    #[test]
    fn registry_covers_every_allocation() {
        assert_eq!(KernelRegistry::get(Allocation::Pasa16).name(), "pasa");
        assert_eq!(KernelRegistry::get(Allocation::Pasa8).name(), "pasa");
        for alloc in [
            Allocation::Fa32,
            Allocation::Fa16_32,
            Allocation::Fa16,
            Allocation::Fp8,
        ] {
            assert_eq!(KernelRegistry::get(alloc).name(), "flash");
        }
        // The dispatch predicate and the registry agree for every row.
        for alloc in Allocation::all_extended() {
            let expect = if alloc.is_shifted() { "pasa" } else { "flash" };
            assert_eq!(KernelRegistry::get(alloc).name(), expect, "{}", alloc.name());
        }
        assert_eq!(KernelRegistry::naive().name(), "naive-f32");
    }

    #[test]
    fn output_carries_the_active_score_boundary() {
        // The guard's pressure check reads the boundary off the output:
        // it must match the allocation's score-store format, not a
        // hardcoded 65504.
        let req = single(3);
        for (alloc, boundary) in [
            (Allocation::Fa16_32, 65504.0f32),
            (Allocation::Fa16, 65504.0),
            (Allocation::Pasa16, 65504.0),
            (Allocation::Fp8, 448.0),
            (Allocation::Pasa8, 448.0),
            (Allocation::Fa32, f32::MAX),
        ] {
            let out = req.clone().with_alloc(alloc).run();
            assert_eq!(out.score_boundary, boundary, "{}", alloc.name());
        }
        // The golden instruments against FP16 by convention.
        let golden = KernelRegistry::naive().forward(&req);
        assert_eq!(golden.score_boundary, 65504.0);
    }

    #[test]
    fn uniform_policy_bit_matches_per_head_policy() {
        // Acceptance: a PerHead table that repeats one β must be
        // bit-identical to the Uniform policy — the (KV head, β)-keyed
        // preprocessing collapses to the shared-K' path.
        use crate::attention::policy::BetaPolicy;
        let mut rng = Pcg64::new(9, 0);
        let dist = Distribution::Uniform { x0: 5.0, am: 1.0 };
        let mut req = AttentionRequest::new(Allocation::Pasa16);
        for _ in 0..4 {
            let c = gen_case(dist, 96, 96, 16, &mut rng);
            req = req.with_head(c.q, c.k, c.v);
        }
        let req = req.with_fp16_inputs().with_blocks(32, 32);
        let b = 0.968994;
        let uni = req.clone().with_beta(b).run();
        let per = req.clone().with_policy(BetaPolicy::PerHead(vec![b; 4])).run();
        let broadcast = req.with_policy(BetaPolicy::PerHead(vec![b])).run();
        for h in 0..4 {
            assert_eq!(uni.heads[h].data, per.heads[h].data, "head {h}");
            assert_eq!(uni.heads[h].data, broadcast.heads[h].data, "head {h} (broadcast)");
            assert_eq!(
                uni.stats[h].overflow_events,
                per.stats[h].overflow_events,
                "head {h} stats"
            );
        }
    }

    #[test]
    fn per_head_betas_match_independent_single_head_runs() {
        // Distinct βs inside one GQA group: each query head must equal a
        // standalone single-head run at its own β — the preprocessing
        // split by (KV head, β) cannot leak one head's K' into another's.
        use crate::attention::policy::BetaPolicy;
        let mut rng = Pcg64::new(11, 0);
        let c = gen_case(Distribution::Uniform { x0: 8.0, am: 1.0 }, 64, 64, 16, &mut rng);
        let betas = [0.9375, 0.968994, 0.984497, 0.9375];
        let mut req = AttentionRequest::new(Allocation::Pasa16)
            .with_kv_head(c.k.clone(), c.v.clone())
            .with_kv_head(c.k.clone(), c.v.clone());
        for _ in 0..4 {
            req = req.with_query_head(c.q.clone());
        }
        let req = req
            .with_fp16_inputs()
            .with_blocks(32, 32)
            .with_policy(BetaPolicy::PerHead(betas.to_vec()));
        let out = req.run();
        for h in 0..4 {
            let solo = AttentionRequest::from_case_cfg(&req.head_case(h), req.cfg)
                .with_beta(betas[h])
                .run();
            assert_eq!(out.heads[h].data, solo.heads[0].data, "head {h}");
        }
    }

    #[test]
    fn run_dispatches_on_request_allocation() {
        let req = single(1);
        let golden = KernelRegistry::naive().forward(&req);
        for alloc in Allocation::all() {
            let out = req.clone().with_alloc(alloc).run();
            assert_eq!(out.heads.len(), 1);
            assert_eq!(out.heads[0].shape(), golden.heads[0].shape());
            let e = relative_rmse(&out.heads[0].data, &golden.heads[0].data);
            assert!(e < 5e-2, "{}: rmse {e}", alloc.name());
        }
    }

    #[test]
    fn multihead_fanout_matches_per_head_runs() {
        // A 4-head MHA request must equal four independent single-head
        // runs, bit for bit (the pooled tile fan-out is pure).
        let mut rng = Pcg64::new(7, 0);
        let dist = Distribution::Uniform { x0: 2.0, am: 1.0 };
        let mut req = AttentionRequest::new(Allocation::Fa16_32);
        for _ in 0..4 {
            let c = gen_case(dist, 64, 64, 16, &mut rng);
            req = req.with_head(c.q, c.k, c.v);
        }
        let req = req.with_fp16_inputs().with_blocks(32, 32);
        let out = req.run();
        assert_eq!(out.heads.len(), 4);
        for h in 0..4 {
            let sub = AttentionRequest::from_case_cfg(&req.head_case(h), req.cfg);
            let solo = sub.run();
            assert_eq!(out.heads[h].data, solo.heads[0].data, "head {h}");
            assert_eq!(
                out.stats[h].overflow_events,
                solo.stats[0].overflow_events,
                "head {h} stats"
            );
        }
    }

    #[test]
    fn pooled_fanout_bit_matches_sequential_fanout() {
        // The tentpole's determinism contract at the kernel layer: pooled
        // (work-stealing) execution and the in-order sequential fallback
        // must agree bit for bit, outputs and telemetry, for a multi-head
        // masked request on every kernel.
        let mut rng = Pcg64::new(21, 0);
        let dist = Distribution::Uniform { x0: 6.0, am: 1.0 };
        let mut req = AttentionRequest::new(Allocation::Pasa16);
        for _ in 0..8 {
            let c = gen_case(dist, 96, 96, 16, &mut rng);
            req = req.with_head(c.q, c.k, c.v);
        }
        let req = req
            .with_fp16_inputs()
            .with_blocks(32, 32)
            .with_mask(AttnMask::Causal);
        let _mode = crate::pool::test_mode_guard();
        for alloc in [Allocation::Fa16_32, Allocation::Pasa16] {
            let r = req.clone().with_alloc(alloc);
            let pooled = r.run();
            crate::pool::set_parallel(false);
            let sequential = r.run();
            crate::pool::set_parallel(true);
            for h in 0..8 {
                assert_eq!(
                    pooled.heads[h].data, sequential.heads[h].data,
                    "{} head {h}",
                    alloc.name()
                );
                assert_eq!(
                    pooled.stats[h].overflow_events, sequential.stats[h].overflow_events,
                    "{} head {h} events",
                    alloc.name()
                );
                assert_eq!(
                    pooled.stats[h].max_abs_score, sequential.stats[h].max_abs_score,
                    "{} head {h} max",
                    alloc.name()
                );
            }
        }
    }

    #[test]
    fn tiny_multi_tile_fanout_is_miri_clean() {
        // Miri target (see .github/workflows/ci.yml): a deliberately tiny
        // request — 2 heads, s=8, d=4, Q-blocks of 4 — that still takes
        // the `SharedRows` raw-pointer path (4 tiles > 1), so the
        // `from_raw_parts_mut` aliasing argument is checked under the
        // interpreter in minutes, not hours. With `PASA_POOL_THREADS=0`
        // the tiles run inline on the caller, which is exactly the
        // configuration the Miri job pins.
        let mut rng = Pcg64::new(13, 0);
        let dist = Distribution::Uniform { x0: 1.0, am: 1.0 };
        let mut req = AttentionRequest::new(Allocation::Fa16_32);
        for _ in 0..2 {
            let c = gen_case(dist, 8, 8, 4, &mut rng);
            req = req.with_head(c.q, c.k, c.v);
        }
        let req = req.with_fp16_inputs().with_blocks(4, 8);
        let out = req.run();
        assert_eq!(out.heads.len(), 2);
        for h in 0..2 {
            let solo = AttentionRequest::from_case_cfg(&req.head_case(h), req.cfg).run();
            assert_eq!(out.heads[h].data, solo.heads[0].data, "head {h}");
        }
    }

    #[test]
    fn stats_flag_overflow_before_output_poisoning() {
        // Fig. 9(a) x0=30: FA16-32 overflows; the stats must report both
        // the pre-store magnitude and the poisoned output.
        let mut rng = Pcg64::new(4, 0);
        let c = gen_case(Distribution::Uniform { x0: 30.0, am: 0.5 }, 256, 256, 128, &mut rng);
        let req = AttentionRequest::from_case(&c, Allocation::Fa16_32).with_fp16_inputs();
        let out = req.run();
        assert!(out.overflowed());
        assert!(out.overflow_events() > 0);
        assert!(out.max_abs_score() > 65504.0);
        // PASA on the same request: clean stats end to end.
        let p = req.with_alloc(Allocation::Pasa16).run();
        assert!(!p.overflowed());
        assert_eq!(p.overflow_events(), 0);
        assert!(p.max_abs_score() < 65504.0);
    }
}
