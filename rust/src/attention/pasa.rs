//! PASA — Algorithm 1 (S4): fully-FP16 flash attention with online
//! pseudo-average shifting and global recovering, factored into a
//! preprocessing stage ([`pasa_preprocess`]) and a per-head inner kernel
//! ([`pasa_head`]) so GQA query groups can share each KV head's shifted
//! K' blocks.
//!
//! Pipeline per Q block i, sweeping KV blocks j:
//!
//! 1. (once per KV head) K'_j = M·K_j — batched GEMM folding the β-scaled
//!    pseudo-average subtraction *and* the 1/α static scaling (Eq. 10–12),
//! 2. S' = Q_i·K'_jᵀ — bias and amplitude collapsed ⇒ no FP16 overflow,
//! 3. local softmax stats (m'_j, P, l'_j) on S',
//! 4. global recovering: running pseudo-average F̄ʲ (Eq. 15) and the
//!    correction terms Δm'_{j−1}, Δm'_j re-express every block's stats in
//!    a common frame (Theorem 2.1 / Eq. 13–14),
//! 5. corrected online update of (m, l, O); final O = O/l.
//!
//! All vector ops run in FP16 (Algorithm 1's annotations); the correction
//! factor Inva = β/(1−β) is exact in FP16 for the optimized β values
//! (Appendix A), which is precisely why the optimal accuracy condition
//! exists.
//!
//! Masking (prefix rules: causal / padded): the dense S' block is still
//! computed in full — the pseudo-average S̄' that anchors the recovery
//! frame is defined over the whole n-column block — but masked positions
//! get zero softmax weight and are excluded from the local maximum and
//! from the overflow telemetry. The recovery identity is per-row exact
//! for *any* frame sequence, so skipping fully-invisible KV blocks (and
//! never updating F̄ for them) keeps the math exact. For padded requests
//! the [`super::kernel::PasaKernel`] truncates K/V to the valid prefix
//! before preprocessing instead, so padding garbage never contaminates
//! the shifted average.
//!
//! Deviation from the paper's line 4 (documented): we initialize
//! m₀ = −inf, not 0. With m₀ = 0 and l₀ = 0, the phantom term
//! m₀ + Δm'₀ = −Inva·F̄¹ can exceed the genuine block-1 maximum whenever
//! the data mean is strongly negative (the paper's own SVD case), driving
//! every exp to zero and the output to 0/0 = NaN. m₀ = −inf is the correct
//! identity for the max and reproduces the paper's intent; a regression
//! test pins this down.
//!
//! ## Hot-path layout
//!
//! The per-Q-block core is [`pasa_q_block`], running out of the
//! thread-local [`AttnWorkspace`] through the fused `tensor::ops` kernels
//! — zero heap allocations in the KV sweep once warm. Q blocks own their
//! full (m, l, F̄, O) recovery state independently, so the kernel layer
//! fans (head × Q-block) tiles onto the persistent worker pool with
//! bit-identical results to the sequential sweep.

use super::config::AttentionConfig;
use super::request::{HeadMask, HeadStats, KvView};
use super::shifting::{effective_invariant, preprocess_k, shifting_matrix};
use super::workspace::{copy_vec, reset_vec, with_workspace, AttnWorkspace};
use crate::numerics::Format;
use crate::tensor::{matmul_nn_into, matmul_nt_stats_into, ops, GemmStats, Matrix};
use crate::workloads::AttentionCase;

/// Shifted K' blocks of one KV head plus the recovery constants —
/// computed once per KV head and shared across its GQA query group.
pub struct PasaPre {
    /// K'_j = M·K_j per KV block (tail block gets its own, smaller M).
    pub kp_blocks: Vec<Matrix>,
    /// Effective correction factor c_j of each block's rounded M.
    pub block_inva: Vec<f32>,
    /// Correction factor of the main (full-width) block's M.
    pub inva_main: f32,
    /// Total KV rows covered.
    pub s2_total: usize,
    /// KV block width (the tiling's s2).
    pub bs2: usize,
}

/// Pre-processing (Algorithm 1 line 6): K'_j = M·K_j for every KV block;
/// the ragged tail gets its own, smaller M. Each block carries the
/// effective correction factor c_j of its rounded M (constants
/// precomputed at high precision, like the paper's FP64-solved β).
pub fn pasa_preprocess(k: &Matrix, cfg: &AttentionConfig) -> PasaPre {
    pasa_preprocess_kv(KvView::Dense(k), cfg)
}

/// View-based preprocessing core: K'_j = M·K_j per KV block, gathering
/// each block through the [`KvView`]. A paged operand is shifted
/// page-block-by-page-block — the `K' = M·K` GEMM works per page gather,
/// no dense K assembly. The gather itself reuses the thread workspace, so
/// preprocessing allocates only what it must keep: one K' matrix per
/// block.
pub fn pasa_preprocess_kv(k: KvView<'_>, cfg: &AttentionConfig) -> PasaPre {
    let s2_total = k.rows();
    let d = k.cols();
    let alpha = (d as f64).sqrt();
    let beta = cfg.beta;
    let bs2 = cfg.blocks.s2;
    // K' is a K-side operand, stored like the FP16 inputs: under the
    // Pasa8 row only the *score* store drops to E4M3 — an E4M3 K' would
    // re-poison the shift (see `AttentionConfig::kprep_gemm`). For the
    // FP16 allocations this is exactly `cfg.gemm()`.
    let gemm = cfg.kprep_gemm();

    let mut kp_blocks: Vec<Matrix> = Vec::new();
    let mut block_inva: Vec<f32> = Vec::new();
    let m_full = shifting_matrix(bs2, alpha, beta, Format::F16);
    let inva_main = effective_invariant(&m_full);
    with_workspace(|ws| {
        let mut j0 = 0;
        while j0 < s2_total {
            let j1 = (j0 + bs2).min(s2_total);
            k.block_into(j0, j1, &mut ws.kj);
            debug_assert_eq!(ws.kj.cols, d, "gathered K panel width != head_dim");
            if j1 - j0 == bs2 {
                kp_blocks.push(preprocess_k(&ws.kj, &m_full, gemm));
                block_inva.push(inva_main);
            } else {
                let m_tail = shifting_matrix(j1 - j0, alpha, beta, Format::F16);
                let c_tail = effective_invariant(&m_tail);
                kp_blocks.push(preprocess_k(&ws.kj, &m_tail, gemm));
                block_inva.push(c_tail);
            }
            j0 = j1;
        }
    });
    PasaPre {
        kp_blocks,
        block_inva,
        inva_main,
        s2_total,
        bs2,
    }
}

/// PASA forward pass for one head (Algorithm 1) — legacy single-head
/// entry over an unmasked case.
///
/// Correction-factor note (documented deviation; see DESIGN.md): the
/// paper's Inva = β/(1−β) is the recovery constant of the *ideal* M, and
/// its optimal-accuracy condition (Eq. 20) analyses M without the α
/// folding of Eq. 10. We instead read the **effective invariant off the
/// rounded M actually used** (`effective_invariant`), which zeroes the
/// aliasing error for any block width — including the ragged tail block,
/// whose different width would otherwise leave an O(1) error in the
/// exponent. For the ideal α-less M the two definitions coincide, and the
/// β solved from the paper's condition is still the default hyperparameter.
pub fn pasa_attention(case: &AttentionCase, cfg: &AttentionConfig) -> Matrix {
    let pre = pasa_preprocess(&case.k, cfg);
    pasa_head(&case.q, &case.v, &pre, HeadMask::None, cfg).0
}

/// Masked PASA inner kernel over preprocessed K' blocks, with telemetry.
/// This is what [`super::kernel::PasaKernel`] fans out per query head.
///
/// Note on padded prefixes: a `Prefix` mask is honored exactly, but the
/// block straddling the boundary was shifted with padding rows included in
/// its pseudo-average; prefer truncating K/V before [`pasa_preprocess`]
/// (what `PasaKernel` does) when the padding may hold garbage.
pub fn pasa_head(
    q: &Matrix,
    v: &Matrix,
    pre: &PasaPre,
    mask: HeadMask,
    cfg: &AttentionConfig,
) -> (Matrix, HeadStats) {
    pasa_head_kv(q, KvView::Dense(v), pre, mask, cfg)
}

/// View-based PASA core: V is gathered block-by-block through the
/// [`KvView`] alongside the preprocessed K' blocks, so the paged decode
/// path touches `O(len_tokens)` V rows per pass. Drives [`pasa_q_block`]
/// over the head's Q blocks sequentially.
pub fn pasa_head_kv(
    q: &Matrix,
    v: KvView<'_>,
    pre: &PasaPre,
    mask: HeadMask,
    cfg: &AttentionConfig,
) -> (Matrix, HeadStats) {
    let s1_total = q.rows;
    assert_eq!(cfg.blocks.s2, pre.bs2, "preprocessing used a different KV blocking");
    let mut out = Matrix::zeros(s1_total, v.cols());
    let oc = out.cols;
    let mut gstats = GemmStats::default();
    with_workspace(|ws| {
        let mut i0 = 0;
        while i0 < s1_total {
            let i1 = (i0 + cfg.blocks.s1).min(s1_total);
            let out_rows = &mut out.data[i0 * oc..i1 * oc];
            let gs = pasa_q_block(q, v, pre, mask, cfg, i0, i1, out_rows, ws);
            gstats.merge(&gs);
            i0 = i1;
        }
    });
    let stats = HeadStats::finish(gstats, &out);
    (out, stats)
}

// lint: hot-path — the PASA tile body; allocation-free given a warm
// workspace (pinned by rust/tests/alloc_discipline.rs).
/// One Q block of PASA's Algorithm 1: rows `[i0, i1)` of `q` against the
/// preprocessed K' sweep, writing the finished output rows into
/// `out_rows` and returning the block's pre-store telemetry. Owns its
/// complete online recovery state (m, l, F̄, O), so tiles are independent
/// — the worker-pool unit. Allocation-free given a warm workspace.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pasa_q_block(
    q: &Matrix,
    v: KvView<'_>,
    pre: &PasaPre,
    mask: HeadMask,
    cfg: &AttentionConfig,
    i0: usize,
    i1: usize,
    out_rows: &mut [f32],
    ws: &mut AttnWorkspace,
) -> GemmStats {
    let s1_total = q.rows;
    let s2_total = pre.s2_total;
    let bs = cfg.blocks;
    debug_assert_eq!(bs.s2, pre.bs2, "preprocessing used a different KV blocking");
    let vfmt = Format::F16; // Algorithm 1: every vector op is FP16
    let gemm = cfg.gemm();
    let boundary = gemm.store.overflow_boundary() as f32;
    let inva_main = pre.inva_main;
    let mut gstats = GemmStats::default();

    let rows = i1 - i0;
    let dv = v.cols();
    debug_assert_eq!(out_rows.len(), rows * dv);
    let qi = q.rows_ref(i0, i1);

    mask.visible_rows_into(i0, i1, s1_total, s2_total, &mut ws.vis);
    let max_vis = *ws.vis.last().unwrap();

    // Line 4 (amended): m₀ = −inf, l₀ = 0, F̄⁰ = 0, O = 0.
    reset_vec(&mut ws.m, rows, f32::NEG_INFINITY);
    reset_vec(&mut ws.l, rows, 0.0);
    reset_vec(&mut ws.fbar, rows, 0.0);
    ws.oi.reset(rows, dv);

    let mut j0 = 0;
    let mut jidx = 0usize;
    while j0 < s2_total {
        if j0 >= max_vis {
            // Every remaining KV block is invisible to this Q block.
            // F̄ is left untouched: the recovery frame only has to be
            // consistent across *processed* blocks.
            break;
        }
        let j1 = (j0 + bs.s2).min(s2_total);
        v.block_into(j0, j1, &mut ws.vj);
        debug_assert_eq!(ws.vj.cols, dv, "gathered V panel width != head_dim");
        let kp = &pre.kp_blocks[jidx];
        let width = j1 - j0;
        ws.bvis.clear();
        ws.bvis
            .extend(ws.vis.iter().map(|&t| t.saturating_sub(j0).min(width)));
        let fully_visible = ws.bvis.iter().all(|&b| b == width);

        // Line 11: S' = Q_i·K'_jᵀ — shifted+scaled scores, FP16 store.
        // Dense even under a mask (S̄' is defined over the full block);
        // telemetry covers the visible region only.
        let stat_vis = if fully_visible { None } else { Some(&ws.bvis[..]) };
        matmul_nt_stats_into(qi, kp, gemm, stat_vis, boundary, &mut gstats, &mut ws.s);

        // Line 12: local softmax stats over the visible prefix — the row
        // max, then P = exp(S' − m') fused with its FP32-reduce row mean
        // (one f16 rounding on store, matching the Pallas kernel and NPU
        // vector units); l'_j = mean · width.
        if fully_visible {
            ops::rowmax_into(&ws.s, &mut ws.row_m);
            ops::exp_sub_rowbias_rowmean32_into(&ws.s, &ws.row_m, vfmt, &mut ws.p, &mut ws.l_loc);
        } else {
            ops::rowmax_prefix_into(&ws.s, &ws.bvis, &mut ws.row_m);
            ops::exp_sub_rowbias_prefix_rowmean32_into(
                &ws.s, &ws.row_m, &ws.bvis, vfmt, &mut ws.p, &mut ws.l_loc,
            );
        }
        for r in 0..rows {
            ws.l_loc[r] = vfmt.round(ws.l_loc[r] * ws.p.cols as f32);
        }

        // Line 13: pseudo-average of the (dense) shifted block.
        ops::rowmean_acc32_into(&ws.s, vfmt, &mut ws.sbar);

        // Line 14 (Eq. 15): running global pseudo-average, computed in
        // the incremental form F̄ += (S̄' − F̄)/j — algebraically the
        // paper's ((j−1)F̄ + S̄')/j but immune to FP16 overflow of the
        // (j−1)·F̄ product at long sequence lengths.
        let jf = (jidx + 1) as f32;
        copy_vec(&mut ws.fbar_prev, &ws.fbar);
        for r in 0..rows {
            let delta = vfmt.round(ws.sbar[r] - ws.fbar[r]);
            ws.fbar[r] = vfmt.round(ws.fbar[r] + vfmt.round(delta / jf));
        }

        // Line 15: correction terms of the maximum,
        // Δm'_{j−1} = Inva·(F̄ʲ⁻¹ − F̄ʲ), Δm'_j = Inva·(S̄'ʲ − F̄ʲ).
        // A ragged tail block shifted with its own β_w gets the extra
        // (c_w − c_main)·S̄' term so its true offset is still recovered.
        let inva_j = pre.block_inva[jidx];
        let dinva = vfmt.round(inva_j - inva_main);
        ws.dm_prev.clear();
        ws.dm_prev.extend((0..rows).map(|r| {
            vfmt.round(inva_main * vfmt.round(ws.fbar_prev[r] - ws.fbar[r]))
        }));
        ws.dm_cur.clear();
        ws.dm_cur.extend((0..rows).map(|r| {
            let base = vfmt.round(inva_main * vfmt.round(ws.sbar[r] - ws.fbar[r]));
            if dinva == 0.0 {
                base
            } else {
                vfmt.round(base + vfmt.round(dinva * ws.sbar[r]))
            }
        }));

        // Line 16: m_j = max(m_{j−1} + Δm'_{j−1}, m'_j + Δm'_j).
        ws.m_new.clear();
        ws.m_new.extend((0..rows).map(|r| {
            let a = vfmt.round(ws.m[r] + ws.dm_prev[r]); // −inf + finite = −inf
            let b = vfmt.round(ws.row_m[r] + ws.dm_cur[r]);
            a.max(b)
        }));

        // Line 17: Δm_{j−1} = m_{j−1} − m_j + Δm'_{j−1},
        //          Δm_j     = m'_j   − m_j + Δm'_j   (both ≤ 0).
        ws.decay.clear();
        ws.decay.extend((0..rows).map(|r| {
            let dm = vfmt.round(vfmt.round(ws.m[r] - ws.m_new[r]) + ws.dm_prev[r]);
            vfmt.round(dm.exp())
        }));
        ws.scale_cur.clear();
        ws.scale_cur.extend((0..rows).map(|r| {
            let dm = vfmt.round(vfmt.round(ws.row_m[r] - ws.m_new[r]) + ws.dm_cur[r]);
            vfmt.round(dm.exp())
        }));

        // Line 18: l_j = exp(Δm_{j−1})·l_{j−1} + exp(Δm_j)·l'_j.
        for r in 0..rows {
            ws.l[r] = vfmt.round(
                vfmt.round(ws.decay[r] * ws.l[r]) + vfmt.round(ws.scale_cur[r] * ws.l_loc[r]),
            );
        }

        // Lines 19–20: O = exp(Δm_j)·(P·V_j) + exp(Δm_{j−1})·O.
        matmul_nn_into(ws.p.as_rows_ref(), &ws.vj, gemm, &mut ws.pv);
        ops::scale_rows_inplace(&mut ws.pv, &ws.scale_cur, vfmt);
        ops::scale_add_rows(&mut ws.oi, &ws.decay, &ws.pv, vfmt);

        std::mem::swap(&mut ws.m, &mut ws.m_new);
        j0 = j1;
        jidx += 1;
    }

    // Line 22: O_i = O_i / l, written straight into the head's output
    // rows. Fully-masked rows are zero by definition (their online state
    // never saw a score).
    ops::div_rows_masked_into(&ws.oi, &ws.l, &ws.vis, vfmt, out_rows);
    gstats
}
// lint: end-hot-path

/// β = 0 degrades PASA to plain FA2 (§2.2: "PASA completely degrades into
/// the FA2.0 algorithm when β is set to zero") — exposed for tests.
pub fn pasa_is_fa2_at_beta_zero() -> bool {
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::config::Allocation;
    use crate::attention::flash::{flash_attention, flash_head};
    use crate::attention::naive::{naive_attention_f32, naive_attention_masked_f32};
    use crate::numerics::{has_overflow, relative_rmse};
    use crate::workloads::{gen_case, Distribution, Pcg64};

    fn rounded_case(dist: Distribution, s: usize, d: usize, seed: u64) -> AttentionCase {
        let mut rng = Pcg64::new(seed, 0);
        let mut c = gen_case(dist, s, s, d, &mut rng);
        c.q.round_to(Format::F16);
        c.k.round_to(Format::F16);
        c.v.round_to(Format::F16);
        c
    }

    fn pasa_cfg() -> AttentionConfig {
        AttentionConfig::new(Allocation::Pasa16).with_blocks(64, 64)
    }

    #[test]
    fn matches_golden_on_benign_data() {
        let c = rounded_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 192, 32, 1);
        let golden = naive_attention_f32(&c);
        let o = pasa_attention(&c, &pasa_cfg());
        let e = relative_rmse(&o.data, &golden.data);
        assert!(e < 2e-2, "rmse {e}");
        assert!(!has_overflow(&o.data));
    }

    #[test]
    fn survives_large_mean_where_fa16_32_dies() {
        // Fig. 9(a) x0 = 30: FA(FP16-FP32) overflows, PASA must not.
        let c = rounded_case(Distribution::Uniform { x0: 30.0, am: 0.5 }, 256, 128, 2);
        let fa = flash_attention(&c, &AttentionConfig::new(Allocation::Fa16_32));
        assert!(has_overflow(&fa.data), "premise: FA16-32 overflows");
        let o = pasa_attention(&c, &pasa_cfg());
        assert!(!has_overflow(&o.data), "PASA must avoid overflow");
        let golden = naive_attention_f32(&c);
        let e = relative_rmse(&o.data, &golden.data);
        assert!(e < 5e-2, "rmse {e}");
    }

    #[test]
    fn survives_strongly_negative_mean() {
        // The SVD-like regime: every score deeply negative. This is the
        // case that motivates the m₀ = −inf amendment.
        let c = rounded_case(Distribution::Uniform { x0: -25.0, am: 0.5 }, 192, 128, 3);
        let o = pasa_attention(&c, &pasa_cfg());
        assert!(!has_overflow(&o.data), "NaN/inf in PASA output");
        let golden = naive_attention_f32(&c);
        let e = relative_rmse(&o.data, &golden.data);
        assert!(e < 5e-2, "rmse {e}");
    }

    #[test]
    fn pasa8_survives_the_e4m3_envelope_where_fp8_dies() {
        // The Pasa8 row's reason to exist: raw scores of a few hundred sit
        // comfortably inside FP16 but past E4M3's 448 boundary — the plain
        // FP8 store poisons, while the pseudo-average shift collapses the
        // bias *before* the E4M3 store and the same data survives with
        // zero pre-store overflow events.
        let c = rounded_case(Distribution::Uniform { x0: 2.0, am: 0.25 }, 128, 128, 21);
        let cfg8 = AttentionConfig::new(Allocation::Fp8).with_blocks(64, 64);
        let (fp8, fp8_stats) = flash_head(&c.q, &c.k, &c.v, HeadMask::None, &cfg8);
        assert!(
            has_overflow(&fp8.data),
            "premise: S ≈ 2²·128 = 512 > 448 must poison the E4M3 store"
        );
        assert!(fp8_stats.overflow_events > 0, "premise: E4M3 store trips");
        let cfgp = AttentionConfig::new(Allocation::Pasa8).with_blocks(64, 64);
        let pre = pasa_preprocess(&c.k, &cfgp);
        let (o, stats) = pasa_head(&c.q, &c.v, &pre, HeadMask::None, &cfgp);
        assert!(!has_overflow(&o.data), "Pasa8 must stay finite");
        assert_eq!(stats.overflow_events, 0, "Pasa8 pre-store events leaked");
        assert!(stats.max_abs_score < 448.0, "shifted S' must fit E4M3");
        let golden = naive_attention_f32(&c);
        let e = relative_rmse(&o.data, &golden.data);
        assert!(e < 0.3, "Pasa8 rmse {e} beyond the E4M3 envelope");
    }

    #[test]
    fn pasa8_preprocessing_keeps_k_prime_on_the_fp16_grid() {
        // The E4M3 score store must not leak into K': the shifted blocks
        // are FP16 (anything coarser would destroy the shift).
        use crate::numerics::Format;
        let c = rounded_case(Distribution::Uniform { x0: 2.0, am: 0.25 }, 96, 32, 22);
        let cfgp = AttentionConfig::new(Allocation::Pasa8).with_blocks(64, 64);
        let pre = pasa_preprocess(&c.k, &cfgp);
        for (j, kp) in pre.kp_blocks.iter().enumerate() {
            assert!(kp.is_on_grid(Format::F16), "block {j} not FP16");
            // ... and genuinely finer than the E4M3 grid somewhere (the
            // clamp is doing real work, not vacuously passing).
        }
        let off_e4m3 = pre.kp_blocks.iter().any(|kp| {
            kp.data
                .iter()
                .any(|&x| x.is_finite() && crate::numerics::round::round_f8e4m3(x) != x)
        });
        assert!(off_e4m3, "K' landed entirely on the E4M3 grid — clamp inert?");
    }

    #[test]
    fn beta_zero_degrades_to_fa2() {
        // §2.2: β = 0 makes M = I/α and all corrections vanish; PASA must
        // then agree with plain full-FP16 FA bit-for-bit-ish (same ops, S
        // scaled inside vs outside the GEMM differ by one rounding).
        let c = rounded_case(Distribution::Uniform { x0: 0.5, am: 1.0 }, 128, 16, 4);
        let p = pasa_attention(&c, &pasa_cfg().with_beta(0.0));
        let f = flash_attention(&c, &AttentionConfig::new(Allocation::Fa16).with_blocks(64, 64));
        let e = relative_rmse(&p.data, &f.data);
        assert!(e < 5e-3, "beta=0 PASA vs FA16 rmse {e}");
    }

    #[test]
    fn block_size_invariance() {
        let c = rounded_case(Distribution::Uniform { x0: 5.0, am: 2.0 }, 160, 32, 5);
        let golden = naive_attention_f32(&c);
        for &(s1, s2) in &[(32usize, 32usize), (64, 64), (160, 160), (64, 32)] {
            let o = pasa_attention(&c, &pasa_cfg().with_blocks(s1, s2));
            let e = relative_rmse(&o.data, &golden.data);
            assert!(e < 3e-2, "blocks ({s1},{s2}): rmse {e}");
        }
    }

    #[test]
    fn ragged_tail_blocks() {
        let c = rounded_case(Distribution::Uniform { x0: 1.0, am: 1.0 }, 100, 16, 6);
        let golden = naive_attention_f32(&c);
        let o = pasa_attention(&c, &pasa_cfg().with_blocks(64, 64));
        let e = relative_rmse(&o.data, &golden.data);
        assert!(e < 3e-2, "rmse {e}");
    }

    #[test]
    fn repeated_calls_reuse_the_workspace_bit_identically() {
        // Same contract as the flash twin: warm (dirty) workspace buffers
        // must reproduce the cold-call outputs bit for bit, masked and
        // unmasked, including the ragged tail path.
        let c = rounded_case(Distribution::Uniform { x0: 4.0, am: 1.0 }, 100, 16, 19);
        let pre = pasa_preprocess(&c.k, &pasa_cfg());
        for mask in [HeadMask::None, HeadMask::Causal, HeadMask::Prefix(70)] {
            let (first, st1) = pasa_head(&c.q, &c.v, &pre, mask, &pasa_cfg());
            let (second, st2) = pasa_head(&c.q, &c.v, &pre, mask, &pasa_cfg());
            assert_eq!(first.data, second.data, "{mask:?}");
            assert_eq!(st1.overflow_events, st2.overflow_events);
            assert_eq!(st1.max_abs_score, st2.max_abs_score);
        }
    }

    #[test]
    fn more_accurate_than_fa16_32_on_biased_data() {
        // The paper's accuracy claim (Fig. 9a): for non-zero mean, PASA's
        // RMSE beats partially-low-precision FA (at the paper's default
        // 128-blocks; averaged over heads to wash out seed luck).
        let mut tot_fa = 0.0;
        let mut tot_p = 0.0;
        for seed in 0..4u64 {
            let c = rounded_case(Distribution::Uniform { x0: 20.0, am: 2.0 }, 256, 128, seed);
            let golden = naive_attention_f32(&c);
            let fa = flash_attention(&c, &AttentionConfig::new(Allocation::Fa16_32));
            let p = pasa_attention(&c, &AttentionConfig::new(Allocation::Pasa16));
            tot_fa += relative_rmse(&fa.data, &golden.data);
            tot_p += relative_rmse(&p.data, &golden.data);
        }
        assert!(
            tot_p < tot_fa,
            "PASA mean rmse {} should beat FA16-32 mean rmse {}",
            tot_p / 4.0,
            tot_fa / 4.0
        );
    }

    #[test]
    fn causal_mask_matches_masked_naive() {
        // Masked PASA against the masked golden reference, on biased data
        // where unshifted FP16 would be in trouble at larger means.
        let c = rounded_case(Distribution::Uniform { x0: 5.0, am: 1.0 }, 160, 32, 11);
        let golden = naive_attention_masked_f32(&c, HeadMask::Causal);
        let pre = pasa_preprocess(&c.k, &pasa_cfg());
        let (o, stats) = pasa_head(&c.q, &c.v, &pre, HeadMask::Causal, &pasa_cfg());
        assert!(!has_overflow(&o.data));
        assert_eq!(stats.nonfinite_outputs, 0);
        let e = relative_rmse(&o.data, &golden.data);
        assert!(e < 3e-2, "rmse {e}");
    }

    #[test]
    fn causal_masked_pasa_survives_overflow_regime() {
        // The causal variant must keep the paper's robustness claim: at
        // x0=30 the masked FA16-32 run still poisons the visible region,
        // masked PASA stays finite and accurate.
        let c = rounded_case(Distribution::Uniform { x0: 30.0, am: 0.5 }, 256, 128, 12);
        let cfg_fa = AttentionConfig::new(Allocation::Fa16_32);
        let (fa, fa_stats) = flash_head(&c.q, &c.k, &c.v, HeadMask::Causal, &cfg_fa);
        assert!(has_overflow(&fa.data), "premise: causal FA16-32 overflows");
        assert!(fa_stats.overflow_events > 0);
        let pre = pasa_preprocess(&c.k, &pasa_cfg());
        let (o, stats) = pasa_head(&c.q, &c.v, &pre, HeadMask::Causal, &pasa_cfg());
        assert!(!has_overflow(&o.data));
        assert_eq!(stats.overflow_events, 0);
        let golden = naive_attention_masked_f32(&c, HeadMask::Causal);
        let e = relative_rmse(&o.data, &golden.data);
        assert!(e < 5e-2, "rmse {e}");
    }

    #[test]
    fn masked_and_unmasked_agree_on_the_last_row() {
        // Causal's final query row sees everything: it must match the
        // unmasked kernel's final row bit-for-bit (same blocks, same ops).
        let c = rounded_case(Distribution::Uniform { x0: 2.0, am: 1.0 }, 128, 16, 13);
        let pre = pasa_preprocess(&c.k, &pasa_cfg());
        let (dense, _) = pasa_head(&c.q, &c.v, &pre, HeadMask::None, &pasa_cfg());
        let (masked, _) = pasa_head(&c.q, &c.v, &pre, HeadMask::Causal, &pasa_cfg());
        let last = 127;
        assert_eq!(dense.row(last), masked.row(last));
    }
}
