//! Attention-lab configuration: block sizes and the precision allocations
//! of the paper's Figs. 1–3 plus PASA.

use crate::numerics::Format;
use crate::tensor::GemmPrecision;

/// Block sizes of the FA/PASA tiling (the paper's s1 × s2, typically 128).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSizes {
    pub s1: usize,
    pub s2: usize,
}

impl Default for BlockSizes {
    fn default() -> Self {
        BlockSizes { s1: 128, s2: 128 }
    }
}

/// The precision allocation strategies evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Allocation {
    /// Fig. 1 — "original FA (FP32)": FP16 inputs on the matrix engine,
    /// FP32 accumulate, FP32 S, FP32 softmax/update. Never overflows.
    Fa32,
    /// Fig. 2 — "partially low-precision FA (FP16-FP32)": S leaves the
    /// matrix engine in FP16 (the overflow site), softmax/update in FP32.
    Fa16_32,
    /// Fig. 3 — "fully low-precision FA": everything FP16.
    Fa16,
    /// PASA (Algorithm 1): fully FP16 with pseudo-average shifting and
    /// global recovering.
    Pasa16,
    /// FP8 (E4M3) score storage: FP16 inputs on the matrix engine, FP32
    /// accumulate, S stored in E4M3 — the overflow site moves from 65504
    /// down to 448 (Table 1's FP8 row). Softmax/update stay FP16, the
    /// H-FA-style split (low-precision scores, half-precision reductions).
    /// Not one of the paper's evaluated allocations (`all()` keeps the
    /// Figs. 1–3 + PASA set); dispatched through the same
    /// [`super::kernel::FlashKernel`] — a pure config-table row, no new
    /// code path.
    Fp8,
    /// PASA shifted into the E4M3 envelope: the pseudo-average shift
    /// collapses the score bias and amplitude *before* the store, so the
    /// S' that reaches the E4M3 grid fits inside the 448 boundary that
    /// poisons plain [`Allocation::Fp8`]. FP32 accumulate, E4M3 S-store
    /// (overflow site 448), FP16 vector ops — dispatched through
    /// [`super::kernel::PasaKernel`] with the shifting matrix and the
    /// `K' = M·K` preprocessing kept in FP16 (see
    /// [`AttentionConfig::kprep_gemm`]): only the shifted score store
    /// drops to 8 bits.
    Pasa8,
}

impl Allocation {
    /// Parse the wire spelling of an allocation — the strings
    /// `Guard::allocation()` emits and the artifact manifest uses. A
    /// guard-side test pins every guard spelling to a lab allocation so
    /// the two vocabularies cannot drift apart.
    pub fn parse(s: &str) -> Option<Allocation> {
        match s {
            "fa32" => Some(Allocation::Fa32),
            "fa16_32" => Some(Allocation::Fa16_32),
            "fa16" => Some(Allocation::Fa16),
            "pasa" | "pasa16" => Some(Allocation::Pasa16),
            "fp8" => Some(Allocation::Fp8),
            "pasa8" => Some(Allocation::Pasa8),
            _ => None,
        }
    }

    /// Every spelling [`Allocation::parse`] accepts — what a CLI error
    /// message should list instead of silently falling back.
    pub fn valid_names() -> &'static [&'static str] {
        &["fa32", "fa16_32", "fa16", "pasa", "pasa16", "fp8", "pasa8"]
    }

    pub fn name(self) -> &'static str {
        match self {
            Allocation::Fa32 => "FA(FP32)",
            Allocation::Fa16_32 => "FA(FP16-FP32)",
            Allocation::Fa16 => "FA(FP16)",
            Allocation::Pasa16 => "PASA(FP16)",
            Allocation::Fp8 => "FA(FP8-E4M3)",
            Allocation::Pasa8 => "PASA(FP8-E4M3)",
        }
    }

    /// GEMM precision for the two matmuls (QKᵀ and PV).
    pub fn gemm(self) -> GemmPrecision {
        match self {
            Allocation::Fa32 => GemmPrecision {
                acc: Format::F32,
                store: Format::F32,
            },
            // The matrix engine accumulates FP16 inputs in FP32 (CUBE / TC
            // behaviour) and stores low-precision; the FP16 *store* of S is
            // the paper's overflow site.
            Allocation::Fa16_32 | Allocation::Fa16 | Allocation::Pasa16 => {
                GemmPrecision::ACC32_STORE16
            }
            // 8-bit rows: the E4M3 *store* of S is the overflow site (448).
            Allocation::Fp8 | Allocation::Pasa8 => GemmPrecision {
                acc: Format::F32,
                store: Format::F8E4M3,
            },
        }
    }

    /// Format of the softmax / online-update vector ops.
    pub fn vector_fmt(self) -> Format {
        match self {
            Allocation::Fa32 | Allocation::Fa16_32 => Format::F32,
            Allocation::Fa16 | Allocation::Pasa16 | Allocation::Fp8 | Allocation::Pasa8 => {
                Format::F16
            }
        }
    }

    /// Format S is stored in between GEMM and softmax. Exhaustive on
    /// purpose (no `_` arm): a new allocation must declare its overflow
    /// site here, not inherit FP16 silently.
    pub fn score_fmt(self) -> Format {
        match self {
            Allocation::Fa32 => Format::F32,
            Allocation::Fp8 | Allocation::Pasa8 => Format::F8E4M3,
            Allocation::Fa16_32 | Allocation::Fa16 | Allocation::Pasa16 => Format::F16,
        }
    }

    /// True for the PASA rows (pseudo-average shift applied before the
    /// score store) — the kernel-registry dispatch predicate.
    pub fn is_shifted(self) -> bool {
        match self {
            Allocation::Pasa16 | Allocation::Pasa8 => true,
            Allocation::Fa32 | Allocation::Fa16_32 | Allocation::Fa16 | Allocation::Fp8 => false,
        }
    }

    /// The paper's evaluated allocations (Figs. 1–3 + PASA) — the set the
    /// evaluation sweeps and goldens iterate; FP16-scale RMSE envelopes
    /// apply to each member.
    pub fn all() -> [Allocation; 4] {
        [
            Allocation::Fa32,
            Allocation::Fa16_32,
            Allocation::Fa16,
            Allocation::Pasa16,
        ]
    }

    /// Every registry row, including the two E4M3 extensions (plain FP8
    /// scores and the Pasa8 shifted-into-E4M3 row) whose error envelopes
    /// are an order coarser than the paper set's. Widened from five to
    /// six entries when `Pasa8` landed — iterating this array is how the
    /// goldens, checksum pins and fuzz harness stay exhaustive over the
    /// registry.
    pub fn all_extended() -> [Allocation; 6] {
        [
            Allocation::Fa32,
            Allocation::Fa16_32,
            Allocation::Fa16,
            Allocation::Pasa16,
            Allocation::Fp8,
            Allocation::Pasa8,
        ]
    }
}

/// Full configuration for one attention run.
#[derive(Clone, Copy, Debug)]
pub struct AttentionConfig {
    pub alloc: Allocation,
    pub blocks: BlockSizes,
    /// PASA's β (ignored by the FA allocations). Default: the paper's
    /// optimized 0.984497 (solved from the optimal accuracy condition).
    pub beta: f64,
    /// Emulate FP16 accumulation *inside* the matrix engine too (the
    /// strictest reading of Fig. 3). Slow — per-step rounding; used by
    /// tests, off by default (CUBE/TC accumulate FP32 internally).
    pub strict_fp16_accum: bool,
}

impl AttentionConfig {
    pub fn new(alloc: Allocation) -> AttentionConfig {
        AttentionConfig {
            alloc,
            blocks: BlockSizes::default(),
            beta: crate::attention::beta::PAPER_BETA,
            strict_fp16_accum: false,
        }
    }

    pub fn with_blocks(mut self, s1: usize, s2: usize) -> Self {
        self.blocks = BlockSizes { s1, s2 };
        self
    }

    pub fn with_beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }

    pub fn gemm(&self) -> GemmPrecision {
        let mut g = self.alloc.gemm();
        if self.strict_fp16_accum && self.alloc != Allocation::Fa32 {
            g.acc = Format::F16;
        }
        g
    }

    /// GEMM precision of PASA's `K' = M·K` preprocessing. The score-store
    /// format never applies to this GEMM: K' is a K-side *operand* of the
    /// score GEMM, stored like the FP16 inputs — an E4M3 K' would destroy
    /// the very shift the Pasa8 row exists for, so an E4M3 score store
    /// clamps back to FP16 here. For every FP16-score allocation this is
    /// exactly [`Self::gemm`], which keeps `Pasa16` bit-identical to the
    /// pre-Pasa8 kernels.
    pub fn kprep_gemm(&self) -> GemmPrecision {
        let mut g = self.gemm();
        if g.store == Format::F8E4M3 {
            g.store = Format::F16;
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_table() {
        assert_eq!(Allocation::Fa32.score_fmt(), Format::F32);
        assert_eq!(Allocation::Fa16_32.score_fmt(), Format::F16);
        assert_eq!(Allocation::Fa16_32.vector_fmt(), Format::F32);
        assert_eq!(Allocation::Fa16.vector_fmt(), Format::F16);
        assert_eq!(Allocation::Pasa16.vector_fmt(), Format::F16);
        // FP8 row: E4M3 score store (overflow at 448), FP32 accumulate,
        // FP16 vector ops.
        assert_eq!(Allocation::Fp8.score_fmt(), Format::F8E4M3);
        assert_eq!(Allocation::Fp8.gemm().store, Format::F8E4M3);
        assert_eq!(Allocation::Fp8.gemm().acc, Format::F32);
        assert_eq!(Allocation::Fp8.vector_fmt(), Format::F16);
        assert_eq!(Allocation::Fp8.gemm().store.overflow_boundary(), 448.0);
        // Pasa8 row: same E4M3 S-store / FP32 acc / FP16 vector table as
        // the Fp8 row — the difference is the kernel (shift before store).
        assert_eq!(Allocation::Pasa8.score_fmt(), Format::F8E4M3);
        assert_eq!(Allocation::Pasa8.gemm().store, Format::F8E4M3);
        assert_eq!(Allocation::Pasa8.gemm().acc, Format::F32);
        assert_eq!(Allocation::Pasa8.vector_fmt(), Format::F16);
        assert_eq!(Allocation::Pasa8.gemm().store.overflow_boundary(), 448.0);
        assert!(Allocation::Pasa8.is_shifted());
        assert!(Allocation::Pasa16.is_shifted());
        assert!(!Allocation::Fp8.is_shifted());
    }

    #[test]
    fn parse_round_trips_guard_spellings() {
        assert_eq!(Allocation::parse("pasa"), Some(Allocation::Pasa16));
        assert_eq!(Allocation::parse("fa16_32"), Some(Allocation::Fa16_32));
        assert_eq!(Allocation::parse("fa32"), Some(Allocation::Fa32));
        assert_eq!(Allocation::parse("fa16"), Some(Allocation::Fa16));
        assert_eq!(Allocation::parse("fp8"), Some(Allocation::Fp8));
        assert_eq!(Allocation::parse("pasa8"), Some(Allocation::Pasa8));
        assert_eq!(Allocation::parse("bf16"), None);
        // Every advertised spelling parses, and every registry row has a
        // spelling that round-trips back to it.
        for name in Allocation::valid_names() {
            assert!(Allocation::parse(name).is_some(), "{name} must parse");
        }
        for alloc in Allocation::all_extended() {
            assert!(
                Allocation::valid_names()
                    .iter()
                    .any(|n| Allocation::parse(n) == Some(alloc)),
                "{} has no wire spelling",
                alloc.name()
            );
        }
    }

    #[test]
    fn extended_set_is_paper_set_plus_the_8bit_rows() {
        let all = Allocation::all();
        let ext = Allocation::all_extended();
        assert_eq!(ext.len(), 6);
        assert_eq!(&ext[..4], &all[..]);
        assert_eq!(ext[4], Allocation::Fp8);
        assert_eq!(ext[5], Allocation::Pasa8);
    }

    #[test]
    fn kprep_keeps_the_shift_in_fp16() {
        // Pasa8's K' = M·K preprocessing stores FP16 even though the score
        // store is E4M3; Pasa16's preprocessing precision is untouched.
        let c8 = AttentionConfig::new(Allocation::Pasa8);
        assert_eq!(c8.gemm().store, Format::F8E4M3);
        assert_eq!(c8.kprep_gemm().store, Format::F16);
        assert_eq!(c8.kprep_gemm().acc, Format::F32);
        let c16 = AttentionConfig::new(Allocation::Pasa16);
        assert_eq!(c16.kprep_gemm(), c16.gemm());
        // The strict-accumulate flag carries through.
        let mut strict = AttentionConfig::new(Allocation::Pasa8);
        strict.strict_fp16_accum = true;
        assert_eq!(strict.kprep_gemm().acc, Format::F16);
        assert_eq!(strict.kprep_gemm().store, Format::F16);
    }

    #[test]
    fn strict_accum_flag() {
        let mut c = AttentionConfig::new(Allocation::Fa16);
        assert_eq!(c.gemm().acc, Format::F32);
        c.strict_fp16_accum = true;
        assert_eq!(c.gemm().acc, Format::F16);
        // Fa32 is unaffected by the strict flag.
        let mut c = AttentionConfig::new(Allocation::Fa32);
        c.strict_fp16_accum = true;
        assert_eq!(c.gemm().acc, Format::F32);
    }
}
