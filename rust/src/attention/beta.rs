//! Optimal accuracy condition for β (S5) — paper §2.3, Appendices A–C.
//!
//! The shifting matrix M = (I − β·J/n)/α is *rounded* to FP16 before use,
//! so the effective β differs from the nominal one; but the correction
//! steps of Algorithm 1 use the exact invariant Inva = β/(1−β). The paper
//! closes this gap by solving the fixed-point equation (Eq. 16/20/22)
//!
//! ```text
//! β/(1−β) = f(β),   f(β) = b·n/(a·(a−b·n)) + (1−a)/a,
//! b = fl_tp(β/n),   a = fl_tp(1 − β/n) + b,
//! ```
//!
//! in FP64, where fl_tp is the FP16 (or BF16) rounding. The optimized β
//! makes the ideal and rounded invariants agree exactly (Table 3).

use crate::numerics::Format;

/// The paper's adopted β (solved from initial 1 − 2⁻⁶ at n = 128, FP16).
pub const PAPER_BETA: f64 = 0.984497;

/// β candidates the paper derives from initial values 1−2⁻⁴, 1−2⁻⁵, 1−2⁻⁶.
pub const PAPER_BETAS: [f64; 3] = [0.9375, 0.968994, 0.984497];

/// The rounded-matrix parameters (a, b) of Eq. (21).
pub fn rounded_params(beta: f64, n: usize, tp: Format) -> (f64, f64) {
    let b = tp.fl(beta / n as f64);
    let a = tp.fl(1.0 - beta / n as f64) + b;
    (a, b)
}

/// The practical (rounded) invariant Inva₁ = b·n/(a(a−b·n)) + (1−a)/a.
pub fn practical_invariant(beta: f64, n: usize, tp: Format) -> f64 {
    let (a, b) = rounded_params(beta, n, tp);
    let bn = b * n as f64;
    bn / (a * (a - bn)) + (1.0 - a) / a
}

/// The ideal invariant Inva = β/(1−β).
pub fn ideal_invariant(beta: f64) -> f64 {
    beta / (1.0 - beta)
}

/// Solve the optimal accuracy condition by fixed-point iteration
/// (Eq. 22): β_{k+1} = f(β_k) / (1 + f(β_k)). Mirrors the paper's
/// `optimal_para.py` (Appendix C) including its FP64 carrier precision.
pub fn solve_optimal_beta(beta0: f64, n: usize, tp: Format, tol: f64, max_iter: usize) -> f64 {
    let mut beta0 = beta0;
    let mut beta = beta0;
    for _ in 0..max_iter {
        let f = practical_invariant(beta0, n, tp);
        beta = f / (1.0 + f);
        let err = (beta - beta0).abs() / beta0.abs();
        beta0 = beta;
        if err <= tol {
            break;
        }
    }
    beta
}

/// One row of the paper's Table 3.
#[derive(Clone, Debug)]
pub struct InvarianceRow {
    pub initial_beta: f64,
    pub inva_initial: f64,
    pub inva1_initial: f64,
    pub rel_err_initial: f64,
    pub optimized_beta: f64,
    pub inva_optimized: f64,
    pub inva1_optimized: f64,
    pub rel_err_optimized: f64,
}

/// Regenerate Table 3 for a given block size n (the paper uses n = 128)
/// and storage format tp (FP16 in the paper).
pub fn table3(n: usize, tp: Format) -> Vec<InvarianceRow> {
    let initials = [
        0.9,
        1.0 - 2f64.powi(-4),
        1.0 - 2f64.powi(-5),
        1.0 - 2f64.powi(-6),
        0.99,
        0.999,
    ];
    initials
        .iter()
        .map(|&b0| {
            let inva = ideal_invariant(b0);
            let inva1 = practical_invariant(b0, n, tp);
            let opt = solve_optimal_beta(b0, n, tp, 1e-8, 200);
            // After optimization the *ideal* invariant of the optimized β
            // is compared against the rounded one (the paper's Table 3
            // reports them equal).
            let inva_opt = ideal_invariant(opt);
            let inva1_opt = practical_invariant(opt, n, tp);
            InvarianceRow {
                initial_beta: b0,
                inva_initial: inva,
                inva1_initial: inva1,
                rel_err_initial: ((inva - inva1) / inva).abs(),
                optimized_beta: opt,
                inva_optimized: inva_opt,
                inva1_optimized: inva1_opt,
                rel_err_optimized: ((inva_opt - inva1_opt) / inva_opt).abs(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_solutions_from_pow2_initials() {
        // Paper §2.3: initials 1−2⁻⁴, 1−2⁻⁵, 1−2⁻⁶ solve to
        // 0.937500, 0.968994, 0.984497 (n = 128, FP16).
        let expect = [0.937500, 0.968994, 0.984497];
        for (i, &p) in [4, 5, 6].iter().enumerate() {
            let b0 = 1.0 - 2f64.powi(-p);
            let b = solve_optimal_beta(b0, 128, Format::F16, 1e-8, 200);
            assert!(
                (b - expect[i]).abs() < 5e-6,
                "initial {b0}: got {b}, want {}",
                expect[i]
            );
        }
    }

    #[test]
    fn optimized_beta_has_zero_invariance_error() {
        // Table 3's punchline: after optimization Inva == Inva1 exactly
        // (to FP64 resolution).
        for &b0 in &[0.9, 0.99, 0.999, 1.0 - 2f64.powi(-5)] {
            let opt = solve_optimal_beta(b0, 128, Format::F16, 1e-10, 500);
            let i = ideal_invariant(opt);
            let i1 = practical_invariant(opt, 128, Format::F16);
            assert!(
                ((i - i1) / i).abs() < 1e-9,
                "b0={b0}: inva {i} vs inva1 {i1}"
            );
        }
    }

    #[test]
    fn table3_initial_rel_errors_match_paper() {
        // Paper Table 3 initial-β relative errors:
        // 0.9 -> 0.32%, 1−2⁻⁴ -> 0%, 1−2⁻⁵ -> 0.81%, 1−2⁻⁶ -> 0.79%,
        // 0.99 -> 3.23%, 0.999 -> 3.20%.
        let t = table3(128, Format::F16);
        let expect = [0.0032, 0.0, 0.0081, 0.0079, 0.0323, 0.0320];
        for (row, &e) in t.iter().zip(&expect) {
            assert!(
                (row.rel_err_initial - e).abs() < 6e-4,
                "beta0={}: rel err {} vs paper {}",
                row.initial_beta,
                row.rel_err_initial,
                e
            );
            assert!(row.rel_err_optimized < 1e-9);
        }
    }

    #[test]
    fn beta_0p9375_is_exact_in_fp16() {
        // Appendix A: β = 0.9375 has an *integer* invariant (15) and is
        // exactly representable — no rounding error at all.
        let inva = ideal_invariant(0.9375);
        assert_eq!(inva, 15.0);
        let inva1 = practical_invariant(0.9375, 128, Format::F16);
        assert!((inva1 - 15.0).abs() < 1e-12);
    }

    #[test]
    fn bf16_branch_also_solves() {
        let b = solve_optimal_beta(1.0 - 2f64.powi(-6), 128, Format::Bf16, 1e-8, 200);
        assert!(b > 0.9 && b < 1.0);
        let i = ideal_invariant(b);
        let i1 = practical_invariant(b, 128, Format::Bf16);
        assert!(((i - i1) / i).abs() < 1e-9);
    }
}
