//! Optimal accuracy condition for β (S5) — paper §2.3, Appendices A–C.
//!
//! The shifting matrix M = (I − β·J/n)/α is *rounded* to FP16 before use,
//! so the effective β differs from the nominal one; but the correction
//! steps of Algorithm 1 use the exact invariant Inva = β/(1−β). The paper
//! closes this gap by solving the fixed-point equation (Eq. 16/20/22)
//!
//! ```text
//! β/(1−β) = f(β),   f(β) = b·n/(a·(a−b·n)) + (1−a)/a,
//! b = fl_tp(β/n),   a = fl_tp(1 − β/n) + b,
//! ```
//!
//! in FP64, where fl_tp is the FP16 (or BF16) rounding. The optimized β
//! makes the ideal and rounded invariants agree exactly (Table 3).

use crate::numerics::Format;

/// The paper's adopted β (solved from initial 1 − 2⁻⁶ at n = 128, FP16).
pub const PAPER_BETA: f64 = 0.984497;

/// β candidates the paper derives from initial values 1−2⁻⁴, 1−2⁻⁵, 1−2⁻⁶.
pub const PAPER_BETAS: [f64; 3] = [0.9375, 0.968994, 0.984497];

/// The rounded-matrix parameters (a, b) of Eq. (21).
pub fn rounded_params(beta: f64, n: usize, tp: Format) -> (f64, f64) {
    let b = tp.fl(beta / n as f64);
    let a = tp.fl(1.0 - beta / n as f64) + b;
    (a, b)
}

/// The practical (rounded) invariant Inva₁ = b·n/(a(a−b·n)) + (1−a)/a.
pub fn practical_invariant(beta: f64, n: usize, tp: Format) -> f64 {
    let (a, b) = rounded_params(beta, n, tp);
    let bn = b * n as f64;
    bn / (a * (a - bn)) + (1.0 - a) / a
}

/// The ideal invariant Inva = β/(1−β).
pub fn ideal_invariant(beta: f64) -> f64 {
    beta / (1.0 - beta)
}

/// Outcome of one fixed-point solve of the optimal accuracy condition:
/// the iterate plus its convergence evidence, so callers can tell a
/// converged β from "the loop ran out of iterations" instead of silently
/// trusting the last iterate.
#[derive(Clone, Copy, Debug)]
pub struct BetaSolve {
    /// The final iterate β (the optimized β when `converged`).
    pub beta: f64,
    /// Fixed-point iterations actually performed.
    pub iterations: usize,
    /// Final relative step |β_{k+1} − β_k| / |β_k| (∞ when `max_iter` is 0
    /// and no step was taken).
    pub residual: f64,
    /// True iff `residual ≤ tol` was reached within `max_iter`.
    pub converged: bool,
}

/// Solve the optimal accuracy condition by fixed-point iteration
/// (Eq. 22): β_{k+1} = f(β_k) / (1 + f(β_k)). Mirrors the paper's
/// `optimal_para.py` (Appendix C) including its FP64 carrier precision.
/// Returns the iterate together with its convergence status; hitting
/// `max_iter` is reported (`converged == false`), never silent.
pub fn solve_optimal_beta(beta0: f64, n: usize, tp: Format, tol: f64, max_iter: usize) -> BetaSolve {
    let mut beta = beta0;
    let mut residual = f64::INFINITY;
    for it in 1..=max_iter {
        let f = practical_invariant(beta, n, tp);
        let next = f / (1.0 + f);
        // β₀ so close to 1 that the rounded (a, b) make a − b·n vanish
        // sends f through a pole; keep the last finite iterate and report
        // the failure instead of iterating on NaN.
        if !next.is_finite() {
            return BetaSolve {
                beta,
                iterations: it - 1,
                residual,
                converged: false,
            };
        }
        // Guarded denominator: β₀ = 0 is a legal input (PASA degrades to
        // FA2) and must converge to 0, not divide 0/0 into NaN.
        residual = (next - beta).abs() / beta.abs().max(f64::MIN_POSITIVE);
        beta = next;
        if residual <= tol {
            return BetaSolve {
                beta,
                iterations: it,
                residual,
                converged: true,
            };
        }
    }
    BetaSolve {
        beta,
        iterations: max_iter,
        residual,
        converged: false,
    }
}

/// One row of the paper's Table 3.
#[derive(Clone, Debug)]
pub struct InvarianceRow {
    pub initial_beta: f64,
    pub inva_initial: f64,
    pub inva1_initial: f64,
    pub rel_err_initial: f64,
    pub optimized_beta: f64,
    pub inva_optimized: f64,
    pub inva1_optimized: f64,
    pub rel_err_optimized: f64,
}

/// Regenerate Table 3 for a given block size n (the paper uses n = 128)
/// and storage format tp (FP16 in the paper).
pub fn table3(n: usize, tp: Format) -> Vec<InvarianceRow> {
    let initials = [
        0.9,
        1.0 - 2f64.powi(-4),
        1.0 - 2f64.powi(-5),
        1.0 - 2f64.powi(-6),
        0.99,
        0.999,
    ];
    initials
        .iter()
        .map(|&b0| {
            let inva = ideal_invariant(b0);
            let inva1 = practical_invariant(b0, n, tp);
            let opt = solve_optimal_beta(b0, n, tp, 1e-8, 200).beta;
            // After optimization the *ideal* invariant of the optimized β
            // is compared against the rounded one (the paper's Table 3
            // reports them equal).
            let inva_opt = ideal_invariant(opt);
            let inva1_opt = practical_invariant(opt, n, tp);
            InvarianceRow {
                initial_beta: b0,
                inva_initial: inva,
                inva1_initial: inva1,
                rel_err_initial: ((inva - inva1) / inva).abs(),
                optimized_beta: opt,
                inva_optimized: inva_opt,
                inva1_optimized: inva1_opt,
                rel_err_optimized: ((inva_opt - inva1_opt) / inva_opt).abs(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_solutions_from_pow2_initials() {
        // Paper §2.3: initials 1−2⁻⁴, 1−2⁻⁵, 1−2⁻⁶ solve to
        // 0.937500, 0.968994, 0.984497 (n = 128, FP16).
        let expect = [0.937500, 0.968994, 0.984497];
        for (i, &p) in [4, 5, 6].iter().enumerate() {
            let b0 = 1.0 - 2f64.powi(-p);
            let s = solve_optimal_beta(b0, 128, Format::F16, 1e-8, 200);
            assert!(
                (s.beta - expect[i]).abs() < 5e-6,
                "initial {b0}: got {}, want {}",
                s.beta,
                expect[i]
            );
            assert!(s.converged, "initial {b0}: did not converge");
            assert!(s.residual <= 1e-8);
            assert!(s.iterations >= 1 && s.iterations <= 200);
        }
    }

    #[test]
    fn optimized_beta_has_zero_invariance_error() {
        // Table 3's punchline: after optimization Inva == Inva1 exactly
        // (to FP64 resolution).
        for &b0 in &[0.9, 0.99, 0.999, 1.0 - 2f64.powi(-5)] {
            let opt = solve_optimal_beta(b0, 128, Format::F16, 1e-10, 500).beta;
            let i = ideal_invariant(opt);
            let i1 = practical_invariant(opt, 128, Format::F16);
            assert!(
                ((i - i1) / i).abs() < 1e-9,
                "b0={b0}: inva {i} vs inva1 {i1}"
            );
        }
    }

    #[test]
    fn table3_initial_rel_errors_match_paper() {
        // Paper Table 3 initial-β relative errors:
        // 0.9 -> 0.32%, 1−2⁻⁴ -> 0%, 1−2⁻⁵ -> 0.81%, 1−2⁻⁶ -> 0.79%,
        // 0.99 -> 3.23%, 0.999 -> 3.20%.
        let t = table3(128, Format::F16);
        let expect = [0.0032, 0.0, 0.0081, 0.0079, 0.0323, 0.0320];
        for (row, &e) in t.iter().zip(&expect) {
            assert!(
                (row.rel_err_initial - e).abs() < 6e-4,
                "beta0={}: rel err {} vs paper {}",
                row.initial_beta,
                row.rel_err_initial,
                e
            );
            assert!(row.rel_err_optimized < 1e-9);
        }
    }

    #[test]
    fn beta_0p9375_is_exact_in_fp16() {
        // Appendix A: β = 0.9375 has an *integer* invariant (15) and is
        // exactly representable — no rounding error at all.
        let inva = ideal_invariant(0.9375);
        assert_eq!(inva, 15.0);
        let inva1 = practical_invariant(0.9375, 128, Format::F16);
        assert!((inva1 - 15.0).abs() < 1e-12);
    }

    #[test]
    fn bf16_branch_also_solves() {
        let b = solve_optimal_beta(1.0 - 2f64.powi(-6), 128, Format::Bf16, 1e-8, 200).beta;
        assert!(b > 0.9 && b < 1.0);
        let i = ideal_invariant(b);
        let i1 = practical_invariant(b, 128, Format::Bf16);
        assert!(((i - i1) / i).abs() < 1e-9);
    }

    #[test]
    fn zero_iterations_reports_unconverged_initial() {
        // max_iter = 0: the solver must hand back β₀ and say so, not
        // pretend the last iterate converged.
        let s = solve_optimal_beta(0.99, 128, Format::F16, 1e-10, 0);
        assert_eq!(s.beta, 0.99);
        assert_eq!(s.iterations, 0);
        assert!(!s.converged);
        assert!(s.residual.is_infinite());
    }

    #[test]
    fn impossible_tolerance_reports_unconverged() {
        // tol = 0 with one iteration cannot converge unless the iterate is
        // an exact fixed point; the status must record the shortfall.
        let s = solve_optimal_beta(0.99, 128, Format::F16, 0.0, 1);
        assert_eq!(s.iterations, 1);
        assert!(!s.converged);
        assert!(s.residual.is_finite() && s.residual > 0.0);
    }

    #[test]
    fn beta0_near_zero_converges_to_zero() {
        // β = 0 degrades PASA to FA2; the solve must stay at 0 without
        // a 0/0 residual poisoning the status.
        let s = solve_optimal_beta(0.0, 128, Format::F16, 1e-12, 50);
        assert_eq!(s.beta, 0.0);
        assert!(s.converged, "residual {} did not settle", s.residual);
        // ... and a tiny positive β₀ collapses toward a tiny fixed point
        // without NaN.
        let s = solve_optimal_beta(1e-9, 128, Format::F16, 1e-6, 200);
        assert!(s.beta.is_finite() && s.beta >= 0.0 && s.beta < 1e-3);
    }

    #[test]
    fn beta0_near_one_reports_the_pole_instead_of_nan() {
        // β₀ ≈ 1 drives the rounded a − b·n of Eq. 21 to exactly zero in
        // FP16 — the fixed-point map has a pole there. The hardened solver
        // must keep the last finite iterate and flag non-convergence, not
        // return NaN.
        for &b0 in &[0.9999, 1.0 - 1e-9] {
            let s = solve_optimal_beta(b0, 128, Format::F16, 1e-10, 500);
            assert!(s.beta.is_finite(), "b0={b0}: non-finite iterate");
            assert!(!s.converged, "b0={b0}: pole reported as converged");
            assert_eq!(s.beta, b0, "b0={b0}: pole must keep the initial iterate");
            assert_eq!(s.iterations, 0);
        }
        // ... while the paper's own 0.999 row is on the good side of the
        // pole and still converges.
        let s = solve_optimal_beta(0.999, 128, Format::F16, 1e-8, 200);
        assert!(s.converged);
        assert!(s.beta > 0.9 && s.beta < 1.0);
    }
}
