//! Per-thread scratch arenas for the attention hot path.
//!
//! Every buffer the flash/PASA/naive inner loops used to allocate per KV
//! block — the gathered K/V blocks, the S and P score blocks, the P·V
//! partial product, the online (m, l, O) state and the per-row visibility
//! scratch — lives in one [`AttnWorkspace`], acquired per kernel
//! invocation from a thread-local pool via [`with_workspace`]. Buffers
//! are reshaped in place ([`crate::tensor::Matrix::reset`] /
//! [`reset_vec`]), so after the first call at a given shape ("warm-up")
//! the inner KV sweep performs **zero heap allocations** — pinned by the
//! `alloc_discipline` integration test with a counting global allocator.
//!
//! The workspace never changes numerics: every fused op writes the exact
//! value sequence of the allocation-heavy composition it replaced (see
//! `tensor::ops`), and buffers are fully overwritten (or explicitly
//! zero-filled) before use, so reuse cannot leak state between calls.
//! Thread-locality means the worker pool's threads each warm their own
//! arena once and reuse it for every (head × Q-block) tile they steal.
//!
//! The gathered `kj`/`vj` blocks double as the **packed K-panels** of the
//! SIMD GEMM path: `KvView::block_into` writes each KV block row-major and
//! contiguous into them (dequantizing byte-backed E4M3 pages on the way),
//! and the AVX2 cores in [`crate::tensor::simd`] then slice four
//! consecutive rows at a time straight out of the panel — no second pack.

use crate::tensor::Matrix;
use std::cell::RefCell;

/// Reusable scratch buffers for one attention tile computation. Acquire
/// through [`with_workspace`]; all buffers are sized lazily and sticky,
/// so steady-state forwards allocate nothing from the KV loop.
#[derive(Default)]
pub struct AttnWorkspace {
    /// Gathered K block (dense copy or paged gather).
    pub(crate) kj: Matrix,
    /// Gathered V block.
    pub(crate) vj: Matrix,
    /// Score block S (flash) / S' (PASA).
    pub(crate) s: Matrix,
    /// Softmax weight block P.
    pub(crate) p: Matrix,
    /// P·V partial product.
    pub(crate) pv: Matrix,
    /// Online output accumulator O_i for the current Q block.
    pub(crate) oi: Matrix,
    /// Online row maxima m.
    pub(crate) m: Vec<f32>,
    /// Candidate row maxima m_j (swapped with `m` each block).
    pub(crate) m_new: Vec<f32>,
    /// Online row normalizers l.
    pub(crate) l: Vec<f32>,
    /// Block-local row maxima.
    pub(crate) row_m: Vec<f32>,
    /// Block-local row sums / means.
    pub(crate) row_l: Vec<f32>,
    /// exp(m_{j−1} − m_j) decay factors (flash) / exp(Δm_{j−1}) (PASA).
    pub(crate) decay: Vec<f32>,
    /// PASA running global pseudo-average F̄ʲ.
    pub(crate) fbar: Vec<f32>,
    /// PASA F̄ʲ⁻¹ (previous block's frame).
    pub(crate) fbar_prev: Vec<f32>,
    /// PASA block pseudo-average S̄'.
    pub(crate) sbar: Vec<f32>,
    /// PASA block-local l'_j.
    pub(crate) l_loc: Vec<f32>,
    /// PASA correction term Δm'_{j−1}.
    pub(crate) dm_prev: Vec<f32>,
    /// PASA correction term Δm'_j.
    pub(crate) dm_cur: Vec<f32>,
    /// PASA exp(Δm_j) scale of the current block.
    pub(crate) scale_cur: Vec<f32>,
    /// Per-row visible KV counts of the current Q block.
    pub(crate) vis: Vec<usize>,
    /// `vis` clipped to the current KV block window.
    pub(crate) bvis: Vec<usize>,
    /// Golden-path f64 softmax weights.
    pub(crate) p64: Vec<f64>,
    /// Golden-path f64 output accumulator.
    pub(crate) acc64: Vec<f64>,
}

/// Clear-and-refill a scratch vector, reusing its allocation (the `Vec`
/// twin of [`Matrix::reset`]).
#[inline]
pub(crate) fn reset_vec<T: Copy>(v: &mut Vec<T>, n: usize, fill: T) {
    v.clear();
    v.resize(n, fill);
}

/// Copy `src` into a scratch vector, reusing its allocation.
#[inline]
pub(crate) fn copy_vec<T: Copy>(v: &mut Vec<T>, src: &[T]) {
    v.clear();
    v.extend_from_slice(src);
}

thread_local! {
    /// A stack (not a single slot) so re-entrant kernel calls — e.g. a
    /// golden reference invoked from inside an instrumented run — each
    /// get their own arena.
    static WORKSPACES: RefCell<Vec<Box<AttnWorkspace>>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's pooled [`AttnWorkspace`] (creating one on
/// first use). The workspace returns to the thread-local pool afterwards,
/// buffers intact — the "warm-up once, allocate never again" contract of
/// the hot path.
pub fn with_workspace<R>(f: impl FnOnce(&mut AttnWorkspace) -> R) -> R {
    let mut ws = WORKSPACES
        .with(|stack| stack.borrow_mut().pop())
        .unwrap_or_default();
    let out = f(&mut ws);
    WORKSPACES.with(|stack| stack.borrow_mut().push(ws));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workspace_is_reused_across_calls() {
        // Grow a buffer in one call; the next call on this thread must see
        // the same capacity (the arena is pooled, not dropped).
        let cap0 = with_workspace(|ws| {
            ws.s.reset(64, 64);
            ws.s.data.capacity()
        });
        let cap1 = with_workspace(|ws| ws.s.data.capacity());
        assert!(cap1 >= cap0);
        let cap2 = with_workspace(|ws| {
            ws.s.reset(32, 16);
            ws.s.data.capacity()
        });
        assert_eq!(cap1, cap2, "shrinking reshape must keep the allocation");
    }

    #[test]
    fn nested_acquisition_gets_distinct_arenas() {
        with_workspace(|outer| {
            outer.s.reset(4, 4);
            outer.s.data[0] = 7.0;
            with_workspace(|inner| {
                inner.s.reset(4, 4);
                assert_eq!(inner.s.data[0], 0.0, "inner arena must be distinct");
            });
            assert_eq!(outer.s.data[0], 7.0);
        });
    }

    #[test]
    fn reset_vec_reuses_and_fills() {
        let mut v: Vec<f32> = Vec::new();
        reset_vec(&mut v, 8, f32::NEG_INFINITY);
        assert_eq!(v.len(), 8);
        assert!(v.iter().all(|&x| x == f32::NEG_INFINITY));
        let cap = v.capacity();
        reset_vec(&mut v, 4, 0.0);
        assert_eq!(v, vec![0.0; 4]);
        assert_eq!(v.capacity(), cap);
        copy_vec(&mut v, &[1.0, 2.0]);
        assert_eq!(v, vec![1.0, 2.0]);
        assert_eq!(v.capacity(), cap);
    }
}
