//! Minimal CLI argument parser (S13) — no clap offline.
//!
//! Grammar: `pasa <subcommand> [--flag value]... [--switch]...`

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(sc) = it.next() {
            if sc.starts_with("--") {
                bail!("expected a subcommand before flags, got {sc}");
            }
            out.subcommand = sc.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // A flag with a value, or a bare switch.
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.flags.insert(name.to_string(), (*v).clone());
                        it.next();
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else {
                bail!("unexpected positional argument {a}");
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer: {e}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects a number: {e}")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(&argv("repro --exp fig9a --heads 4 --verbose")).unwrap();
        assert_eq!(a.subcommand, "repro");
        assert_eq!(a.get("exp"), Some("fig9a"));
        assert_eq!(a.get_usize("heads", 16).unwrap(), 4);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = Args::parse(&argv("serve")).unwrap();
        assert_eq!(a.get_usize("requests", 8).unwrap(), 8);
        assert_eq!(a.get_or("policy", "adaptive"), "adaptive");
        assert!(Args::parse(&argv("--oops first")).is_err());
        assert!(Args::parse(&argv("run stray")).is_err());
        let bad = Args::parse(&argv("run --n abc")).unwrap();
        assert!(bad.get_usize("n", 1).is_err());
    }

    #[test]
    fn negative_numbers_are_values() {
        let a = Args::parse(&argv("repro --x0 -30")).unwrap();
        assert_eq!(a.get_f64("x0", 0.0).unwrap(), -30.0);
    }
}
