//! Paged KV-cache manager (S11) — vLLM-style block allocator over host
//! memory.
//!
//! The decode HLO consumes dense (L, B, max_seq, W) cache tensors, but the
//! coordinator stores each request's KV in fixed-size *pages* (blocks of
//! `page_tokens` token-rows), so resident memory is proportional to the
//! tokens actually generated, admission is capacity-checked in pages, and
//! shared prompt prefixes can be forked copy-on-write at page granularity.
//! Dense tensors are assembled only at the batch boundary.

use crate::attention::{KvPageSource, KvView};
use crate::numerics::{f32_to_f8e4m3_bits, f8e4m3_decode_table};
use crate::tensor::Matrix;
use anyhow::{bail, Result};
use std::cell::UnsafeCell;

/// Identifier of one page in the pool arena (same `u32` as the attention
/// lab's `attention::PageId` — a paged `KvView` indexes this pool).
pub type PageId = u32;

/// Element storage format of the KV arena.
///
/// The pool's *logical* contents are always `row_width` f32 per token row;
/// `E4m3` stores each element as one FP8-E4M3 byte (4× the resident
/// sequences at a fixed byte budget), quantizing on write and dequantizing
/// on the gather into the attention workspace panel. Quantization error is
/// priced by the differential-fuzz per-allocation RMSE gates
/// (`rust/tests/differential_fuzz.rs`), not bit-equality — E4M3 KV is a
/// lossy residency/accuracy trade the paper's PASA shifting makes safe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvStore {
    /// Full-precision f32 pages (4 bytes/element) — the fuzz oracle.
    F32,
    /// FP8-E4M3 pages (1 byte/element), RTNE-quantized on write.
    E4m3,
}

impl KvStore {
    pub fn bytes_per_elem(self) -> usize {
        match self {
            KvStore::F32 => 4,
            KvStore::E4m3 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvStore::F32 => "f32",
            KvStore::E4m3 => "e4m3",
        }
    }

    /// Parse a CLI knob value (`pasa serve --kv-store {f32|e4m3}`).
    pub fn parse(s: &str) -> Result<KvStore> {
        match s {
            "f32" => Ok(KvStore::F32),
            "e4m3" => Ok(KvStore::E4m3),
            other => bail!("unknown KV store format {other:?} (expected f32 or e4m3)"),
        }
    }
}

/// Fixed-capacity page pool. Each page holds `page_tokens` rows of
/// `row_width` elements (one layer's K *or* V slice of those tokens),
/// stored per [`KvStore`]: native f32 or one E4M3 byte per element.
///
/// Page *data* is interior-mutable (`UnsafeCell`) so the engine's
/// parallel decode can write each slot's freshly-privatized pages through
/// a shared `&KvPool` while other slots read their own (disjoint) pages —
/// see [`SeqCache::write_row_prepared`] for the checked invariant. Page
/// *metadata* (refcounts, the free list) is only ever touched through
/// `&mut self`.
pub struct KvPool {
    pub page_tokens: usize,
    pub row_width: usize,
    store: KvStore,
    /// f32 arena — populated iff `store == KvStore::F32`.
    arena: Vec<UnsafeCell<f32>>,
    /// E4M3 byte arena — populated iff `store == KvStore::E4m3`.
    arena8: Vec<UnsafeCell<u8>>,
    refcount: Vec<u32>,
    free: Vec<PageId>,
    total_pages: usize,
}

// SAFETY: both arenas are written either through `&mut self` (exclusive)
// or through `page_write`, whose contract restricts writes to pages with
// refcount 1 reachable from exactly one sequence's page table — so no two
// threads ever access the same page concurrently with at least one
// writing. Metadata is `&mut self`-only and neither arena is ever resized
// after construction.
unsafe impl Sync for KvPool {}

impl KvPool {
    pub fn new(total_pages: usize, page_tokens: usize, row_width: usize) -> KvPool {
        Self::new_with_store(total_pages, page_tokens, row_width, KvStore::F32)
    }

    pub fn new_with_store(
        total_pages: usize,
        page_tokens: usize,
        row_width: usize,
        store: KvStore,
    ) -> KvPool {
        let elems = total_pages * page_tokens * row_width;
        let mut arena = Vec::new();
        let mut arena8 = Vec::new();
        match store {
            KvStore::F32 => {
                arena.reserve_exact(elems);
                arena.resize_with(elems, || UnsafeCell::new(0.0));
            }
            KvStore::E4m3 => {
                arena8.reserve_exact(elems);
                // 0x00 is +0.0 in E4M3, so fresh pages decode to zeros.
                arena8.resize_with(elems, || UnsafeCell::new(0));
            }
        }
        KvPool {
            page_tokens,
            row_width,
            store,
            arena,
            arena8,
            refcount: vec![0; total_pages],
            free: (0..total_pages as PageId).rev().collect(),
            total_pages,
        }
    }

    /// Size the pool by *bytes* instead of pages — the apples-to-apples
    /// comparison surface for KV storage formats: at a fixed byte budget,
    /// `E4m3` holds 4× the pages of `F32` (2× an FP16 baseline), which is
    /// exactly the doubled-residency effect `bench_serving` measures.
    pub fn with_byte_budget(
        bytes: usize,
        page_tokens: usize,
        row_width: usize,
        store: KvStore,
    ) -> KvPool {
        let page_bytes = page_tokens * row_width * store.bytes_per_elem();
        let pages = bytes / page_bytes.max(1);
        Self::new_with_store(pages, page_tokens, row_width, store)
    }

    pub fn store(&self) -> KvStore {
        self.store
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn page_floats(&self) -> usize {
        self.page_tokens * self.row_width
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free.len()
    }

    /// Utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        self.used_pages() as f64 / self.total_pages.max(1) as f64
    }

    /// Marker carried by every pool-capacity error (`alloc`,
    /// `ensure_capacity`, CoW growth). [`KvPool::is_exhausted_error`] keys
    /// off it; keep the two in sync.
    const EXHAUSTED: &'static str = "KV pool exhausted";

    /// True when `e` is pool exhaustion — the one cache failure the
    /// serving engine treats as backpressure (evict/requeue) rather than
    /// a bug. Classified by the [`Self::EXHAUSTED`] marker, which the
    /// vendored `anyhow`'s flattened Display preserves through context
    /// wrapping (a regression test pins this through the CoW path; a
    /// typed-error downcast would replace it if the real `anyhow` ever
    /// lands).
    pub fn is_exhausted_error(e: &anyhow::Error) -> bool {
        e.to_string().contains(Self::EXHAUSTED)
    }

    fn alloc(&mut self) -> Result<PageId> {
        match self.free.pop() {
            Some(id) => {
                debug_assert_eq!(self.refcount[id as usize], 0);
                self.refcount[id as usize] = 1;
                // Fresh pages are zeroed: the PASA kernels' pseudo-average
                // must not see stale garbage in masked positions. (E4M3:
                // byte 0x00 decodes to +0.0.)
                match self.store {
                    KvStore::F32 => {
                        for c in self.page_mut(id).iter_mut() {
                            *c = 0.0;
                        }
                    }
                    KvStore::E4m3 => {
                        for c in self.page8_mut(id).iter_mut() {
                            *c = 0;
                        }
                    }
                }
                Ok(id)
            }
            None => bail!("{} ({} pages)", Self::EXHAUSTED, self.total_pages),
        }
    }

    /// Add one reference to a resident page (CoW forks and radix
    /// prefix-cache sharing). Checked: a pathological fan-out loop that
    /// reached `u32::MAX` references would silently wrap in release
    /// builds and corrupt CoW ownership, so the overflow surfaces as the
    /// usual exhaustion-style `Err` — the engine already treats that
    /// family as backpressure. On failure the count is untouched.
    pub(super) fn retain(&mut self, id: PageId) -> Result<()> {
        let rc = &mut self.refcount[id as usize];
        match rc.checked_add(1) {
            Some(n) => {
                *rc = n;
                Ok(())
            }
            None => bail!(
                "{}: page {id} refcount saturated at u32::MAX (fan-out too deep)",
                Self::EXHAUSTED
            ),
        }
    }

    /// Drop one reference; the page returns to the free list at zero.
    /// Shared with the sibling prefix-cache module, whose radix nodes
    /// hold page references of their own.
    pub(super) fn release(&mut self, id: PageId) {
        let rc = &mut self.refcount[id as usize];
        assert!(*rc > 0, "double free of page {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
        }
    }

    fn page(&self, id: PageId) -> &[f32] {
        debug_assert_eq!(self.store, KvStore::F32, "f32 page view of a byte-backed pool");
        let off = id as usize * self.page_floats();
        let pf = self.page_floats();
        let cells = &self.arena[off..off + pf];
        // SAFETY: UnsafeCell<f32> is layout-compatible with f32, and the
        // pool's Sync invariant guarantees no thread writes this page
        // while a read borrow can exist (writes require either &mut self
        // or exclusive page ownership).
        unsafe { &*(cells as *const [UnsafeCell<f32>] as *const [f32]) }
    }

    fn page_mut(&mut self, id: PageId) -> &mut [f32] {
        debug_assert_eq!(self.store, KvStore::F32, "f32 page view of a byte-backed pool");
        let off = id as usize * self.page_floats();
        let pf = self.page_floats();
        let cells = &mut self.arena[off..off + pf];
        // SAFETY: `&mut self` is exclusive pool access.
        unsafe { &mut *(cells as *mut [UnsafeCell<f32>] as *mut [f32]) }
    }

    fn page8(&self, id: PageId) -> &[u8] {
        debug_assert_eq!(self.store, KvStore::E4m3, "byte page view of an f32 pool");
        let off = id as usize * self.page_floats();
        let pf = self.page_floats();
        let cells = &self.arena8[off..off + pf];
        // SAFETY: UnsafeCell<u8> is layout-compatible with u8, and the
        // pool's Sync invariant guarantees no thread writes this page
        // while a read borrow can exist (same argument as `page`).
        unsafe { &*(cells as *const [UnsafeCell<u8>] as *const [u8]) }
    }

    fn page8_mut(&mut self, id: PageId) -> &mut [u8] {
        debug_assert_eq!(self.store, KvStore::E4m3, "byte page view of an f32 pool");
        let off = id as usize * self.page_floats();
        let pf = self.page_floats();
        let cells = &mut self.arena8[off..off + pf];
        // SAFETY: `&mut self` is exclusive pool access.
        unsafe { &mut *(cells as *mut [UnsafeCell<u8>] as *mut [u8]) }
    }

    /// Store `src` into page `id` at element offset `off` through the
    /// store format — f32 verbatim, E4M3 RTNE-quantized — with exclusive
    /// (`&mut self`) pool access. The single write seam of the exclusive
    /// paths (`write_row`, CoW is byte-level and bypasses it).
    fn store_at(&mut self, id: PageId, off: usize, src: &[f32]) {
        match self.store {
            KvStore::F32 => {
                self.page_mut(id)[off..off + src.len()].copy_from_slice(src);
            }
            KvStore::E4m3 => {
                let dst = &mut self.page8_mut(id)[off..off + src.len()];
                for (d, &x) in dst.iter_mut().zip(src) {
                    *d = f32_to_f8e4m3_bits(x);
                }
            }
        }
    }

    /// Write `src` into page `id` starting at element offset `off`,
    /// through a **shared** pool reference — the parallel-decode write
    /// path. Quantizes through the store format exactly like
    /// [`Self::store_at`].
    ///
    /// # Safety
    /// The caller must guarantee exclusive access to page `id` for the
    /// duration of the call: no other thread may read or write it.
    /// [`SeqCache::write_row_prepared`] upholds this by only writing
    /// refcount-1 pages reachable solely from the calling slot's table.
    unsafe fn page_write(&self, id: PageId, off: usize, src: &[f32]) {
        let base = id as usize * self.page_floats() + off;
        debug_assert!(off + src.len() <= self.page_floats());
        debug_assert_eq!(
            self.refcount[id as usize], 1,
            "page_write on page {id} with refcount {} — the exclusive-access \
             contract requires a refcount-1 page owned by the calling slot",
            self.refcount[id as usize]
        );
        match self.store {
            KvStore::F32 => {
                // SAFETY: the caller guarantees exclusive access to page
                // `id` for the duration of the call (debug builds assert
                // the refcount-1 ownership witness above), so no other
                // thread can read or write these cells while we store
                // through them.
                unsafe {
                    for (i, &x) in src.iter().enumerate() {
                        *self.arena[base + i].get() = x;
                    }
                }
            }
            KvStore::E4m3 => {
                // SAFETY: same exclusive-access argument as the F32 arm;
                // the store merely quantizes each element to its E4M3
                // byte first.
                unsafe {
                    for (i, &x) in src.iter().enumerate() {
                        *self.arena8[base + i].get() = f32_to_f8e4m3_bits(x);
                    }
                }
            }
        }
    }

    /// Chaos seam: pull up to `n` pages out of the free list so admission
    /// and in-flight growth see a genuinely exhausted pool — a forced
    /// exhaustion spike. The pages are held at refcount 1 (the usual
    /// used/free/utilization accounting reflects the seizure) and come
    /// back through [`Self::release_pages`].
    pub fn seize_free_pages(&mut self, n: usize) -> Vec<PageId> {
        let mut out = Vec::with_capacity(n.min(self.free.len()));
        for _ in 0..n {
            match self.alloc() {
                Ok(id) => out.push(id),
                Err(_) => break,
            }
        }
        out
    }

    /// Release pages held by [`Self::seize_free_pages`].
    pub fn release_pages(&mut self, pages: &[PageId]) {
        for &id in pages {
            self.release(id);
        }
    }

    /// Chaos seam: corrupt one element of a resident page in place.
    /// `flip_bit` toggles an exponent bit — a huge-but-finite excursion
    /// that exercises the numeric guard — otherwise the element becomes
    /// NaN (the non-finite watchdog's territory). Store-aware: f32 pools
    /// corrupt the f32 word, E4M3 pools the stored byte (`0x7f` is the
    /// E4M3 NaN encoding). Exclusive (`&mut self`) access, so injected
    /// damage can never race a reader — it is visible only to *later*
    /// reads, exactly like real silent storage corruption.
    pub fn corrupt_element(&mut self, id: PageId, elem: usize, flip_bit: bool) {
        match self.store {
            KvStore::F32 => {
                let page = self.page_mut(id);
                page[elem] = if flip_bit {
                    f32::from_bits(page[elem].to_bits() ^ 0x4000_0000)
                } else {
                    f32::NAN
                };
            }
            KvStore::E4m3 => {
                let page = self.page8_mut(id);
                page[elem] = if flip_bit { page[elem] ^ 0x40 } else { 0x7f };
            }
        }
    }
}

/// The attention lab reads pages straight out of the pool: a
/// `KvView::Paged` over this pool is the zero-copy bridge from the
/// serving cache to the instrumented kernels. Byte-backed (E4M3) pools
/// have no raw f32 page view — every read goes through the dequantizing
/// [`KvPageSource::gather_rows`] override below.
impl KvPageSource for KvPool {
    fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    fn row_width(&self) -> usize {
        self.row_width
    }

    fn page_data(&self, id: PageId) -> &[f32] {
        match self.store {
            KvStore::F32 => self.page(id),
            KvStore::E4m3 => panic!(
                "byte-backed E4m3 KV pages have no raw f32 view — gather through \
                 KvPageSource::gather_rows (KvView::block_into does)"
            ),
        }
    }

    // lint: hot-path — per-page gather of the serving decode sweep.
    fn gather_rows(
        &self,
        id: PageId,
        off: usize,
        take: usize,
        col0: usize,
        cols: usize,
        out: &mut Matrix,
        out_row0: usize,
    ) {
        let w = self.row_width;
        match self.store {
            KvStore::F32 => {
                let src = &self.page(id)[off * w..(off + take) * w];
                for t in 0..take {
                    let srow = &src[t * w + col0..t * w + col0 + cols];
                    out.row_mut(out_row0 + t).copy_from_slice(srow);
                }
            }
            KvStore::E4m3 => {
                // Dequantize on the gather: one 256-entry LUT lookup per
                // element, fused into the panel copy so no intermediate
                // f32 page is ever materialized.
                let lut = f8e4m3_decode_table();
                let src = &self.page8(id)[off * w..(off + take) * w];
                for t in 0..take {
                    let srow = &src[t * w + col0..t * w + col0 + cols];
                    let drow = out.row_mut(out_row0 + t);
                    for (d, &b) in drow.iter_mut().zip(srow) {
                        *d = lut[b as usize];
                    }
                }
            }
        }
    }
    // lint: end-hot-path
}

/// One sequence's paged cache: per layer, a page table for K and for V.
#[derive(Clone, Debug, Default)]
pub struct SeqCache {
    /// pages[layer] = (k_pages, v_pages).
    pages: Vec<(Vec<PageId>, Vec<PageId>)>,
    pub len_tokens: usize,
    n_layers: usize,
}

impl SeqCache {
    pub fn new(n_layers: usize) -> SeqCache {
        SeqCache {
            pages: vec![(Vec::new(), Vec::new()); n_layers],
            len_tokens: 0,
            n_layers,
        }
    }

    /// Pages needed (per layer, per K/V) for `tokens` rows.
    fn pages_for(tokens: usize, page_tokens: usize) -> usize {
        tokens.div_ceil(page_tokens)
    }

    /// Total pool pages this sequence would hold at `tokens` length.
    pub fn pages_required(n_layers: usize, tokens: usize, page_tokens: usize) -> usize {
        2 * n_layers * Self::pages_for(tokens, page_tokens)
    }

    /// Grow page tables to cover `tokens` rows, allocating from the pool.
    pub fn ensure_capacity(&mut self, pool: &mut KvPool, tokens: usize) -> Result<()> {
        let need = Self::pages_for(tokens, pool.page_tokens);
        // Pre-check so a mid-way failure doesn't leak a partial grow.
        let mut missing = 0usize;
        for (kp, vp) in &self.pages {
            missing += need.saturating_sub(kp.len()) + need.saturating_sub(vp.len());
        }
        if missing > pool.free_pages() {
            bail!(
                "{}: need {missing} pages, {} free",
                KvPool::EXHAUSTED,
                pool.free_pages()
            );
        }
        for (kp, vp) in &mut self.pages {
            while kp.len() < need {
                kp.push(pool.alloc()?);
            }
            while vp.len() < need {
                vp.push(pool.alloc()?);
            }
        }
        Ok(())
    }

    /// Copy-on-write fork (prefix sharing): pages are shared, refcounted.
    /// Fails only when a refcount would saturate ([`KvPool::retain`]) —
    /// rolled back completely, the same backpressure `Err` family as
    /// pool exhaustion.
    pub fn fork(&self, pool: &mut KvPool) -> Result<SeqCache> {
        self.fork_first_pages(pool, usize::MAX, self.len_tokens)
    }

    /// Partial-prefix copy-on-write fork: share only the pages covering
    /// the **page-aligned** prefix of `tokens` (truncated down — a
    /// partially filled tail page is never shared, so the fork can only
    /// observe rows the donor had finalized by a page boundary) and
    /// truncate `len_tokens` to that aligned match. `tokens` clamps to
    /// the donor's `len_tokens`. The radix prefix cache seeds admissions
    /// through this; the tail pages the donor holds beyond the cut are
    /// simply not referenced (the "tail-page release" of a prefix fork is
    /// never taking the reference in the first place).
    pub fn fork_prefix(&self, pool: &mut KvPool, tokens: usize) -> Result<SeqCache> {
        let pt = pool.page_tokens.max(1);
        let aligned = (tokens.min(self.len_tokens) / pt) * pt;
        self.fork_first_pages(pool, aligned / pt, aligned)
    }

    /// Shared core of [`Self::fork`] / [`Self::fork_prefix`]: clone the
    /// first `keep` pages of every per-layer K/V table, retaining each.
    /// A mid-way retain failure releases every reference already taken —
    /// the pool is exactly as before the call.
    fn fork_first_pages(&self, pool: &mut KvPool, keep: usize, len_tokens: usize) -> Result<SeqCache> {
        let mut out = SeqCache::new(self.n_layers);
        out.len_tokens = len_tokens;
        for (li, (kp, vp)) in self.pages.iter().enumerate() {
            for (src, want_v) in [(kp, false), (vp, true)] {
                for &id in src.iter().take(keep) {
                    if let Err(e) = pool.retain(id) {
                        out.release(pool);
                        return Err(e);
                    }
                    let (ok, ov) = &mut out.pages[li];
                    if want_v { ov.push(id) } else { ok.push(id) }
                }
            }
        }
        Ok(out)
    }

    /// Assemble a sequence cache directly from already-resident shared
    /// pages — the radix prefix cache's seeding primitive.
    /// `page_pairs[pi][layer]` is the (K, V) page pair covering page
    /// `pi`, so the result holds `page_pairs.len() × page_tokens`
    /// finalized rows. Every page is retained; a mid-way retain failure
    /// rolls back completely.
    pub(super) fn from_shared_pages(
        pool: &mut KvPool,
        n_layers: usize,
        page_pairs: &[Vec<(PageId, PageId)>],
    ) -> Result<SeqCache> {
        let mut out = SeqCache::new(n_layers);
        for pair in page_pairs {
            debug_assert_eq!(pair.len(), n_layers, "one (K, V) pair per layer");
            for (li, &(k, v)) in pair.iter().enumerate() {
                if let Err(e) = pool.retain(k) {
                    out.release(pool);
                    return Err(e);
                }
                out.pages[li].0.push(k);
                if let Err(e) = pool.retain(v) {
                    out.release(pool);
                    return Err(e);
                }
                out.pages[li].1.push(v);
            }
        }
        out.len_tokens = page_pairs.len() * pool.page_tokens;
        Ok(out)
    }

    /// Make a shared (CoW) page private before a write. Pool exhaustion is
    /// an *expected* runtime condition — a fork fleet can legitimately
    /// outgrow the arena — so it surfaces as an `Err` the engine can turn
    /// into backpressure, never a panic. On failure the page table is
    /// untouched (the shared page stays valid).
    fn ensure_private(pool: &mut KvPool, id: &mut PageId) -> Result<()> {
        if pool.refcount[*id as usize] > 1 {
            let fresh = match pool.store {
                KvStore::F32 => {
                    let copy: Vec<f32> = pool.page(*id).to_vec();
                    let fresh = pool
                        .alloc()
                        .map_err(|e| e.context("copy-on-write of a shared KV page"))?;
                    pool.page_mut(fresh).copy_from_slice(&copy);
                    fresh
                }
                KvStore::E4m3 => {
                    // CoW copies raw bytes — no decode/re-encode round
                    // trip, so a forked page stays bit-identical.
                    let copy: Vec<u8> = pool.page8(*id).to_vec();
                    let fresh = pool
                        .alloc()
                        .map_err(|e| e.context("copy-on-write of a shared KV page"))?;
                    pool.page8_mut(fresh).copy_from_slice(&copy);
                    fresh
                }
            };
            pool.release(*id);
            *id = fresh;
        }
        Ok(())
    }

    /// Write one token's K and V rows for a layer at absolute position.
    /// Fails (without corrupting the cache) when a copy-on-write
    /// materialization cannot get a fresh page.
    pub fn write_row(
        &mut self,
        pool: &mut KvPool,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<()> {
        let w = pool.row_width;
        assert_eq!(k_row.len(), w);
        assert_eq!(v_row.len(), w);
        let (pg, off) = (pos / pool.page_tokens, pos % pool.page_tokens);
        let (kp, vp) = &mut self.pages[layer];
        let kid = &mut kp[pg];
        Self::ensure_private(pool, kid)?;
        let kid = *kid;
        pool.store_at(kid, off * w, k_row);
        let vid = &mut vp[pg];
        Self::ensure_private(pool, vid)?;
        let vid = *vid;
        pool.store_at(vid, off * w, v_row);
        self.len_tokens = self.len_tokens.max(pos + 1);
        Ok(())
    }

    /// Chaos seam: corrupt the first element of this sequence's K row at
    /// (`layer`, `pos`) via [`KvPool::corrupt_element`]. Returns `false`
    /// (nothing touched) when the position isn't resident. Takes `&mut
    /// KvPool`, so injected damage can never race a reader — it is seen
    /// only by *later* attention steps, and only by this sequence (pages
    /// are per-sequence unless CoW-shared).
    pub fn corrupt_row(&self, pool: &mut KvPool, layer: usize, pos: usize, flip_bit: bool) -> bool {
        if layer >= self.n_layers || pos >= self.len_tokens {
            return false;
        }
        let (kp, _) = &self.pages[layer];
        let Some(&page) = kp.get(pos / pool.page_tokens) else {
            return false;
        };
        pool.corrupt_element(page, (pos % pool.page_tokens) * pool.row_width, flip_bit);
        true
    }

    /// Do everything a decode step at `pos` needs *exclusive* pool access
    /// for — grow capacity and privatize (CoW) the K/V pages holding
    /// `pos` across all layers — so the step's compute can then run
    /// against a shared `&KvPool` ([`Self::write_row_prepared`]). The
    /// serving engine calls this per slot, sequentially, before fanning
    /// the slots' decode steps onto the worker pool. Pool exhaustion is
    /// the usual backpressure `Err`; on failure the tables are untouched
    /// or grown-but-unwritten — never corrupted.
    pub fn prepare_step(&mut self, pool: &mut KvPool, pos: usize) -> Result<()> {
        self.ensure_capacity(pool, pos + 1)?;
        let pg = pos / pool.page_tokens;
        for (kp, vp) in &mut self.pages {
            Self::ensure_private(pool, &mut kp[pg])?;
            Self::ensure_private(pool, &mut vp[pg])?;
        }
        Ok(())
    }

    /// Write one token's K and V rows through a **shared** pool reference
    /// — the parallel-decode twin of [`Self::write_row`]. Requires a
    /// prior [`Self::prepare_step`] covering `pos`: the target pages must
    /// exist and be privately owned (refcount 1), which this method
    /// asserts so a violated invariant is a loud panic, not silent data
    /// corruption. Bit-identical to `write_row` (same bytes to the same
    /// pages); it merely cannot allocate or copy-on-write.
    pub fn write_row_prepared(
        &mut self,
        pool: &KvPool,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let w = pool.row_width;
        assert_eq!(k_row.len(), w);
        assert_eq!(v_row.len(), w);
        let (pg, off) = (pos / pool.page_tokens, pos % pool.page_tokens);
        let (kp, vp) = &self.pages[layer];
        let (kid, vid) = (kp[pg], vp[pg]);
        assert_eq!(
            pool.refcount[kid as usize], 1,
            "write_row_prepared on a shared K page (missing prepare_step?)"
        );
        assert_eq!(
            pool.refcount[vid as usize], 1,
            "write_row_prepared on a shared V page (missing prepare_step?)"
        );
        // SAFETY: both pages are refcount-1, so this slot's table is the
        // only reference to them, and we hold `&mut self` — no other
        // thread can touch these pages.
        unsafe {
            pool.page_write(kid, off * w, k_row);
            pool.page_write(vid, off * w, v_row);
        }
        self.len_tokens = self.len_tokens.max(pos + 1);
    }

    /// Assemble this sequence's K (or V) for `layer` into a dense
    /// (max_seq, W) slice; positions beyond len are zeroed. Fails — before
    /// touching any page — when the dense buffer cannot hold all
    /// `len_tokens` valid rows: silently truncating KV would hand the
    /// kernels a cache that looks complete but is missing its tail.
    pub fn fill_dense(
        &self,
        pool: &KvPool,
        layer: usize,
        want_v: bool,
        out: &mut [f32],
    ) -> Result<()> {
        let w = pool.row_width;
        let pt = pool.page_tokens;
        if self.len_tokens * w > out.len() {
            bail!(
                "fill_dense: dense buffer holds {} rows but the paged cache has {} valid rows \
                 (layer {layer}, row width {w})",
                out.len() / w.max(1),
                self.len_tokens
            );
        }
        out.fill(0.0);
        let (kp, vp) = &self.pages[layer];
        let pages = if want_v { vp } else { kp };
        for (pi, &id) in pages.iter().enumerate() {
            let rows = (self.len_tokens.saturating_sub(pi * pt)).min(pt);
            if rows == 0 {
                break;
            }
            let dst_off = pi * pt * w;
            let dst = &mut out[dst_off..dst_off + rows * w];
            // Store-agnostic assembly: the dense-batch (PJRT) backend
            // consumes f32 regardless of how the pages are resident.
            match pool.store {
                KvStore::F32 => dst.copy_from_slice(&pool.page(id)[..rows * w]),
                KvStore::E4m3 => {
                    let lut = f8e4m3_decode_table();
                    for (d, &b) in dst.iter_mut().zip(&pool.page8(id)[..rows * w]) {
                        *d = lut[b as usize];
                    }
                }
            }
        }
        Ok(())
    }

    /// Page table of this sequence's K (or V) for one layer — the raw
    /// material of a paged attention view.
    pub fn page_ids(&self, layer: usize, want_v: bool) -> &[PageId] {
        let (kp, vp) = &self.pages[layer];
        if want_v {
            vp
        } else {
            kp
        }
    }

    /// Zero-copy attention views of this sequence's (K, V) for one layer:
    /// the serving engine hands these straight to
    /// [`crate::attention::AttentionRequest::run_with_kv`] — `len_tokens`
    /// worth of rows gathered page-by-page, no dense assembly.
    pub fn kv_views<'a>(&'a self, pool: &'a KvPool, layer: usize) -> (KvView<'a>, KvView<'a>) {
        self.kv_views_at(pool, layer, self.len_tokens)
    }

    /// [`Self::kv_views`] fenced at an explicit valid length `len ≤
    /// len_tokens`. Chunked prefill needs this: a chunk writes all its
    /// K/V rows for layer *l* and then attends each query row against
    /// only the rows at positions `≤` its own — but `len_tokens` is
    /// cache-wide (the max over every layer's writes), so by the time
    /// layer *l+1* runs, `len_tokens` already covers the whole chunk.
    /// The explicit fence restores the per-row causal prefix, which is
    /// what makes chunk results independent of where chunk boundaries
    /// fall.
    pub fn kv_views_at<'a>(
        &'a self,
        pool: &'a KvPool,
        layer: usize,
        len: usize,
    ) -> (KvView<'a>, KvView<'a>) {
        debug_assert!(len <= self.len_tokens, "view fence {len} past {}", self.len_tokens);
        (
            KvView::paged(self.page_ids(layer, false), pool, len),
            KvView::paged(self.page_ids(layer, true), pool, len),
        )
    }

    /// Release all pages back to the pool.
    pub fn release(&mut self, pool: &mut KvPool) {
        for (kp, vp) in &mut self.pages {
            for id in kp.drain(..).chain(vp.drain(..)) {
                pool.release(id);
            }
        }
        self.len_tokens = 0;
    }

    pub fn total_pages_held(&self) -> usize {
        self.pages
            .iter()
            .map(|(k, v)| k.len() + v.len())
            .sum()
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> KvPool {
        KvPool::new(64, 4, 8) // 64 pages, 4 tokens/page, width 8
    }

    #[test]
    fn alloc_write_read_round_trip() {
        let mut p = pool();
        let mut s = SeqCache::new(2);
        s.ensure_capacity(&mut p, 6).unwrap();
        assert_eq!(s.total_pages_held(), 2 * 2 * 2); // 2 layers * K,V * 2 pages
        let krow: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        s.write_row(&mut p, 1, 5, &krow, &vrow).unwrap();
        let mut dense = vec![1.0f32; 16 * 8];
        s.fill_dense(&p, 1, false, &mut dense).unwrap();
        assert_eq!(&dense[5 * 8..6 * 8], krow.as_slice());
        assert_eq!(&dense[..8], &[0.0; 8]); // untouched rows zeroed
        s.fill_dense(&p, 1, true, &mut dense).unwrap();
        assert_eq!(&dense[5 * 8..6 * 8], vrow.as_slice());
        s.release(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn capacity_exhaustion_is_clean() {
        let mut p = KvPool::new(7, 4, 8); // too few pages for 2 layers x 2
        let mut s = SeqCache::new(2);
        let r = s.ensure_capacity(&mut p, 5); // needs 2 pages x4 = 8 > 7
        assert!(r.is_err());
        // Failed ensure must not leak pages.
        assert_eq!(p.used_pages(), 0);
        s.release(&mut p);
    }

    #[test]
    fn seize_and_release_round_trip_the_free_list() {
        let mut p = pool();
        let total = p.free_pages();
        let seized = p.seize_free_pages(total + 10); // over-ask caps at free
        assert_eq!(seized.len(), total);
        assert_eq!(p.free_pages(), 0);
        assert!(p.seize_free_pages(1).is_empty());
        let mut s = SeqCache::new(1);
        assert!(s.ensure_capacity(&mut p, 4).is_err(), "pool is seized");
        p.release_pages(&seized);
        assert_eq!(p.free_pages(), total);
        assert_eq!(p.used_pages(), 0);
        s.ensure_capacity(&mut p, 4).unwrap();
        s.release(&mut p);
    }

    #[test]
    fn corrupt_row_poisons_then_flips_the_written_k_row() {
        let mut p = pool();
        let mut s = SeqCache::new(1);
        s.ensure_capacity(&mut p, 4).unwrap();
        let row = [1.5f32; 8];
        s.write_row(&mut p, 0, 2, &row, &row).unwrap();
        // NaN poison lands on the K row's first element only.
        assert!(s.corrupt_row(&mut p, 0, 2, false));
        let mut dense = vec![0.0; 4 * 8];
        s.fill_dense(&p, 0, false, &mut dense).unwrap();
        assert!(dense[2 * 8].is_nan());
        assert_eq!(dense[2 * 8 + 1], 1.5);
        s.fill_dense(&p, 0, true, &mut dense).unwrap();
        assert_eq!(dense[2 * 8], 1.5, "V rows are untouched");
        // Bit flip produces a finite-but-huge excursion, not NaN.
        s.write_row(&mut p, 0, 2, &row, &row).unwrap();
        assert!(s.corrupt_row(&mut p, 0, 2, true));
        s.fill_dense(&p, 0, false, &mut dense).unwrap();
        assert!(dense[2 * 8].is_finite() && dense[2 * 8] != 1.5);
        // Out-of-residency targets are refused, not panicked on.
        assert!(!s.corrupt_row(&mut p, 0, 99, false));
        assert!(!s.corrupt_row(&mut p, 5, 0, false));
        s.release(&mut p);
    }

    #[test]
    fn corrupt_row_on_an_e4m3_pool_sets_the_nan_byte() {
        let mut p = KvPool::new_with_store(16, 4, 8, KvStore::E4m3);
        let mut s = SeqCache::new(1);
        s.ensure_capacity(&mut p, 4).unwrap();
        s.write_row(&mut p, 0, 0, &[1.0; 8], &[1.0; 8]).unwrap();
        assert!(s.corrupt_row(&mut p, 0, 0, false));
        let mut dense = vec![0.0; 4 * 8];
        s.fill_dense(&p, 0, false, &mut dense).unwrap();
        assert!(dense[0].is_nan(), "0x7f dequantizes to NaN");
        s.release(&mut p);
    }

    #[test]
    fn fork_shares_then_copies_on_write() {
        let mut p = pool();
        let mut a = SeqCache::new(1);
        a.ensure_capacity(&mut p, 4).unwrap();
        let row = [7.0f32; 8];
        a.write_row(&mut p, 0, 0, &row, &row).unwrap();
        let used_before = p.used_pages();
        let mut b = a.fork(&mut p).unwrap();
        assert_eq!(p.used_pages(), used_before, "fork must not allocate");
        // Writing through the fork triggers CoW — the original is intact.
        let row2 = [9.0f32; 8];
        b.write_row(&mut p, 0, 1, &row2, &row2).unwrap();
        assert!(p.used_pages() > used_before);
        let mut da = vec![0.0; 4 * 8];
        a.fill_dense(&p, 0, false, &mut da).unwrap();
        assert_eq!(&da[8..16], &[0.0; 8], "original must not see fork's write");
        let mut db = vec![0.0; 4 * 8];
        b.len_tokens = 2;
        b.fill_dense(&p, 0, false, &mut db).unwrap();
        assert_eq!(&db[8..16], row2.as_slice());
        assert_eq!(&db[..8], row.as_slice(), "fork sees shared prefix");
        a.release(&mut p);
        b.release(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn fresh_pages_are_zeroed() {
        let mut p = pool();
        let mut s = SeqCache::new(1);
        s.ensure_capacity(&mut p, 4).unwrap();
        s.write_row(&mut p, 0, 0, &[5.0; 8], &[5.0; 8]).unwrap();
        s.release(&mut p);
        // Reallocate: the recycled page must read as zeros.
        let mut s2 = SeqCache::new(1);
        s2.ensure_capacity(&mut p, 4).unwrap();
        s2.len_tokens = 1;
        let mut dense = vec![1.0; 4 * 8];
        s2.fill_dense(&p, 0, false, &mut dense).unwrap();
        assert_eq!(&dense[..8], &[0.0; 8]);
        s2.release(&mut p);
    }

    #[test]
    fn fill_dense_rejects_short_buffer() {
        // Regression (PR 2): a dense buffer shorter than the valid paged
        // contents used to be silently truncated mid-copy; it must now be
        // a hard error that names the shortfall.
        let mut p = pool();
        let mut s = SeqCache::new(1);
        s.ensure_capacity(&mut p, 8).unwrap();
        for pos in 0..8 {
            let row = [pos as f32; 8];
            s.write_row(&mut p, 0, pos, &row, &row).unwrap();
        }
        // 5 rows of space for 8 valid rows: refused, buffer untouched
        // semantics aside (the error fires before any copy).
        let mut short = vec![9.0f32; 5 * 8];
        let err = s.fill_dense(&p, 0, false, &mut short).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("fill_dense"), "unhelpful error: {msg}");
        assert!(msg.contains("8 valid rows"), "unhelpful error: {msg}");
        // An exactly-sized buffer works.
        let mut exact = vec![0.0f32; 8 * 8];
        s.fill_dense(&p, 0, false, &mut exact).unwrap();
        assert_eq!(&exact[7 * 8..8 * 8], &[7.0f32; 8]);
        s.release(&mut p);
    }

    #[test]
    fn cow_write_on_exhausted_pool_errors_cleanly() {
        // Regression (PR 2): copy-on-write used to `.expect("CoW alloc")`
        // on pool exhaustion. It must return an error instead, leave the
        // shared page intact, and keep the page accounting consistent.
        let mut p = KvPool::new(2, 4, 8); // exactly K+V for one 1-layer seq
        let mut a = SeqCache::new(1);
        a.ensure_capacity(&mut p, 4).unwrap();
        let row = [3.0f32; 8];
        a.write_row(&mut p, 0, 0, &row, &row).unwrap();
        assert_eq!(p.free_pages(), 0);
        let mut b = a.fork(&mut p).unwrap(); // shares both pages, still 0 free
        let r = b.write_row(&mut p, 0, 1, &[4.0; 8], &[4.0; 8]);
        assert!(r.is_err(), "CoW on an exhausted pool must fail");
        let err = r.unwrap_err();
        // The engine's backpressure classifier must recognize exhaustion
        // even through the CoW context wrapping (pins the marker string).
        assert!(
            KvPool::is_exhausted_error(&err),
            "exhaustion not classified: {err}"
        );
        let msg = format!("{err}");
        assert!(msg.contains("copy-on-write"), "unhelpful error: {msg}");
        // The shared page must still be readable and unmodified.
        let mut dense = vec![0.0f32; 4 * 8];
        a.fill_dense(&p, 0, false, &mut dense).unwrap();
        assert_eq!(&dense[..8], &row);
        // No page leaked or double-freed by the failed write.
        assert_eq!(p.used_pages(), 2);
        b.release(&mut p);
        a.release(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn prepared_writes_match_the_exclusive_path() {
        // write_row_prepared must land the same bytes as write_row, and
        // prepare_step must privatize a forked page so the prepared write
        // is legal (and CoW-correct: the original stays intact).
        let mut p = pool();
        let mut a = SeqCache::new(2);
        a.ensure_capacity(&mut p, 4).unwrap();
        let row = [2.0f32; 8];
        a.write_row(&mut p, 0, 0, &row, &row).unwrap();
        let mut b = a.fork(&mut p).unwrap();
        b.prepare_step(&mut p, 1).unwrap();
        let row2 = [9.5f32; 8];
        b.write_row_prepared(&p, 0, 1, &row2, &row2);
        assert_eq!(b.len_tokens, 2);
        let mut db = vec![0.0f32; 4 * 8];
        b.fill_dense(&p, 0, false, &mut db).unwrap();
        assert_eq!(&db[..8], &row, "shared prefix preserved");
        assert_eq!(&db[8..16], &row2, "prepared write landed");
        let mut da = vec![0.0f32; 4 * 8];
        a.fill_dense(&p, 0, false, &mut da).unwrap();
        assert_eq!(&da[8..16], &[0.0; 8], "original must not see the write");
        // Equivalence: the same write through the exclusive path gives
        // bit-identical page contents.
        let mut c = SeqCache::new(2);
        c.ensure_capacity(&mut p, 4).unwrap();
        c.write_row(&mut p, 0, 1, &row2, &row2).unwrap();
        let mut dc = vec![0.0f32; 4 * 8];
        c.fill_dense(&p, 0, false, &mut dc).unwrap();
        assert_eq!(&dc[8..16], &db[8..16]);
        a.release(&mut p);
        b.release(&mut p);
        c.release(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    #[should_panic(expected = "write_row_prepared on a shared K page")]
    fn prepared_write_on_a_shared_page_panics() {
        // The refcount-1 assertion is the safety net under the parallel
        // decode path: skipping prepare_step must fail loudly.
        let mut p = pool();
        let mut a = SeqCache::new(1);
        a.ensure_capacity(&mut p, 4).unwrap();
        let mut b = a.fork(&mut p).unwrap(); // pages now shared (refcount 2)
        b.write_row_prepared(&p, 0, 0, &[1.0; 8], &[1.0; 8]);
    }

    #[test]
    fn paged_views_read_back_written_rows() {
        // The kv_views bridge: a paged attention view over this cache
        // gathers exactly the written rows, clamped to len_tokens.
        let mut p = pool();
        let mut s = SeqCache::new(2);
        s.ensure_capacity(&mut p, 7).unwrap();
        for pos in 0..7 {
            let krow: Vec<f32> = (0..8).map(|i| (pos * 10 + i) as f32).collect();
            let vrow: Vec<f32> = (0..8).map(|i| -((pos * 10 + i) as f32)).collect();
            s.write_row(&mut p, 1, pos, &krow, &vrow).unwrap();
        }
        let (kv, vv) = s.kv_views(&p, 1);
        assert_eq!(kv.rows(), 7);
        assert_eq!(kv.cols(), 8);
        let k = kv.to_matrix();
        let v = vv.to_matrix();
        assert_eq!(k.at(5, 3), 53.0);
        assert_eq!(v.at(6, 7), -67.0);
        // Block gather across a page boundary (4 tokens/page).
        let blk = kv.block(2, 6);
        assert_eq!(blk.shape(), (4, 8));
        assert_eq!(blk.at(0, 0), 20.0);
        assert_eq!(blk.at(3, 1), 51.0);
        // Column window = one "head" of the packed row.
        let kh = kv.col_window(4, 4);
        assert_eq!(kh.cols(), 4);
        assert_eq!(kh.to_matrix().at(5, 0), 54.0);
        s.release(&mut p);
    }

    fn pool_e4m3() -> KvPool {
        KvPool::new_with_store(64, 4, 8, KvStore::E4m3)
    }

    #[test]
    fn byte_budget_sizes_pages_by_store_format() {
        // Same byte budget, 4× the pages under E4M3 (1 B vs 4 B per elem)
        // — the fixed-pool-size residency comparison surface.
        let budget = 64 * 4 * 8 * 4; // 64 f32 pages of 4 tokens × width 8
        let pf = KvPool::with_byte_budget(budget, 4, 8, KvStore::F32);
        let pq = KvPool::with_byte_budget(budget, 4, 8, KvStore::E4m3);
        assert_eq!(pf.total_pages(), 64);
        assert_eq!(pq.total_pages(), 256);
        assert_eq!(pq.store(), KvStore::E4m3);
    }

    #[test]
    fn e4m3_pool_round_trips_grid_values_and_quantizes_the_rest() {
        use crate::numerics::round_f8e4m3;
        let mut p = pool_e4m3();
        let mut s = SeqCache::new(1);
        s.ensure_capacity(&mut p, 6).unwrap();
        // On-grid values survive exactly; off-grid values land on the
        // RTNE-rounded E4M3 neighbor; 448 is the format max.
        let krow = [0.0f32, 0.5, -2.0, 448.0, 1.1, -0.07, 300.0, 2e-9];
        let vrow = [1.0f32; 8];
        s.write_row(&mut p, 0, 5, &krow, &vrow).unwrap();
        let mut dense = vec![9.0f32; 16 * 8];
        s.fill_dense(&p, 0, false, &mut dense).unwrap();
        for (i, (&got, &x)) in dense[5 * 8..6 * 8].iter().zip(&krow).enumerate() {
            assert_eq!(
                got.to_bits(),
                round_f8e4m3(x).to_bits(),
                "elem {i}: wrote {x}, read {got}"
            );
        }
        assert_eq!(&dense[..8], &[0.0; 8], "fresh E4M3 rows decode to zeros");
        // The paged view gathers the same dequantized values.
        s.len_tokens = 6;
        let (kv, _vv) = s.kv_views(&p, 0);
        let k = kv.to_matrix();
        assert_eq!(k.at(5, 3), 448.0);
        assert_eq!(k.at(5, 4).to_bits(), round_f8e4m3(1.1).to_bits());
        // Column-window gather dequantizes the same bytes.
        let kh = kv.col_window(4, 4);
        assert_eq!(kh.to_matrix().at(5, 0).to_bits(), round_f8e4m3(1.1).to_bits());
        s.release(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn e4m3_cow_and_prepared_writes_match_the_exclusive_path() {
        // The fork/CoW and shared-reference write machinery must behave
        // identically under byte-backed storage: raw-byte CoW copies,
        // quantizing prepared writes, intact originals.
        let mut p = pool_e4m3();
        let mut a = SeqCache::new(1);
        a.ensure_capacity(&mut p, 4).unwrap();
        let row = [2.5f32; 8];
        a.write_row(&mut p, 0, 0, &row, &row).unwrap();
        let used_before = p.used_pages();
        let mut b = a.fork(&mut p).unwrap();
        assert_eq!(p.used_pages(), used_before, "fork must not allocate");
        b.prepare_step(&mut p, 1).unwrap();
        assert!(p.used_pages() > used_before, "prepare_step privatized CoW pages");
        let row2 = [1.1f32; 8];
        b.write_row_prepared(&p, 0, 1, &row2, &row2);
        let mut db = vec![0.0f32; 4 * 8];
        b.fill_dense(&p, 0, false, &mut db).unwrap();
        assert_eq!(&db[..8], &[2.5f32; 8], "shared prefix preserved bit-exactly");
        let q = crate::numerics::round_f8e4m3(1.1);
        assert_eq!(&db[8..16], &[q; 8], "prepared write quantized like write_row");
        let mut da = vec![0.0f32; 4 * 8];
        a.fill_dense(&p, 0, false, &mut da).unwrap();
        assert_eq!(&da[8..16], &[0.0; 8], "original must not see the fork's write");
        a.release(&mut p);
        b.release(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn refcount_saturation_is_backpressure_not_wraparound() {
        // Satellite bugfix: the unchecked `refcount += 1` would wrap at
        // u32::MAX in release builds, silently corrupting CoW ownership.
        // It must instead fail as the usual exhaustion-style Err.
        let mut p = pool();
        let mut a = SeqCache::new(1);
        a.ensure_capacity(&mut p, 4).unwrap();
        let kid = a.page_ids(0, false)[0];
        p.refcount[kid as usize] = u32::MAX - 1;
        p.retain(kid).unwrap(); // reaches the ceiling exactly
        assert_eq!(p.refcount[kid as usize], u32::MAX);
        let err = p.retain(kid).unwrap_err();
        assert!(
            KvPool::is_exhausted_error(&err),
            "saturation not classified as backpressure: {err}"
        );
        assert_eq!(
            p.refcount[kid as usize],
            u32::MAX,
            "a failed retain must not move the count"
        );
        // A fork over the saturated table rolls back cleanly: the K page
        // retain fails and no reference leaks anywhere.
        let vid = a.page_ids(0, true)[0];
        let v_before = p.refcount[vid as usize];
        let used = p.used_pages();
        assert!(a.fork(&mut p).is_err());
        assert_eq!(p.used_pages(), used);
        assert_eq!(p.refcount[vid as usize], v_before, "rollback released the V retain");
        // Unwind the synthetic references so the drain accounting holds.
        p.refcount[kid as usize] = 1;
        a.release(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn fork_prefix_shares_only_aligned_pages() {
        let mut p = pool(); // 4 tokens/page
        let mut a = SeqCache::new(2);
        a.ensure_capacity(&mut p, 10).unwrap(); // 3 pages per table
        for pos in 0..10 {
            let row = [pos as f32; 8];
            a.write_row(&mut p, 0, pos, &row, &row).unwrap();
            a.write_row(&mut p, 1, pos, &row, &row).unwrap();
        }
        let used = p.used_pages();
        // 10 tokens truncate down to the 8-token page boundary: 2 of the
        // 3 pages per table are shared; the partial tail page is not.
        let b = a.fork_prefix(&mut p, 10).unwrap();
        assert_eq!(b.len_tokens, 8);
        assert_eq!(b.total_pages_held(), 2 * 2 * 2);
        assert_eq!(p.used_pages(), used, "a prefix fork must not allocate");
        // The fork reads the shared prefix bit-exactly.
        let mut db = vec![0.0f32; 8 * 8];
        b.fill_dense(&p, 1, false, &mut db).unwrap();
        assert_eq!(&db[7 * 8..8 * 8], &[7.0f32; 8]);
        // Aligned cuts keep exactly the asked pages; oversized asks clamp
        // to the donor's own aligned length.
        let c = a.fork_prefix(&mut p, 4).unwrap();
        assert_eq!((c.len_tokens, c.total_pages_held()), (4, 2 * 2));
        let d = a.fork_prefix(&mut p, 64).unwrap();
        assert_eq!(d.len_tokens, 8, "clamps to the donor's aligned length");
        // A sub-page ask shares nothing at all.
        let e = a.fork_prefix(&mut p, 3).unwrap();
        assert_eq!((e.len_tokens, e.total_pages_held()), (0, 0));
        for mut s in [b, c, d, e] {
            s.release(&mut p);
        }
        a.release(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    #[should_panic(expected = "no raw f32 view")]
    fn e4m3_pages_refuse_the_raw_f32_view() {
        let mut p = pool_e4m3();
        let mut s = SeqCache::new(1);
        s.ensure_capacity(&mut p, 1).unwrap();
        let id = s.page_ids(0, false)[0];
        let _ = p.page_data(id);
    }
}
