//! Paged KV-cache manager (S11) — vLLM-style block allocator over host
//! memory.
//!
//! The decode HLO consumes dense (L, B, max_seq, W) cache tensors, but the
//! coordinator stores each request's KV in fixed-size *pages* (blocks of
//! `page_tokens` token-rows), so resident memory is proportional to the
//! tokens actually generated, admission is capacity-checked in pages, and
//! shared prompt prefixes can be forked copy-on-write at page granularity.
//! Dense tensors are assembled only at the batch boundary.

use anyhow::{bail, Result};

/// Identifier of one page in the pool arena.
pub type PageId = u32;

/// Fixed-capacity page pool. Each page holds `page_tokens` rows of
/// `row_width` f32 (one layer's K *or* V slice of those tokens).
pub struct KvPool {
    pub page_tokens: usize,
    pub row_width: usize,
    arena: Vec<f32>,
    refcount: Vec<u32>,
    free: Vec<PageId>,
    total_pages: usize,
}

impl KvPool {
    pub fn new(total_pages: usize, page_tokens: usize, row_width: usize) -> KvPool {
        KvPool {
            page_tokens,
            row_width,
            arena: vec![0.0; total_pages * page_tokens * row_width],
            refcount: vec![0; total_pages],
            free: (0..total_pages as PageId).rev().collect(),
            total_pages,
        }
    }

    pub fn page_floats(&self) -> usize {
        self.page_tokens * self.row_width
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free.len()
    }

    /// Utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        self.used_pages() as f64 / self.total_pages.max(1) as f64
    }

    fn alloc(&mut self) -> Result<PageId> {
        match self.free.pop() {
            Some(id) => {
                debug_assert_eq!(self.refcount[id as usize], 0);
                self.refcount[id as usize] = 1;
                // Fresh pages are zeroed: the PASA kernels' pseudo-average
                // must not see stale garbage in masked positions.
                let off = id as usize * self.page_floats();
                let pf = self.page_floats();
                self.arena[off..off + pf].fill(0.0);
                Ok(id)
            }
            None => bail!("KV pool exhausted ({} pages)", self.total_pages),
        }
    }

    fn retain(&mut self, id: PageId) {
        self.refcount[id as usize] += 1;
    }

    fn release(&mut self, id: PageId) {
        let rc = &mut self.refcount[id as usize];
        assert!(*rc > 0, "double free of page {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
        }
    }

    fn page(&self, id: PageId) -> &[f32] {
        let off = id as usize * self.page_floats();
        &self.arena[off..off + self.page_floats()]
    }

    fn page_mut(&mut self, id: PageId) -> &mut [f32] {
        let off = id as usize * self.page_floats();
        let pf = self.page_floats();
        &mut self.arena[off..off + pf]
    }
}

/// One sequence's paged cache: per layer, a page table for K and for V.
#[derive(Clone, Debug, Default)]
pub struct SeqCache {
    /// pages[layer] = (k_pages, v_pages).
    pages: Vec<(Vec<PageId>, Vec<PageId>)>,
    pub len_tokens: usize,
    n_layers: usize,
}

impl SeqCache {
    pub fn new(n_layers: usize) -> SeqCache {
        SeqCache {
            pages: vec![(Vec::new(), Vec::new()); n_layers],
            len_tokens: 0,
            n_layers,
        }
    }

    /// Pages needed (per layer, per K/V) for `tokens` rows.
    fn pages_for(tokens: usize, page_tokens: usize) -> usize {
        tokens.div_ceil(page_tokens)
    }

    /// Total pool pages this sequence would hold at `tokens` length.
    pub fn pages_required(n_layers: usize, tokens: usize, page_tokens: usize) -> usize {
        2 * n_layers * Self::pages_for(tokens, page_tokens)
    }

    /// Grow page tables to cover `tokens` rows, allocating from the pool.
    pub fn ensure_capacity(&mut self, pool: &mut KvPool, tokens: usize) -> Result<()> {
        let need = Self::pages_for(tokens, pool.page_tokens);
        // Pre-check so a mid-way failure doesn't leak a partial grow.
        let mut missing = 0usize;
        for (kp, vp) in &self.pages {
            missing += need.saturating_sub(kp.len()) + need.saturating_sub(vp.len());
        }
        if missing > pool.free_pages() {
            bail!(
                "KV pool exhausted: need {missing} pages, {} free",
                pool.free_pages()
            );
        }
        for (kp, vp) in &mut self.pages {
            while kp.len() < need {
                kp.push(pool.alloc()?);
            }
            while vp.len() < need {
                vp.push(pool.alloc()?);
            }
        }
        Ok(())
    }

    /// Copy-on-write fork (prefix sharing): pages are shared, refcounted.
    pub fn fork(&self, pool: &mut KvPool) -> SeqCache {
        let mut out = self.clone();
        for (kp, vp) in &mut out.pages {
            for id in kp.iter().chain(vp.iter()) {
                pool.retain(*id);
            }
        }
        out
    }

    fn ensure_private(pool: &mut KvPool, id: &mut PageId) {
        if pool.refcount[*id as usize] > 1 {
            let copy: Vec<f32> = pool.page(*id).to_vec();
            let fresh = pool.alloc().expect("CoW alloc");
            pool.page_mut(fresh).copy_from_slice(&copy);
            pool.release(*id);
            *id = fresh;
        }
    }

    /// Write one token's K and V rows for a layer at absolute position.
    pub fn write_row(
        &mut self,
        pool: &mut KvPool,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let w = pool.row_width;
        assert_eq!(k_row.len(), w);
        assert_eq!(v_row.len(), w);
        let (pg, off) = (pos / pool.page_tokens, pos % pool.page_tokens);
        let (kp, vp) = &mut self.pages[layer];
        let kid = &mut kp[pg];
        Self::ensure_private(pool, kid);
        let kid = *kid;
        pool.page_mut(kid)[off * w..(off + 1) * w].copy_from_slice(k_row);
        let vid = &mut vp[pg];
        Self::ensure_private(pool, vid);
        let vid = *vid;
        pool.page_mut(vid)[off * w..(off + 1) * w].copy_from_slice(v_row);
        self.len_tokens = self.len_tokens.max(pos + 1);
    }

    /// Assemble this sequence's K (or V) for `layer` into a dense
    /// (max_seq, W) slice; positions beyond len are zeroed.
    pub fn fill_dense(&self, pool: &KvPool, layer: usize, want_v: bool, out: &mut [f32]) {
        let w = pool.row_width;
        let pt = pool.page_tokens;
        out.fill(0.0);
        let (kp, vp) = &self.pages[layer];
        let pages = if want_v { vp } else { kp };
        let mut written = 0usize;
        for (pi, &id) in pages.iter().enumerate() {
            let rows = (self.len_tokens.saturating_sub(pi * pt)).min(pt);
            if rows == 0 {
                break;
            }
            let src = pool.page(id);
            let dst_off = pi * pt * w;
            if dst_off + rows * w > out.len() {
                break; // dense buffer shorter than paged capacity
            }
            out[dst_off..dst_off + rows * w].copy_from_slice(&src[..rows * w]);
            written += rows;
        }
        let _ = written;
    }

    /// Release all pages back to the pool.
    pub fn release(&mut self, pool: &mut KvPool) {
        for (kp, vp) in &mut self.pages {
            for id in kp.drain(..).chain(vp.drain(..)) {
                pool.release(id);
            }
        }
        self.len_tokens = 0;
    }

    pub fn total_pages_held(&self) -> usize {
        self.pages
            .iter()
            .map(|(k, v)| k.len() + v.len())
            .sum()
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> KvPool {
        KvPool::new(64, 4, 8) // 64 pages, 4 tokens/page, width 8
    }

    #[test]
    fn alloc_write_read_round_trip() {
        let mut p = pool();
        let mut s = SeqCache::new(2);
        s.ensure_capacity(&mut p, 6).unwrap();
        assert_eq!(s.total_pages_held(), 2 * 2 * 2); // 2 layers * K,V * 2 pages
        let krow: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        s.write_row(&mut p, 1, 5, &krow, &vrow);
        let mut dense = vec![1.0f32; 16 * 8];
        s.fill_dense(&p, 1, false, &mut dense);
        assert_eq!(&dense[5 * 8..6 * 8], krow.as_slice());
        assert_eq!(&dense[..8], &[0.0; 8]); // untouched rows zeroed
        s.fill_dense(&p, 1, true, &mut dense);
        assert_eq!(&dense[5 * 8..6 * 8], vrow.as_slice());
        s.release(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn capacity_exhaustion_is_clean() {
        let mut p = KvPool::new(7, 4, 8); // too few pages for 2 layers x 2
        let mut s = SeqCache::new(2);
        let r = s.ensure_capacity(&mut p, 5); // needs 2 pages x4 = 8 > 7
        assert!(r.is_err());
        // Failed ensure must not leak pages.
        assert_eq!(p.used_pages(), 0);
        s.release(&mut p);
    }

    #[test]
    fn fork_shares_then_copies_on_write() {
        let mut p = pool();
        let mut a = SeqCache::new(1);
        a.ensure_capacity(&mut p, 4).unwrap();
        let row = [7.0f32; 8];
        a.write_row(&mut p, 0, 0, &row, &row);
        let used_before = p.used_pages();
        let mut b = a.fork(&mut p);
        assert_eq!(p.used_pages(), used_before, "fork must not allocate");
        // Writing through the fork triggers CoW — the original is intact.
        let row2 = [9.0f32; 8];
        b.write_row(&mut p, 0, 1, &row2, &row2);
        assert!(p.used_pages() > used_before);
        let mut da = vec![0.0; 4 * 8];
        a.fill_dense(&p, 0, false, &mut da);
        assert_eq!(&da[8..16], &[0.0; 8], "original must not see fork's write");
        let mut db = vec![0.0; 4 * 8];
        b.len_tokens = 2;
        b.fill_dense(&p, 0, false, &mut db);
        assert_eq!(&db[8..16], row2.as_slice());
        assert_eq!(&db[..8], row.as_slice(), "fork sees shared prefix");
        a.release(&mut p);
        b.release(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn fresh_pages_are_zeroed() {
        let mut p = pool();
        let mut s = SeqCache::new(1);
        s.ensure_capacity(&mut p, 4).unwrap();
        s.write_row(&mut p, 0, 0, &[5.0; 8], &[5.0; 8]);
        s.release(&mut p);
        // Reallocate: the recycled page must read as zeros.
        let mut s2 = SeqCache::new(1);
        s2.ensure_capacity(&mut p, 4).unwrap();
        s2.len_tokens = 1;
        let mut dense = vec![1.0; 4 * 8];
        s2.fill_dense(&p, 0, false, &mut dense);
        assert_eq!(&dense[..8], &[0.0; 8]);
        s2.release(&mut p);
    }
}
