//! Radix prefix cache over prompt token IDs (S12): shared-prefix KV
//! reuse for the millions-of-users system-prompt workload.
//!
//! The tree is **page-granular**: every node's edge label is exactly one
//! full page of token ids (`page_tokens` of them), because a whole page
//! is the smallest unit the CoW pool can share — a partially matching
//! page would mix two prompts' rows in one refcounted unit. A node owns
//! the page run that stores its tokens' K/V rows: one `(K, V)`
//! [`PageId`] pair per layer, each holding a pool reference
//! ([`KvPool::retain`]) for as long as the node lives. Children extend
//! the prefix by one more page, so a root-to-node path spells a
//! page-aligned prompt prefix and the pages along it are exactly the
//! cached KV for that prefix.
//!
//! * **Match rule**: a prompt matches the longest root path whose
//!   concatenated edge labels are a prefix of the prompt — always a
//!   multiple of `page_tokens`. Anything past the last full page is
//!   re-prefilled by the consumer (and the engine additionally caps
//!   reuse at `prompt_len − 1`, because the final prompt row must be
//!   prefilled to produce first-token logits).
//! * **Insert** ([`PrefixCache::insert`]) walks an admitted request's
//!   finalized prompt pages into the tree after its prefill completes,
//!   retaining the pages straight out of the request's own `SeqCache` —
//!   no copy, the cache and the request *share* the pages from that
//!   moment on. Insertion is best-effort: a refcount saturation stops it
//!   without failing the request.
//! * **Seed** ([`PrefixCache::seed`]) builds a [`SeqCache`] whose
//!   leading pages are the matched nodes' pages (retained again, once
//!   per consumer), so the consumer skips that prefix of chunked
//!   prefill entirely. Copy-on-write isolates any later write.
//! * **Eviction**: least-recently-used **leaves** first (`last_used`
//!   stamps from a monotone use-clock — no wall time, so replays are
//!   deterministic), either to honor the configured page budget after
//!   an insert or on demand when the engine needs free pages
//!   ([`PrefixCache::evict_for`]). Releasing a node's references only
//!   returns pages to the free list once no live sequence shares them.
//!
//! Determinism: the cache stores bytes the donor's prefill wrote and
//! hands them out bit-identically; a consumer's stream equals its
//! cache-off run because chunked prefill is boundary-invariant and the
//! shared rows are exactly what its own prefill would have produced.
//! Nothing here consumes randomness or clocks.

use super::kv_cache::{KvPool, PageId, SeqCache};
use anyhow::Result;

/// Outcome of an admission-time probe: how much of a prompt the radix
/// tree already holds. Matched exhaustively in the engine (pasa-lint
/// protects this enum from wildcard arms): a new decision kind must be
/// handled at every dispatch site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefixDecision {
    /// No cached page covers this prompt.
    Miss,
    /// The first `tokens` prompt tokens (a multiple of `page_tokens`)
    /// are resident in shared pages: admission may charge their pages
    /// once (they are already held) and skip their prefill.
    Hit { tokens: usize },
}

/// One radix node: a page worth of token ids and the page run storing
/// their K/V rows.
struct Node {
    /// Edge label — exactly `page_tokens` token ids.
    tokens: Vec<u32>,
    /// The owned page run: one (K, V) page pair per layer.
    pages: Vec<(PageId, PageId)>,
    /// Use-clock stamp of the last probe/seed/insert touching this node.
    last_used: u64,
    children: Vec<Node>,
}

/// The radix prefix cache (see module docs).
pub struct PrefixCache {
    page_tokens: usize,
    n_layers: usize,
    /// Page-reference budget: eviction trims the tree back to this many
    /// held references after inserts.
    max_pages: usize,
    /// Monotone use-clock for LRU stamps (never wall time).
    clock: u64,
    /// Page references currently held by the tree (2 × n_layers per node).
    pages_held: usize,
    /// First-page nodes (the root itself holds no pages).
    roots: Vec<Node>,
}

impl PrefixCache {
    /// `max_pages` caps how many pool-page references the tree may hold;
    /// inserts beyond it evict cold leaves first.
    pub fn new(page_tokens: usize, n_layers: usize, max_pages: usize) -> PrefixCache {
        PrefixCache {
            page_tokens: page_tokens.max(1),
            n_layers,
            max_pages,
            clock: 0,
            pages_held: 0,
            roots: Vec::new(),
        }
    }

    /// Page references the tree currently holds.
    pub fn pages_held(&self) -> usize {
        self.pages_held
    }

    /// Longest cached prefix of `prompt`, capped at `cap_tokens`
    /// (both truncated down to page alignment). Read-only — the LRU
    /// stamps move when the match is actually consumed ([`Self::seed`]).
    pub fn probe(&self, prompt: &[u32], cap_tokens: usize) -> PrefixDecision {
        let pt = self.page_tokens;
        let want = (cap_tokens.min(prompt.len()) / pt) * pt;
        let mut matched = 0usize;
        let mut level = &self.roots;
        while matched + pt <= want {
            let toks = &prompt[matched..matched + pt];
            let Some(node) = level.iter().find(|n| n.tokens[..] == *toks) else {
                break;
            };
            matched += pt;
            level = &node.children;
        }
        if matched == 0 {
            PrefixDecision::Miss
        } else {
            PrefixDecision::Hit { tokens: matched }
        }
    }

    /// Build a [`SeqCache`] seeded with the cached pages covering the
    /// first `tokens` tokens of `prompt` (page-aligned; normally the
    /// `tokens` of a [`PrefixDecision::Hit`]). Stamps the matched path
    /// as recently used. The result's `len_tokens` is the tokens
    /// actually covered — it can fall short of the ask if the tree
    /// changed since the probe, so callers must trust `len_tokens`, not
    /// the ask. Fails (rolled back, nothing retained) only on refcount
    /// saturation.
    pub fn seed(&mut self, pool: &mut KvPool, prompt: &[u32], tokens: usize) -> Result<SeqCache> {
        let pt = self.page_tokens;
        let want = (tokens.min(prompt.len()) / pt) * pt;
        self.clock += 1;
        let stamp = self.clock;
        let mut pairs: Vec<Vec<(PageId, PageId)>> = Vec::new();
        let mut level = &mut self.roots;
        while (pairs.len() + 1) * pt <= want {
            let lo = pairs.len() * pt;
            let toks = &prompt[lo..lo + pt];
            let cur = level;
            let Some(i) = cur.iter().position(|n| n.tokens[..] == *toks) else {
                break;
            };
            cur[i].last_used = stamp;
            pairs.push(cur[i].pages.clone());
            level = &mut cur[i].children;
        }
        SeqCache::from_shared_pages(pool, self.n_layers, &pairs)
    }

    /// Insert the page-aligned prefix of `prompt` whose rows `cache`
    /// holds finalized (a completed prefill), sharing the pages — no
    /// copy. Returns the tokens *newly* cached (already-present pages
    /// re-stamp as used and cost nothing). Best-effort: a refcount
    /// saturation stops the walk early instead of failing, and the
    /// budget is enforced afterwards by [`Self::enforce_budget`].
    pub fn insert(&mut self, pool: &mut KvPool, prompt: &[u32], cache: &SeqCache) -> usize {
        let pt = self.page_tokens;
        let aligned = (prompt.len().min(cache.len_tokens) / pt) * pt;
        self.clock += 1;
        let stamp = self.clock;
        let n_layers = self.n_layers;
        let mut added = 0usize;
        let mut pi = 0usize;
        let mut level = &mut self.roots;
        while (pi + 1) * pt <= aligned {
            let toks = &prompt[pi * pt..(pi + 1) * pt];
            let cur = level;
            let idx = match cur.iter().position(|n| n.tokens[..] == *toks) {
                Some(i) => {
                    cur[i].last_used = stamp;
                    i
                }
                None => {
                    let mut pages = Vec::with_capacity(n_layers);
                    let mut ok = true;
                    for li in 0..n_layers {
                        let k = cache.page_ids(li, false)[pi];
                        let v = cache.page_ids(li, true)[pi];
                        if pool.retain(k).is_err() {
                            ok = false;
                            break;
                        }
                        if pool.retain(v).is_err() {
                            pool.release(k);
                            ok = false;
                            break;
                        }
                        pages.push((k, v));
                    }
                    if !ok {
                        for (k, v) in pages {
                            pool.release(k);
                            pool.release(v);
                        }
                        return added;
                    }
                    self.pages_held += 2 * n_layers;
                    added += pt;
                    cur.push(Node {
                        tokens: toks.to_vec(),
                        pages,
                        last_used: stamp,
                        children: Vec::new(),
                    });
                    cur.len() - 1
                }
            };
            pi += 1;
            level = &mut cur[idx].children;
        }
        added
    }

    /// Trim the tree back to its page budget, evicting least-recently
    /// used leaves first. Returns page references released.
    pub fn enforce_budget(&mut self, pool: &mut KvPool) -> usize {
        let mut freed = 0usize;
        while self.pages_held > self.max_pages && self.evict_lru_leaf(pool) {
            freed += 2 * self.n_layers;
        }
        freed
    }

    /// Pool-pressure eviction: drop cold leaves until the pool shows at
    /// least `need_free` free pages or the tree is empty. A released
    /// reference only frees the page once no live sequence shares it, so
    /// the loop also stops when eviction stops helping. Returns page
    /// references released.
    pub fn evict_for(&mut self, pool: &mut KvPool, need_free: usize) -> usize {
        let mut freed = 0usize;
        while pool.free_pages() < need_free && self.evict_lru_leaf(pool) {
            freed += 2 * self.n_layers;
        }
        freed
    }

    /// Release every cached page reference (engine shutdown / drain
    /// accounting). Returns page references released.
    pub fn flush(&mut self, pool: &mut KvPool) -> usize {
        fn drop_all(nodes: &mut Vec<Node>, pool: &mut KvPool) {
            for mut n in nodes.drain(..) {
                for (k, v) in n.pages.drain(..) {
                    pool.release(k);
                    pool.release(v);
                }
                drop_all(&mut n.children, pool);
            }
        }
        let freed = self.pages_held;
        drop_all(&mut self.roots, pool);
        self.pages_held = 0;
        freed
    }

    /// Evict the least-recently-used leaf (only leaves are evictable:
    /// removing an interior node would orphan the deeper prefixes whose
    /// meaning depends on the full path). Returns whether a leaf fell.
    fn evict_lru_leaf(&mut self, pool: &mut KvPool) -> bool {
        let Some(stamp) = Self::min_leaf_stamp(&self.roots) else {
            return false;
        };
        if Self::remove_leaf_with(&mut self.roots, stamp, pool) {
            self.pages_held -= 2 * self.n_layers;
            true
        } else {
            false
        }
    }

    fn min_leaf_stamp(nodes: &[Node]) -> Option<u64> {
        let mut best: Option<u64> = None;
        for n in nodes {
            let s = if n.children.is_empty() {
                Some(n.last_used)
            } else {
                Self::min_leaf_stamp(&n.children)
            };
            best = match (best, s) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
        }
        best
    }

    fn remove_leaf_with(nodes: &mut Vec<Node>, stamp: u64, pool: &mut KvPool) -> bool {
        for i in 0..nodes.len() {
            if nodes[i].children.is_empty() {
                if nodes[i].last_used == stamp {
                    let mut n = nodes.remove(i);
                    for (k, v) in n.pages.drain(..) {
                        pool.release(k);
                        pool.release(v);
                    }
                    return true;
                }
            } else if Self::remove_leaf_with(&mut nodes[i].children, stamp, pool) {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PT: usize = 4;
    const LAYERS: usize = 2;

    fn pool() -> KvPool {
        KvPool::new(64, PT, 8)
    }

    /// Prefill a donor cache over `prompt` with per-position marker rows.
    fn donor(p: &mut KvPool, prompt: &[u32]) -> SeqCache {
        let mut c = SeqCache::new(LAYERS);
        c.ensure_capacity(p, prompt.len()).unwrap();
        for (pos, &t) in prompt.iter().enumerate() {
            let row = [t as f32 + pos as f32 / 100.0; 8];
            for l in 0..LAYERS {
                c.write_row(p, l, pos, &row, &row).unwrap();
            }
        }
        c
    }

    fn prompt(prefix: &[u32], suffix: &[u32]) -> Vec<u32> {
        let mut v = prefix.to_vec();
        v.extend_from_slice(suffix);
        v
    }

    #[test]
    fn insert_then_probe_matches_page_aligned_prefix() {
        let mut p = pool();
        let mut pc = PrefixCache::new(PT, LAYERS, 1024);
        let shared: Vec<u32> = (100..108).collect(); // 2 full pages
        let a = prompt(&shared, &[1, 2, 3]);
        let ca = donor(&mut p, &a);
        let used = p.used_pages();
        // 11 tokens insert their 2 aligned pages; the partial tail stays
        // private to the donor.
        assert_eq!(pc.insert(&mut p, &a, &ca), 8);
        assert_eq!(pc.pages_held(), 2 * 2 * LAYERS);
        assert_eq!(p.used_pages(), used, "insert shares, never allocates");
        // A prompt sharing both pages, then diverging.
        let b = prompt(&shared, &[9, 9, 9, 9]);
        assert_eq!(pc.probe(&b, b.len()), PrefixDecision::Hit { tokens: 8 });
        // A prompt diverging inside page 2 only matches page 1.
        let c = prompt(&shared[..5], &[7, 7, 7]);
        assert_eq!(pc.probe(&c, c.len()), PrefixDecision::Hit { tokens: 4 });
        // The cap truncates down to page alignment.
        assert_eq!(pc.probe(&b, 7), PrefixDecision::Hit { tokens: 4 });
        assert_eq!(pc.probe(&b, 3), PrefixDecision::Miss);
        // An unrelated prompt misses.
        let d: Vec<u32> = (200..212).collect();
        assert_eq!(pc.probe(&d, d.len()), PrefixDecision::Miss);
        // Re-inserting the same prompt adds nothing new.
        assert_eq!(pc.insert(&mut p, &a, &ca), 0);
        let mut ca = ca;
        ca.release(&mut p);
        assert_eq!(pc.flush(&mut p), 2 * 2 * LAYERS);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn seed_shares_pages_and_reads_donor_rows_bit_exactly() {
        let mut p = pool();
        let mut pc = PrefixCache::new(PT, LAYERS, 1024);
        let a: Vec<u32> = (10..22).collect(); // 3 full pages
        let mut ca = donor(&mut p, &a);
        pc.insert(&mut p, &a, &ca);
        let used = p.used_pages();
        let hit = match pc.probe(&a, a.len() - 1) {
            PrefixDecision::Hit { tokens } => tokens,
            PrefixDecision::Miss => panic!("expected a hit"),
        };
        assert_eq!(hit, 8, "cap at prompt_len - 1 truncates to 2 pages");
        let mut s = pc.seed(&mut p, &a, hit).unwrap();
        assert_eq!(s.len_tokens, 8);
        assert_eq!(p.used_pages(), used, "seeding shares, never allocates");
        // The seeded cache reads exactly the donor's rows.
        let mut want = vec![0.0f32; 12 * 8];
        ca.fill_dense(&p, 1, false, &mut want).unwrap();
        let mut got = vec![0.0f32; 8 * 8];
        s.fill_dense(&p, 1, false, &mut got).unwrap();
        assert_eq!(&got[..], &want[..8 * 8]);
        // Donor release keeps the cached pages resident (tree still
        // holds references); consumer release too; flush drains fully.
        ca.release(&mut p);
        s.release(&mut p);
        assert!(p.used_pages() > 0);
        pc.flush(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn lru_eviction_drops_cold_leaves_first() {
        let mut p = pool();
        // Budget of one node's pages: 2 layers × (K, V) = 4.
        let mut pc = PrefixCache::new(PT, LAYERS, 2 * LAYERS);
        let a: Vec<u32> = (10..14).collect();
        let b: Vec<u32> = (20..24).collect();
        let mut ca = donor(&mut p, &a);
        let mut cb = donor(&mut p, &b);
        assert_eq!(pc.insert(&mut p, &a, &ca), 4);
        assert_eq!(pc.insert(&mut p, &b, &cb), 4);
        assert_eq!(pc.pages_held(), 4 * LAYERS, "over budget until enforced");
        let freed = pc.enforce_budget(&mut p);
        assert_eq!(freed, 2 * LAYERS);
        // `a` was colder (b's insert stamped later): a is gone, b stays.
        assert_eq!(pc.probe(&a, 4), PrefixDecision::Miss);
        assert_eq!(pc.probe(&b, 4), PrefixDecision::Hit { tokens: 4 });
        ca.release(&mut p);
        cb.release(&mut p);
        // Pool-pressure eviction drops the rest on demand.
        let total = p.total_pages();
        let freed = pc.evict_for(&mut p, total);
        assert_eq!(freed, 2 * LAYERS);
        assert_eq!(pc.pages_held(), 0);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn seeding_bumps_lru_so_hot_prefixes_survive() {
        let mut p = pool();
        let mut pc = PrefixCache::new(PT, LAYERS, 2 * LAYERS);
        let a: Vec<u32> = (10..14).collect();
        let b: Vec<u32> = (20..24).collect();
        let mut ca = donor(&mut p, &a);
        let mut cb = donor(&mut p, &b);
        pc.insert(&mut p, &a, &ca);
        pc.insert(&mut p, &b, &cb);
        // Touch `a` after b's insert: now b is the cold one.
        let mut s = pc.seed(&mut p, &a, 4).unwrap();
        pc.enforce_budget(&mut p);
        assert_eq!(pc.probe(&a, 4), PrefixDecision::Hit { tokens: 4 });
        assert_eq!(pc.probe(&b, 4), PrefixDecision::Miss);
        s.release(&mut p);
        ca.release(&mut p);
        cb.release(&mut p);
        pc.flush(&mut p);
        assert_eq!(p.used_pages(), 0);
    }

    #[test]
    fn deep_paths_evict_leaf_before_parent() {
        let mut p = pool();
        let mut pc = PrefixCache::new(PT, LAYERS, 1024);
        let a: Vec<u32> = (10..22).collect(); // 3 pages → a 3-deep path
        let mut ca = donor(&mut p, &a);
        pc.insert(&mut p, &a, &ca);
        ca.release(&mut p);
        // Evict everything on demand: leaves must fall deepest-first (an
        // interior eviction would orphan the deeper prefix meaning).
        let total = p.total_pages();
        let freed = pc.evict_for(&mut p, total);
        assert_eq!(freed, 3 * 2 * LAYERS);
        assert_eq!(pc.pages_held(), 0);
        assert_eq!(p.used_pages(), 0);
    }
}
