//! Request router (S11): admission control + priority/FCFS queueing.

use super::request::{Request, RequestId};
#[cfg(test)]
use super::request::Priority;
use std::collections::VecDeque;

/// Admission verdict.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Admission {
    Queued,
    /// Rejected with a reason (e.g. prompt longer than the prefill bucket).
    Rejected(String),
}

/// Priority router: three FCFS lanes drained highest-priority-first.
/// Backpressure: a configurable max queue depth rejects excess load
/// instead of buffering unboundedly.
pub struct Router {
    lanes: [VecDeque<Request>; 3],
    pub max_depth: usize,
    pub max_prompt_bytes: usize,
    next_id: RequestId,
    total_admitted: u64,
    total_rejected: u64,
}

impl Router {
    pub fn new(max_depth: usize, max_prompt_bytes: usize) -> Router {
        Router {
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            max_depth,
            max_prompt_bytes,
            next_id: 1,
            total_admitted: 0,
            total_rejected: 0,
        }
    }

    pub fn fresh_id(&mut self) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Admit or reject a request.
    pub fn submit(&mut self, req: Request) -> Admission {
        if req.prompt.len() > self.max_prompt_bytes {
            self.total_rejected += 1;
            return Admission::Rejected(format!(
                "prompt {}B exceeds {}B",
                req.prompt.len(),
                self.max_prompt_bytes
            ));
        }
        if self.depth() >= self.max_depth {
            self.total_rejected += 1;
            return Admission::Rejected("queue full".into());
        }
        let lane = req.priority as usize;
        self.lanes[lane].push_back(req);
        self.total_admitted += 1;
        Admission::Queued
    }

    /// Next request: highest priority lane first, FCFS within a lane.
    pub fn pop(&mut self) -> Option<Request> {
        for lane in (0..3).rev() {
            if let Some(r) = self.lanes[lane].pop_front() {
                return Some(r);
            }
        }
        None
    }

    pub fn depth(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.depth() == 0
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.total_admitted, self.total_rejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(router: &mut Router, p: Priority) -> Request {
        let id = router.fresh_id();
        Request::new(id, "hi").with_priority(p)
    }

    #[test]
    fn priority_order_then_fcfs() {
        let mut r = Router::new(16, 1024);
        let a = req(&mut r, Priority::Normal);
        let b = req(&mut r, Priority::Interactive);
        let c = req(&mut r, Priority::Normal);
        let d = req(&mut r, Priority::Batch);
        let (ia, ib, ic, id) = (a.id, b.id, c.id, d.id);
        for x in [a, b, c, d] {
            assert_eq!(r.submit(x), Admission::Queued);
        }
        assert_eq!(r.pop().unwrap().id, ib); // interactive first
        assert_eq!(r.pop().unwrap().id, ia); // then FCFS normals
        assert_eq!(r.pop().unwrap().id, ic);
        assert_eq!(r.pop().unwrap().id, id); // batch last
        assert!(r.pop().is_none());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut r = Router::new(2, 1024);
        for _ in 0..2 {
            let x = req(&mut r, Priority::Normal);
            assert_eq!(r.submit(x), Admission::Queued);
        }
        let x = req(&mut r, Priority::Normal);
        assert!(matches!(r.submit(x), Admission::Rejected(_)));
        assert_eq!(r.stats(), (2, 1));
    }

    #[test]
    fn oversized_prompt_rejected() {
        let mut r = Router::new(4, 8);
        let id = r.fresh_id();
        let x = Request::new(id, "a very long prompt indeed");
        assert!(matches!(r.submit(x), Admission::Rejected(_)));
    }
}
