//! Request router (S11): admission control + priority/FCFS queueing with
//! a bounded-starvation guarantee.
//!
//! Admission rejects on **tokenized** prompt length (`Request::
//! prompt_tokens`) — the same currency the scheduler budgets in — never
//! on `prompt.len()` bytes (a multi-byte character is several tokens;
//! the old byte check both over-rejected multi-byte prompts and measured
//! a different quantity than the prefill budget spends).
//!
//! Starvation bound: each lane tracks how many times a higher-priority
//! pop has bypassed its head. Once a head has been bypassed `max_bypass`
//! times it becomes the next pop regardless of priority — so under a
//! sustained interactive flood, batch work is served after a bounded
//! number of bypasses instead of never. `max_bypass = usize::MAX`
//! (the constructor default) restores strict priority order; the engine
//! derives a finite bound from its `waiting_served_ratio` knob. The
//! choice is a pure function of queue state — no clocks, no RNG — so
//! trace replays are deterministic.

use super::request::{Request, RequestId};
#[cfg(test)]
use super::request::Priority;
use std::collections::VecDeque;

/// Admission verdict.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Admission {
    Queued,
    /// Rejected with a reason (e.g. prompt longer than the context).
    Rejected(String),
}

/// Priority router: three FCFS lanes drained highest-priority-first,
/// subject to the per-lane bypass bound above. Backpressure: a
/// configurable max queue depth rejects excess load instead of buffering
/// unboundedly.
pub struct Router {
    lanes: [VecDeque<Request>; 3],
    pub max_depth: usize,
    /// Admission limit in prompt **tokens** (typically the model's
    /// `max_seq` — with chunked prefill, any prompt that fits the
    /// context is servable).
    pub max_prompt_tokens: usize,
    /// How many times a lane head may be bypassed by higher-priority
    /// pops before it is force-served. `usize::MAX` = strict priority.
    pub max_bypass: usize,
    /// Bypass count of each lane's current head.
    bypass: [usize; 3],
    next_id: RequestId,
    total_admitted: u64,
    total_rejected: u64,
}

impl Router {
    pub fn new(max_depth: usize, max_prompt_tokens: usize) -> Router {
        Router {
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            max_depth,
            max_prompt_tokens,
            max_bypass: usize::MAX,
            bypass: [0; 3],
            next_id: 1,
            total_admitted: 0,
            total_rejected: 0,
        }
    }

    pub fn fresh_id(&mut self) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Admit or reject a request.
    pub fn submit(&mut self, req: Request) -> Admission {
        if req.prompt_tokens > self.max_prompt_tokens {
            self.total_rejected += 1;
            return Admission::Rejected(format!(
                "prompt {} tokens exceeds {} token limit",
                req.prompt_tokens, self.max_prompt_tokens
            ));
        }
        if self.depth() >= self.max_depth {
            self.total_rejected += 1;
            return Admission::Rejected("queue full".into());
        }
        let lane = req.priority as usize;
        self.lanes[lane].push_back(req);
        self.total_admitted += 1;
        Admission::Queued
    }

    /// The lane the next pop will drain: a starved lane first (lowest
    /// priority wins among starved — it has waited through the most
    /// bypasses), otherwise the highest-priority non-empty lane.
    fn next_lane(&self) -> Option<usize> {
        if let Some(l) = (0..3)
            .find(|&l| !self.lanes[l].is_empty() && self.bypass[l] >= self.max_bypass)
        {
            return Some(l);
        }
        (0..3).rev().find(|&l| !self.lanes[l].is_empty())
    }

    /// The request the next `pop` would return, without consuming it or
    /// touching the bypass counters — what the scheduler inspects when
    /// deciding whether the batch has budget for another admission.
    pub fn peek(&self) -> Option<&Request> {
        self.lanes[self.next_lane()?].front()
    }

    /// Next request under the bounded-starvation priority order (see
    /// module docs). Every lower-priority non-empty lane this pop skips
    /// records one bypass against its head.
    pub fn pop(&mut self) -> Option<Request> {
        let lane = self.next_lane()?;
        let r = self.lanes[lane].pop_front();
        self.bypass[lane] = 0;
        for l in 0..lane {
            if !self.lanes[l].is_empty() {
                self.bypass[l] = self.bypass[l].saturating_add(1);
            }
        }
        r
    }

    /// Remove a queued request by id (any lane, any position) — the
    /// cancellation path. Returns the request if it was still queued.
    /// Removing a lane's head resets that lane's bypass counter: the
    /// starvation bound is a property of a *specific* waiting head, not
    /// of the lane itself.
    pub fn remove(&mut self, id: RequestId) -> Option<Request> {
        for l in 0..3 {
            if let Some(pos) = self.lanes[l].iter().position(|r| r.id == id) {
                let removed = self.lanes[l].remove(pos);
                if pos == 0 {
                    self.bypass[l] = 0;
                }
                return removed;
            }
        }
        None
    }

    /// Drain every queued request matching `pred`, highest lane first,
    /// FCFS within a lane — the deadline sweep. Any lane whose head is
    /// removed has its bypass counter reset (same argument as
    /// [`Router::remove`]).
    pub fn drain_where<F: FnMut(&Request) -> bool>(&mut self, mut pred: F) -> Vec<Request> {
        let mut out = Vec::new();
        for l in (0..3).rev() {
            let mut kept = VecDeque::new();
            let mut head_removed = false;
            for (i, r) in self.lanes[l].drain(..).enumerate() {
                if pred(&r) {
                    if i == 0 {
                        head_removed = true;
                    }
                    out.push(r);
                } else {
                    kept.push_back(r);
                }
            }
            self.lanes[l] = kept;
            if head_removed {
                self.bypass[l] = 0;
            }
        }
        out
    }

    /// Shed one request under queue-depth pressure: the **newest**
    /// request of the **lowest-priority** non-empty lane — the work
    /// that would have been served last anyway, so shedding it forfeits
    /// the least finished progress. Resets the lane's bypass counter
    /// only when the shed entry was also the head (single-entry lane).
    pub fn shed_lowest_newest(&mut self) -> Option<Request> {
        let lane = (0..3).find(|&l| !self.lanes[l].is_empty())?;
        let shed = self.lanes[lane].pop_back();
        if self.lanes[lane].is_empty() {
            self.bypass[lane] = 0;
        }
        shed
    }

    pub fn depth(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.depth() == 0
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.total_admitted, self.total_rejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(router: &mut Router, p: Priority) -> Request {
        let id = router.fresh_id();
        Request::new(id, "hi").with_priority(p)
    }

    #[test]
    fn priority_order_then_fcfs() {
        let mut r = Router::new(16, 1024);
        let a = req(&mut r, Priority::Normal);
        let b = req(&mut r, Priority::Interactive);
        let c = req(&mut r, Priority::Normal);
        let d = req(&mut r, Priority::Batch);
        let (ia, ib, ic, id) = (a.id, b.id, c.id, d.id);
        for x in [a, b, c, d] {
            assert_eq!(r.submit(x), Admission::Queued);
        }
        assert_eq!(r.pop().unwrap().id, ib); // interactive first
        assert_eq!(r.pop().unwrap().id, ia); // then FCFS normals
        assert_eq!(r.pop().unwrap().id, ic);
        assert_eq!(r.pop().unwrap().id, id); // batch last
        assert!(r.pop().is_none());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut r = Router::new(2, 1024);
        for _ in 0..2 {
            let x = req(&mut r, Priority::Normal);
            assert_eq!(r.submit(x), Admission::Queued);
        }
        let x = req(&mut r, Priority::Normal);
        assert!(matches!(r.submit(x), Admission::Rejected(_)));
        assert_eq!(r.stats(), (2, 1));
    }

    #[test]
    fn oversized_prompt_rejected_in_tokens() {
        // 8-token limit; "a very long prompt indeed" is 25 bytes = 26 tokens.
        let mut r = Router::new(4, 8);
        let id = r.fresh_id();
        let x = Request::new(id, "a very long prompt indeed");
        match r.submit(x) {
            Admission::Rejected(msg) => assert!(msg.contains("token"), "{msg}"),
            a => panic!("expected rejection, got {a:?}"),
        }
    }

    #[test]
    fn multibyte_prompt_admitted_on_token_count_not_bytes() {
        // The regression the byte-based check failed: a 40-char multi-byte
        // prompt is 80 bytes but 81 tokens. Under the old rule (derived
        // from prefill_seq * 4 = 64 *bytes* for a prefill_seq-16 model) it
        // was rejected; under the token rule with a 96-token context it is
        // admissible — and the chunked-prefill engine really can serve it.
        let prompt = "é".repeat(40);
        assert_eq!(prompt.len(), 80); // bytes — what the old check saw
        let old_byte_limit = 16 * 4;
        assert!(prompt.len() > old_byte_limit, "premise of the regression");
        let mut r = Router::new(4, 96);
        let id = r.fresh_id();
        assert_eq!(r.submit(Request::new(id, prompt)), Admission::Queued);
    }

    #[test]
    fn peek_agrees_with_pop() {
        let mut r = Router::new(16, 1024);
        for p in [Priority::Batch, Priority::Interactive, Priority::Normal] {
            let x = req(&mut r, p);
            r.submit(x);
        }
        while let Some(expect) = r.peek().map(|q| q.id) {
            assert_eq!(r.pop().unwrap().id, expect);
        }
        assert!(r.pop().is_none());
    }

    #[test]
    fn starvation_bound_forces_low_priority_through_a_flood() {
        // One batch request queued behind a continuous interactive supply:
        // with max_bypass = 3 it must surface after exactly 3 bypasses,
        // no matter how many interactive requests keep arriving.
        let mut r = Router::new(64, 1024);
        r.max_bypass = 3;
        let b = req(&mut r, Priority::Batch);
        let batch_id = b.id;
        r.submit(b);
        let mut served_before_batch = 0;
        for _ in 0..16 {
            let x = req(&mut r, Priority::Interactive);
            r.submit(x);
            let popped = r.pop().unwrap();
            if popped.id == batch_id {
                break;
            }
            served_before_batch += 1;
        }
        assert_eq!(served_before_batch, 3, "batch head must pop after max_bypass bypasses");
    }

    #[test]
    fn remove_pulls_a_queued_request_from_any_lane_position() {
        let mut r = Router::new(16, 1024);
        let a = req(&mut r, Priority::Normal);
        let b = req(&mut r, Priority::Normal);
        let c = req(&mut r, Priority::Interactive);
        let (ia, ib, ic) = (a.id, b.id, c.id);
        for x in [a, b, c] {
            r.submit(x);
        }
        assert_eq!(r.remove(ib).map(|q| q.id), Some(ib)); // mid-lane
        assert!(r.remove(ib).is_none(), "already removed");
        assert!(r.remove(999).is_none(), "unknown id");
        assert_eq!(r.depth(), 2);
        assert_eq!(r.pop().unwrap().id, ic);
        assert_eq!(r.pop().unwrap().id, ia);
    }

    #[test]
    fn drain_where_sweeps_matching_requests_across_lanes() {
        let mut r = Router::new(16, 1024);
        let mut ids = Vec::new();
        for p in [Priority::Batch, Priority::Interactive, Priority::Normal, Priority::Batch] {
            let x = req(&mut r, p);
            ids.push(x.id);
            r.submit(x);
        }
        // Sweep the two batch requests (odd lane in this submission order).
        let drained = r.drain_where(|q| q.priority == Priority::Batch);
        assert_eq!(drained.len(), 2);
        assert!(drained.iter().all(|q| q.priority == Priority::Batch));
        assert_eq!(r.depth(), 2);
        assert!(r
            .drain_where(|q| q.priority == Priority::Batch)
            .is_empty());
    }

    #[test]
    fn shed_takes_newest_of_lowest_priority_lane() {
        let mut r = Router::new(16, 1024);
        let a = req(&mut r, Priority::Interactive);
        let b = req(&mut r, Priority::Batch);
        let c = req(&mut r, Priority::Batch);
        let (ia, ib, ic) = (a.id, b.id, c.id);
        for x in [a, b, c] {
            r.submit(x);
        }
        // Newest batch entry goes first, then the older batch head, then
        // (only once the batch lane is empty) the interactive request.
        assert_eq!(r.shed_lowest_newest().map(|q| q.id), Some(ic));
        assert_eq!(r.shed_lowest_newest().map(|q| q.id), Some(ib));
        assert_eq!(r.shed_lowest_newest().map(|q| q.id), Some(ia));
        assert!(r.shed_lowest_newest().is_none());
    }

    #[test]
    fn strict_priority_when_bypass_unbounded() {
        // Default max_bypass = usize::MAX preserves the original strict
        // drain order (the proptest suite pins this over random traffic).
        let mut r = Router::new(64, 1024);
        let b = req(&mut r, Priority::Batch);
        let batch_id = b.id;
        r.submit(b);
        for _ in 0..8 {
            let x = req(&mut r, Priority::Interactive);
            r.submit(x);
            assert_ne!(r.pop().unwrap().id, batch_id);
        }
    }
}
