//! L3 coordinator (S11): the serving system — router, paged KV cache,
//! continuous-batching engine, adaptive PASA overflow guard, metrics.

pub mod engine;
pub mod faults;
pub mod guard;
pub mod kv_cache;
pub mod metrics;
pub mod prefix_cache;
pub mod request;
pub mod router;
pub mod scheduler;

pub use engine::{Backend, Engine, EngineConfig};
pub use faults::{FaultKind, FaultPlan, FaultRates, FaultRecord, ScriptedFault};
pub use guard::{Guard, GuardPolicy, GuardSignal, DEFAULT_PREEMPTIVE_FRAC};
pub use kv_cache::{KvPool, KvStore, SeqCache};
pub use metrics::{HistSummary, Histogram, Metrics, PrefixStats, Robustness, SchedDeferrals};
pub use prefix_cache::{PrefixCache, PrefixDecision};
pub use request::{
    Completion, FinishReason, GenParams, Phase, Priority, Request, StreamEvent, TokenEvent,
};
pub use router::{Admission, Router};
pub use scheduler::{BatchState, SchedDecision, SchedulerConfig};
