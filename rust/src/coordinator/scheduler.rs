//! Token-budget continuous-batching scheduler (S11): the *pure* decision
//! core behind `Engine::step`.
//!
//! Every admission decision is a function of `(SchedulerConfig,
//! BatchState, candidate)` — integers derived from token counts, slot
//! counts and free pages. No wall clock, no RNG, no hidden state: replay
//! the same arrival trace and the scheduler makes the same decisions in
//! the same order, which is what makes the engine's token-identity
//! certification (batched ≡ sequential, bit for bit) meaningful.
//!
//! The model is TGI's `batching_task` distilled to its budget arithmetic:
//!
//! * `max_batch_prefill_tokens` — prompt tokens the engine may prefill
//!   per scheduler iteration. In-flight chunked prefills draw from it
//!   first (FCFS), admissions spend the remainder. A prompt longer than
//!   the leftover budget still admits (lab backend) — it just prefills
//!   across several iterations, one budget-sized chunk per round,
//!   interleaved with the in-flight decode rounds so long prompts never
//!   stall short ones.
//! * `max_batch_total_tokens` — ceiling on Σ committed tokens over the
//!   active batch, where a request commits `min(prompt + max_new,
//!   max_seq)` tokens up front. This is the KV-residency budget.
//! * `waiting_served_ratio` — bounds starvation: it maps to the router's
//!   `max_bypass` (`ceil(ratio)`), the number of higher-priority pops a
//!   waiting head tolerates before it is force-served.
//! * `max_batch_size` — slot-count cap (0 = the backend's native width:
//!   `decode_batch` on PJRT, whose dense tensors are that wide; the lab
//!   backend has no structural limit so 0 means `decode_batch` there too,
//!   keeping the two backends comparable by default).
//!
//! An empty batch always admits the queue head (no budget can deadlock
//! an idle engine); the one exception is a request whose KV pages can
//! never fit, which the engine rejects outright instead of spinning.

/// Scheduler knobs (see module docs). All token-denominated.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Prompt tokens prefillable per engine step (chunk budget).
    pub max_batch_prefill_tokens: usize,
    /// Ceiling on committed tokens across the active batch.
    pub max_batch_total_tokens: usize,
    /// Starvation bound: a waiting lane head is force-served after
    /// `ceil(ratio)` higher-priority pops.
    pub waiting_served_ratio: f64,
    /// Max concurrent sequences; 0 = backend default (`decode_batch`).
    pub max_batch_size: usize,
    /// How many times an `Evicted` (KV-backpressure) request is
    /// re-enqueued with exponential step backoff before the eviction
    /// becomes terminal. 0 (default) disables retry — evictions surface
    /// to the client exactly as before.
    pub retry_budget: usize,
    /// Queue-depth load-shedding threshold: when the router holds more
    /// than this many waiting requests at the start of a step, the
    /// excess is shed newest-lowest-priority-first with
    /// `FinishReason::Shed`. 0 (default) disables shedding.
    pub shed_queue_depth: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch_prefill_tokens: 512,
            max_batch_total_tokens: 8192,
            waiting_served_ratio: 4.0,
            max_batch_size: 0,
            retry_budget: 0,
            shed_queue_depth: 0,
        }
    }
}

impl SchedulerConfig {
    /// Budgets so large the scheduler degenerates to the pre-scheduler
    /// behaviour: admit whenever a slot is free, prefill whole prompts in
    /// one chunk, strict priority order. The FIFO comparator arm of the
    /// serving benchmarks.
    pub fn fifo_compat() -> Self {
        SchedulerConfig {
            max_batch_prefill_tokens: usize::MAX / 4,
            max_batch_total_tokens: usize::MAX / 4,
            waiting_served_ratio: f64::INFINITY,
            max_batch_size: 0,
            retry_budget: 0,
            shed_queue_depth: 0,
        }
    }

    /// The router bypass bound this config's `waiting_served_ratio`
    /// implies (∞ or NaN ⇒ strict priority, never force-serve).
    pub fn max_bypass(&self) -> usize {
        if !self.waiting_served_ratio.is_finite() {
            return usize::MAX;
        }
        (self.waiting_served_ratio.ceil().max(1.0)) as usize
    }
}

/// Snapshot of the batch the scheduler decides against — all integers,
/// assembled by the engine from (queue, slot, budget) state only.
#[derive(Clone, Copy, Debug)]
pub struct BatchState {
    /// Occupied slots (active requests, any phase).
    pub active_slots: usize,
    /// Effective slot cap (config resolved against the backend width).
    pub max_slots: usize,
    /// Σ committed tokens of the active batch.
    pub committed_tokens: usize,
    /// Prefill-token budget still unspent this iteration.
    pub prefill_budget_left: usize,
    /// Free pages in the KV pool.
    pub free_pages: usize,
    /// Pool page size in tokens.
    pub page_tokens: usize,
    /// Model layer count (a committed token costs `2 * n_layers` rows).
    pub n_layers: usize,
    /// Context length — commitments clamp to it.
    pub max_seq: usize,
    /// Whether the backend can split this prompt's prefill into chunks
    /// (lab: yes; PJRT: its AOT prefill module is one fixed shape).
    pub chunkable: bool,
    /// Prompt tokens of the candidate already resident in radix
    /// prefix-cache pages (page-aligned; 0 without a cache). Their pages
    /// are charged once — they are *already held* by the cache, so the
    /// candidate only needs pages beyond them — and their prefill is
    /// skipped, so chunking covers only the fresh remainder.
    pub shared_tokens: usize,
}

/// The scheduler's verdict on one candidate admission. Every variant is
/// matched exhaustively in the engine (pasa-lint protects this enum from
/// wildcard arms): adding a defer reason forces every consumer to decide
/// what it means for them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedDecision {
    /// Admit now and prefill `chunk` tokens this iteration (`chunk <
    /// prompt_tokens` ⇒ the prefill continues in later iterations).
    Admit { chunk: usize },
    /// Batch is at its slot cap.
    DeferSlots,
    /// Committed-token budget (`max_batch_total_tokens`) exhausted.
    DeferTotalTokens,
    /// Per-iteration prefill budget exhausted.
    DeferPrefillBudget,
    /// The KV pool cannot hold the request's committed pages right now.
    DeferKvPages,
    /// The request's committed pages exceed the *total* pool — it can
    /// never run; the engine must reject it rather than retry forever.
    RejectNeverFits,
}

/// Committed-token cost of a request: the KV rows it may occupy.
pub fn committed_tokens(prompt_tokens: usize, max_new: usize, max_seq: usize) -> usize {
    prompt_tokens.saturating_add(max_new).min(max_seq)
}

/// Pages a commitment of `tokens` occupies — the pool's own formula, so
/// the scheduler and the cache can never disagree about capacity.
fn pages_for(tokens: usize, n_layers: usize, page_tokens: usize) -> usize {
    super::kv_cache::SeqCache::pages_required(n_layers, tokens, page_tokens.max(1))
}

/// Decide whether the queue head admits into the batch — pure in
/// `(cfg, st, prompt_tokens, max_new)`.
pub fn admission(
    cfg: &SchedulerConfig,
    st: &BatchState,
    prompt_tokens: usize,
    max_new: usize,
) -> SchedDecision {
    let commit = committed_tokens(prompt_tokens, max_new, st.max_seq);
    // Radix-shared pages are charged once: the prefix cache already holds
    // the pages covering `shared_tokens`, so the candidate's *new* page
    // demand is only what lies beyond them. Pricing shared prefixes at
    // full private residency over-counted and produced spurious
    // DeferKvPages/RejectNeverFits for shared-prefix fleets (bugfix).
    let shared = st.shared_tokens.min(commit);
    let need_pages = pages_for(commit, st.n_layers, st.page_tokens)
        .saturating_sub(pages_for(shared, st.n_layers, st.page_tokens));
    if need_pages > st.free_pages {
        // Page check first: it distinguishes "wait for retirements" from
        // "can never run". With no active slots there are no retirements
        // coming — deferring would spin the engine forever.
        return if st.active_slots == 0 {
            SchedDecision::RejectNeverFits
        } else {
            SchedDecision::DeferKvPages
        };
    }
    // Prefill covers only the tokens the prefix cache doesn't: seeded
    // rows are already finalized KV. The engine caps sharing at
    // `prompt_tokens − 1` (the last prompt row must prefill to produce
    // first-token logits), so `fresh ≥ 1` whenever a cache is in play;
    // the `.max(1)` guards the pure function against a hostile snapshot.
    let fresh = prompt_tokens.saturating_sub(st.shared_tokens).max(1);
    // An empty batch always makes progress: budgets defer *relative to*
    // other work, and there is none.
    if st.active_slots == 0 {
        let chunk = if st.chunkable {
            fresh.min(st.prefill_budget_left.max(1))
        } else {
            fresh
        };
        return SchedDecision::Admit { chunk };
    }
    if st.active_slots >= st.max_slots {
        return SchedDecision::DeferSlots;
    }
    if st.committed_tokens.saturating_add(commit) > cfg.max_batch_total_tokens {
        return SchedDecision::DeferTotalTokens;
    }
    if st.prefill_budget_left == 0 || (!st.chunkable && fresh > st.prefill_budget_left) {
        return SchedDecision::DeferPrefillBudget;
    }
    let chunk = if st.chunkable {
        fresh.min(st.prefill_budget_left)
    } else {
        fresh
    };
    SchedDecision::Admit { chunk }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st() -> BatchState {
        BatchState {
            active_slots: 1,
            max_slots: 4,
            committed_tokens: 40,
            prefill_budget_left: 64,
            free_pages: 1024,
            page_tokens: 8,
            n_layers: 2,
            max_seq: 128,
            chunkable: true,
            shared_tokens: 0,
        }
    }

    #[test]
    fn admits_within_all_budgets() {
        let cfg = SchedulerConfig::default();
        assert_eq!(admission(&cfg, &st(), 20, 10), SchedDecision::Admit { chunk: 20 });
    }

    #[test]
    fn long_prompt_admits_with_a_budget_sized_chunk() {
        let cfg = SchedulerConfig::default();
        // 4096-token prompt against a 64-token budget: admit, first chunk 64.
        let mut s = st();
        s.max_seq = 8192;
        s.committed_tokens = 0;
        assert_eq!(admission(&cfg, &s, 4096, 16), SchedDecision::Admit { chunk: 64 });
    }

    #[test]
    fn defer_reasons_fire_in_order() {
        let cfg = SchedulerConfig {
            max_batch_total_tokens: 64,
            ..SchedulerConfig::default()
        };
        let mut s = st();
        s.active_slots = 4;
        assert_eq!(admission(&cfg, &s, 8, 8), SchedDecision::DeferSlots);
        let mut s = st();
        s.committed_tokens = 60;
        assert_eq!(admission(&cfg, &s, 8, 8), SchedDecision::DeferTotalTokens);
        let mut s = st();
        s.prefill_budget_left = 0;
        assert_eq!(
            admission(&SchedulerConfig::default(), &s, 8, 8),
            SchedDecision::DeferPrefillBudget
        );
        let mut s = st();
        s.free_pages = 2;
        assert_eq!(
            admission(&SchedulerConfig::default(), &s, 8, 8),
            SchedDecision::DeferKvPages
        );
    }

    #[test]
    fn unchunkable_prompt_defers_when_bigger_than_budget() {
        let cfg = SchedulerConfig::default();
        let mut s = st();
        s.chunkable = false;
        assert_eq!(admission(&cfg, &s, 100, 8), SchedDecision::DeferPrefillBudget);
        assert_eq!(admission(&cfg, &s, 32, 8), SchedDecision::Admit { chunk: 32 });
    }

    #[test]
    fn empty_batch_always_progresses_or_rejects() {
        let cfg = SchedulerConfig {
            max_batch_total_tokens: 8, // absurdly small
            ..SchedulerConfig::default()
        };
        let mut s = st();
        s.active_slots = 0;
        s.committed_tokens = 0;
        // Budget alone can't wedge an idle engine.
        assert!(matches!(admission(&cfg, &s, 100, 8), SchedDecision::Admit { .. }));
        // ...but a pool that can never hold it is a hard reject.
        s.free_pages = 2;
        assert_eq!(admission(&cfg, &s, 100, 8), SchedDecision::RejectNeverFits);
    }

    #[test]
    fn shared_prefix_pages_are_charged_once() {
        // Regression (radix prefix cache): feasibility used to price every
        // candidate at full private residency, so a shared-prefix request
        // hit DeferKvPages even though the cache already held most of its
        // pages. Commit 72 tokens → 36 pages; only 8 free.
        let cfg = SchedulerConfig::default();
        let mut s = st();
        s.free_pages = 8;
        assert_eq!(admission(&cfg, &s, 64, 8), SchedDecision::DeferKvPages);
        // 56 of the 64 prompt tokens (7 full pages → 28 page refs) are
        // cache-resident: the new demand is 36 − 28 = 8 pages, which fits,
        // and the admit chunk covers only the 8 fresh tokens.
        s.shared_tokens = 56;
        assert_eq!(admission(&cfg, &s, 64, 8), SchedDecision::Admit { chunk: 8 });
        // Same discount flips an empty-batch hard reject into progress.
        let mut s = st();
        s.active_slots = 0;
        s.free_pages = 8;
        assert_eq!(admission(&cfg, &s, 64, 8), SchedDecision::RejectNeverFits);
        s.shared_tokens = 56;
        assert_eq!(admission(&cfg, &s, 64, 8), SchedDecision::Admit { chunk: 8 });
    }

    #[test]
    fn chunk_budget_is_spent_on_fresh_tokens_only() {
        // A 100-token prompt with 96 cache-resident tokens needs a 4-token
        // prefill, not a budget-sized chunk of already-finalized rows.
        let cfg = SchedulerConfig::default();
        let mut s = st();
        s.shared_tokens = 96;
        assert_eq!(admission(&cfg, &s, 100, 8), SchedDecision::Admit { chunk: 4 });
        // An unchunkable prompt compares its *fresh* span to the budget.
        s.chunkable = false;
        assert_eq!(admission(&cfg, &s, 100, 8), SchedDecision::Admit { chunk: 4 });
    }

    #[test]
    fn committed_tokens_clamp_to_context() {
        assert_eq!(committed_tokens(100, 100, 128), 128);
        assert_eq!(committed_tokens(10, 5, 128), 15);
    }

    #[test]
    fn waiting_served_ratio_maps_to_bypass_bound() {
        assert_eq!(SchedulerConfig::default().max_bypass(), 4);
        let c = SchedulerConfig { waiting_served_ratio: 1.2, ..Default::default() };
        assert_eq!(c.max_bypass(), 2);
        assert_eq!(SchedulerConfig::fifo_compat().max_bypass(), usize::MAX);
    }
}
