//! Request model (S11): what flows through the router → scheduler → engine.

use crate::model::{tokenizer, Sampling};
use std::time::Instant;

pub type RequestId = u64;

/// Generation parameters attached to a request.
#[derive(Clone, Copy, Debug)]
pub struct GenParams {
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    /// Stop at EOS token.
    pub stop_at_eos: bool,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_new_tokens: 32,
            sampling: Sampling::Greedy,
            stop_at_eos: true,
        }
    }
}

/// Admission priority (higher first; FCFS within a class).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Batch = 0,
    Normal = 1,
    Interactive = 2,
}

/// Why a request finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    /// Context window exhausted (hit max_seq).
    ContextFull,
    /// Rejected at admission (e.g. prompt too long).
    Rejected,
    /// Evicted mid-flight: the KV pool could not grow the sequence (e.g.
    /// copy-on-write exhaustion) — backpressure, not a crash; the client
    /// may resubmit. When `SchedulerConfig::retry_budget` > 0 the engine
    /// resubmits on the client's behalf with step-denominated backoff;
    /// this reason then means the budget was exhausted (or the retry
    /// could not re-enter the queue).
    Evicted,
    /// Killed by its step-denominated deadline (`EngineConfig::
    /// deadline_steps` or `Request::with_deadline`) before finishing —
    /// queued, mid-prefill, or decoding alike.
    DeadlineExceeded,
    /// Shed under queue-depth pressure (`SchedulerConfig::
    /// shed_queue_depth`): dropped newest-lowest-priority-first before
    /// ever being admitted, so the work lost is the work that would have
    /// been served last.
    Shed,
    /// Cancelled by the client via `Engine::cancel` — pages released,
    /// stream closed.
    Cancelled,
    /// Quarantined by the engine's fault watchdog: the slot produced a
    /// non-finite logit row (or its backend step failed outright), so it
    /// was isolated instead of sampling garbage. Co-batched neighbours
    /// are unaffected — bit-identical to a fault-free run.
    Faulted,
}

/// Lifecycle state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Prefilling,
    Decoding,
    Finished(FinishReason),
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: String,
    /// Tokenized prompt length (BOS + bytes), computed once at
    /// construction — the currency every admission and scheduling
    /// decision budgets in. Never `prompt.len()` bytes.
    pub prompt_tokens: usize,
    pub params: GenParams,
    pub priority: Priority,
    pub arrival: Instant,
    /// Engine-step clock value at submission, stamped by
    /// `Engine::submit`. The zero point of the request's deadline —
    /// step-denominated (never wall clock) so runs replay exactly.
    pub arrival_step: u64,
    /// Per-request deadline override in engine steps (`None` = use
    /// `EngineConfig::deadline_steps`; `Some(0)` is never set — use
    /// `None`). The request is killed with
    /// [`FinishReason::DeadlineExceeded`] once
    /// `current_step - arrival_step >= deadline`.
    pub deadline_steps: Option<u64>,
    /// How many times this request has been retried after an eviction
    /// (engine-internal; compared against `SchedulerConfig::
    /// retry_budget` and drives the exponential step backoff).
    pub retries: usize,
}

impl Request {
    pub fn new(id: RequestId, prompt: impl Into<String>) -> Request {
        let prompt = prompt.into();
        Request {
            id,
            prompt_tokens: tokenizer::token_len(&prompt),
            prompt,
            params: GenParams::default(),
            priority: Priority::Normal,
            arrival: Instant::now(),
            arrival_step: 0,
            deadline_steps: None,
            retries: 0,
        }
    }

    pub fn with_params(mut self, params: GenParams) -> Self {
        self.params = params;
        self
    }

    pub fn with_priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// Set a per-request deadline in engine steps (overrides the
    /// engine-wide `EngineConfig::deadline_steps`).
    pub fn with_deadline(mut self, steps: u64) -> Self {
        self.deadline_steps = Some(steps);
        self
    }
}

/// Completed request record.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub prompt: String,
    pub text: String,
    pub tokens: Vec<u32>,
    pub reason: FinishReason,
    pub prompt_tokens: usize,
    /// Wall times in seconds.
    pub queue_time: f64,
    pub prefill_time: f64,
    pub first_token_latency: f64,
    pub total_latency: f64,
    /// Which attention allocation finished the request ("pasa", ...).
    pub allocation: String,
    /// How many times the overflow guard switched this request to PASA.
    pub guard_switches: usize,
}

/// One generated token of an in-flight request, emitted the moment it is
/// sampled — the per-token streaming unit. Timestamps are observational
/// (they feed the TTFT/ITL histograms); the scheduler never reads them.
#[derive(Clone, Copy, Debug)]
pub struct TokenEvent {
    pub request_id: RequestId,
    pub token: u32,
    /// 0-based index within the request's *generated* stream.
    pub index: usize,
    /// Absolute context position (prompt_len + index).
    pub position: usize,
    pub emitted_at: Instant,
}

/// The engine's streaming output: interleaved per-token events and
/// stream-close markers, drained with `Engine::take_events`. Every token
/// that later appears in a `Completion` was first emitted here, in order
/// — the stream is the completion, delivered incrementally.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// One sampled token of an in-flight request.
    Token(TokenEvent),
    /// The request's stream closed (a `Completion` is available).
    Finished {
        request_id: RequestId,
        reason: FinishReason,
    },
}
