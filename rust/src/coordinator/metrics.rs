//! Serving metrics (S11): latency histograms, token counters, overflow
//! switches, scheduler deferral counters — what the E2E example and
//! bench harness report.
//!
//! Histograms are bounded-memory: bucket counts are exact, and exact
//! percentiles come from a fixed-size **reservoir** (Algorithm R, seeded
//! — deterministic across runs) instead of an unbounded sample vector.
//! A serving run that records millions of step latencies retains at most
//! [`RESERVOIR_CAP`] samples per histogram, and percentile queries sort
//! a bounded copy — the old implementation cloned and re-sorted an
//! ever-growing vector on *every* `percentile()` call.

use super::faults::FaultKind;
use crate::workloads::Pcg64;
use std::time::Instant;

/// Max retained samples per histogram. Below this count percentiles are
/// exact; above it they are reservoir estimates over a uniform sample.
pub const RESERVOIR_CAP: usize = 4096;

/// Streaming histogram: fixed log-spaced buckets (seconds) with exact
/// counts/mean/max, plus a bounded reservoir for percentile queries.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    /// Uniform reservoir sample of everything recorded (≤ RESERVOIR_CAP).
    samples: Vec<f64>,
    seen: u64,
    sum: f64,
    max: f64,
    /// Seeded reservoir RNG — measurement plumbing only. Scheduler
    /// decisions never read it, and a fixed seed keeps replays
    /// deterministic.
    rng: Pcg64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let bounds: Vec<f64> = (-4..=4).map(|e| 10f64.powi(e)).collect();
        Histogram {
            counts: vec![0; bounds.len() + 1],
            bounds,
            samples: Vec::new(),
            seen: 0,
            sum: 0.0,
            max: 0.0,
            rng: Pcg64::new(0x4e57, 0x0b5e),
        }
    }

    pub fn record(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.seen += 1;
        self.sum += v;
        self.max = self.max.max(v);
        // Algorithm R: the j-th record replaces a reservoir entry with
        // probability CAP/j, keeping the reservoir a uniform sample.
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(v);
        } else {
            let j = self.rng.below(self.seen as usize);
            if j < RESERVOIR_CAP {
                self.samples[j] = v;
            }
        }
    }

    /// Total values recorded (not the retained sample count).
    pub fn count(&self) -> usize {
        self.seen as usize
    }

    /// Exact running mean over everything recorded.
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            return 0.0;
        }
        self.sum / self.seen as f64
    }

    /// Exact running max over everything recorded.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// The retained reservoir, sorted — one bounded sort, shared by every
    /// percentile a report wants.
    fn sorted_samples(&self) -> Vec<f64> {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    }

    fn percentile_of(sorted: &[f64], p: f64) -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }

    /// One percentile (exact below [`RESERVOIR_CAP`] records, reservoir
    /// estimate above). Cost is bounded by the reservoir size regardless
    /// of how much was recorded; for several percentiles at once prefer
    /// [`Histogram::summary`], which sorts once.
    pub fn percentile(&self, p: f64) -> f64 {
        Self::percentile_of(&self.sorted_samples(), p)
    }

    /// Sort-once summary for reports.
    pub fn summary(&self) -> HistSummary {
        let sorted = self.sorted_samples();
        HistSummary {
            count: self.count(),
            mean: self.mean(),
            p50: Self::percentile_of(&sorted, 50.0),
            p95: Self::percentile_of(&sorted, 95.0),
            p99: Self::percentile_of(&sorted, 99.0),
            max: self.max(),
        }
    }
}

/// Percentile snapshot of one histogram (see [`Histogram::summary`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct HistSummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

/// Why admissions were deferred, by scheduler reason — the observability
/// face of `SchedDecision` (each counter increments when a step's
/// admission loop stops for that reason).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedDeferrals {
    pub slots: u64,
    pub total_tokens: u64,
    pub prefill_budget: u64,
    pub kv_pages: u64,
    /// Evicted requests parked for retry backoff instead of completing
    /// — the deferral-accounting face of the retry budget.
    pub retry_backoff: u64,
}

/// Radix prefix-cache and fan-out counters — the observability face of
/// `coordinator::prefix_cache`. `tokens_saved` is prefill work the cache
/// skipped (the engine-level pin lower-bounds it for a shared-prefix
/// fleet); `evictions` counts page references the LRU policy released.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixStats {
    /// Admissions seeded from cached pages.
    pub hits: u64,
    /// Prompt tokens whose prefill was skipped via shared pages.
    pub tokens_saved: u64,
    /// Page references released by LRU eviction (budget or pool pressure).
    pub evictions: u64,
    /// Sibling decode slots created by best-of-n fan-out forks.
    pub fanout_forks: u64,
}

/// Robustness counters: chaos injections by kind plus the
/// request-lifecycle hardening outcomes. `faults_by_kind` reconciles
/// one-for-one against the installed `FaultPlan`'s injection log (the
/// chaos soak pins the equality).
#[derive(Clone, Debug, Default)]
pub struct Robustness {
    /// Injections recorded, indexed by [`FaultKind::index`].
    pub faults_by_kind: [u64; FaultKind::COUNT],
    /// Evicted requests re-enqueued under the retry budget.
    pub retries: u64,
    /// Requests shed under queue-depth pressure.
    pub sheds: u64,
    /// Requests killed by a step-denominated deadline.
    pub deadline_kills: u64,
    /// Slots quarantined by the non-finite-logit watchdog or an
    /// injected backend step failure.
    pub quarantines: u64,
    /// Requests cancelled via `Engine::cancel`.
    pub cancellations: u64,
    /// Router peek/pop disagreements survived (recoverable; formerly a
    /// process abort).
    pub router_desyncs: u64,
}

impl Robustness {
    /// Count one injection of `kind`.
    pub fn fault(&mut self, kind: FaultKind) {
        self.faults_by_kind[kind.index()] += 1;
    }

    /// Total injections across all kinds.
    pub fn faults_total(&self) -> u64 {
        self.faults_by_kind.iter().sum()
    }
}

/// Aggregate serving metrics.
#[derive(Debug)]
pub struct Metrics {
    pub started: Instant,
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    /// Prefill chunks executed (≥ 1 per request on the lab backend; a
    /// chunked long prompt contributes several).
    pub prefill_chunks: u64,
    pub decode_steps: u64,
    pub decode_batch_occupancy: Vec<usize>,
    pub guard_switches: u64,
    pub overflow_steps: u64,
    pub deferrals: SchedDeferrals,
    /// Prefix-cache hit/saving/eviction and fan-out counters.
    pub prefix: PrefixStats,
    /// Chaos-injection and lifecycle-hardening counters.
    pub robustness: Robustness,
    pub ttft: Histogram, // time to first token (arrival → first sample)
    /// Inter-token latency: gap between consecutive sampled tokens of the
    /// same request (the streaming smoothness metric; a chunked prefill
    /// admitted mid-flight shows up here if it stalls decodes).
    pub itl: Histogram,
    pub total_latency: Histogram,
    pub step_latency: Histogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            requests_completed: 0,
            tokens_generated: 0,
            prefill_tokens: 0,
            prefill_chunks: 0,
            decode_steps: 0,
            decode_batch_occupancy: Vec::new(),
            guard_switches: 0,
            overflow_steps: 0,
            deferrals: SchedDeferrals::default(),
            prefix: PrefixStats::default(),
            robustness: Robustness::default(),
            ttft: Histogram::new(),
            itl: Histogram::new(),
            total_latency: Histogram::new(),
            step_latency: Histogram::new(),
        }
    }

    pub fn throughput_tok_s(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64().max(1e-9);
        self.tokens_generated as f64 / dt
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.decode_batch_occupancy.is_empty() {
            return 0.0;
        }
        self.decode_batch_occupancy.iter().sum::<usize>() as f64
            / self.decode_batch_occupancy.len() as f64
    }

    /// Human-readable serving report.
    pub fn report(&self) -> String {
        let ttft = self.ttft.summary();
        let lat = self.total_latency.summary();
        let itl = self.itl.summary();
        let d = &self.deferrals;
        format!(
            "requests={} tokens={} prefill_tokens={} prefill_chunks={} steps={} occ={:.2} \
             tok/s={:.1} ttft_mean={:.3}s ttft_p50={:.3}s ttft_p95={:.3}s \
             itl_mean={:.4}s itl_p95={:.4}s lat_mean={:.3}s \
             lat_p95={:.3}s step_mean={:.4}s guard_switches={} overflow_steps={} \
             defers[slots={} tokens={} prefill={} kv={} retry={}] \
             prefix[hits={} tokens_saved={} evictions={} forks={}] \
             chaos[faults={} retries={} sheds={} deadline={} quarantine={} cancel={} desync={}]",
            self.requests_completed,
            self.tokens_generated,
            self.prefill_tokens,
            self.prefill_chunks,
            self.decode_steps,
            self.mean_batch_occupancy(),
            self.throughput_tok_s(),
            ttft.mean,
            ttft.p50,
            ttft.p95,
            itl.mean,
            itl.p95,
            lat.mean,
            lat.p95,
            self.step_latency.mean(),
            self.guard_switches,
            self.overflow_steps,
            d.slots,
            d.total_tokens,
            d.prefill_budget,
            d.kv_pages,
            d.retry_backoff,
            self.prefix.hits,
            self.prefix.tokens_saved,
            self.prefix.evictions,
            self.prefix.fanout_forks,
            self.robustness.faults_total(),
            self.robustness.retries,
            self.robustness.sheds,
            self.robustness.deadline_kills,
            self.robustness.quarantines,
            self.robustness.cancellations,
            self.robustness.router_desyncs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 / 100.0);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.505).abs() < 1e-9);
        assert!((h.percentile(50.0) - 0.5).abs() < 0.02);
        assert!((h.percentile(95.0) - 0.95).abs() < 0.02);
        assert_eq!(h.max(), 1.0);
    }

    #[test]
    fn reservoir_bounds_memory_under_sustained_load() {
        // 100k records: retained samples stay capped, exact stats stay
        // exact, and the reservoir percentile lands near the true one.
        let mut h = Histogram::new();
        let n = 100_000;
        for i in 1..=n {
            h.record(i as f64 / n as f64);
        }
        assert_eq!(h.count(), n);
        assert!(h.samples.len() <= RESERVOIR_CAP);
        assert!((h.mean() - (n + 1) as f64 / (2.0 * n as f64)).abs() < 1e-9);
        assert_eq!(h.max(), 1.0);
        let p95 = h.percentile(95.0);
        assert!((p95 - 0.95).abs() < 0.05, "reservoir p95 drifted: {p95}");
        // Deterministic: a second identically-fed histogram agrees bit-wise.
        let mut h2 = Histogram::new();
        for i in 1..=n {
            h2.record(i as f64 / n as f64);
        }
        assert_eq!(h.percentile(95.0), h2.percentile(95.0));
    }

    #[test]
    fn summary_matches_individual_queries() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        let s = h.summary();
        assert_eq!(s.count, 1000);
        assert_eq!(s.p50, h.percentile(50.0));
        assert_eq!(s.p95, h.percentile(95.0));
        assert_eq!(s.max, 1000.0);
    }

    #[test]
    fn metrics_report_nonempty() {
        let mut m = Metrics::new();
        m.requests_completed = 3;
        m.tokens_generated = 42;
        m.decode_batch_occupancy = vec![2, 4, 3];
        m.ttft.record(0.1);
        m.itl.record(0.01);
        let r = m.report();
        assert!(r.contains("requests=3"));
        assert!(r.contains("occ=3.00"));
        assert!(r.contains("itl_mean="));
        assert!(r.contains("defers["));
        assert!(r.contains("prefix["));
        assert!(r.contains("chaos["));
    }

    #[test]
    fn robustness_counters_reconcile_by_kind() {
        let mut rb = Robustness::default();
        rb.fault(FaultKind::KvNanPoison);
        rb.fault(FaultKind::LogitNan);
        rb.fault(FaultKind::LogitNan);
        assert_eq!(rb.faults_by_kind[FaultKind::KvNanPoison.index()], 1);
        assert_eq!(rb.faults_by_kind[FaultKind::LogitNan.index()], 2);
        assert_eq!(rb.faults_total(), 3);
    }
}
