//! Serving metrics (S11): latency histograms, token counters, overflow
//! switches — what the E2E example and bench harness report.

use std::time::Instant;

/// Streaming histogram with fixed log-spaced latency buckets (seconds).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    samples: Vec<f64>, // kept for exact percentiles at report time
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let bounds: Vec<f64> = (-4..=4).map(|e| 10f64.powi(e)).collect();
        Histogram {
            counts: vec![0; bounds.len() + 1],
            bounds,
            samples: Vec::new(),
        }
    }

    pub fn record(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.samples.push(v);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(0.0, f64::max)
    }
}

/// Aggregate serving metrics.
#[derive(Debug)]
pub struct Metrics {
    pub started: Instant,
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub decode_steps: u64,
    pub decode_batch_occupancy: Vec<usize>,
    pub guard_switches: u64,
    pub overflow_steps: u64,
    pub ttft: Histogram,       // time to first token
    pub total_latency: Histogram,
    pub step_latency: Histogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            requests_completed: 0,
            tokens_generated: 0,
            prefill_tokens: 0,
            decode_steps: 0,
            decode_batch_occupancy: Vec::new(),
            guard_switches: 0,
            overflow_steps: 0,
            ttft: Histogram::new(),
            total_latency: Histogram::new(),
            step_latency: Histogram::new(),
        }
    }

    pub fn throughput_tok_s(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64().max(1e-9);
        self.tokens_generated as f64 / dt
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.decode_batch_occupancy.is_empty() {
            return 0.0;
        }
        self.decode_batch_occupancy.iter().sum::<usize>() as f64
            / self.decode_batch_occupancy.len() as f64
    }

    /// Human-readable serving report.
    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} prefill_tokens={} steps={} occ={:.2} \
             tok/s={:.1} ttft_mean={:.3}s ttft_p95={:.3}s lat_mean={:.3}s \
             lat_p95={:.3}s step_mean={:.4}s guard_switches={} overflow_steps={}",
            self.requests_completed,
            self.tokens_generated,
            self.prefill_tokens,
            self.decode_steps,
            self.mean_batch_occupancy(),
            self.throughput_tok_s(),
            self.ttft.mean(),
            self.ttft.percentile(95.0),
            self.total_latency.mean(),
            self.total_latency.percentile(95.0),
            self.step_latency.mean(),
            self.guard_switches,
            self.overflow_steps,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 / 100.0);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.505).abs() < 1e-9);
        assert!((h.percentile(50.0) - 0.5).abs() < 0.02);
        assert!((h.percentile(95.0) - 0.95).abs() < 0.02);
        assert_eq!(h.max(), 1.0);
    }

    #[test]
    fn metrics_report_nonempty() {
        let mut m = Metrics::new();
        m.requests_completed = 3;
        m.tokens_generated = 42;
        m.decode_batch_occupancy = vec![2, 4, 3];
        m.ttft.record(0.1);
        let r = m.report();
        assert!(r.contains("requests=3"));
        assert!(r.contains("occ=3.00"));
    }
}
