//! Adaptive PASA overflow guard (S11) — the paper's future-work feature
//! ("it is also promising to design an adaptive mechanism to start PASA"),
//! built here as a first-class coordinator policy.
//!
//! Policy: requests start on the fast partially-low-precision FA
//! allocation; when a step's [`GuardSignal`] shows trouble the step is
//! *replayed* under PASA — safe because prefill/decode are functional
//! (cache in → cache out) — and the request is pinned to PASA for its
//! remaining lifetime.
//!
//! Signals come from two sources:
//! * the attention lab's kernel telemetry
//!   ([`crate::attention::AttentionOutput`]): pre-store overflow events
//!   and max |S| straight from the score GEMM — the paper's
//!   instrumentation point, which can flag *pre-overflow pressure* before
//!   any NaN reaches the logits;
//! * the runtime path's logits scan (the legacy NaN sniffing), kept for
//!   the PJRT modules whose internals we don't instrument.

use crate::attention::AttentionOutput;
use crate::numerics::Format;

/// Overflow telemetry for one engine step.
#[derive(Clone, Copy, Debug, Default)]
pub struct GuardSignal {
    /// Pre-store score values beyond the low-precision overflow boundary.
    pub overflow_events: usize,
    /// Largest pre-store |S| observed (0 when unknown, e.g. logits-only).
    pub max_abs_score: f32,
    /// Non-finite values observed in outputs/logits.
    pub nonfinite: usize,
}

impl GuardSignal {
    /// Legacy signal from a logits row: counts non-finite entries.
    pub fn from_logits(logits: &[f32]) -> GuardSignal {
        GuardSignal {
            overflow_events: 0,
            max_abs_score: 0.0,
            nonfinite: logits.iter().filter(|x| !x.is_finite()).count(),
        }
    }

    /// Rich signal from the attention lab's per-head kernel telemetry.
    pub fn from_attention(out: &AttentionOutput) -> GuardSignal {
        GuardSignal {
            overflow_events: out.overflow_events(),
            max_abs_score: out.max_abs_score(),
            nonfinite: out.nonfinite_outputs(),
        }
    }

    /// No overflow, no poisoning, no score above `score_limit`.
    pub fn is_clean(&self, score_limit: f32) -> bool {
        self.nonfinite == 0 && self.overflow_events == 0 && self.max_abs_score <= score_limit
    }

    /// Fold another signal in (e.g. one per transformer layer of a decode
    /// step): event counts add, the score maximum is the max.
    pub fn merge(&mut self, o: &GuardSignal) {
        self.overflow_events += o.overflow_events;
        self.nonfinite += o.nonfinite;
        if o.max_abs_score > self.max_abs_score {
            self.max_abs_score = o.max_abs_score;
        }
    }
}

/// Which attention allocation the engine should run next for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GuardPolicy {
    /// Always run PASA (the paper's robust default).
    AlwaysPasa,
    /// Always run partially-low-precision FA (fast but overflow-prone).
    AlwaysFa16,
    /// Full-precision FA reference.
    AlwaysFa32,
    /// Start on FA16-32, switch to PASA on overflow (sticky per request).
    Adaptive,
}

impl GuardPolicy {
    pub fn parse(s: &str) -> Option<GuardPolicy> {
        match s {
            "pasa" => Some(GuardPolicy::AlwaysPasa),
            "fa16_32" | "fa16" => Some(GuardPolicy::AlwaysFa16),
            "fa32" => Some(GuardPolicy::AlwaysFa32),
            "adaptive" => Some(GuardPolicy::Adaptive),
            _ => None,
        }
    }
}

/// Per-request guard state.
#[derive(Clone, Debug)]
pub struct Guard {
    policy: GuardPolicy,
    pinned_pasa: bool,
    /// Pre-emptive trip point for max |S| (default: the FP16 overflow
    /// boundary — scores past it *did* overflow a low-precision store).
    score_limit: f32,
    pub switches: usize,
}

impl Guard {
    pub fn new(policy: GuardPolicy) -> Guard {
        Guard {
            policy,
            pinned_pasa: false,
            score_limit: Format::F16.overflow_boundary() as f32,
            switches: 0,
        }
    }

    /// Lower the score trip point below the FP16 boundary (e.g. 0.9×65504)
    /// to switch on overflow *pressure* before the first poisoned step.
    pub fn with_score_limit(mut self, limit: f32) -> Guard {
        self.score_limit = limit;
        self
    }

    /// Allocation to use for the next step.
    pub fn allocation(&self) -> &'static str {
        match self.policy {
            GuardPolicy::AlwaysPasa => "pasa",
            GuardPolicy::AlwaysFa16 => "fa16_32",
            GuardPolicy::AlwaysFa32 => "fa32",
            GuardPolicy::Adaptive => {
                if self.pinned_pasa {
                    "pasa"
                } else {
                    "fa16_32"
                }
            }
        }
    }

    /// Inspect a step's telemetry; returns true if the step must be
    /// replayed under PASA (adaptive mode only).
    pub fn observe_signal(&mut self, sig: &GuardSignal) -> bool {
        if sig.is_clean(self.score_limit) {
            return false;
        }
        match self.policy {
            GuardPolicy::Adaptive if !self.pinned_pasa => {
                self.pinned_pasa = true;
                self.switches += 1;
                true
            }
            _ => false, // nothing left to switch to — surface the NaNs
        }
    }

    /// Legacy logits-only inspection (the runtime path).
    pub fn observe(&mut self, logits: &[f32]) -> bool {
        self.observe_signal(&GuardSignal::from_logits(logits))
    }

    pub fn is_pinned(&self) -> bool {
        self.pinned_pasa
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_switches_once_and_sticks() {
        let mut g = Guard::new(GuardPolicy::Adaptive);
        assert_eq!(g.allocation(), "fa16_32");
        assert!(!g.observe(&[0.0, 1.0]));
        assert_eq!(g.allocation(), "fa16_32");
        assert!(g.observe(&[f32::NAN, 1.0])); // replay requested
        assert_eq!(g.allocation(), "pasa");
        assert_eq!(g.switches, 1);
        // Further overflow (shouldn't happen under PASA) doesn't loop.
        assert!(!g.observe(&[f32::INFINITY]));
        assert_eq!(g.switches, 1);
    }

    #[test]
    fn fixed_policies_never_switch() {
        for (p, alloc) in [
            (GuardPolicy::AlwaysPasa, "pasa"),
            (GuardPolicy::AlwaysFa16, "fa16_32"),
            (GuardPolicy::AlwaysFa32, "fa32"),
        ] {
            let mut g = Guard::new(p);
            assert_eq!(g.allocation(), alloc);
            assert!(!g.observe(&[f32::NAN]));
            assert_eq!(g.allocation(), alloc);
        }
    }

    #[test]
    fn parse_policies() {
        assert_eq!(GuardPolicy::parse("adaptive"), Some(GuardPolicy::Adaptive));
        assert_eq!(GuardPolicy::parse("pasa"), Some(GuardPolicy::AlwaysPasa));
        assert_eq!(GuardPolicy::parse("nope"), None);
    }

    #[test]
    fn guard_spellings_map_onto_lab_allocations() {
        // Every allocation string the guard can emit must resolve to a
        // lab Allocation (the engine's replay path and any lab-backed
        // runtime rely on this bridge staying total).
        use crate::attention::Allocation;
        for policy in [
            GuardPolicy::AlwaysPasa,
            GuardPolicy::AlwaysFa16,
            GuardPolicy::AlwaysFa32,
            GuardPolicy::Adaptive,
        ] {
            let mut g = Guard::new(policy);
            assert!(
                Allocation::parse(g.allocation()).is_some(),
                "{policy:?}: {:?} has no lab allocation",
                g.allocation()
            );
            g.observe(&[f32::NAN]); // flip adaptive to its pinned spelling
            assert!(
                Allocation::parse(g.allocation()).is_some(),
                "{policy:?} (pinned): {:?} has no lab allocation",
                g.allocation()
            );
        }
    }

    #[test]
    fn kernel_telemetry_trips_before_poisoning() {
        // A signal with pre-store overflow events but still-finite outputs
        // must already trip the adaptive guard.
        let mut g = Guard::new(GuardPolicy::Adaptive);
        let sig = GuardSignal {
            overflow_events: 3,
            max_abs_score: 9.0e4,
            nonfinite: 0,
        };
        assert!(g.observe_signal(&sig));
        assert_eq!(g.allocation(), "pasa");
    }

    #[test]
    fn score_limit_is_preemptive() {
        // With a lowered limit, pure score pressure (no overflow yet)
        // trips the guard.
        let mut g = Guard::new(GuardPolicy::Adaptive).with_score_limit(0.9 * 65504.0);
        let pressure = GuardSignal {
            overflow_events: 0,
            max_abs_score: 60000.0,
            nonfinite: 0,
        };
        assert!(g.observe_signal(&pressure));
        // Default limit would not have tripped.
        let mut g = Guard::new(GuardPolicy::Adaptive);
        assert!(!g.observe_signal(&pressure));
    }

    #[test]
    fn merge_folds_per_layer_signals() {
        let mut a = GuardSignal {
            overflow_events: 1,
            max_abs_score: 100.0,
            nonfinite: 0,
        };
        a.merge(&GuardSignal {
            overflow_events: 2,
            max_abs_score: 7.0e4,
            nonfinite: 3,
        });
        assert_eq!(a.overflow_events, 3);
        assert_eq!(a.nonfinite, 3);
        assert_eq!(a.max_abs_score, 7.0e4);
        assert!(!a.is_clean(65504.0));
    }

    #[test]
    fn signal_from_logits_counts_nonfinite() {
        let sig = GuardSignal::from_logits(&[1.0, f32::NAN, f32::INFINITY, 2.0]);
        assert_eq!(sig.nonfinite, 2);
        assert!(!sig.is_clean(65504.0));
        assert!(GuardSignal::from_logits(&[0.5, -0.5]).is_clean(65504.0));
    }
}
