//! Adaptive PASA overflow guard (S11) — the paper's future-work feature
//! ("it is also promising to design an adaptive mechanism to start PASA"),
//! built here as a first-class coordinator policy.
//!
//! Policy: requests start on the fast partially-low-precision FA
//! allocation; if a step's logits come back non-finite (the INF/NaN
//! signature of a QKᵀ FP16 overflow), the step is *replayed* under PASA —
//! safe because prefill/decode are functional (cache in → cache out) — and
//! the request is pinned to PASA for its remaining lifetime.

/// Which attention allocation the engine should run next for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GuardPolicy {
    /// Always run PASA (the paper's robust default).
    AlwaysPasa,
    /// Always run partially-low-precision FA (fast but overflow-prone).
    AlwaysFa16,
    /// Full-precision FA reference.
    AlwaysFa32,
    /// Start on FA16-32, switch to PASA on overflow (sticky per request).
    Adaptive,
}

impl GuardPolicy {
    pub fn parse(s: &str) -> Option<GuardPolicy> {
        match s {
            "pasa" => Some(GuardPolicy::AlwaysPasa),
            "fa16_32" | "fa16" => Some(GuardPolicy::AlwaysFa16),
            "fa32" => Some(GuardPolicy::AlwaysFa32),
            "adaptive" => Some(GuardPolicy::Adaptive),
            _ => None,
        }
    }
}

/// Per-request guard state.
#[derive(Clone, Debug)]
pub struct Guard {
    policy: GuardPolicy,
    pinned_pasa: bool,
    pub switches: usize,
}

impl Guard {
    pub fn new(policy: GuardPolicy) -> Guard {
        Guard {
            policy,
            pinned_pasa: false,
            switches: 0,
        }
    }

    /// Allocation to use for the next step.
    pub fn allocation(&self) -> &'static str {
        match self.policy {
            GuardPolicy::AlwaysPasa => "pasa",
            GuardPolicy::AlwaysFa16 => "fa16_32",
            GuardPolicy::AlwaysFa32 => "fa32",
            GuardPolicy::Adaptive => {
                if self.pinned_pasa {
                    "pasa"
                } else {
                    "fa16_32"
                }
            }
        }
    }

    /// Inspect a step's logits; returns true if the step must be replayed
    /// under PASA (adaptive mode only).
    pub fn observe(&mut self, logits: &[f32]) -> bool {
        let overflowed = logits.iter().any(|x| !x.is_finite());
        if !overflowed {
            return false;
        }
        match self.policy {
            GuardPolicy::Adaptive if !self.pinned_pasa => {
                self.pinned_pasa = true;
                self.switches += 1;
                true
            }
            _ => false, // nothing left to switch to — surface the NaNs
        }
    }

    pub fn is_pinned(&self) -> bool {
        self.pinned_pasa
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_switches_once_and_sticks() {
        let mut g = Guard::new(GuardPolicy::Adaptive);
        assert_eq!(g.allocation(), "fa16_32");
        assert!(!g.observe(&[0.0, 1.0]));
        assert_eq!(g.allocation(), "fa16_32");
        assert!(g.observe(&[f32::NAN, 1.0])); // replay requested
        assert_eq!(g.allocation(), "pasa");
        assert_eq!(g.switches, 1);
        // Further overflow (shouldn't happen under PASA) doesn't loop.
        assert!(!g.observe(&[f32::INFINITY]));
        assert_eq!(g.switches, 1);
    }

    #[test]
    fn fixed_policies_never_switch() {
        for (p, alloc) in [
            (GuardPolicy::AlwaysPasa, "pasa"),
            (GuardPolicy::AlwaysFa16, "fa16_32"),
            (GuardPolicy::AlwaysFa32, "fa32"),
        ] {
            let mut g = Guard::new(p);
            assert_eq!(g.allocation(), alloc);
            assert!(!g.observe(&[f32::NAN]));
            assert_eq!(g.allocation(), alloc);
        }
    }

    #[test]
    fn parse_policies() {
        assert_eq!(GuardPolicy::parse("adaptive"), Some(GuardPolicy::Adaptive));
        assert_eq!(GuardPolicy::parse("pasa"), Some(GuardPolicy::AlwaysPasa));
        assert_eq!(GuardPolicy::parse("nope"), None);
    }
}
