//! Adaptive PASA overflow guard (S11) — the paper's future-work feature
//! ("it is also promising to design an adaptive mechanism to start PASA"),
//! built here as a first-class coordinator policy.
//!
//! Policy: requests start on the fast partially-low-precision FA
//! allocation; when a step's [`GuardSignal`] shows trouble the step is
//! *replayed* under PASA — safe because prefill/decode are functional
//! (cache in → cache out) — and the request is pinned to PASA for its
//! remaining lifetime.
//!
//! Signals come from two sources:
//! * the attention lab's kernel telemetry
//!   ([`crate::attention::AttentionOutput`]): pre-store overflow events
//!   and max |S| straight from the score GEMM — the paper's
//!   instrumentation point, which can flag *pre-overflow pressure* before
//!   any NaN reaches the logits;
//! * the runtime path's logits scan (the legacy NaN sniffing), kept for
//!   the PJRT modules whose internals we don't instrument.

use crate::attention::{Allocation, AttentionOutput};
use crate::numerics::Format;

/// Overflow telemetry for one engine step.
#[derive(Clone, Copy, Debug)]
pub struct GuardSignal {
    /// Pre-store score values beyond the low-precision overflow boundary.
    pub overflow_events: usize,
    /// Largest pre-store |S| observed (0 when unknown, e.g. logits-only).
    pub max_abs_score: f32,
    /// Non-finite values observed in outputs/logits.
    pub nonfinite: usize,
    /// Overflow boundary of the format the scores were stored in — read
    /// off `AttentionOutput::score_boundary` (65504 for the FP16
    /// allocations, 448 for FP8-E4M3). Pressure checks compare
    /// `max_abs_score` against a *fraction of this boundary*, so the
    /// guard follows the active allocation's limit instead of a
    /// hardcoded constant.
    pub boundary: f32,
}

impl Default for GuardSignal {
    /// The empty signal: clean, with a neutral boundary (f32::MAX — the
    /// identity of the min-fold in [`GuardSignal::merge`], so a default
    /// accumulator adopts the first real layer signal's boundary).
    fn default() -> Self {
        GuardSignal {
            overflow_events: 0,
            max_abs_score: 0.0,
            nonfinite: 0,
            boundary: f32::MAX,
        }
    }
}

impl GuardSignal {
    /// Legacy signal from a logits row: counts non-finite entries. The
    /// boundary defaults to FP16's — the format the legacy low-precision
    /// pipelines stored scores in.
    pub fn from_logits(logits: &[f32]) -> GuardSignal {
        GuardSignal {
            overflow_events: 0,
            max_abs_score: 0.0,
            nonfinite: logits.iter().filter(|x| !x.is_finite()).count(),
            boundary: Format::F16.overflow_boundary() as f32,
        }
    }

    /// Rich signal from the attention lab's per-head kernel telemetry,
    /// carrying the allocation's own overflow boundary.
    pub fn from_attention(out: &AttentionOutput) -> GuardSignal {
        GuardSignal {
            overflow_events: out.overflow_events(),
            max_abs_score: out.max_abs_score(),
            nonfinite: out.nonfinite_outputs(),
            boundary: out.score_boundary,
        }
    }

    /// Score pressure as a fraction of the active format's overflow
    /// boundary (1.0 = at the boundary).
    pub fn pressure(&self) -> f32 {
        self.max_abs_score / self.boundary
    }

    /// No overflow, no poisoning, and pressure at or below `limit_frac`
    /// of the active format's overflow boundary (1.0 = trip only past
    /// the boundary itself).
    pub fn is_clean(&self, limit_frac: f32) -> bool {
        self.nonfinite == 0
            && self.overflow_events == 0
            && self.max_abs_score <= limit_frac * self.boundary
    }

    /// Fold another signal in (e.g. one per transformer layer of a decode
    /// step): event counts add, the score maximum is the max, the
    /// boundary is the tightest seen (layers of one step share one
    /// allocation, so in practice the boundaries agree; min is the
    /// conservative fold if they ever differ).
    pub fn merge(&mut self, o: &GuardSignal) {
        self.overflow_events += o.overflow_events;
        self.nonfinite += o.nonfinite;
        if o.max_abs_score > self.max_abs_score {
            self.max_abs_score = o.max_abs_score;
        }
        if o.boundary < self.boundary {
            self.boundary = o.boundary;
        }
    }
}

/// Default pressure trip point of the pre-emptive guard: pin PASA once
/// max |S| crosses this fraction of the active format's overflow boundary.
pub const DEFAULT_PREEMPTIVE_FRAC: f32 = 0.85;

/// Which attention allocation the engine should run next for a request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GuardPolicy {
    /// Always run PASA (the paper's robust default).
    AlwaysPasa,
    /// Always run partially-low-precision FA (fast but overflow-prone).
    AlwaysFa16,
    /// Full-precision FA reference.
    AlwaysFa32,
    /// Start on FA16-32, switch to PASA on overflow (sticky per request).
    /// The tripped step already stored a poisoned score, so it is
    /// *replayed* under PASA.
    Adaptive,
    /// Start on FA16-32 and pin PASA on score *pressure*: once max |S|
    /// crosses `score_limit_frac` of the active format's overflow
    /// boundary, before the first poisoned step. A pressure-only trip
    /// needs **no replay** — the tripping step's outputs are still exact;
    /// only subsequent steps change allocation. (If damage somehow lands
    /// first — e.g. a single-step jump straight past the boundary — the
    /// step is replayed like Adaptive.)
    Preemptive { score_limit_frac: f32 },
}

impl GuardPolicy {
    pub fn parse(s: &str) -> Option<GuardPolicy> {
        match s {
            "pasa" => Some(GuardPolicy::AlwaysPasa),
            "fa16_32" | "fa16" => Some(GuardPolicy::AlwaysFa16),
            "fa32" => Some(GuardPolicy::AlwaysFa32),
            "adaptive" => Some(GuardPolicy::Adaptive),
            "preemptive" => Some(GuardPolicy::Preemptive {
                score_limit_frac: DEFAULT_PREEMPTIVE_FRAC,
            }),
            _ => None,
        }
    }
}

/// The default allocation fallback chain of the switching policies:
/// start on the fast partially-low-precision FA path, rescue to PASA.
const CHAIN_FA16: &[&str] = &["fa16_32", "pasa"];

/// Per-request guard state.
///
/// Switching policies (`Adaptive` / `Preemptive`) walk an **allocation
/// fallback chain** instead of a single FA→PASA flip: each unclean step
/// advances one stage and sticks. The default chain is the classic
/// `fa16_32 → pasa`; an engine started on the FP8 row walks
/// `fp8 → pasa8 → pasa` ([`Guard::fallback_chain`]) — the rescue path
/// first steps *within* the 8-bit envelope (Pasa8's shift moves the
/// overflow site away from 448 without abandoning E4M3 scores) and only
/// escalates to full FP16 PASA if the shifted store still trips.
#[derive(Clone, Debug)]
pub struct Guard {
    policy: GuardPolicy,
    /// Allocation spellings the switching policies walk, mildest first.
    chain: &'static [&'static str],
    /// Current chain stage (0 = the starting allocation).
    stage: usize,
    /// Trip point for max |S| as a fraction of the signal's format
    /// boundary (1.0 = trip only past the boundary itself; the
    /// `Preemptive` policy installs its `score_limit_frac` here).
    score_limit_frac: f32,
    pub switches: usize,
}

impl Guard {
    pub fn new(policy: GuardPolicy) -> Guard {
        let score_limit_frac = match policy {
            GuardPolicy::Preemptive { score_limit_frac } => score_limit_frac,
            GuardPolicy::AlwaysPasa
            | GuardPolicy::AlwaysFa16
            | GuardPolicy::AlwaysFa32
            | GuardPolicy::Adaptive => 1.0,
        };
        Guard {
            policy,
            chain: CHAIN_FA16,
            stage: 0,
            score_limit_frac,
            switches: 0,
        }
    }

    /// The allocation fallback chain rooted at a starting allocation —
    /// every spelling parses back through [`Allocation::parse`] and the
    /// first stage parses back to `start` itself (both pinned by tests —
    /// the chain must never silently substitute a different starting
    /// kernel). FP8 starts step within 8-bit first: `fp8 → pasa8 →
    /// pasa`; the PASA rows have nowhere milder to go than their own
    /// stronger sibling; FA32 cannot overflow, so its chain is itself.
    pub fn fallback_chain(start: Allocation) -> &'static [&'static str] {
        match start {
            Allocation::Fa16_32 => CHAIN_FA16,
            Allocation::Fa16 => &["fa16", "pasa"],
            Allocation::Fp8 => &["fp8", "pasa8", "pasa"],
            Allocation::Pasa8 => &["pasa8", "pasa"],
            Allocation::Pasa16 => &["pasa"],
            Allocation::Fa32 => &["fa32"],
        }
    }

    /// Root the switching policies' fallback chain at `start` (the
    /// engine's `start_alloc` knob). Fixed policies keep their fixed
    /// allocation — the chain only drives `Adaptive` / `Preemptive`.
    pub fn with_start(mut self, start: Allocation) -> Guard {
        self.chain = Self::fallback_chain(start);
        self.stage = 0;
        self
    }

    /// Lower the score trip point to a fraction of the active format's
    /// overflow boundary (e.g. 0.9) to switch on overflow *pressure*
    /// before the first poisoned step.
    pub fn with_score_limit_frac(mut self, frac: f32) -> Guard {
        self.score_limit_frac = frac;
        self
    }

    /// Legacy absolute trip point on the FP16 scale (e.g. 0.9×65504);
    /// converted to a boundary fraction so signals from other formats
    /// (FP8's 448) scale correctly.
    pub fn with_score_limit(self, limit: f32) -> Guard {
        self.with_score_limit_frac(limit / Format::F16.overflow_boundary() as f32)
    }

    /// Allocation to use for the next step.
    pub fn allocation(&self) -> &'static str {
        match self.policy {
            GuardPolicy::AlwaysPasa => "pasa",
            GuardPolicy::AlwaysFa16 => "fa16_32",
            GuardPolicy::AlwaysFa32 => "fa32",
            GuardPolicy::Adaptive | GuardPolicy::Preemptive { .. } => self.chain[self.stage],
        }
    }

    /// Inspect a step's telemetry; returns true if the step must be
    /// replayed under the next chain allocation ([`Self::allocation`]
    /// after this call). Adaptive replays any unclean step; Preemptive
    /// advances on pure score pressure *without* a replay (the step's
    /// outputs are still exact) and replays only when damage — a
    /// pre-store overflow or a non-finite output — already landed. At the
    /// end of the chain there is nothing left to switch to and the
    /// telemetry surfaces as-is.
    pub fn observe_signal(&mut self, sig: &GuardSignal) -> bool {
        if sig.is_clean(self.score_limit_frac) {
            return false;
        }
        let can_step = self.stage + 1 < self.chain.len();
        match self.policy {
            GuardPolicy::Adaptive => {
                if !can_step {
                    return false; // chain exhausted: telemetry surfaces as-is
                }
                self.stage += 1;
                self.switches += 1;
                true
            }
            GuardPolicy::Preemptive { .. } => {
                if !can_step {
                    return false; // chain exhausted: telemetry surfaces as-is
                }
                self.stage += 1;
                self.switches += 1;
                sig.overflow_events > 0 || sig.nonfinite > 0
            }
            // Fixed policies never switch, whatever the signal says.
            GuardPolicy::AlwaysPasa | GuardPolicy::AlwaysFa16 | GuardPolicy::AlwaysFa32 => false,
        }
    }

    /// Legacy logits-only inspection (the runtime path).
    pub fn observe(&mut self, logits: &[f32]) -> bool {
        self.observe_signal(&GuardSignal::from_logits(logits))
    }

    /// True once the guard has left its starting allocation (for the
    /// default chain this is exactly the old "pinned to PASA" state; an
    /// FP8 chain is pinned from its first step onto Pasa8, even though a
    /// later trip may still escalate it to Pasa16).
    pub fn is_pinned(&self) -> bool {
        self.stage > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_switches_once_and_sticks() {
        let mut g = Guard::new(GuardPolicy::Adaptive);
        assert_eq!(g.allocation(), "fa16_32");
        assert!(!g.observe(&[0.0, 1.0]));
        assert_eq!(g.allocation(), "fa16_32");
        assert!(g.observe(&[f32::NAN, 1.0])); // replay requested
        assert_eq!(g.allocation(), "pasa");
        assert_eq!(g.switches, 1);
        // Further overflow (shouldn't happen under PASA) doesn't loop.
        assert!(!g.observe(&[f32::INFINITY]));
        assert_eq!(g.switches, 1);
    }

    #[test]
    fn fixed_policies_never_switch() {
        for (p, alloc) in [
            (GuardPolicy::AlwaysPasa, "pasa"),
            (GuardPolicy::AlwaysFa16, "fa16_32"),
            (GuardPolicy::AlwaysFa32, "fa32"),
        ] {
            let mut g = Guard::new(p);
            assert_eq!(g.allocation(), alloc);
            assert!(!g.observe(&[f32::NAN]));
            assert_eq!(g.allocation(), alloc);
        }
    }

    #[test]
    fn parse_policies() {
        assert_eq!(GuardPolicy::parse("adaptive"), Some(GuardPolicy::Adaptive));
        assert_eq!(GuardPolicy::parse("pasa"), Some(GuardPolicy::AlwaysPasa));
        assert_eq!(
            GuardPolicy::parse("preemptive"),
            Some(GuardPolicy::Preemptive {
                score_limit_frac: DEFAULT_PREEMPTIVE_FRAC
            })
        );
        assert_eq!(GuardPolicy::parse("nope"), None);
    }

    #[test]
    fn guard_spellings_map_onto_lab_allocations() {
        // Every allocation string the guard can emit must resolve to a
        // lab Allocation (the engine's replay path and any lab-backed
        // runtime rely on this bridge staying total) — including every
        // stage of every fallback chain.
        for policy in [
            GuardPolicy::AlwaysPasa,
            GuardPolicy::AlwaysFa16,
            GuardPolicy::AlwaysFa32,
            GuardPolicy::Adaptive,
            GuardPolicy::Preemptive {
                score_limit_frac: 0.8,
            },
        ] {
            let mut g = Guard::new(policy);
            assert!(
                Allocation::parse(g.allocation()).is_some(),
                "{policy:?}: {:?} has no lab allocation",
                g.allocation()
            );
            g.observe(&[f32::NAN]); // flip adaptive to its pinned spelling
            assert!(
                Allocation::parse(g.allocation()).is_some(),
                "{policy:?} (pinned): {:?} has no lab allocation",
                g.allocation()
            );
        }
        for start in Allocation::all_extended() {
            let chain = Guard::fallback_chain(start);
            for s in chain {
                assert!(
                    Allocation::parse(s).is_some(),
                    "chain of {}: {s:?} has no lab allocation",
                    start.name()
                );
            }
            // The first stage must be the requested start itself — a
            // chain that substitutes a different kernel at stage 0 would
            // silently ignore the user's --alloc.
            assert_eq!(
                Allocation::parse(chain[0]),
                Some(start),
                "chain of {} does not start at itself",
                start.name()
            );
        }
    }

    #[test]
    fn fp8_chain_steps_within_8bit_before_abandoning_it() {
        // An adaptive guard rooted at the FP8 row: the first trip rescues
        // to Pasa8 (still E4M3 scores — the shift moves the overflow site
        // away, the envelope stays 8-bit); a second trip escalates to
        // full FP16 PASA; a third has nowhere to go.
        let mut g = Guard::new(GuardPolicy::Adaptive).with_start(Allocation::Fp8);
        assert_eq!(g.allocation(), "fp8");
        assert!(!g.is_pinned());
        let trip = GuardSignal {
            overflow_events: 2,
            max_abs_score: 500.0,
            nonfinite: 0,
            boundary: 448.0,
        };
        assert!(g.observe_signal(&trip), "first trip must replay");
        assert_eq!(g.allocation(), "pasa8");
        assert!(g.is_pinned());
        assert_eq!(g.switches, 1);
        assert!(g.observe_signal(&trip), "second trip must replay");
        assert_eq!(g.allocation(), "pasa");
        assert_eq!(g.switches, 2);
        assert!(!g.observe_signal(&trip), "chain exhausted — surface it");
        assert_eq!(g.allocation(), "pasa");
        assert_eq!(g.switches, 2);
        // A clean signal never advances the chain.
        let mut g = Guard::new(GuardPolicy::Adaptive).with_start(Allocation::Fp8);
        assert!(!g.observe_signal(&GuardSignal::default()));
        assert_eq!(g.allocation(), "fp8");
    }

    #[test]
    fn preemptive_fp8_chain_pins_on_pressure_per_stage() {
        // Pressure at 300/448 = 0.67 > 0.6 advances the pre-emptive chain
        // without a replay; once on Pasa8 the same |S| peak re-evaluates
        // against the *same* 448 boundary but post-shift telemetry — a
        // clean shifted signal keeps the stage.
        let mut g = Guard::new(GuardPolicy::Preemptive {
            score_limit_frac: 0.6,
        })
        .with_start(Allocation::Fp8);
        let pressure = GuardSignal {
            overflow_events: 0,
            max_abs_score: 300.0,
            nonfinite: 0,
            boundary: 448.0,
        };
        assert!(!g.observe_signal(&pressure), "pressure pin, no replay");
        assert_eq!(g.allocation(), "pasa8");
        assert_eq!(g.switches, 1);
        let shifted_clean = GuardSignal {
            overflow_events: 0,
            max_abs_score: 12.0,
            nonfinite: 0,
            boundary: 448.0,
        };
        assert!(!g.observe_signal(&shifted_clean));
        assert_eq!(g.allocation(), "pasa8", "clean shifted step must stick");
    }

    #[test]
    fn fixed_policies_ignore_the_start_knob() {
        let mut g = Guard::new(GuardPolicy::AlwaysPasa).with_start(Allocation::Fp8);
        assert_eq!(g.allocation(), "pasa");
        assert!(!g.observe(&[f32::NAN]));
        assert_eq!(g.allocation(), "pasa");
    }

    #[test]
    fn kernel_telemetry_trips_before_poisoning() {
        // A signal with pre-store overflow events but still-finite outputs
        // must already trip the adaptive guard.
        let mut g = Guard::new(GuardPolicy::Adaptive);
        let sig = GuardSignal {
            overflow_events: 3,
            max_abs_score: 9.0e4,
            nonfinite: 0,
            boundary: 65504.0,
        };
        assert!(g.observe_signal(&sig));
        assert_eq!(g.allocation(), "pasa");
    }

    #[test]
    fn score_limit_is_preemptive() {
        // With a lowered limit, pure score pressure (no overflow yet)
        // trips the guard. The legacy absolute spelling converts onto the
        // fractional scale.
        let pressure = GuardSignal {
            overflow_events: 0,
            max_abs_score: 60000.0,
            nonfinite: 0,
            boundary: 65504.0,
        };
        let mut g = Guard::new(GuardPolicy::Adaptive).with_score_limit(0.9 * 65504.0);
        assert!(g.observe_signal(&pressure));
        let mut g = Guard::new(GuardPolicy::Adaptive).with_score_limit_frac(0.9);
        assert!(g.observe_signal(&pressure));
        // Default limit would not have tripped.
        let mut g = Guard::new(GuardPolicy::Adaptive);
        assert!(!g.observe_signal(&pressure));
    }

    #[test]
    fn preemptive_pins_on_pressure_without_replay() {
        // Pure pressure (no overflow, no NaN): the pre-emptive guard pins
        // PASA for subsequent steps but does NOT ask for a replay — the
        // pressured step's outputs are still exact.
        let mut g = Guard::new(GuardPolicy::Preemptive {
            score_limit_frac: 0.8,
        });
        assert_eq!(g.allocation(), "fa16_32");
        let pressure = GuardSignal {
            overflow_events: 0,
            max_abs_score: 60000.0, // 0.916 of 65504
            nonfinite: 0,
            boundary: 65504.0,
        };
        assert!(!g.observe_signal(&pressure), "pressure must not replay");
        assert!(g.is_pinned());
        assert_eq!(g.allocation(), "pasa");
        assert_eq!(g.switches, 1);
        // ... but if damage lands in one jump, Preemptive replays like
        // Adaptive.
        let mut g = Guard::new(GuardPolicy::Preemptive {
            score_limit_frac: 0.8,
        });
        let damage = GuardSignal {
            overflow_events: 4,
            max_abs_score: 1.2e5,
            nonfinite: 0,
            boundary: 65504.0,
        };
        assert!(g.observe_signal(&damage), "damage must replay");
        assert_eq!(g.allocation(), "pasa");
    }

    #[test]
    fn pressure_scales_to_the_active_format_boundary() {
        // The same |S| peak is clean under an FP16 boundary and pressured
        // under FP8's 448 — the signal's own boundary, not a hardcoded
        // 65504, decides.
        let f16 = GuardSignal {
            overflow_events: 0,
            max_abs_score: 300.0,
            nonfinite: 0,
            boundary: 65504.0,
        };
        assert!(f16.is_clean(0.8));
        let fp8 = GuardSignal {
            boundary: 448.0,
            ..f16
        };
        assert!(!fp8.is_clean(0.8)); // 300 > 0.8 · 448
        assert!((fp8.pressure() - 300.0 / 448.0).abs() < 1e-6);
        let mut g = Guard::new(GuardPolicy::Preemptive {
            score_limit_frac: 0.8,
        });
        assert!(!g.observe_signal(&fp8), "pressure pin, no replay");
        assert!(g.is_pinned());
    }

    #[test]
    fn merge_folds_per_layer_signals() {
        let mut a = GuardSignal {
            overflow_events: 1,
            max_abs_score: 100.0,
            nonfinite: 0,
            boundary: 65504.0,
        };
        a.merge(&GuardSignal {
            overflow_events: 2,
            max_abs_score: 7.0e4,
            nonfinite: 3,
            boundary: 65504.0,
        });
        assert_eq!(a.overflow_events, 3);
        assert_eq!(a.nonfinite, 3);
        assert_eq!(a.max_abs_score, 7.0e4);
        assert_eq!(a.boundary, 65504.0);
        assert!(!a.is_clean(1.0));
        // A default accumulator adopts the first real boundary (min-fold
        // identity), the lab runtime's per-layer merge pattern.
        let mut acc = GuardSignal::default();
        assert!(acc.is_clean(1.0));
        acc.merge(&a);
        assert_eq!(acc.boundary, 65504.0);
    }

    #[test]
    fn signal_from_logits_counts_nonfinite() {
        let sig = GuardSignal::from_logits(&[1.0, f32::NAN, f32::INFINITY, 2.0]);
        assert_eq!(sig.nonfinite, 2);
        assert!(!sig.is_clean(1.0));
        assert!(GuardSignal::from_logits(&[0.5, -0.5]).is_clean(1.0));
    }
}
