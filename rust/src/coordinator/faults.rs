//! Deterministic fault injection (S19): the chaos harness behind the
//! engine's request-lifecycle hardening.
//!
//! A [`FaultPlan`] is a seeded xorshift stream (the fuzz toolkit's
//! [`XorShift64`] — no clocks, no OS entropy) plus per-kind Bernoulli
//! rates and an optional list of [`ScriptedFault`]s. The engine *offers*
//! the plan injection sites as it steps — `(kind, request, site, step)`
//! tuples at the real seams: the KV row a decode just wrote, the backend
//! step about to run, the pool's free list, the admission gate. The plan
//! decides, and **logs every injection** as a [`FaultRecord`], so any
//! run replays exactly from its seed and the `Metrics` robustness
//! counters can be reconciled against the log one-for-one
//! (`rust/tests/integration_chaos.rs` pins both).
//!
//! Sites are offered sequentially in slot order, never inside the worker
//! pool's parallel region, so the injection stream a seed produces is
//! independent of thread interleaving — the same certify-by-harness
//! discipline the differential fuzzer applies to the kernels, lifted to
//! the serving engine.

use super::request::RequestId;
use crate::testkit::XorShift64;

/// Message marker carried by a simulated backend step failure. The
/// engine classifies errors containing it with [`is_injected_error`]
/// (the same pattern `KvPool::EXHAUSTED` uses for backpressure) and
/// quarantines the slot instead of propagating.
pub const INJECTED_STEP_ERROR: &str = "injected backend step fault";

/// True when `e` is a fault-plan-injected backend error: quarantine the
/// slot ([`super::request::FinishReason::Faulted`]), never abort the
/// batch.
pub fn is_injected_error(e: &anyhow::Error) -> bool {
    e.to_string().contains(INJECTED_STEP_ERROR)
}

/// The operational fault kinds the harness can inject. pasa-lint
/// protects this enum (no `_` arms in non-test matches), so adding a
/// kind fails to compile at every dispatch site instead of silently
/// falling through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// NaN-poison the K row a decode step just wrote — silent storage
    /// corruption that surfaces at the *next* read as non-finite logits
    /// (the watchdog's territory).
    KvNanPoison,
    /// Flip an exponent bit in the K row just written — a
    /// huge-but-finite excursion that exercises the numeric guard's
    /// overflow chain rather than the watchdog.
    KvBitFlip,
    /// Seize the pool's free pages for a hold window: an exhaustion
    /// spike. Admission defers; in-flight growth evicts (and, with a
    /// retry budget, comes back).
    PoolSeize,
    /// A backend decode step fails outright (simulated step error).
    StepError,
    /// A decode step takes much longer — observational only (inflates
    /// the recorded step latency; nothing feeds back into scheduling,
    /// so determinism is untouched).
    LatencySpike,
    /// The scheduler stops admitting for a window of steps.
    SchedStall,
    /// The logits row a decode step produced comes back non-finite.
    LogitNan,
}

impl FaultKind {
    pub const COUNT: usize = 7;

    /// Every kind, in [`FaultKind::index`] order.
    pub const ALL: [FaultKind; FaultKind::COUNT] = [
        FaultKind::KvNanPoison,
        FaultKind::KvBitFlip,
        FaultKind::PoolSeize,
        FaultKind::StepError,
        FaultKind::LatencySpike,
        FaultKind::SchedStall,
        FaultKind::LogitNan,
    ];

    /// Dense index for per-kind counters (`0..COUNT`).
    pub fn index(self) -> usize {
        match self {
            FaultKind::KvNanPoison => 0,
            FaultKind::KvBitFlip => 1,
            FaultKind::PoolSeize => 2,
            FaultKind::StepError => 3,
            FaultKind::LatencySpike => 4,
            FaultKind::SchedStall => 5,
            FaultKind::LogitNan => 6,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultKind::KvNanPoison => "kv-nan-poison",
            FaultKind::KvBitFlip => "kv-bit-flip",
            FaultKind::PoolSeize => "pool-seize",
            FaultKind::StepError => "step-error",
            FaultKind::LatencySpike => "latency-spike",
            FaultKind::SchedStall => "sched-stall",
            FaultKind::LogitNan => "logit-nan",
        }
    }
}

/// Per-site Bernoulli rates, one per [`FaultKind`]. A "site" is one
/// offered injection point: once per step for the step-scoped kinds
/// ([`FaultKind::PoolSeize`] / [`FaultKind::SchedStall`]), once per
/// decoding slot per step for the slot-scoped ones.
#[derive(Clone, Copy, Debug)]
pub struct FaultRates {
    pub kv_nan_poison: f64,
    pub kv_bit_flip: f64,
    pub pool_seize: f64,
    pub step_error: f64,
    pub latency_spike: f64,
    pub sched_stall: f64,
    pub logit_nan: f64,
}

impl FaultRates {
    /// No random faults (the scripted-plan base).
    pub fn zero() -> FaultRates {
        FaultRates {
            kv_nan_poison: 0.0,
            kv_bit_flip: 0.0,
            pool_seize: 0.0,
            step_error: 0.0,
            latency_spike: 0.0,
            sched_stall: 0.0,
            logit_nan: 0.0,
        }
    }

    /// The chaos-soak mix: every seam exercised within a few hundred
    /// steps, no single kind dominating the run.
    pub fn standard() -> FaultRates {
        FaultRates {
            kv_nan_poison: 0.01,
            kv_bit_flip: 0.01,
            pool_seize: 0.03,
            step_error: 0.01,
            latency_spike: 0.02,
            sched_stall: 0.03,
            logit_nan: 0.01,
        }
    }

    /// The same rate for every kind — the bench grid's single knob.
    pub fn uniform(p: f64) -> FaultRates {
        FaultRates {
            kv_nan_poison: p,
            kv_bit_flip: p,
            pool_seize: p,
            step_error: p,
            latency_spike: p,
            sched_stall: p,
            logit_nan: p,
        }
    }

    fn rate(&self, kind: FaultKind) -> f64 {
        match kind {
            FaultKind::KvNanPoison => self.kv_nan_poison,
            FaultKind::KvBitFlip => self.kv_bit_flip,
            FaultKind::PoolSeize => self.pool_seize,
            FaultKind::StepError => self.step_error,
            FaultKind::LatencySpike => self.latency_spike,
            FaultKind::SchedStall => self.sched_stall,
            FaultKind::LogitNan => self.logit_nan,
        }
    }
}

/// One precisely-placed fault: fires the first time the engine offers a
/// matching `(kind, request, site)` tuple, then never again.
///
/// `site` is seam-scoped: for the slot-scoped kinds it is the request's
/// generated-token count at the offered seam — identical in solo and
/// batched runs, which is what makes the quarantine co-batch
/// bit-identity test exact. For the step-scoped kinds
/// ([`FaultKind::PoolSeize`] / [`FaultKind::SchedStall`]) it is the
/// engine step itself (and `request_id` is 0).
#[derive(Clone, Copy, Debug)]
pub struct ScriptedFault {
    pub kind: FaultKind,
    pub request_id: RequestId,
    pub site: u64,
    fired: bool,
}

impl ScriptedFault {
    pub fn new(kind: FaultKind, request_id: RequestId, site: u64) -> ScriptedFault {
        ScriptedFault {
            kind,
            request_id,
            site,
            fired: false,
        }
    }
}

/// One injection, as logged: enough to replay a run's damage and to
/// reconcile the metrics counters against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultRecord {
    /// Engine step at which the injection fired.
    pub step: u64,
    pub kind: FaultKind,
    /// The targeted request (0 for the step-scoped kinds).
    pub target: RequestId,
}

/// A seeded, replayable fault schedule. Install on an engine with
/// `Engine::install_faults`; the engine offers it sites as it steps.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    rng: XorShift64,
    rates: FaultRates,
    /// Pages grabbed from the free list per [`FaultKind::PoolSeize`]
    /// injection.
    pub seize_pages: usize,
    /// Steps a seizure holds its pages before releasing them.
    pub seize_hold_steps: u64,
    /// Steps a [`FaultKind::SchedStall`] blocks admission.
    pub stall_steps: u64,
    /// Seconds a [`FaultKind::LatencySpike`] adds to the recorded step
    /// latency (observational only).
    pub latency_spike_secs: f64,
    scripted: Vec<ScriptedFault>,
    log: Vec<FaultRecord>,
}

impl FaultPlan {
    pub fn new(seed: u64, rates: FaultRates) -> FaultPlan {
        FaultPlan {
            rng: XorShift64::new(seed),
            rates,
            seize_pages: 8,
            seize_hold_steps: 4,
            stall_steps: 3,
            latency_spike_secs: 0.25,
            scripted: Vec::new(),
            log: Vec::new(),
        }
    }

    /// The chaos-soak preset: [`FaultRates::standard`] from `seed`.
    pub fn standard(seed: u64) -> FaultPlan {
        FaultPlan::new(seed, FaultRates::standard())
    }

    /// A plan that fires *only* the given scripted faults — zero random
    /// rates, so the run is exact down to the single injection (and
    /// consumes no randomness at all).
    pub fn scripted(faults: Vec<ScriptedFault>) -> FaultPlan {
        let mut p = FaultPlan::new(0, FaultRates::zero());
        p.scripted = faults;
        p
    }

    /// Offer the plan an injection site; returns whether to inject, and
    /// logs the injection if so. Scripted faults match first (each fires
    /// at most once); otherwise the kind's rate draws on the seeded
    /// stream. The RNG is consulted **only when the kind's rate is
    /// nonzero**, so a scripted plan's behaviour is independent of how
    /// many sites the engine happens to offer.
    pub fn fires(&mut self, kind: FaultKind, target: RequestId, site: u64, step: u64) -> bool {
        let scripted_hit = self
            .scripted
            .iter_mut()
            .find(|f| !f.fired && f.kind == kind && f.request_id == target && f.site == site);
        let fire = if let Some(f) = scripted_hit {
            f.fired = true;
            true
        } else {
            let rate = self.rates.rate(kind);
            rate > 0.0 && self.rng.chance(rate)
        };
        if fire {
            self.log.push(FaultRecord { step, kind, target });
        }
        fire
    }

    /// Every injection so far, in firing order.
    pub fn log(&self) -> &[FaultRecord] {
        &self.log
    }

    /// Injections by kind, indexed by [`FaultKind::index`] — what the
    /// `Metrics` robustness counters must reconcile against exactly.
    pub fn counts(&self) -> [u64; FaultKind::COUNT] {
        let mut out = [0u64; FaultKind::COUNT];
        for r in &self.log {
            out[r.kind.index()] += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_replays_the_same_injection_stream() {
        let run = || {
            let mut p = FaultPlan::standard(0xC0FFEE);
            let mut fired = Vec::new();
            for step in 0..200u64 {
                for id in 1..=3u64 {
                    for kind in FaultKind::ALL {
                        if p.fires(kind, id, step, step) {
                            fired.push((step, id, kind));
                        }
                    }
                }
            }
            (fired, p.log().to_vec(), p.counts())
        };
        let (a, log_a, counts_a) = run();
        let (b, log_b, counts_b) = run();
        assert_eq!(a, b, "same seed must replay the same stream");
        assert_eq!(log_a, log_b);
        assert_eq!(counts_a, counts_b);
        assert!(!a.is_empty(), "standard rates over 600 sites must fire");
        let total: u64 = counts_a.iter().sum();
        assert_eq!(total, log_a.len() as u64, "counts must sum to the log");
    }

    #[test]
    fn scripted_faults_fire_exactly_once_and_only_at_their_site() {
        let mut p = FaultPlan::scripted(vec![ScriptedFault::new(FaultKind::LogitNan, 7, 3)]);
        let mut hits = 0;
        for step in 0..50u64 {
            for site in 0..10u64 {
                if p.fires(FaultKind::LogitNan, 7, site, step) {
                    assert_eq!(site, 3, "must fire at the scripted site only");
                    hits += 1;
                }
                assert!(!p.fires(FaultKind::StepError, 7, site, step));
                assert!(!p.fires(FaultKind::LogitNan, 8, site, step));
            }
        }
        assert_eq!(hits, 1, "a scripted fault fires exactly once");
        assert_eq!(p.log().len(), 1);
        assert_eq!(p.counts()[FaultKind::LogitNan.index()], 1);
        assert_eq!(
            p.log()[0],
            FaultRecord {
                step: 0,
                kind: FaultKind::LogitNan,
                target: 7
            }
        );
    }

    #[test]
    fn kind_index_and_all_agree() {
        for (i, k) in FaultKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
            assert!(!k.name().is_empty());
        }
    }
}
