//! Serving engine (S11): continuous-batching loop over the AOT model.
//!
//! One `step()` = one scheduler iteration:
//!   1. admit queued requests into free decode slots (prefill, B=1 module,
//!      KV seeded into the paged pool),
//!   2. run one decode step per allocation group (slots pinned to PASA by
//!      the overflow guard run separately from fast-path slots),
//!   3. guard inspection: non-finite logits ⇒ replay the step under PASA
//!      (functional cache-in/cache-out makes replay exact), pin the slot,
//!   4. sample, write the new KV row back into the paged cache, retire
//!      finished requests.
//!
//! The decode HLO has a fixed batch bucket B; inactive slots are masked by
//! feeding pos=0/token=PAD and ignoring their outputs (their cache slots
//! are re-assembled from the paged pool each step, so scribbles from
//! masked lanes never persist).

use super::guard::{Guard, GuardPolicy, GuardSignal};
use super::kv_cache::{KvPool, SeqCache};
use super::metrics::Metrics;
use super::request::{Completion, FinishReason, Phase, Request};
use super::router::{Admission, Router};
use crate::model::{sample, tokenizer, Specials};
use crate::runtime::ModelRuntime;
use crate::workloads::Pcg64;
use anyhow::{Context, Result};
use std::time::Instant;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub policy: GuardPolicy,
    /// Total pages in the KV pool.
    pub kv_pages: usize,
    /// Tokens per page.
    pub page_tokens: usize,
    pub max_queue: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: GuardPolicy::Adaptive,
            kv_pages: 4096,
            page_tokens: 32,
            max_queue: 256,
        }
    }
}

struct ActiveRequest {
    req: Request,
    guard: Guard,
    cache: SeqCache,
    /// Prompt + generated token ids.
    tokens: Vec<u32>,
    prompt_len: usize,
    phase: Phase,
    prefill_done: Option<Instant>,
    first_token: Option<Instant>,
}

/// The continuous-batching serving engine.
pub struct Engine<'rt> {
    rt: &'rt ModelRuntime,
    pub cfg: EngineConfig,
    pub router: Router,
    pool: KvPool,
    slots: Vec<Option<ActiveRequest>>,
    pub metrics: Metrics,
    completions: Vec<Completion>,
    rng: Pcg64,
    sp: Specials,
    // Reusable batch assembly buffers (hot-loop allocation hoisting).
    kbatch: Vec<f32>,
    vbatch: Vec<f32>,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt ModelRuntime, cfg: EngineConfig) -> Engine<'rt> {
        let d = rt.dims;
        let b = d.decode_batch;
        let cache_len = d.n_layers * b * d.max_seq * d.head_width();
        let sp = Specials {
            pad: d.pad,
            bos: d.bos,
            eos: d.eos,
        };
        Engine {
            rt,
            router: Router::new(cfg.max_queue, d.prefill_seq * 4),
            pool: KvPool::new(cfg.kv_pages, cfg.page_tokens, d.head_width()),
            slots: (0..b).map(|_| None).collect(),
            metrics: Metrics::new(),
            completions: Vec::new(),
            rng: Pcg64::new(0xe61e, 0),
            sp,
            kbatch: vec![0.0; cache_len],
            vbatch: vec![0.0; cache_len],
            cfg,
        }
    }

    /// Submit a request (admission-checked).
    pub fn submit(&mut self, req: Request) -> Admission {
        self.router.submit(req)
    }

    pub fn fresh_id(&mut self) -> u64 {
        self.router.fresh_id()
    }

    /// True when no queued or active work remains.
    pub fn idle(&self) -> bool {
        self.router.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    pub fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    pub fn kv_utilization(&self) -> f64 {
        self.pool.utilization()
    }

    /// One scheduler iteration. Returns the number of active slots after
    /// the step (0 = fully idle).
    pub fn step(&mut self) -> Result<usize> {
        self.admit_loop()?;
        if self.slots.iter().any(|s| s.is_some()) {
            self.decode_round()?;
        }
        Ok(self.active_count())
    }

    /// Run until the queue and all slots drain; returns completions.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while !self.idle() {
            self.step()?;
        }
        Ok(self.take_completions())
    }

    // ---- admission / prefill ------------------------------------------

    fn admit_loop(&mut self) -> Result<()> {
        let d = self.rt.dims;
        loop {
            let free_slot = match self.slots.iter().position(|s| s.is_none()) {
                Some(i) => i,
                None => return Ok(()),
            };
            // Capacity check: a full-context sequence must fit in pages.
            let need = SeqCache::pages_required(d.n_layers, d.max_seq, self.pool.page_tokens);
            if self.pool.free_pages() < need {
                return Ok(()); // backpressure: keep queued
            }
            let req = match self.router.pop() {
                Some(r) => r,
                None => return Ok(()),
            };
            let active = self.prefill_request(req)?;
            self.slots[free_slot] = Some(active);
        }
    }

    fn prefill_request(&mut self, req: Request) -> Result<ActiveRequest> {
        let d = self.rt.dims;
        let (mut ids, n) = tokenizer::encode(&req.prompt, d.prefill_seq, self.sp);
        ids.truncate(d.prefill_seq);
        let mut guard = Guard::new(self.cfg.policy);

        let t0 = Instant::now();
        let mut out = self
            .rt
            .prefill(guard.allocation(), &ids, n)
            .context("prefill")?;
        // Guard: inspect the last-prompt-token logits row for overflow.
        // (The PJRT modules are uninstrumented, so this is the legacy
        // logits signal; the attention lab feeds kernel telemetry via
        // GuardSignal::from_attention instead.)
        let v = d.vocab_size;
        let last_row = &out.logits[(n - 1) * v..n * v];
        let sig = GuardSignal::from_logits(last_row);
        if guard.observe_signal(&sig) {
            self.metrics.overflow_steps += 1;
            self.metrics.guard_switches += 1;
            out = self
                .rt
                .prefill(guard.allocation(), &ids, n)
                .context("prefill replay under PASA")?;
        }
        let prefill_done = Instant::now();
        self.metrics.prefill_tokens += n as u64;

        // Seed the paged cache from the dense prefill output.
        let mut cache = SeqCache::new(d.n_layers);
        cache.ensure_capacity(&mut self.pool, n)?;
        let w = d.head_width();
        let per_layer = d.max_seq * w;
        for l in 0..d.n_layers {
            for p in 0..n {
                let off = l * per_layer + p * w;
                let krow = out.cache.k[off..off + w].to_vec();
                let vrow = out.cache.v[off..off + w].to_vec();
                cache.write_row(&mut self.pool, l, p, &krow, &vrow);
            }
        }

        // First generated token comes from the prompt's last logits row.
        let last_row = &out.logits[(n - 1) * v..n * v];
        let tok = sample(last_row, req.params.sampling, &mut self.rng);
        let mut tokens: Vec<u32> = ids[..n].to_vec();
        tokens.push(tok);

        let mut ar = ActiveRequest {
            req,
            guard,
            cache,
            tokens,
            prompt_len: n,
            phase: Phase::Decoding,
            prefill_done: Some(prefill_done),
            first_token: Some(Instant::now()),
        };
        let _ = t0;
        // Immediately-finished cases (max_new_tokens == 0 is nonsensical
        // but must not wedge the slot).
        if ar.req.params.max_new_tokens == 0 {
            ar.phase = Phase::Finished(FinishReason::MaxTokens);
        }
        Ok(ar)
    }

    // ---- decode --------------------------------------------------------

    /// Distinct allocations among active slots this round.
    fn allocation_groups(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for s in self.slots.iter().flatten() {
            let a = s.guard.allocation();
            if !out.contains(&a) {
                out.push(a);
            }
        }
        out
    }

    fn decode_round(&mut self) -> Result<()> {
        for alloc in self.allocation_groups() {
            self.decode_group(alloc)?;
        }
        // Retire finished requests.
        let b = self.slots.len();
        for i in 0..b {
            let done = matches!(
                self.slots[i].as_ref().map(|s| s.phase),
                Some(Phase::Finished(_))
            );
            if done {
                let mut ar = self.slots[i].take().unwrap();
                ar.cache.release(&mut self.pool);
                self.finish(ar);
            }
        }
        Ok(())
    }

    /// One batched decode step for every active slot on `alloc`.
    fn decode_group(&mut self, alloc: &'static str) -> Result<()> {
        let d = self.rt.dims;
        let b = d.decode_batch;
        let w = d.head_width();
        let v = d.vocab_size;
        let seq_floats = d.max_seq * w;

        let members: Vec<usize> = (0..b)
            .filter(|&i| {
                self.slots[i]
                    .as_ref()
                    .map(|s| s.guard.allocation() == alloc && s.phase == Phase::Decoding)
                    .unwrap_or(false)
            })
            .collect();
        if members.is_empty() {
            return Ok(());
        }
        self.metrics
            .decode_batch_occupancy
            .push(members.len());

        // Assemble the dense batch caches from the paged pool.
        self.kbatch.fill(0.0);
        self.vbatch.fill(0.0);
        let mut tokens = vec![self.sp.pad as i32; b];
        let mut pos = vec![0i32; b];
        for &i in &members {
            let s = self.slots[i].as_ref().unwrap();
            let p = s.tokens.len() - 1; // position of the token being fed
            tokens[i] = *s.tokens.last().unwrap() as i32;
            pos[i] = p as i32;
            for l in 0..d.n_layers {
                let off = (l * b + i) * seq_floats;
                s.cache
                    .fill_dense(&self.pool, l, false, &mut self.kbatch[off..off + seq_floats]);
                s.cache
                    .fill_dense(&self.pool, l, true, &mut self.vbatch[off..off + seq_floats]);
            }
        }

        let t0 = Instant::now();
        let (mut logits, mut kout, mut vout) = self
            .rt
            .decode(alloc, &tokens, &pos, &self.kbatch, &self.vbatch)
            .context("decode")?;
        self.metrics.decode_steps += 1;
        self.metrics
            .step_latency
            .record(t0.elapsed().as_secs_f64());

        // Guard pass: any member overflowing gets the whole group's step
        // replayed under PASA (cache inputs unchanged — replay is exact).
        let mut replay = false;
        for &i in &members {
            let sig = GuardSignal::from_logits(&logits[i * v..(i + 1) * v]);
            let s = self.slots[i].as_mut().unwrap();
            if s.guard.observe_signal(&sig) {
                replay = true;
                self.metrics.guard_switches += 1;
            }
            if sig.nonfinite > 0 {
                self.metrics.overflow_steps += 1;
            }
        }
        if replay {
            let (l2, k2, v2) = self
                .rt
                .decode("pasa", &tokens, &pos, &self.kbatch, &self.vbatch)
                .context("decode replay under PASA")?;
            logits = l2;
            kout = k2;
            vout = v2;
            self.metrics.decode_steps += 1;
        }

        // Write back the new KV row, sample, advance. The decode module
        // returns only the new rows, shaped (L, B, W).
        for &i in &members {
            let s = self.slots[i].as_mut().unwrap();
            let p = pos[i] as usize;
            s.cache.ensure_capacity(&mut self.pool, p + 1)?;
            for l in 0..d.n_layers {
                let off = (l * b + i) * w;
                let krow = kout[off..off + w].to_vec();
                let vrow = vout[off..off + w].to_vec();
                s.cache.write_row(&mut self.pool, l, p, &krow, &vrow);
            }
            let row = &logits[i * v..(i + 1) * v];
            let tok = sample(row, s.req.params.sampling, &mut self.rng);
            if s.first_token.is_none() {
                s.first_token = Some(Instant::now());
            }
            s.tokens.push(tok);
            self.metrics.tokens_generated += 1;

            let generated = s.tokens.len() - s.prompt_len;
            if s.req.params.stop_at_eos && tok == self.sp.eos {
                s.phase = Phase::Finished(FinishReason::Eos);
            } else if generated >= s.req.params.max_new_tokens {
                s.phase = Phase::Finished(FinishReason::MaxTokens);
            } else if s.tokens.len() >= d.max_seq {
                s.phase = Phase::Finished(FinishReason::ContextFull);
            }
        }
        Ok(())
    }

    fn finish(&mut self, ar: ActiveRequest) {
        let now = Instant::now();
        let reason = match ar.phase {
            Phase::Finished(r) => r,
            _ => FinishReason::MaxTokens,
        };
        let queue_time = ar
            .prefill_done
            .map(|t| (t - ar.req.arrival).as_secs_f64())
            .unwrap_or(0.0);
        let ttft = ar
            .first_token
            .map(|t| (t - ar.req.arrival).as_secs_f64())
            .unwrap_or(0.0);
        let total = (now - ar.req.arrival).as_secs_f64();
        self.metrics.ttft.record(ttft);
        self.metrics.total_latency.record(total);
        self.metrics.requests_completed += 1;
        let gen_ids: Vec<u32> = ar.tokens[ar.prompt_len..].to_vec();
        self.completions.push(Completion {
            id: ar.req.id,
            prompt: ar.req.prompt.clone(),
            text: tokenizer::decode(&gen_ids, self.sp),
            tokens: gen_ids,
            reason,
            prompt_tokens: ar.prompt_len,
            queue_time,
            prefill_time: queue_time,
            first_token_latency: ttft,
            total_latency: total,
            allocation: ar.guard.allocation().to_string(),
            guard_switches: ar.guard.switches,
        });
    }
}
