//! Serving engine (S11): continuous-batching loop over one of two model
//! backends.
//!
//! One `step()` = one scheduler iteration:
//!   1. admit queued requests into free decode slots (prefill, KV seeded
//!      into the paged pool),
//!   2. one decode step per active slot (grouped per allocation on the
//!      PJRT backend; per-slot paged requests on the lab backend),
//!   3. guard inspection ⇒ replay the step under PASA (functional
//!      cache-in/cache-out makes replay exact), pin the slot. Under the
//!      [`GuardPolicy::Preemptive`] knob the pin fires on score
//!      *pressure* (max |S| approaching the active format's overflow
//!      boundary) with **no replay** — the pressured step's outputs are
//!      still exact, so only subsequent steps change allocation,
//!   4. sample, write the new KV row back into the paged cache, retire
//!      finished requests.
//!
//! ## Backends
//!
//! * [`Backend::Lab`] — the pure-Rust [`LabModel`]: every decode step
//!   builds per-slot paged [`crate::attention::AttentionRequest`]s
//!   (`s1 = 1` query row against a `KvView::Paged` of `len_tokens` rows),
//!   so per-step cache work is `O(len_tokens)` gathers, and the guard
//!   consumes `GuardSignal::from_attention` — pre-store max |S| and
//!   overflow events straight from the score GEMM, the paper's
//!   instrumentation point.
//! * [`Backend::Pjrt`] — the AOT HLO runtime. Its decode module consumes a
//!   dense `(L, B, max_seq, W)` cache, so this path still assembles the
//!   batch with `fill_dense` and falls back to legacy logits NaN-sniffing
//!   (the compiled modules are uninstrumented). It is the *fallback*
//!   signal source; the lab path never uses it.
//!
//! KV-pool exhaustion mid-flight (copy-on-write growth) is backpressure:
//! the slot finishes with [`FinishReason::Evicted`] and its pages return
//! to the pool — never a panic, never a corrupted cache.

use super::guard::{Guard, GuardPolicy, GuardSignal};
use super::kv_cache::{KvPool, SeqCache};
use super::metrics::Metrics;
use super::request::{Completion, FinishReason, Phase, Request};
use super::router::{Admission, Router};
use crate::attention::Allocation;
use crate::model::{sample, tokenizer, ModelDims, Specials};
use crate::runtime::{LabModel, ModelRuntime};
use crate::workloads::Pcg64;
use anyhow::{Context, Result};
use std::sync::Mutex;
use std::time::Instant;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub policy: GuardPolicy,
    /// Starting attention allocation of the *switching* guard policies —
    /// the root of the guard's fallback chain (`--alloc` on the CLI).
    /// `Fa16_32` keeps the classic `fa16_32 → pasa` behaviour; `Fp8`
    /// walks `fp8 → pasa8 → pasa`, rescuing within the 8-bit envelope
    /// before abandoning it. Fixed policies (`AlwaysPasa` & co.) ignore
    /// it. **Lab backend only** for non-default values: the PJRT
    /// manifest ships no fp8/pasa8 modules, and its batched group-replay
    /// path replays under "pasa" — the CLI rejects a non-default
    /// `--alloc` on the PJRT serve path for exactly this reason.
    pub start_alloc: Allocation,
    /// Total pages in the KV pool.
    pub kv_pages: usize,
    /// Tokens per page.
    pub page_tokens: usize,
    pub max_queue: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: GuardPolicy::Adaptive,
            start_alloc: Allocation::Fa16_32,
            kv_pages: 4096,
            page_tokens: 32,
            max_queue: 256,
        }
    }
}

/// The model execution backend behind the engine (see module docs).
pub enum Backend<'rt> {
    Pjrt(&'rt ModelRuntime),
    Lab(Box<LabModel>),
}

/// True when an error is KV-pool exhaustion — the one failure the engine
/// treats as backpressure (evict the slot) rather than a bug to surface.
/// Delegates to the pool's own classifier so the marker lives next to the
/// message that carries it.
fn is_kv_backpressure(e: &anyhow::Error) -> bool {
    KvPool::is_exhausted_error(e)
}

/// Observe a step signal on a guard, folding any pin into the engine
/// metrics; returns whether the step must be replayed under PASA. The
/// guard's own `switches` counter is the source of truth for pin events
/// (a `Preemptive` pressure pin increments it without requesting a
/// replay), so the metric can never drift from the guard state.
fn observe_guard(guard: &mut Guard, sig: &GuardSignal, metrics: &mut Metrics) -> bool {
    let before = guard.switches;
    let replay = guard.observe_signal(sig);
    metrics.guard_switches += (guard.switches - before) as u64;
    replay
}

struct ActiveRequest {
    req: Request,
    guard: Guard,
    cache: SeqCache,
    /// Prompt + generated token ids.
    tokens: Vec<u32>,
    prompt_len: usize,
    phase: Phase,
    /// When the request left the queue (prefill started).
    admitted: Instant,
    prefill_done: Option<Instant>,
    first_token: Option<Instant>,
}

/// The continuous-batching serving engine.
pub struct Engine<'rt> {
    backend: Backend<'rt>,
    dims: ModelDims,
    pub cfg: EngineConfig,
    pub router: Router,
    pool: KvPool,
    slots: Vec<Option<ActiveRequest>>,
    pub metrics: Metrics,
    completions: Vec<Completion>,
    rng: Pcg64,
    sp: Specials,
    // Reusable batch assembly buffers (PJRT path only — the lab path
    // never assembles a dense cache).
    kbatch: Vec<f32>,
    vbatch: Vec<f32>,
}

impl<'rt> Engine<'rt> {
    /// Engine over the PJRT runtime (AOT artifacts).
    pub fn new(rt: &'rt ModelRuntime, cfg: EngineConfig) -> Engine<'rt> {
        Self::with_backend(Backend::Pjrt(rt), rt.dims, cfg)
    }

    /// Engine over the pure-Rust lab runtime — paged decode through the
    /// kernel registry, no artifacts required.
    pub fn from_lab(model: LabModel, cfg: EngineConfig) -> Engine<'static> {
        let dims = model.dims;
        Engine::with_backend(Backend::Lab(Box::new(model)), dims, cfg)
    }

    fn with_backend(backend: Backend<'rt>, dims: ModelDims, cfg: EngineConfig) -> Engine<'rt> {
        let b = dims.decode_batch;
        let cache_len = match backend {
            // The PJRT decode module wants the dense (L, B, max_seq, W)
            // cache tensors; the lab backend reads pages directly.
            Backend::Pjrt(_) => dims.n_layers * b * dims.max_seq * dims.head_width(),
            Backend::Lab(_) => 0,
        };
        let sp = Specials {
            pad: dims.pad,
            bos: dims.bos,
            eos: dims.eos,
        };
        Engine {
            backend,
            dims,
            router: Router::new(cfg.max_queue, dims.prefill_seq * 4),
            pool: KvPool::new(cfg.kv_pages, cfg.page_tokens, dims.head_width()),
            slots: (0..b).map(|_| None).collect(),
            metrics: Metrics::new(),
            completions: Vec::new(),
            rng: Pcg64::new(0xe61e, 0),
            sp,
            kbatch: vec![0.0; cache_len],
            vbatch: vec![0.0; cache_len],
            cfg,
        }
    }

    /// Submit a request (admission-checked).
    pub fn submit(&mut self, req: Request) -> Admission {
        self.router.submit(req)
    }

    pub fn fresh_id(&mut self) -> u64 {
        self.router.fresh_id()
    }

    /// True when no queued or active work remains.
    pub fn idle(&self) -> bool {
        self.router.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    pub fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    pub fn kv_utilization(&self) -> f64 {
        self.pool.utilization()
    }

    /// The paged KV pool (read-only; tests inspect cache contents).
    pub fn kv_pool(&self) -> &KvPool {
        &self.pool
    }

    /// The paged cache of an active slot, if occupied.
    pub fn slot_cache(&self, slot: usize) -> Option<&SeqCache> {
        self.slots.get(slot)?.as_ref().map(|s| &s.cache)
    }

    /// The allocation an active slot's guard would run next.
    pub fn slot_allocation(&self, slot: usize) -> Option<&'static str> {
        self.slots.get(slot)?.as_ref().map(|s| s.guard.allocation())
    }

    /// One scheduler iteration. Returns the number of active slots after
    /// the step (0 = fully idle).
    pub fn step(&mut self) -> Result<usize> {
        self.admit_loop()?;
        if self.slots.iter().any(|s| s.is_some()) {
            self.decode_round()?;
        }
        Ok(self.active_count())
    }

    /// Run until the queue and all slots drain; returns completions.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while !self.idle() {
            self.step()?;
        }
        Ok(self.take_completions())
    }

    // ---- admission / prefill ------------------------------------------

    fn admit_loop(&mut self) -> Result<()> {
        let d = self.dims;
        loop {
            let free_slot = match self.slots.iter().position(|s| s.is_none()) {
                Some(i) => i,
                None => return Ok(()),
            };
            // Capacity check: a full-context sequence must fit in pages.
            let need = SeqCache::pages_required(d.n_layers, d.max_seq, self.pool.page_tokens);
            if self.pool.free_pages() < need {
                return Ok(()); // backpressure: keep queued
            }
            let req = match self.router.pop() {
                Some(r) => r,
                None => return Ok(()),
            };
            let is_lab = matches!(self.backend, Backend::Lab(_));
            // Copy-only bookkeeping for the (shouldn't-happen) rejection
            // path — no per-admission prompt clone.
            let (rid, arrival) = (req.id, req.arrival);
            let admitted = Instant::now();
            let active = if is_lab {
                self.prefill_lab(req)
            } else {
                self.prefill_pjrt(req)
            };
            match active {
                Ok(a) => self.slots[free_slot] = Some(a),
                // Shouldn't happen — admission pre-reserves max_seq worth
                // of pages — but if pool accounting ever drifts, reject
                // this one request instead of killing the engine (and
                // every other in-flight request) on an expected capacity
                // condition.
                Err(e) if is_kv_backpressure(&e) => {
                    self.reject_evicted(rid, arrival, admitted)
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Complete a request that could not be admitted (pool exhaustion at
    /// prefill): an Evicted completion with correct time attribution —
    /// queueing up to `admitted`, the failed forward as prefill time — so
    /// the caller sees the outcome instead of a dead engine. The prompt
    /// echo is empty (the request was consumed by the failed prefill; this
    /// path trades the echo for not cloning every admitted prompt).
    fn reject_evicted(&mut self, id: u64, arrival: Instant, admitted: Instant) {
        let now = Instant::now();
        self.metrics.requests_completed += 1;
        self.completions.push(Completion {
            id,
            prompt: String::new(),
            text: String::new(),
            tokens: Vec::new(),
            reason: FinishReason::Evicted,
            prompt_tokens: 0,
            queue_time: (admitted - arrival).as_secs_f64(),
            prefill_time: (now - admitted).as_secs_f64(),
            first_token_latency: 0.0,
            total_latency: (now - arrival).as_secs_f64(),
            allocation: String::new(),
            guard_switches: 0,
        });
    }

    /// Wrap a finished prefill into the slot state (shared tail of both
    /// backend prefill paths).
    #[allow(clippy::too_many_arguments)]
    fn activate(
        req: Request,
        guard: Guard,
        cache: SeqCache,
        tokens: Vec<u32>,
        prompt_len: usize,
        admitted: Instant,
        prefill_done: Instant,
    ) -> ActiveRequest {
        let mut ar = ActiveRequest {
            req,
            guard,
            cache,
            tokens,
            prompt_len,
            phase: Phase::Decoding,
            admitted,
            prefill_done: Some(prefill_done),
            first_token: Some(Instant::now()),
        };
        // Immediately-finished cases (max_new_tokens == 0 is nonsensical
        // but must not wedge the slot).
        if ar.req.params.max_new_tokens == 0 {
            ar.phase = Phase::Finished(FinishReason::MaxTokens);
        }
        ar
    }

    fn prefill_pjrt(&mut self, req: Request) -> Result<ActiveRequest> {
        let d = self.dims;
        let Backend::Pjrt(rt) = &self.backend else {
            unreachable!("prefill_pjrt on a lab engine")
        };
        let rt = *rt;
        let (mut ids, n) = tokenizer::encode(&req.prompt, d.prefill_seq, self.sp);
        ids.truncate(d.prefill_seq);
        let mut guard = Guard::new(self.cfg.policy).with_start(self.cfg.start_alloc);

        let admitted = Instant::now();
        let mut out = rt
            .prefill(guard.allocation(), &ids, n)
            .context("prefill")?;
        // Guard: inspect the last-prompt-token logits row for overflow.
        // (The PJRT modules are uninstrumented, so this is the legacy
        // logits signal — the fallback; the lab backend feeds kernel
        // telemetry via GuardSignal::from_attention instead.)
        let v = d.vocab_size;
        let last_row = &out.logits[(n - 1) * v..n * v];
        let sig = GuardSignal::from_logits(last_row);
        if observe_guard(&mut guard, &sig, &mut self.metrics) {
            self.metrics.overflow_steps += 1;
            out = rt
                .prefill(guard.allocation(), &ids, n)
                .context("prefill replay under PASA")?;
        }
        let prefill_done = Instant::now();
        self.metrics.prefill_tokens += n as u64;

        // Seed the paged cache from the dense prefill output. On any
        // failure the partially-grown cache must hand its pages back —
        // leaking them would shrink the pool for every later request.
        let mut cache = SeqCache::new(d.n_layers);
        let w = d.head_width();
        let per_layer = d.max_seq * w;
        let seeded = (|| -> Result<()> {
            cache.ensure_capacity(&mut self.pool, n)?;
            for l in 0..d.n_layers {
                for p in 0..n {
                    let off = l * per_layer + p * w;
                    cache.write_row(
                        &mut self.pool,
                        l,
                        p,
                        &out.cache.k[off..off + w],
                        &out.cache.v[off..off + w],
                    )?;
                }
            }
            Ok(())
        })();
        if let Err(e) = seeded {
            cache.release(&mut self.pool);
            return Err(e.context("prefill cache seeding"));
        }

        // First generated token comes from the prompt's last logits row.
        let last_row = &out.logits[(n - 1) * v..n * v];
        let tok = sample(last_row, req.params.sampling, &mut self.rng);
        let mut tokens: Vec<u32> = ids[..n].to_vec();
        tokens.push(tok);
        Ok(Self::activate(
            req,
            guard,
            cache,
            tokens,
            n,
            admitted,
            prefill_done,
        ))
    }

    fn prefill_lab(&mut self, req: Request) -> Result<ActiveRequest> {
        let d = self.dims;
        let (ids, n) = tokenizer::encode(&req.prompt, d.prefill_seq, self.sp);
        let mut guard = Guard::new(self.cfg.policy).with_start(self.cfg.start_alloc);

        let admitted = Instant::now();
        let Backend::Lab(model) = &self.backend else {
            unreachable!("prefill_lab on a PJRT engine")
        };
        let alloc =
            Allocation::parse(guard.allocation()).expect("guard allocation maps to the lab");
        let mut out = model.prefill(alloc, &ids, n).context("lab prefill")?;
        // Guard on the kernels' pre-store telemetry (max |S| / overflow
        // events at the score GEMM) — trouble is visible before any NaN
        // reaches the logits. Replays walk the guard's fallback chain:
        // an FP8 start rescues to Pasa8 first and only escalates to full
        // FP16 PASA if the shifted store still trips (the loop is bounded
        // by the chain length — observe_signal returns false once the
        // chain is exhausted). Like the decode path, the prefill counts
        // at most one overflow step no matter how many chain stages the
        // rescue walked.
        let mut overflowed_step = false;
        while observe_guard(&mut guard, &out.signal, &mut self.metrics) {
            overflowed_step = true;
            let rescue = Allocation::parse(guard.allocation())
                .expect("guard allocation maps to the lab");
            out = model
                .prefill(rescue, &ids, n)
                .context("lab prefill replay")?;
        }
        if overflowed_step {
            self.metrics.overflow_steps += 1;
        }
        let prefill_done = Instant::now();
        self.metrics.prefill_tokens += n as u64;

        // Seed the paged cache; release the partial grow on failure (see
        // prefill_pjrt).
        let mut cache = SeqCache::new(d.n_layers);
        let seeded = (|| -> Result<()> {
            cache.ensure_capacity(&mut self.pool, n)?;
            for l in 0..d.n_layers {
                for p in 0..n {
                    cache.write_row(
                        &mut self.pool,
                        l,
                        p,
                        out.k_rows[l].row(p),
                        out.v_rows[l].row(p),
                    )?;
                }
            }
            Ok(())
        })();
        if let Err(e) = seeded {
            cache.release(&mut self.pool);
            return Err(e.context("prefill cache seeding"));
        }

        let v = d.vocab_size;
        let last_row = &out.logits[(n - 1) * v..n * v];
        let tok = sample(last_row, req.params.sampling, &mut self.rng);
        let mut tokens: Vec<u32> = ids[..n].to_vec();
        tokens.push(tok);
        Ok(Self::activate(
            req,
            guard,
            cache,
            tokens,
            n,
            admitted,
            prefill_done,
        ))
    }

    // ---- decode --------------------------------------------------------

    /// Distinct allocations among active slots this round.
    fn allocation_groups(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for s in self.slots.iter().flatten() {
            let a = s.guard.allocation();
            if !out.contains(&a) {
                out.push(a);
            }
        }
        out
    }

    fn decode_round(&mut self) -> Result<()> {
        if matches!(self.backend, Backend::Lab(_)) {
            self.decode_round_lab()?;
        } else {
            for alloc in self.allocation_groups() {
                self.decode_group_pjrt(alloc)?;
            }
        }
        // Retire finished requests.
        let b = self.slots.len();
        for i in 0..b {
            let done = matches!(
                self.slots[i].as_ref().map(|s| s.phase),
                Some(Phase::Finished(_))
            );
            if done {
                let mut ar = self.slots[i].take().unwrap();
                ar.cache.release(&mut self.pool);
                self.finish(ar);
            }
        }
        Ok(())
    }

    /// Advance one slot after a decode step: sample, extend, check stop
    /// conditions. Free function over the slot so the backends' disjoint
    /// borrows stay simple.
    fn advance_slot(
        s: &mut ActiveRequest,
        logits_row: &[f32],
        max_seq: usize,
        eos: u32,
        rng: &mut Pcg64,
        metrics: &mut Metrics,
    ) {
        let tok = sample(logits_row, s.req.params.sampling, rng);
        if s.first_token.is_none() {
            s.first_token = Some(Instant::now());
        }
        s.tokens.push(tok);
        metrics.tokens_generated += 1;

        let generated = s.tokens.len() - s.prompt_len;
        if s.req.params.stop_at_eos && tok == eos {
            s.phase = Phase::Finished(FinishReason::Eos);
        } else if generated >= s.req.params.max_new_tokens {
            s.phase = Phase::Finished(FinishReason::MaxTokens);
        } else if s.tokens.len() >= max_seq {
            s.phase = Phase::Finished(FinishReason::ContextFull);
        }
    }

    /// Lab-backend decode: the active slots' paged decode steps fan out
    /// over the persistent worker pool (`O(len_tokens)` page gathers each,
    /// kernel telemetry into the guard, per-slot PASA replay on a trip).
    ///
    /// Three phases keep the shared-pool writes sound and the results
    /// bit-identical to the old sequential loop:
    /// 1. **prepare** (sequential, exclusive pool): grow each slot's
    ///    capacity and privatize the pages its step will write
    ///    ([`SeqCache::prepare_step`]); pool exhaustion here is per-slot
    ///    backpressure (evict), never a crash.
    /// 2. **compute** (parallel, shared pool): each runnable slot's step
    ///    — including any guard-triggered PASA replay — runs as a worker
    ///    pool tile via [`LabModel::decode_step_prepared`]; slots own
    ///    their caches, writes land only in their privatized pages.
    /// 3. **fold** (sequential, in slot order): metrics, then sampling —
    ///    so the RNG stream matches the sequential implementation
    ///    token for token.
    fn decode_round_lab(&mut self) -> Result<()> {
        let d = self.dims;
        let b = self.slots.len();
        let members: Vec<usize> = (0..b)
            .filter(|&i| {
                matches!(
                    self.slots[i].as_ref().map(|s| s.phase),
                    Some(Phase::Decoding)
                )
            })
            .collect();
        if members.is_empty() {
            return Ok(());
        }
        self.metrics.decode_batch_occupancy.push(members.len());

        // Phase 1: allocate/privatize under exclusive pool access.
        let mut runnable: Vec<usize> = Vec::with_capacity(members.len());
        for &i in &members {
            let s = self.slots[i].as_mut().unwrap();
            let pos = s.tokens.len() - 1;
            match s.cache.prepare_step(&mut self.pool, pos) {
                Ok(()) => runnable.push(i),
                // KV pool exhausted: backpressure, not a crash — evict the
                // slot, its pages free up at retirement.
                Err(e) if is_kv_backpressure(&e) => {
                    s.phase = Phase::Finished(FinishReason::Evicted);
                }
                Err(e) => return Err(e.context("lab decode prepare")),
            }
        }
        if runnable.is_empty() {
            return Ok(());
        }

        // Phase 2: the compute steps as pool tiles. Each task takes its
        // slot's state out of the table (so it owns the cache and guard)
        // and shares the model and the page pool read-mostly.
        struct StepOut {
            logits: Vec<f32>,
            /// One wall-clock sample per executed step (first run + every
            /// chain replay).
            latencies: Vec<f64>,
            overflowed: bool,
            switch_delta: u64,
            err: Option<anyhow::Error>,
        }
        let tasks: Vec<Mutex<(usize, ActiveRequest, StepOut)>> = runnable
            .iter()
            .map(|&i| {
                let ar = self.slots[i].take().unwrap();
                Mutex::new((
                    i,
                    ar,
                    StepOut {
                        logits: Vec::new(),
                        latencies: Vec::new(),
                        overflowed: false,
                        switch_delta: 0,
                        err: None,
                    },
                ))
            })
            .collect();
        {
            let Backend::Lab(model) = &self.backend else {
                unreachable!("decode_round_lab on a PJRT engine")
            };
            let model: &LabModel = model;
            let pool_ref = &self.pool;
            let tasks_ref = &tasks;
            crate::pool::global().run_tiles(tasks_ref.len(), |t| {
                let mut slot = tasks_ref[t].lock().unwrap();
                let (_, ar, out) = &mut *slot;
                let alloc = Allocation::parse(ar.guard.allocation())
                    .expect("guard allocation maps to the lab");
                let tok = *ar.tokens.last().unwrap();
                let pos = ar.tokens.len() - 1;
                let t0 = Instant::now();
                match model.decode_step_prepared(alloc, tok, pos, &mut ar.cache, pool_ref) {
                    Ok((mut logits, mut sig)) => {
                        out.latencies.push(t0.elapsed().as_secs_f64());
                        if sig.overflow_events > 0 || sig.nonfinite > 0 {
                            out.overflowed = true;
                        }
                        let before = ar.guard.switches;
                        // Replay this slot's step down the guard's
                        // fallback chain (fp8 → pasa8 → pasa on an FP8
                        // start). The step is functional in (token, pos,
                        // cache prefix), so each replay rewrites the same
                        // KV rows — the cache ends up exactly as if the
                        // final allocation had run the step first. The
                        // loop is bounded by the chain length.
                        while ar.guard.observe_signal(&sig) {
                            let rescue = Allocation::parse(ar.guard.allocation())
                                .expect("guard allocation maps to the lab");
                            let t1 = Instant::now();
                            match model.decode_step_prepared(
                                rescue,
                                tok,
                                pos,
                                &mut ar.cache,
                                pool_ref,
                            ) {
                                Ok((l2, s2)) => {
                                    logits = l2;
                                    sig = s2;
                                    out.latencies.push(t1.elapsed().as_secs_f64());
                                    if sig.overflow_events > 0 || sig.nonfinite > 0 {
                                        out.overflowed = true;
                                    }
                                }
                                Err(e) => {
                                    out.err = Some(e.context("lab decode replay"));
                                    break;
                                }
                            }
                        }
                        out.switch_delta = (ar.guard.switches - before) as u64;
                        out.logits = logits;
                    }
                    Err(e) => out.err = Some(e.context("lab decode step")),
                }
            });
        }

        // Phase 3: restore slots, fold metrics, sample in slot order.
        let mut failure: Option<anyhow::Error> = None;
        for task in tasks {
            let (i, ar, out) = task.into_inner().unwrap();
            self.slots[i] = Some(ar);
            for &lat in &out.latencies {
                self.metrics.decode_steps += 1;
                // Replayed steps are real serving latency: record them.
                self.metrics.step_latency.record(lat);
            }
            if out.overflowed {
                self.metrics.overflow_steps += 1;
            }
            self.metrics.guard_switches += out.switch_delta;
            let s = self.slots[i].as_mut().unwrap();
            if let Some(e) = out.err {
                if is_kv_backpressure(&e) {
                    s.phase = Phase::Finished(FinishReason::Evicted);
                } else if failure.is_none() {
                    failure = Some(e);
                }
                continue;
            }
            Self::advance_slot(
                s,
                &out.logits,
                d.max_seq,
                self.sp.eos,
                &mut self.rng,
                &mut self.metrics,
            );
        }
        if let Some(e) = failure {
            return Err(e);
        }
        Ok(())
    }

    /// PJRT-backend decode: one batched dense step for every active slot
    /// on `alloc` (the compiled decode module consumes dense caches, so
    /// this path pays the `fill_dense` assembly and sniffs logits).
    fn decode_group_pjrt(&mut self, alloc: &'static str) -> Result<()> {
        let d = self.dims;
        let b = d.decode_batch;
        let w = d.head_width();
        let v = d.vocab_size;
        let seq_floats = d.max_seq * w;
        let Backend::Pjrt(rt) = &self.backend else {
            unreachable!("decode_group_pjrt on a lab engine")
        };
        let rt = *rt;

        let members: Vec<usize> = (0..b)
            .filter(|&i| {
                self.slots[i]
                    .as_ref()
                    .map(|s| s.guard.allocation() == alloc && s.phase == Phase::Decoding)
                    .unwrap_or(false)
            })
            .collect();
        if members.is_empty() {
            return Ok(());
        }
        self.metrics.decode_batch_occupancy.push(members.len());

        // Assemble the dense batch caches from the paged pool.
        self.kbatch.fill(0.0);
        self.vbatch.fill(0.0);
        let mut tokens = vec![self.sp.pad as i32; b];
        let mut pos = vec![0i32; b];
        for &i in &members {
            let s = self.slots[i].as_ref().unwrap();
            let p = s.tokens.len() - 1; // position of the token being fed
            tokens[i] = *s.tokens.last().unwrap() as i32;
            pos[i] = p as i32;
            for l in 0..d.n_layers {
                let off = (l * b + i) * seq_floats;
                s.cache.fill_dense(
                    &self.pool,
                    l,
                    false,
                    &mut self.kbatch[off..off + seq_floats],
                )?;
                s.cache.fill_dense(
                    &self.pool,
                    l,
                    true,
                    &mut self.vbatch[off..off + seq_floats],
                )?;
            }
        }

        let t0 = Instant::now();
        let (mut logits, mut kout, mut vout) = rt
            .decode(alloc, &tokens, &pos, &self.kbatch, &self.vbatch)
            .context("decode")?;
        self.metrics.decode_steps += 1;
        self.metrics.step_latency.record(t0.elapsed().as_secs_f64());

        // Guard pass: any member overflowing gets the whole group's step
        // replayed under PASA (cache inputs unchanged — replay is exact).
        let mut replay = false;
        for &i in &members {
            let sig = GuardSignal::from_logits(&logits[i * v..(i + 1) * v]);
            let s = self.slots[i].as_mut().unwrap();
            if observe_guard(&mut s.guard, &sig, &mut self.metrics) {
                replay = true;
            }
            if sig.nonfinite > 0 {
                self.metrics.overflow_steps += 1;
            }
        }
        if replay {
            // The PJRT group replay is pinned to "pasa": this backend is
            // restricted to the default fa16_32 → pasa chain (see
            // `EngineConfig::start_alloc`), whose rescue stage is exactly
            // "pasa" — a longer chain here would desynchronize guard
            // state from the executed allocation.
            let t1 = Instant::now();
            let (l2, k2, v2) = rt
                .decode("pasa", &tokens, &pos, &self.kbatch, &self.vbatch)
                .context("decode replay under PASA")?;
            logits = l2;
            kout = k2;
            vout = v2;
            self.metrics.decode_steps += 1;
            // Replayed steps are real serving latency: record them too.
            self.metrics.step_latency.record(t1.elapsed().as_secs_f64());
        }

        // Write back the new KV row, sample, advance. The decode module
        // returns only the new rows, shaped (L, B, W).
        for &i in &members {
            let s = self.slots[i].as_mut().unwrap();
            let p = pos[i] as usize;
            let mut wrote = true;
            if let Err(e) = s.cache.ensure_capacity(&mut self.pool, p + 1) {
                if !is_kv_backpressure(&e) {
                    return Err(e.context("decode cache growth"));
                }
                wrote = false;
            }
            if wrote {
                for l in 0..d.n_layers {
                    let off = (l * b + i) * w;
                    if let Err(e) = s.cache.write_row(
                        &mut self.pool,
                        l,
                        p,
                        &kout[off..off + w],
                        &vout[off..off + w],
                    ) {
                        if !is_kv_backpressure(&e) {
                            return Err(e.context("decode KV write-back"));
                        }
                        wrote = false;
                        break;
                    }
                }
            }
            if !wrote {
                // Pool exhausted mid-flight: backpressure — evict.
                s.phase = Phase::Finished(FinishReason::Evicted);
                continue;
            }
            let row = &logits[i * v..(i + 1) * v];
            Self::advance_slot(
                s,
                row,
                d.max_seq,
                self.sp.eos,
                &mut self.rng,
                &mut self.metrics,
            );
        }
        Ok(())
    }

    fn finish(&mut self, ar: ActiveRequest) {
        let now = Instant::now();
        let reason = match ar.phase {
            Phase::Finished(r) => r,
            _ => FinishReason::MaxTokens,
        };
        // True queue wait: arrival → admission (prefill start). Prefill
        // execution is reported separately — the two used to be conflated
        // (both were arrival → prefill_done).
        let queue_time = (ar.admitted - ar.req.arrival).as_secs_f64();
        let prefill_time = ar
            .prefill_done
            .map(|t| (t - ar.admitted).as_secs_f64())
            .unwrap_or(0.0);
        let ttft = ar
            .first_token
            .map(|t| (t - ar.req.arrival).as_secs_f64())
            .unwrap_or(0.0);
        let total = (now - ar.req.arrival).as_secs_f64();
        self.metrics.ttft.record(ttft);
        self.metrics.total_latency.record(total);
        self.metrics.requests_completed += 1;
        let gen_ids: Vec<u32> = ar.tokens[ar.prompt_len..].to_vec();
        self.completions.push(Completion {
            id: ar.req.id,
            prompt: ar.req.prompt.clone(),
            text: tokenizer::decode(&gen_ids, self.sp),
            tokens: gen_ids,
            reason,
            prompt_tokens: ar.prompt_len,
            queue_time,
            prefill_time,
            first_token_latency: ttft,
            total_latency: total,
            allocation: ar.guard.allocation().to_string(),
            guard_switches: ar.guard.switches,
        });
    }
}
