//! Serving engine (S11): token-budget continuous batching over one of
//! two model backends.
//!
//! One `step()` = one scheduler iteration:
//!   1. **admit + prefill** — continue in-flight chunked prefills (FCFS,
//!      drawing from `max_batch_prefill_tokens`), then admit queued
//!      requests while the pure scheduler ([`super::scheduler`]) says the
//!      batch has budget: slot cap, committed-token ceiling, prefill
//!      budget, KV pages. A long prompt admits with a budget-sized first
//!      chunk and keeps prefilling one chunk per iteration — interleaved
//!      with the in-flight decode rounds, so a 4096-token prompt never
//!      stalls anyone's decode by more than one chunk of compute.
//!   2. **decode round** — one decode step per `Decoding` slot (grouped
//!      per allocation on the PJRT backend; per-slot paged requests fanned
//!      over the worker pool on the lab backend), guard inspection ⇒
//!      replay down the fallback chain, sample, stream a [`TokenEvent`].
//!   3. **retire** — finished slots leave the batch (`filter`), their KV
//!      pages free immediately, and the next iteration's admission sees
//!      the freed budget (`concatenate`) — waiting work re-admits
//!      mid-flight, not at batch boundaries.
//!
//! ## Determinism and token identity
//!
//! Scheduler decisions are pure functions of (queue, slot, budget) state
//! — token counts and free pages, never wall-clock time or RNG — so an
//! arrival trace replays to the same admission schedule every run.
//! Sampling uses a **per-request** RNG stream seeded from the request id
//! (not one engine-wide stream consumed in slot order), and the lab
//! chunked-prefill path is bit-invariant to chunk boundaries
//! ([`LabModel::prefill_chunk`]). Together these make every request's
//! output stream bit-identical to a sequential one-request-at-a-time run
//! of the same engine — certified by the scheduler integration tests the
//! same way paged≡dense and pooled≡sequential already are.
//!
//! Timestamps exist only on the observation side (TTFT/ITL histograms,
//! `TokenEvent::emitted_at`); nothing feeds them back into decisions.
//!
//! ## Backends
//!
//! * [`Backend::Lab`] — the pure-Rust [`LabModel`]: chunked prefill
//!   through [`LabModel::prefill_chunk`] (per-row attention against the
//!   paged cache), decode steps as per-slot paged
//!   [`crate::attention::AttentionRequest`]s with kernel telemetry into
//!   the guard ([`GuardSignal::from_attention`]).
//! * [`Backend::Pjrt`] — the AOT HLO runtime. Its prefill module is one
//!   fixed shape (no chunking — prompts cap at `prefill_seq`) and its
//!   decode consumes dense `(L, B, max_seq, W)` caches, so this path
//!   assembles batches with `fill_dense` and falls back to legacy logits
//!   NaN-sniffing.
//!
//! KV-pool exhaustion mid-flight (copy-on-write growth) is backpressure:
//! the slot finishes with [`FinishReason::Evicted`] and its pages return
//! to the pool — never a panic, never a corrupted cache.
//!
//! ## Lifecycle hardening and chaos
//!
//! Every step additionally runs (in order, before admission): fault
//! injection from an installed [`FaultPlan`] (seizure releases, pool
//! seizures, scheduler stalls), the step-denominated deadline sweep
//! (`FinishReason::DeadlineExceeded` for queued, retry-parked, and
//! active requests alike), queue-depth load shedding
//! (`FinishReason::Shed`), and re-enqueue of evicted requests whose
//! retry backoff has elapsed. Decode rounds carry a per-slot
//! non-finite-logit watchdog: a poisoned row quarantines *that slot*
//! (`FinishReason::Faulted`) and leaves co-batched neighbours
//! bit-identical to a fault-free run. All of it is step-denominated and
//! seeded — no clocks, no OS entropy — so a chaos run replays exactly
//! from its seed (`rust/tests/integration_chaos.rs`).

use super::faults::{is_injected_error, FaultKind, FaultPlan, INJECTED_STEP_ERROR};
use super::guard::{Guard, GuardPolicy, GuardSignal};
use super::kv_cache::{KvPool, KvStore, PageId, SeqCache};
use super::metrics::Metrics;
use super::prefix_cache::{PrefixCache, PrefixDecision};
use super::request::{Completion, FinishReason, Phase, Request, StreamEvent, TokenEvent};
use super::router::{Admission, Router};
use super::scheduler::{self, BatchState, SchedDecision, SchedulerConfig};
use crate::attention::Allocation;
use crate::model::{sample, tokenizer, ModelDims, Specials};
use crate::runtime::{LabModel, ModelRuntime};
use crate::workloads::Pcg64;
use anyhow::{Context, Result};
use std::sync::Mutex;
use std::time::Instant;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub policy: GuardPolicy,
    /// Starting attention allocation of the *switching* guard policies —
    /// the root of the guard's fallback chain (`--alloc` on the CLI).
    /// `Fa16_32` keeps the classic `fa16_32 → pasa` behaviour; `Fp8`
    /// walks `fp8 → pasa8 → pasa`, rescuing within the 8-bit envelope
    /// before abandoning it. Fixed policies (`AlwaysPasa` & co.) ignore
    /// it. **Lab backend only** for non-default values: the PJRT
    /// manifest ships no fp8/pasa8 modules, and its batched group-replay
    /// path replays under "pasa" — the CLI rejects a non-default
    /// `--alloc` on the PJRT serve path for exactly this reason.
    pub start_alloc: Allocation,
    /// Total pages in the KV pool **at f32 storage** — `kv_pages ×
    /// page_tokens × head_width × 4` bytes. The pool is sized by that
    /// byte budget, so choosing a 1-byte [`KvStore`] multiplies the
    /// page count (4× for `E4m3`) instead of shrinking the arena: the
    /// knob compares storage formats at fixed memory, not fixed pages.
    pub kv_pages: usize,
    /// Tokens per page.
    pub page_tokens: usize,
    /// KV page element format (`pasa serve --kv-store {f32|e4m3}`).
    /// **Lab backend only** for `E4m3`: the PJRT dense-cache path is
    /// gated off byte-backed pools by the CLI.
    pub kv_store: KvStore,
    pub max_queue: usize,
    /// Continuous-batching budgets (see [`SchedulerConfig`]).
    pub sched: SchedulerConfig,
    /// Page-reference budget of the radix prefix cache (0 = disabled).
    /// **Lab backend only**: the cache seeds admissions through partial
    /// CoW forks, which the PJRT dense-cache path cannot consume. When
    /// on, completed prefills publish their page-aligned prompt pages
    /// into a radix tree; later admissions sharing that prefix skip its
    /// prefill entirely, and cold prefixes are LRU-evicted under pool
    /// pressure (`pasa serve --prefix-cache`).
    pub prefix_cache_pages: usize,
    /// Default per-request deadline in **engine steps** (0 = none). A
    /// request that has not finished within this many steps of its
    /// submission is killed with [`FinishReason::DeadlineExceeded`] —
    /// queued, mid-prefill, or decoding alike. `Request::with_deadline`
    /// overrides per request. Step-denominated (never wall clock) so
    /// trace replays stay deterministic.
    pub deadline_steps: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: GuardPolicy::Adaptive,
            start_alloc: Allocation::Fa16_32,
            kv_pages: 4096,
            page_tokens: 32,
            kv_store: KvStore::F32,
            max_queue: 256,
            sched: SchedulerConfig::default(),
            prefix_cache_pages: 0,
            deadline_steps: 0,
        }
    }
}

/// The model execution backend behind the engine (see module docs).
pub enum Backend<'rt> {
    Pjrt(&'rt ModelRuntime),
    Lab(Box<LabModel>),
}

/// True when an error is KV-pool exhaustion — the one failure the engine
/// treats as backpressure (evict the slot) rather than a bug to surface.
/// Delegates to the pool's own classifier so the marker lives next to the
/// message that carries it.
fn is_kv_backpressure(e: &anyhow::Error) -> bool {
    KvPool::is_exhausted_error(e)
}

/// Observe a step signal on a guard, folding any pin into the engine
/// metrics; returns whether the step must be replayed under PASA. The
/// guard's own `switches` counter is the source of truth for pin events
/// (a `Preemptive` pressure pin increments it without requesting a
/// replay), so the metric can never drift from the guard state.
fn observe_guard(guard: &mut Guard, sig: &GuardSignal, metrics: &mut Metrics) -> bool {
    let before = guard.switches;
    let replay = guard.observe_signal(sig);
    metrics.guard_switches += (guard.switches - before) as u64;
    replay
}

/// One slot of the dynamic batch. The slot's position in `Engine::active`
/// is its batch lane for the round; retirement compacts the vector
/// (filter), admission appends (concatenate), so lanes shift between
/// rounds but stay stable within one.
struct ActiveRequest {
    req: Request,
    guard: Guard,
    cache: SeqCache,
    /// The prompt's token ids (BOS + bytes, truncated to the backend's
    /// prompt capacity) — the chunked prefill reads straight from here.
    prompt_ids: Vec<u32>,
    /// Prompt tokens prefilled so far; `== prompt_ids.len()` ⇒ done.
    prefilled: usize,
    /// Prompt + generated token ids.
    tokens: Vec<u32>,
    prompt_len: usize,
    phase: Phase,
    /// Per-request sampling RNG, seeded from the request id: the stream a
    /// request consumes is independent of what else is in the batch —
    /// load-bearing for token identity under non-greedy sampling.
    rng: Pcg64,
    /// When the request left the queue (prefill started).
    admitted: Instant,
    prefill_done: Option<Instant>,
    first_token: Option<Instant>,
    /// Previous token emission — feeds the ITL histogram.
    last_token: Option<Instant>,
}

impl ActiveRequest {
    fn committed_tokens(&self, max_seq: usize) -> usize {
        scheduler::committed_tokens(self.prompt_len, self.req.params.max_new_tokens, max_seq)
    }
}

/// Sampling RNG for a request: a fixed salt mixed with the id as both
/// seed and stream — distinct requests get distinct, reproducible
/// streams regardless of admission order or co-tenants.
fn request_rng(id: u64) -> Pcg64 {
    Pcg64::new(0xe61e ^ id, id)
}

/// Effective deadline of a request in engine steps: the per-request
/// override wins, otherwise the engine-wide default; 0/None means no
/// deadline at all.
fn deadline_of(req: &Request, engine_default: u64) -> Option<u64> {
    match req.deadline_steps {
        Some(d) => Some(d),
        None if engine_default > 0 => Some(engine_default),
        None => None,
    }
}

/// Emit one sampled token: stream event, ITL/TTFT instants, counters.
fn emit_token(
    s: &mut ActiveRequest,
    tok: u32,
    metrics: &mut Metrics,
    events: &mut Vec<StreamEvent>,
) {
    let now = Instant::now();
    if s.first_token.is_none() {
        s.first_token = Some(now);
    }
    if let Some(prev) = s.last_token {
        metrics.itl.record((now - prev).as_secs_f64());
    }
    s.last_token = Some(now);
    events.push(StreamEvent::Token(TokenEvent {
        request_id: s.req.id,
        token: tok,
        index: s.tokens.len() - s.prompt_len,
        position: s.tokens.len(),
        emitted_at: now,
    }));
    s.tokens.push(tok);
    metrics.tokens_generated += 1;
}

/// Stop conditions, applied uniformly to every sampled token (including
/// the first, straight out of prefill — an EOS first token finishes the
/// request instead of decoding past it).
fn apply_stop_rules(s: &mut ActiveRequest, tok: u32, max_seq: usize, eos: u32) {
    let generated = s.tokens.len() - s.prompt_len;
    if s.req.params.stop_at_eos && tok == eos {
        s.phase = Phase::Finished(FinishReason::Eos);
    } else if generated >= s.req.params.max_new_tokens {
        s.phase = Phase::Finished(FinishReason::MaxTokens);
    } else if s.tokens.len() >= max_seq {
        s.phase = Phase::Finished(FinishReason::ContextFull);
    }
}

/// Advance one slot after a decode step: sample (per-request RNG), emit,
/// check stop conditions. Free function over the slot so the backends'
/// disjoint borrows stay simple.
fn advance_slot(
    s: &mut ActiveRequest,
    logits_row: &[f32],
    max_seq: usize,
    eos: u32,
    metrics: &mut Metrics,
    events: &mut Vec<StreamEvent>,
) {
    let tok = sample(logits_row, s.req.params.sampling, &mut s.rng);
    emit_token(s, tok, metrics, events);
    apply_stop_rules(s, tok, max_seq, eos);
}

/// The continuous-batching serving engine.
pub struct Engine<'rt> {
    backend: Backend<'rt>,
    dims: ModelDims,
    pub cfg: EngineConfig,
    pub router: Router,
    pool: KvPool,
    /// The dynamic slot set: every active request, in admission order.
    active: Vec<ActiveRequest>,
    pub metrics: Metrics,
    completions: Vec<Completion>,
    events: Vec<StreamEvent>,
    sp: Specials,
    // Reusable batch assembly buffers (PJRT path only — the lab path
    // never assembles a dense cache).
    kbatch: Vec<f32>,
    vbatch: Vec<f32>,
    /// Engine-step clock: completed `step()` calls. The time base for
    /// deadlines, retry backoff, and fault-injection sites.
    step_index: u64,
    /// Installed chaos plan, if any (`install_faults`).
    faults: Option<FaultPlan>,
    /// Admission is stalled until this step (scheduler-stall faults).
    stall_until: u64,
    /// Evicted requests parked for retry: (eligible step, request).
    retryq: Vec<(u64, Request)>,
    /// Pages seized by pool-exhaustion faults: (release step, pages).
    seized: Vec<(u64, Vec<PageId>)>,
    /// Radix prefix cache over prompt token IDs (None = disabled; see
    /// [`EngineConfig::prefix_cache_pages`]).
    prefix: Option<PrefixCache>,
    /// Best-of-n fan-out registrations: (primary id, sibling ids). The
    /// entry survives the primary's eviction-retry parking and fires
    /// when its prefill completes ([`Engine::fire_ready_fanout`]); a
    /// primary that terminates without decoding orphans its siblings
    /// with the same reason.
    fanout: Vec<(u64, Vec<u64>)>,
    /// Primaries whose prefill completed this step, with the final
    /// prompt row's logits — the material sibling first tokens are
    /// sampled from.
    fanout_ready: Vec<(u64, Vec<f32>)>,
}

impl<'rt> Engine<'rt> {
    /// Engine over the PJRT runtime (AOT artifacts).
    pub fn new(rt: &'rt ModelRuntime, cfg: EngineConfig) -> Engine<'rt> {
        Self::with_backend(Backend::Pjrt(rt), rt.dims, cfg)
    }

    /// Engine over the pure-Rust lab runtime — paged decode through the
    /// kernel registry, no artifacts required.
    pub fn from_lab(model: LabModel, cfg: EngineConfig) -> Engine<'static> {
        let dims = model.dims;
        Engine::with_backend(Backend::Lab(Box::new(model)), dims, cfg)
    }

    fn with_backend(backend: Backend<'rt>, dims: ModelDims, cfg: EngineConfig) -> Engine<'rt> {
        let b = dims.decode_batch;
        let cache_len = match backend {
            // The PJRT decode module wants the dense (L, B, max_seq, W)
            // cache tensors; the lab backend reads pages directly.
            Backend::Pjrt(_) => dims.n_layers * b * dims.max_seq * dims.head_width(),
            Backend::Lab(_) => 0,
        };
        let sp = Specials {
            pad: dims.pad,
            bos: dims.bos,
            eos: dims.eos,
        };
        // Admission limit in *tokens*: anything that fits the context is
        // servable under chunked prefill (the PJRT path additionally
        // truncates to its fixed prefill shape, as it always has).
        let mut router = Router::new(cfg.max_queue, dims.max_seq);
        router.max_bypass = cfg.sched.max_bypass();
        let prefix = (cfg.prefix_cache_pages > 0 && matches!(backend, Backend::Lab(_)))
            .then(|| PrefixCache::new(cfg.page_tokens, dims.n_layers, cfg.prefix_cache_pages));
        Engine {
            backend,
            dims,
            router,
            pool: KvPool::with_byte_budget(
                cfg.kv_pages * cfg.page_tokens * dims.head_width() * 4,
                cfg.page_tokens,
                dims.head_width(),
                cfg.kv_store,
            ),
            active: Vec::with_capacity(b),
            metrics: Metrics::new(),
            completions: Vec::new(),
            events: Vec::new(),
            sp,
            kbatch: vec![0.0; cache_len],
            vbatch: vec![0.0; cache_len],
            step_index: 0,
            faults: None,
            stall_until: 0,
            retryq: Vec::new(),
            seized: Vec::new(),
            prefix,
            fanout: Vec::new(),
            fanout_ready: Vec::new(),
            cfg,
        }
    }

    /// Submit a request (admission-checked). Stamps the request's
    /// `arrival_step` with the engine-step clock — the zero point of its
    /// step-denominated deadline, if any.
    pub fn submit(&mut self, mut req: Request) -> Admission {
        req.arrival_step = self.step_index;
        self.router.submit(req)
    }

    pub fn fresh_id(&mut self) -> u64 {
        self.router.fresh_id()
    }

    /// Submit a request that fans out into `n` independent decode
    /// streams sharing one prefill (TGI's `generate_best_of` shape): the
    /// primary admits, prefills and publishes like any request; when its
    /// prefill completes, each sibling gets a full CoW fork of the
    /// prompt cache, its own id-seeded RNG, and a first token sampled
    /// from the primary's final prompt logits — bit-identical to running
    /// the sibling as its own request, at one prefill's cost for all
    /// `n`. Returns `(admission of the primary, all n stream ids)` —
    /// primary first. A primary that never reaches decoding (shed,
    /// deadline, terminal eviction, quarantine, cancel) closes every
    /// sibling stream with the same reason. **Lab backend only**: the
    /// PJRT dense batch has no room for surprise slots.
    pub fn submit_best_of(&mut self, req: Request, n: usize) -> Result<(Admission, Vec<u64>)> {
        anyhow::ensure!(n >= 1, "best-of needs n >= 1 (got {n})");
        anyhow::ensure!(
            matches!(self.backend, Backend::Lab(_)),
            "best-of fan-out requires the lab backend (the PJRT decode module's \
             dense batch width cannot absorb forked slots)"
        );
        let primary = req.id;
        let siblings: Vec<u64> = (1..n).map(|_| self.router.fresh_id()).collect();
        let mut ids = vec![primary];
        ids.extend(siblings.iter().copied());
        let admission = self.submit(req);
        if admission == Admission::Queued && !siblings.is_empty() {
            self.fanout.push((primary, siblings));
        }
        Ok((admission, ids))
    }

    /// Release every page reference the radix prefix cache holds —
    /// drain accounting (the chaos soak's drains-to-zero invariant) and
    /// shutdown. Returns page references released; 0 with no cache.
    pub fn flush_prefix_cache(&mut self) -> usize {
        match self.prefix.as_mut() {
            Some(pc) => pc.flush(&mut self.pool),
            None => 0,
        }
    }

    /// Page references the radix prefix cache currently holds.
    pub fn prefix_pages_held(&self) -> usize {
        self.prefix.as_ref().map_or(0, |pc| pc.pages_held())
    }

    /// True when no queued, active, retry-parked, or seized-page work
    /// remains (a held seizure keeps the engine stepping so the pages
    /// are released on schedule).
    pub fn idle(&self) -> bool {
        self.router.is_empty()
            && self.active.is_empty()
            && self.retryq.is_empty()
            && self.seized.is_empty()
    }

    /// Install a chaos fault plan (see [`super::faults`]): subsequent
    /// steps offer it injection sites and log every firing. Installing
    /// on a live engine is allowed — the plan's stream starts at the
    /// next step.
    pub fn install_faults(&mut self, plan: FaultPlan) {
        self.faults = Some(plan);
    }

    /// The installed fault plan, if any — tests reconcile its injection
    /// log against the metrics robustness counters.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Engine-step clock: the number of completed [`Engine::step`]
    /// calls. The time base for deadlines, retry backoff, and fault
    /// sites.
    pub fn current_step(&self) -> u64 {
        self.step_index
    }

    /// Cancel a request wherever it currently lives — queued, parked for
    /// retry, or active (mid-prefill or decoding). Releases its KV pages
    /// immediately, closes its stream with a single
    /// [`FinishReason::Cancelled`] terminal event, and returns `true`.
    /// Returns `false` for unknown ids and for requests that already
    /// finished this step (their terminal event is already accounted).
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(req) = self.router.remove(id) {
            self.metrics.robustness.cancellations += 1;
            self.finish_queued(req, FinishReason::Cancelled);
            return true;
        }
        if let Some(pos) = self.retryq.iter().position(|(_, r)| r.id == id) {
            let (_, req) = self.retryq.remove(pos);
            self.metrics.robustness.cancellations += 1;
            self.finish_queued(req, FinishReason::Cancelled);
            return true;
        }
        if let Some(pos) = self.active.iter().position(|s| s.req.id == id) {
            if matches!(self.active[pos].phase, Phase::Finished(_)) {
                return false; // already terminal; retirement owns it
            }
            let mut ar = self.active.remove(pos);
            ar.phase = Phase::Finished(FinishReason::Cancelled);
            ar.cache.release(&mut self.pool);
            self.metrics.robustness.cancellations += 1;
            self.finish(ar);
            return true;
        }
        false
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Drain the per-token stream accumulated since the last call:
    /// [`StreamEvent::Token`]s in emission order, interleaved with
    /// [`StreamEvent::Finished`] markers. Callers that want streaming
    /// drain this between `step()`s; `run_to_completion` leaves the
    /// events buffered for a final drain.
    pub fn take_events(&mut self) -> Vec<StreamEvent> {
        std::mem::take(&mut self.events)
    }

    pub fn kv_utilization(&self) -> f64 {
        self.pool.utilization()
    }

    /// The paged KV pool (read-only; tests inspect cache contents).
    pub fn kv_pool(&self) -> &KvPool {
        &self.pool
    }

    /// The paged cache of an active slot (slot = index in admission
    /// order; retirement compacts).
    pub fn slot_cache(&self, slot: usize) -> Option<&SeqCache> {
        self.active.get(slot).map(|s| &s.cache)
    }

    /// The allocation an active slot's guard would run next.
    pub fn slot_allocation(&self, slot: usize) -> Option<&'static str> {
        self.active.get(slot).map(|s| s.guard.allocation())
    }

    /// Effective slot cap: the config knob resolved against the backend
    /// (0 = backend default; PJRT is structurally capped by its dense
    /// batch width).
    fn max_slots(&self) -> usize {
        let native = self.dims.decode_batch;
        let knob = self.cfg.sched.max_batch_size;
        match (&self.backend, knob) {
            (_, 0) => native,
            (Backend::Pjrt(_), n) => n.min(native),
            (Backend::Lab(_), n) => n,
        }
    }

    /// Σ committed tokens over the active batch.
    fn committed_total(&self) -> usize {
        self.active
            .iter()
            .map(|s| s.committed_tokens(self.dims.max_seq))
            .sum()
    }

    /// One scheduler iteration. Returns the number of active slots after
    /// the step (0 = fully idle). Lifecycle order: fault injection
    /// (seizure releases first), the deadline sweep, load shedding,
    /// retry re-enqueue, then admission (unless stalled), decode,
    /// retirement — so a freed page or expired deadline is visible to
    /// the *same* step's admission decisions.
    pub fn step(&mut self) -> Result<usize> {
        let step = self.step_index;
        self.inject_step_faults(step);
        self.enforce_deadlines(step);
        self.shed_overload();
        self.requeue_retries(step);
        if step >= self.stall_until {
            self.admit_and_prefill()?;
        }
        self.fire_ready_fanout();
        if self.active.iter().any(|s| s.phase == Phase::Decoding) {
            self.decode_round()?;
        }
        self.retire_finished();
        self.step_index += 1;
        Ok(self.active.len())
    }

    /// Release due page seizures, then (with a plan installed and work
    /// pending) offer the step-scoped injection sites: scheduler stalls
    /// and pool seizures. Sites are only offered while the engine has
    /// work, so an idle drain after the trace consumes no randomness.
    fn inject_step_faults(&mut self, step: u64) {
        if !self.seized.is_empty() {
            let mut held = std::mem::take(&mut self.seized);
            held.retain(|(due, pages)| {
                if step >= *due {
                    self.pool.release_pages(pages);
                    false
                } else {
                    true
                }
            });
            self.seized = held;
        }
        let Engine {
            faults,
            metrics,
            router,
            active,
            retryq,
            pool,
            seized,
            stall_until,
            ..
        } = self;
        let Some(plan) = faults.as_mut() else { return };
        if router.is_empty() && active.is_empty() && retryq.is_empty() {
            return;
        }
        if plan.fires(FaultKind::SchedStall, 0, step, step) {
            metrics.robustness.fault(FaultKind::SchedStall);
            *stall_until = step + plan.stall_steps;
        }
        if plan.fires(FaultKind::PoolSeize, 0, step, step) {
            metrics.robustness.fault(FaultKind::PoolSeize);
            let pages = pool.seize_free_pages(plan.seize_pages);
            if !pages.is_empty() {
                seized.push((step + plan.seize_hold_steps, pages));
            }
        }
    }

    /// Kill every request whose step-denominated deadline has expired —
    /// queued, parked for retry, or active (any non-finished phase).
    /// Active kills release their pages at retirement this same step.
    fn enforce_deadlines(&mut self, step: u64) {
        let engine_deadline = self.cfg.deadline_steps as u64;
        let expired = |r: &Request| match deadline_of(r, engine_deadline) {
            Some(d) => step.saturating_sub(r.arrival_step) >= d,
            None => false,
        };
        let mut dead: Vec<Request> = self.router.drain_where(|r| expired(r));
        let mut i = 0;
        while i < self.retryq.len() {
            if expired(&self.retryq[i].1) {
                dead.push(self.retryq.remove(i).1);
            } else {
                i += 1;
            }
        }
        for req in dead {
            self.metrics.robustness.deadline_kills += 1;
            self.finish_queued(req, FinishReason::DeadlineExceeded);
        }
        let Engine {
            active, metrics, ..
        } = self;
        for s in active.iter_mut() {
            if matches!(s.phase, Phase::Finished(_)) || !expired(&s.req) {
                continue;
            }
            s.phase = Phase::Finished(FinishReason::DeadlineExceeded);
            metrics.robustness.deadline_kills += 1;
        }
    }

    /// Queue-depth load shedding: while the router holds more than
    /// `shed_queue_depth` waiting requests, shed newest-lowest-first
    /// with [`FinishReason::Shed`] (0 disables).
    fn shed_overload(&mut self) {
        let cap = self.cfg.sched.shed_queue_depth;
        if cap == 0 {
            return;
        }
        while self.router.depth() > cap {
            let Some(req) = self.router.shed_lowest_newest() else {
                break;
            };
            self.metrics.robustness.sheds += 1;
            self.finish_queued(req, FinishReason::Shed);
        }
    }

    /// Re-enqueue retry-parked requests whose backoff has elapsed. The
    /// resubmission goes straight to the router (preserving the
    /// original `arrival_step`, so deadlines keep counting across
    /// retries); a router rejection makes the eviction terminal.
    fn requeue_retries(&mut self, step: u64) {
        if self.retryq.is_empty() {
            return;
        }
        let mut due = Vec::new();
        let mut i = 0;
        while i < self.retryq.len() {
            if self.retryq[i].0 <= step {
                due.push(self.retryq.remove(i).1);
            } else {
                i += 1;
            }
        }
        for req in due {
            match self.router.submit(req.clone()) {
                Admission::Queued => {}
                Admission::Rejected(_) => {
                    // The retry could not even re-enter the queue (router
                    // backpressure): the eviction is terminal after all.
                    self.finish_queued(req, FinishReason::Evicted);
                }
            }
        }
    }

    /// Close the stream of a request that never held a slot (cancelled,
    /// shed, deadline-killed, or terminally evicted while queued): one
    /// terminal event, one completion with the true prompt echo and
    /// queue-time attribution, zero generated tokens.
    fn finish_queued(&mut self, req: Request, reason: FinishReason) {
        self.resolve_orphaned_fanout(req.id, reason);
        let now = Instant::now();
        let total = (now - req.arrival).as_secs_f64();
        self.metrics.total_latency.record(total);
        self.metrics.requests_completed += 1;
        self.events.push(StreamEvent::Finished {
            request_id: req.id,
            reason,
        });
        self.completions.push(Completion {
            id: req.id,
            prompt: req.prompt,
            text: String::new(),
            tokens: Vec::new(),
            reason,
            prompt_tokens: req.prompt_tokens,
            queue_time: total,
            prefill_time: 0.0,
            first_token_latency: 0.0,
            total_latency: total,
            allocation: String::new(),
            guard_switches: 0,
        });
    }

    /// Run until the queue and all slots drain; returns completions.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while !self.idle() {
            self.step()?;
        }
        Ok(self.take_completions())
    }

    // ---- admission / prefill ------------------------------------------

    /// Phase 1 of a step: spend this iteration's prefill budget — first
    /// on in-flight chunked prefills (FCFS in admission order), then on
    /// admissions, while the pure scheduler approves.
    fn admit_and_prefill(&mut self) -> Result<()> {
        let is_lab = matches!(self.backend, Backend::Lab(_));
        let mut budget = self.cfg.sched.max_batch_prefill_tokens.max(1);

        // (a) Continue in-flight chunked prefills.
        for idx in 0..self.active.len() {
            if budget == 0 {
                break;
            }
            if self.active[idx].phase != Phase::Prefilling {
                continue;
            }
            let rem = self.active[idx].prompt_len - self.active[idx].prefilled;
            let chunk = rem.min(budget);
            budget -= chunk;
            if let Err(e) = self.prefill_chunk_lab(idx, chunk) {
                if is_kv_backpressure(&e) {
                    self.active[idx].phase = Phase::Finished(FinishReason::Evicted);
                } else {
                    return Err(e);
                }
            }
        }

        // (b) Admissions under the remaining budget.
        loop {
            let (ptoks, max_new, shared) = match self.router.peek() {
                // Prompt capacity differs per backend: the PJRT prefill
                // module is one fixed shape, the lab chunks up to max_seq.
                Some(h) => {
                    // Radix probe: how much of this prompt is already
                    // cached. Capped at the tokens *before* the last
                    // prompt row — its prefill must still run to produce
                    // the first-token logits (probe truncates to page
                    // alignment itself). Read-only: the LRU stamps move
                    // only when the match is consumed at admission.
                    let shared = match &self.prefix {
                        Some(pc) => {
                            let ids =
                                tokenizer::encode_prompt(&h.prompt, self.dims.max_seq, self.sp);
                            match pc.probe(&ids, ids.len().saturating_sub(1)) {
                                PrefixDecision::Hit { tokens } => tokens,
                                PrefixDecision::Miss => 0,
                            }
                        }
                        None => 0,
                    };
                    (
                        h.prompt_tokens
                            .min(if is_lab { self.dims.max_seq } else { self.dims.prefill_seq }),
                        h.params.max_new_tokens,
                        shared,
                    )
                }
                None => break,
            };
            let st = BatchState {
                active_slots: self.active.len(),
                max_slots: self.max_slots(),
                committed_tokens: self.committed_total(),
                prefill_budget_left: budget,
                free_pages: self.pool.free_pages(),
                page_tokens: self.pool.page_tokens,
                n_layers: self.dims.n_layers,
                max_seq: self.dims.max_seq,
                chunkable: is_lab,
                shared_tokens: shared,
            };
            match scheduler::admission(&self.cfg.sched, &st, ptoks, max_new) {
                SchedDecision::Admit { chunk } => {
                    // A peek/pop disagreement would be a router bug, but
                    // it must never abort a serving process mid-flight:
                    // count it, skip the admission, and let the next
                    // step re-peek a consistent head.
                    let Some(req) = self.router.pop() else {
                        self.metrics.robustness.router_desyncs += 1;
                        break;
                    };
                    budget = budget.saturating_sub(chunk);
                    self.admit(req, chunk, shared)?;
                }
                SchedDecision::DeferSlots => {
                    self.metrics.deferrals.slots += 1;
                    break;
                }
                SchedDecision::DeferTotalTokens => {
                    self.metrics.deferrals.total_tokens += 1;
                    break;
                }
                SchedDecision::DeferPrefillBudget => {
                    self.metrics.deferrals.prefill_budget += 1;
                    break;
                }
                SchedDecision::DeferKvPages => {
                    // Cold cached prefixes are reclaimable pool space:
                    // evict and re-decide this head before deferring.
                    // Terminates — a round that frees nothing breaks.
                    if self.relieve_kv_pressure(ptoks, max_new) > 0 {
                        continue;
                    }
                    self.metrics.deferrals.kv_pages += 1;
                    break;
                }
                SchedDecision::RejectNeverFits => {
                    // Same relief before the verdict becomes terminal: a
                    // pool mostly held by the cache is not "never fits".
                    if self.relieve_kv_pressure(ptoks, max_new) > 0 {
                        continue;
                    }
                    // This request can never run on this pool; surface an
                    // Evicted completion instead of spinning forever, and
                    // keep trying the next head. A peek/pop disagreement
                    // is recoverable here too — same argument as Admit.
                    let Some(req) = self.router.pop() else {
                        self.metrics.robustness.router_desyncs += 1;
                        break;
                    };
                    let now = Instant::now();
                    self.reject_evicted(req.id, req.arrival, now);
                }
            }
        }
        Ok(())
    }

    /// Evict cold cached prefixes until the pool could hold a candidate
    /// committing `ptoks + max_new` tokens (or the cache runs out of
    /// leaves). Returns page references freed — 0 without a cache, which
    /// keeps the defer/reject paths exactly as before the cache existed.
    fn relieve_kv_pressure(&mut self, ptoks: usize, max_new: usize) -> usize {
        let commit = scheduler::committed_tokens(ptoks, max_new, self.dims.max_seq);
        let need =
            SeqCache::pages_required(self.dims.n_layers, commit, self.pool.page_tokens.max(1));
        let freed = match self.prefix.as_mut() {
            Some(pc) => pc.evict_for(&mut self.pool, need),
            None => 0,
        };
        self.metrics.prefix.evictions += freed as u64;
        freed
    }

    /// Admit one popped request: seat the slot, run its first prefill
    /// chunk (lab) or its whole fixed-shape prefill (PJRT). `shared` is
    /// the admission probe's cached-prefix span: the slot's cache seeds
    /// from the radix tree's pages and prefill starts beyond them. KV
    /// exhaustion during the first forward rejects the request as
    /// Evicted instead of killing the engine.
    fn admit(&mut self, req: Request, first_chunk: usize, shared: usize) -> Result<()> {
        let admitted = Instant::now();
        let (rid, arrival) = (req.id, req.arrival);
        if matches!(self.backend, Backend::Lab(_)) {
            let d = self.dims;
            let prompt_ids = tokenizer::encode_prompt(&req.prompt, d.max_seq, self.sp);
            let prompt_len = prompt_ids.len();
            // Seed from the radix cache: the returned cache's
            // `len_tokens` is the prefix actually covered (the tree may
            // have cooled since the probe — trust the seed, not the
            // probe). A refcount-saturation failure falls back to a cold
            // admit; the shared rows are byte-identical to what this
            // request's own prefill would write, so skipping them
            // changes nothing downstream (chunked prefill is
            // boundary-invariant).
            let mut cache = SeqCache::new(d.n_layers);
            if shared > 0 {
                if let Some(pc) = self.prefix.as_mut() {
                    if let Ok(seeded) = pc.seed(&mut self.pool, &prompt_ids, shared) {
                        cache = seeded;
                    }
                }
            }
            let prefilled = cache.len_tokens.min(prompt_len.saturating_sub(1));
            debug_assert_eq!(
                prefilled,
                cache.len_tokens,
                "probe cap keeps the seed strictly inside the prompt"
            );
            if prefilled > 0 {
                self.metrics.prefix.hits += 1;
                self.metrics.prefix.tokens_saved += prefilled as u64;
            }
            let rng = request_rng(req.id);
            self.active.push(ActiveRequest {
                guard: Guard::new(self.cfg.policy).with_start(self.cfg.start_alloc),
                cache,
                tokens: prompt_ids.clone(),
                prompt_ids,
                prefilled,
                prompt_len,
                phase: Phase::Prefilling,
                rng,
                admitted,
                prefill_done: None,
                first_token: None,
                last_token: None,
                req,
            });
            let idx = self.active.len() - 1;
            if let Err(e) = self.prefill_chunk_lab(idx, first_chunk) {
                let mut s = self.active.remove(idx);
                s.cache.release(&mut self.pool);
                if is_kv_backpressure(&e) {
                    self.reject_evicted(rid, arrival, admitted);
                    return Ok(());
                }
                return Err(e);
            }
            Ok(())
        } else {
            match self.prefill_pjrt(req, admitted) {
                Ok(slot) => {
                    self.active.push(slot);
                    Ok(())
                }
                Err(e) if is_kv_backpressure(&e) => {
                    self.reject_evicted(rid, arrival, admitted);
                    Ok(())
                }
                Err(e) => Err(e),
            }
        }
    }

    /// Fire pending best-of fan-outs: every primary whose prefill
    /// completed this step forks its full prompt cache (CoW — shares
    /// every page, even the partial tail; the sibling's first decode
    /// write privatizes what it touches) into one decode slot per
    /// sibling. Each sibling samples its first token from the primary's
    /// final prompt logits with its **own** id-seeded RNG — from here on
    /// it is indistinguishable, bit for bit, from having been its own
    /// request: same cache bytes its own prefill would have written,
    /// same RNG stream, same guard start (the guard chain replays during
    /// prefill leave the cache as if the final allocation ran alone, and
    /// the primary's post-prefill guard state is exactly what the
    /// sibling's own prefill would have produced).
    fn fire_ready_fanout(&mut self) {
        if self.fanout_ready.is_empty() {
            return;
        }
        let d = self.dims;
        let eos = self.sp.eos;
        for (pid, row) in std::mem::take(&mut self.fanout_ready) {
            let Some(fi) = self.fanout.iter().position(|(p, _)| *p == pid) else {
                continue;
            };
            let (_, siblings) = self.fanout.remove(fi);
            // The primary is still seated this step even if its first
            // token already finished it (retirement runs after fan-out).
            let Some(pi) = self.active.iter().position(|s| s.req.id == pid) else {
                continue;
            };
            for sid in siblings {
                let cache = match self.active[pi].cache.fork(&mut self.pool) {
                    Ok(c) => c,
                    Err(_) => {
                        // Refcount saturation: this sibling never gets a
                        // cache — close its stream as evicted.
                        self.finish_fanout_orphan(sid, FinishReason::Evicted);
                        continue;
                    }
                };
                let p = &self.active[pi];
                let mut req = p.req.clone();
                req.id = sid;
                let mut s = ActiveRequest {
                    guard: p.guard.clone(),
                    cache,
                    tokens: p.prompt_ids.clone(),
                    prompt_ids: p.prompt_ids.clone(),
                    prefilled: p.prompt_len,
                    prompt_len: p.prompt_len,
                    phase: Phase::Decoding,
                    rng: request_rng(sid),
                    admitted: p.admitted,
                    prefill_done: p.prefill_done,
                    first_token: None,
                    last_token: None,
                    req,
                };
                self.metrics.prefix.fanout_forks += 1;
                let tok = sample(&row, s.req.params.sampling, &mut s.rng);
                emit_token(&mut s, tok, &mut self.metrics, &mut self.events);
                apply_stop_rules(&mut s, tok, d.max_seq, eos);
                self.active.push(s);
            }
        }
    }

    /// Close the stream of a fan-out sibling that never got (or never
    /// will get) a decode slot: the primary terminated before decoding,
    /// or its fork failed. One terminal event, one empty completion,
    /// the primary's terminal reason.
    fn finish_fanout_orphan(&mut self, sid: u64, reason: FinishReason) {
        self.metrics.requests_completed += 1;
        self.events.push(StreamEvent::Finished {
            request_id: sid,
            reason,
        });
        self.completions.push(Completion {
            id: sid,
            prompt: String::new(),
            text: String::new(),
            tokens: Vec::new(),
            reason,
            prompt_tokens: 0,
            queue_time: 0.0,
            prefill_time: 0.0,
            first_token_latency: 0.0,
            total_latency: 0.0,
            allocation: String::new(),
            guard_switches: 0,
        });
    }

    /// A primary reached a terminal state with its fan-out unfired
    /// (shed, deadline-killed, cancelled, quarantined at prefill, or
    /// terminally evicted): orphan every sibling with the same reason.
    /// Eviction-*retry* parking never lands here — `retire_finished`
    /// re-parks without finishing, so the registration survives the
    /// retry and fires on the successful attempt.
    fn resolve_orphaned_fanout(&mut self, primary: u64, reason: FinishReason) {
        let Some(fi) = self.fanout.iter().position(|(p, _)| *p == primary) else {
            return;
        };
        let (_, siblings) = self.fanout.remove(fi);
        for sid in siblings {
            self.finish_fanout_orphan(sid, reason);
        }
    }

    /// Complete a request that could not be admitted (pool exhaustion at
    /// prefill, or a commitment larger than the whole pool): an Evicted
    /// completion with correct time attribution — queueing up to
    /// `admitted`, anything after as prefill time — so the caller sees
    /// the outcome instead of a dead engine. The prompt echo is empty
    /// (the request was consumed by the failed admission; this path
    /// trades the echo for not cloning every admitted prompt).
    fn reject_evicted(&mut self, id: u64, arrival: Instant, admitted: Instant) {
        self.resolve_orphaned_fanout(id, FinishReason::Evicted);
        let now = Instant::now();
        self.metrics.requests_completed += 1;
        self.events.push(StreamEvent::Finished {
            request_id: id,
            reason: FinishReason::Evicted,
        });
        self.completions.push(Completion {
            id,
            prompt: String::new(),
            text: String::new(),
            tokens: Vec::new(),
            reason: FinishReason::Evicted,
            prompt_tokens: 0,
            queue_time: (admitted - arrival).as_secs_f64(),
            prefill_time: (now - admitted).as_secs_f64(),
            first_token_latency: 0.0,
            total_latency: (now - arrival).as_secs_f64(),
            allocation: String::new(),
            guard_switches: 0,
        });
    }

    /// Run one prefill chunk of slot `idx` through the lab model,
    /// walking the guard's fallback chain on a trip (the chunk is
    /// functional in (ids, range, cache-prefix) — each replay rewrites
    /// the same rows under the rescue allocation). On the final chunk:
    /// sample the first token from the last prompt row's logits, emit
    /// it, and move the slot to `Decoding`.
    fn prefill_chunk_lab(&mut self, idx: usize, chunk: usize) -> Result<()> {
        let d = self.dims;
        let eos = self.sp.eos;
        let Engine {
            backend,
            pool,
            active,
            metrics,
            events,
            prefix,
            fanout,
            fanout_ready,
            ..
        } = self;
        let Backend::Lab(model) = backend else {
            unreachable!("chunked prefill on a PJRT engine")
        };
        let s = &mut active[idx];
        let start = s.prefilled;
        let end = (start + chunk).min(s.prompt_len);
        debug_assert!(end > start, "zero-length prefill chunk");
        let alloc =
            Allocation::parse(s.guard.allocation()).expect("guard allocation maps to the lab");
        let (mut logits, mut sig) = model
            .prefill_chunk(alloc, &s.prompt_ids, start, end, &mut s.cache, pool)
            .context("lab prefill chunk")?;
        let mut overflowed = false;
        while observe_guard(&mut s.guard, &sig, metrics) {
            overflowed = true;
            let rescue = Allocation::parse(s.guard.allocation())
                .expect("guard allocation maps to the lab");
            let (l2, s2) = model
                .prefill_chunk(rescue, &s.prompt_ids, start, end, &mut s.cache, pool)
                .context("lab prefill chunk replay")?;
            logits = l2;
            sig = s2;
        }
        if overflowed {
            metrics.overflow_steps += 1;
        }
        metrics.prefill_tokens += (end - start) as u64;
        metrics.prefill_chunks += 1;
        s.prefilled = end;
        if end == s.prompt_len {
            s.prefill_done = Some(Instant::now());
            let row = logits.as_ref().expect("final chunk returns logits");
            // Watchdog: a non-finite first-token row means this slot's
            // numerics are poisoned beyond what the guard chain could
            // rescue — quarantine it instead of sampling garbage.
            if row.iter().any(|x| !x.is_finite()) {
                s.phase = Phase::Finished(FinishReason::Faulted);
                metrics.robustness.quarantines += 1;
                return Ok(());
            }
            s.phase = Phase::Decoding;
            // Publish the finalized page-aligned prompt pages into the
            // radix cache (best-effort, shares — never copies), then
            // trim the cache back to its page budget. Decode never
            // touches these pages: the write position's page is either
            // past them or privatized by `prepare_step` first.
            if let Some(pc) = prefix.as_mut() {
                pc.insert(pool, &s.prompt_ids, &s.cache);
                metrics.prefix.evictions += pc.enforce_budget(pool) as u64;
            }
            // A registered best-of primary hands its final prompt logits
            // to the fan-out stage (fired after admission this step).
            if fanout.iter().any(|(p, _)| *p == s.req.id) {
                fanout_ready.push((s.req.id, row.clone()));
            }
            let tok = sample(row, s.req.params.sampling, &mut s.rng);
            emit_token(s, tok, metrics, events);
            apply_stop_rules(s, tok, d.max_seq, eos);
        }
        Ok(())
    }

    fn prefill_pjrt(&mut self, req: Request, admitted: Instant) -> Result<ActiveRequest> {
        let d = self.dims;
        let Backend::Pjrt(rt) = &self.backend else {
            unreachable!("prefill_pjrt on a lab engine")
        };
        let rt = *rt;
        let (mut ids, n) = tokenizer::encode(&req.prompt, d.prefill_seq, self.sp);
        ids.truncate(d.prefill_seq);
        let mut guard = Guard::new(self.cfg.policy).with_start(self.cfg.start_alloc);

        let mut out = rt
            .prefill(guard.allocation(), &ids, n)
            .context("prefill")?;
        // Guard: inspect the last-prompt-token logits row for overflow.
        // (The PJRT modules are uninstrumented, so this is the legacy
        // logits signal — the fallback; the lab backend feeds kernel
        // telemetry via GuardSignal::from_attention instead.)
        let v = d.vocab_size;
        let last_row = &out.logits[(n - 1) * v..n * v];
        let sig = GuardSignal::from_logits(last_row);
        if observe_guard(&mut guard, &sig, &mut self.metrics) {
            self.metrics.overflow_steps += 1;
            out = rt
                .prefill(guard.allocation(), &ids, n)
                .context("prefill replay under PASA")?;
        }
        let prefill_done = Instant::now();
        self.metrics.prefill_tokens += n as u64;
        self.metrics.prefill_chunks += 1;

        // Seed the paged cache from the dense prefill output. On any
        // failure the partially-grown cache must hand its pages back —
        // leaking them would shrink the pool for every later request.
        let mut cache = SeqCache::new(d.n_layers);
        let w = d.head_width();
        let per_layer = d.max_seq * w;
        let seeded = (|| -> Result<()> {
            cache.ensure_capacity(&mut self.pool, n)?;
            for l in 0..d.n_layers {
                for p in 0..n {
                    let off = l * per_layer + p * w;
                    cache.write_row(
                        &mut self.pool,
                        l,
                        p,
                        &out.cache.k[off..off + w],
                        &out.cache.v[off..off + w],
                    )?;
                }
            }
            Ok(())
        })();
        if let Err(e) = seeded {
            cache.release(&mut self.pool);
            return Err(e.context("prefill cache seeding"));
        }

        // First generated token comes from the prompt's last logits row.
        let last_row = &out.logits[(n - 1) * v..n * v];
        let mut slot = ActiveRequest {
            guard,
            cache,
            tokens: ids[..n].to_vec(),
            prompt_ids: ids[..n].to_vec(),
            prefilled: n,
            prompt_len: n,
            phase: Phase::Decoding,
            rng: request_rng(req.id),
            admitted,
            prefill_done: Some(prefill_done),
            first_token: None,
            last_token: None,
            req,
        };
        // Watchdog (PJRT face): quarantine a non-finite first-token row
        // that even the replay left poisoned, instead of sampling it.
        if last_row.iter().any(|x| !x.is_finite()) {
            slot.phase = Phase::Finished(FinishReason::Faulted);
            self.metrics.robustness.quarantines += 1;
            return Ok(slot);
        }
        let tok = sample(last_row, slot.req.params.sampling, &mut slot.rng);
        emit_token(&mut slot, tok, &mut self.metrics, &mut self.events);
        apply_stop_rules(&mut slot, tok, d.max_seq, self.sp.eos);
        Ok(slot)
    }

    // ---- decode --------------------------------------------------------

    /// Distinct allocations among decoding slots this round.
    fn allocation_groups(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for s in &self.active {
            if s.phase != Phase::Decoding {
                continue;
            }
            let a = s.guard.allocation();
            if !out.contains(&a) {
                out.push(a);
            }
        }
        out
    }

    fn decode_round(&mut self) -> Result<()> {
        if matches!(self.backend, Backend::Lab(_)) {
            self.decode_round_lab()
        } else {
            for alloc in self.allocation_groups() {
                self.decode_group_pjrt(alloc)?;
            }
            Ok(())
        }
    }

    /// Retire finished slots: release pages, emit the completion, compact
    /// the batch (`filter`). The freed budget and pages are visible to
    /// the *next* step's admission (`concatenate`).
    ///
    /// Evicted slots with retry budget left do **not** complete here:
    /// the request parks in the retry queue with exponential step
    /// backoff (`2^retries`, capped) and re-runs from scratch — its
    /// stream re-emits from index 0, and the eventual completion carries
    /// only the successful attempt's tokens. Exactly one terminal event
    /// is ever emitted, at the attempt that actually finishes.
    fn retire_finished(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            let Phase::Finished(reason) = self.active[i].phase else {
                i += 1;
                continue;
            };
            let mut ar = self.active.remove(i);
            ar.cache.release(&mut self.pool);
            if reason == FinishReason::Evicted && ar.req.retries < self.cfg.sched.retry_budget {
                let mut req = ar.req;
                req.retries += 1;
                self.metrics.robustness.retries += 1;
                self.metrics.deferrals.retry_backoff += 1;
                let backoff = 1u64 << (req.retries.min(6) as u32);
                self.retryq.push((self.step_index + backoff, req));
            } else {
                self.finish(ar);
            }
        }
    }

    /// Lab-backend decode: the decoding slots' paged decode steps fan out
    /// over the persistent worker pool (`O(len_tokens)` page gathers each,
    /// kernel telemetry into the guard, per-slot chain replay on a trip).
    ///
    /// Three phases keep the shared-pool writes sound and the results
    /// bit-identical to a sequential loop:
    /// 1. **prepare** (sequential, exclusive pool): grow each slot's
    ///    capacity and privatize the pages its step will write
    ///    ([`SeqCache::prepare_step`]); pool exhaustion here is per-slot
    ///    backpressure (evict), never a crash.
    /// 2. **compute** (parallel, shared pool): each runnable slot's step
    ///    — including any guard-triggered chain replay — runs as a worker
    ///    pool tile via [`LabModel::decode_step_prepared`]; slots own
    ///    their caches, writes land only in their privatized pages.
    /// 3. **fold** (sequential, in slot order): metrics, then sampling
    ///    from each slot's own RNG — deterministic regardless of worker
    ///    interleaving.
    fn decode_round_lab(&mut self) -> Result<()> {
        let d = self.dims;
        // Phase 1: allocate/privatize under exclusive pool access.
        {
            let Engine {
                active,
                pool,
                prefix,
                metrics,
                ..
            } = self;
            for s in active.iter_mut() {
                if s.phase != Phase::Decoding {
                    continue;
                }
                let pos = s.tokens.len() - 1;
                let mut r = s.cache.prepare_step(pool, pos);
                if let Err(e) = &r {
                    if is_kv_backpressure(e) {
                        // Cold cached prefixes are reclaimable: evict up
                        // to a step's worst-case page demand (one fresh
                        // page plus one CoW copy per K/V table) and retry
                        // once before treating exhaustion as eviction.
                        let freed = prefix
                            .as_mut()
                            .map_or(0, |pc| pc.evict_for(pool, 4 * d.n_layers));
                        metrics.prefix.evictions += freed as u64;
                        if freed > 0 {
                            r = s.cache.prepare_step(pool, pos);
                        }
                    }
                }
                match r {
                    Ok(()) => {}
                    // KV pool exhausted: backpressure, not a crash — evict
                    // the slot, its pages free up at retirement.
                    Err(e) if is_kv_backpressure(&e) => {
                        s.phase = Phase::Finished(FinishReason::Evicted);
                    }
                    Err(e) => return Err(e.context("lab decode prepare")),
                }
            }
        }
        let runnable: Vec<bool> = self
            .active
            .iter()
            .map(|s| s.phase == Phase::Decoding)
            .collect();
        let run_idx: Vec<usize> = runnable
            .iter()
            .enumerate()
            .filter_map(|(i, &r)| r.then_some(i))
            .collect();
        if run_idx.is_empty() {
            return Ok(());
        }
        self.metrics.decode_batch_occupancy.push(run_idx.len());

        // Chaos: per-slot decode faults are drawn here, sequentially in
        // slot order — never inside the parallel region — so the
        // injection stream is a pure function of the seeded plan and the
        // (request id, token index) sites offered, independent of worker
        // interleaving. The site is the slot's generated-token count,
        // identical in solo and batched runs (what makes the scripted
        // co-batch bit-identity test exact).
        #[derive(Clone, Copy, Default)]
        struct SlotFault {
            step_error: bool,
            latency_spike: bool,
            logit_nan: bool,
        }
        let step = self.step_index;
        let spike_secs = self.faults.as_ref().map_or(0.0, |p| p.latency_spike_secs);
        let slot_faults: Vec<SlotFault> = {
            let Engine {
                faults,
                active,
                metrics,
                ..
            } = self;
            match faults.as_mut() {
                None => vec![SlotFault::default(); active.len()],
                Some(plan) => active
                    .iter()
                    .map(|s| {
                        let mut f = SlotFault::default();
                        if s.phase != Phase::Decoding {
                            return f;
                        }
                        let site = (s.tokens.len() - s.prompt_len) as u64;
                        if plan.fires(FaultKind::StepError, s.req.id, site, step) {
                            metrics.robustness.fault(FaultKind::StepError);
                            f.step_error = true;
                        }
                        if plan.fires(FaultKind::LatencySpike, s.req.id, site, step) {
                            metrics.robustness.fault(FaultKind::LatencySpike);
                            f.latency_spike = true;
                        }
                        if plan.fires(FaultKind::LogitNan, s.req.id, site, step) {
                            metrics.robustness.fault(FaultKind::LogitNan);
                            f.logit_nan = true;
                        }
                        f
                    })
                    .collect(),
            }
        };

        // Phase 2: the compute steps as pool tiles. The whole slot vector
        // moves into the task table (each task owns its cache and guard)
        // and shares the model and the page pool read-mostly.
        #[derive(Default)]
        struct StepOut {
            logits: Vec<f32>,
            /// One wall-clock sample per executed step (first run + every
            /// chain replay).
            latencies: Vec<f64>,
            overflowed: bool,
            switch_delta: u64,
            err: Option<anyhow::Error>,
        }
        let slots = std::mem::take(&mut self.active);
        let tasks: Vec<Mutex<(ActiveRequest, StepOut)>> = slots
            .into_iter()
            .map(|s| Mutex::new((s, StepOut::default())))
            .collect();
        {
            let Backend::Lab(model) = &self.backend else {
                unreachable!("decode_round_lab on a PJRT engine")
            };
            let model: &LabModel = model;
            let pool_ref = &self.pool;
            let tasks_ref = &tasks;
            let run_ref = &run_idx;
            let faults_ref = &slot_faults;
            crate::pool::global().run_tiles(run_ref.len(), |t| {
                let mut slot = tasks_ref[run_ref[t]].lock().unwrap();
                let (ar, out) = &mut *slot;
                if faults_ref[run_ref[t]].step_error {
                    // Simulated backend failure (drawn pre-fan-out): the
                    // slot's step "ran" and died; the fold quarantines.
                    out.err = Some(anyhow::anyhow!("{}", INJECTED_STEP_ERROR));
                    return;
                }
                let alloc = Allocation::parse(ar.guard.allocation())
                    .expect("guard allocation maps to the lab");
                let tok = *ar.tokens.last().unwrap();
                let pos = ar.tokens.len() - 1;
                let t0 = Instant::now();
                match model.decode_step_prepared(alloc, tok, pos, &mut ar.cache, pool_ref) {
                    Ok((mut logits, mut sig)) => {
                        out.latencies.push(t0.elapsed().as_secs_f64());
                        if sig.overflow_events > 0 || sig.nonfinite > 0 {
                            out.overflowed = true;
                        }
                        let before = ar.guard.switches;
                        // Replay this slot's step down the guard's
                        // fallback chain (fp8 → pasa8 → pasa on an FP8
                        // start). The step is functional in (token, pos,
                        // cache prefix), so each replay rewrites the same
                        // KV rows — the cache ends up exactly as if the
                        // final allocation had run the step first. The
                        // loop is bounded by the chain length.
                        while ar.guard.observe_signal(&sig) {
                            let rescue = Allocation::parse(ar.guard.allocation())
                                .expect("guard allocation maps to the lab");
                            let t1 = Instant::now();
                            match model.decode_step_prepared(
                                rescue,
                                tok,
                                pos,
                                &mut ar.cache,
                                pool_ref,
                            ) {
                                Ok((l2, s2)) => {
                                    logits = l2;
                                    sig = s2;
                                    out.latencies.push(t1.elapsed().as_secs_f64());
                                    if sig.overflow_events > 0 || sig.nonfinite > 0 {
                                        out.overflowed = true;
                                    }
                                }
                                Err(e) => {
                                    out.err = Some(e.context("lab decode replay"));
                                    break;
                                }
                            }
                        }
                        out.switch_delta = (ar.guard.switches - before) as u64;
                        out.logits = logits;
                    }
                    Err(e) => out.err = Some(e.context("lab decode step")),
                }
            });
        }

        // Phase 3: restore the slot vector in order, fold metrics, apply
        // injected damage, run the watchdog, sample.
        let eos = self.sp.eos;
        let mut failure: Option<anyhow::Error> = None;
        let Engine {
            active,
            metrics,
            events,
            pool,
            faults: plan_opt,
            ..
        } = self;
        for (i, task) in tasks.into_iter().enumerate() {
            let (ar, out) = task.into_inner().unwrap();
            active.push(ar);
            if !runnable[i] {
                continue;
            }
            let fr = slot_faults[i];
            let s = active.last_mut().unwrap();
            let mut first = true;
            for &lat in &out.latencies {
                metrics.decode_steps += 1;
                // Replayed steps are real serving latency: record them.
                // An injected latency spike inflates the step's first
                // sample — the observational face of a slow backend step
                // (nothing feeds back into scheduling, so determinism is
                // untouched).
                let lat = if first && fr.latency_spike {
                    lat + spike_secs
                } else {
                    lat
                };
                first = false;
                metrics.step_latency.record(lat);
            }
            if out.overflowed {
                metrics.overflow_steps += 1;
            }
            metrics.guard_switches += out.switch_delta;
            if let Some(e) = out.err {
                if is_kv_backpressure(&e) {
                    s.phase = Phase::Finished(FinishReason::Evicted);
                } else if is_injected_error(&e) {
                    // A (simulated) backend step failure is this slot's
                    // problem only: quarantine it, keep the batch alive.
                    s.phase = Phase::Finished(FinishReason::Faulted);
                    metrics.robustness.quarantines += 1;
                } else if failure.is_none() {
                    failure = Some(e);
                }
                continue;
            }
            let mut logits = out.logits;
            if fr.logit_nan {
                logits[0] = f32::NAN;
            }
            // Watchdog: a non-finite logit row must never reach sampling
            // — quarantine the slot instead of emitting garbage tokens.
            if logits.iter().any(|x| !x.is_finite()) {
                s.phase = Phase::Finished(FinishReason::Faulted);
                metrics.robustness.quarantines += 1;
                continue;
            }
            // KV corruption targets the row this step just wrote: the
            // damage is read by *later* attention steps (and only by
            // this sequence — pages are per-slot), modelling silent
            // storage corruption in cold KV. `site` is captured before
            // `advance_slot` grows the token stream.
            let written_pos = s.tokens.len() - 1;
            let site = (s.tokens.len() - s.prompt_len) as u64;
            advance_slot(s, &logits, d.max_seq, eos, metrics, events);
            if let Some(plan) = plan_opt.as_mut() {
                if plan.fires(FaultKind::KvNanPoison, s.req.id, site, step) {
                    metrics.robustness.fault(FaultKind::KvNanPoison);
                    s.cache.corrupt_row(pool, 0, written_pos, false);
                } else if plan.fires(FaultKind::KvBitFlip, s.req.id, site, step) {
                    metrics.robustness.fault(FaultKind::KvBitFlip);
                    s.cache.corrupt_row(pool, 0, written_pos, true);
                }
            }
        }
        if let Some(e) = failure {
            return Err(e);
        }
        Ok(())
    }

    /// PJRT-backend decode: one batched dense step for every decoding
    /// slot on `alloc` (the compiled decode module consumes dense caches,
    /// so this path pays the `fill_dense` assembly and sniffs logits).
    /// Batch lane = slot index in `active` — the admission path caps the
    /// batch at the module's dense width.
    fn decode_group_pjrt(&mut self, alloc: &'static str) -> Result<()> {
        let d = self.dims;
        let b = d.decode_batch;
        let w = d.head_width();
        let v = d.vocab_size;
        let seq_floats = d.max_seq * w;
        let Backend::Pjrt(rt) = &self.backend else {
            unreachable!("decode_group_pjrt on a lab engine")
        };
        let rt = *rt;

        let members: Vec<usize> = (0..self.active.len())
            .filter(|&i| {
                let s = &self.active[i];
                s.guard.allocation() == alloc && s.phase == Phase::Decoding
            })
            .collect();
        if members.is_empty() {
            return Ok(());
        }
        debug_assert!(self.active.len() <= b, "PJRT batch wider than its module");
        self.metrics.decode_batch_occupancy.push(members.len());

        // Assemble the dense batch caches from the paged pool.
        self.kbatch.fill(0.0);
        self.vbatch.fill(0.0);
        let mut tokens = vec![self.sp.pad as i32; b];
        let mut pos = vec![0i32; b];
        for &i in &members {
            let s = &self.active[i];
            let p = s.tokens.len() - 1; // position of the token being fed
            tokens[i] = *s.tokens.last().unwrap() as i32;
            pos[i] = p as i32;
            for l in 0..d.n_layers {
                let off = (l * b + i) * seq_floats;
                s.cache.fill_dense(
                    &self.pool,
                    l,
                    false,
                    &mut self.kbatch[off..off + seq_floats],
                )?;
                s.cache.fill_dense(
                    &self.pool,
                    l,
                    true,
                    &mut self.vbatch[off..off + seq_floats],
                )?;
            }
        }

        let t0 = Instant::now();
        let (mut logits, mut kout, mut vout) = rt
            .decode(alloc, &tokens, &pos, &self.kbatch, &self.vbatch)
            .context("decode")?;
        self.metrics.decode_steps += 1;
        self.metrics.step_latency.record(t0.elapsed().as_secs_f64());

        // Guard pass: any member overflowing gets the whole group's step
        // replayed under PASA (cache inputs unchanged — replay is exact).
        let mut replay = false;
        for &i in &members {
            let sig = GuardSignal::from_logits(&logits[i * v..(i + 1) * v]);
            let s = &mut self.active[i];
            if observe_guard(&mut s.guard, &sig, &mut self.metrics) {
                replay = true;
            }
            if sig.nonfinite > 0 {
                self.metrics.overflow_steps += 1;
            }
        }
        if replay {
            // The PJRT group replay is pinned to "pasa": this backend is
            // restricted to the default fa16_32 → pasa chain (see
            // `EngineConfig::start_alloc`), whose rescue stage is exactly
            // "pasa" — a longer chain here would desynchronize guard
            // state from the executed allocation.
            let t1 = Instant::now();
            let (l2, k2, v2) = rt
                .decode("pasa", &tokens, &pos, &self.kbatch, &self.vbatch)
                .context("decode replay under PASA")?;
            logits = l2;
            kout = k2;
            vout = v2;
            self.metrics.decode_steps += 1;
            // Replayed steps are real serving latency: record them too.
            self.metrics.step_latency.record(t1.elapsed().as_secs_f64());
        }

        // Write back the new KV row, sample, advance. The decode module
        // returns only the new rows, shaped (L, B, W).
        for &i in &members {
            let s = &mut self.active[i];
            let p = pos[i] as usize;
            let mut wrote = true;
            if let Err(e) = s.cache.ensure_capacity(&mut self.pool, p + 1) {
                if !is_kv_backpressure(&e) {
                    return Err(e.context("decode cache growth"));
                }
                wrote = false;
            }
            if wrote {
                for l in 0..d.n_layers {
                    let off = (l * b + i) * w;
                    if let Err(e) = s.cache.write_row(
                        &mut self.pool,
                        l,
                        p,
                        &kout[off..off + w],
                        &vout[off..off + w],
                    ) {
                        if !is_kv_backpressure(&e) {
                            return Err(e.context("decode KV write-back"));
                        }
                        wrote = false;
                        break;
                    }
                }
            }
            if !wrote {
                // Pool exhausted mid-flight: backpressure — evict.
                s.phase = Phase::Finished(FinishReason::Evicted);
                continue;
            }
            let row = &logits[i * v..(i + 1) * v];
            // Watchdog: a row still non-finite after the group replay is
            // quarantined — this slot only; co-batched neighbours sample
            // their own rows untouched.
            if row.iter().any(|x| !x.is_finite()) {
                s.phase = Phase::Finished(FinishReason::Faulted);
                self.metrics.robustness.quarantines += 1;
                continue;
            }
            advance_slot(
                s,
                row,
                d.max_seq,
                self.sp.eos,
                &mut self.metrics,
                &mut self.events,
            );
        }
        Ok(())
    }

    fn finish(&mut self, ar: ActiveRequest) {
        let now = Instant::now();
        let reason = match ar.phase {
            Phase::Finished(r) => r,
            _ => FinishReason::MaxTokens,
        };
        // A best-of primary finishing with its fan-out still registered
        // never decoded (quarantine, deadline, terminal eviction):
        // its siblings share that fate. A fired fan-out has already
        // removed the registration, so this is a no-op then.
        self.resolve_orphaned_fanout(ar.req.id, reason);
        // True queue wait: arrival → admission (prefill start). Prefill
        // execution is reported separately — the two used to be conflated
        // (both were arrival → prefill_done).
        let queue_time = (ar.admitted - ar.req.arrival).as_secs_f64();
        let prefill_time = ar
            .prefill_done
            .map(|t| (t - ar.admitted).as_secs_f64())
            .unwrap_or(0.0);
        let ttft = ar
            .first_token
            .map(|t| (t - ar.req.arrival).as_secs_f64())
            .unwrap_or(0.0);
        let total = (now - ar.req.arrival).as_secs_f64();
        self.metrics.ttft.record(ttft);
        self.metrics.total_latency.record(total);
        self.metrics.requests_completed += 1;
        self.events.push(StreamEvent::Finished {
            request_id: ar.req.id,
            reason,
        });
        let gen_ids: Vec<u32> = ar.tokens[ar.prompt_len..].to_vec();
        self.completions.push(Completion {
            id: ar.req.id,
            prompt: ar.req.prompt.clone(),
            text: tokenizer::decode(&gen_ids, self.sp),
            tokens: gen_ids,
            reason,
            prompt_tokens: ar.prompt_len,
            queue_time,
            prefill_time,
            first_token_latency: ttft,
            total_latency: total,
            allocation: ar.guard.allocation().to_string(),
            guard_switches: ar.guard.switches,
        });
    }
}
