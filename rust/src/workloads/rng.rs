//! Deterministic RNG substrate (S3) — no `rand` crate offline.
//!
//! PCG64 (PCG-XSL-RR 128/64) with Box–Muller normals and a Bernoulli
//! sampler; these replace the paper's `torch.rand`, `torch.norm` and
//! `numpy.random.binomial` generators (Eqs. 17–18). Deterministic seeding
//! makes every experiment in EXPERIMENTS.md exactly re-runnable.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with a stream id; distinct `(seed, stream)` pairs give
    /// independent sequences.
    pub fn new(seed: u64, stream: u64) -> Pcg64 {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut r = Pcg64 {
            state: 0,
            inc,
        };
        r.state = r.state.wrapping_mul(PCG_MULT).wrapping_add(r.inc);
        r.state = r.state.wrapping_add(seed as u128);
        r.state = r.state.wrapping_mul(PCG_MULT).wrapping_add(r.inc);
        r
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi) — the paper's U(x0 − Am, x0 + Am) of Eq. (17).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value per call; the pair's
    /// second member is discarded for simplicity — throughput is not the
    /// bottleneck of the numeric studies).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        // Avoid ln(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Bernoulli(p) — the outlier gate of Eq. (18).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_separated() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        let mut c = Pcg64::new(42, 1);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let cv: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(av, bv);
        assert_ne!(av, cv);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Pcg64::new(7, 0);
        let n = 20000;
        let mut s = 0.0;
        for _ in 0..n {
            let x = r.uniform(19.5, 20.5); // x0=20, Am=0.5 like Fig 9(a)
            assert!((19.5..20.5).contains(&x));
            s += x;
        }
        let mean = s / n as f64;
        assert!((mean - 20.0).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11, 3);
        let n = 40000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal(5.0, 2.0);
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 5.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Pcg64::new(3, 9);
        let n = 200000;
        let hits = (0..n).filter(|_| r.bernoulli(0.001)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.001).abs() < 0.0005, "rate {rate}");
    }
}
