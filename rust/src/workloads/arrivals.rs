//! Arrival-process traces for the serving benchmarks (S11): seeded,
//! deterministic request streams with Poisson or bursty inter-arrival
//! structure, in *engine-step* time.
//!
//! The serving engine's scheduler is a pure function of (queue, slot,
//! budget) state, so a trace — (arrival step, prompt tokens, max_new)
//! triples — fully determines every admission decision of a run. The
//! benches replay the same trace against different scheduler configs
//! (FIFO-compat vs continuous batching, chunk budgets) and compare
//! TTFT/ITL/throughput on identical offered load; the scheduler
//! integration tests replay a trace twice (batched vs one-request-solo)
//! and demand bit-identical token streams.
//!
//! Time is measured in scheduler iterations ("steps"), not wall-clock:
//! the driver submits every request whose `step` has come due before
//! calling `Engine::step`. This keeps the workload independent of host
//! speed — a trace means the same thing on every machine.

use super::rng::Pcg64;

/// One request of an arrival trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Arrival {
    /// Engine step at which the request arrives (non-decreasing).
    pub step: usize,
    /// Prompt length in tokens (BOS included).
    pub prompt_tokens: usize,
    /// Generation budget.
    pub max_new: usize,
}

/// Bounds for the per-request shape draws.
#[derive(Clone, Copy, Debug)]
pub struct ArrivalShape {
    pub min_prompt_tokens: usize,
    pub max_prompt_tokens: usize,
    pub min_new: usize,
    pub max_new: usize,
}

impl Default for ArrivalShape {
    fn default() -> Self {
        ArrivalShape {
            min_prompt_tokens: 4,
            max_prompt_tokens: 64,
            min_new: 4,
            max_new: 24,
        }
    }
}

fn draw_shape(rng: &mut Pcg64, shape: &ArrivalShape) -> (usize, usize) {
    let p = shape.min_prompt_tokens
        + rng.below(shape.max_prompt_tokens - shape.min_prompt_tokens + 1);
    let n = shape.min_new + rng.below(shape.max_new - shape.min_new + 1);
    (p, n)
}

/// Poisson arrivals: exponential inter-arrival gaps with mean
/// `1 / rate_per_step`, quantized to whole steps. `rate_per_step` is the
/// offered load in requests per engine step.
pub fn poisson_trace(n: usize, rate_per_step: f64, shape: ArrivalShape, seed: u64) -> Vec<Arrival> {
    assert!(rate_per_step > 0.0, "rate must be positive");
    let mut rng = Pcg64::new(seed, 0xA112);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            // Inverse-CDF exponential draw; (1 - u) keeps ln() finite.
            let u = rng.next_f64();
            t += -(1.0 - u).ln() / rate_per_step;
            let (prompt_tokens, max_new) = draw_shape(&mut rng, &shape);
            Arrival {
                step: t as usize,
                prompt_tokens,
                max_new,
            }
        })
        .collect()
}

/// Bursty arrivals: requests come in bursts of `burst` back-to-back
/// (same step), with `gap` quiet steps between bursts — the adversarial
/// shape for admission control (deep instantaneous queue, idle valleys).
pub fn bursty_trace(
    n: usize,
    burst: usize,
    gap: usize,
    shape: ArrivalShape,
    seed: u64,
) -> Vec<Arrival> {
    assert!(burst > 0, "burst size must be positive");
    let mut rng = Pcg64::new(seed, 0xB567);
    (0..n)
        .map(|i| {
            let (prompt_tokens, max_new) = draw_shape(&mut rng, &shape);
            Arrival {
                step: (i / burst) * (gap + 1),
                prompt_tokens,
                max_new,
            }
        })
        .collect()
}

/// A prompt whose byte-level tokenization is exactly `tokens` long
/// (BOS + bytes): the bridge from a trace's token count to a concrete
/// `Request` prompt string.
pub fn prompt_of_tokens(tokens: usize) -> String {
    assert!(tokens >= 1, "a prompt is at least the BOS token");
    "x".repeat(tokens - 1)
}

/// A prompt of exactly `total_tokens` whose first `prefix_tokens` tokens
/// are identical for every `idx` and whose suffix is distinct per `idx`:
/// the workload shape the radix prefix cache is built for (a fleet
/// sharing one system prompt, each request with its own tail).
///
/// BOS counts as token 0 of the shared prefix, so the shared byte span is
/// `prefix_tokens - 1` bytes of a fixed pattern. The suffix encodes `idx`
/// in base-26 letters (little-endian, `'a'`-filled), so any two requests
/// with `idx < 26^suffix_len` get different suffixes while staying
/// byte-level-tokenizer clean.
pub fn shared_prefix_prompt(prefix_tokens: usize, total_tokens: usize, idx: usize) -> String {
    assert!(prefix_tokens >= 1, "the shared prefix includes at least BOS");
    assert!(
        total_tokens > prefix_tokens,
        "a request needs at least one token beyond the shared prefix"
    );
    let mut s = String::with_capacity(total_tokens - 1);
    // Shared span: a fixed uppercase cycle, identical across the fleet.
    for j in 0..prefix_tokens - 1 {
        s.push((b'A' + (j % 23) as u8) as char);
    }
    // Distinct tail: idx in base-26, little-endian, 'a'-filled.
    let mut v = idx;
    for _ in 0..total_tokens - prefix_tokens {
        s.push((b'a' + (v % 26) as u8) as char);
        v /= 26;
    }
    s
}

/// Poisson arrivals shaped for prefix-cache studies: every prompt is at
/// least `prefix_tokens + 1` long, so each request carries the full
/// shared prefix plus a distinct tail (pair with
/// [`shared_prefix_prompt`] at submission time, indexed by trace
/// position). Same inter-arrival structure and determinism contract as
/// [`poisson_trace`], under its own stream salt.
pub fn shared_prefix_trace(
    n: usize,
    rate_per_step: f64,
    prefix_tokens: usize,
    shape: ArrivalShape,
    seed: u64,
) -> Vec<Arrival> {
    assert!(rate_per_step > 0.0, "rate must be positive");
    let mut rng = Pcg64::new(seed, 0x5A8E);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            let u = rng.next_f64();
            t += -(1.0 - u).ln() / rate_per_step;
            let (p, max_new) = draw_shape(&mut rng, &shape);
            Arrival {
                step: t as usize,
                prompt_tokens: p.max(prefix_tokens + 1),
                max_new,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tokenizer;

    #[test]
    fn poisson_trace_is_deterministic_and_ordered() {
        let a = poisson_trace(64, 0.5, ArrivalShape::default(), 7);
        let b = poisson_trace(64, 0.5, ArrivalShape::default(), 7);
        assert_eq!(a, b, "same seed must replay the same trace");
        assert!(a.windows(2).all(|w| w[0].step <= w[1].step));
        let c = poisson_trace(64, 0.5, ArrivalShape::default(), 8);
        assert_ne!(a, c, "different seed must differ");
        for r in &a {
            assert!(r.prompt_tokens >= 4 && r.prompt_tokens <= 64);
            assert!(r.max_new >= 4 && r.max_new <= 24);
        }
    }

    #[test]
    fn bursty_trace_has_bursts_and_gaps() {
        let t = bursty_trace(12, 4, 3, ArrivalShape::default(), 1);
        // Bursts of 4 at steps 0, 4, 8.
        assert!(t[..4].iter().all(|r| r.step == 0));
        assert!(t[4..8].iter().all(|r| r.step == 4));
        assert!(t[8..].iter().all(|r| r.step == 8));
    }

    #[test]
    fn prompt_of_tokens_round_trips_through_the_tokenizer() {
        for n in [1usize, 2, 17, 81] {
            assert_eq!(tokenizer::token_len(&prompt_of_tokens(n)), n);
        }
    }

    #[test]
    fn shared_prefix_prompts_share_bytes_and_differ_in_tail() {
        let pre = 17usize;
        let total = 40usize;
        let a = shared_prefix_prompt(pre, total, 0);
        let b = shared_prefix_prompt(pre, total, 7);
        let c = shared_prefix_prompt(pre, total, 7 + 26 * 26 * 26);
        assert_eq!(tokenizer::token_len(&a), total);
        assert_eq!(a.as_bytes()[..pre - 1], b.as_bytes()[..pre - 1]);
        assert_eq!(a.as_bytes()[..pre - 1], c.as_bytes()[..pre - 1]);
        assert_ne!(a, b, "distinct idx must yield a distinct tail");
        assert_ne!(b, c, "base-26 digits must not alias within the tail");
        // Same idx replays the same prompt.
        assert_eq!(b, shared_prefix_prompt(pre, total, 7));
    }

    #[test]
    fn shared_prefix_trace_keeps_prompts_beyond_the_prefix() {
        let pre = 32usize;
        let t = shared_prefix_trace(48, 0.5, pre, ArrivalShape::default(), 11);
        assert_eq!(
            t,
            shared_prefix_trace(48, 0.5, pre, ArrivalShape::default(), 11),
            "same seed must replay the same trace"
        );
        assert!(t.windows(2).all(|w| w[0].step <= w[1].step));
        assert!(t.iter().all(|r| r.prompt_tokens > pre));
        assert_ne!(
            t,
            shared_prefix_trace(48, 0.5, pre, ArrivalShape::default(), 12)
        );
    }

    #[test]
    fn mean_poisson_rate_is_roughly_honored() {
        let t = poisson_trace(400, 0.25, ArrivalShape::default(), 42);
        let last = t.last().unwrap().step as f64;
        // 400 requests at 0.25 req/step ≈ 1600 steps; allow wide slack.
        assert!((800.0..3200.0).contains(&last), "span {last}");
    }
}
