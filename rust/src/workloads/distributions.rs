//! Random benchmark generators (S6) — the paper's Eq. (17) and Eq. (18).
//!
//! * Uniform:  Q,K,V ~ U(x0 − Am, x0 + Am)
//! * Hybrid:   Q,K,V ~ N(x0, 1) + N(0, Am²)·Bernoulli(p),  p = 0.001
//!
//! The benchmark shape is the paper's (B, N, S, D) = (1, 16, 1280, 128);
//! head count is a parameter so the (slow, bit-exact) low-precision sweeps
//! can run on a subset while keeping the distribution identical.

use super::rng::Pcg64;
use crate::tensor::Matrix;

/// One attention problem instance (single batch, single head).
#[derive(Clone, Debug)]
pub struct AttentionCase {
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
}

impl AttentionCase {
    pub fn seq_q(&self) -> usize {
        self.q.rows
    }
    pub fn seq_kv(&self) -> usize {
        self.k.rows
    }
    pub fn head_dim(&self) -> usize {
        self.q.cols
    }
}

/// A multi-head benchmark case: `heads[h]` is an independent head.
#[derive(Clone, Debug)]
pub struct MultiHeadCase {
    pub heads: Vec<AttentionCase>,
    pub label: String,
}

/// The two random families of Table 2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Distribution {
    /// U(x0 − Am, x0 + Am) — Eq. (17).
    Uniform { x0: f64, am: f64 },
    /// N(x0, 1) + N(0, Am²)·Bernoulli(p) — Eq. (18).
    Hybrid { x0: f64, am: f64, p: f64 },
}

impl Distribution {
    pub fn label(&self) -> String {
        match self {
            Distribution::Uniform { x0, am } => format!("uniform(x0={x0},Am={am})"),
            Distribution::Hybrid { x0, am, p } => format!("hybrid(x0={x0},Am={am},p={p})"),
        }
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match *self {
            Distribution::Uniform { x0, am } => rng.uniform(x0 - am, x0 + am),
            Distribution::Hybrid { x0, am, p } => {
                let base = rng.normal(x0, 1.0);
                if rng.bernoulli(p) {
                    base + rng.normal(0.0, am)
                } else {
                    base
                }
            }
        }
    }

    /// Fill a matrix with iid samples.
    pub fn matrix(&self, rows: usize, cols: usize, rng: &mut Pcg64) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for x in &mut m.data {
            *x = self.sample(rng) as f32;
        }
        m
    }
}

/// Generate one head's Q, K, V from a distribution.
pub fn gen_case(dist: Distribution, s1: usize, s2: usize, d: usize, rng: &mut Pcg64) -> AttentionCase {
    AttentionCase {
        q: dist.matrix(s1, d, rng),
        k: dist.matrix(s2, d, rng),
        v: dist.matrix(s2, d, rng),
    }
}

/// Generate the paper's benchmark tensor: `n_heads` independent heads of
/// shape (s, d). Paper default: n_heads = 16, s = 1280, d = 128.
pub fn gen_multihead(
    dist: Distribution,
    n_heads: usize,
    s: usize,
    d: usize,
    seed: u64,
) -> MultiHeadCase {
    let mut heads = Vec::with_capacity(n_heads);
    for h in 0..n_heads {
        let mut rng = Pcg64::new(seed, h as u64);
        heads.push(gen_case(dist, s, s, d, &mut rng));
    }
    MultiHeadCase {
        heads,
        label: dist.label(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::finite_mean;

    #[test]
    fn uniform_case_statistics() {
        let dist = Distribution::Uniform { x0: 20.0, am: 0.5 };
        let mut rng = Pcg64::new(1, 0);
        let c = gen_case(dist, 128, 128, 64, &mut rng);
        let mean = finite_mean(&c.q.data);
        assert!((mean - 20.0).abs() < 0.05, "mean {mean}");
        assert!(c.q.data.iter().all(|&x| (19.5..20.5).contains(&(x as f64))));
    }

    #[test]
    fn hybrid_outliers_present() {
        let dist = Distribution::Hybrid {
            x0: 0.0,
            am: 100.0,
            p: 0.01,
        };
        let mut rng = Pcg64::new(2, 0);
        let c = gen_case(dist, 256, 256, 64, &mut rng);
        let extreme = c.q.data.iter().filter(|&&x| x.abs() > 10.0).count();
        assert!(extreme > 0, "expected outliers from the Bernoulli branch");
        // but they must be rare
        assert!((extreme as f64) < 0.05 * c.q.data.len() as f64);
    }

    #[test]
    fn multihead_heads_are_independent() {
        let dist = Distribution::Uniform { x0: 0.0, am: 1.0 };
        let mh = gen_multihead(dist, 3, 32, 16, 9);
        assert_eq!(mh.heads.len(), 3);
        assert_ne!(mh.heads[0].q.data, mh.heads[1].q.data);
        // deterministic across calls
        let mh2 = gen_multihead(dist, 3, 32, 16, 9);
        assert_eq!(mh.heads[2].q.data, mh2.heads[2].q.data);
    }
}
