//! Random benchmark generators (S6) — the paper's Eq. (17) and Eq. (18).
//!
//! * Uniform:  Q,K,V ~ U(x0 − Am, x0 + Am)
//! * Hybrid:   Q,K,V ~ N(x0, 1) + N(0, Am²)·Bernoulli(p),  p = 0.001
//!
//! The benchmark shape is the paper's (B, N, S, D) = (1, 16, 1280, 128);
//! head count is a parameter so the (slow, bit-exact) low-precision sweeps
//! can run on a subset while keeping the distribution identical.
//! [`MultiHeadCase`] carries separate query and KV head lists (GQA/MQA)
//! and optional per-head valid KV lengths, so masked and grouped variants
//! of the paper's workloads are first-class generator outputs.

use super::rng::Pcg64;
use crate::tensor::Matrix;

/// One attention problem instance (single batch, single head).
#[derive(Clone, Debug)]
pub struct AttentionCase {
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
}

impl AttentionCase {
    pub fn seq_q(&self) -> usize {
        self.q.rows
    }
    pub fn seq_kv(&self) -> usize {
        self.k.rows
    }
    pub fn head_dim(&self) -> usize {
        self.q.cols
    }
}

/// Fill value for padded KV rows in mask-aware generation: large enough
/// that an unmasked kernel reading the padding overflows FP16 instantly,
/// so a passing masked run proves the mask actually excludes it.
pub const PAD_GARBAGE: f32 = 3.0e4;

/// The contiguous GQA/MQA head-group mapping: query head `h` of
/// `n_heads` is served by KV head `h / (n_heads / n_kv_heads)`. The
/// single source of truth — both `MultiHeadCase` and the attention
/// layer's `AttentionRequest` route through here.
pub fn gqa_kv_head(h: usize, n_heads: usize, n_kv_heads: usize) -> usize {
    h / (n_heads / n_kv_heads.max(1)).max(1)
}

/// A multi-head benchmark case: `q[h]` are the query heads, `k`/`v` the
/// KV heads (`q.len()` a multiple of `k.len()` — GQA grouping), and
/// `kv_lens` optional per-query-head valid KV lengths (empty ⇒ dense).
#[derive(Clone, Debug)]
pub struct MultiHeadCase {
    pub q: Vec<Matrix>,
    pub k: Vec<Matrix>,
    pub v: Vec<Matrix>,
    pub kv_lens: Vec<usize>,
    pub label: String,
}

impl MultiHeadCase {
    pub fn n_heads(&self) -> usize {
        self.q.len()
    }

    pub fn n_kv_heads(&self) -> usize {
        self.k.len()
    }

    /// KV head serving query head `h` (contiguous grouping).
    pub fn kv_head_for(&self, h: usize) -> usize {
        gqa_kv_head(h, self.n_heads(), self.n_kv_heads())
    }

    /// Materialize query head `h` with its mapped KV head.
    pub fn head_case(&self, h: usize) -> AttentionCase {
        let kv = self.kv_head_for(h);
        AttentionCase {
            q: self.q[h].clone(),
            k: self.k[kv].clone(),
            v: self.v[kv].clone(),
        }
    }

    /// Pack the per-KV-head K (and V) matrices into token-major rows:
    /// `(s2 × n_kv_heads·d)` with KV head `j`'s columns at
    /// `[j·d, (j+1)·d)`. This is exactly the paged pool's row layout
    /// (`row_width = n_kv_heads·d`), so a paged-attention fixture is
    /// "write each packed row at its position, then view per head with a
    /// column window".
    pub fn packed_kv_rows(&self) -> (Matrix, Matrix) {
        let n_kv = self.n_kv_heads();
        let s2 = self.k[0].rows;
        let d = self.k[0].cols;
        let mut kp = Matrix::zeros(s2, n_kv * d);
        let mut vp = Matrix::zeros(s2, n_kv * d);
        for j in 0..n_kv {
            for r in 0..s2 {
                kp.row_mut(r)[j * d..(j + 1) * d].copy_from_slice(self.k[j].row(r));
                vp.row_mut(r)[j * d..(j + 1) * d].copy_from_slice(self.v[j].row(r));
            }
        }
        (kp, vp)
    }
}

/// The two random families of Table 2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Distribution {
    /// U(x0 − Am, x0 + Am) — Eq. (17).
    Uniform { x0: f64, am: f64 },
    /// N(x0, 1) + N(0, Am²)·Bernoulli(p) — Eq. (18).
    Hybrid { x0: f64, am: f64, p: f64 },
}

impl Distribution {
    pub fn label(&self) -> String {
        match self {
            Distribution::Uniform { x0, am } => format!("uniform(x0={x0},Am={am})"),
            Distribution::Hybrid { x0, am, p } => format!("hybrid(x0={x0},Am={am},p={p})"),
        }
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match *self {
            Distribution::Uniform { x0, am } => rng.uniform(x0 - am, x0 + am),
            Distribution::Hybrid { x0, am, p } => {
                let base = rng.normal(x0, 1.0);
                if rng.bernoulli(p) {
                    base + rng.normal(0.0, am)
                } else {
                    base
                }
            }
        }
    }

    /// Fill a matrix with iid samples.
    pub fn matrix(&self, rows: usize, cols: usize, rng: &mut Pcg64) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for x in &mut m.data {
            *x = self.sample(rng) as f32;
        }
        m
    }
}

/// Generate one head's Q, K, V from a distribution.
pub fn gen_case(
    dist: Distribution,
    s1: usize,
    s2: usize,
    d: usize,
    rng: &mut Pcg64,
) -> AttentionCase {
    AttentionCase {
        q: dist.matrix(s1, d, rng),
        k: dist.matrix(s2, d, rng),
        v: dist.matrix(s2, d, rng),
    }
}

/// Generate the paper's benchmark tensor: `n_heads` independent MHA heads
/// of shape (s, d). Paper default: n_heads = 16, s = 1280, d = 128.
/// Head `h` draws Q, K, V sequentially from stream `h` — byte-compatible
/// with the original single-head generator, so seeded experiment data is
/// stable across the API generations.
pub fn gen_multihead(
    dist: Distribution,
    n_heads: usize,
    s: usize,
    d: usize,
    seed: u64,
) -> MultiHeadCase {
    let mut q = Vec::with_capacity(n_heads);
    let mut k = Vec::with_capacity(n_heads);
    let mut v = Vec::with_capacity(n_heads);
    for h in 0..n_heads {
        let mut rng = Pcg64::new(seed, h as u64);
        let c = gen_case(dist, s, s, d, &mut rng);
        q.push(c.q);
        k.push(c.k);
        v.push(c.v);
    }
    MultiHeadCase {
        q,
        k,
        v,
        kv_lens: Vec::new(),
        label: dist.label(),
    }
}

/// GQA/MQA variant of the benchmark tensor: `n_heads` query heads over
/// `n_kv_heads` KV heads (each KV head drawn on its own deterministic
/// stream, so a query head and its mapped KV head reproduce bit-exactly
/// as a standalone single-head case).
pub fn gen_gqa_multihead(
    dist: Distribution,
    n_heads: usize,
    n_kv_heads: usize,
    s1: usize,
    s2: usize,
    d: usize,
    seed: u64,
) -> MultiHeadCase {
    assert!(n_kv_heads >= 1 && n_heads % n_kv_heads == 0, "bad GQA head counts");
    let mut q = Vec::with_capacity(n_heads);
    for h in 0..n_heads {
        let mut rng = Pcg64::new(seed, h as u64);
        q.push(dist.matrix(s1, d, &mut rng));
    }
    let mut k = Vec::with_capacity(n_kv_heads);
    let mut v = Vec::with_capacity(n_kv_heads);
    for kvh in 0..n_kv_heads {
        // Distinct stream family from the query heads.
        let mut rng = Pcg64::new(seed, 0x4b56 + kvh as u64);
        k.push(dist.matrix(s2, d, &mut rng));
        v.push(dist.matrix(s2, d, &mut rng));
    }
    let label = format!("{} heads={n_heads}/kv={n_kv_heads}", dist.label());
    MultiHeadCase {
        q,
        k,
        v,
        kv_lens: Vec::new(),
        label,
    }
}

/// Mask-aware generation: a right-padded batch of `n_heads` MHA heads.
/// Head `h` has `lens[h % lens.len()]` valid KV rows; the padding region
/// is filled with [`PAD_GARBAGE`] so an unmasked run is guaranteed to
/// overflow — a passing `AttnMask::Padded` run proves mask correctness.
pub fn gen_padded_multihead(
    dist: Distribution,
    n_heads: usize,
    s: usize,
    d: usize,
    lens: &[usize],
    seed: u64,
) -> MultiHeadCase {
    assert!(!lens.is_empty(), "need at least one valid length");
    let mut mh = gen_multihead(dist, n_heads, s, d, seed);
    let mut kv_lens = Vec::with_capacity(n_heads);
    for h in 0..n_heads {
        let len = lens[h % lens.len()].min(s);
        kv_lens.push(len);
        for m in [&mut mh.k[h], &mut mh.v[h]] {
            for r in len..s {
                m.row_mut(r).fill(PAD_GARBAGE);
            }
        }
    }
    mh.kv_lens = kv_lens;
    mh.label = format!("{} padded", mh.label);
    mh
}

/// Paged-decode benchmark case: the serving hot-path shape — `n_heads`
/// single-row query heads (`s1 = 1`, the token being decoded) over
/// `n_kv_heads` KV heads of `len` valid rows grown to `max_seq` capacity,
/// with the region past `len` filled with [`PAD_GARBAGE`]. `kv_lens` is
/// the broadcast valid length, so the dense reference must prefix-mask —
/// and a paged view whose `len_tokens = len` must bit-match it while the
/// garbage tail proves the view never reads past the valid prefix.
pub fn gen_paged_decode_case(
    dist: Distribution,
    n_heads: usize,
    n_kv_heads: usize,
    len: usize,
    max_seq: usize,
    d: usize,
    seed: u64,
) -> MultiHeadCase {
    assert!(len >= 1 && len <= max_seq, "bad paged-decode lengths");
    let mut mh = gen_gqa_multihead(dist, n_heads, n_kv_heads, 1, max_seq, d, seed);
    for j in 0..n_kv_heads {
        for m in [&mut mh.k[j], &mut mh.v[j]] {
            for r in len..max_seq {
                m.row_mut(r).fill(PAD_GARBAGE);
            }
        }
    }
    mh.kv_lens = vec![len];
    mh.label = format!("{} paged-decode len={len}/{max_seq}", mh.label);
    mh
}

/// Random valid lengths for a padded batch, in `[min_len, s]`.
pub fn gen_padded_lens(n_heads: usize, s: usize, min_len: usize, rng: &mut Pcg64) -> Vec<usize> {
    (0..n_heads)
        .map(|_| min_len + rng.below(s.saturating_sub(min_len) + 1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::finite_mean;

    #[test]
    fn uniform_case_statistics() {
        let dist = Distribution::Uniform { x0: 20.0, am: 0.5 };
        let mut rng = Pcg64::new(1, 0);
        let c = gen_case(dist, 128, 128, 64, &mut rng);
        let mean = finite_mean(&c.q.data);
        assert!((mean - 20.0).abs() < 0.05, "mean {mean}");
        assert!(c.q.data.iter().all(|&x| (19.5..20.5).contains(&(x as f64))));
    }

    #[test]
    fn hybrid_outliers_present() {
        let dist = Distribution::Hybrid {
            x0: 0.0,
            am: 100.0,
            p: 0.01,
        };
        let mut rng = Pcg64::new(2, 0);
        let c = gen_case(dist, 256, 256, 64, &mut rng);
        let extreme = c.q.data.iter().filter(|&&x| x.abs() > 10.0).count();
        assert!(extreme > 0, "expected outliers from the Bernoulli branch");
        // but they must be rare
        assert!((extreme as f64) < 0.05 * c.q.data.len() as f64);
    }

    #[test]
    fn multihead_heads_are_independent() {
        let dist = Distribution::Uniform { x0: 0.0, am: 1.0 };
        let mh = gen_multihead(dist, 3, 32, 16, 9);
        assert_eq!(mh.n_heads(), 3);
        assert_eq!(mh.n_kv_heads(), 3);
        assert_ne!(mh.q[0].data, mh.q[1].data);
        assert_ne!(mh.k[0].data, mh.k[1].data);
        // deterministic across calls
        let mh2 = gen_multihead(dist, 3, 32, 16, 9);
        assert_eq!(mh.q[2].data, mh2.q[2].data);
        assert_eq!(mh.v[2].data, mh2.v[2].data);
    }

    #[test]
    fn gqa_generation_maps_groups() {
        let dist = Distribution::Uniform { x0: 0.0, am: 1.0 };
        let mh = gen_gqa_multihead(dist, 8, 2, 32, 48, 16, 5);
        assert_eq!(mh.n_heads(), 8);
        assert_eq!(mh.n_kv_heads(), 2);
        assert_eq!(mh.q[0].shape(), (32, 16));
        assert_eq!(mh.k[0].shape(), (48, 16));
        // Heads 0..3 share KV head 0, heads 4..7 share KV head 1.
        assert_eq!(mh.kv_head_for(3), 0);
        assert_eq!(mh.kv_head_for(4), 1);
        let c = mh.head_case(5);
        assert_eq!(c.q.data, mh.q[5].data);
        assert_eq!(c.k.data, mh.k[1].data);
    }

    #[test]
    fn padded_generation_fills_garbage_and_records_lens() {
        let dist = Distribution::Uniform { x0: 0.0, am: 1.0 };
        let mh = gen_padded_multihead(dist, 3, 16, 8, &[4, 16], 7);
        assert_eq!(mh.kv_lens, vec![4, 16, 4]);
        // Valid region is benign, padding is garbage.
        assert!(mh.k[0].at(3, 0).abs() < 2.0);
        assert_eq!(mh.k[0].at(4, 0), PAD_GARBAGE);
        assert_eq!(mh.v[0].at(15, 7), PAD_GARBAGE);
        // Head 1 is fully valid: no padding rows at all.
        assert!(mh.k[1].data.iter().all(|&x| x.abs() < 2.0));
    }

    #[test]
    fn paged_decode_case_shape_and_garbage_tail() {
        let dist = Distribution::Uniform { x0: 0.5, am: 1.0 };
        let mh = gen_paged_decode_case(dist, 4, 2, 10, 32, 8, 3);
        assert_eq!(mh.n_heads(), 4);
        assert_eq!(mh.n_kv_heads(), 2);
        assert_eq!(mh.q[0].shape(), (1, 8));
        assert_eq!(mh.k[0].shape(), (32, 8));
        assert_eq!(mh.kv_lens, vec![10]);
        assert!(mh.k[0].at(9, 0).abs() < 2.0, "valid region is benign");
        assert_eq!(mh.k[0].at(10, 0), PAD_GARBAGE);
        assert_eq!(mh.v[1].at(31, 7), PAD_GARBAGE);
    }

    #[test]
    fn packed_rows_interleave_kv_heads() {
        let dist = Distribution::Uniform { x0: 0.0, am: 1.0 };
        let mh = gen_gqa_multihead(dist, 4, 2, 1, 6, 3, 5);
        let (kp, vp) = mh.packed_kv_rows();
        assert_eq!(kp.shape(), (6, 6));
        for r in 0..6 {
            assert_eq!(&kp.row(r)[0..3], mh.k[0].row(r));
            assert_eq!(&kp.row(r)[3..6], mh.k[1].row(r));
            assert_eq!(&vp.row(r)[3..6], mh.v[1].row(r));
        }
    }

    #[test]
    fn padded_lens_stay_in_range() {
        let mut rng = Pcg64::new(11, 0);
        let lens = gen_padded_lens(32, 100, 10, &mut rng);
        assert_eq!(lens.len(), 32);
        assert!(lens.iter().all(|&l| (10..=100).contains(&l)));
        assert!(lens.iter().any(|&l| l < 100), "expected some padding");
    }
}
