//! Workload generators (S3, S6): deterministic RNG, the paper's random
//! benchmark distributions (Eqs. 17–18), the resonance mechanism (Fig. 6)
//! and model-shaped overflow traces (Qwen2 / SVD substitutes).

pub mod arrivals;
pub mod distributions;
pub mod resonance;
pub mod rng;
pub mod traces;

pub use arrivals::{
    bursty_trace, poisson_trace, prompt_of_tokens, shared_prefix_prompt, shared_prefix_trace,
    Arrival, ArrivalShape,
};
pub use distributions::{
    gen_case, gen_gqa_multihead, gen_multihead, gen_padded_lens, gen_padded_multihead,
    gen_paged_decode_case, gqa_kv_head, AttentionCase, Distribution, MultiHeadCase, PAD_GARBAGE,
};
pub use resonance::{ResonanceCategory, ResonanceSpec};
pub use rng::Pcg64;
pub use traces::{all_traces, qwen2_overflow_trace, svd_img2vid_trace, TraceSpec};
