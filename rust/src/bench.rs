//! Bench harness (S14) — no criterion offline, so a small timed-run
//! framework with warmup, repetitions and robust statistics. Used by all
//! `benches/*.rs` targets (each with `harness = false`).
//!
//! Every [`Bencher::run`] result is also recorded in a process-global
//! registry; a bench `main` ends with [`emit_json`]`("bench_name")`,
//! which writes the machine-readable `BENCH_<name>.json` next to the
//! stdout table — shape, allocation, ns/iter and items/sec per row — so
//! the perf trajectory of the repo can finally be tracked across PRs
//! (point `PASA_BENCH_JSON_DIR` somewhere to collect them). Use
//! [`Bencher::run_tagged`] when a row has structured shape/allocation
//! metadata; untagged rows carry their name only.
//!
//! CI smoke mode: `PASA_BENCH_SMOKE=1` makes [`smoke`] return true —
//! benches shrink to one tiny shape and [`Bencher::smoke`]-sized
//! iteration counts, so the bench binaries *run* (and emit JSON) on every
//! CI pass instead of merely compiling.

use std::sync::Mutex;
use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub p95_s: f64,
    /// Optional throughput annotation (items per iteration).
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn items_per_sec(&self) -> f64 {
        self.items_per_iter / self.mean_s
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10.4} ms/iter (median {:.4}, min {:.4}, p95 {:.4}; n={})",
            self.name,
            self.mean_s * 1e3,
            self.median_s * 1e3,
            self.min_s * 1e3,
            self.p95_s * 1e3,
            self.iters
        )?;
        if self.items_per_iter > 0.0 {
            write!(f, "  [{:.1} items/s]", self.items_per_sec())?;
        }
        Ok(())
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop repeating once this much wall time is spent.
    pub budget_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            budget_s: 5.0,
        }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 30,
            budget_s: 2.0,
        }
    }

    /// Minimal configuration for the CI smoke pass: prove the bench runs
    /// end to end and emits JSON, without spending CI minutes on it.
    pub fn smoke() -> Bencher {
        Bencher {
            warmup_iters: 1,
            min_iters: 1,
            max_iters: 2,
            budget_s: 0.25,
        }
    }

    /// The bench configuration for the current environment: [`smoke`]
    /// under `PASA_BENCH_SMOKE=1`, otherwise the given default.
    pub fn for_env(default: Bencher) -> Bencher {
        if smoke() {
            Bencher::smoke()
        } else {
            default
        }
    }

    /// Time `f`, preventing dead-code elimination through the returned
    /// value's drop. The result is also recorded (untagged) in the
    /// process-global registry drained by [`emit_json`].
    pub fn run<T>(&self, name: &str, items_per_iter: f64, f: impl FnMut() -> T) -> BenchResult {
        self.run_tagged(name, "", "", items_per_iter, f)
    }

    /// [`Self::run`] with structured shape/allocation tags carried into
    /// the JSON record (the stdout table is unchanged).
    pub fn run_tagged<T>(
        &self,
        name: &str,
        shape: &str,
        alloc: &str,
        items_per_iter: f64,
        mut f: impl FnMut() -> T,
    ) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let result = BenchResult {
            name: name.to_string(),
            iters: n,
            mean_s: samples.iter().sum::<f64>() / n as f64,
            median_s: samples[n / 2],
            min_s: samples[0],
            p95_s: samples[((n as f64 * 0.95) as usize).min(n - 1)],
            items_per_iter,
        };
        record(&result, shape, alloc);
        result
    }
}

/// True when the CI smoke pass is running (`PASA_BENCH_SMOKE=1`): benches
/// shrink to one tiny shape each.
pub fn smoke() -> bool {
    std::env::var("PASA_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Schema version stamped into every `BENCH_*.json` report. Bump when a
/// field is added, renamed or re-scaled, so perf-history tooling can
/// refuse (or migrate) reports it does not understand instead of silently
/// misreading them. Version 1 = the PR 8 shape: top-level `bench` /
/// `smoke` / `schema_version` / `results[]` with the ten per-row fields.
pub const BENCH_SCHEMA_VERSION: u32 = 1;

/// One registry row of the JSON report.
struct JsonRow {
    name: String,
    shape: String,
    alloc: String,
    iters: usize,
    mean_ns: f64,
    median_ns: f64,
    min_ns: f64,
    p95_ns: f64,
    items_per_iter: f64,
    items_per_sec: f64,
}

static REGISTRY: Mutex<Vec<JsonRow>> = Mutex::new(Vec::new());

fn record(r: &BenchResult, shape: &str, alloc: &str) {
    REGISTRY.lock().unwrap().push(JsonRow {
        name: r.name.clone(),
        shape: shape.to_string(),
        alloc: alloc.to_string(),
        iters: r.iters,
        mean_ns: r.mean_s * 1e9,
        median_ns: r.median_s * 1e9,
        min_ns: r.min_s * 1e9,
        p95_ns: r.p95_s * 1e9,
        items_per_iter: r.items_per_iter,
        items_per_sec: r.items_per_sec(),
    });
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Finite numbers only (NaN/inf are not JSON); benches never produce
/// them, but a malformed report must not poison the perf history.
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

/// Drain the result registry into `BENCH_<bench>.json` (in
/// `PASA_BENCH_JSON_DIR`, default `.`). Call once at the end of each
/// bench `main`. Failure to write is a warning, never a bench failure.
pub fn emit_json(bench: &str) {
    let dir = std::env::var("PASA_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    emit_json_to(&dir, bench);
}

/// [`emit_json`] with an explicit output directory — no environment read
/// or mutation, so it is safe to exercise from the (multithreaded) test
/// harness.
pub fn emit_json_to(dir: &str, bench: &str) {
    let rows = std::mem::take(&mut *REGISTRY.lock().unwrap());
    let mut body = String::new();
    body.push_str("{\n");
    body.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(bench)));
    body.push_str(&format!("  \"schema_version\": {BENCH_SCHEMA_VERSION},\n"));
    body.push_str(&format!("  \"smoke\": {},\n", smoke()));
    body.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"name\": \"{}\", \"shape\": \"{}\", \"alloc\": \"{}\", \"iters\": {}, \
             \"mean_ns\": {}, \"median_ns\": {}, \"min_ns\": {}, \"p95_ns\": {}, \
             \"items_per_iter\": {}, \"items_per_sec\": {}}}{}\n",
            json_escape(&r.name),
            json_escape(&r.shape),
            json_escape(&r.alloc),
            r.iters,
            json_num(r.mean_ns),
            json_num(r.median_ns),
            json_num(r.min_ns),
            json_num(r.p95_ns),
            json_num(r.items_per_iter),
            json_num(r.items_per_sec),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    body.push_str("  ]\n}\n");
    let path = format!("{dir}/BENCH_{bench}.json");
    match std::fs::write(&path, body) {
        Ok(()) => println!("\n[bench] wrote {path} ({} results)", rows.len()),
        Err(e) => eprintln!("[bench] could not write {path}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            budget_s: 0.5,
        };
        let r = b.run("spin", 100.0, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.p95_s);
        assert!(r.items_per_sec() > 0.0);
        assert!(format!("{r}").contains("spin"));
    }

    #[test]
    fn json_report_is_written_and_well_formed() {
        let b = Bencher::smoke();
        let dir = std::env::temp_dir().join("pasa_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let _ = b.run_tagged("tiny \"quoted\"", "8x8", "FA(FP32)", 8.0, || 1 + 1);
        // The env-free entry: tests must not setenv in a threaded harness.
        emit_json_to(dir.to_str().unwrap(), "unit_test");
        let path = dir.join("BENCH_unit_test.json");
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"bench\": \"unit_test\""));
        assert!(
            body.contains(&format!("\"schema_version\": {BENCH_SCHEMA_VERSION}")),
            "report must carry the schema version"
        );
        assert!(body.contains("\\\"quoted\\\""));
        assert!(body.contains("\"shape\": \"8x8\""));
        assert!(body.contains("\"alloc\": \"FA(FP32)\""));
        assert!(body.contains("\"mean_ns\""));
        // Balanced braces/brackets — cheap structural sanity without a
        // JSON parser in the dep-free build.
        assert_eq!(body.matches('{').count(), body.matches('}').count());
        assert_eq!(body.matches('[').count(), body.matches(']').count());
        std::fs::remove_file(path).ok();
    }
}
