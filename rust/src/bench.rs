//! Bench harness (S14) — no criterion offline, so a small timed-run
//! framework with warmup, repetitions and robust statistics. Used by all
//! `benches/*.rs` targets (each with `harness = false`).

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub p95_s: f64,
    /// Optional throughput annotation (items per iteration).
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn items_per_sec(&self) -> f64 {
        self.items_per_iter / self.mean_s
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10.4} ms/iter (median {:.4}, min {:.4}, p95 {:.4}; n={})",
            self.name,
            self.mean_s * 1e3,
            self.median_s * 1e3,
            self.min_s * 1e3,
            self.p95_s * 1e3,
            self.iters
        )?;
        if self.items_per_iter > 0.0 {
            write!(f, "  [{:.1} items/s]", self.items_per_sec())?;
        }
        Ok(())
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop repeating once this much wall time is spent.
    pub budget_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            budget_s: 5.0,
        }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 30,
            budget_s: 2.0,
        }
    }

    /// Time `f`, preventing dead-code elimination through the returned
    /// value's drop.
    pub fn run<T>(&self, name: &str, items_per_iter: f64, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        BenchResult {
            name: name.to_string(),
            iters: n,
            mean_s: samples.iter().sum::<f64>() / n as f64,
            median_s: samples[n / 2],
            min_s: samples[0],
            p95_s: samples[((n as f64 * 0.95) as usize).min(n - 1)],
            items_per_iter,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            budget_s: 0.5,
        };
        let r = b.run("spin", 100.0, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.p95_s);
        assert!(r.items_per_sec() > 0.0);
        assert!(format!("{r}").contains("spin"));
    }
}
