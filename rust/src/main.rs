//! `pasa` — leader entrypoint / CLI (S13).
//!
//! Subcommands:
//!   repro      — regenerate a paper table/figure (see DESIGN.md §4)
//!   serve      — run the serving engine on a synthetic request workload
//!   solve-beta — solve the optimal accuracy condition (Eq. 16/22)
//!   info       — print the artifact manifest and model dims
//!   lint       — run the repo-native static-analysis pass (S14)
//!   help

#![allow(clippy::field_reassign_with_default)]

use anyhow::{bail, Result};
use pasa::attention::{beta, Allocation};
use pasa::cli::Args;
use pasa::coordinator::{
    Engine, EngineConfig, FaultKind, FaultPlan, GenParams, GuardPolicy, KvStore, Request,
    SchedulerConfig, StreamEvent,
};
use pasa::experiments::{self, ExpOptions};
use pasa::model::Sampling;
use pasa::numerics::Format;
use pasa::runtime::{LabModel, ModelRuntime};
use std::path::Path;

const HELP: &str = "\
pasa — Online Pseudo-average Shifting Attention (paper reproduction)

USAGE: pasa <subcommand> [flags]

  repro --exp <id|all> [--heads N] [--seq N] [--dim N] [--scale N] [--seed N]
        regenerate a paper table/figure (table1 table3 table4 fig5 fig6
        fig7 fig9a fig9b fig10a fig10b fig11 fig12 fig13 fig14
        guard_rescue)
  serve [--artifacts DIR] [--requests N] [--lab] [--stream]
        [--policy pasa|fa16_32|fa32|adaptive|preemptive]
        [--alloc fa16_32|fp8|pasa8|...] [--kv-store f32|e4m3]
        [--max-new N] [--temperature T]
        [--max-batch-prefill-tokens N] [--max-batch-total-tokens N]
        [--waiting-served-ratio R] [--max-batch-size N] [--fifo]
        [--deadline-steps N] [--retry-budget N] [--shed-queue-depth N]
        [--chaos-seed S] [--prefix-cache] [--prefix-cache-pages N]
        [--best-of N]
        run the continuous-batching serving engine over a synthetic
        prompt workload. --lab uses the artifact-free pure-Rust backend
        (chunked prefill); --stream prints per-token events as they are
        sampled; --fifo disables the token budgets (pre-scheduler
        behaviour, the benchmark comparator). --alloc roots the
        switching policies' fallback chain: fa16_32 -> pasa, or
        fp8 -> pasa8 -> pasa (lab only). --kv-store e4m3 stores KV
        pages as 1-byte FP8 (4x pages at the same byte budget; lab only).
        Lifecycle hardening: --deadline-steps kills requests older than
        N engine steps, --retry-budget re-enqueues evicted requests up
        to N times with exponential step backoff, --shed-queue-depth
        sheds the newest low-priority request above a queue depth
        (0 disables each). --chaos-seed S (lab only, S != 0) installs a
        seeded fault-injection plan; the run prints its injection log
        and replays exactly from the same seed. --prefix-cache (lab
        only) shares page-aligned prompt-prefix KV pages across requests
        through a radix tree (--prefix-cache-pages caps its residency,
        default half the pool; LRU leaves evict under pressure).
        --best-of N (lab only) fans each prompt's single prefill out
        into N decode slots over copy-on-write forks
  solve-beta [--n 128] [--init 0.984375] [--fmt fp16|bf16]
        solve the optimal accuracy condition
  info  [--artifacts DIR]
        print the artifact manifest and model dims
  lint  [--root DIR]
        run the repo-native static-analysis pass (unsafe-audit,
        boundary-literal, wildcard-arm, hot-path-alloc) over rust/src
        and rust/tests; exits nonzero on any violation
";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_str() {
        "repro" => cmd_repro(&args),
        "serve" => cmd_serve(&args),
        "solve-beta" => cmd_solve_beta(&args),
        "info" => cmd_info(&args),
        "lint" => cmd_lint(&args),
        "" | "help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand {other}\n{HELP}"),
    }
}

fn cmd_repro(args: &Args) -> Result<()> {
    let opts = ExpOptions {
        heads: args.get_usize("heads", 4)?,
        seq: args.get_usize("seq", 1280)?,
        dim: args.get_usize("dim", 128)?,
        trace_scale: args.get_usize("scale", 4)?,
        seed: args.get_usize("seed", 42)? as u64,
    };
    let id = args.get_or("exp", "all");
    let report = experiments::run(&id, &opts)?;
    println!("{report}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let n_requests = args.get_usize("requests", 8)?;
    let max_new = args.get_usize("max-new", 24)?;
    let temp = args.get_f64("temperature", 0.0)?;
    let policy_str = args.get_or("policy", "adaptive");
    let policy = GuardPolicy::parse(&policy_str).ok_or_else(|| {
        anyhow::anyhow!(
            "bad --policy {policy_str:?}; valid policies: \
             pasa, fa16_32, fa16, fa32, adaptive, preemptive"
        )
    })?;
    // The starting allocation roots the switching policies' fallback
    // chain (fa16_32 -> pasa by default; fp8 -> pasa8 -> pasa for the
    // 8-bit envelope). An unknown spelling is a hard error listing every
    // valid name — never a silent fallback.
    let alloc_str = args.get_or("alloc", "fa16_32");
    let start_alloc = Allocation::parse(&alloc_str).ok_or_else(|| {
        anyhow::anyhow!(
            "bad --alloc {alloc_str:?}; valid allocations: {}",
            Allocation::valid_names().join(", ")
        )
    })?;
    let lab = args.has("lab");
    // `serve` without --lab runs the PJRT backend, whose AOT manifest
    // ships only the fa16_32 / pasa / fa32 modules — an 8-bit fallback
    // chain (fp8 → pasa8 → pasa) is a lab-engine feature. Fail up front
    // with the constraint instead of erroring on a module lookup
    // mid-prefill (or, worse, letting guard state and executed
    // allocation diverge on the group-replay path).
    if !lab && start_alloc != Allocation::Fa16_32 {
        bail!(
            "--alloc {alloc_str} is not servable on the PJRT backend; the AOT \
             manifest only ships fa16_32/pasa/fa32 modules. Non-default starting \
             allocations (fp8, pasa8, ...) need the lab backend (--lab)."
        );
    }
    // KV page storage format. E4M3 pages are gathered (dequantized) by
    // the lab backend's paged attention; the PJRT dense-cache path is
    // kept on f32 pools for the same keep-it-servable reason as --alloc.
    let kv_store = KvStore::parse(&args.get_or("kv-store", "f32"))?;
    if !lab && kv_store != KvStore::F32 {
        bail!(
            "--kv-store {} needs the lab backend (--lab); the PJRT dense-cache \
             path serves from f32 pools only.",
            kv_store.name()
        );
    }

    // Continuous-batching knobs (see SchedulerConfig): token budgets,
    // the starvation ratio, and the slot cap. --fifo restores the
    // pre-scheduler admit-when-a-slot-is-free behaviour for comparison.
    let mut sched = if args.has("fifo") {
        SchedulerConfig::fifo_compat()
    } else {
        SchedulerConfig::default()
    };
    sched.max_batch_prefill_tokens =
        args.get_usize("max-batch-prefill-tokens", sched.max_batch_prefill_tokens)?;
    sched.max_batch_total_tokens =
        args.get_usize("max-batch-total-tokens", sched.max_batch_total_tokens)?;
    sched.waiting_served_ratio =
        args.get_f64("waiting-served-ratio", sched.waiting_served_ratio)?;
    sched.max_batch_size = args.get_usize("max-batch-size", sched.max_batch_size)?;

    // Lifecycle-hardening knobs (S19): all default to 0 = disabled, so a
    // plain `serve` run behaves exactly as before.
    sched.retry_budget = args.get_usize("retry-budget", sched.retry_budget)?;
    sched.shed_queue_depth = args.get_usize("shed-queue-depth", sched.shed_queue_depth)?;
    let deadline_steps = args.get_usize("deadline-steps", 0)?;
    let chaos_seed = args.get_usize("chaos-seed", 0)? as u64;
    if chaos_seed != 0 && !lab {
        bail!(
            "--chaos-seed needs the lab backend (--lab); the fault seams \
             live in the lab decode path."
        );
    }

    // Prefix sharing and fan-out (S20): both ride the lab backend's paged
    // CoW cache; the PJRT dense-cache path has no pages to share.
    let prefix_cache_pages = args.get_usize("prefix-cache-pages", 0)?;
    let prefix_cache = args.has("prefix-cache") || prefix_cache_pages > 0;
    if prefix_cache && !lab {
        bail!(
            "--prefix-cache needs the lab backend (--lab); prompt-prefix \
             sharing lives in the paged KV pool."
        );
    }
    let best_of = args.get_usize("best-of", 1)?;
    if best_of == 0 {
        bail!("--best-of must be at least 1");
    }
    if best_of > 1 && !lab {
        bail!(
            "--best-of needs the lab backend (--lab); fan-out forks the \
             paged KV cache copy-on-write."
        );
    }

    let mut cfg = EngineConfig::default();
    cfg.policy = policy;
    cfg.start_alloc = start_alloc;
    cfg.kv_store = kv_store;
    cfg.sched = sched;
    cfg.deadline_steps = deadline_steps;
    if prefix_cache {
        cfg.prefix_cache_pages = if prefix_cache_pages > 0 {
            prefix_cache_pages
        } else {
            cfg.kv_pages / 2
        };
    }

    // The engine borrows a PJRT runtime; keep it alive across both arms.
    let rt;
    let mut eng = if lab {
        Engine::from_lab(LabModel::synthetic(lab_serve_dims(), 42), cfg)
    } else {
        rt = ModelRuntime::load(Path::new(&dir))?;
        Engine::new(&rt, cfg)
    };
    if chaos_seed != 0 {
        eng.install_faults(FaultPlan::standard(chaos_seed));
    }

    let prompts = synthetic_prompts(n_requests);
    let sampling = if temp > 0.0 {
        Sampling::Temperature(temp as f32)
    } else {
        Sampling::Greedy
    };
    for p in prompts {
        let id = eng.fresh_id();
        let req = Request::new(id, p).with_params(GenParams {
            max_new_tokens: max_new,
            sampling,
            stop_at_eos: true,
        });
        if best_of > 1 {
            eng.submit_best_of(req, best_of)?;
        } else {
            eng.submit(req);
        }
    }

    let stream = args.has("stream");
    let mut comps = Vec::new();
    while !eng.idle() {
        eng.step()?;
        if stream {
            // Drain and print the per-token stream as it is produced —
            // exhaustive over StreamEvent so a new event kind is a
            // compile error here, not a silently unprinted message.
            for ev in eng.take_events() {
                match ev {
                    StreamEvent::Token(t) => println!(
                        "stream[{:>3}] #{:<3} pos={:<4} token={}",
                        t.request_id, t.index, t.position, t.token
                    ),
                    StreamEvent::Finished { request_id, reason } => {
                        println!("stream[{request_id:>3}] finished: {reason:?}")
                    }
                }
            }
        }
        comps.extend(eng.take_completions());
    }
    for c in &comps {
        println!(
            "[{:>3}] {:?} -> {:?} ({:?}, alloc={}, ttft={:.3}s)",
            c.id, c.prompt, c.text, c.reason, c.allocation, c.first_token_latency
        );
    }
    println!("\n{}", eng.metrics.report());
    if prefix_cache {
        println!(
            "prefix cache pages resident at end: {} (flushing)",
            eng.prefix_pages_held()
        );
        eng.flush_prefix_cache();
    }
    println!("kv pool utilization at end: {:.3}", eng.kv_utilization());
    if let Some(plan) = eng.fault_plan() {
        let counts = plan.counts();
        let per_kind: Vec<String> = FaultKind::ALL
            .iter()
            .zip(counts.iter())
            .filter(|(_, &c)| c > 0)
            .map(|(k, c)| format!("{}={c}", k.name()))
            .collect();
        println!(
            "chaos plan: {} injection(s) [{}] — replay with --chaos-seed {chaos_seed}",
            plan.log().len(),
            per_kind.join(" ")
        );
    }
    Ok(())
}

/// Dims of the synthetic lab model behind `serve --lab`: byte-level
/// vocab, big enough context that chunked prefill is observable, small
/// enough to run instantly on a laptop.
fn lab_serve_dims() -> pasa::model::ModelDims {
    pasa::model::ModelDims {
        vocab_size: 259,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_head: 8,
        d_ff: 64,
        max_seq: 128,
        prefill_seq: 32,
        decode_batch: 4,
        pad: 256,
        bos: 257,
        eos: 258,
    }
}

/// Prompts drawn from the training corpus templates (so a trained model
/// produces meaningful continuations).
pub fn synthetic_prompts(n: usize) -> Vec<String> {
    let words = ["zero", "one", "two", "three", "four", "five", "six", "seven", "eight", "nine"];
    (0..n)
        .map(|i| match i % 3 {
            0 => format!("math: {} plus {} equals", i % 5, (i * 7 + 2) % 5),
            1 => format!("count up: {}", words[i % 6]),
            _ => format!("recall {} maps to", words[(i * 3) % 10]),
        })
        .collect()
}

fn cmd_solve_beta(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 128)?;
    let init = args.get_f64("init", 1.0 - 2f64.powi(-6))?;
    let fmt = match args.get_or("fmt", "fp16").as_str() {
        "fp16" => Format::F16,
        "bf16" => Format::Bf16,
        other => bail!("unknown --fmt {other}"),
    };
    let s = beta::solve_optimal_beta(init, n, fmt, 1e-10, 500);
    println!("optimal beta for n={n}, {}: {:.6}", fmt.name(), s.beta);
    println!(
        "  convergence: {} after {} iterations (residual {:.3e})",
        if s.converged { "yes" } else { "NO" },
        s.iterations,
        s.residual
    );
    println!(
        "  ideal invariant     beta/(1-beta) = {:.6}",
        beta::ideal_invariant(s.beta)
    );
    println!(
        "  practical invariant (Eq. 20)      = {:.6}",
        beta::practical_invariant(s.beta, n, fmt)
    );
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    // Default to the manifest directory so `cargo run -- lint` works from
    // anywhere inside the checkout; `--root` overrides for out-of-tree use.
    let root = args.get_or("root", env!("CARGO_MANIFEST_DIR"));
    let violations = pasa::analysis::lint_tree(Path::new(&root))?;
    if violations.is_empty() {
        println!("pasa lint: clean (0 violations)");
        return Ok(());
    }
    for v in &violations {
        println!("{v}");
    }
    bail!("pasa lint: {} violation(s)", violations.len());
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let m = pasa::model::Manifest::load(Path::new(&dir))?;
    println!("model dims: {:?}", m.dims);
    println!("modules:");
    for e in &m.modules {
        println!(
            "  {:<18} kind={:<8} attention={:<8} {}",
            e.name,
            e.kind,
            e.attention,
            e.path.display()
        );
    }
    println!("parameters: {} tensors", m.params.len());
    Ok(())
}
