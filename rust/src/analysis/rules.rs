//! The four repo-invariant rules of `pasa lint`.
//!
//! Every rule works on the [`Scanned`] views (masked code + comment
//! text), so tokens inside comments and string literals never fire. Rules
//! 2 and 3 additionally skip `#[cfg(test)]` regions: tests deliberately
//! pin raw boundary values and use `_` catch-alls in assertion plumbing.
//!
//! * **Rule 1 — unsafe-audit** (with `super::unsafe_audit`): every
//!   `unsafe` block / `unsafe impl` carries a `SAFETY:` comment, and every
//!   unsafe site of any kind appears in the checked-in audit registry.
//! * **Rule 2 — boundary-literal**: no raw FP overflow boundaries
//!   (`65504`, `448`, `240`) outside `numerics/` — use the
//!   `Format::…::overflow_boundary()` accessors, so a format-table change
//!   cannot silently diverge from a hardcoded copy.
//! * **Rule 3 — wildcard-arm**: no `_` arms in `match`es over the
//!   protected enums (`Allocation`, `AttnMask`, `GuardPolicy`, and the
//!   scheduler's `SchedDecision` / `StreamEvent` — a new defer reason or
//!   stream event kind must be handled at every dispatch site); adding a
//!   variant must break the build everywhere it is matched.
//! * **Rule 4 — hot-path-alloc**: no allocating calls inside
//!   `lint: hot-path` fenced regions of `attention/`, `tensor/`,
//!   `pool.rs` — the zero-allocation contract that
//!   `rust/tests/alloc_discipline.rs` measures dynamically, enforced
//!   statically.

use super::scanner::Scanned;
use super::{Rule, Violation};
use std::fmt;

/// What follows the `unsafe` keyword.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum UnsafeKind {
    /// `unsafe { … }` expression block.
    Block,
    /// `unsafe impl Trait for T`.
    Impl,
    /// `unsafe fn` (incl. `unsafe extern "C" fn`).
    Fn,
    /// `unsafe trait`.
    Trait,
}

impl UnsafeKind {
    pub fn name(self) -> &'static str {
        match self {
            UnsafeKind::Block => "block",
            UnsafeKind::Impl => "impl",
            UnsafeKind::Fn => "fn",
            UnsafeKind::Trait => "trait",
        }
    }
}

impl fmt::Display for UnsafeKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One `unsafe` occurrence, keyed for the audit registry.
#[derive(Clone, Debug)]
pub struct UnsafeSite {
    /// Repo-relative path, `/`-separated.
    pub file: String,
    pub kind: UnsafeKind,
    /// 1-based line of the `unsafe` keyword.
    pub line: usize,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte offsets of word-boundary occurrences of `word` in `text`
/// (`matches!` does not contain the word `match`).
fn word_positions(text: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = text[from..].find(word) {
        let at = from + rel;
        let before_ok = !text[..at].chars().next_back().is_some_and(is_ident);
        let after_ok = !text[at + word.len()..].chars().next().is_some_and(is_ident);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + word.len();
    }
    out
}

// ---------------------------------------------------------------------------
// `#[cfg(test)]` regions
// ---------------------------------------------------------------------------

/// Per-line flag: is this line inside a `#[cfg(test)] mod … { … }` region
/// (attribute line through the module's closing brace)?
pub fn test_regions(sc: &Scanned) -> Vec<bool> {
    let n = sc.masked.len();
    let mut in_test = vec![false; n];
    let mut i = 0;
    while i < n {
        let line = &sc.masked[i];
        let Some(attr_at) = line.find("#[cfg(test)]") else {
            i += 1;
            continue;
        };
        // The attribute must annotate a `mod` item — same line, or the
        // next non-blank, non-attribute line. `#[cfg(test)] use …` and
        // similar single-item gates are not regions.
        let tail = &line[attr_at + "#[cfg(test)]".len()..];
        let mut mod_line = None;
        if declares_mod(tail) {
            mod_line = Some(i);
        } else {
            let mut j = i + 1;
            while j < n {
                let t = sc.masked[j].trim();
                if t.is_empty() || t.starts_with("#[") {
                    j += 1;
                    continue;
                }
                if declares_mod(t) {
                    mod_line = Some(j);
                }
                break;
            }
        }
        let Some(m) = mod_line else {
            i += 1;
            continue;
        };
        // `mod tests;` (out-of-line) covers just its declaration;
        // otherwise brace-match from the mod line to the region's end.
        let mut depth = 0i64;
        let mut opened = false;
        let mut end = None;
        'scan: for (k, l) in sc.masked.iter().enumerate().skip(m) {
            for c in l.chars() {
                if !opened && c == ';' {
                    end = Some(k);
                    break 'scan;
                }
                if c == '{' {
                    depth += 1;
                    opened = true;
                } else if c == '}' {
                    depth -= 1;
                    if opened && depth == 0 {
                        end = Some(k);
                        break 'scan;
                    }
                }
            }
        }
        let end = end.unwrap_or(n - 1);
        for flag in in_test.iter_mut().take(end + 1).skip(i) {
            *flag = true;
        }
        i = end + 1;
    }
    in_test
}

fn declares_mod(masked_text: &str) -> bool {
    !word_positions(masked_text, "mod").is_empty()
}

// ---------------------------------------------------------------------------
// Rule 1 — unsafe sites
// ---------------------------------------------------------------------------

/// Collect every `unsafe` site in the file; push a violation for each
/// `unsafe` block / `unsafe impl` that lacks a `SAFETY:` comment. (`unsafe
/// fn` declares an obligation for *callers* — its contract lives in the
/// doc comment and is discharged with a `SAFETY:` at each call site, which
/// is where this rule checks it.)
pub fn collect_unsafe_sites(rel: &str, sc: &Scanned, out: &mut Vec<Violation>) -> Vec<UnsafeSite> {
    let mut sites = Vec::new();
    for (li, line) in sc.masked.iter().enumerate() {
        for col in word_positions(line, "unsafe") {
            let kind = classify_unsafe(sc, li, col + "unsafe".len());
            if matches!(kind, UnsafeKind::Block | UnsafeKind::Impl) && !safety_documented(sc, li) {
                out.push(Violation::new(
                    Rule::UnsafeAudit,
                    rel,
                    li + 1,
                    format!("`unsafe {kind}` without a `SAFETY:` comment"),
                ));
            }
            sites.push(UnsafeSite {
                file: rel.to_string(),
                kind,
                line: li + 1,
            });
        }
    }
    sites
}

/// Classify by the first meaningful token after the `unsafe` keyword
/// (`extern "C"` qualifiers are skipped; masked strings read as blanks).
fn classify_unsafe(sc: &Scanned, li: usize, col: usize) -> UnsafeKind {
    let mut text = String::new();
    if let Some(line) = sc.masked.get(li) {
        if col <= line.len() {
            text.push_str(&line[col..]);
        }
    }
    for l in sc.masked.iter().skip(li + 1).take(3) {
        text.push(' ');
        text.push_str(l);
    }
    for word in text.split_whitespace() {
        if word == "extern" {
            continue;
        }
        if word == "impl" {
            return UnsafeKind::Impl;
        }
        if word == "fn" || word.starts_with("fn(") || word.starts_with("fn<") {
            return UnsafeKind::Fn;
        }
        if word == "trait" {
            return UnsafeKind::Trait;
        }
        return UnsafeKind::Block;
    }
    UnsafeKind::Block
}

/// A `SAFETY:` (or rustdoc `# Safety`) comment on the site's line, or in
/// the contiguous comment/attribute/blank run directly above it.
fn safety_documented(sc: &Scanned, li: usize) -> bool {
    if has_safety(&sc.comments[li]) {
        return true;
    }
    let lo = li.saturating_sub(40);
    for l in (lo..li).rev() {
        if has_safety(&sc.comments[l]) {
            return true;
        }
        let code = sc.masked[l].trim();
        if !code.is_empty() && !code.starts_with("#[") && !code.starts_with("#!") {
            return false;
        }
    }
    false
}

fn has_safety(comment: &str) -> bool {
    comment.contains("SAFETY:") || comment.contains("# Safety")
}

// ---------------------------------------------------------------------------
// Rule 2 — boundary literals
// ---------------------------------------------------------------------------

/// Files that *define* the boundaries (or test against them) may spell
/// them raw; everything else must go through the `Format` accessors.
fn boundary_exempt(rel: &str) -> bool {
    rel.starts_with("rust/src/numerics/")
        || rel.starts_with("rust/src/analysis/")
        || rel.starts_with("rust/tests/")
}

pub fn check_boundary_literals(
    rel: &str,
    sc: &Scanned,
    in_test: &[bool],
    out: &mut Vec<Violation>,
) {
    if boundary_exempt(rel) {
        return;
    }
    for (li, line) in sc.masked.iter().enumerate() {
        if in_test[li] {
            continue;
        }
        for tok in numeric_tokens(line) {
            if let Some(hint) = forbidden_boundary(&tok) {
                out.push(Violation::new(
                    Rule::BoundaryLiteral,
                    rel,
                    li + 1,
                    format!("raw FP boundary literal `{tok}` — use {hint}"),
                ));
            }
        }
    }
}

fn forbidden_boundary(tok: &str) -> Option<&'static str> {
    let cleaned: String = tok.chars().filter(|&c| c != '_').collect();
    let v: f64 = cleaned.parse().ok()?;
    if v == crate::numerics::Format::F16.overflow_boundary() {
        Some("`Format::F16.overflow_boundary()`")
    } else if v == crate::numerics::Format::F8E4M3.overflow_boundary() {
        Some("`Format::F8E4M3.overflow_boundary()`")
    } else if v == 240.0 {
        // The E4M3 boundary under the UZ convention (paper Table 1);
        // reserved even though no `Format` row carries it yet.
        Some("a named constant in `numerics`")
    } else {
        None
    }
}

/// Maximal numeric tokens of a masked line: runs of digits / `_` / `.`
/// starting at a fresh digit. Tuple indices (`pair.0`) and identifier
/// tails (`x448`) are not fresh; `..` range punctuation ends a token.
fn numeric_tokens(line: &str) -> Vec<String> {
    let chars: Vec<char> = line.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        let fresh = match i.checked_sub(1).map(|p| chars[p]) {
            None => true,
            Some(p) if is_ident(p) => false,
            // After a lone `.` this is a tuple index / field; after `..`
            // it is the upper bound of a range and stands alone.
            Some('.') => i >= 2 && chars[i - 2] == '.',
            Some(_) => true,
        };
        if c.is_ascii_digit() && fresh {
            let mut j = i;
            while j < chars.len() {
                let cj = chars[j];
                if cj.is_ascii_digit() || cj == '_' {
                    j += 1;
                } else if cj == '.' && chars.get(j + 1) != Some(&'.') {
                    j += 1;
                } else {
                    break;
                }
            }
            let mut tok: String = chars[i..j].iter().collect();
            while tok.ends_with('.') || tok.ends_with('_') {
                tok.pop();
            }
            out.push(tok);
            i = j.max(i + 1);
        } else {
            i += 1;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 3 — wildcard arms over precision-critical enums
// ---------------------------------------------------------------------------

/// A `match` is protected when any arm *pattern* names one of these — the
/// enums whose variants gate precision dispatch. Arm expressions don't
/// count (constructing an `Allocation` in a body is fine).
const PROTECTED_ENUMS: [&str; 7] = [
    "Allocation::",
    "AttnMask::",
    "FaultKind::",
    "GuardPolicy::",
    "PrefixDecision::",
    "SchedDecision::",
    "StreamEvent::",
];

pub fn check_wildcard_arms(rel: &str, sc: &Scanned, in_test: &[bool], out: &mut Vec<Violation>) {
    // Flatten the masked lines so a match body can span lines; keep a
    // byte → line map for reporting.
    let mut flat = String::new();
    let mut line_of = Vec::new();
    for (li, l) in sc.masked.iter().enumerate() {
        for _ in 0..l.len() {
            line_of.push(li);
        }
        line_of.push(li); // the '\n'
        flat.push_str(l);
        flat.push('\n');
    }
    for start in word_positions(&flat, "match") {
        let li = line_of[start];
        if in_test[li] {
            continue;
        }
        let Some(arms) = parse_match_arms(&flat, start + "match".len()) else {
            continue;
        };
        let protected = arms
            .iter()
            .any(|(pat, _)| PROTECTED_ENUMS.iter().any(|&e| pat.contains(e)));
        if !protected {
            continue;
        }
        for (pat, off) in &arms {
            let head = pat.split(" if ").next().unwrap_or("");
            if head.split('|').any(|alt| alt.trim() == "_") {
                out.push(Violation::new(
                    Rule::WildcardArm,
                    rel,
                    line_of[*off] + 1,
                    "`_` arm in a match over a protected enum \
                     (Allocation / AttnMask / FaultKind / GuardPolicy / \
                     PrefixDecision / SchedDecision / StreamEvent) — name \
                     every variant so new rows fail to compile here"
                        .to_string(),
                ));
            }
        }
    }
}

/// Parse the arms of the `match` whose keyword ends at byte `from`:
/// returns `(pattern_text, pattern_start_offset)` per arm, or `None` when
/// no body follows (e.g. `match` bound by a macro fragment).
fn parse_match_arms(flat: &str, from: usize) -> Option<Vec<(String, usize)>> {
    let b = flat.as_bytes();
    let n = b.len();
    // Scrutinee: up to the first `{` at bracket depth 0.
    let mut i = from;
    let mut depth = 0i64;
    let body = loop {
        if i >= n {
            return None;
        }
        match b[i] {
            b'(' | b'[' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b'{' => {
                if depth == 0 {
                    break i + 1;
                }
                depth += 1;
            }
            b';' => {
                if depth == 0 {
                    return None;
                }
            }
            _ => {}
        }
        i += 1;
    };
    // Arms: pattern up to `=>` at depth 0, then skip the expression
    // (brace-matched block, or up to `,` / the body's closing `}`).
    let mut arms = Vec::new();
    let mut i = body;
    let mut depth = 0i64;
    let mut arm_start = body;
    while i < n {
        match b[i] {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' => depth -= 1,
            b'}' => {
                if depth == 0 {
                    return Some(arms);
                }
                depth -= 1;
            }
            b'=' if depth == 0 && b.get(i + 1) == Some(&b'>') => {
                arms.push((flat[arm_start..i].trim().to_string(), arm_start));
                i += 2;
                while i < n && b[i].is_ascii_whitespace() {
                    i += 1;
                }
                if i < n && b[i] == b'{' {
                    let mut d = 1i64;
                    i += 1;
                    while i < n && d > 0 {
                        match b[i] {
                            b'{' => d += 1,
                            b'}' => d -= 1,
                            _ => {}
                        }
                        i += 1;
                    }
                    while i < n && b[i].is_ascii_whitespace() {
                        i += 1;
                    }
                    if i < n && b[i] == b',' {
                        i += 1;
                    }
                } else {
                    let mut d = 0i64;
                    while i < n {
                        match b[i] {
                            b'(' | b'[' | b'{' => d += 1,
                            b')' | b']' => d -= 1,
                            b'}' => {
                                if d == 0 {
                                    break;
                                }
                                d -= 1;
                            }
                            b',' if d == 0 => {
                                i += 1;
                                break;
                            }
                            _ => {}
                        }
                        i += 1;
                    }
                }
                arm_start = i;
                continue;
            }
            _ => {}
        }
        i += 1;
    }
    Some(arms)
}

// ---------------------------------------------------------------------------
// Rule 4 — allocations inside hot-path fences
// ---------------------------------------------------------------------------

const FENCE_START: &str = "lint: hot-path";
const FENCE_END: &str = "lint: end-hot-path";

/// Call tokens that allocate. `.push(`/`.extend(`/`.clear(`/`reserve` are
/// deliberately allowed: workspace vectors grow amortized during warm-up,
/// which is exactly the discipline `alloc_discipline.rs` certifies.
const ALLOCATING: [&str; 11] = [
    "Vec::new(",
    "vec![",
    ".to_vec(",
    ".collect(",
    ".collect::<",
    ".clone(",
    ".to_owned(",
    "Box::new(",
    "String::new(",
    "format!(",
    "Matrix::zeros(",
];

/// Does `line` contain `tok` as a call? Tokens starting with an
/// identifier char must sit on a word boundary (`mono_format!(…)` must
/// not read as `format!(…)`); method tokens starting with `.` match
/// anywhere — their preceding char is the receiver by construction.
fn line_calls(line: &str, tok: &str) -> bool {
    if !tok.starts_with(is_ident) {
        return line.contains(tok);
    }
    let mut from = 0;
    while let Some(rel) = line[from..].find(tok) {
        let at = from + rel;
        if !line[..at].chars().next_back().is_some_and(is_ident) {
            return true;
        }
        from = at + tok.len();
    }
    false
}

fn hot_path_scoped(rel: &str) -> bool {
    rel.starts_with("rust/src/attention/")
        || rel.starts_with("rust/src/tensor/")
        || rel == "rust/src/pool.rs"
}

pub fn check_hot_path(rel: &str, sc: &Scanned, out: &mut Vec<Violation>) {
    if !hot_path_scoped(rel) {
        return;
    }
    let mut open: Option<usize> = None;
    for (li, com) in sc.comments.iter().enumerate() {
        // End first: the end marker embeds neither marker in the other.
        if com.contains(FENCE_END) {
            if open.take().is_none() {
                out.push(Violation::new(
                    Rule::HotPathAlloc,
                    rel,
                    li + 1,
                    "hot-path fence end without a matching start".to_string(),
                ));
            }
            continue;
        }
        if com.contains(FENCE_START) {
            if let Some(o) = open {
                out.push(Violation::new(
                    Rule::HotPathAlloc,
                    rel,
                    li + 1,
                    format!("nested hot-path fence (previous opened at line {})", o + 1),
                ));
            }
            open = Some(li);
            continue;
        }
        if open.is_some() {
            for tok in ALLOCATING {
                if line_calls(&sc.masked[li], tok) {
                    out.push(Violation::new(
                        Rule::HotPathAlloc,
                        rel,
                        li + 1,
                        format!("allocating call `{tok}…)` inside a hot-path fence"),
                    ));
                }
            }
        }
    }
    if let Some(o) = open {
        out.push(Violation::new(
            Rule::HotPathAlloc,
            rel,
            sc.masked.len(),
            format!("unclosed hot-path fence (opened at line {})", o + 1),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::super::scanner::scan;
    use super::*;

    fn lint_src(rel: &str, src: &str) -> Vec<Violation> {
        let sc = scan(src);
        let in_test = test_regions(&sc);
        let mut out = Vec::new();
        collect_unsafe_sites(rel, &sc, &mut out);
        check_boundary_literals(rel, &sc, &in_test, &mut out);
        check_wildcard_arms(rel, &sc, &in_test, &mut out);
        check_hot_path(rel, &sc, &mut out);
        out
    }

    fn sites(src: &str) -> Vec<UnsafeSite> {
        let sc = scan(src);
        let mut out = Vec::new();
        collect_unsafe_sites("f.rs", &sc, &mut out)
    }

    #[test]
    fn unsafe_block_requires_safety_comment() {
        let bad = "fn f() {\n    let x = unsafe { g() };\n}\n";
        let v = lint_src("rust/src/x.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, Rule::UnsafeAudit);
        assert_eq!(v[0].line, 2);

        let good = "fn f() {\n    // SAFETY: g is sound here.\n    let x = unsafe { g() };\n}\n";
        assert!(lint_src("rust/src/x.rs", good).is_empty());
    }

    #[test]
    fn safety_comment_walks_over_attributes_and_blanks() {
        let src = "// SAFETY: argued above.\n\n#[allow(dead_code)]\nunsafe impl Sync for T {}\n";
        assert!(lint_src("rust/src/x.rs", src).is_empty());
        // …but not over intervening code.
        let src2 = "// SAFETY: stale.\nfn other() {}\nunsafe impl Sync for T {}\n";
        assert_eq!(lint_src("rust/src/x.rs", src2).len(), 1);
    }

    #[test]
    fn unsafe_kinds_classify() {
        let s = sites(
            "// SAFETY: a\nunsafe impl Send for T {}\n\
             unsafe fn f() {}\n\
             unsafe extern \"C\" fn g() {}\n\
             unsafe trait Marker {}\n\
             fn h() { /* SAFETY: b */ unsafe { p() } }\n",
        );
        let kinds: Vec<UnsafeKind> = s.iter().map(|x| x.kind).collect();
        assert_eq!(
            kinds,
            [
                UnsafeKind::Impl,
                UnsafeKind::Fn,
                UnsafeKind::Fn,
                UnsafeKind::Trait,
                UnsafeKind::Block
            ]
        );
    }

    #[test]
    fn unsafe_fn_needs_no_inline_safety_comment() {
        // Its contract is rustdoc-`# Safety`; the discharge happens at
        // call sites. Only the registry tracks the site.
        let v = lint_src("rust/src/x.rs", "unsafe fn raw() {}\n");
        assert!(v.is_empty(), "{v:?}");
        assert_eq!(sites("unsafe fn raw() {}\n").len(), 1);
    }

    #[test]
    fn boundary_literals_flagged_outside_numerics() {
        let v = lint_src("rust/src/coordinator/x.rs", "let b = 65504.0;\n");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, Rule::BoundaryLiteral);
        let v = lint_src("rust/src/coordinator/x.rs", "let b = 448_f32;\n");
        assert_eq!(v.len(), 1);
        let v = lint_src("rust/src/coordinator/x.rs", "let b = 240.0f32;\n");
        assert_eq!(v.len(), 1);
    }

    #[test]
    fn boundary_literals_allowed_where_exempt() {
        assert!(lint_src("rust/src/numerics/round.rs", "let b = 65504.0;\n").is_empty());
        assert!(lint_src("rust/tests/t.rs", "assert!(x < 448.0);\n").is_empty());
        // In comments / strings / cfg(test) of a non-exempt file.
        let src = "// the FP16 max is 65504\nlet s = \"448\";\n\
                   #[cfg(test)]\nmod tests {\n    const B: f32 = 65504.0;\n}\n";
        assert!(lint_src("rust/src/coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn boundary_literal_token_edges() {
        // Not the boundary value: no hit.
        assert!(lint_src("rust/src/x.rs", "let a = 165504.0 + 44.8 + 4480.;\n").is_empty());
        // Identifier tails and tuple fields: no hit.
        assert!(lint_src("rust/src/x.rs", "let a = x448 + pair.0;\n").is_empty());
        // Range upper bound is a standalone literal: hit.
        assert_eq!(lint_src("rust/src/x.rs", "for i in 0..448 {}\n").len(), 1);
        // Underscore grouping still parses: hit.
        assert_eq!(lint_src("rust/src/x.rs", "let a = 65_504.0;\n").len(), 1);
    }

    #[test]
    fn wildcard_arm_over_protected_enum_flagged() {
        let src = "fn f(a: Allocation) -> u32 {\n    match a {\n        Allocation::Fa32 => 1,\n        _ => 0,\n    }\n}\n";
        let v = lint_src("rust/src/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, Rule::WildcardArm);
    }

    #[test]
    fn wildcard_arm_over_prefix_decision_flagged() {
        let src = "fn f(d: PrefixDecision) -> usize {\n    match d {\n        PrefixDecision::Hit { tokens } => tokens,\n        _ => 0,\n    }\n}\n";
        let v = lint_src("rust/src/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, Rule::WildcardArm);
    }

    #[test]
    fn wildcard_arm_guards_and_alternation_count() {
        let src = "match m {\n    AttnMask::Causal => 1,\n    _ if hot => 2,\n    AttnMask::None => 3,\n}\n";
        assert_eq!(lint_src("rust/src/x.rs", src).len(), 1);
        let src2 = "match m {\n    AttnMask::Causal | _ => 1,\n}\n";
        assert_eq!(lint_src("rust/src/x.rs", src2).len(), 1);
    }

    #[test]
    fn wildcard_arm_unprotected_or_expression_only_is_fine() {
        // `_` over an unprotected enum: fine.
        let src = "match k {\n    KvView::Dense(m) => 1,\n    _ => 0,\n}\n";
        assert!(lint_src("rust/src/x.rs", src).is_empty());
        // Protected name only in arm *expressions*: fine.
        let src2 = "match i {\n    0 => AttnMask::None,\n    _ => AttnMask::Causal,\n}\n";
        assert!(lint_src("rust/src/x.rs", src2).is_empty());
        // Exhaustive protected match with block arms and nested braces.
        let src3 = "match a {\n    Allocation::Fa32 => { if x { y() } else { z() } }\n    Allocation::Fp8 => w(),\n}\n";
        assert!(lint_src("rust/src/x.rs", src3).is_empty());
    }

    #[test]
    fn wildcard_arm_in_tests_is_fine() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(a: Allocation) -> u32 {\n        match a {\n            Allocation::Fa32 => 1,\n            _ => 0,\n        }\n    }\n}\n";
        assert!(lint_src("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn hot_path_fence_catches_allocations() {
        let src = format!(
            "// {FENCE_START}\nfn f(out: &mut [f32]) {{\n    let v = other.to_vec();\n}}\n// {FENCE_END}\n"
        );
        let v = lint_src("rust/src/tensor/x.rs", &src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, Rule::HotPathAlloc);
        assert_eq!(v[0].line, 3);
        // Same allocation outside any fence, or outside the scoped dirs:
        // fine.
        assert!(lint_src("rust/src/tensor/x.rs", "let v = o.to_vec();\n").is_empty());
        assert!(lint_src("rust/src/model/x.rs", &src).is_empty());
    }

    #[test]
    fn hot_path_fences_must_balance() {
        let unclosed = format!("// {FENCE_START}\nfn f() {{}}\n");
        assert_eq!(lint_src("rust/src/pool.rs", &unclosed).len(), 1);
        let orphan_end = format!("fn f() {{}}\n// {FENCE_END}\n");
        assert_eq!(lint_src("rust/src/pool.rs", &orphan_end).len(), 1);
        let nested = format!("// {FENCE_START}\n// {FENCE_START}\n// {FENCE_END}\n");
        assert_eq!(lint_src("rust/src/pool.rs", &nested).len(), 1);
    }

    #[test]
    fn fence_markers_in_strings_do_not_fence() {
        let src = format!("fn f() {{ let s = \"{FENCE_START}\"; let v = x.to_vec(); }}\n");
        assert!(lint_src("rust/src/tensor/x.rs", &src).is_empty());
    }
}
