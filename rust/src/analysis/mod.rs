//! `pasa lint` — repo-native static analysis for numerical safety (S14).
//!
//! A numerics codebase has invariants `rustc` and clippy cannot see:
//! which FP constants are *boundaries* (a hardcoded `65504` that drifts
//! from the format table corrupts every guard decision downstream), which
//! enums are *precision-critical* (a `_` arm over [`Allocation`] silently
//! swallows a new precision row instead of failing to compile), which
//! regions are *hot paths* (an accidental `.clone()` in the KV sweep
//! un-does the zero-allocation work that `alloc_discipline.rs` certifies),
//! and which `unsafe` sites have actually been *reviewed*. This module
//! enforces all four as a tier-1 test and a CLI subcommand:
//!
//! ```text
//! cargo run --release -- lint        # scan the tree, exit 1 on violations
//! cargo test --test lint_invariants  # the same scan as a tier-1 test
//! ```
//!
//! Layout: [`scanner`] produces comment/string-masked views of each file,
//! [`rules`] implements the four rules over those views, and
//! [`unsafe_audit`] holds the checked-in registry every `unsafe` site must
//! appear in. The scanner is dependency-free by design — the lint runs
//! wherever `cargo test` runs, with nothing to install and no rustc
//! version coupling.
//!
//! [`Allocation`]: crate::attention::Allocation

pub mod rules;
pub mod scanner;
pub mod unsafe_audit;

pub use rules::{UnsafeKind, UnsafeSite};

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The four lint rules.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Rule {
    /// Rule 1: `SAFETY:` comments + the audit registry.
    UnsafeAudit,
    /// Rule 2: no raw FP boundary literals outside `numerics/`.
    BoundaryLiteral,
    /// Rule 3: no `_` arms over precision-critical enums.
    WildcardArm,
    /// Rule 4: no allocating calls inside hot-path fences.
    HotPathAlloc,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::UnsafeAudit => "unsafe-audit",
            Rule::BoundaryLiteral => "boundary-literal",
            Rule::WildcardArm => "wildcard-arm",
            Rule::HotPathAlloc => "hot-path-alloc",
        }
    }
}

/// One finding, formatted `file:line: [rule] message` (line 0 when the
/// finding is about an absence, e.g. a stale audit entry).
#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: Rule,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Violation {
    pub(crate) fn new(rule: Rule, file: &str, line: usize, message: String) -> Violation {
        Violation {
            rule,
            file: file.to_string(),
            line,
            message,
        }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.rule.name(),
            self.message
        )
    }
}

/// The per-file lint result: violations from rules 1–4 plus the `unsafe`
/// inventory (the caller aggregates inventories across the tree and runs
/// the registry cross-check once).
pub struct FileReport {
    pub violations: Vec<Violation>,
    pub unsafe_sites: Vec<UnsafeSite>,
}

/// Lint one file's source text. `rel` is the repo-relative `/`-separated
/// path — the rules use it for scoping (exempt dirs, hot-path dirs).
pub fn lint_file(rel: &str, text: &str) -> FileReport {
    let sc = scanner::scan(text);
    let in_test = rules::test_regions(&sc);
    let mut violations = Vec::new();
    let unsafe_sites = rules::collect_unsafe_sites(rel, &sc, &mut violations);
    rules::check_boundary_literals(rel, &sc, &in_test, &mut violations);
    rules::check_wildcard_arms(rel, &sc, &in_test, &mut violations);
    rules::check_hot_path(rel, &sc, &mut violations);
    FileReport {
        violations,
        unsafe_sites,
    }
}

/// Lint the whole tree under `root` (the repo root): every `.rs` file in
/// `rust/src/` and `rust/tests/`, excluding the deliberately-violating
/// `lint_fixtures/` corpus, then the audit-registry cross-check. Returns
/// findings sorted by `(file, line)`.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs(&root.join("rust").join("src"), &mut files)?;
    collect_rs(&root.join("rust").join("tests"), &mut files)?;
    files.sort();
    let mut violations = Vec::new();
    let mut sites = Vec::new();
    for path in &files {
        let rel = rel_name(root, path);
        let text = fs::read_to_string(path)?;
        let mut rep = lint_file(&rel, &text);
        violations.append(&mut rep.violations);
        sites.extend(rep.unsafe_sites);
    }
    violations.extend(unsafe_audit::check(&sites));
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(violations)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.file_name().is_some_and(|n| n == "lint_fixtures") {
            continue;
        }
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_name(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn violation_display_is_grep_friendly() {
        let v = Violation::new(Rule::BoundaryLiteral, "rust/src/a.rs", 7, "msg".to_string());
        assert_eq!(v.to_string(), "rust/src/a.rs:7: [boundary-literal] msg");
    }

    #[test]
    fn lint_file_aggregates_all_rules() {
        // One source tripping rules 2 and 3 at once; rule 1 records the
        // site inventory without a violation (SAFETY present).
        let src = "\
// SAFETY: test fixture.
unsafe impl Sync for T {}
fn f(a: Allocation) -> f32 {
    match a {
        Allocation::Fa32 => 65504.0,
        _ => 0.0,
    }
}
";
        let rep = lint_file("rust/src/coordinator/x.rs", src);
        assert_eq!(rep.unsafe_sites.len(), 1);
        let rules_hit: Vec<Rule> = rep.violations.iter().map(|v| v.rule).collect();
        assert!(rules_hit.contains(&Rule::BoundaryLiteral), "{rules_hit:?}");
        assert!(rules_hit.contains(&Rule::WildcardArm), "{rules_hit:?}");
        assert_eq!(rep.violations.len(), 2, "{:?}", rep.violations);
    }
}
