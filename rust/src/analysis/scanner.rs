//! Character-level Rust source scanner for the lint rules.
//!
//! Splits a source file into two parallel per-line views:
//!
//! * **masked code** — the source with every comment, string literal and
//!   char literal replaced by spaces, column positions preserved, so the
//!   rules can match tokens against *code only* (a `match` inside a doc
//!   comment or a `65504` inside an error message never fires a rule);
//! * **comment text** — the concatenated comment content of each line,
//!   which is what the `SAFETY:` and hot-path fence-marker checks read.
//!
//! This is deliberately a state machine, not a parser: the repo's
//! invariants are all expressible at the token/line level, and ~200 lines
//! with zero dependencies run inside every `cargo test` without coupling
//! the build to a rustc-internals crate.
//!
//! Handled syntax: line comments, nested block comments, plain and byte
//! strings (`"…"`, `b"…"`), raw strings of any hash arity (`r"…"`,
//! `r##"…"##`, `br#"…"#`), char and byte-char literals, and the
//! lifetime-vs-char-literal ambiguity (`'a` vs `'a'`).

/// A scanned source file: per input line, the comment/string-masked code
/// and the comment text.
pub struct Scanned {
    /// Code with comments/strings/chars masked to spaces; one entry per
    /// source line, columns preserved.
    pub masked: Vec<String>,
    /// Comment text of each line ("" where the line has none).
    pub comments: Vec<String>,
}

enum St {
    Code,
    LineComment,
    /// Nested block comment; the payload is the nesting depth.
    BlockComment(usize),
    /// Inside `"…"` / `b"…"`; the flag marks a pending `\` escape.
    Str(bool),
    /// Inside a raw string; the payload is the `#` arity of its delimiter.
    RawStr(usize),
    /// Inside `'…'`; the flag marks a pending `\` escape.
    CharLit(bool),
}

/// Scan `src` into masked-code and comment-text lines.
pub fn scan(src: &str) -> Scanned {
    let ch: Vec<char> = src.chars().collect();
    let n = ch.len();
    let mut masked = Vec::new();
    let mut comments = Vec::new();
    let mut code = String::new();
    let mut com = String::new();
    let mut st = St::Code;
    let mut i = 0;
    while i < n {
        let c = ch[i];
        if c == '\n' {
            if matches!(st, St::LineComment) {
                st = St::Code;
            }
            masked.push(std::mem::take(&mut code));
            comments.push(std::mem::take(&mut com));
            i += 1;
            continue;
        }
        match st {
            St::Code => i = step_code(&ch, i, &mut code, &mut st),
            St::LineComment => {
                com.push(c);
                code.push(' ');
                i += 1;
            }
            St::BlockComment(depth) => {
                let next = ch.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    code.push(' ');
                    code.push(' ');
                    st = if depth == 1 {
                        St::Code
                    } else {
                        St::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    com.push('/');
                    com.push('*');
                    code.push(' ');
                    code.push(' ');
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else {
                    com.push(c);
                    code.push(' ');
                    i += 1;
                }
            }
            St::Str(escaped) => {
                code.push(' ');
                st = if escaped {
                    St::Str(false)
                } else if c == '\\' {
                    St::Str(true)
                } else if c == '"' {
                    St::Code
                } else {
                    St::Str(false)
                };
                i += 1;
            }
            St::CharLit(escaped) => {
                code.push(' ');
                st = if escaped {
                    St::CharLit(false)
                } else if c == '\\' {
                    St::CharLit(true)
                } else if c == '\'' {
                    St::Code
                } else {
                    St::CharLit(false)
                };
                i += 1;
            }
            St::RawStr(hashes) => {
                if c == '"' && (1..=hashes).all(|k| ch.get(i + k) == Some(&'#')) {
                    for _ in 0..=hashes {
                        code.push(' ');
                    }
                    st = St::Code;
                    i += hashes + 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !com.is_empty() {
        masked.push(code);
        comments.push(com);
    }
    Scanned { masked, comments }
}

/// One step of the `Code` state: classify the token starting at `i`,
/// append its mask to `code`, set the next state, return the next index.
fn step_code(ch: &[char], i: usize, code: &mut String, st: &mut St) -> usize {
    let c = ch[i];
    let next = ch.get(i + 1).copied();
    if c == '/' && next == Some('/') {
        code.push(' ');
        code.push(' ');
        *st = St::LineComment;
        return i + 2;
    }
    if c == '/' && next == Some('*') {
        code.push(' ');
        code.push(' ');
        *st = St::BlockComment(1);
        return i + 2;
    }
    if c == '"' {
        code.push(' ');
        *st = St::Str(false);
        return i + 1;
    }
    // `r` / `b` string prefixes only start a literal when they are not the
    // tail of an identifier (`attr"` is not a raw string; `r"` is).
    let prev_is_ident = code
        .chars()
        .next_back()
        .is_some_and(|p| p.is_alphanumeric() || p == '_');
    if c == 'b' && next == Some('"') && !prev_is_ident {
        code.push(' ');
        code.push(' ');
        *st = St::Str(false);
        return i + 2;
    }
    if (c == 'r' || (c == 'b' && next == Some('r'))) && !prev_is_ident {
        let start = if c == 'b' { i + 2 } else { i + 1 };
        let mut h = 0;
        while ch.get(start + h) == Some(&'#') {
            h += 1;
        }
        if ch.get(start + h) == Some(&'"') {
            for _ in i..=start + h {
                code.push(' ');
            }
            *st = St::RawStr(h);
            return start + h + 1;
        }
    }
    if c == '\'' {
        // `'a` (lifetime) vs `'a'` (char literal): an identifier char
        // right after the quote that is *not* closed by a second quote is
        // a lifetime. Everything else (`'\n'`, `' '`, `'a'`) is a literal.
        let c1 = ch.get(i + 1).copied();
        let c2 = ch.get(i + 2).copied();
        let lifetime = c1.is_some_and(|x| x.is_alphanumeric() || x == '_') && c2 != Some('\'');
        if lifetime {
            code.push('\'');
            return i + 1;
        }
        code.push(' ');
        *st = St::CharLit(false);
        return i + 1;
    }
    code.push(c);
    i + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn masked(src: &str) -> Vec<String> {
        scan(src).masked
    }

    #[test]
    fn line_comments_are_masked_and_captured() {
        let src = "let x = 1; // keep 65504 here\nlet y = 2;\n";
        let sc = scan(src);
        assert_eq!(sc.masked[0].trim_end(), "let x = 1;");
        assert_eq!(sc.masked[0].len(), "let x = 1; // keep 65504 here".len());
        assert!(sc.comments[0].contains("65504"));
        assert_eq!(sc.masked[1], "let y = 2;");
        assert_eq!(sc.comments[1], "");
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let sc = scan("a /* one /* two */ still */ b\n/* open\nclose */ c\n");
        // The nested `*/` must not close the outer comment: `still` is
        // comment text, and only `a … b` survive as code.
        assert!(sc.masked[0].starts_with('a'));
        assert!(sc.masked[0].ends_with('b'));
        assert!(!sc.masked[0].contains("still"));
        assert!(sc.comments[0].contains("two"));
        assert!(sc.comments[0].contains("still"));
        assert_eq!(sc.masked[1].trim(), "");
        assert_eq!(sc.masked[2].trim(), "c");
        assert!(sc.comments[1].contains("open"));
    }

    #[test]
    fn strings_are_masked_with_escapes() {
        // The escaped quote must not terminate the literal early.
        let m = masked("let s = \"match _ => \\\" 65504\"; done()\n");
        assert!(m[0].starts_with("let s ="));
        assert!(m[0].ends_with("; done()"));
        assert!(!m[0].contains("match"));
        assert!(!m[0].contains("65504"));
    }

    #[test]
    fn raw_and_byte_strings_are_masked() {
        let m = masked("let a = r#\"says \"hi\" unsafe\"#; let b = b\"448\";\n");
        assert!(!m[0].contains("unsafe"));
        assert!(!m[0].contains("448"));
        assert!(m[0].contains("let a ="));
        assert!(m[0].contains("let b ="));
    }

    #[test]
    fn identifier_tail_r_is_not_a_raw_string() {
        // `var"` would otherwise open a raw string and eat the file.
        let m = masked("let tr = xr; foo(\"s\"); bar()\n");
        assert_eq!(m[0], "let tr = xr; foo(   ); bar()");
    }

    #[test]
    fn lifetimes_survive_char_literals_do_not() {
        let m = masked("fn f<'a>(s: &'a str) { let c = 'x'; let n = '\\n'; }\n");
        assert!(m[0].contains("fn f<'a>(s: &'a str)"));
        assert!(!m[0].contains('x'), "char literal leaked: {}", m[0]);
        let m2 = masked("let c = 'x'; g::<'b>()\n");
        assert!(m2[0].contains("g::<'b>()"));
    }

    #[test]
    fn columns_are_preserved() {
        let src = "abc /* xx */ def \"ss\" ghi\n";
        let m = masked(src);
        assert_eq!(m[0].len(), src.len() - 1);
        assert_eq!(m[0].find("def"), src.find("def"));
        assert_eq!(m[0].find("ghi"), src.find("ghi"));
    }

    #[test]
    fn unterminated_final_line_is_kept() {
        let sc = scan("let x = 1; // tail");
        assert_eq!(sc.masked.len(), 1);
        assert!(sc.comments[0].contains("tail"));
    }
}
