//! The checked-in `unsafe` audit registry.
//!
//! Every `unsafe` site in the crate — blocks, impls, fns, traits — must be
//! accounted for here, by `(file, kind)` with an **exact count** and a
//! one-line recap of why the site is sound. The lint cross-checks the
//! registry against what [`super::rules::collect_unsafe_sites`] actually
//! finds, in both directions:
//!
//! * a site the registry doesn't cover (or a count that grew) fails — new
//!   unsafe code cannot land without a reviewed registry edit in the same
//!   diff, which makes `git log -p` on this file the crate's complete
//!   unsafe-review history;
//! * a registry entry with no remaining sites (or a count that shrank)
//!   also fails — stale audit claims are as misleading as missing ones.
//!
//! The recap lines here are deliberately short; the load-bearing argument
//! lives in the `SAFETY:` comment at each site (rule 1 guarantees it
//! exists for blocks and impls).

use super::rules::{UnsafeKind, UnsafeSite};
use super::{Rule, Violation};

/// One audited `(file, kind)` group.
pub struct AuditEntry {
    /// Repo-relative `/`-separated path.
    pub file: &'static str,
    pub kind: UnsafeKind,
    /// Exact number of sites of this kind in this file.
    pub count: usize,
    /// One-line soundness recap (the full argument is at the site).
    pub why: &'static str,
}

/// The complete unsafe inventory of the crate, as reviewed.
pub const AUDIT: &[AuditEntry] = &[
    AuditEntry {
        file: "rust/src/attention/kernel.rs",
        kind: UnsafeKind::Impl,
        count: 2,
        why: "Send/Sync for SharedRows: (head × Q-block) tiles write disjoint \
              row ranges, recorded and asserted by claim_rows in debug builds",
    },
    AuditEntry {
        file: "rust/src/attention/kernel.rs",
        kind: UnsafeKind::Block,
        count: 1,
        why: "from_raw_parts_mut over one tile's claimed row range; the owning \
              matrix outlives run_tiles, which blocks until every tile is done",
    },
    AuditEntry {
        file: "rust/src/coordinator/kv_cache.rs",
        kind: UnsafeKind::Impl,
        count: 1,
        why: "Sync for KvPool: arena writes go through &mut self or through \
              page_write's exclusively-owned refcount-1 pages",
    },
    AuditEntry {
        file: "rust/src/coordinator/kv_cache.rs",
        kind: UnsafeKind::Fn,
        count: 1,
        why: "page_write: shared-reference write path; the caller must own \
              the page exclusively (refcount 1), debug-asserted on entry",
    },
    AuditEntry {
        file: "rust/src/coordinator/kv_cache.rs",
        kind: UnsafeKind::Block,
        count: 7,
        why: "UnsafeCell arena views (f32 and E4M3 byte arenas): reads through \
              layout-compatible slices of pages the reader owns, writes behind \
              the refcount-1 witness",
    },
    AuditEntry {
        file: "rust/src/tensor/simd.rs",
        kind: UnsafeKind::Fn,
        count: 3,
        why: "AVX2 target_feature microkernels (dot/dot4/axpy); callers must \
              hold the detected() witness, enforced by the safe wrappers",
    },
    AuditEntry {
        file: "rust/src/tensor/simd.rs",
        kind: UnsafeKind::Block,
        count: 6,
        why: "in-bounds unaligned loadu/storeu over slice-derived pointers \
              inside the kernels, plus detected()-gated wrapper dispatch",
    },
    AuditEntry {
        file: "rust/src/pool.rs",
        kind: UnsafeKind::Block,
        count: 1,
        why: "lifetime-erasing transmute of the tile closure; BatchGuard \
              drains every claimed tile before the submitting frame unwinds",
    },
    AuditEntry {
        file: "rust/tests/alloc_discipline.rs",
        kind: UnsafeKind::Impl,
        count: 1,
        why: "GlobalAlloc for the counting test allocator, forwarding \
              verbatim to System",
    },
    AuditEntry {
        file: "rust/tests/alloc_discipline.rs",
        kind: UnsafeKind::Fn,
        count: 4,
        why: "the four GlobalAlloc trait methods of the counting allocator",
    },
    AuditEntry {
        file: "rust/tests/alloc_discipline.rs",
        kind: UnsafeKind::Block,
        count: 4,
        why: "System forwarding calls under the caller's own GlobalAlloc \
              contract",
    },
];

/// Cross-check collected sites against [`AUDIT`] (exact counts, both
/// directions). `sites` must cover the whole tree for the stale-entry
/// direction to be meaningful.
pub fn check(sites: &[UnsafeSite]) -> Vec<Violation> {
    check_against(sites, AUDIT)
}

/// [`check`] against an explicit registry (tests pass fixture registries).
pub fn check_against(sites: &[UnsafeSite], audit: &[AuditEntry]) -> Vec<Violation> {
    let mut found: Vec<(&str, UnsafeKind, usize)> = Vec::new();
    for s in sites {
        if let Some(e) = found
            .iter_mut()
            .find(|(f, k, _)| *f == s.file && *k == s.kind)
        {
            e.2 += 1;
        } else {
            found.push((&s.file, s.kind, 1));
        }
    }
    let mut out = Vec::new();
    for &(file, kind, count) in &found {
        let audited = audit
            .iter()
            .find(|e| e.file == file && e.kind == kind)
            .map_or(0, |e| e.count);
        if count != audited {
            let line = sites
                .iter()
                .find(|s| s.file == file && s.kind == kind)
                .map_or(0, |s| s.line);
            out.push(Violation::new(
                Rule::UnsafeAudit,
                file,
                line,
                format!(
                    "{count} `unsafe {kind}` site(s) found but the audit registry \
                     records {audited} — review the site and update \
                     rust/src/analysis/unsafe_audit.rs in the same change"
                ),
            ));
        }
    }
    for e in audit {
        let present = found.iter().any(|&(f, k, _)| f == e.file && k == e.kind);
        if !present && e.count > 0 {
            out.push(Violation::new(
                Rule::UnsafeAudit,
                e.file,
                0,
                format!(
                    "stale audit entry: no `unsafe {}` sites remain in this file \
                     — remove the entry from rust/src/analysis/unsafe_audit.rs",
                    e.kind
                ),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn site(file: &str, kind: UnsafeKind, line: usize) -> UnsafeSite {
        UnsafeSite {
            file: file.to_string(),
            kind,
            line,
        }
    }

    #[test]
    fn exact_match_passes() {
        let audit = [AuditEntry {
            file: "a.rs",
            kind: UnsafeKind::Block,
            count: 2,
            why: "test",
        }];
        let sites = [
            site("a.rs", UnsafeKind::Block, 3),
            site("a.rs", UnsafeKind::Block, 9),
        ];
        assert!(check_against(&sites, &audit).is_empty());
    }

    #[test]
    fn unaudited_and_overgrown_sites_fail() {
        let audit = [AuditEntry {
            file: "a.rs",
            kind: UnsafeKind::Block,
            count: 1,
            why: "test",
        }];
        // A brand-new file with unsafe: fails.
        let v = check_against(&[site("b.rs", UnsafeKind::Block, 1)], &audit);
        assert_eq!(v.iter().filter(|x| x.file == "b.rs").count(), 1);
        // Count grew beyond the audited number: fails.
        let v = check_against(
            &[
                site("a.rs", UnsafeKind::Block, 1),
                site("a.rs", UnsafeKind::Block, 2),
            ],
            &audit,
        );
        assert_eq!(v.iter().filter(|x| x.file == "a.rs").count(), 1);
        // A different *kind* in an audited file is still unaudited.
        let v = check_against(
            &[
                site("a.rs", UnsafeKind::Block, 1),
                site("a.rs", UnsafeKind::Impl, 4),
            ],
            &audit,
        );
        assert_eq!(v.len(), 1);
    }

    #[test]
    fn stale_entries_fail() {
        let audit = [AuditEntry {
            file: "gone.rs",
            kind: UnsafeKind::Impl,
            count: 1,
            why: "test",
        }];
        let v = check_against(&[], &audit);
        assert_eq!(v.len(), 1);
        assert!(v[0].message.contains("stale"));
    }

    #[test]
    fn registry_is_internally_consistent() {
        // No duplicate (file, kind) groups, no zero counts, no empty
        // rationales.
        for (i, e) in AUDIT.iter().enumerate() {
            assert!(e.count > 0, "{}: zero-count entry", e.file);
            assert!(!e.why.is_empty(), "{}: empty rationale", e.file);
            for other in &AUDIT[i + 1..] {
                assert!(
                    !(e.file == other.file && e.kind == other.kind),
                    "duplicate audit group {} / {}",
                    e.file,
                    e.kind
                );
            }
        }
    }
}
