//! # PASA — Online Pseudo-average Shifting Attention
//!
//! Production-style reproduction of *"Online Pseudo-average Shifting
//! Attention (PASA) for Robust Low-precision LLM Inference: Algorithms and
//! Numerical Analysis"* (Cheng et al., 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas flash/PASA kernels (`python/compile/kernels/`),
//! * **L2** — JAX transformer, AOT-lowered to HLO text (`python/compile/`),
//! * **L3** — this crate: the serving coordinator, the PJRT runtime that
//!   executes the AOT artifacts, and the bit-exact FP16 attention lab that
//!   regenerates every table and figure of the paper's evaluation.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

// The bit-exact emulation layers index heavily into row slices (matching
// the papers' loop nests); iterator rewrites obscure the numerics.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::field_reassign_with_default)]

pub mod analysis;
pub mod attention;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod experiments;
pub mod model;
pub mod numerics;
pub mod pool;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod testkit;
pub mod workloads;
