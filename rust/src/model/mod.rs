//! Serving-model substrate (S12): manifest/config parsing, weight loading,
//! byte-level tokenizer and sampling.

pub mod config;
pub mod sampling;
pub mod tokenizer;
pub mod weights;

pub use config::{Manifest, ModelDims, ModuleEntry};
pub use sampling::{sample, Sampling};
pub use tokenizer::{decode, encode, Specials};
pub use weights::{ParamTensor, Weights};
