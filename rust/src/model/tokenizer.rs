//! Byte-level tokenizer (S12) — mirrors python/compile/model.py's
//! encode_text/decode_bytes exactly (vocab = 256 bytes + PAD/BOS/EOS).

/// Token ids for the specials (must match the manifest's config line).
#[derive(Clone, Copy, Debug)]
pub struct Specials {
    pub pad: u32,
    pub bos: u32,
    pub eos: u32,
}

impl Default for Specials {
    fn default() -> Self {
        Specials {
            pad: 256,
            bos: 257,
            eos: 258,
        }
    }
}

/// Encode text: BOS + utf-8 bytes, truncated to `max_len`, padded with PAD.
/// Returns (ids, valid_len).
pub fn encode(text: &str, max_len: usize, sp: Specials) -> (Vec<u32>, usize) {
    let mut ids = Vec::with_capacity(max_len);
    ids.push(sp.bos);
    for &b in text.as_bytes().iter().take(max_len.saturating_sub(1)) {
        ids.push(b as u32);
    }
    let n = ids.len();
    ids.resize(max_len, sp.pad);
    (ids, n)
}

/// Tokenized length of a prompt with no truncation or padding:
/// BOS + one token per utf-8 byte. This is the admission currency — the
/// router and scheduler budget in these units, never in `str::len` bytes
/// (a multi-byte character is several tokens, an admission check on bytes
/// against a token budget is simply wrong).
pub fn token_len(text: &str) -> usize {
    1 + text.len()
}

/// Encode a prompt without padding: BOS + utf-8 bytes, truncated to
/// `max_len` tokens. The chunked-prefill engine path consumes this (it
/// prefills exactly the valid tokens, chunk by chunk, so PAD rows never
/// enter the paged cache).
pub fn encode_prompt(text: &str, max_len: usize, sp: Specials) -> Vec<u32> {
    let mut ids = Vec::with_capacity(token_len(text).min(max_len));
    ids.push(sp.bos);
    for &b in text.as_bytes().iter().take(max_len.saturating_sub(1)) {
        ids.push(b as u32);
    }
    ids
}

/// Decode ids back to text, skipping specials and invalid bytes.
pub fn decode(ids: &[u32], sp: Specials) -> String {
    let bytes: Vec<u8> = ids
        .iter()
        .filter(|&&t| t < 256 && t != sp.pad && t != sp.bos && t != sp.eos)
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_shape_and_padding() {
        let sp = Specials::default();
        let (ids, n) = encode("hello", 16, sp);
        assert_eq!(n, 6);
        assert_eq!(ids.len(), 16);
        assert_eq!(ids[0], sp.bos);
        assert_eq!(&ids[1..6], &[104, 101, 108, 108, 111]);
        assert!(ids[6..].iter().all(|&t| t == sp.pad));
    }

    #[test]
    fn truncation() {
        let sp = Specials::default();
        let (ids, n) = encode("abcdefgh", 4, sp);
        assert_eq!(n, 4);
        assert_eq!(ids, vec![sp.bos, 97, 98, 99]);
    }

    #[test]
    fn token_len_counts_bytes_plus_bos() {
        assert_eq!(token_len(""), 1);
        assert_eq!(token_len("hello"), 6);
        // Multi-byte characters cost one token per byte.
        assert_eq!(token_len("é"), 3);
        assert_eq!(token_len(&"é".repeat(10)), 21);
    }

    #[test]
    fn encode_prompt_unpadded_matches_encode_prefix() {
        let sp = Specials::default();
        let ids = encode_prompt("hello", 16, sp);
        assert_eq!(ids.len(), 6);
        let (padded, n) = encode("hello", 16, sp);
        assert_eq!(&padded[..n], &ids[..]);
        // Truncation at the token limit.
        assert_eq!(encode_prompt("abcdefgh", 4, sp), vec![sp.bos, 97, 98, 99]);
    }

    #[test]
    fn round_trip() {
        let sp = Specials::default();
        let (ids, _) = encode("pasa attention!", 64, sp);
        assert_eq!(decode(&ids, sp), "pasa attention!");
    }

    #[test]
    fn decode_skips_specials_and_eos() {
        let sp = Specials::default();
        let ids = [sp.bos, 104, 105, sp.eos, sp.pad];
        assert_eq!(decode(&ids, sp), "hi");
    }
}
