//! Token sampling (S12): greedy, temperature and top-k over logits.

use crate::workloads::Pcg64;

/// Sampling policy for generation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    Greedy,
    /// Softmax sampling at a temperature.
    Temperature(f32),
    /// Top-k filtering then temperature sampling.
    TopK { k: usize, temperature: f32 },
}

/// Pick the next token id from a logits row.
pub fn sample(logits: &[f32], policy: Sampling, rng: &mut Pcg64) -> u32 {
    match policy {
        Sampling::Greedy => argmax(logits) as u32,
        Sampling::Temperature(t) => sample_softmax(logits, t, usize::MAX, rng),
        Sampling::TopK { k, temperature } => sample_softmax(logits, temperature, k, rng),
    }
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in v.iter().enumerate() {
        // NaN-safe: NaN never wins, ties keep the lowest id (deterministic).
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

fn sample_softmax(logits: &[f32], temperature: f32, k: usize, rng: &mut Pcg64) -> u32 {
    let t = temperature.max(1e-4);
    // Select the top-k candidate set.
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if k < logits.len() {
        idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal));
        idx.truncate(k.max(1));
    }
    let m = idx
        .iter()
        .map(|&i| logits[i])
        .fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return argmax(logits) as u32;
    }
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - m) / t) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut r = rng.next_f64() * total;
    for (w, &i) in weights.iter().zip(&idx) {
        r -= w;
        if r <= 0.0 {
            return i as u32;
        }
    }
    *idx.last().unwrap() as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Pcg64::new(1, 0);
        let logits = [0.1, 5.0, -2.0, 4.9];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn greedy_is_nan_safe() {
        let mut rng = Pcg64::new(1, 0);
        let logits = [f32::NAN, 1.0, 0.5];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut rng = Pcg64::new(2, 0);
        let logits = [0.0, 3.0, 1.0];
        for _ in 0..50 {
            assert_eq!(sample(&logits, Sampling::Temperature(0.01), &mut rng), 1);
        }
    }

    #[test]
    fn topk_restricts_support() {
        let mut rng = Pcg64::new(3, 0);
        let logits = [10.0, 9.5, -100.0, -100.0];
        for _ in 0..100 {
            let t = sample(
                &logits,
                Sampling::TopK {
                    k: 2,
                    temperature: 1.0,
                },
                &mut rng,
            );
            assert!(t < 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut rng = Pcg64::new(4, 0);
        let logits = [1.0, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[sample(&logits, Sampling::Temperature(1.0), &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "uniform logits should hit all ids");
    }
}
