//! Weight loading — the `weights.bin` format written by
//! python/compile/train.py::save_weights.
//!
//! Layout (little-endian): magic "PASAW001", u32 n; per parameter:
//! u32 name_len, name bytes, u32 ndim, u32 dims[ndim], f32 data.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// One named parameter tensor (row-major f32).
#[derive(Clone, Debug)]
pub struct ParamTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl ParamTensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// All weights, preserving file order (the AOT argument order).
#[derive(Clone, Debug, Default)]
pub struct Weights {
    pub tensors: Vec<ParamTensor>,
    pub by_name: HashMap<String, usize>,
}

impl Weights {
    pub fn load(path: &Path) -> Result<Weights> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let mut cur = 0usize;
        let take = |cur: &mut usize, n: usize| -> Result<&[u8]> {
            if *cur + n > bytes.len() {
                bail!("weights file truncated at offset {cur}");
            }
            let s = &bytes[*cur..*cur + n];
            *cur += n;
            Ok(s)
        };
        let magic = take(&mut cur, 8)?;
        if magic != b"PASAW001" {
            bail!("bad weights magic {:?}", magic);
        }
        let read_u32 = |cur: &mut usize| -> Result<u32> {
            let b = take(cur, 4)?;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        };
        let n = read_u32(&mut cur)? as usize;
        let mut tensors = Vec::with_capacity(n);
        let mut by_name = HashMap::new();
        for _ in 0..n {
            let name_len = read_u32(&mut cur)? as usize;
            let name = String::from_utf8(take(&mut cur, name_len)?.to_vec())?;
            let ndim = read_u32(&mut cur)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut cur)? as usize);
            }
            let count: usize = dims.iter().product::<usize>().max(1);
            let raw = take(&mut cur, 4 * count)?;
            let mut data = vec![0f32; count];
            for (i, ch) in raw.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            }
            by_name.insert(name.clone(), tensors.len());
            tensors.push(ParamTensor { name, dims, data });
        }
        Ok(Weights { tensors, by_name })
    }

    pub fn get(&self, name: &str) -> Option<&ParamTensor> {
        self.by_name.get(name).map(|&i| &self.tensors[i])
    }

    /// Verify the tensors match the manifest's parameter inventory
    /// (names, order and shapes — the AOT argument contract).
    pub fn check_against(&self, params: &[(String, Vec<usize>)]) -> Result<()> {
        if self.tensors.len() != params.len() {
            bail!(
                "weights has {} tensors, manifest expects {}",
                self.tensors.len(),
                params.len()
            );
        }
        for (t, (name, dims)) in self.tensors.iter().zip(params) {
            if &t.name != name {
                bail!("weight order mismatch: {} vs manifest {}", t.name, name);
            }
            if &t.dims != dims {
                bail!("shape mismatch for {}: {:?} vs {:?}", name, t.dims, dims);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_weights_file(path: &Path) {
        let mut buf: Vec<u8> = b"PASAW001".to_vec();
        buf.extend(2u32.to_le_bytes());
        for (name, dims, vals) in [
            ("a", vec![2u32, 3u32], vec![1f32, 2., 3., 4., 5., 6.]),
            ("b", vec![2u32], vec![7f32, 8.]),
        ] {
            buf.extend((name.len() as u32).to_le_bytes());
            buf.extend(name.as_bytes());
            buf.extend((dims.len() as u32).to_le_bytes());
            for d in &dims {
                buf.extend(d.to_le_bytes());
            }
            for v in &vals {
                buf.extend(v.to_le_bytes());
            }
        }
        std::fs::write(path, buf).unwrap();
    }

    #[test]
    fn round_trip() {
        let p = std::env::temp_dir().join("pasa_weights_test.bin");
        fake_weights_file(&p);
        let w = Weights::load(&p).unwrap();
        assert_eq!(w.tensors.len(), 2);
        assert_eq!(w.get("a").unwrap().dims, vec![2, 3]);
        assert_eq!(w.get("b").unwrap().data, vec![7.0, 8.0]);
        w.check_against(&[
            ("a".into(), vec![2, 3]),
            ("b".into(), vec![2]),
        ])
        .unwrap();
        assert!(w
            .check_against(&[("a".into(), vec![3, 2]), ("b".into(), vec![2])])
            .is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let p = std::env::temp_dir().join("pasa_weights_bad.bin");
        std::fs::write(&p, b"NOTMAGIC").unwrap();
        assert!(Weights::load(&p).is_err());
    }
}
