//! Serving-model configuration, parsed from `artifacts/manifest.txt`
//! (the L2 AOT pipeline's contract — see python/compile/aot.py).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One exported HLO module.
#[derive(Clone, Debug)]
pub struct ModuleEntry {
    pub name: String,
    pub path: PathBuf,
    /// "prefill" | "decode" | "head".
    pub kind: String,
    /// "pasa" | "fa16_32" | "fa32".
    pub attention: String,
    pub attrs: HashMap<String, i64>,
}

/// Model architecture constants (mirror of python ModelConfig).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub prefill_seq: usize,
    pub decode_batch: usize,
    pub pad: u32,
    pub bos: u32,
    pub eos: u32,
}

impl ModelDims {
    pub fn head_width(&self) -> usize {
        self.n_heads * self.d_head
    }
}

/// Parsed manifest: modules, parameter inventory, dims.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub modules: Vec<ModuleEntry>,
    /// (name, dims) in the canonical parameter order.
    pub params: Vec<(String, Vec<usize>)>,
    pub dims: ModelDims,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut modules = Vec::new();
        let mut params = Vec::new();
        let mut config: HashMap<String, i64> = HashMap::new();
        for line in text.lines() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts.first() {
                Some(&"module") => {
                    if parts.len() < 4 {
                        bail!("bad module line: {line}");
                    }
                    let mut attrs = HashMap::new();
                    let mut kind = String::new();
                    let mut attention = String::new();
                    for kv in &parts[3..] {
                        let (k, v) = kv
                            .split_once('=')
                            .ok_or_else(|| anyhow!("bad attr {kv}"))?;
                        match k {
                            "kind" => kind = v.to_string(),
                            "attention" => attention = v.to_string(),
                            _ => {
                                attrs.insert(k.to_string(), v.parse()?);
                            }
                        }
                    }
                    modules.push(ModuleEntry {
                        name: parts[1].to_string(),
                        path: dir.join(parts[2]),
                        kind,
                        attention,
                        attrs,
                    });
                }
                Some(&"param") => {
                    let dims = if parts[2] == "scalar" {
                        vec![]
                    } else {
                        parts[2]
                            .split('x')
                            .map(|d| d.parse().map_err(|e| anyhow!("bad dim {d}: {e}")))
                            .collect::<Result<Vec<usize>>>()?
                    };
                    params.push((parts[1].to_string(), dims));
                }
                Some(&"config") => {
                    for kv in &parts[1..] {
                        let (k, v) = kv
                            .split_once('=')
                            .ok_or_else(|| anyhow!("bad config attr {kv}"))?;
                        config.insert(k.to_string(), v.parse()?);
                    }
                }
                _ => {}
            }
        }
        let get = |k: &str| -> Result<usize> {
            Ok(*config.get(k).ok_or_else(|| anyhow!("config missing {k}"))? as usize)
        };
        let dims = ModelDims {
            vocab_size: get("vocab_size")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_head: get("d_head")?,
            d_ff: get("d_ff")?,
            max_seq: get("max_seq")?,
            prefill_seq: get("prefill_seq")?,
            decode_batch: get("decode_batch")?,
            pad: get("pad")? as u32,
            bos: get("bos")? as u32,
            eos: get("eos")? as u32,
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            modules,
            params,
            dims,
        })
    }

    /// Find a module by kind + attention allocation.
    pub fn module(&self, kind: &str, attention: &str) -> Result<&ModuleEntry> {
        self.modules
            .iter()
            .find(|m| m.kind == kind && m.attention == attention)
            .ok_or_else(|| anyhow!("no module kind={kind} attention={attention}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::write(
            dir.join("manifest.txt"),
            "module prefill_pasa prefill_pasa.hlo.txt kind=prefill attention=pasa batch=1 seq=256 maxseq=512\n\
             module decode_pasa decode_pasa.hlo.txt kind=decode attention=pasa batch=4 maxseq=512\n\
             param tok_emb 259x256\n\
             param lnf_g 256\n\
             config vocab_size=259 d_model=256 n_layers=4 n_heads=8 d_head=32 d_ff=1024 \
             max_seq=512 prefill_seq=256 decode_batch=4 pad=256 bos=257 eos=258\n",
        )
        .unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("pasa_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.modules.len(), 2);
        assert_eq!(m.params[0].0, "tok_emb");
        assert_eq!(m.params[0].1, vec![259, 256]);
        assert_eq!(m.params[1].1, vec![256]);
        assert_eq!(m.dims.decode_batch, 4);
        assert_eq!(m.dims.bos, 257);
        let e = m.module("decode", "pasa").unwrap();
        assert_eq!(e.attrs["batch"], 4);
        assert!(m.module("decode", "fa8").is_err());
    }
}
