//! Bit-exact software bfloat16 (BF16).
//!
//! The paper's Table 1 lists BF16 with precision 2^-8 = 3.906e-3 and the
//! same overflow boundary as FP32 (3.4e38). Algorithm 1 notes that BF16
//! inputs are converted to FP16 for PASA to keep the optimal accuracy;
//! we still need BF16 itself to (a) regenerate Table 1 and (b) emulate the
//! `tp = BF16` branch of the `fl_tp(.)` operator in Appendix A.

/// Unit roundoff for bfloat16, 2^-8.
pub const BF16_EPS: f32 = 3.90625e-3;

/// Convert an `f32` to bfloat16 bits with RTNE.
pub fn f32_to_bf16_bits(f: f32) -> u16 {
    let x = f.to_bits();
    if f.is_nan() {
        // Quiet the NaN, keep the sign.
        return ((x >> 16) as u16) | 0x0040;
    }
    let keep = x >> 16;
    let rem = x & 0xffff;
    let half = 0x8000u32;
    let rounded = if rem > half || (rem == half && keep & 1 == 1) {
        keep + 1 // may carry into the exponent — that is correct RTNE
    } else {
        keep
    };
    rounded as u16
}

/// Convert bfloat16 bits to `f32` (exact).
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Round an `f32` to the nearest bfloat16 value, returned as `f32`.
#[inline]
pub fn round_bf16(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

/// `fl_BF16` from f64 (single rounding: f64 -> bf16 directly).
pub fn fl_bf16_f64(x: f64) -> f64 {
    if x.is_nan() {
        return f64::NAN;
    }
    // bf16 has 8 mantissa bits; f64 -> f32 -> bf16 can double-round only if
    // the f64 value is within 2^-29 ulp of a bf16 tie — we do it directly.
    let bits = x.to_bits();
    let sign = ((bits >> 48) & 0x8000) as u16;
    let abs = x.abs();
    if abs.is_infinite() {
        return x;
    }
    if abs >= 3.3961775292304957e38 {
        // >= (2 - 2^-9) * 2^127 rounds to inf
        return if sign != 0 {
            f64::NEG_INFINITY
        } else {
            f64::INFINITY
        };
    }
    if abs == 0.0 {
        return x;
    }
    let exp = (bits >> 52 & 0x7ff) as i32 - 1023;
    if exp < -133 {
        // below half the smallest subnormal (2^-133 ties to 0/min-sub)
        let min_sub = 2f64.powi(-133);
        let m = (abs / min_sub).round_ties_even();
        return m * min_sub * if sign != 0 { -1.0 } else { 1.0 };
    }
    if exp < -126 {
        // subnormal bf16: quantum 2^-133
        let q = 2f64.powi(-133);
        let m = (abs / q).round_ties_even();
        return m * q * if sign != 0 { -1.0 } else { 1.0 };
    }
    // normal: quantum 2^(exp-7)
    let q = 2f64.powi(exp - 7);
    let m = (abs / q).round_ties_even();
    let v = m * q;
    if v >= 3.402823669209385e38 * 1.0000001 {
        return if sign != 0 {
            f64::NEG_INFINITY
        } else {
            f64::INFINITY
        };
    }
    v * if sign != 0 { -1.0 } else { 1.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_exact_values() {
        let two127 = 2f32.powi(127);
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 128.0, two127, -two127] {
            assert_eq!(round_bf16(v), v, "v={v}");
        }
    }

    #[test]
    fn table1_bf16_precision() {
        // Paper Table 1: BF16 precision 3.906e-3, overflow boundary 3.4e38
        // (bf16 max = 0x7f7f = 3.3895e38; f32::MAX rounds *up* to inf).
        assert!((BF16_EPS - 2f32.powi(-8)).abs() < 1e-12);
        assert_eq!(round_bf16(1.0 + 2f32.powi(-9)), 1.0); // half-ulp absorbed
        let bf16_max = bf16_bits_to_f32(0x7f7f);
        assert!((bf16_max - 3.3895314e38).abs() < 1e31);
        assert_eq!(round_bf16(bf16_max), bf16_max);
        assert!(round_bf16(f32::MAX).is_infinite()); // RTNE carries past max
        assert!(round_bf16(3.39e38) >= bf16_max);
    }

    #[test]
    fn rtne_tie_behaviour() {
        // 1 + 2^-9 ties between 1.0 (even mant) and 1 + 2^-8.
        assert_eq!(round_bf16(1.0 + 2f32.powi(-9)), 1.0);
        let odd = 1.0 + 2f32.powi(-8);
        assert_eq!(round_bf16(odd + 2f32.powi(-9)), 1.0 + 2.0 * 2f32.powi(-8));
    }

    #[test]
    fn carry_into_exponent() {
        // Rounding 1.9999... up must carry cleanly to 2.0.
        assert_eq!(round_bf16(1.999999), 2.0);
    }

    #[test]
    fn f64_direct_matches_f32_path_generically() {
        for i in 1..2000 {
            let v = (i as f64) * 0.37 - 350.0;
            assert_eq!(fl_bf16_f64(v) as f32, round_bf16(v as f32), "v={v}");
        }
    }

    #[test]
    fn nan_and_inf() {
        assert!(round_bf16(f32::NAN).is_nan());
        assert!(round_bf16(f32::INFINITY).is_infinite());
        assert!(fl_bf16_f64(f64::INFINITY).is_infinite());
    }
}
