//! Bit-exact software IEEE 754 binary16 ("half", FP16).
//!
//! The paper's entire numerical study is about FP16 rounding and overflow
//! (Table 1: precision 4.88e-4, overflow boundary 65504). The offline crate
//! registry has no `half`, so we implement binary16 from scratch:
//!
//! * `f32 -> f16` conversion with round-to-nearest-even (RTNE), overflow to
//!   +/-inf, gradual underflow to subnormals,
//! * `f64 -> f16` direct conversion (single rounding — no double-rounding
//!   through f32), used for the paper's `fl_tp(.)` operator in Eq. (21),
//! * arithmetic via f32: by Rump's precision-inheritance result, binary16
//!   (p=11) add/sub/mul/div/sqrt computed in binary32 (p=24 >= 2*11+2) and
//!   rounded once back to binary16 are *correctly rounded*, so the f32
//!   round-trip emulation is bit-exact w.r.t. IEEE hardware.
//!
//! The attention lab (`crate::tensor`, `crate::attention`) stores "FP16"
//! values as f32 that are exactly representable in binary16 and re-rounds
//! after every operation via [`round_f16`]; this module is the ground truth
//! for that rounding.

/// Smallest positive normal binary16 value, 2^-14.
pub const F16_MIN_POSITIVE: f32 = 6.103515625e-5;
/// Largest finite binary16 value (the paper's overflow boundary).
pub const F16_MAX: f32 = 65504.0;
/// Unit roundoff u = 2^-11 for binary16; the paper's Table 1 lists the
/// machine epsilon 2^-11 = 4.88e-4.
pub const F16_EPS: f32 = 4.8828125e-4;

/// RTNE right-shift of `v` by `s` bits (guard/round/sticky collapsed).
#[inline]
fn round_shift_rtne_u32(v: u32, s: u32) -> u32 {
    if s == 0 {
        return v;
    }
    if s > 31 {
        return 0;
    }
    let keep = v >> s;
    let rem = v & ((1u32 << s) - 1);
    let half = 1u32 << (s - 1);
    if rem > half || (rem == half && keep & 1 == 1) {
        keep + 1
    } else {
        keep
    }
}

#[inline]
fn round_shift_rtne_u64(v: u64, s: u32) -> u64 {
    if s == 0 {
        return v;
    }
    if s > 63 {
        // Only the sticky information survives; anything nonzero rounds to
        // zero magnitude here because half-ulp can't be reached.
        return 0;
    }
    let keep = v >> s;
    let rem = v & ((1u64 << s) - 1);
    let half = 1u64 << (s - 1);
    if rem > half || (rem == half && keep & 1 == 1) {
        keep + 1
    } else {
        keep
    }
}

/// Convert an `f32` to binary16 bits with IEEE RTNE semantics.
pub fn f32_to_f16_bits(f: f32) -> u16 {
    let x = f.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let abs = x & 0x7fff_ffff;

    if abs >= 0x7f80_0000 {
        // Inf stays inf; NaN becomes a quiet NaN with payload preserved-ish.
        return if abs > 0x7f80_0000 {
            sign | 0x7e00
        } else {
            sign | 0x7c00
        };
    }
    if abs >= 0x4780_0000 {
        // >= 65536: magnitude beyond any finite half value -> inf.
        return sign | 0x7c00;
    }
    if abs < 0x3880_0000 {
        // < 2^-14: subnormal range (or zero).
        if abs < 0x3300_0000 {
            // < 2^-25: rounds to zero (2^-25 exactly ties to even = 0).
            if abs == 0x3300_0000 {
                return sign; // unreachable (covered by <), kept for clarity
            }
            return sign;
        }
        let exp = (abs >> 23) as i32; // biased f32 exponent, 102..=112
        let mant = (abs & 0x7f_ffff) | 0x80_0000;
        // target subnormal integer m = round(value * 2^24) = round(mant * 2^(exp-126))
        let s = (126 - exp) as u32; // 14..=24
        let m = round_shift_rtne_u32(mant, s);
        // m can round up to 0x400 = smallest normal; the bit pattern is
        // then exactly exponent-field 1, mantissa 0 — still correct.
        return sign | m as u16;
    }
    // Normal range.
    let mut e = ((abs >> 23) as i32) - 127 + 15; // 1..=30 before rounding
    let mut m = round_shift_rtne_u32((abs & 0x7f_ffff) | 0x80_0000, 13); // in [0x400, 0x800]
    if m >= 0x800 {
        m >>= 1;
        e += 1;
    }
    if e >= 31 {
        return sign | 0x7c00;
    }
    sign | ((e as u16) << 10) | (m as u16 & 0x3ff)
}

/// Convert an `f64` to binary16 bits with a *single* RTNE rounding.
///
/// This is the paper's `fl_tp(.)` (Eq. 21) for tp = FP16: the optimal
/// accuracy condition rounds FP64 quantities like `beta/n` directly to FP16.
/// Going through f32 first could double-round; this path cannot.
pub fn f64_to_f16_bits(f: f64) -> u16 {
    let x = f.to_bits();
    let sign = ((x >> 48) & 0x8000) as u16;
    let abs = x & 0x7fff_ffff_ffff_ffff;

    if abs >= 0x7ff0_0000_0000_0000 {
        return if abs > 0x7ff0_0000_0000_0000 {
            sign | 0x7e00
        } else {
            sign | 0x7c00
        };
    }
    // 65536.0 in f64 bits:
    if abs >= 0x40f0_0000_0000_0000 {
        return sign | 0x7c00;
    }
    // 2^-14 in f64: biased exponent 1023-14 = 1009 -> 0x3f10...
    if abs < 0x3f10_0000_0000_0000 {
        // subnormal half (or zero); 2^-25 threshold: biased 1023-25 = 998
        if abs <= 0x3e60_0000_0000_0000 {
            // <= 2^-25: 2^-25 exactly ties to even (0); below flushes to 0.
            return sign;
        }
        let exp = (abs >> 52) as i32; // biased, 999..=1008
        let mant = (abs & 0xf_ffff_ffff_ffff) | 0x10_0000_0000_0000;
        // m = round(value * 2^24) = round(mant * 2^(exp - 1023 - 52 + 24))
        let s = (1051 - exp) as u32; // 43..=52
        let m = round_shift_rtne_u64(mant, s);
        return sign | m as u16;
    }
    let mut e = ((abs >> 52) as i32) - 1023 + 15;
    let mut m = round_shift_rtne_u64((abs & 0xf_ffff_ffff_ffff) | 0x10_0000_0000_0000, 42) as u32;
    if m >= 0x800 {
        m >>= 1;
        e += 1;
    }
    if e >= 31 {
        return sign | 0x7c00;
    }
    sign | ((e as u16) << 10) | (m as u16 & 0x3ff)
}

/// Convert binary16 bits to `f32` (exact — every half value is an f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = (h & 0x8000) as u32;
    let exp = (h >> 10) & 0x1f;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign << 16
        } else {
            // Subnormal: value = mant * 2^-24 (exact in f32).
            return (mant as f32) * f32::from_bits(0x3380_0000) * sign_mul(sign);
        }
    } else if exp == 0x1f {
        (sign << 16) | 0x7f80_0000 | (mant << 13)
    } else {
        (sign << 16) | (((exp as u32) + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[inline]
fn sign_mul(sign_bit: u32) -> f32 {
    if sign_bit != 0 {
        -1.0
    } else {
        1.0
    }
}

/// Round an `f32` to the nearest binary16 value, returned as `f32`.
///
/// This is the workhorse of the attention lab: "FP16 storage" is emulated
/// as f32 values on the binary16 grid, re-rounded after every operation.
/// Overflow saturates to +/-inf exactly like a hardware FP16 unit.
#[inline]
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// `fl_FP16` from f64, returned as f64 (exact embedding).
#[inline]
pub fn fl_f16_f64(x: f64) -> f64 {
    f16_bits_to_f32(f64_to_f16_bits(x)) as f64
}

/// A binary16 value as a bit pattern — used where bit-exactness matters
/// (tests, Table 1 constants, the beta solver).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3c00);
    pub const MAX: F16 = F16(0x7bff); // 65504
    pub const MIN_POSITIVE: F16 = F16(0x0400); // 2^-14
    pub const MIN_SUBNORMAL: F16 = F16(0x0001); // 2^-24
    pub const INFINITY: F16 = F16(0x7c00);
    pub const NEG_INFINITY: F16 = F16(0xfc00);
    pub const NAN: F16 = F16(0x7e00);

    #[inline]
    pub fn from_f32(x: f32) -> F16 {
        F16(f32_to_f16_bits(x))
    }
    #[inline]
    pub fn from_f64(x: f64) -> F16 {
        F16(f64_to_f16_bits(x))
    }
    #[inline]
    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }
    #[inline]
    pub fn to_f64(self) -> f64 {
        self.to_f32() as f64
    }
    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7c00) == 0x7c00 && (self.0 & 0x3ff) != 0
    }
    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7fff) == 0x7c00
    }
    #[inline]
    pub fn is_finite(self) -> bool {
        (self.0 & 0x7c00) != 0x7c00
    }
    /// Correctly-rounded binary16 ops via f32 (see module docs).
    #[inline]
    pub fn add(self, o: F16) -> F16 {
        F16::from_f32(self.to_f32() + o.to_f32())
    }
    #[inline]
    pub fn sub(self, o: F16) -> F16 {
        F16::from_f32(self.to_f32() - o.to_f32())
    }
    #[inline]
    pub fn mul(self, o: F16) -> F16 {
        F16::from_f32(self.to_f32() * o.to_f32())
    }
    #[inline]
    pub fn div(self, o: F16) -> F16 {
        F16::from_f32(self.to_f32() / o.to_f32())
    }
}

impl std::fmt::Display for F16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_f32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_constants_round_trip() {
        assert_eq!(F16::ONE.to_f32(), 1.0);
        assert_eq!(F16::MAX.to_f32(), 65504.0);
        assert_eq!(F16::MIN_POSITIVE.to_f32(), 6.103515625e-5);
        assert_eq!(F16::MIN_SUBNORMAL.to_f32(), 5.960464477539063e-8);
        assert!(F16::NAN.is_nan());
        assert!(F16::INFINITY.is_infinite());
    }

    #[test]
    fn table1_overflow_boundary() {
        // Paper Table 1: FP16 overflow boundary 65504, precision 4.88e-4.
        assert_eq!(round_f16(65504.0), 65504.0);
        assert_eq!(round_f16(65519.9), 65504.0); // below the tie: stays finite
        assert!(round_f16(65520.0).is_infinite()); // tie rounds to even=2^16 -> inf
        assert!(round_f16(70000.0).is_infinite());
        assert!(round_f16(-70000.0).is_infinite());
        assert!((F16_EPS - 2f32.powi(-11)).abs() < 1e-12);
    }

    #[test]
    fn rtne_ties() {
        // 1.0 + eps/2 = 1.00024414... exactly halfway between 1.0 and 1.0+2^-10:
        let half_ulp = 2f32.powi(-11);
        assert_eq!(round_f16(1.0 + half_ulp), 1.0); // ties to even (mant 0)
        let x = 1.0 + 2f32.powi(-10); // next half value, odd mantissa
        assert_eq!(round_f16(x + half_ulp), 1.0 + 2.0 * 2f32.powi(-10)); // ties up to even
        assert_eq!(round_f16(x + 0.49 * half_ulp), x);
    }

    #[test]
    fn subnormals() {
        let min_sub = 2f32.powi(-24);
        assert_eq!(round_f16(min_sub), min_sub);
        assert_eq!(round_f16(min_sub * 0.49), 0.0);
        assert_eq!(round_f16(min_sub * 0.5), 0.0); // tie to even (0)
        assert_eq!(round_f16(min_sub * 0.51), min_sub);
        assert_eq!(round_f16(min_sub * 1.5), 2.0 * min_sub); // tie to even (2)
        // Largest subnormal:
        let max_sub = 1023.0 * 2f32.powi(-24);
        assert_eq!(round_f16(max_sub), max_sub);
        assert_eq!(f32_to_f16_bits(max_sub), 0x03ff);
        // Rounding up across the subnormal/normal boundary:
        assert_eq!(f32_to_f16_bits(max_sub + 2f32.powi(-25)), 0x0400);
    }

    #[test]
    fn exhaustive_f16_f32_round_trip() {
        // Every finite half value must round-trip bit-exactly through f32.
        for bits in 0u16..=0xffff {
            let h = F16(bits);
            if h.is_nan() {
                assert!(F16::from_f32(h.to_f32()).is_nan());
                continue;
            }
            assert_eq!(F16::from_f32(h.to_f32()).0, bits, "bits {bits:#06x}");
        }
    }

    #[test]
    fn f64_direct_conversion_matches_f32_on_grid() {
        // On values where no double rounding can occur, f64->f16 must agree
        // with f32->f16.
        for bits in (0u16..=0x7bff).step_by(7) {
            let v = F16(bits).to_f32() as f64 * 1.0000001;
            let a = f64_to_f16_bits(v);
            let b = f32_to_f16_bits(v as f32);
            assert_eq!(a, b, "v={v}");
        }
    }

    #[test]
    fn f64_single_rounding_beats_double_rounding() {
        // Construct a value exactly halfway (in f64) between an f16 tie
        // boundary region where f64->f32 rounds up onto the tie and then
        // f32->f16 would tie-to-even differently than direct rounding.
        // 1 + 2^-11 is the f16 tie; pick x slightly below in f64:
        let x = 1.0f64 + 2f64.powi(-11) - 2f64.powi(-40);
        // Direct: below tie -> 1.0
        assert_eq!(fl_f16_f64(x), 1.0);
        // (f32 path also gets this right since 2^-40 survives in f32's 24
        // bits relative to 1.0? No: 1+2^-11-2^-40 rounds in f32 to 1+2^-11
        // exactly — the tie — then ties-to-even to 1.0. Same answer here,
        // but the direct path never depended on that luck.)
        let via_f32 = round_f16((x as f32) as f32);
        assert_eq!(via_f32, 1.0);
    }

    #[test]
    fn arithmetic_is_rounded() {
        // 1 + 2^-11 in f16 arithmetic must give exactly 1 (absorbed).
        assert_eq!(F16::ONE.add(F16::from_f32(4.8828125e-4)), F16::ONE);
        // 255.875 is representable; 256 + 0.0625 is not (ulp at 256 is 0.25).
        let a = F16::from_f32(256.0);
        let b = F16::from_f32(0.0625);
        assert_eq!(a.add(b).to_f32(), 256.0);
        // Overflow in multiply -> inf.
        let big = F16::from_f32(300.0);
        assert!(big.mul(big).is_infinite());
    }

    #[test]
    fn paper_beta_constants_exact_in_f16() {
        // Appendix A: 0.9375, 0.96875, 0.984375 are exactly representable.
        for &b in &[0.9375f32, 0.96875, 0.984375] {
            assert_eq!(round_f16(b), b);
        }
        // 0.9 is NOT exactly representable:
        assert_ne!(round_f16(0.9), 0.9);
    }
}
