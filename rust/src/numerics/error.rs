//! Error metrics for the numerical studies.
//!
//! The paper's accuracy metric is the relative root-mean-square error,
//! Eq. (19): RMSE = ||O_computed - O_golden||_2 / ||O_golden||_2, plus the
//! overflow metric "did INF/NaN appear" and the NaN percentage of Table 4.

/// Relative RMSE per the paper's Eq. (19). Returns `f64::NAN` if either
/// input contains non-finite values (an overflowed run has no RMSE — the
/// paper plots a "NAN" text marker instead).
pub fn relative_rmse(computed: &[f32], golden: &[f32]) -> f64 {
    assert_eq!(computed.len(), golden.len(), "shape mismatch in RMSE");
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&c, &g) in computed.iter().zip(golden) {
        if !c.is_finite() || !g.is_finite() {
            return f64::NAN;
        }
        let d = c as f64 - g as f64;
        num += d * d;
        den += (g as f64) * (g as f64);
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

/// Fraction of NaN elements, as a percentage (Table 4's "NAN PERCENTAGE").
pub fn nan_percentage(v: &[f32]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let n = v.iter().filter(|x| x.is_nan()).count();
    100.0 * n as f64 / v.len() as f64
}

/// Fraction of non-finite (NaN or inf) elements, as a percentage.
pub fn nonfinite_percentage(v: &[f32]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let n = v.iter().filter(|x| !x.is_finite()).count();
    100.0 * n as f64 / v.len() as f64
}

/// True if any element overflowed to inf or NaN — the paper's overflow
/// detector ("whether the matmul result exceeds 65504").
pub fn has_overflow(v: &[f32]) -> bool {
    v.iter().any(|x| !x.is_finite())
}

/// Max absolute value over a slice, ignoring non-finite entries.
pub fn max_abs(v: &[f32]) -> f32 {
    v.iter()
        .filter(|x| x.is_finite())
        .fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// (min, max) over finite entries — used for the Fig. 11–14 range reports.
pub fn finite_range(v: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in v {
        if x.is_finite() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
    }
    (lo, hi)
}

/// Mean over finite entries.
pub fn finite_mean(v: &[f32]) -> f64 {
    let mut s = 0.0f64;
    let mut n = 0usize;
    for &x in v {
        if x.is_finite() {
            s += x as f64;
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        s / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_basics() {
        let g = [1.0f32, 2.0, 3.0];
        assert_eq!(relative_rmse(&g, &g), 0.0);
        let c = [1.1f32, 2.0, 3.0];
        let e = relative_rmse(&c, &g);
        // (1.1f32 − 1.0) carries f32 representation error ~1.5e-8.
        let expect = (0.01f64 / 14.0).sqrt();
        assert!((e - expect).abs() < 1e-8);
    }

    #[test]
    fn rmse_nan_on_overflow() {
        let g = [1.0f32, 2.0];
        assert!(relative_rmse(&[f32::INFINITY, 2.0], &g).is_nan());
        assert!(relative_rmse(&[f32::NAN, 2.0], &g).is_nan());
    }

    #[test]
    fn nan_pct() {
        let v = [1.0f32, f32::NAN, 3.0, f32::NAN];
        assert_eq!(nan_percentage(&v), 50.0);
        assert_eq!(nonfinite_percentage(&[f32::INFINITY, 1.0]), 50.0);
        assert!(!has_overflow(&[1.0, 2.0]));
        assert!(has_overflow(&[1.0, f32::INFINITY]));
    }

    #[test]
    fn ranges() {
        let v = [-3.0f32, 7.0, f32::NAN, 1.0];
        assert_eq!(finite_range(&v), (-3.0, 7.0));
        assert_eq!(max_abs(&v), 7.0);
        assert!((finite_mean(&v) - 5.0 / 3.0).abs() < 1e-9);
    }
}
