//! Precision dispatch: the `fl_tp(.)` rounding operator over data formats.
//!
//! The attention lab emulates each precision allocation of Figs. 1–3 by
//! re-rounding intermediate values to the storage format after every
//! operation. `Format` enumerates the paper's Table 1 rows.
//!
//! ## Monomorphized rounding
//!
//! [`Format::round`] is a 4-way `match` per element — fine for scalar call
//! sites, but inside the GEMM/vector-op inner loops the dispatch used to
//! be re-decided per element. The hot kernels now pick a [`RoundSpec`]
//! **once per call** via [`crate::mono_format!`] and run a monomorphized
//! loop whose rounding call inlines to the underlying bitwise conversion
//! (`round_f16` / `round_bf16` / `round_f8e4m3` are all pure bit
//! manipulation; `F32` rounding compiles to the identity).

use super::bf16::{fl_bf16_f64, round_bf16};
use super::f16::{fl_f16_f64, round_f16};

/// Floating-point data formats of the paper's Table 1.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Format {
    /// IEEE binary16 — precision 4.88e-4, overflow at 65504.
    F16,
    /// bfloat16 — precision 3.906e-3, overflow at 3.4e38.
    Bf16,
    /// IEEE binary32 — precision 5.96e-8, overflow at 3.4e38.
    F32,
    /// 1-4-3 FP8 (E4M3) — precision 6.25e-2, overflow at 448. Included to
    /// regenerate Table 1 and for the FP8 future-work extension bench.
    F8E4M3,
}

impl Format {
    /// Unit roundoff (the paper's "Precision" column of Table 1).
    pub fn eps(self) -> f64 {
        match self {
            Format::F16 => 2f64.powi(-11),
            Format::Bf16 => 2f64.powi(-8),
            Format::F32 => 2f64.powi(-24),
            Format::F8E4M3 => 2f64.powi(-4),
        }
    }

    /// Largest finite value (the paper's "Overflow Boundary" column).
    pub fn overflow_boundary(self) -> f64 {
        match self {
            Format::F16 => 65504.0,
            Format::Bf16 => 3.3895313892515355e38,
            Format::F32 => f32::MAX as f64,
            Format::F8E4M3 => 448.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Format::F16 => "FP16",
            Format::Bf16 => "BF16",
            Format::F32 => "FP32",
            Format::F8E4M3 => "FP8",
        }
    }

    /// Round an f32 onto this format's grid (identity for F32).
    #[inline]
    pub fn round(self, x: f32) -> f32 {
        match self {
            Format::F16 => round_f16(x),
            Format::Bf16 => round_bf16(x),
            Format::F32 => x,
            Format::F8E4M3 => round_f8e4m3(x),
        }
    }

    /// Round a whole slice in place with the format branch taken once —
    /// the bulk-storage path ([`crate::tensor::Matrix::round_to`]).
    pub fn round_slice(self, xs: &mut [f32]) {
        crate::mono_format!(self, R => {
            if !R::IS_IDENTITY {
                for x in xs.iter_mut() {
                    *x = R::round(*x);
                }
            }
        });
    }

    /// Single-rounding `fl_tp` from f64 (Appendix A, Eq. 21).
    #[inline]
    pub fn fl(self, x: f64) -> f64 {
        match self {
            Format::F16 => fl_f16_f64(x),
            Format::Bf16 => fl_bf16_f64(x),
            Format::F32 => x as f32 as f64,
            Format::F8E4M3 => round_f8e4m3(x as f32) as f64,
        }
    }
}

/// A compile-time rounding strategy: one implementor per [`Format`], so
/// inner loops can be monomorphized over the format instead of matching
/// per element. Instantiate via [`crate::mono_format!`].
pub trait RoundSpec {
    /// The format this spec rounds to.
    const FMT: Format;
    /// True only for [`RoundF32`] — lets loops skip a no-op rounding pass.
    const IS_IDENTITY: bool = false;
    fn round(x: f32) -> f32;

    /// Round a 4-lane panel — the vector-lane extension of the
    /// monomorphization, consumed by the SIMD GEMM microkernels
    /// ([`crate::tensor::simd`]). The default is per-lane scalar rounding,
    /// which makes lane-wise bit-identity to the scalar cores definitional:
    /// a vectorized core that stores through this hook cannot diverge from
    /// the scalar store rounding, whatever the format.
    #[inline(always)]
    fn round4(x: [f32; 4]) -> [f32; 4] {
        [
            Self::round(x[0]),
            Self::round(x[1]),
            Self::round(x[2]),
            Self::round(x[3]),
        ]
    }
}

/// Monomorphized [`Format::F16`] rounding.
pub struct RoundF16;
impl RoundSpec for RoundF16 {
    const FMT: Format = Format::F16;
    #[inline(always)]
    fn round(x: f32) -> f32 {
        round_f16(x)
    }
}

/// Monomorphized [`Format::Bf16`] rounding.
pub struct RoundBf16;
impl RoundSpec for RoundBf16 {
    const FMT: Format = Format::Bf16;
    #[inline(always)]
    fn round(x: f32) -> f32 {
        round_bf16(x)
    }
}

/// Monomorphized [`Format::F32`] rounding (the identity).
pub struct RoundF32;
impl RoundSpec for RoundF32 {
    const FMT: Format = Format::F32;
    const IS_IDENTITY: bool = true;
    #[inline(always)]
    fn round(x: f32) -> f32 {
        x
    }
}

/// Monomorphized [`Format::F8E4M3`] rounding.
pub struct RoundF8;
impl RoundSpec for RoundF8 {
    const FMT: Format = Format::F8E4M3;
    #[inline(always)]
    fn round(x: f32) -> f32 {
        round_f8e4m3(x)
    }
}

/// Expand `$body` once per [`Format`] with `$R` bound to the matching
/// [`RoundSpec`] type — the "choose the rounding branch once per call"
/// primitive of the hot kernels:
///
/// ```ignore
/// crate::mono_format!(fmt, R => rowsum_mono::<R>(m, out));
/// ```
#[macro_export]
macro_rules! mono_format {
    ($fmt:expr, $R:ident => $body:expr) => {
        match $fmt {
            $crate::numerics::Format::F16 => {
                type $R = $crate::numerics::round::RoundF16;
                $body
            }
            $crate::numerics::Format::Bf16 => {
                type $R = $crate::numerics::round::RoundBf16;
                $body
            }
            $crate::numerics::Format::F32 => {
                type $R = $crate::numerics::round::RoundF32;
                $body
            }
            $crate::numerics::Format::F8E4M3 => {
                type $R = $crate::numerics::round::RoundF8;
                $body
            }
        }
    };
}

/// RTNE right-shift (guard/round/sticky collapsed) — the same helper shape
/// as the binary16 converter's, for the 24-bit f32 significand.
#[inline]
fn round_shift_rtne_u32(v: u32, s: u32) -> u32 {
    if s == 0 {
        return v;
    }
    if s > 31 {
        return 0;
    }
    let keep = v >> s;
    let rem = v & ((1u32 << s) - 1);
    let half = 1u32 << (s - 1);
    if rem > half || (rem == half && keep & 1 == 1) {
        keep + 1
    } else {
        keep
    }
}

/// Convert an `f32` to FP8 E4M3FN bits (sign 1, exp 4 @ bias 7, mant 3)
/// with IEEE round-to-nearest-even — pure bit manipulation, no
/// transcendental calls (the old implementation paid a `log2().floor()`
/// plus `powi` per element).
///
/// E4M3FN encoding notes: there is no infinity; the all-ones pattern
/// `S.1111.111` is the single NaN. Values that round beyond the largest
/// finite magnitude 448 therefore become NaN — but 464 (the exact RTNE
/// midpoint between 448 = `1110₂·2⁵` and the non-existent 480) ties *down*
/// to the even mantissa 448.
pub fn f32_to_f8e4m3_bits(f: f32) -> u8 {
    let x = f.to_bits();
    let sign = ((x >> 24) & 0x80) as u8;
    let abs = x & 0x7fff_ffff;

    if abs >= 0x7f80_0000 {
        // f32 inf/NaN: E4M3FN has no inf — both map to the NaN pattern.
        return sign | 0x7f;
    }
    if abs == 0 {
        return sign; // ±0 preserved
    }
    let e32 = ((abs >> 23) as i32) - 127;
    let mant24 = (abs & 0x7f_ffff) | 0x80_0000;
    if e32 < -6 {
        // Subnormal range: grid quantum 2^-9, target integer
        // m = round(value · 2^9) = RTNE-shift of the 24-bit significand.
        // value = mant24 · 2^(e32 − 23)  ⇒  shift = 23 − 9 − e32.
        let s = (14 - e32) as u32;
        let m = round_shift_rtne_u32(mant24, s);
        // m can round up to 8 = smallest normal (exp field 1, mant 0) —
        // the bit pattern is then exactly right, as in the f16 converter.
        return sign | m as u8;
    }
    // Normal range: keep 4 significand bits (1 hidden + 3 stored).
    let mut e8 = e32 + 7;
    let mut m = round_shift_rtne_u32(mant24, 20); // in [0x8, 0x10]
    if m >= 0x10 {
        m >>= 1;
        e8 += 1;
    }
    if e8 > 15 || (e8 == 15 && (m & 7) == 7) {
        // Past the largest finite 448 (= exp 15, mant 6): the would-be
        // exp-15/mant-7 code is NaN in E4M3FN ⇒ overflow saturates to NaN.
        return sign | 0x7f;
    }
    sign | ((e8 as u8) << 3) | (m as u8 & 7)
}

/// Convert E4M3FN bits to `f32` (exact — every E4M3 value is an f32).
pub fn f8e4m3_bits_to_f32(b: u8) -> f32 {
    if (b & 0x7f) == 0x7f {
        return f32::NAN;
    }
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = (b >> 3) & 0x0f;
    let mant = (b & 7) as f32;
    if exp == 0 {
        // Subnormal: value = mant · 2^-9 (exact in f32).
        sign * mant * 2f32.powi(-9)
    } else {
        sign * (8.0 + mant) * 2f32.powi(exp as i32 - 10) // (1 + m/8)·2^(e−7)
    }
}

/// Round to FP8 E4M3FN (OCP spec: bias 7, max 448, no inf; overflow maps
/// to the NaN pattern). Bitwise RTNE — see [`f32_to_f8e4m3_bits`].
#[inline]
pub fn round_f8e4m3(x: f32) -> f32 {
    f8e4m3_bits_to_f32(f32_to_f8e4m3_bits(x))
}

/// The 256-entry E4M3FN decode table — the bulk-dequantization path of the
/// byte-backed KV cache (`KvStore::E4m3`): one table load per gathered
/// element instead of the bit-decode arithmetic. Entry `b` is exactly
/// [`f8e4m3_bits_to_f32`]`(b)`, so table and scalar decode cannot diverge.
pub fn f8e4m3_decode_table() -> &'static [f32; 256] {
    static TABLE: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0.0f32; 256];
        for (b, slot) in t.iter_mut().enumerate() {
            *slot = f8e4m3_bits_to_f32(b as u8);
        }
        t
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_rows() {
        // The exact rows of the paper's Table 1.
        assert!((Format::F8E4M3.eps() - 6.25e-2).abs() < 1e-12);
        assert_eq!(Format::F8E4M3.overflow_boundary(), 448.0);
        assert!((Format::F16.eps() - 4.88e-4).abs() < 1e-6);
        assert_eq!(Format::F16.overflow_boundary(), 65504.0);
        assert!((Format::Bf16.eps() - 3.906e-3).abs() < 1e-6);
        assert!(Format::Bf16.overflow_boundary() > 3.38e38);
        assert!((Format::F32.eps() - 5.96e-8).abs() < 1e-10);
        assert!(Format::F32.overflow_boundary() > 3.4e38);
    }

    #[test]
    fn f8_grid() {
        assert_eq!(round_f8e4m3(448.0), 448.0);
        assert_eq!(round_f8e4m3(1.0), 1.0);
        assert_eq!(round_f8e4m3(1.05), 1.0); // ulp at 1.0 is 0.125
        assert_eq!(round_f8e4m3(1.07), 1.125);
        assert!(round_f8e4m3(500.0).is_nan());
        assert_eq!(round_f8e4m3(-448.0), -448.0);
    }

    /// Every signed E4M3FN grid point (all 256 bit patterns) must be a
    /// fixed point of the rounding, and the round-trip through f32 must be
    /// bit-exact — the exhaustive pin for the bitwise converter.
    #[test]
    fn f8_exhaustive_grid_round_trip() {
        for bits in 0u16..=0xff {
            let b = bits as u8;
            let v = f8e4m3_bits_to_f32(b);
            if (b & 0x7f) == 0x7f {
                assert!(v.is_nan(), "bits {b:#04x} must decode to NaN");
                assert_eq!(f32_to_f8e4m3_bits(v) & 0x7f, 0x7f);
                continue;
            }
            assert!(v.is_finite(), "bits {b:#04x}");
            let back = f32_to_f8e4m3_bits(v);
            // −0.0 and +0.0 keep their sign bit; everything else is exact.
            assert_eq!(back, b, "bits {b:#04x} (value {v})");
            assert_eq!(round_f8e4m3(v).to_bits(), v.to_bits(), "fixed point at {v}");
        }
    }

    /// Midpoints between adjacent grid values must tie to the even
    /// mantissa, and off-midpoints to the nearer neighbour — checked for
    /// every adjacent positive pair (normals and subnormals).
    #[test]
    fn f8_ties_to_even_between_all_neighbours() {
        // Positive finite grid, ascending: bits 0x00..=0x7e decode in
        // monotonically increasing order (sign-magnitude encoding).
        let grid: Vec<f32> = (0u8..=0x7e).map(f8e4m3_bits_to_f32).collect();
        for w in grid.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let mid = (lo as f64 + hi as f64) / 2.0;
            let lo_bits = f32_to_f8e4m3_bits(lo);
            let hi_bits = f32_to_f8e4m3_bits(hi);
            let even = if lo_bits & 1 == 0 { lo } else { hi };
            assert_eq!(
                round_f8e4m3(mid as f32),
                even,
                "midpoint {mid} between {lo} ({lo_bits:#04x}) and {hi} ({hi_bits:#04x})"
            );
            // Slightly off the midpoint rounds to the nearer value.
            let q = (hi - lo) as f64;
            assert_eq!(round_f8e4m3((mid - q / 16.0) as f32), lo, "below mid of [{lo},{hi}]");
            assert_eq!(round_f8e4m3((mid + q / 16.0) as f32), hi, "above mid of [{lo},{hi}]");
        }
    }

    #[test]
    fn f8_overflow_and_saturation_boundary() {
        // 464 is the midpoint between 448 (even mantissa 6) and the
        // non-existent 480: RTNE ties down to 448.
        assert_eq!(round_f8e4m3(464.0), 448.0);
        assert_eq!(round_f8e4m3(-464.0), -448.0);
        assert_eq!(round_f8e4m3(460.0), 448.0);
        // Anything beyond the midpoint overflows to NaN (E4M3FN).
        assert!(round_f8e4m3(464.0001).is_nan());
        assert!(round_f8e4m3(480.0).is_nan());
        assert!(round_f8e4m3(-1e30).is_nan());
        assert!(round_f8e4m3(f32::INFINITY).is_nan());
        assert!(round_f8e4m3(f32::NAN).is_nan());
    }

    #[test]
    fn f8_subnormals_and_underflow() {
        let min_sub = 2f32.powi(-9);
        assert_eq!(round_f8e4m3(min_sub), min_sub);
        assert_eq!(round_f8e4m3(min_sub * 0.49), 0.0);
        assert_eq!(round_f8e4m3(min_sub * 0.5), 0.0); // tie to even (0)
        assert_eq!(round_f8e4m3(min_sub * 0.51), min_sub);
        assert_eq!(round_f8e4m3(min_sub * 1.5), 2.0 * min_sub); // tie to even
        // Largest subnormal and the subnormal→normal rounding carry.
        let max_sub = 7.0 * 2f32.powi(-9);
        assert_eq!(round_f8e4m3(max_sub), max_sub);
        assert_eq!(f32_to_f8e4m3_bits(max_sub), 0x07);
        assert_eq!(f32_to_f8e4m3_bits(7.5 * 2f32.powi(-9)), 0x08); // ties up to 2^-6
        // Signed zero is preserved.
        assert_eq!(round_f8e4m3(-0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(round_f8e4m3(0.0).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn f32_identity() {
        for &v in &[1.0f32, 1e-30, 3.0e38, -7.25] {
            assert_eq!(Format::F32.round(v), v);
        }
    }

    #[test]
    fn round_slice_matches_scalar_round() {
        let src: Vec<f32> = (0..64).map(|i| (i as f32 - 31.5) * 17.3).collect();
        for fmt in [Format::F16, Format::Bf16, Format::F32, Format::F8E4M3] {
            let mut s = src.clone();
            fmt.round_slice(&mut s);
            for (a, &x) in s.iter().zip(&src) {
                let b = fmt.round(x);
                assert!(
                    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()),
                    "{} at {x}: {a} vs {b}",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn round4_is_per_lane_round_for_every_format() {
        // The vector-lane extension must be exactly per-lane scalar
        // rounding — including NaN-producing lanes (E4M3 overflow).
        let panel = [1.0471f32, -465.0, 70000.0, 2f32.powi(-9) * 1.5];
        for fmt in [Format::F16, Format::Bf16, Format::F32, Format::F8E4M3] {
            let lanes = crate::mono_format!(fmt, R => R::round4(panel));
            for (t, (&got, &x)) in lanes.iter().zip(&panel).enumerate() {
                let want = fmt.round(x);
                assert!(
                    got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
                    "{} lane {t}: {got} vs {want}",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn f8_decode_table_matches_scalar_decode() {
        let t = f8e4m3_decode_table();
        for b in 0u16..=0xff {
            let want = f8e4m3_bits_to_f32(b as u8);
            let got = t[b as usize];
            assert!(
                got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
                "byte {b:#04x}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn mono_format_binds_the_matching_spec() {
        for fmt in [Format::F16, Format::Bf16, Format::F32, Format::F8E4M3] {
            let bound = crate::mono_format!(fmt, R => R::FMT);
            assert_eq!(bound, fmt);
            let x = 1.0471f32;
            let r = crate::mono_format!(fmt, R => R::round(x));
            assert_eq!(r.to_bits(), fmt.round(x).to_bits(), "{}", fmt.name());
        }
    }
}
