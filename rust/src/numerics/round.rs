//! Precision dispatch: the `fl_tp(.)` rounding operator over data formats.
//!
//! The attention lab emulates each precision allocation of Figs. 1–3 by
//! re-rounding intermediate values to the storage format after every
//! operation. `Format` enumerates the paper's Table 1 rows.

use super::bf16::{fl_bf16_f64, round_bf16};
use super::f16::{fl_f16_f64, round_f16};

/// Floating-point data formats of the paper's Table 1.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Format {
    /// IEEE binary16 — precision 4.88e-4, overflow at 65504.
    F16,
    /// bfloat16 — precision 3.906e-3, overflow at 3.4e38.
    Bf16,
    /// IEEE binary32 — precision 5.96e-8, overflow at 3.4e38.
    F32,
    /// 1-4-3 FP8 (E4M3) — precision 6.25e-2, overflow at 448. Included to
    /// regenerate Table 1 and for the FP8 future-work extension bench.
    F8E4M3,
}

impl Format {
    /// Unit roundoff (the paper's "Precision" column of Table 1).
    pub fn eps(self) -> f64 {
        match self {
            Format::F16 => 2f64.powi(-11),
            Format::Bf16 => 2f64.powi(-8),
            Format::F32 => 2f64.powi(-24),
            Format::F8E4M3 => 2f64.powi(-4),
        }
    }

    /// Largest finite value (the paper's "Overflow Boundary" column).
    pub fn overflow_boundary(self) -> f64 {
        match self {
            Format::F16 => 65504.0,
            Format::Bf16 => 3.3895313892515355e38,
            Format::F32 => f32::MAX as f64,
            Format::F8E4M3 => 448.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Format::F16 => "FP16",
            Format::Bf16 => "BF16",
            Format::F32 => "FP32",
            Format::F8E4M3 => "FP8",
        }
    }

    /// Round an f32 onto this format's grid (identity for F32).
    #[inline]
    pub fn round(self, x: f32) -> f32 {
        match self {
            Format::F16 => round_f16(x),
            Format::Bf16 => round_bf16(x),
            Format::F32 => x,
            Format::F8E4M3 => round_f8e4m3(x),
        }
    }

    /// Single-rounding `fl_tp` from f64 (Appendix A, Eq. 21).
    #[inline]
    pub fn fl(self, x: f64) -> f64 {
        match self {
            Format::F16 => fl_f16_f64(x),
            Format::Bf16 => fl_bf16_f64(x),
            Format::F32 => x as f32 as f64,
            Format::F8E4M3 => round_f8e4m3(x as f32) as f64,
        }
    }
}

/// Round to FP8 E4M3 (OCP spec: bias 7, max 448, no inf — saturating NaN;
/// we map overflow to NaN like E4M3FN).
pub fn round_f8e4m3(x: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    if x == 0.0 {
        return x;
    }
    let sign = if x < 0.0 { -1.0f32 } else { 1.0 };
    let a = x.abs();
    if a > 464.0 {
        // beyond the rounding boundary (448 + half ulp 16) -> NaN (E4M3FN)
        return f32::NAN;
    }
    // subnormal quantum 2^-9; normal quantum 2^(exp-3)
    let exp = a.log2().floor() as i32;
    let q = if exp < -6 {
        2f32.powi(-9)
    } else {
        2f32.powi(exp - 3)
    };
    let m = (a as f64 / q as f64).round_ties_even() as f32;
    let v = (m * q).min(448.0);
    // m*q can round up to the next binade boundary; that is still on-grid
    // except at 464 -> 448 saturation handled by min (448+16 ties to 448's
    // even neighbour 480 which doesn't exist in E4M3FN -> saturate).
    sign * v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_rows() {
        // The exact rows of the paper's Table 1.
        assert!((Format::F8E4M3.eps() - 6.25e-2).abs() < 1e-12);
        assert_eq!(Format::F8E4M3.overflow_boundary(), 448.0);
        assert!((Format::F16.eps() - 4.88e-4).abs() < 1e-6);
        assert_eq!(Format::F16.overflow_boundary(), 65504.0);
        assert!((Format::Bf16.eps() - 3.906e-3).abs() < 1e-6);
        assert!(Format::Bf16.overflow_boundary() > 3.38e38);
        assert!((Format::F32.eps() - 5.96e-8).abs() < 1e-10);
        assert!(Format::F32.overflow_boundary() > 3.4e38);
    }

    #[test]
    fn f8_grid() {
        assert_eq!(round_f8e4m3(448.0), 448.0);
        assert_eq!(round_f8e4m3(1.0), 1.0);
        assert_eq!(round_f8e4m3(1.05), 1.0); // ulp at 1.0 is 0.125
        assert_eq!(round_f8e4m3(1.07), 1.125);
        assert!(round_f8e4m3(500.0).is_nan());
        assert_eq!(round_f8e4m3(-448.0), -448.0);
    }

    #[test]
    fn f32_identity() {
        for &v in &[1.0f32, 1e-30, 3.0e38, -7.25] {
            assert_eq!(Format::F32.round(v), v);
        }
    }
}
