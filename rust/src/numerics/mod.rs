//! Software floating-point substrate (S1).
//!
//! Bit-exact binary16 / bfloat16 / FP8-E4M3 emulation with RTNE rounding,
//! the `fl_tp(.)` operator of the paper's Appendix A, and the error metrics
//! (relative RMSE of Eq. 19, NaN percentages of Table 4).

pub mod bf16;
pub mod error;
pub mod f16;
pub mod round;

pub use bf16::{bf16_bits_to_f32, f32_to_bf16_bits, round_bf16};
pub use error::{
    finite_mean, finite_range, has_overflow, max_abs, nan_percentage, nonfinite_percentage,
    relative_rmse,
};
pub use f16::{f16_bits_to_f32, f32_to_f16_bits, round_f16, F16, F16_EPS, F16_MAX};
pub use round::{
    f32_to_f8e4m3_bits, f8e4m3_bits_to_f32, f8e4m3_decode_table, round_f8e4m3, Format,
};
