//! Row-wise reductions and elementwise ops with precision emulation (S2).
//!
//! These are the "vector unit" operations of the FA/PASA inner loop:
//! rowmax, rowsum, rowmean, exp, scale/update. Each has a format-aware
//! variant that rounds after every elementary operation, emulating a
//! low-precision vector core (the paper notes NPUs have a *normal*
//! vectorization capability — these ops are exactly where its rounding
//! error accumulates).
//!
//! ## Hot-path layout
//!
//! The attention inner loop runs through the **fused, in-place** entries
//! ([`scale_rowmax`], [`exp_sub_rowbias_rowsum_into`],
//! [`exp_sub_rowbias_rowmean32_into`], …): one pass over the score block
//! instead of two or three, output written into caller-owned buffers so
//! the KV sweep allocates nothing. Every fused op performs the *exact*
//! rounding sequence of the unfused composition it replaces (pinned by
//! tests), and the format dispatch is hoisted to one
//! [`crate::mono_format!`] branch per call.

use super::matrix::Matrix;
use crate::numerics::round::RoundSpec;
use crate::numerics::Format;

/// Row maxima (exact in any format — max introduces no rounding).
pub fn rowmax(m: &Matrix) -> Vec<f32> {
    let mut out = Vec::new();
    rowmax_into(m, &mut out);
    out
}

// lint: hot-path — buffer-reusing reduction; zero allocations after warm-up.
/// Buffer-reusing [`rowmax`].
pub fn rowmax_into(m: &Matrix, out: &mut Vec<f32>) {
    out.clear();
    for r in 0..m.rows {
        out.push(m.row(r).iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)));
    }
}
// lint: end-hot-path

/// Row sums with sequential accumulation rounded to `fmt` at each step.
pub fn rowsum(m: &Matrix, fmt: Format) -> Vec<f32> {
    crate::mono_format!(fmt, R => rowsum_mono::<R>(m))
}

fn rowsum_mono<R: RoundSpec>(m: &Matrix) -> Vec<f32> {
    (0..m.rows)
        .map(|r| {
            let mut s = 0.0f32;
            for &x in m.row(r) {
                s = R::round(s + x);
            }
            s
        })
        .collect()
}

/// Row means: rowsum then divide, both rounded to `fmt`.
pub fn rowmean(m: &Matrix, fmt: Format) -> Vec<f32> {
    let n = m.cols as f32;
    rowsum(m, fmt)
        .into_iter()
        .map(|s| fmt.round(s / n))
        .collect()
}

/// Row means accumulated in f32 (matrix-engine semantics: a rowsum is a
/// GEMM against the all-ones vector, which accumulates in FP32 on
/// CUBE/TensorCores) with a single `fmt` rounding on store. PASA's
/// pseudo-average measurement uses this: the S̄' error is amplified by
/// Inva = β/(1−β) ≈ 63.5 in the correction terms, so a strict-FP16
/// sequential ladder would dominate the error budget (see DESIGN.md).
pub fn rowmean_acc32(m: &Matrix, fmt: Format) -> Vec<f32> {
    let mut out = Vec::new();
    rowmean_acc32_into(m, fmt, &mut out);
    out
}

// lint: hot-path — buffer-reusing reduction; zero allocations after warm-up.
/// Buffer-reusing [`rowmean_acc32`].
pub fn rowmean_acc32_into(m: &Matrix, fmt: Format, out: &mut Vec<f32>) {
    out.clear();
    let n = m.cols as f64;
    crate::mono_format!(fmt, R => {
        for r in 0..m.rows {
            let mut s = 0.0f64;
            for &x in m.row(r) {
                s += x as f64;
            }
            out.push(R::round((s / n) as f32));
        }
    });
}
// lint: end-hot-path

/// Row maxima over the first `vis[r]` columns (−inf for an empty prefix).
/// The masked kernels use this so a never-attended score can't inflate the
/// online maximum (which would underflow every genuine weight in FP16).
pub fn rowmax_prefix(m: &Matrix, vis: &[usize]) -> Vec<f32> {
    let mut out = Vec::new();
    rowmax_prefix_into(m, vis, &mut out);
    out
}

// lint: hot-path — buffer-reusing reduction; zero allocations after warm-up.
/// Buffer-reusing [`rowmax_prefix`].
pub fn rowmax_prefix_into(m: &Matrix, vis: &[usize], out: &mut Vec<f32>) {
    assert_eq!(vis.len(), m.rows);
    out.clear();
    for r in 0..m.rows {
        out.push(
            m.row(r)[..vis[r].min(m.cols)]
                .iter()
                .fold(f32::NEG_INFINITY, |a, &b| a.max(b)),
        );
    }
}
// lint: end-hot-path

// lint: hot-path — fused in-place softmax-stage ops of the FA/PASA KV
// sweep; all output goes to caller-owned buffers.
/// Fused static scaling + row max, in place: `m ← fmt(m · k)` and
/// `maxes[r] = max_c m[r][c]` in one pass — exactly
/// [`scale`] followed by [`rowmax`] (same rounding, same max fold), minus
/// one full traversal and the output allocation. This is Eq. (2)'s S/α
/// feeding Eq. (4)'s row max in the FA inner loop.
pub fn scale_rowmax(m: &mut Matrix, k: f32, fmt: Format, maxes: &mut Vec<f32>) {
    maxes.clear();
    crate::mono_format!(fmt, R => {
        for r in 0..m.rows {
            let row = m.row_mut(r);
            let mut mx = f32::NEG_INFINITY;
            for x in row.iter_mut() {
                *x = R::round(*x * k);
                mx = mx.max(*x);
            }
            maxes.push(mx);
        }
    });
}

/// Prefix-masked [`scale_rowmax`]: scales (in place, `fmt`-rounded) and
/// maxes only the columns `c < vis[r]`; the masked tail is left untouched
/// and must never be read downstream (pair with the prefix-aware softmax
/// ops). An empty prefix yields the −inf max-fold identity. For formats
/// with an infinity this is bit-identical to `scale_rowmax` over a
/// −inf-filled tail (−inf scales to −inf and loses every max); for E4M3
/// — which has **no infinity**, so a −inf tail would round to NaN and
/// poison the row — it is the only correct masked path.
pub fn scale_rowmax_prefix(
    m: &mut Matrix,
    k: f32,
    fmt: Format,
    vis: &[usize],
    maxes: &mut Vec<f32>,
) {
    assert_eq!(vis.len(), m.rows);
    maxes.clear();
    crate::mono_format!(fmt, R => {
        for r in 0..m.rows {
            let limit = vis[r].min(m.cols);
            let row = m.row_mut(r);
            let mut mx = f32::NEG_INFINITY;
            for x in row[..limit].iter_mut() {
                *x = R::round(*x * k);
                mx = mx.max(*x);
            }
            maxes.push(mx);
        }
    });
}

/// Prefix-masked [`exp_sub_rowbias_rowsum_into`]: weights beyond `vis[r]`
/// are exact 0 and contribute exactly nothing to the `fmt`-rounded
/// sequential row sum — bit-identical to the dense op over a row whose
/// masked tail holds −inf (exp(−inf) = 0 and `round(acc + 0) = acc`),
/// without ever materializing −inf through a store format that may not
/// represent it (E4M3).
pub fn exp_sub_rowbias_prefix_rowsum_into(
    s: &Matrix,
    bias: &[f32],
    vis: &[usize],
    fmt: Format,
    p: &mut Matrix,
    sums: &mut Vec<f32>,
) {
    assert_eq!(bias.len(), s.rows);
    assert_eq!(vis.len(), s.rows);
    p.reset(s.rows, s.cols); // masked weights are exact 0 from the reset
    sums.clear();
    crate::mono_format!(fmt, R => {
        for r in 0..s.rows {
            let b = bias[r];
            let limit = vis[r].min(s.cols);
            let src = s.row(r);
            let dst = p.row_mut(r);
            let mut acc = 0.0f32;
            for c in 0..limit {
                let d = R::round(src[c] - b);
                let e = R::round(d.exp());
                dst[c] = e;
                acc = R::round(acc + e);
            }
            sums.push(acc);
        }
    });
}
// lint: end-hot-path

/// Masked attenuator: `exp(m[r][c] − v[r])` for `c < vis[r]`, exact 0
/// beyond — masked positions carry zero softmax weight without relying on
/// the score buffer holding −inf (PASA keeps dense finite shifted scores
/// for its pseudo-average and masks only here).
pub fn exp_sub_rowbias_prefix(m: &Matrix, v: &[f32], vis: &[usize], fmt: Format) -> Matrix {
    assert_eq!(v.len(), m.rows);
    assert_eq!(vis.len(), m.rows);
    let mut out = Matrix::zeros(m.rows, m.cols);
    crate::mono_format!(fmt, R => {
        for r in 0..m.rows {
            let b = v[r];
            let limit = vis[r].min(m.cols);
            let src = m.row(r);
            let dst = out.row_mut(r);
            for c in 0..limit {
                let d = R::round(src[c] - b);
                dst[c] = R::round(d.exp());
            }
        }
    });
    out
}

/// `exp(m[r][c] - v[r])` elementwise, rounded to `fmt`.
/// This is Eq. (5): P = exp(S - m). The subtraction makes every exponent
/// non-positive, so exp is an attenuator (never overflows).
pub fn exp_sub_rowbias(m: &Matrix, v: &[f32], fmt: Format) -> Matrix {
    assert_eq!(v.len(), m.rows);
    let mut out = Matrix::zeros(m.rows, m.cols);
    crate::mono_format!(fmt, R => {
        for r in 0..m.rows {
            let b = v[r];
            let src = m.row(r);
            let dst = out.row_mut(r);
            for c in 0..m.cols {
                let d = R::round(src[c] - b);
                dst[c] = R::round(d.exp());
            }
        }
    });
    out
}

// lint: hot-path — fused softmax + stats kernels; outputs land in
// caller-owned workspace buffers.
/// Fused Eq. (5) + Eq. (6) right half: `p = fmt(exp(fmt(s − bias)))` and
/// `sums[r] = ` sequential `fmt`-rounded row sum of `p` — exactly
/// [`exp_sub_rowbias`] followed by [`rowsum`], one pass, caller-owned
/// buffers. The FA inner loop's softmax step.
pub fn exp_sub_rowbias_rowsum_into(
    s: &Matrix,
    bias: &[f32],
    fmt: Format,
    p: &mut Matrix,
    sums: &mut Vec<f32>,
) {
    assert_eq!(bias.len(), s.rows);
    p.reshape(s.rows, s.cols); // dense: every element written
    sums.clear();
    crate::mono_format!(fmt, R => {
        for r in 0..s.rows {
            let b = bias[r];
            let src = s.row(r);
            let dst = p.row_mut(r);
            let mut acc = 0.0f32;
            for c in 0..src.len() {
                let d = R::round(src[c] - b);
                let e = R::round(d.exp());
                dst[c] = e;
                acc = R::round(acc + e);
            }
            sums.push(acc);
        }
    });
}

/// Fused Eq. (5) + FP32-reduce row mean: `p` as in [`exp_sub_rowbias`],
/// `means[r]` as in [`rowmean_acc32`] of `p` — the PASA inner loop's
/// local softmax stats, one pass.
pub fn exp_sub_rowbias_rowmean32_into(
    s: &Matrix,
    bias: &[f32],
    fmt: Format,
    p: &mut Matrix,
    means: &mut Vec<f32>,
) {
    assert_eq!(bias.len(), s.rows);
    p.reshape(s.rows, s.cols); // dense: every element written
    means.clear();
    let n = s.cols as f64;
    crate::mono_format!(fmt, R => {
        for r in 0..s.rows {
            let b = bias[r];
            let src = s.row(r);
            let dst = p.row_mut(r);
            let mut acc = 0.0f64;
            for c in 0..src.len() {
                let d = R::round(src[c] - b);
                let e = R::round(d.exp());
                dst[c] = e;
                acc += e as f64;
            }
            means.push(R::round((acc / n) as f32));
        }
    });
}

/// Prefix-masked [`exp_sub_rowbias_rowmean32_into`]: weights beyond
/// `vis[r]` are exact 0 (contributing exactly 0.0 to the f64 mean
/// accumulator, as in the unfused composition), and the mean still
/// divides by the full block width — PASA's S̄' is defined over the whole
/// block.
pub fn exp_sub_rowbias_prefix_rowmean32_into(
    s: &Matrix,
    bias: &[f32],
    vis: &[usize],
    fmt: Format,
    p: &mut Matrix,
    means: &mut Vec<f32>,
) {
    assert_eq!(bias.len(), s.rows);
    assert_eq!(vis.len(), s.rows);
    p.reset(s.rows, s.cols);
    means.clear();
    let n = s.cols as f64;
    crate::mono_format!(fmt, R => {
        for r in 0..s.rows {
            let b = bias[r];
            let limit = vis[r].min(s.cols);
            let src = s.row(r);
            let dst = p.row_mut(r);
            let mut acc = 0.0f64;
            for c in 0..limit {
                let d = R::round(src[c] - b);
                let e = R::round(d.exp());
                dst[c] = e;
                acc += e as f64;
            }
            // dst[limit..] is exact 0 from the reset — zero softmax weight
            // and zero mean contribution, like the unfused path.
            means.push(R::round((acc / n) as f32));
        }
    });
}
// lint: end-hot-path

/// Elementwise `exp` of a vector, rounded to `fmt`.
pub fn exp_vec(v: &[f32], fmt: Format) -> Vec<f32> {
    v.iter().map(|&x| fmt.round(x.exp())).collect()
}

/// `out[r][c] = fmt(a[r][c] * s[r])` — row-scaled copy.
pub fn scale_rows(m: &Matrix, s: &[f32], fmt: Format) -> Matrix {
    let mut out = m.clone();
    scale_rows_inplace(&mut out, s, fmt);
    out
}

// lint: hot-path — in-place rescale/update pair of the online softmax.
/// In-place [`scale_rows`] — the PASA `exp(Δm_j)·(P·V_j)` rescale without
/// the copy.
pub fn scale_rows_inplace(m: &mut Matrix, s: &[f32], fmt: Format) {
    assert_eq!(s.len(), m.rows);
    crate::mono_format!(fmt, R => {
        for r in 0..m.rows {
            let k = s[r];
            for x in m.row_mut(r).iter_mut() {
                *x = R::round(*x * k);
            }
        }
    });
}

/// In-place fused update `acc = fmt(fmt(acc * s[r]) + add)` — the FA/PASA
/// online output rescale of Eq. (7) / Algorithm 1 line 20.
pub fn scale_add_rows(acc: &mut Matrix, s: &[f32], add: &Matrix, fmt: Format) {
    assert_eq!(acc.shape(), add.shape());
    assert_eq!(s.len(), acc.rows);
    crate::mono_format!(fmt, R => {
        for r in 0..acc.rows {
            let k = s[r];
            let arow = &mut acc.data[r * acc.cols..(r + 1) * acc.cols];
            let brow = &add.data[r * add.cols..(r + 1) * add.cols];
            for c in 0..arow.len() {
                arow[c] = R::round(R::round(arow[c] * k) + brow[c]);
            }
        }
    });
}
// lint: end-hot-path

/// `out[r][c] = fmt(m[r][c] / d[r])` — the final O = O / l of Eq. (8).
pub fn div_rows(m: &Matrix, d: &[f32], fmt: Format) -> Matrix {
    assert_eq!(d.len(), m.rows);
    let mut out = Matrix::zeros(m.rows, m.cols);
    crate::mono_format!(fmt, R => {
        for r in 0..m.rows {
            let k = d[r];
            let src = m.row(r);
            let dst = out.row_mut(r);
            for c in 0..m.cols {
                dst[c] = R::round(src[c] / k);
            }
        }
    });
    out
}

// lint: hot-path — final normalize writes straight into the head's output.
/// Fused Eq. (8) + output store: `dst_row = fmt(oi[r] / l[r])` for each
/// visible row, zeros for fully-masked rows (`vis[r] == 0`) — exactly
/// [`div_rows`] followed by the kernel's per-row copy/zero, writing
/// straight into the head's output rows.
pub fn div_rows_masked_into(
    oi: &Matrix,
    l: &[f32],
    vis: &[usize],
    fmt: Format,
    out_rows: &mut [f32],
) {
    assert_eq!(l.len(), oi.rows);
    assert_eq!(vis.len(), oi.rows);
    assert_eq!(out_rows.len(), oi.rows * oi.cols);
    crate::mono_format!(fmt, R => {
        for r in 0..oi.rows {
            let dst = &mut out_rows[r * oi.cols..(r + 1) * oi.cols];
            if vis[r] == 0 {
                dst.fill(0.0);
            } else {
                let k = l[r];
                let src = oi.row(r);
                for c in 0..src.len() {
                    dst[c] = R::round(src[c] / k);
                }
            }
        }
    });
}
// lint: end-hot-path

/// Elementwise scalar multiply, rounded to `fmt`.
pub fn scale(m: &Matrix, k: f32, fmt: Format) -> Matrix {
    let mut out = m.clone();
    crate::mono_format!(fmt, R => {
        for x in &mut out.data {
            *x = R::round(*x * k);
        }
    });
    out
}

/// Full-precision softmax over each row (the golden path).
pub fn softmax_rows_f32(m: &Matrix) -> Matrix {
    let mx = rowmax(m);
    let mut out = Matrix::zeros(m.rows, m.cols);
    for r in 0..m.rows {
        let b = mx[r];
        let src = m.row(r);
        let dst = out.row_mut(r);
        let mut s = 0.0f64;
        for c in 0..m.cols {
            let e = ((src[c] - b) as f64).exp();
            dst[c] = e as f32;
            s += e;
        }
        for c in 0..m.cols {
            dst[c] = (dst[c] as f64 / s) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Matrix {
        Matrix::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn reductions() {
        let a = m(2, 3, &[1., 5., 3., -1., -5., -3.]);
        assert_eq!(rowmax(&a), vec![5.0, -1.0]);
        assert_eq!(rowsum(&a, Format::F32), vec![9.0, -9.0]);
        assert_eq!(rowmean(&a, Format::F32), vec![3.0, -3.0]);
    }

    #[test]
    fn rowsum_f16_rounds() {
        // 1.0 followed by half-ulps: FP16 sequential sum absorbs them all.
        let mut v = vec![2f32.powi(-11); 32];
        v[0] = 1.0;
        let a = m(1, 32, &v);
        assert_eq!(rowsum(&a, Format::F16)[0], 1.0);
        assert!(rowsum(&a, Format::F32)[0] > 1.01);
    }

    #[test]
    fn exp_sub_is_attenuator() {
        let a = m(1, 3, &[10.0, 8.0, -100.0]);
        let p = exp_sub_rowbias(&a, &[10.0], Format::F16);
        assert_eq!(p.at(0, 0), 1.0);
        assert!(p.at(0, 1) < 1.0 && p.at(0, 1) > 0.0);
        assert!(p.at(0, 2) >= 0.0); // underflow to 0 allowed, never inf
        assert!(p.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prefix_ops_match_dense_when_fully_visible() {
        let a = m(2, 3, &[1., 5., 3., -1., -5., -3.]);
        let full = [3usize, 3];
        assert_eq!(rowmax_prefix(&a, &full), rowmax(&a));
        let bias = rowmax(&a);
        let dense = exp_sub_rowbias(&a, &bias, Format::F16);
        let prefixed = exp_sub_rowbias_prefix(&a, &bias, &full, Format::F16);
        assert_eq!(dense, prefixed);
    }

    #[test]
    fn prefix_ops_mask_the_tail() {
        let a = m(1, 4, &[1.0, 2.0, 90.0, 7.0]);
        let vis = [2usize];
        // The masked 90.0 must not become the row max...
        assert_eq!(rowmax_prefix(&a, &vis), vec![2.0]);
        // ...and masked entries carry exactly zero weight.
        let p = exp_sub_rowbias_prefix(&a, &[2.0], &vis, Format::F16);
        assert_eq!(p.at(0, 0), Format::F16.round((-1.0f32).exp()));
        assert_eq!(p.at(0, 1), 1.0);
        assert_eq!(p.at(0, 2), 0.0);
        assert_eq!(p.at(0, 3), 0.0);
        // Empty prefix: −inf max, all-zero row.
        assert_eq!(rowmax_prefix(&a, &[0]), vec![f32::NEG_INFINITY]);
        let z = exp_sub_rowbias_prefix(&a, &[f32::NEG_INFINITY], &[0], Format::F16);
        assert!(z.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let a = m(2, 4, &[0.1, 2.0, -3.0, 0.7, 100.0, 100.0, 100.0, 100.0]);
        let s = softmax_rows_f32(&a);
        for r in 0..2 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        assert!((s.at(1, 0) - 0.25).abs() < 1e-6);
    }

    #[test]
    fn scale_add_update() {
        let mut acc = m(1, 2, &[2.0, 4.0]);
        let add = m(1, 2, &[1.0, 1.0]);
        scale_add_rows(&mut acc, &[0.5], &add, Format::F32);
        assert_eq!(acc, m(1, 2, &[2.0, 3.0]));
    }

    #[test]
    fn div_rows_final_normalize() {
        let o = m(2, 2, &[2.0, 4.0, 9.0, 3.0]);
        let d = div_rows(&o, &[2.0, 3.0], Format::F32);
        assert_eq!(d, m(2, 2, &[1.0, 2.0, 3.0, 1.0]));
    }

    /// Each fused kernel must be bit-identical to the unfused composition
    /// it replaces — the workspace refactor's rounding-order contract.
    #[test]
    fn fused_ops_bit_match_their_compositions() {
        let vals: Vec<f32> = (0..48)
            .map(|i| ((i as f32 * 0.37).sin() * 9.0) - 2.0)
            .collect();
        let a = m(4, 12, &vals);
        for fmt in [Format::F16, Format::F32, Format::Bf16] {
            // scale + rowmax == scale_rowmax.
            let k = 0.1728f32;
            let scaled = scale(&a, k, fmt);
            let want_max = rowmax(&scaled);
            let mut fused = a.clone();
            let mut maxes = vec![99.0f32; 1];
            scale_rowmax(&mut fused, k, fmt, &mut maxes);
            assert_eq!(fused, scaled, "{}", fmt.name());
            assert_eq!(maxes, want_max, "{}", fmt.name());

            // exp_sub_rowbias + rowsum == exp_sub_rowbias_rowsum_into.
            let bias = rowmax(&a);
            let p_ref = exp_sub_rowbias(&a, &bias, fmt);
            let sums_ref = rowsum(&p_ref, fmt);
            let mut p = Matrix::full(1, 1, f32::NAN);
            let mut sums = Vec::new();
            exp_sub_rowbias_rowsum_into(&a, &bias, fmt, &mut p, &mut sums);
            assert_eq!(p, p_ref, "{}", fmt.name());
            assert_eq!(sums, sums_ref, "{}", fmt.name());

            // exp_sub_rowbias + rowmean_acc32 == the fused mean variant.
            let means_ref = rowmean_acc32(&p_ref, fmt);
            let mut means = Vec::new();
            exp_sub_rowbias_rowmean32_into(&a, &bias, fmt, &mut p, &mut means);
            assert_eq!(p, p_ref, "{}", fmt.name());
            assert_eq!(means, means_ref, "{}", fmt.name());

            // Prefix variant vs prefix composition (ragged vis incl. 0).
            let vis = [12usize, 5, 0, 9];
            let bias_pref = rowmax_prefix(&a, &vis);
            let pp_ref = exp_sub_rowbias_prefix(&a, &bias_pref, &vis, fmt);
            let pmeans_ref = rowmean_acc32(&pp_ref, fmt);
            let mut pp = Matrix::full(2, 2, f32::NAN);
            let mut pmeans = Vec::new();
            exp_sub_rowbias_prefix_rowmean32_into(&a, &bias_pref, &vis, fmt, &mut pp, &mut pmeans);
            assert_eq!(pp, pp_ref, "{}", fmt.name());
            assert_eq!(pmeans, pmeans_ref, "{}", fmt.name());

            // The flash masked path's prefix-fused pair must bit-match
            // the legacy −inf-tail composition: scale_rowmax over a row
            // whose masked tail is −inf == scale_rowmax_prefix over the
            // visible prefix, and the dense exp/rowsum over the −inf tail
            // == the prefix exp/rowsum (exp(−inf) = 0 contributes
            // nothing). This is the FP16/F32/BF16-bit-identity half of
            // the E4M3 mask fix; the E4M3 half (finite masked FP8 rows)
            // is pinned by `masked_fp8_rows_stay_finite_and_match_naive`
            // in attention/flash.rs.
            let mut inf_tail = a.clone();
            for r in 0..4 {
                for c in vis[r]..12 {
                    inf_tail.row_mut(r)[c] = f32::NEG_INFINITY;
                }
            }
            let mut legacy = inf_tail.clone();
            let mut legacy_max = Vec::new();
            scale_rowmax(&mut legacy, k, fmt, &mut legacy_max);
            let mut pref = a.clone();
            let mut pref_max = Vec::new();
            scale_rowmax_prefix(&mut pref, k, fmt, &vis, &mut pref_max);
            assert_eq!(legacy_max, pref_max, "{}", fmt.name());
            for r in 0..4 {
                assert_eq!(
                    &legacy.row(r)[..vis[r]],
                    &pref.row(r)[..vis[r]],
                    "{} row {r} visible prefix",
                    fmt.name()
                );
            }
            let mut p_legacy = Matrix::full(1, 1, f32::NAN);
            let mut sums_legacy = Vec::new();
            exp_sub_rowbias_rowsum_into(&legacy, &legacy_max, fmt, &mut p_legacy, &mut sums_legacy);
            let mut p_pref = Matrix::full(1, 1, f32::NAN);
            let mut sums_pref = Vec::new();
            exp_sub_rowbias_prefix_rowsum_into(
                &pref, &pref_max, &vis, fmt, &mut p_pref, &mut sums_pref,
            );
            // Fully-masked rows diverge *by design*: the legacy path
            // computes exp(−inf − (−inf)) = NaN there (harmless — the
            // kernel zeroes vis == 0 rows at the final store), while the
            // prefix path produces the correct exact-zero row. Visible
            // rows must agree bit for bit.
            for r in 0..4 {
                if vis[r] > 0 {
                    assert_eq!(
                        sums_legacy[r].to_bits(),
                        sums_pref[r].to_bits(),
                        "{} row {r} rowsum",
                        fmt.name()
                    );
                } else {
                    assert!(sums_legacy[r].is_nan(), "{} legacy empty row", fmt.name());
                    assert_eq!(sums_pref[r], 0.0, "{} prefix empty row", fmt.name());
                }
                assert_eq!(
                    &p_legacy.row(r)[..vis[r]],
                    &p_pref.row(r)[..vis[r]],
                    "{} row {r} weights",
                    fmt.name()
                );
                assert!(
                    p_pref.row(r)[vis[r]..].iter().all(|&x| x == 0.0),
                    "{} row {r} masked weights must be exact 0",
                    fmt.name()
                );
            }

            // scale_rows == scale_rows_inplace (already shared), and
            // div_rows + masked copy == div_rows_masked_into.
            let l = [1.5f32, 2.0, 3.0, 0.5];
            let div_ref = div_rows(&a, &l, fmt);
            let mut out_rows = vec![f32::NAN; 4 * 12];
            let vis_rows = [3usize, 0, 1, 12];
            div_rows_masked_into(&a, &l, &vis_rows, fmt, &mut out_rows);
            for r in 0..4 {
                let dst = &out_rows[r * 12..(r + 1) * 12];
                if vis_rows[r] == 0 {
                    assert!(dst.iter().all(|&x| x == 0.0), "{} row {r}", fmt.name());
                } else {
                    assert_eq!(dst, div_ref.row(r), "{} row {r}", fmt.name());
                }
            }
        }
    }
}
