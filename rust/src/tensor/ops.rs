//! Row-wise reductions and elementwise ops with precision emulation (S2).
//!
//! These are the "vector unit" operations of the FA/PASA inner loop:
//! rowmax, rowsum, rowmean, exp, scale/update. Each has a format-aware
//! variant that rounds after every elementary operation, emulating a
//! low-precision vector core (the paper notes NPUs have a *normal*
//! vectorization capability — these ops are exactly where its rounding
//! error accumulates).

use super::matrix::Matrix;
use crate::numerics::Format;

/// Row maxima (exact in any format — max introduces no rounding).
pub fn rowmax(m: &Matrix) -> Vec<f32> {
    (0..m.rows)
        .map(|r| m.row(r).iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)))
        .collect()
}

/// Row sums with sequential accumulation rounded to `fmt` at each step.
pub fn rowsum(m: &Matrix, fmt: Format) -> Vec<f32> {
    (0..m.rows)
        .map(|r| {
            let mut s = 0.0f32;
            for &x in m.row(r) {
                s = fmt.round(s + x);
            }
            s
        })
        .collect()
}

/// Row means: rowsum then divide, both rounded to `fmt`.
pub fn rowmean(m: &Matrix, fmt: Format) -> Vec<f32> {
    let n = m.cols as f32;
    rowsum(m, fmt)
        .into_iter()
        .map(|s| fmt.round(s / n))
        .collect()
}

/// Row means accumulated in f32 (matrix-engine semantics: a rowsum is a
/// GEMM against the all-ones vector, which accumulates in FP32 on
/// CUBE/TensorCores) with a single `fmt` rounding on store. PASA's
/// pseudo-average measurement uses this: the S̄' error is amplified by
/// Inva = β/(1−β) ≈ 63.5 in the correction terms, so a strict-FP16
/// sequential ladder would dominate the error budget (see DESIGN.md).
pub fn rowmean_acc32(m: &Matrix, fmt: Format) -> Vec<f32> {
    let n = m.cols as f64;
    (0..m.rows)
        .map(|r| {
            let mut s = 0.0f64;
            for &x in m.row(r) {
                s += x as f64;
            }
            fmt.round((s / n) as f32)
        })
        .collect()
}

/// Row maxima over the first `vis[r]` columns (−inf for an empty prefix).
/// The masked kernels use this so a never-attended score can't inflate the
/// online maximum (which would underflow every genuine weight in FP16).
pub fn rowmax_prefix(m: &Matrix, vis: &[usize]) -> Vec<f32> {
    assert_eq!(vis.len(), m.rows);
    (0..m.rows)
        .map(|r| {
            m.row(r)[..vis[r].min(m.cols)]
                .iter()
                .fold(f32::NEG_INFINITY, |a, &b| a.max(b))
        })
        .collect()
}

/// Masked attenuator: `exp(m[r][c] − v[r])` for `c < vis[r]`, exact 0
/// beyond — masked positions carry zero softmax weight without relying on
/// the score buffer holding −inf (PASA keeps dense finite shifted scores
/// for its pseudo-average and masks only here).
pub fn exp_sub_rowbias_prefix(m: &Matrix, v: &[f32], vis: &[usize], fmt: Format) -> Matrix {
    assert_eq!(v.len(), m.rows);
    assert_eq!(vis.len(), m.rows);
    let mut out = Matrix::zeros(m.rows, m.cols);
    for r in 0..m.rows {
        let b = v[r];
        let limit = vis[r].min(m.cols);
        let src = m.row(r);
        let dst = out.row_mut(r);
        for c in 0..limit {
            let d = fmt.round(src[c] - b);
            dst[c] = fmt.round(d.exp());
        }
    }
    out
}

/// `exp(m[r][c] - v[r])` elementwise, rounded to `fmt`.
/// This is Eq. (5): P = exp(S - m). The subtraction makes every exponent
/// non-positive, so exp is an attenuator (never overflows).
pub fn exp_sub_rowbias(m: &Matrix, v: &[f32], fmt: Format) -> Matrix {
    assert_eq!(v.len(), m.rows);
    let mut out = Matrix::zeros(m.rows, m.cols);
    for r in 0..m.rows {
        let b = v[r];
        let src = m.row(r);
        let dst = out.row_mut(r);
        for c in 0..m.cols {
            let d = fmt.round(src[c] - b);
            dst[c] = fmt.round(d.exp());
        }
    }
    out
}

/// Elementwise `exp` of a vector, rounded to `fmt`.
pub fn exp_vec(v: &[f32], fmt: Format) -> Vec<f32> {
    v.iter().map(|&x| fmt.round(x.exp())).collect()
}

/// `out[r][c] = fmt(a[r][c] * s[r])` — row-scaled copy.
pub fn scale_rows(m: &Matrix, s: &[f32], fmt: Format) -> Matrix {
    assert_eq!(s.len(), m.rows);
    let mut out = Matrix::zeros(m.rows, m.cols);
    for r in 0..m.rows {
        let k = s[r];
        let src = m.row(r);
        let dst = out.row_mut(r);
        for c in 0..m.cols {
            dst[c] = fmt.round(src[c] * k);
        }
    }
    out
}

/// In-place fused update `acc = fmt(fmt(acc * s[r]) + add)` — the FA/PASA
/// online output rescale of Eq. (7) / Algorithm 1 line 20.
pub fn scale_add_rows(acc: &mut Matrix, s: &[f32], add: &Matrix, fmt: Format) {
    assert_eq!(acc.shape(), add.shape());
    assert_eq!(s.len(), acc.rows);
    for r in 0..acc.rows {
        let k = s[r];
        let arow = &mut acc.data[r * acc.cols..(r + 1) * acc.cols];
        let brow = &add.data[r * add.cols..(r + 1) * add.cols];
        for c in 0..arow.len() {
            arow[c] = fmt.round(fmt.round(arow[c] * k) + brow[c]);
        }
    }
}

/// `out[r][c] = fmt(m[r][c] / d[r])` — the final O = O / l of Eq. (8).
pub fn div_rows(m: &Matrix, d: &[f32], fmt: Format) -> Matrix {
    assert_eq!(d.len(), m.rows);
    let mut out = Matrix::zeros(m.rows, m.cols);
    for r in 0..m.rows {
        let k = d[r];
        let src = m.row(r);
        let dst = out.row_mut(r);
        for c in 0..m.cols {
            dst[c] = fmt.round(src[c] / k);
        }
    }
    out
}

/// Elementwise scalar multiply, rounded to `fmt`.
pub fn scale(m: &Matrix, k: f32, fmt: Format) -> Matrix {
    let mut out = m.clone();
    for x in &mut out.data {
        *x = fmt.round(*x * k);
    }
    out
}

/// Full-precision softmax over each row (the golden path).
pub fn softmax_rows_f32(m: &Matrix) -> Matrix {
    let mx = rowmax(m);
    let mut out = Matrix::zeros(m.rows, m.cols);
    for r in 0..m.rows {
        let b = mx[r];
        let src = m.row(r);
        let dst = out.row_mut(r);
        let mut s = 0.0f64;
        for c in 0..m.cols {
            let e = ((src[c] - b) as f64).exp();
            dst[c] = e as f32;
            s += e;
        }
        for c in 0..m.cols {
            dst[c] = (dst[c] as f64 / s) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Matrix {
        Matrix::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn reductions() {
        let a = m(2, 3, &[1., 5., 3., -1., -5., -3.]);
        assert_eq!(rowmax(&a), vec![5.0, -1.0]);
        assert_eq!(rowsum(&a, Format::F32), vec![9.0, -9.0]);
        assert_eq!(rowmean(&a, Format::F32), vec![3.0, -3.0]);
    }

    #[test]
    fn rowsum_f16_rounds() {
        // 1.0 followed by half-ulps: FP16 sequential sum absorbs them all.
        let mut v = vec![2f32.powi(-11); 32];
        v[0] = 1.0;
        let a = m(1, 32, &v);
        assert_eq!(rowsum(&a, Format::F16)[0], 1.0);
        assert!(rowsum(&a, Format::F32)[0] > 1.01);
    }

    #[test]
    fn exp_sub_is_attenuator() {
        let a = m(1, 3, &[10.0, 8.0, -100.0]);
        let p = exp_sub_rowbias(&a, &[10.0], Format::F16);
        assert_eq!(p.at(0, 0), 1.0);
        assert!(p.at(0, 1) < 1.0 && p.at(0, 1) > 0.0);
        assert!(p.at(0, 2) >= 0.0); // underflow to 0 allowed, never inf
        assert!(p.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prefix_ops_match_dense_when_fully_visible() {
        let a = m(2, 3, &[1., 5., 3., -1., -5., -3.]);
        let full = [3usize, 3];
        assert_eq!(rowmax_prefix(&a, &full), rowmax(&a));
        let bias = rowmax(&a);
        let dense = exp_sub_rowbias(&a, &bias, Format::F16);
        let prefixed = exp_sub_rowbias_prefix(&a, &bias, &full, Format::F16);
        assert_eq!(dense, prefixed);
    }

    #[test]
    fn prefix_ops_mask_the_tail() {
        let a = m(1, 4, &[1.0, 2.0, 90.0, 7.0]);
        let vis = [2usize];
        // The masked 90.0 must not become the row max...
        assert_eq!(rowmax_prefix(&a, &vis), vec![2.0]);
        // ...and masked entries carry exactly zero weight.
        let p = exp_sub_rowbias_prefix(&a, &[2.0], &vis, Format::F16);
        assert_eq!(p.at(0, 0), Format::F16.round((-1.0f32).exp()));
        assert_eq!(p.at(0, 1), 1.0);
        assert_eq!(p.at(0, 2), 0.0);
        assert_eq!(p.at(0, 3), 0.0);
        // Empty prefix: −inf max, all-zero row.
        assert_eq!(rowmax_prefix(&a, &[0]), vec![f32::NEG_INFINITY]);
        let z = exp_sub_rowbias_prefix(&a, &[f32::NEG_INFINITY], &[0], Format::F16);
        assert!(z.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let a = m(2, 4, &[0.1, 2.0, -3.0, 0.7, 100.0, 100.0, 100.0, 100.0]);
        let s = softmax_rows_f32(&a);
        for r in 0..2 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        assert!((s.at(1, 0) - 0.25).abs() < 1e-6);
    }

    #[test]
    fn scale_add_update() {
        let mut acc = m(1, 2, &[2.0, 4.0]);
        let add = m(1, 2, &[1.0, 1.0]);
        scale_add_rows(&mut acc, &[0.5], &add, Format::F32);
        assert_eq!(acc, m(1, 2, &[2.0, 3.0]));
    }

    #[test]
    fn div_rows_final_normalize() {
        let o = m(2, 2, &[2.0, 4.0, 9.0, 3.0]);
        let d = div_rows(&o, &[2.0, 3.0], Format::F32);
        assert_eq!(d, m(2, 2, &[1.0, 2.0, 3.0, 1.0]));
    }
}
