//! Explicitly vectorized GEMM microkernels (S2b): x86-64 AVX2 with
//! runtime detection and a portable scalar fallback.
//!
//! The f32-accumulate GEMM cores in [`super::gemm`] dispatch here once per
//! call ([`enabled`]) and then run the whole panel through these
//! microkernels. Bit-identity to the scalar cores is a hard contract, not
//! a tolerance: every kernel reproduces the *exact* f32 operation sequence
//! of its scalar twin —
//!
//! * [`dot`] mirrors `gemm::dot_f32`'s eight independent lane accumulators
//!   (`_mm256_mul_ps` + `_mm256_add_ps`, never FMA — a fused
//!   multiply-add would skip the intermediate product rounding and break
//!   bitwise equality), then reduces the eight lanes **sequentially** in
//!   the same order as the scalar `acc.iter().sum::<f32>()`, then walks
//!   the `len % 8` remainder scalarly;
//! * [`dot4`] runs four of those accumulations concurrently over one
//!   packed 4-row K-panel (the register-blocking win: the A row is loaded
//!   once per 8-column step instead of four times), each output element
//!   bit-identical to a standalone [`dot`];
//! * [`axpy`] vectorizes the `c[j] += a·b[j]` update of the P·V GEMM —
//!   element-wise independent, so lane-parallel evaluation is trivially
//!   bit-identical.
//!
//! Store rounding never happens here: the GEMM cores round results through
//! [`crate::numerics::round::RoundSpec::round4`] / `round`, whose lanes
//! are the scalar bitwise converters by definition.
//!
//! ## Dispatch and the force switch
//!
//! [`enabled`] = AVX2 detected (cached `is_x86_feature_detected!`) AND not
//! disabled by `PASA_SIMD=0` AND not forced off programmatically.
//! [`set_force`] is the test hook (mirroring `pool::set_parallel`) that
//! lets the SIMD-vs-scalar twin tests pin both paths in one process;
//! [`test_mode_guard`] serializes tests that toggle the process-global
//! switch. Under Miri, and on non-x86-64 targets, detection reports
//! `false` and every public kernel runs its scalar fallback — the wrappers
//! are safe to call unconditionally.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Force-switch states (see [`set_force`]).
const AUTO: u8 = 0;
const FORCE_OFF: u8 = 1;
const FORCE_ON: u8 = 2;

static FORCE: AtomicU8 = AtomicU8::new(AUTO);

/// Programmatically override SIMD dispatch: `Some(true)` forces the
/// vector path on (still subject to hardware detection — forcing AVX2
/// onto a CPU without it is not a thing), `Some(false)` forces the scalar
/// fallback, `None` restores auto (detection + `PASA_SIMD` env).
/// Process-global; tests that toggle it hold [`test_mode_guard`].
pub fn set_force(mode: Option<bool>) {
    let v = match mode {
        None => AUTO,
        Some(false) => FORCE_OFF,
        Some(true) => FORCE_ON,
    };
    FORCE.store(v, Ordering::SeqCst);
}

/// Cached hardware capability: true iff this is an x86-64 CPU with AVX2
/// (always false under Miri, which interprets the scalar fallback).
#[cfg(all(target_arch = "x86_64", not(miri)))]
pub fn detected() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
}

/// Cached hardware capability (non-x86-64 / Miri: never available).
#[cfg(any(not(target_arch = "x86_64"), miri))]
pub fn detected() -> bool {
    false
}

/// `PASA_SIMD=0` (or `off`/`false`) disables the vector path — the CI
/// scalar-fallback leg. Read once per process.
fn env_disabled() -> bool {
    static DISABLED: OnceLock<bool> = OnceLock::new();
    *DISABLED.get_or_init(|| {
        matches!(
            std::env::var("PASA_SIMD").as_deref(),
            Ok("0") | Ok("off") | Ok("false")
        )
    })
}

/// Should the GEMM cores take the vector path for this call? One relaxed
/// atomic load — the cores sample it once per GEMM, not per element.
#[inline]
pub fn enabled() -> bool {
    match FORCE.load(Ordering::Relaxed) {
        FORCE_OFF => false,
        FORCE_ON => detected(),
        _ => detected() && !env_disabled(),
    }
}

/// Serialize tests that toggle the process-global [`set_force`] switch
/// (the `pool::test_mode_guard` pattern). Lock poisoning from a failed
/// sibling test is ignored — the guard only provides mutual exclusion.
pub fn test_mode_guard() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    match GUARD.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::{
        __m256, _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_setzero_ps,
        _mm256_storeu_ps,
    };

    /// AVX2 twin of `gemm::dot_f32`: eight lane accumulators over
    /// 8-element chunks, sequential lane reduction, scalar remainder.
    ///
    /// # Safety
    /// The CPU must support AVX2 (`target_feature` contract); callers
    /// gate on [`super::detected`].
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot(ar: &[f32], br: &[f32]) -> f32 {
        let n = ar.len().min(br.len());
        let chunks = n / 8;
        let mut lanes = [0.0f32; 8];
        // SAFETY: every `loadu`/`storeu` reads or writes exactly 8 f32s at
        // `base + w*8` with `w < chunks = n/8`, so the accesses stay inside
        // `ar`/`br` (length ≥ n) and the 8-slot `lanes` array; unaligned
        // forms are used so no alignment requirement exists. AVX2 is
        // guaranteed by this fn's `target_feature` contract.
        unsafe {
            let mut acc = _mm256_setzero_ps();
            let ap = ar.as_ptr();
            let bp = br.as_ptr();
            for w in 0..chunks {
                let va = _mm256_loadu_ps(ap.add(w * 8));
                let vb = _mm256_loadu_ps(bp.add(w * 8));
                // mul then add — never FMA — to match the scalar core's
                // `acc[t] += a*b` (two IEEE roundings per step).
                acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            }
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        }
        // Sequential lane fold + scalar remainder: the scalar core's exact
        // reduction order.
        let mut s = lanes.iter().sum::<f32>();
        for t in chunks * 8..n {
            s += ar[t] * br[t];
        }
        s
    }

    /// Four concurrent [`dot`] accumulations of one A row against a packed
    /// 4-row K-panel. Each lane register accumulates exactly one row's
    /// product stream, so `out[r]` is bit-identical to `dot(ar, b_r)`.
    ///
    /// # Safety
    /// The CPU must support AVX2; callers gate on [`super::detected`].
    /// Each `b` row must be at least `ar.len()` long (asserted).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4(
        ar: &[f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) -> [f32; 4] {
        let n = ar.len();
        assert!(b0.len() >= n && b1.len() >= n && b2.len() >= n && b3.len() >= n);
        let chunks = n / 8;
        let mut lanes = [[0.0f32; 8]; 4];
        // SAFETY: all loads/stores touch exactly 8 f32s at `base + w*8`
        // with `w < chunks = n/8`, in bounds of `ar` (length n), each `b`
        // row (length ≥ n, asserted above) and the 8-slot lane arrays;
        // unaligned forms carry no alignment requirement. AVX2 is
        // guaranteed by this fn's `target_feature` contract.
        unsafe {
            let mut c0: __m256 = _mm256_setzero_ps();
            let mut c1 = _mm256_setzero_ps();
            let mut c2 = _mm256_setzero_ps();
            let mut c3 = _mm256_setzero_ps();
            let ap = ar.as_ptr();
            for w in 0..chunks {
                let va = _mm256_loadu_ps(ap.add(w * 8));
                c0 = _mm256_add_ps(c0, _mm256_mul_ps(va, _mm256_loadu_ps(b0.as_ptr().add(w * 8))));
                c1 = _mm256_add_ps(c1, _mm256_mul_ps(va, _mm256_loadu_ps(b1.as_ptr().add(w * 8))));
                c2 = _mm256_add_ps(c2, _mm256_mul_ps(va, _mm256_loadu_ps(b2.as_ptr().add(w * 8))));
                c3 = _mm256_add_ps(c3, _mm256_mul_ps(va, _mm256_loadu_ps(b3.as_ptr().add(w * 8))));
            }
            _mm256_storeu_ps(lanes[0].as_mut_ptr(), c0);
            _mm256_storeu_ps(lanes[1].as_mut_ptr(), c1);
            _mm256_storeu_ps(lanes[2].as_mut_ptr(), c2);
            _mm256_storeu_ps(lanes[3].as_mut_ptr(), c3);
        }
        let rows = [b0, b1, b2, b3];
        let mut out = [0.0f32; 4];
        for r in 0..4 {
            let mut s = lanes[r].iter().sum::<f32>();
            for t in chunks * 8..n {
                s += ar[t] * rows[r][t];
            }
            out[r] = s;
        }
        out
    }

    /// Vectorized `c[j] += al * b[j]` — element-wise independent, so the
    /// lane split cannot change any element's value sequence.
    ///
    /// # Safety
    /// The CPU must support AVX2; callers gate on [`super::detected`].
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy(c: &mut [f32], al: f32, b: &[f32]) {
        let n = c.len().min(b.len());
        let chunks = n / 8;
        // SAFETY: loads/stores touch exactly 8 f32s at `base + w*8` with
        // `w < chunks = n/8`, in bounds of both slices (length ≥ n); `c`
        // is borrowed mutably so no aliasing read can observe the store.
        // AVX2 is guaranteed by this fn's `target_feature` contract.
        unsafe {
            let va = _mm256_set1_ps(al);
            let bp = b.as_ptr();
            let cp = c.as_mut_ptr();
            for w in 0..chunks {
                let vc = _mm256_loadu_ps(cp.add(w * 8) as *const f32);
                let vb = _mm256_loadu_ps(bp.add(w * 8));
                _mm256_storeu_ps(cp.add(w * 8), _mm256_add_ps(vc, _mm256_mul_ps(va, vb)));
            }
        }
        for t in chunks * 8..n {
            c[t] += al * b[t];
        }
    }
}

// lint: hot-path — SIMD microkernel wrappers of the GEMM inner loops.
/// Vector dot product, bit-identical to `gemm::dot_f32` by construction.
/// Safe to call anywhere: falls back to the scalar core when AVX2 is
/// absent (so dispatch mistakes degrade to slow, never to unsound).
#[inline]
pub fn dot(ar: &[f32], br: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if detected() {
        // SAFETY: `detected()` verified AVX2 on this CPU — the
        // `target_feature(enable = "avx2")` contract of `avx2::dot`.
        return unsafe { avx2::dot(ar, br) };
    }
    super::gemm::dot_f32(ar, br)
}

/// One A row against a packed 4-row K-panel; `out[r]` is bit-identical to
/// [`dot`]`(ar, b_r)`. Scalar fallback when AVX2 is absent.
#[inline]
pub fn dot4(ar: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    #[cfg(target_arch = "x86_64")]
    if detected() {
        // SAFETY: `detected()` verified AVX2 on this CPU — the
        // `target_feature(enable = "avx2")` contract of `avx2::dot4`.
        return unsafe { avx2::dot4(ar, b0, b1, b2, b3) };
    }
    [
        super::gemm::dot_f32(ar, b0),
        super::gemm::dot_f32(ar, b1),
        super::gemm::dot_f32(ar, b2),
        super::gemm::dot_f32(ar, b3),
    ]
}

/// Vectorized `c[j] += al * b[j]` row update (the P·V accumulation).
/// Scalar fallback when AVX2 is absent.
#[inline]
pub fn axpy(c: &mut [f32], al: f32, b: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if detected() {
        // SAFETY: `detected()` verified AVX2 on this CPU — the
        // `target_feature(enable = "avx2")` contract of `avx2::axpy`.
        unsafe { avx2::axpy(c, al, b) };
        return;
    }
    for (x, y) in c.iter_mut().zip(b) {
        *x += al * y;
    }
}
// lint: end-hot-path

#[cfg(test)]
mod tests {
    use super::super::gemm::dot_f32;
    use super::*;

    fn seq(n: usize, salt: u64) -> Vec<f32> {
        // Deterministic, sign-mixed, non-representable-sum data so any
        // reordering of the accumulation shows up in the bits.
        (0..n)
            .map(|i| {
                let h = (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ salt;
                ((h % 2000) as f32 - 1000.0) * 1.7e-3 + (h % 7) as f32 * 0.311
            })
            .collect()
    }

    #[test]
    fn dot_matches_scalar_bitwise_across_lengths() {
        // Lengths cover: empty, sub-chunk, exact chunks, ragged remainders.
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 24, 31, 64, 67] {
            let a = seq(n, 1);
            let b = seq(n, 2);
            let want = dot_f32(&a, &b);
            let got = dot(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn dot4_lanes_match_independent_dots_bitwise() {
        for n in [0usize, 5, 8, 19, 32, 45] {
            let a = seq(n, 3);
            let rows: Vec<Vec<f32>> = (0..4).map(|r| seq(n, 10 + r)).collect();
            let got = dot4(&a, &rows[0], &rows[1], &rows[2], &rows[3]);
            for r in 0..4 {
                let want = dot_f32(&a, &rows[r]);
                assert_eq!(got[r].to_bits(), want.to_bits(), "n={n} row {r}");
            }
        }
    }

    #[test]
    fn axpy_matches_scalar_bitwise() {
        for n in [0usize, 6, 8, 21, 40] {
            let base = seq(n, 20);
            let b = seq(n, 21);
            let al = 0.73f32;
            let mut want = base.clone();
            for (x, y) in want.iter_mut().zip(&b) {
                *x += al * y;
            }
            let mut got = base.clone();
            axpy(&mut got, al, &b);
            let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "n={n}");
        }
    }

    #[test]
    fn force_switch_controls_dispatch() {
        let _g = test_mode_guard();
        set_force(Some(false));
        assert!(!enabled(), "force-off must win over detection");
        set_force(Some(true));
        assert_eq!(
            enabled(),
            detected(),
            "force-on is still bounded by hardware detection"
        );
        set_force(None);
    }
}
