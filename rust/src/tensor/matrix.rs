//! Row-major matrix container for the attention lab (S2).
//!
//! All storage is `f32`; low-precision formats are emulated by keeping the
//! values on the target format's grid (see `crate::numerics`). This makes a
//! "FP16 matrix" a `Matrix` whose every element satisfies
//! `x == round_f16(x)` — bit-exact w.r.t. hardware FP16 while keeping the
//! hot loops in native f32.

use crate::numerics::Format;

/// Dense row-major `rows x cols` matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "matrix shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn full(rows: usize, cols: usize, v: f32) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![v; rows * cols],
        }
    }

    /// Identity matrix of size n.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Horizontal slice of rows `[r0, r1)` (copy).
    pub fn rows_slice(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Vertical slice of columns `[c0, c1)` (copy) — e.g. one head's
    /// `d_head` window of a packed `(seq, n_heads·d_head)` activation.
    pub fn cols_slice(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Matrix::zeros(self.rows, c1 - c0);
        for r in 0..self.rows {
            out.row_mut(r)
                .copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Round every element onto `fmt`'s grid (in place).
    pub fn round_to(&mut self, fmt: Format) {
        if fmt == Format::F32 {
            return;
        }
        for x in &mut self.data {
            *x = fmt.round(*x);
        }
    }

    /// Rounded copy.
    pub fn rounded(&self, fmt: Format) -> Matrix {
        let mut m = self.clone();
        m.round_to(fmt);
        m
    }

    pub fn is_on_grid(&self, fmt: Format) -> bool {
        self.data
            .iter()
            .all(|&x| x.is_nan() || fmt.round(x) == x || x.to_bits() == fmt.round(x).to_bits())
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn transpose_round_trip() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.at(2, 1), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn eye_and_slice() {
        let i = Matrix::eye(3);
        assert_eq!(i.at(1, 1), 1.0);
        assert_eq!(i.at(1, 2), 0.0);
        let s = i.rows_slice(1, 3);
        assert_eq!(s.shape(), (2, 3));
        assert_eq!(s.at(0, 1), 1.0);
    }

    #[test]
    fn rounding_to_grid() {
        let mut m = Matrix::from_vec(1, 2, vec![1.0001, 70000.0]);
        m.round_to(Format::F16);
        assert_eq!(m.at(0, 0), 1.0); // 1.0001 is within a half-ulp of 1.0
        assert!(m.at(0, 1).is_infinite()); // overflow boundary 65504
        assert!(m.is_on_grid(Format::F16));
    }
}
