//! Row-major matrix container for the attention lab (S2).
//!
//! All storage is `f32`; low-precision formats are emulated by keeping the
//! values on the target format's grid (see `crate::numerics`). This makes a
//! "FP16 matrix" a `Matrix` whose every element satisfies
//! `x == round_f16(x)` — bit-exact w.r.t. hardware FP16 while keeping the
//! hot loops in native f32.

use crate::numerics::Format;

/// Dense row-major `rows x cols` matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "matrix shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn full(rows: usize, cols: usize, v: f32) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![v; rows * cols],
        }
    }

    /// Identity matrix of size n.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Horizontal slice of rows `[r0, r1)` (copy).
    pub fn rows_slice(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Vertical slice of columns `[c0, c1)` (copy) — e.g. one head's
    /// `d_head` window of a packed `(seq, n_heads·d_head)` activation.
    pub fn cols_slice(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Matrix::zeros(self.rows, c1 - c0);
        for r in 0..self.rows {
            out.row_mut(r)
                .copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Reshape in place to `(rows, cols)`, zero-filled, **reusing the
    /// backing allocation** — the workspace-buffer primitive of the
    /// zero-allocation attention hot path. Equivalent to `*self =
    /// Matrix::zeros(rows, cols)` except the heap block is kept once the
    /// buffer has grown to its steady-state size.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Reshape in place **without** zero-filling the retained storage —
    /// for consumers that overwrite every element (the dense GEMM and
    /// softmax kernels), sparing the hot loop one memset per block.
    /// Storage grown beyond the previous length is zeroed; the retained
    /// prefix keeps stale values, so callers must write all elements.
    pub fn reshape(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Become a copy of `src`'s rows `[r0, r1)`, reusing the backing
    /// allocation — the reusable-buffer twin of [`Self::rows_slice`].
    pub fn copy_rows_from(&mut self, src: &Matrix, r0: usize, r1: usize) {
        assert!(r0 <= r1 && r1 <= src.rows);
        self.rows = r1 - r0;
        self.cols = src.cols;
        self.data.clear();
        self.data
            .extend_from_slice(&src.data[r0 * src.cols..r1 * src.cols]);
    }

    /// Borrowed view of rows `[r0, r1)` — no copy, no allocation. The
    /// GEMM `_into` kernels take their A operand this way so the
    /// attention Q-block loop never materializes a row slice.
    #[inline]
    pub fn rows_ref(&self, r0: usize, r1: usize) -> RowsRef<'_> {
        assert!(r0 <= r1 && r1 <= self.rows);
        RowsRef {
            rows: r1 - r0,
            cols: self.cols,
            data: &self.data[r0 * self.cols..r1 * self.cols],
        }
    }

    /// Borrowed view of the whole matrix.
    #[inline]
    pub fn as_rows_ref(&self) -> RowsRef<'_> {
        self.rows_ref(0, self.rows)
    }

    /// Round every element onto `fmt`'s grid (in place; the format branch
    /// is taken once for the whole buffer).
    pub fn round_to(&mut self, fmt: Format) {
        fmt.round_slice(&mut self.data);
    }

    /// Rounded copy.
    pub fn rounded(&self, fmt: Format) -> Matrix {
        let mut m = self.clone();
        m.round_to(fmt);
        m
    }

    pub fn is_on_grid(&self, fmt: Format) -> bool {
        self.data
            .iter()
            .all(|&x| x.is_nan() || fmt.round(x) == x || x.to_bits() == fmt.round(x).to_bits())
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
}

/// Borrowed, row-major view over a contiguous row range of a [`Matrix`]
/// (or any row-major `f32` buffer). `Copy`, allocation-free — the A
/// operand of the GEMM `_into` kernels.
#[derive(Clone, Copy, Debug)]
pub struct RowsRef<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

impl<'a> RowsRef<'a> {
    #[inline]
    pub fn row(&self, r: usize) -> &'a [f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn transpose_round_trip() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.at(2, 1), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn eye_and_slice() {
        let i = Matrix::eye(3);
        assert_eq!(i.at(1, 1), 1.0);
        assert_eq!(i.at(1, 2), 0.0);
        let s = i.rows_slice(1, 3);
        assert_eq!(s.shape(), (2, 3));
        assert_eq!(s.at(0, 1), 1.0);
    }

    #[test]
    fn reset_and_copy_reuse_the_allocation() {
        let mut m = Matrix::zeros(8, 8);
        let cap = m.data.capacity();
        m.reset(4, 6);
        assert_eq!(m.shape(), (4, 6));
        assert!(m.data.iter().all(|&x| x == 0.0));
        assert_eq!(m.data.capacity(), cap, "reset must not reallocate");
        let src = Matrix::from_vec(3, 4, (0..12).map(|i| i as f32).collect());
        m.copy_rows_from(&src, 1, 3);
        assert_eq!(m.shape(), (2, 4));
        assert_eq!(m.data, src.rows_slice(1, 3).data);
        assert_eq!(m.data.capacity(), cap, "copy_rows_from must not reallocate");
        // reshape keeps stale storage (overwrite-all consumers) but zeroes
        // genuinely new tail elements, and never reallocates once warm.
        m.reshape(3, 4);
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(&m.data[..8], &src.rows_slice(1, 3).data[..], "retained prefix");
        assert!(m.data[8..].iter().all(|&x| x == 0.0), "grown tail zeroed");
        assert_eq!(m.data.capacity(), cap, "reshape must not reallocate");
    }

    #[test]
    fn rows_ref_views_match_slices() {
        let m = Matrix::from_vec(3, 4, (0..12).map(|i| i as f32).collect());
        let v = m.rows_ref(1, 3);
        assert_eq!(v.shape(), (2, 4));
        assert_eq!(v.row(0), m.row(1));
        assert_eq!(v.row(1), m.row(2));
        let all = m.as_rows_ref();
        assert_eq!(all.rows, 3);
        assert_eq!(all.data, &m.data[..]);
    }

    #[test]
    fn rounding_to_grid() {
        let mut m = Matrix::from_vec(1, 2, vec![1.0001, 70000.0]);
        m.round_to(Format::F16);
        assert_eq!(m.at(0, 0), 1.0); // 1.0001 is within a half-ulp of 1.0
        assert!(m.at(0, 1).is_infinite()); // overflow boundary 65504
        assert!(m.is_on_grid(Format::F16));
    }
}
