//! Tensor mini-library (S2): row-major matrices, mixed-precision GEMM and
//! the vector-unit ops used by the FA/PASA inner loops.

pub mod gemm;
pub mod matrix;
pub mod ops;
pub mod simd;

pub use gemm::{
    matmul_nn, matmul_nn_into, matmul_nt, matmul_nt_into, matmul_nt_prefix,
    matmul_nt_prefix_into, matmul_nt_stats, matmul_nt_stats_into, GemmPrecision, GemmStats,
};
pub use matrix::{Matrix, RowsRef};
