//! Tensor mini-library (S2): row-major matrices, mixed-precision GEMM and
//! the vector-unit ops used by the FA/PASA inner loops.

pub mod gemm;
pub mod matrix;
pub mod ops;

pub use gemm::{matmul_nn, matmul_nt, matmul_nt_prefix, matmul_nt_stats, GemmPrecision, GemmStats};
pub use matrix::Matrix;
