//! Mixed-precision GEMM (S2).
//!
//! The paper's precision allocations (Figs. 1–3) differ in *where* the
//! matmul accumulates and *what format* its result is stored in:
//!
//! * matrix engines (NPU CUBE / GPU TC) take FP16 inputs and accumulate in
//!   either FP32 (Figs. 1–2) or FP16 (Fig. 3, "fully low precision"),
//! * the result is stored to FP32 (Fig. 1) or FP16 (Figs. 2–3), where the
//!   FP16 store is the overflow site the paper analyses (S = QK^T can
//!   exceed 65504 even when inputs are modest — the GEMM "amplifier").
//!
//! `matmul_nt`/`matmul_nn` emulate all of these bit-exactly: inputs are
//! assumed on the input format's grid already; `acc` controls per-step
//! rounding of products and partial sums; `store` rounds the final element.
//!
//! ## Hot-path layout
//!
//! Every kernel here comes in two shapes: the classic allocating entry
//! (`matmul_nt`, `matmul_nt_stats`, …) and an `_into` variant that writes
//! into a caller-owned buffer (reused via [`Matrix::reset`]) and takes its
//! A operand as a borrowed [`RowsRef`] — the attention Q-block loop runs
//! entirely through the `_into` forms, so the inner KV sweep performs no
//! heap allocation. The per-element `match` on the accumulate/store
//! formats is hoisted out of the loops: each entry dispatches **once per
//! call** through [`crate::mono_format!`] into a monomorphized core whose
//! rounding inlines to the bitwise converters.

use super::matrix::{Matrix, RowsRef};
use super::simd;
use crate::numerics::round::RoundSpec;
use crate::numerics::Format;

/// Accumulation and storage precision of one GEMM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmPrecision {
    /// Format products and running sums are rounded to after every step.
    pub acc: Format,
    /// Format the final element is rounded to on store.
    pub store: Format,
}

impl GemmPrecision {
    pub const F32: GemmPrecision = GemmPrecision {
        acc: Format::F32,
        store: Format::F32,
    };
    /// FP16 inputs, FP32 accumulate, FP16 store — Fig. 2 ("partially low
    /// precision"): the overflow happens at the store.
    pub const ACC32_STORE16: GemmPrecision = GemmPrecision {
        acc: Format::F32,
        store: Format::F16,
    };
    /// Fully FP16 — Fig. 3: every product and partial sum rounds to FP16.
    pub const FULL16: GemmPrecision = GemmPrecision {
        acc: Format::F16,
        store: Format::F16,
    };
}

/// Running pre-store statistics of one GEMM — the paper's overflow
/// instrumentation point: |S| is checked against the store format's
/// overflow boundary *before* the store rounding loses the magnitude.
#[derive(Clone, Copy, Debug, Default)]
pub struct GemmStats {
    /// Largest pre-store |value| observed (can be `inf` when the emulated
    /// low-precision accumulator itself overflowed).
    pub max_abs: f32,
    /// Number of pre-store values whose magnitude exceeded the boundary
    /// the caller instrumented against (the FP16 65504 in the lab).
    pub overflow_events: usize,
}

impl GemmStats {
    #[inline]
    fn record(&mut self, pre_store: f32, boundary: f32) {
        let a = pre_store.abs();
        if a > self.max_abs {
            self.max_abs = a;
        }
        if a > boundary {
            self.overflow_events += 1;
        }
    }

    pub fn merge(&mut self, o: &GemmStats) {
        if o.max_abs > self.max_abs {
            self.max_abs = o.max_abs;
        }
        self.overflow_events += o.overflow_events;
    }
}

// lint: hot-path — dot-product cores of every GEMM inner loop.
/// One dot product `A[i]·B[j]` under the f32-accumulate fast path — the
/// exact accumulation order of [`matmul_nt`]'s vectorized loop, factored
/// out so the instrumented/masked variants stay bit-identical to it. Also
/// the bit-identity reference (and non-x86-64 fallback) of the AVX2
/// microkernels in [`super::simd`].
#[inline]
pub(crate) fn dot_f32(ar: &[f32], br: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let mut ac = ar.chunks_exact(8);
    let mut bc = br.chunks_exact(8);
    for (aw, bw) in (&mut ac).zip(&mut bc) {
        for t in 0..8 {
            acc[t] += aw[t] * bw[t];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        s += x * y;
    }
    s
}

/// One dot product under emulated low-precision accumulation (sequential
/// systolic order), monomorphized over the accumulate format — the exact
/// order of the pre-refactor `dot_emulated`, with the per-element format
/// `match` hoisted to the caller's one-time dispatch.
#[inline]
fn dot_emulated<A: RoundSpec>(ar: &[f32], br: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (x, y) in ar.iter().zip(br) {
        let prod = A::round(x * y);
        s = A::round(s + prod);
    }
    s
}
// lint: end-hot-path

// ---- C = A · Bᵀ ---------------------------------------------------------

/// C = A · Bᵀ with per-step precision emulation.
/// A is (m × k), B is (n × k), C is (m × n): `C[i][j] = Σ_l A[i][l]·B[j][l]`.
///
/// This is the natural layout for S = Q·Kᵀ (both Q and K are (seq × d)).
pub fn matmul_nt(a: &Matrix, b: &Matrix, p: GemmPrecision) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_nt_into(a.as_rows_ref(), b, p, &mut c);
    c
}

// lint: hot-path — buffer-reusing score GEMM; reshape is amortized.
/// Buffer-reusing [`matmul_nt`]: `c` is reshaped in place (no allocation
/// once warm) and the format dispatch happens once per call.
pub fn matmul_nt_into(a: RowsRef<'_>, b: &Matrix, p: GemmPrecision, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_nt: inner dims differ");
    c.reshape(a.rows, b.rows); // every element written below
    crate::mono_format!(p.store, S => match p.acc {
        // Fast path: native f32 accumulate, round only on store.
        // Eight independent accumulators break the strict-FP reduction
        // chain so the loop auto-vectorizes (§Perf: ~2.5x on the lab's
        // GEMM-bound experiments). Matrix engines don't specify an
        // accumulation order, so any f32 summation order is a valid
        // emulation of the FP32-accumulate allocations.
        Format::F32 => nt_core_f32::<S>(a, b, c),
        // Emulated low-precision accumulate: round every product and
        // every partial sum (sequential order, like a systolic chain).
        acc => crate::mono_format!(acc, A => nt_core_emu::<A, S>(a, b, c)),
    });
}

fn nt_core_f32<S: RoundSpec>(a: RowsRef<'_>, b: &Matrix, c: &mut Matrix) {
    if simd::enabled() {
        return nt_core_f32_simd::<S>(a, b, c);
    }
    for i in 0..a.rows {
        let ar = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..b.rows {
            crow[j] = S::round(dot_f32(ar, b.row(j)));
        }
    }
}

/// AVX2-blocked twin of [`nt_core_f32`]: B is row-major, so four
/// consecutive B rows form one contiguous packed K-panel sliced straight
/// out of `b.data` — the workspace K-block the attention loop stages is
/// consumed 4 rows at a time by [`simd::dot4`], and the results round
/// through the vector-lane [`RoundSpec::round4`]. Bit-identical to the
/// scalar core: each `dot4` lane reproduces `dot_f32` exactly and `round4`
/// is per-lane scalar rounding by definition.
fn nt_core_f32_simd<S: RoundSpec>(a: RowsRef<'_>, b: &Matrix, c: &mut Matrix) {
    let (n, k) = (b.rows, b.cols);
    for i in 0..a.rows {
        let ar = a.row(i);
        let crow = c.row_mut(i);
        let mut j = 0;
        while j + 4 <= n {
            let panel = &b.data[j * k..(j + 4) * k];
            let d = simd::dot4(ar, &panel[..k], &panel[k..2 * k], &panel[2 * k..3 * k], &panel[3 * k..]);
            crow[j..j + 4].copy_from_slice(&S::round4(d));
            j += 4;
        }
        while j < n {
            crow[j] = S::round(simd::dot(ar, b.row(j)));
            j += 1;
        }
    }
}

fn nt_core_emu<A: RoundSpec, S: RoundSpec>(a: RowsRef<'_>, b: &Matrix, c: &mut Matrix) {
    for i in 0..a.rows {
        let ar = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..b.rows {
            crow[j] = S::round(dot_emulated::<A>(ar, b.row(j)));
        }
    }
}
// lint: end-hot-path

/// Dense C = A · Bᵀ with pre-store statistics.
///
/// Bit-identical to [`matmul_nt`]; additionally records max |value| and
/// overflow events against `boundary` over the columns `j < stat_vis[i]`
/// of each row (`None` ⇒ every column). The masked attention kernels pass
/// the per-row visible prefix so never-attended score regions don't feed
/// the overflow guard, and PASA — which needs the *dense* block for its
/// pseudo-average — still reports visible-region telemetry only.
pub fn matmul_nt_stats(
    a: &Matrix,
    b: &Matrix,
    p: GemmPrecision,
    stat_vis: Option<&[usize]>,
    boundary: f32,
    stats: &mut GemmStats,
) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_nt_stats_into(a.as_rows_ref(), b, p, stat_vis, boundary, stats, &mut c);
    c
}

// lint: hot-path — instrumented score GEMM of the attention KV sweep.
/// Buffer-reusing [`matmul_nt_stats`] — the attention score GEMM of the
/// zero-allocation hot path.
pub fn matmul_nt_stats_into(
    a: RowsRef<'_>,
    b: &Matrix,
    p: GemmPrecision,
    stat_vis: Option<&[usize]>,
    boundary: f32,
    stats: &mut GemmStats,
    c: &mut Matrix,
) {
    assert_eq!(a.cols, b.cols, "matmul_nt_stats: inner dims differ");
    if let Some(vis) = stat_vis {
        assert_eq!(vis.len(), a.rows, "matmul_nt_stats: vis length mismatch");
    }
    c.reshape(a.rows, b.rows); // every element written below
    crate::mono_format!(p.store, S => match p.acc {
        Format::F32 => nt_stats_core_f32::<S>(a, b, stat_vis, boundary, stats, c),
        acc => crate::mono_format!(
            acc,
            A => nt_stats_core_emu::<A, S>(a, b, stat_vis, boundary, stats, c)
        ),
    });
}

fn nt_stats_core_f32<S: RoundSpec>(
    a: RowsRef<'_>,
    b: &Matrix,
    stat_vis: Option<&[usize]>,
    boundary: f32,
    stats: &mut GemmStats,
    c: &mut Matrix,
) {
    if simd::enabled() {
        return nt_stats_core_f32_simd::<S>(a, b, stat_vis, boundary, stats, c);
    }
    let n = b.rows;
    for i in 0..a.rows {
        let ar = a.row(i);
        let limit = stat_vis.map_or(n, |v| v[i].min(n));
        let crow = c.row_mut(i);
        for j in 0..n {
            let s = dot_f32(ar, b.row(j));
            if j < limit {
                stats.record(s, boundary);
            }
            crow[j] = S::round(s);
        }
    }
}

/// AVX2-blocked twin of [`nt_stats_core_f32`]. Statistics are recorded on
/// the pre-store f32 panel values in ascending-`j` order — the scalar
/// core's exact record sequence — before the lane rounding.
fn nt_stats_core_f32_simd<S: RoundSpec>(
    a: RowsRef<'_>,
    b: &Matrix,
    stat_vis: Option<&[usize]>,
    boundary: f32,
    stats: &mut GemmStats,
    c: &mut Matrix,
) {
    let (n, k) = (b.rows, b.cols);
    for i in 0..a.rows {
        let ar = a.row(i);
        let limit = stat_vis.map_or(n, |v| v[i].min(n));
        let crow = c.row_mut(i);
        let mut j = 0;
        while j + 4 <= n {
            let panel = &b.data[j * k..(j + 4) * k];
            let d = simd::dot4(ar, &panel[..k], &panel[k..2 * k], &panel[2 * k..3 * k], &panel[3 * k..]);
            for (t, &s) in d.iter().enumerate() {
                if j + t < limit {
                    stats.record(s, boundary);
                }
            }
            crow[j..j + 4].copy_from_slice(&S::round4(d));
            j += 4;
        }
        while j < n {
            let s = simd::dot(ar, b.row(j));
            if j < limit {
                stats.record(s, boundary);
            }
            crow[j] = S::round(s);
            j += 1;
        }
    }
}

fn nt_stats_core_emu<A: RoundSpec, S: RoundSpec>(
    a: RowsRef<'_>,
    b: &Matrix,
    stat_vis: Option<&[usize]>,
    boundary: f32,
    stats: &mut GemmStats,
    c: &mut Matrix,
) {
    let n = b.rows;
    for i in 0..a.rows {
        let ar = a.row(i);
        let limit = stat_vis.map_or(n, |v| v[i].min(n));
        let crow = c.row_mut(i);
        for j in 0..n {
            let s = dot_emulated::<A>(ar, b.row(j));
            if j < limit {
                stats.record(s, boundary);
            }
            crow[j] = S::round(s);
        }
    }
}
// lint: end-hot-path

/// Prefix-masked C = A · Bᵀ: row `i` computes only columns `j < vis[i]`
/// and fills the rest with `fill` (−inf in the attention kernels, so
/// masked scores vanish under the softmax). Visible entries are
/// bit-identical to [`matmul_nt`]; the masked region never touches the
/// matrix engine — the flash-causal block-skipping optimization.
/// Statistics cover the computed region only.
pub fn matmul_nt_prefix(
    a: &Matrix,
    b: &Matrix,
    p: GemmPrecision,
    vis: &[usize],
    fill: f32,
    boundary: f32,
    stats: &mut GemmStats,
) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_nt_prefix_into(a.as_rows_ref(), b, p, vis, fill, boundary, stats, &mut c);
    c
}

// lint: hot-path — masked score GEMM of the flash-causal block skip.
/// Buffer-reusing [`matmul_nt_prefix`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_prefix_into(
    a: RowsRef<'_>,
    b: &Matrix,
    p: GemmPrecision,
    vis: &[usize],
    fill: f32,
    boundary: f32,
    stats: &mut GemmStats,
    c: &mut Matrix,
) {
    assert_eq!(a.cols, b.cols, "matmul_nt_prefix: inner dims differ");
    assert_eq!(vis.len(), a.rows, "matmul_nt_prefix: vis length mismatch");
    c.reshape(a.rows, b.rows); // computed prefix + fill cover every element
    crate::mono_format!(p.store, S => match p.acc {
        Format::F32 => nt_prefix_core_f32::<S>(a, b, vis, fill, boundary, stats, c),
        acc => crate::mono_format!(
            acc,
            A => nt_prefix_core_emu::<A, S>(a, b, vis, fill, boundary, stats, c)
        ),
    });
}

fn nt_prefix_core_f32<S: RoundSpec>(
    a: RowsRef<'_>,
    b: &Matrix,
    vis: &[usize],
    fill: f32,
    boundary: f32,
    stats: &mut GemmStats,
    c: &mut Matrix,
) {
    if simd::enabled() {
        return nt_prefix_core_f32_simd::<S>(a, b, vis, fill, boundary, stats, c);
    }
    let n = b.rows;
    for i in 0..a.rows {
        let ar = a.row(i);
        let limit = vis[i].min(n);
        let crow = c.row_mut(i);
        for j in 0..limit {
            let s = dot_f32(ar, b.row(j));
            stats.record(s, boundary);
            crow[j] = S::round(s);
        }
        for x in crow[limit..].iter_mut() {
            *x = fill;
        }
    }
}

/// AVX2-blocked twin of [`nt_prefix_core_f32`]: packed 4-row panels up to
/// the visible prefix, scalar dots to the ragged prefix end, then the fill
/// sweep. The masked region never touches a microkernel — the block-skip
/// property the scalar core guarantees.
fn nt_prefix_core_f32_simd<S: RoundSpec>(
    a: RowsRef<'_>,
    b: &Matrix,
    vis: &[usize],
    fill: f32,
    boundary: f32,
    stats: &mut GemmStats,
    c: &mut Matrix,
) {
    let (n, k) = (b.rows, b.cols);
    for i in 0..a.rows {
        let ar = a.row(i);
        let limit = vis[i].min(n);
        let crow = c.row_mut(i);
        let mut j = 0;
        while j + 4 <= limit {
            let panel = &b.data[j * k..(j + 4) * k];
            let d = simd::dot4(ar, &panel[..k], &panel[k..2 * k], &panel[2 * k..3 * k], &panel[3 * k..]);
            for &s in d.iter() {
                stats.record(s, boundary);
            }
            crow[j..j + 4].copy_from_slice(&S::round4(d));
            j += 4;
        }
        while j < limit {
            let s = simd::dot(ar, b.row(j));
            stats.record(s, boundary);
            crow[j] = S::round(s);
            j += 1;
        }
        for x in crow[limit..].iter_mut() {
            *x = fill;
        }
    }
}

fn nt_prefix_core_emu<A: RoundSpec, S: RoundSpec>(
    a: RowsRef<'_>,
    b: &Matrix,
    vis: &[usize],
    fill: f32,
    boundary: f32,
    stats: &mut GemmStats,
    c: &mut Matrix,
) {
    let n = b.rows;
    for i in 0..a.rows {
        let ar = a.row(i);
        let limit = vis[i].min(n);
        let crow = c.row_mut(i);
        for j in 0..limit {
            let s = dot_emulated::<A>(ar, b.row(j));
            stats.record(s, boundary);
            crow[j] = S::round(s);
        }
        for x in crow[limit..].iter_mut() {
            *x = fill;
        }
    }
}
// lint: end-hot-path

// ---- C = A · B ----------------------------------------------------------

/// C = A · B with per-step precision emulation.
/// A is (m × k), B is (k × n), C is (m × n).
pub fn matmul_nn(a: &Matrix, b: &Matrix, p: GemmPrecision) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_nn_into(a.as_rows_ref(), b, p, &mut c);
    c
}

// lint: hot-path — P·V GEMM of the attention output accumulation.
/// Buffer-reusing [`matmul_nn`] — the P·V GEMM of the zero-allocation hot
/// path. The f32-accumulate path accumulates directly into the (zeroed)
/// output rows instead of a per-row scratch vector, so it allocates
/// nothing; the emulated path walks B column-wise rather than paying a
/// transpose copy (same sequential rounding order as before).
pub fn matmul_nn_into(a: RowsRef<'_>, b: &Matrix, p: GemmPrecision, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul_nn: inner dims differ");
    c.reset(a.rows, b.cols);
    crate::mono_format!(p.store, S => match p.acc {
        Format::F32 => nn_core_f32::<S>(a, b, c),
        acc => crate::mono_format!(acc, A => nn_core_emu::<A, S>(a, b, c)),
    });
}

fn nn_core_f32<S: RoundSpec>(a: RowsRef<'_>, b: &Matrix, c: &mut Matrix) {
    if simd::enabled() {
        return nn_core_f32_simd::<S>(a, b, c);
    }
    // i-k-j loop order: stream B rows, accumulate into C rows (zeroed by
    // the caller's reset), round once at the end.
    let n = b.cols;
    for i in 0..a.rows {
        let ar = a.row(i);
        let crow = c.row_mut(i);
        for (l, &al) in ar.iter().enumerate() {
            if al == 0.0 {
                continue;
            }
            let br = b.row(l);
            for j in 0..n {
                crow[j] += al * br[j];
            }
        }
        if !S::IS_IDENTITY {
            for x in crow.iter_mut() {
                *x = S::round(*x);
            }
        }
    }
}

/// AVX2 twin of [`nn_core_f32`]: the same i-k-j sweep with the row update
/// vectorized by [`simd::axpy`]. Every `c[j]` sees the identical sequence
/// of `+= al·b[l][j]` operations (the axpy lanes are element-wise
/// independent), so bit-identity is structural.
fn nn_core_f32_simd<S: RoundSpec>(a: RowsRef<'_>, b: &Matrix, c: &mut Matrix) {
    for i in 0..a.rows {
        let ar = a.row(i);
        let crow = c.row_mut(i);
        for (l, &al) in ar.iter().enumerate() {
            if al == 0.0 {
                continue;
            }
            simd::axpy(crow, al, b.row(l));
        }
        if !S::IS_IDENTITY {
            for x in crow.iter_mut() {
                *x = S::round(*x);
            }
        }
    }
}

fn nn_core_emu<A: RoundSpec, S: RoundSpec>(a: RowsRef<'_>, b: &Matrix, c: &mut Matrix) {
    // Low-precision accumulate needs the dot-product order (i,j,l) so each
    // element's partial sums round sequentially; B is walked column-wise
    // (b[l][j]) — the same value sequence the old transpose-copy produced.
    let (n, k) = (b.cols, a.cols);
    for i in 0..a.rows {
        let ar = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            let mut s = 0.0f32;
            for l in 0..k {
                let prod = A::round(ar[l] * b.data[l * n + j]);
                s = A::round(s + prod);
            }
            crow[j] = S::round(s);
        }
    }
}
// lint: end-hot-path

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Matrix {
        Matrix::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn nt_matches_nn_on_transpose() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = m(4, 3, &[1., 0., 1., 0., 1., 0., 1., 1., 1., 2., 2., 2.]);
        let c1 = matmul_nt(&a, &b, GemmPrecision::F32);
        let c2 = matmul_nn(&a, &b.transpose(), GemmPrecision::F32);
        assert_eq!(c1, c2);
        assert_eq!(c1.at(0, 0), 4.0);
        assert_eq!(c1.at(1, 3), 30.0);
        // The emulated accumulator shares the sequential order between the
        // two layouts, so the agreement is bit-exact there too.
        let e1 = matmul_nt(&a, &b, GemmPrecision::FULL16);
        let e2 = matmul_nn(&a, &b.transpose(), GemmPrecision::FULL16);
        assert_eq!(e1, e2);
    }

    #[test]
    fn identity_is_neutral() {
        let a = m(2, 2, &[1.5, -2.0, 0.25, 7.0]);
        let c = matmul_nn(&a, &Matrix::eye(2), GemmPrecision::F32);
        assert_eq!(c, a);
    }

    #[test]
    fn store16_overflows_at_65504() {
        // Inputs are modest FP16 values but the dot product exceeds 65504:
        // the Fig. 2 allocation stores S in FP16 and must produce inf.
        let a = m(1, 128, &[30.0; 128]); // 30*30*128 = 115200 > 65504
        let b = m(1, 128, &[30.0; 128]);
        let c32 = matmul_nt(&a, &b, GemmPrecision::F32);
        assert_eq!(c32.at(0, 0), 115200.0);
        let c16 = matmul_nt(&a, &b, GemmPrecision::ACC32_STORE16);
        assert!(c16.at(0, 0).is_infinite());
    }

    #[test]
    fn full16_accumulation_rounds_each_step() {
        // 1 + 2^-11 absorbed at every add: summing 2048 copies of eps/?
        // Classic: in FP16, 1.0 + 0.0004883 (=2^-11) = 1.0, so summing one
        // 1.0 then many half-ulps stays exactly 1.0.
        let k = 64;
        let mut av = vec![2f32.powi(-11); k];
        av[0] = 1.0;
        let a = m(1, k, &av);
        let b = m(1, k, &vec![1.0; k]);
        let full = matmul_nt(&a, &b, GemmPrecision::FULL16);
        assert_eq!(full.at(0, 0), 1.0);
        let f32acc = matmul_nt(&a, &b, GemmPrecision::F32);
        assert!(f32acc.at(0, 0) > 1.03);
    }

    #[test]
    fn stats_variant_is_bit_identical_and_instrumented() {
        let a = m(2, 128, &[30.0f32; 256]);
        let b = m(3, 128, &[30.0f32; 384]);
        let plain = matmul_nt(&a, &b, GemmPrecision::ACC32_STORE16);
        let mut st = GemmStats::default();
        let c = matmul_nt_stats(&a, &b, GemmPrecision::ACC32_STORE16, None, 65504.0, &mut st);
        assert_eq!(plain, c);
        // 30*30*128 = 115200 pre-store, stored as inf: 6 events, max recorded.
        assert_eq!(st.overflow_events, 6);
        assert_eq!(st.max_abs, 115200.0);
    }

    #[test]
    fn prefix_variant_is_bit_identical_and_instrumented() {
        // The prefix-path twin of the test above (pins the hoisted-match
        // refactor on the *emulated* accumulator): visible entries must be
        // bit-identical to the dense GEMM, the masked region filled, and
        // the stats restricted to the computed region.
        let a = m(2, 128, &[30.0f32; 256]);
        let b = m(3, 128, &[30.0f32; 384]);
        let dense = matmul_nt(&a, &b, GemmPrecision::FULL16);
        let mut st = GemmStats::default();
        let vis = [2usize, 1];
        let c = matmul_nt_prefix(
            &a,
            &b,
            GemmPrecision::FULL16,
            &vis,
            f32::NEG_INFINITY,
            65504.0,
            &mut st,
        );
        for i in 0..2 {
            for j in 0..3 {
                if j < vis[i] {
                    assert_eq!(
                        c.at(i, j).to_bits(),
                        dense.at(i, j).to_bits(),
                        "visible ({i},{j})"
                    );
                } else {
                    assert_eq!(c.at(i, j), f32::NEG_INFINITY, "masked ({i},{j})");
                }
            }
        }
        // The FP16 accumulator itself overflows (900·128 ≫ 65504): every
        // computed element is an overflow event; masked ones must not be.
        assert_eq!(st.overflow_events, 3);
        assert!(st.max_abs.is_infinite());
    }

    #[test]
    fn stats_respect_visible_prefix() {
        let a = m(2, 128, &[30.0f32; 256]);
        let b = m(3, 128, &[30.0f32; 384]);
        let mut st = GemmStats::default();
        // Row 0 sees 1 column, row 1 sees none: one event only.
        let vis = [1usize, 0];
        let c = matmul_nt_stats(&a, &b, GemmPrecision::ACC32_STORE16, Some(&vis), 65504.0, &mut st);
        assert_eq!(st.overflow_events, 1);
        // The dense product is still fully computed (PASA needs it).
        assert!(c.at(1, 2).is_infinite());
    }

    #[test]
    fn prefix_variant_fills_masked_region() {
        let a = m(2, 16, &(0..32).map(|i| i as f32 * 0.1).collect::<Vec<_>>());
        let b = m(4, 16, &(0..64).map(|i| (i % 7) as f32 * 0.2).collect::<Vec<_>>());
        let dense = matmul_nt(&a, &b, GemmPrecision::F32);
        let mut st = GemmStats::default();
        let vis = [3usize, 1];
        let c = matmul_nt_prefix(
            &a,
            &b,
            GemmPrecision::F32,
            &vis,
            f32::NEG_INFINITY,
            65504.0,
            &mut st,
        );
        for i in 0..2 {
            for j in 0..4 {
                if j < vis[i] {
                    assert_eq!(c.at(i, j), dense.at(i, j), "visible ({i},{j})");
                } else {
                    assert_eq!(c.at(i, j), f32::NEG_INFINITY, "masked ({i},{j})");
                }
            }
        }
        assert_eq!(st.overflow_events, 0);
        assert!(st.max_abs > 0.0);
    }

    #[test]
    fn into_variants_reuse_dirty_buffers_bit_identically() {
        // The `_into` entries must be insensitive to the reused buffer's
        // previous shape and contents — the workspace-reuse contract.
        let a = m(3, 16, &(0..48).map(|i| (i as f32).sin() * 4.0).collect::<Vec<_>>());
        let b = m(5, 16, &(0..80).map(|i| (i as f32).cos() * 3.0).collect::<Vec<_>>());
        for p in [
            GemmPrecision::F32,
            GemmPrecision::ACC32_STORE16,
            GemmPrecision::FULL16,
        ] {
            let fresh = matmul_nt(&a, &b, p);
            let mut dirty = Matrix::full(9, 2, f32::NAN);
            matmul_nt_into(a.as_rows_ref(), &b, p, &mut dirty);
            assert_eq!(fresh, dirty);

            let mut st1 = GemmStats::default();
            let fresh = matmul_nt_stats(&a, &b, p, None, 65504.0, &mut st1);
            let mut st2 = GemmStats::default();
            let mut dirty = Matrix::full(1, 1, f32::NAN);
            matmul_nt_stats_into(a.as_rows_ref(), &b, p, None, 65504.0, &mut st2, &mut dirty);
            assert_eq!(fresh, dirty);
            assert_eq!(st1.overflow_events, st2.overflow_events);
            assert_eq!(st1.max_abs, st2.max_abs);

            let bt = b.transpose();
            let fresh = matmul_nn(&a, &bt, p);
            let mut dirty = Matrix::full(2, 7, 3.5);
            matmul_nn_into(a.as_rows_ref(), &bt, p, &mut dirty);
            assert_eq!(fresh, dirty);
        }
        // RowsRef lets the caller run a row window without slicing: the
        // result must equal the sliced matmul exactly.
        let mut st = GemmStats::default();
        let mut win = Matrix::zeros(0, 0);
        matmul_nt_stats_into(a.rows_ref(1, 3), &b, GemmPrecision::F32, None, 65504.0, &mut st, &mut win);
        let sliced = matmul_nt(&a.rows_slice(1, 3), &b, GemmPrecision::F32);
        assert_eq!(win, sliced);
    }

    #[test]
    fn full16_can_overflow_in_accumulation() {
        // Partial sums exceed 65504 before any store.
        const K: usize = 8;
        let k = K;
        let a = m(1, k, &[200.0; K]);
        let b = m(1, k, &[200.0; K]);
        let c = matmul_nt(&a, &b, GemmPrecision::FULL16);
        assert!(c.at(0, 0).is_infinite()); // 200*200*8 = 320000
        let c32 = matmul_nt(&a, &b, GemmPrecision::ACC32_STORE16);
        assert!(c32.at(0, 0).is_infinite()); // still inf on store
        let cf = matmul_nt(&a, &b, GemmPrecision::F32);
        assert_eq!(cf.at(0, 0), 320000.0);
    }

    /// FNV-1a over the bit patterns of a matrix — one checksum pins one
    /// (format × entry) twin exactly.
    fn fnv_matrix(mut h: u64, m: &Matrix) -> u64 {
        for &x in &m.data {
            for byte in x.to_bits().to_le_bytes() {
                h = (h ^ byte as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }

    /// Run all four `_into` entries across every store format at f32
    /// accumulate (the SIMD-covered cores) and checksum each result plus
    /// its stats — 16 (format × entry) twins per run.
    fn checksum_all_entries() -> Vec<(String, u64)> {
        const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;
        // k=19 exercises the 8-lane remainder (19 = 2·8+3), n=7 the 4-row
        // panel remainder (7 = 4+3); magnitudes straddle each format's
        // rounding grid so store rounding is non-trivial everywhere.
        let (mm, k, n) = (5usize, 19usize, 7usize);
        let a = m(
            mm,
            k,
            &(0..mm * k)
                .map(|i| (i as f32 * 0.37).sin() * 300.0)
                .collect::<Vec<_>>(),
        );
        let b = m(
            n,
            k,
            &(0..n * k)
                .map(|i| (i as f32 * 0.23).cos() * 250.0)
                .collect::<Vec<_>>(),
        );
        let bt = b.transpose();
        let vis = [7usize, 5, 0, 3, 6];
        let mut out = Vec::new();
        for store in [Format::F32, Format::F16, Format::Bf16, Format::F8E4M3] {
            let p = GemmPrecision {
                acc: Format::F32,
                store,
            };
            let boundary = store.overflow_boundary();

            let mut c = Matrix::zeros(0, 0);
            matmul_nt_into(a.as_rows_ref(), &b, p, &mut c);
            out.push((format!("{}/nt", store.name()), fnv_matrix(FNV_SEED, &c)));

            let mut st = GemmStats::default();
            matmul_nt_stats_into(a.as_rows_ref(), &b, p, Some(&vis), boundary, &mut st, &mut c);
            let mut h = fnv_matrix(FNV_SEED, &c);
            h = h.wrapping_mul(31).wrapping_add(st.overflow_events as u64);
            h ^= st.max_abs.to_bits() as u64;
            out.push((format!("{}/nt_stats", store.name()), h));

            let mut st = GemmStats::default();
            matmul_nt_prefix_into(
                a.as_rows_ref(),
                &b,
                p,
                &vis,
                f32::NEG_INFINITY,
                boundary,
                &mut st,
                &mut c,
            );
            let mut h = fnv_matrix(FNV_SEED, &c);
            h = h.wrapping_mul(31).wrapping_add(st.overflow_events as u64);
            h ^= st.max_abs.to_bits() as u64;
            out.push((format!("{}/nt_prefix", store.name()), h));

            matmul_nn_into(a.as_rows_ref(), &bt, p, &mut c);
            out.push((format!("{}/nn", store.name()), fnv_matrix(FNV_SEED, &c)));
        }
        out
    }

    #[test]
    fn simd_and_scalar_cores_are_bit_identical_per_format_and_entry() {
        let _g = simd::test_mode_guard();
        simd::set_force(Some(false));
        let scalar = checksum_all_entries();
        simd::set_force(Some(true));
        let vector = checksum_all_entries();
        simd::set_force(None);
        assert_eq!(scalar.len(), 16, "4 formats × 4 entries");
        for (s, v) in scalar.iter().zip(&vector) {
            assert_eq!(s, v, "SIMD/scalar checksum diverged for {}", s.0);
        }
        if !simd::detected() {
            eprintln!(
                "simd twins: AVX2 not detected on this host; force-on ran the scalar fallback"
            );
        }
    }

    #[test]
    fn simd_runtime_detection_smoke() {
        let _g = simd::test_mode_guard();
        // Both dispatch states must be reachable from the control surface.
        simd::set_force(Some(false));
        assert!(!simd::enabled(), "force-off must disable the vector path");
        simd::set_force(Some(true));
        assert_eq!(
            simd::enabled(),
            simd::detected(),
            "force-on follows hardware detection"
        );
        simd::set_force(None);
        if simd::detected() {
            let av: Vec<f32> = (0..37).map(|i| (i as f32).sin() * 5.0).collect();
            let bv: Vec<f32> = (0..37).map(|i| (i as f32).cos() * 5.0).collect();
            assert_eq!(
                simd::dot(&av, &bv).to_bits(),
                dot_f32(&av, &bv).to_bits(),
                "detected vector dot must match the scalar reference bitwise"
            );
        } else {
            eprintln!("simd smoke: AVX2 not detected; vector path unreachable on this host");
        }
    }
}
