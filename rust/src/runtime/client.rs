//! PJRT client wrapper (RT): load HLO-text artifacts, compile once,
//! execute from the serving hot path. Python never runs here.

use anyhow::{Context, Result};
use std::path::Path;

/// Thin wrapper owning the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text module and compile it (HLO text, not serialized
    /// proto — see DESIGN.md / aot.py for the jax>=0.5 id-width gotcha).
    pub fn load_module(&self, path: &Path) -> Result<Executor> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executor {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled module ready to execute.
pub struct Executor {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executor {
    /// Execute with literal inputs; returns the flattened output tuple
    /// (aot.py lowers with return_tuple=True).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(lit.to_tuple()?)
    }

    /// Borrow-based execute — avoids cloning long-lived weight literals on
    /// every call (execute takes Borrow<Literal>).
    pub fn run_borrowed(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(lit.to_tuple()?)
    }
}

/// Build an f32 literal with the given dims.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal with the given dims.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
