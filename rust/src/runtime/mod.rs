//! Runtime (RT): the two model execution backends behind the serving
//! engine — the xla-crate PJRT layer that loads and executes the AOT
//! HLO-text artifacts, and the pure-Rust lab runtime whose attention runs
//! through the instrumented kernel registry over paged KV views.

pub mod client;
pub mod lab;
pub mod model_runtime;

pub use client::{literal_f32, literal_i32, to_f32_vec, Executor, Runtime};
pub use lab::{LabModel, LabPrefill, LayerWeights, NormMode};
pub use model_runtime::{HostCache, ModelRuntime, PrefillOutput};
