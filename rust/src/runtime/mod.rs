//! Runtime (RT): the xla-crate PJRT layer that loads and executes the AOT
//! HLO-text artifacts from the L3 hot path.

pub mod client;
pub mod model_runtime;

pub use client::{literal_f32, literal_i32, to_f32_vec, Executor, Runtime};
pub use model_runtime::{HostCache, ModelRuntime, PrefillOutput};
