//! Model runtime (RT): owns the compiled prefill/decode executables and
//! the weight literals; exposes the two typed entry points the serving
//! engine calls. All shapes come from the manifest (the AOT contract).

use super::client::{literal_f32, literal_i32, to_f32_vec, Executor, Runtime};
use crate::model::{Manifest, ModelDims, Weights};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// Per-request host-resident KV cache: (n_layers, max_seq, head_width).
#[derive(Clone, Debug)]
pub struct HostCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub dims: ModelDims,
}

impl HostCache {
    pub fn zeros(dims: ModelDims) -> HostCache {
        let n = dims.n_layers * dims.max_seq * dims.head_width();
        HostCache {
            k: vec![0.0; n],
            v: vec![0.0; n],
            dims,
        }
    }

    pub fn per_layer(&self) -> usize {
        self.dims.max_seq * self.dims.head_width()
    }
}

/// Result of a prefill call.
pub struct PrefillOutput {
    /// (prefill_seq, vocab) logits for the prompt tokens.
    pub logits: Vec<f32>,
    pub cache: HostCache,
}

/// The compiled model with weights resident as literals.
pub struct ModelRuntime {
    rt: Runtime,
    pub manifest: Manifest,
    pub dims: ModelDims,
    param_literals: Vec<xla::Literal>,
    executors: Mutex<HashMap<String, Executor>>,
}

impl ModelRuntime {
    /// Load manifest + weights from the artifacts dir; executables are
    /// compiled lazily per (kind, allocation) on first use.
    pub fn load(artifacts: &Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifacts)?;
        let weights = Weights::load(&artifacts.join("weights.bin"))?;
        weights.check_against(&manifest.params)?;
        let rt = Runtime::cpu()?;
        let mut param_literals = Vec::with_capacity(weights.tensors.len());
        for t in &weights.tensors {
            let dims: Vec<i64> = if t.dims.is_empty() {
                vec![1]
            } else {
                t.dims.iter().map(|&d| d as i64).collect()
            };
            param_literals.push(literal_f32(&t.data, &dims)?);
        }
        let dims = manifest.dims;
        Ok(ModelRuntime {
            rt,
            manifest,
            dims,
            param_literals,
            executors: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (once) and run a module. Prefill/decode take the weight
    /// literals as a prefix; standalone head modules take only `io`.
    fn run_module(
        &self,
        kind: &str,
        alloc: &str,
        io: &[xla::Literal],
        with_params: bool,
    ) -> Result<Vec<xla::Literal>> {
        let key = format!("{kind}_{alloc}");
        {
            let mut map = self.executors.lock().unwrap();
            if !map.contains_key(&key) {
                let entry = self.manifest.module(kind, alloc)?;
                let exe = self.rt.load_module(&entry.path)?;
                map.insert(key.clone(), exe);
            }
        }
        let map = self.executors.lock().unwrap();
        let exe = map.get(&key).ok_or_else(|| anyhow!("lost executor"))?;
        // execute() takes Borrow<Literal>: borrow the resident weight
        // literals instead of cloning them on every call.
        let borrows: Vec<&xla::Literal> = if with_params {
            self.param_literals.iter().chain(io.iter()).collect()
        } else {
            io.iter().collect()
        };
        exe.run_borrowed(&borrows)
    }

    /// Prefill one prompt (batch 1): tokens must be exactly
    /// `dims.prefill_seq` long (padded), `seq_len` its valid length.
    pub fn prefill(&self, alloc: &str, tokens: &[u32], seq_len: usize) -> Result<PrefillOutput> {
        let d = self.dims;
        anyhow::ensure!(
            tokens.len() == d.prefill_seq,
            "prefill expects {} tokens, got {}",
            d.prefill_seq,
            tokens.len()
        );
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let io = [
            literal_i32(&toks, &[1, d.prefill_seq as i64])?,
            literal_i32(&[seq_len as i32], &[1])?,
        ];
        let outs = self.run_module("prefill", alloc, &io, true)?;
        anyhow::ensure!(outs.len() == 3, "prefill returns 3 outputs");
        let logits = to_f32_vec(&outs[0])?;
        let kc = to_f32_vec(&outs[1])?;
        let vc = to_f32_vec(&outs[2])?;
        // Cache comes back as (L, 1, max_seq, W) — squeeze the batch dim.
        let cache = HostCache {
            k: kc,
            v: vc,
            dims: d,
        };
        Ok(PrefillOutput { logits, cache })
    }

    /// One decode step over the fixed batch bucket. `kbatch`/`vbatch` are
    /// (L, B, max_seq, W) flattened; returns (logits (B, V), and the new
    /// KV rows (L, B, W) — the coordinator owns the cache and writes the
    /// rows back into its paged pool (§Perf: full-cache outputs moved
    /// 32 MB/step across PJRT for 32 KB of new information).
    pub fn decode(
        &self,
        alloc: &str,
        tokens: &[i32],
        pos: &[i32],
        kbatch: &[f32],
        vbatch: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = self.dims;
        let b = d.decode_batch;
        anyhow::ensure!(tokens.len() == b && pos.len() == b, "decode batch mismatch");
        let cache_dims = [
            d.n_layers as i64,
            b as i64,
            d.max_seq as i64,
            d.head_width() as i64,
        ];
        let io = [
            literal_i32(tokens, &[b as i64])?,
            literal_i32(pos, &[b as i64])?,
            literal_f32(kbatch, &cache_dims)?,
            literal_f32(vbatch, &cache_dims)?,
        ];
        let outs = self.run_module("decode", alloc, &io, true)?;
        anyhow::ensure!(outs.len() == 3, "decode returns 3 outputs");
        Ok((
            to_f32_vec(&outs[0])?,
            to_f32_vec(&outs[1])?,
            to_f32_vec(&outs[2])?,
        ))
    }

    /// Run a standalone head module (quickstart / benches): q,k,v are
    /// (seq, dim) f32 flattened.
    pub fn head(&self, alloc: &str, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let entry = self.manifest.module("head", alloc)?;
        let seq = entry.attrs["seq"];
        let dim = entry.attrs["dim"];
        let io = [
            literal_f32(q, &[seq, dim])?,
            literal_f32(k, &[seq, dim])?,
            literal_f32(v, &[seq, dim])?,
        ];
        let outs = self.run_module("head", alloc, &io, false)?;
        to_f32_vec(&outs[0])
    }
}
