//! Lab-backed model runtime (S13): a pure-Rust forward pass of the L2
//! transformer whose attention runs through the attention lab's
//! [`crate::attention::KernelRegistry`] — and, on the decode path, over
//! *paged* KV views gathered straight from the coordinator's page pool.
//!
//! This is the serving half of the paged-KV tentpole: where the PJRT
//! runtime consumes a dense `(L, B, max_seq, W)` cache tensor that the
//! engine must assemble with `fill_dense` every step (`O(max_seq)` per
//! slot per layer), [`LabModel::decode_step`] hands each layer's kernels a
//! `KvView::Paged` of exactly `len_tokens` rows — `O(len_tokens)` gathers,
//! no dense staging buffer — and returns the kernels' pre-store max |S| /
//! overflow telemetry as a [`GuardSignal`], so the engine's adaptive guard
//! trips on the paper's instrumentation point instead of sniffing logits
//! for NaN after the fact.
//!
//! The forward mirrors `python/compile/model.py` (GPT-style byte LM:
//! LN → QKV → MHA → residual, LN → GELU MLP → residual, tied logits).
//! Weights can come from the AOT `weights.bin` ([`LabModel::load`]) or be
//! synthesized in-process ([`LabModel::synthetic`]) so the serving engine
//! is exercisable — and testable — on hosts with no artifacts at all.
//!
//! [`NormMode::Identity`] replaces layer norm with its affine part only.
//! Layer norm squashes activation magnitudes, which makes deterministic
//! overflow scenarios impossible to stage through real weights; identity
//! mode lets tests inject the paper's biased Q/K regimes (Eq. 17) into the
//! serving path at controlled positions. Production configs use
//! [`NormMode::LayerNorm`].

use crate::attention::{
    Allocation, AttentionConfig, AttentionRequest, AttnMask, BetaPolicy, BlockSizes, KvPair, KvView,
};
use crate::coordinator::{GuardSignal, KvPool, SeqCache};
use crate::model::{Manifest, ModelDims, Weights};
use crate::tensor::{matmul_nn, matmul_nt, GemmPrecision, Matrix};
use crate::workloads::Pcg64;
use anyhow::{anyhow, ensure, Context, Result};
use std::path::Path;

/// One transformer block's parameters (shapes follow python ModelConfig:
/// `wq/wk/wv: (d_model × W)`, `wo: (W × d_model)`, `w1: (d_model × d_ff)`,
/// `w2: (d_ff × d_model)` with `W = n_heads · d_head`).
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Matrix,
    pub b1: Vec<f32>,
    pub w2: Matrix,
    pub b2: Vec<f32>,
}

/// Normalization mode of the lab forward (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NormMode {
    /// Standard layer norm (the production transformer).
    LayerNorm,
    /// Affine-only (`x·g + b`): preserves activation magnitudes so tests
    /// can stage deterministic overflow at chosen positions.
    Identity,
}

/// Result of a lab prefill: valid-length logits, the per-layer K/V rows to
/// seed the paged cache with, and the merged attention telemetry.
pub struct LabPrefill {
    /// `(n × vocab)` logits for the `n` valid prompt tokens, row-major.
    pub logits: Vec<f32>,
    /// Per layer: the `(n × W)` K rows of the prompt.
    pub k_rows: Vec<Matrix>,
    /// Per layer: the `(n × W)` V rows of the prompt.
    pub v_rows: Vec<Matrix>,
    /// Merged per-layer kernel telemetry of the whole prefill.
    pub signal: GuardSignal,
}

/// The pure-Rust serving model (see module docs).
pub struct LabModel {
    pub dims: ModelDims,
    /// `(vocab × d_model)` token embedding; also the tied logits matrix.
    pub tok_emb: Matrix,
    /// `(max_seq × d_model)` learned positional embedding.
    pub pos_emb: Matrix,
    pub layers: Vec<LayerWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub norm: NormMode,
    /// Attention tiling handed to the lab kernels.
    pub blocks: BlockSizes,
    /// β policy installed on every attention request this model builds —
    /// the runtime-layer knob of the precision-policy subsystem (per-head
    /// tables from the autotune pass, or the default uniform paper β).
    /// Install a *concrete* policy (`Uniform`/`PerHead`): a `Solved`
    /// policy is legal but re-runs its fixed-point solve on every layer
    /// forward — pre-resolve it once with
    /// [`BetaPolicy::resolved`]`(blocks.s2, fmt)` instead.
    pub beta_policy: BetaPolicy,
}

fn randn(rng: &mut Pcg64, rows: usize, cols: usize, scale: f64) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for v in &mut m.data {
        *v = rng.normal(0.0, scale) as f32;
    }
    m
}

fn get_mat(w: &Weights, name: &str, rows: usize, cols: usize) -> Result<Matrix> {
    let t = w
        .get(name)
        .ok_or_else(|| anyhow!("weights missing tensor {name}"))?;
    ensure!(
        t.dims == [rows, cols],
        "tensor {name}: dims {:?}, expected [{rows}, {cols}]",
        t.dims
    );
    Ok(Matrix::from_vec(rows, cols, t.data.clone()))
}

fn get_vec(w: &Weights, name: &str, len: usize) -> Result<Vec<f32>> {
    let t = w
        .get(name)
        .ok_or_else(|| anyhow!("weights missing tensor {name}"))?;
    ensure!(
        t.dims == [len],
        "tensor {name}: dims {:?}, expected [{len}]",
        t.dims
    );
    Ok(t.data.clone())
}

/// tanh-approximate GELU (jax.nn.gelu's default), elementwise in place.
fn gelu_inplace(m: &mut Matrix) {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    for x in &mut m.data {
        let t = (C * (*x + 0.044_715 * *x * *x * *x)).tanh();
        *x = 0.5 * *x * (1.0 + t);
    }
}

fn add_bias(m: &mut Matrix, b: &[f32]) {
    for r in 0..m.rows {
        for (x, &bb) in m.row_mut(r).iter_mut().zip(b) {
            *x += bb;
        }
    }
}

fn add_into(acc: &mut Matrix, add: &Matrix) {
    debug_assert_eq!(acc.shape(), add.shape());
    for (a, &b) in acc.data.iter_mut().zip(&add.data) {
        *a += b;
    }
}

/// Interleave per-head `(s × d_head)` outputs back into `(s × W)`.
fn concat_heads(heads: &[Matrix]) -> Matrix {
    let rows = heads[0].rows;
    let dh = heads[0].cols;
    let mut out = Matrix::zeros(rows, dh * heads.len());
    for (i, h) in heads.iter().enumerate() {
        for r in 0..rows {
            out.row_mut(r)[i * dh..(i + 1) * dh].copy_from_slice(h.row(r));
        }
    }
    out
}

impl LabModel {
    /// Build from a loaded AOT weight set (python param naming contract).
    pub fn from_weights(dims: ModelDims, w: &Weights) -> Result<LabModel> {
        let d = dims.d_model;
        let hw = dims.head_width();
        let mut layers = Vec::with_capacity(dims.n_layers);
        for i in 0..dims.n_layers {
            let p = |n: &str| format!("l{i}.{n}");
            layers.push(LayerWeights {
                ln1_g: get_vec(w, &p("ln1_g"), d)?,
                ln1_b: get_vec(w, &p("ln1_b"), d)?,
                wq: get_mat(w, &p("wq"), d, hw)?,
                wk: get_mat(w, &p("wk"), d, hw)?,
                wv: get_mat(w, &p("wv"), d, hw)?,
                wo: get_mat(w, &p("wo"), hw, d)?,
                ln2_g: get_vec(w, &p("ln2_g"), d)?,
                ln2_b: get_vec(w, &p("ln2_b"), d)?,
                w1: get_mat(w, &p("w1"), d, dims.d_ff)?,
                b1: get_vec(w, &p("b1"), dims.d_ff)?,
                w2: get_mat(w, &p("w2"), dims.d_ff, d)?,
                b2: get_vec(w, &p("b2"), d)?,
            });
        }
        Ok(LabModel {
            dims,
            tok_emb: get_mat(w, "tok_emb", dims.vocab_size, d)?,
            pos_emb: get_mat(w, "pos_emb", dims.max_seq, d)?,
            layers,
            lnf_g: get_vec(w, "lnf_g", d)?,
            lnf_b: get_vec(w, "lnf_b", d)?,
            norm: NormMode::LayerNorm,
            blocks: BlockSizes::default(),
            beta_policy: BetaPolicy::default(),
        })
    }

    /// Load manifest + weights from an artifacts directory.
    pub fn load(artifacts: &Path) -> Result<LabModel> {
        let manifest = Manifest::load(artifacts).context("lab runtime manifest")?;
        let weights =
            Weights::load(&artifacts.join("weights.bin")).context("lab runtime weights")?;
        weights.check_against(&manifest.params)?;
        LabModel::from_weights(manifest.dims, &weights)
    }

    /// Random init with the python trainer's scaling (σ = 0.02, residual
    /// projections down-scaled) — a fully host-side model for tests,
    /// benches and artifact-less serving demos.
    pub fn synthetic(dims: ModelDims, seed: u64) -> LabModel {
        let mut rng = Pcg64::new(seed, 0);
        let d = dims.d_model;
        let hw = dims.head_width();
        let res = 0.02 / (2.0 * dims.n_layers as f64).sqrt();
        let mut layers = Vec::with_capacity(dims.n_layers);
        for _ in 0..dims.n_layers {
            layers.push(LayerWeights {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                wq: randn(&mut rng, d, hw, 0.02),
                wk: randn(&mut rng, d, hw, 0.02),
                wv: randn(&mut rng, d, hw, 0.02),
                wo: randn(&mut rng, hw, d, res),
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                w1: randn(&mut rng, d, dims.d_ff, 0.02),
                b1: vec![0.0; dims.d_ff],
                w2: randn(&mut rng, dims.d_ff, d, res),
                b2: vec![0.0; d],
            });
        }
        LabModel {
            dims,
            tok_emb: randn(&mut rng, dims.vocab_size, d, 0.02),
            pos_emb: randn(&mut rng, dims.max_seq, d, 0.02),
            layers,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
            norm: NormMode::LayerNorm,
            blocks: BlockSizes::default(),
            beta_policy: BetaPolicy::default(),
        }
    }

    fn norm_rows(&self, x: &Matrix, g: &[f32], b: &[f32]) -> Matrix {
        let mut out = Matrix::zeros(x.rows, x.cols);
        for r in 0..x.rows {
            let row = x.row(r);
            let dst = out.row_mut(r);
            match self.norm {
                NormMode::LayerNorm => {
                    let n = row.len() as f32;
                    let mu = row.iter().sum::<f32>() / n;
                    let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n;
                    let inv = 1.0 / (var + 1e-5).sqrt();
                    for c in 0..row.len() {
                        dst[c] = (row[c] - mu) * inv * g[c] + b[c];
                    }
                }
                NormMode::Identity => {
                    for c in 0..row.len() {
                        dst[c] = row[c] * g[c] + b[c];
                    }
                }
            }
        }
        out
    }

    fn attn_config(&self, alloc: Allocation) -> AttentionConfig {
        AttentionConfig::new(alloc).with_blocks(self.blocks.s1, self.blocks.s2)
    }

    /// Run one layer's multi-head attention through the kernel registry.
    /// `q_full` is `(s1 × W)`; `kv` has one (K, V) view pair per head.
    fn mha(
        &self,
        q_full: &Matrix,
        kv: &[KvPair<'_>],
        mask: AttnMask,
        alloc: Allocation,
        sig: &mut GuardSignal,
    ) -> Matrix {
        let dh = self.dims.d_head;
        let mut req = AttentionRequest::new(alloc).with_mask(mask);
        req.cfg = self.attn_config(alloc);
        req = req.with_policy(self.beta_policy.clone());
        for h in 0..self.dims.n_heads {
            req = req.with_query_head(q_full.cols_slice(h * dh, (h + 1) * dh));
        }
        let out = req.run_with_kv(kv);
        sig.merge(&GuardSignal::from_attention(&out));
        concat_heads(&out.heads)
    }

    /// Everything after attention in one block, plus the residual adds.
    fn finish_block(&self, lw: &LayerWeights, x: &mut Matrix, attn: &Matrix) {
        let proj = matmul_nn(attn, &lw.wo, GemmPrecision::F32);
        add_into(x, &proj);
        let h2 = self.norm_rows(x, &lw.ln2_g, &lw.ln2_b);
        let mut up = matmul_nn(&h2, &lw.w1, GemmPrecision::F32);
        add_bias(&mut up, &lw.b1);
        gelu_inplace(&mut up);
        let mut down = matmul_nn(&up, &lw.w2, GemmPrecision::F32);
        add_bias(&mut down, &lw.b2);
        add_into(x, &down);
    }

    fn embed(&self, token: u32, pos: usize) -> Vec<f32> {
        let te = self.tok_emb.row(token as usize);
        let pe = self.pos_emb.row(pos);
        te.iter().zip(pe).map(|(&a, &b)| a + b).collect()
    }

    /// Prefill a prompt of `n` valid tokens (causal self-attention through
    /// the lab kernels, dense K/V — prefill K/V are freshly computed and
    /// contiguous, paging begins once they are seeded into the pool).
    pub fn prefill(&self, alloc: Allocation, ids: &[u32], n: usize) -> Result<LabPrefill> {
        ensure!(n >= 1, "empty prompt");
        ensure!(n <= ids.len(), "valid length {n} exceeds {} ids", ids.len());
        ensure!(n <= self.dims.max_seq, "prompt longer than max_seq");
        let d = self.dims.d_model;
        let dh = self.dims.d_head;
        let mut x = Matrix::zeros(n, d);
        for p in 0..n {
            x.row_mut(p).copy_from_slice(&self.embed(ids[p], p));
        }
        let mut sig = GuardSignal::default();
        let mut k_rows = Vec::with_capacity(self.layers.len());
        let mut v_rows = Vec::with_capacity(self.layers.len());
        for lw in &self.layers {
            let h = self.norm_rows(&x, &lw.ln1_g, &lw.ln1_b);
            let q = matmul_nn(&h, &lw.wq, GemmPrecision::F32);
            let k = matmul_nn(&h, &lw.wk, GemmPrecision::F32);
            let v = matmul_nn(&h, &lw.wv, GemmPrecision::F32);
            let k_heads: Vec<Matrix> = (0..self.dims.n_heads)
                .map(|hh| k.cols_slice(hh * dh, (hh + 1) * dh))
                .collect();
            let v_heads: Vec<Matrix> = (0..self.dims.n_heads)
                .map(|hh| v.cols_slice(hh * dh, (hh + 1) * dh))
                .collect();
            let pairs: Vec<KvPair<'_>> = k_heads
                .iter()
                .zip(&v_heads)
                .map(|(kh, vh)| KvPair {
                    k: KvView::Dense(kh),
                    v: KvView::Dense(vh),
                })
                .collect();
            let attn = self.mha(&q, &pairs, AttnMask::Causal, alloc, &mut sig);
            self.finish_block(lw, &mut x, &attn);
            k_rows.push(k);
            v_rows.push(v);
        }
        let xf = self.norm_rows(&x, &self.lnf_g, &self.lnf_b);
        let logits = matmul_nt(&xf, &self.tok_emb, GemmPrecision::F32);
        Ok(LabPrefill {
            logits: logits.data,
            k_rows,
            v_rows,
            signal: sig,
        })
    }

    /// Prefill one **chunk** of a prompt — positions `[start, end)` of
    /// `ids` — directly into the paged cache, and return the last prompt
    /// row's logits once the final chunk lands (`end == ids.len()`).
    ///
    /// This is the chunked-prefill engine path: a long prompt is split
    /// into budget-sized chunks, each interleaved with the in-flight
    /// decode rounds, so a 4096-token prompt never stalls other streams.
    ///
    /// ## Chunk-boundary invariance (the token-identity contract)
    ///
    /// Where chunk boundaries fall depends on how much prefill budget the
    /// scheduler had left — which depends on what else was in the batch.
    /// For batched streams to stay bit-identical to their solo runs, the
    /// *result* must not depend on the split. This holds by construction:
    ///
    /// * Embedding, layer norm, the Q/K/V/MLP GEMMs and the residual adds
    ///   are all row-independent — computing rows `[start, end)` in one
    ///   call is bit-identical to computing them one at a time.
    /// * Attention runs **per query row** against the paged cache fenced
    ///   at that row's own causal prefix ([`SeqCache::kv_views_at`] with
    ///   `len = pos + 1`, `s1 = 1`, [`AttnMask::None`]) — exactly the
    ///   decode-step shape, over exactly the same rows, no matter how
    ///   many chunks wrote them.
    ///
    /// So `prefill_chunk(0..n)` ≡ `prefill_chunk(0..k); prefill_chunk(k..n)`
    /// bit for bit, for every split `k` — and the engine routes *all* lab
    /// prefills through this path (a short prompt is simply one chunk),
    /// making the sequential baseline identical by construction.
    ///
    /// Like the decode step, the chunk is functional in (ids, range,
    /// cache-prefix): a guard replay under a rescue allocation rewrites
    /// the same rows and leaves the cache as if the rescue had run first.
    pub fn prefill_chunk(
        &self,
        alloc: Allocation,
        ids: &[u32],
        start: usize,
        end: usize,
        cache: &mut SeqCache,
        pool: &mut KvPool,
    ) -> Result<(Option<Vec<f32>>, GuardSignal)> {
        ensure!(start < end, "empty prefill chunk [{start}, {end})");
        ensure!(end <= ids.len(), "chunk end {end} past {} prompt ids", ids.len());
        ensure!(end <= self.dims.max_seq, "prompt longer than max_seq");
        let d = self.dims.d_model;
        let dh = self.dims.d_head;
        let hw = self.dims.head_width();
        let c = end - start;
        let mut x = Matrix::zeros(c, d);
        for (r, p) in (start..end).enumerate() {
            x.row_mut(r).copy_from_slice(&self.embed(ids[p], p));
        }
        cache.ensure_capacity(pool, end)?;
        let mut sig = GuardSignal::default();
        for (li, lw) in self.layers.iter().enumerate() {
            let h = self.norm_rows(&x, &lw.ln1_g, &lw.ln1_b);
            let q = matmul_nn(&h, &lw.wq, GemmPrecision::F32);
            let k = matmul_nn(&h, &lw.wk, GemmPrecision::F32);
            let v = matmul_nn(&h, &lw.wv, GemmPrecision::F32);
            let mut attn = Matrix::zeros(c, hw);
            for r in 0..c {
                let pos = start + r;
                cache.write_row(pool, li, pos, k.row(r), v.row(r))?;
                let qrow = Matrix::from_vec(1, hw, q.row(r).to_vec());
                let out = {
                    let (kview, vview) = cache.kv_views_at(pool, li, pos + 1);
                    let pairs: Vec<KvPair<'_>> = (0..self.dims.n_heads)
                        .map(|hh| KvPair {
                            k: kview.col_window(hh * dh, dh),
                            v: vview.col_window(hh * dh, dh),
                        })
                        .collect();
                    self.mha(&qrow, &pairs, AttnMask::None, alloc, &mut sig)
                };
                attn.row_mut(r).copy_from_slice(out.row(0));
            }
            self.finish_block(lw, &mut x, &attn);
        }
        let logits = if end == ids.len() {
            // Only the last prompt row feeds sampling; skip the other
            // rows' vocab GEMM (norm + tied-logits GEMM are row-
            // independent, so this is bit-identical to slicing a full
            // logits matrix).
            let last = Matrix::from_vec(1, d, x.row(c - 1).to_vec());
            let xf = self.norm_rows(&last, &self.lnf_g, &self.lnf_b);
            Some(matmul_nt(&xf, &self.tok_emb, GemmPrecision::F32).data)
        } else {
            None
        };
        Ok((logits, sig))
    }

    /// One paged decode step for one sequence: computes the step's K/V
    /// rows, writes them into the paged cache at `pos`, then runs every
    /// layer's attention over `KvView::Paged` of the `pos + 1` valid rows
    /// (each query head windowed onto its `d_head` columns of the packed
    /// cache row). Returns the vocab logits and the merged telemetry.
    ///
    /// The step is functional in (token, pos, cache-prefix): replaying it
    /// under a different allocation rewrites the same rows, so a guard
    /// replay leaves the cache exactly as if the step had run on the
    /// replay allocation from the start.
    pub fn decode_step(
        &self,
        alloc: Allocation,
        token: u32,
        pos: usize,
        cache: &mut SeqCache,
        pool: &mut KvPool,
    ) -> Result<(Vec<f32>, GuardSignal)> {
        ensure!(pos < self.dims.max_seq, "decode position past max_seq");
        cache.prepare_step(pool, pos)?;
        self.decode_step_prepared(alloc, token, pos, cache, pool)
    }

    /// The compute half of [`Self::decode_step`], against a **shared**
    /// pool reference — what lets the engine fan independent slots' decode
    /// steps onto the worker pool concurrently. Requires a prior
    /// [`SeqCache::prepare_step`] for `pos` (capacity grown, written
    /// pages privatized); given that, it is bit-identical to the
    /// exclusive-path step: same KV rows written to the same pages, same
    /// kernels over the same views.
    pub fn decode_step_prepared(
        &self,
        alloc: Allocation,
        token: u32,
        pos: usize,
        cache: &mut SeqCache,
        pool: &KvPool,
    ) -> Result<(Vec<f32>, GuardSignal)> {
        ensure!(pos < self.dims.max_seq, "decode position past max_seq");
        let dh = self.dims.d_head;
        let mut x = Matrix::from_vec(1, self.dims.d_model, self.embed(token, pos));
        let mut sig = GuardSignal::default();
        for (li, lw) in self.layers.iter().enumerate() {
            let h = self.norm_rows(&x, &lw.ln1_g, &lw.ln1_b);
            let q = matmul_nn(&h, &lw.wq, GemmPrecision::F32);
            let k = matmul_nn(&h, &lw.wk, GemmPrecision::F32);
            let v = matmul_nn(&h, &lw.wv, GemmPrecision::F32);
            cache.write_row_prepared(pool, li, pos, k.row(0), v.row(0));
            let attn = {
                let (kview, vview) = cache.kv_views(pool, li);
                let pairs: Vec<KvPair<'_>> = (0..self.dims.n_heads)
                    .map(|hh| KvPair {
                        k: kview.col_window(hh * dh, dh),
                        v: vview.col_window(hh * dh, dh),
                    })
                    .collect();
                // One query row at the sequence end sees every valid KV
                // row; the view's len_tokens is the implicit prefix mask.
                self.mha(&q, &pairs, AttnMask::None, alloc, &mut sig)
            };
            self.finish_block(lw, &mut x, &attn);
        }
        let xf = self.norm_rows(&x, &self.lnf_g, &self.lnf_b);
        let logits = matmul_nt(&xf, &self.tok_emb, GemmPrecision::F32);
        Ok((logits.data, sig))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dims() -> ModelDims {
        ModelDims {
            vocab_size: 259,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_head: 8,
            d_ff: 32,
            max_seq: 32,
            prefill_seq: 16,
            decode_batch: 2,
            pad: 256,
            bos: 257,
            eos: 258,
        }
    }

    #[test]
    fn synthetic_prefill_shapes_and_finiteness() {
        let m = LabModel::synthetic(tiny_dims(), 7);
        let (ids, n) = crate::model::tokenizer::encode("hello", 16, Default::default());
        let out = m.prefill(Allocation::Fa32, &ids, n).unwrap();
        assert_eq!(out.logits.len(), n * 259);
        assert_eq!(out.k_rows.len(), 2);
        assert_eq!(out.k_rows[0].shape(), (n, 16));
        assert!(out.logits.iter().all(|x| x.is_finite()));
        assert_eq!(out.signal.nonfinite, 0);
    }

    #[test]
    fn decode_step_is_deterministic_and_writes_rows() {
        let m = LabModel::synthetic(tiny_dims(), 8);
        let mut pool = KvPool::new(64, 4, 16);
        let mut cache = SeqCache::new(2);
        let (l1, s1) = m
            .decode_step(Allocation::Pasa16, 42, 0, &mut cache, &mut pool)
            .unwrap();
        assert_eq!(cache.len_tokens, 1);
        assert_eq!(l1.len(), 259);
        assert!(l1.iter().all(|x| x.is_finite()));
        assert_eq!(s1.nonfinite, 0);
        // Replaying the same step must be bit-identical (functional step).
        let (l2, _) = m
            .decode_step(Allocation::Pasa16, 42, 0, &mut cache, &mut pool)
            .unwrap();
        assert_eq!(l1, l2);
        cache.release(&mut pool);
    }

    #[test]
    fn beta_policy_plumbs_through_the_decode_path() {
        // A PerHead table repeating the paper β must be bit-identical to
        // the default Uniform policy (the per-head resolution collapses to
        // the shared-K' path); a genuinely per-head table still decodes to
        // finite logits.
        use crate::attention::PAPER_BETA;
        let mut m = LabModel::synthetic(tiny_dims(), 10);
        let mut pool = KvPool::new(64, 4, 16);
        let mut cache = SeqCache::new(2);
        let (base, _) = m
            .decode_step(Allocation::Pasa16, 7, 0, &mut cache, &mut pool)
            .unwrap();
        m.beta_policy = BetaPolicy::PerHead(vec![PAPER_BETA; 2]);
        let (same, _) = m
            .decode_step(Allocation::Pasa16, 7, 0, &mut cache, &mut pool)
            .unwrap();
        assert_eq!(base, same, "uniform-valued PerHead diverged from Uniform");
        m.beta_policy = BetaPolicy::PerHead(vec![0.9375, 0.984497]);
        let (mixed, sig) = m
            .decode_step(Allocation::Pasa16, 7, 0, &mut cache, &mut pool)
            .unwrap();
        assert!(mixed.iter().all(|x| x.is_finite()));
        assert_eq!(sig.nonfinite, 0);
        cache.release(&mut pool);
    }

    #[test]
    fn prefill_chunk_is_invariant_to_chunk_boundaries() {
        // The token-identity contract: any split of [0, n) into chunks
        // yields a bit-identical cache and final logits.
        let m = LabModel::synthetic(tiny_dims(), 11);
        let sp: crate::model::Specials = Default::default();
        let ids = crate::model::tokenizer::encode_prompt("chunk invariance!", 32, sp);
        let n = ids.len();
        let splits: [&[usize]; 3] = [&[n], &[1, n], &[5, 9, n]];
        let mut outs = Vec::new();
        for split in splits {
            let mut pool = KvPool::new(128, 4, 16);
            let mut cache = SeqCache::new(2);
            let mut start = 0;
            let mut logits = None;
            let mut sig = GuardSignal::default();
            for &end in split {
                let (lg, s) = m
                    .prefill_chunk(Allocation::Pasa16, &ids, start, end, &mut cache, &mut pool)
                    .unwrap();
                logits = lg;
                sig.merge(&s);
                start = end;
            }
            let logits = logits.expect("final chunk returns logits");
            assert_eq!(sig.nonfinite, 0);
            // Snapshot the cache contents before releasing.
            let mut dense = vec![0.0f32; tiny_dims().max_seq * 16];
            let mut rows: Vec<Vec<f32>> = Vec::new();
            for l in 0..2 {
                for want_v in [false, true] {
                    cache.fill_dense(&pool, l, want_v, &mut dense).unwrap();
                    rows.push(dense[..n * 16].to_vec());
                }
            }
            cache.release(&mut pool);
            outs.push((logits, rows));
        }
        for o in &outs[1..] {
            assert_eq!(outs[0].0, o.0, "logits depend on chunk split");
            assert_eq!(outs[0].1, o.1, "cache rows depend on chunk split");
        }
    }

    #[test]
    fn decode_attends_to_prefill_cache() {
        // Seed the cache from a prefill, then decode the next position:
        // the step must consume the seeded rows (different prompts give
        // different next-token logits even for the same decode token).
        let m = LabModel::synthetic(tiny_dims(), 9);
        let sp: crate::model::Specials = Default::default();
        let mut logits = Vec::new();
        for text in ["abc", "xyz"] {
            let (ids, n) = crate::model::tokenizer::encode(text, 16, sp);
            let pf = m.prefill(Allocation::Fa32, &ids, n).unwrap();
            let mut pool = KvPool::new(128, 4, 16);
            let mut cache = SeqCache::new(2);
            cache.ensure_capacity(&mut pool, n).unwrap();
            for l in 0..2 {
                for p in 0..n {
                    cache
                        .write_row(&mut pool, l, p, pf.k_rows[l].row(p), pf.v_rows[l].row(p))
                        .unwrap();
                }
            }
            let (lg, _) = m
                .decode_step(Allocation::Fa32, 65, n, &mut cache, &mut pool)
                .unwrap();
            cache.release(&mut pool);
            logits.push(lg);
        }
        assert_ne!(logits[0], logits[1], "cache must influence the decode step");
    }
}
