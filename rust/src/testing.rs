//! Mini property-testing harness (S15) — no proptest offline.
//!
//! `check(n, gen, prop)` runs `prop` on `n` random inputs from `gen`; on
//! failure it performs greedy shrinking via the input's `Shrink` impl and
//! panics with the minimal failing case. Used for the coordinator
//! invariants (paged KV pool, router) and the numeric substrates.

use crate::workloads::Pcg64;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
            // shrink one element
            for (i, x) in self.iter().enumerate() {
                for sx in x.shrink().into_iter().take(1) {
                    let mut v = self.clone();
                    v[i] = sx;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over `n` random cases; shrink on failure.
///
/// `prop` returns `Err(reason)` on violation.
pub fn check<T, G, P>(n: usize, seed: u64, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Pcg64) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Pcg64::new(seed, 0x9097);
    for case_idx in 0..n {
        let input = gen(&mut rng);
        if let Err(first_reason) = prop(&input) {
            // Greedy shrink to a minimal failing input.
            let mut cur = input;
            let mut reason = first_reason;
            let mut progress = true;
            let mut rounds = 0;
            while progress && rounds < 200 {
                progress = false;
                rounds += 1;
                for cand in cur.shrink() {
                    if let Err(r) = prop(&cand) {
                        cur = cand;
                        reason = r;
                        progress = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case_idx}, seed {seed}) on minimal input {cur:?}: {reason}"
            );
        }
    }
}

/// Convenience: assert with a formatted reason inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            50,
            1,
            |rng| rng.below(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("impossible".into())
                }
            },
        );
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            check(
                100,
                2,
                |rng| rng.below(1000) + 10,
                |&x| {
                    if x < 50 {
                        Ok(())
                    } else {
                        Err(format!("{x} >= 50"))
                    }
                },
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // Greedy shrinking must land on exactly 50 (the boundary).
        assert!(msg.contains("minimal input 50"), "{msg}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let v = vec![5usize, 6, 7, 8];
        let shrunk = v.shrink();
        assert!(shrunk.iter().any(|s| s.len() < v.len()));
    }
}
