//! Integration tests for the token-budget continuous-batching scheduler
//! (engine + scheduler + router + streaming), all on the artifact-free
//! lab backend.
//!
//! The heart of the suite is the **token-identity certification**: on a
//! seeded mixed arrival trace — greedy, temperature and top-k sampling,
//! prompts long enough to chunk, budgets tight enough to defer — every
//! request's token stream must be **bit-identical** to a sequential
//! one-request-at-a-time run of the same engine. This extends the repo's
//! paged≡dense and pooled≡sequential certifications to the scheduler
//! layer: batching, chunking and deferral decisions must be invisible in
//! the tokens. It holds by construction (chunk-boundary-invariant
//! prefill, per-slot paged decode, per-request sampling RNG, pure
//! scheduler decisions) and this suite is where the construction is
//! held to account.

use pasa::coordinator::{
    Admission, Completion, Engine, EngineConfig, FinishReason, GenParams, GuardPolicy, Request,
    SchedulerConfig, StreamEvent,
};
use pasa::model::{ModelDims, Sampling};
use pasa::runtime::{LabModel, NormMode};
use pasa::tensor::Matrix;
use pasa::workloads::{prompt_of_tokens, shared_prefix_prompt, Pcg64};

fn dims(n_layers: usize, max_seq: usize, decode_batch: usize) -> ModelDims {
    ModelDims {
        vocab_size: 259,
        d_model: 16,
        n_layers,
        n_heads: 2,
        d_head: 8,
        d_ff: 32,
        max_seq,
        prefill_seq: 16,
        decode_batch,
        pad: 256,
        bos: 257,
        eos: 258,
    }
}

fn params(max_new_tokens: usize, sampling: Sampling) -> GenParams {
    GenParams {
        max_new_tokens,
        sampling,
        stop_at_eos: false,
    }
}

/// Drive an engine over an arrival trace measured in engine steps:
/// submit everything due, step, drain completions and events, repeat
/// until idle. Returns (completions, events) in emission order.
fn drive(
    eng: &mut Engine<'_>,
    arrivals: &[(usize, Request)],
) -> (Vec<Completion>, Vec<StreamEvent>) {
    let mut comps = Vec::new();
    let mut events = Vec::new();
    let mut next = 0usize;
    let mut step = 0usize;
    while next < arrivals.len() || !eng.idle() {
        while next < arrivals.len() && arrivals[next].0 <= step {
            assert_eq!(
                eng.submit(arrivals[next].1.clone()),
                Admission::Queued,
                "trace request must admit"
            );
            next += 1;
        }
        eng.step().unwrap();
        comps.extend(eng.take_completions());
        events.extend(eng.take_events());
        step += 1;
        assert!(step < 10_000, "engine failed to drain the trace");
    }
    (comps, events)
}

fn tokens_of(events: &[StreamEvent], id: u64) -> Vec<u32> {
    events
        .iter()
        .filter_map(|e| match e {
            StreamEvent::Token(t) if t.request_id == id => Some(t.token),
            StreamEvent::Token(_) | StreamEvent::Finished { .. } => None,
        })
        .collect()
}

#[test]
fn batched_token_streams_are_bit_identical_to_sequential_runs() {
    // Mixed seeded trace: three sampling modes, prompts from 3 to 40
    // tokens (the long ones must chunk under the 8-token budget),
    // staggered arrivals, a committed-token ceiling low enough to defer.
    let spec: [(usize, usize, usize, Sampling); 8] = [
        (0, 3, 8, Sampling::Greedy),
        (0, 40, 12, Sampling::Temperature(0.8)),
        (1, 17, 6, Sampling::TopK { k: 8, temperature: 0.9 }),
        (2, 9, 10, Sampling::Greedy),
        (2, 33, 8, Sampling::Temperature(1.1)),
        (5, 5, 16, Sampling::TopK { k: 4, temperature: 0.7 }),
        (6, 21, 6, Sampling::Greedy),
        (9, 12, 9, Sampling::Temperature(0.9)),
    ];
    let cfg = || {
        let mut c = EngineConfig::default();
        c.policy = GuardPolicy::Adaptive;
        c.kv_pages = 256;
        c.page_tokens = 8;
        c.max_queue = 64;
        c.sched = SchedulerConfig {
            max_batch_prefill_tokens: 8,
            max_batch_total_tokens: 120,
            waiting_served_ratio: 4.0,
            max_batch_size: 0,
            ..SchedulerConfig::default()
        };
        c
    };
    let request = |id: u64, ptoks: usize, max_new: usize, s: Sampling| {
        Request::new(id, prompt_of_tokens(ptoks)).with_params(params(max_new, s))
    };

    // Batched run: everything through one engine under contention.
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(2, 64, 3), 42), cfg());
    let arrivals: Vec<(usize, Request)> = spec
        .iter()
        .enumerate()
        .map(|(i, &(step, p, n, s))| (step, request(i as u64 + 1, p, n, s)))
        .collect();
    let (comps, events) = drive(&mut eng, &arrivals);
    assert_eq!(comps.len(), 8);
    assert!(eng.idle());
    assert_eq!(eng.kv_utilization(), 0.0, "pages leaked");

    // The trace actually exercised the scheduler: prompts chunked, and
    // at least one admission was deferred on a budget.
    assert!(
        eng.metrics.prefill_chunks > 8,
        "long prompts must have chunked (chunks = {})",
        eng.metrics.prefill_chunks
    );
    let d = &eng.metrics.deferrals;
    assert!(
        d.slots + d.total_tokens + d.prefill_budget + d.kv_pages > 0,
        "budgets were never contended — the trace is too easy to certify anything"
    );

    // Streaming integrity: per request, the event stream IS the
    // completion — same tokens, dense 0-based indices, positions offset
    // by the prompt, exactly one Finished marker with the same reason.
    for c in &comps {
        let streamed = tokens_of(&events, c.id);
        assert_eq!(streamed, c.tokens, "request {} stream != completion", c.id);
        let mut idx = 0usize;
        for e in &events {
            match e {
                StreamEvent::Token(t) if t.request_id == c.id => {
                    assert_eq!(t.index, idx, "request {} indices not dense", c.id);
                    assert_eq!(t.position, c.prompt_tokens + idx);
                    idx += 1;
                }
                StreamEvent::Token(_) | StreamEvent::Finished { .. } => {}
            }
        }
        let finished: Vec<FinishReason> = events
            .iter()
            .filter_map(|e| match e {
                StreamEvent::Finished { request_id, reason } if *request_id == c.id => {
                    Some(*reason)
                }
                StreamEvent::Token(_) | StreamEvent::Finished { .. } => None,
            })
            .collect();
        assert_eq!(finished, vec![c.reason], "request {} finish markers", c.id);
    }

    // TTFT/ITL accounting: one TTFT sample per request; one ITL gap per
    // generated token except each request's first.
    let total: u64 = comps.iter().map(|c| c.tokens.len() as u64).sum();
    assert_eq!(eng.metrics.ttft.count(), 8);
    assert_eq!(eng.metrics.itl.count() as u64, total - 8);

    // The certification: each request solo — same id (the sampling RNG
    // seed), same prompt, same params, fresh identical model — must
    // produce the very same tokens the contended batch produced.
    for (i, &(_, p, n, s)) in spec.iter().enumerate() {
        let id = i as u64 + 1;
        let mut solo = Engine::from_lab(LabModel::synthetic(dims(2, 64, 3), 42), cfg());
        let (sc, se) = drive(&mut solo, &[(0, request(id, p, n, s))]);
        assert_eq!(sc.len(), 1);
        let batched = comps.iter().find(|c| c.id == id).unwrap();
        assert_eq!(
            sc[0].tokens, batched.tokens,
            "request {id}: batched stream diverged from its solo run"
        );
        assert_eq!(tokens_of(&se, id), batched.tokens);
    }
}

#[test]
fn chunked_prefill_never_stalls_inflight_decodes() {
    // A short request is decoding; a 33-token prompt is admitted
    // mid-flight under an 8-token chunk budget. The pin: during every
    // one of the long prompt's chunk rounds, the in-flight request
    // gains exactly one token — a mid-flight prefill never costs an
    // in-flight stream more than one chunk of latency, and never a
    // skipped round.
    let mut cfg = EngineConfig::default();
    cfg.policy = GuardPolicy::AlwaysPasa;
    cfg.kv_pages = 64;
    cfg.page_tokens = 8;
    cfg.sched.max_batch_prefill_tokens = 8;
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(1, 64, 2), 7), cfg);

    let a = eng.fresh_id();
    eng.submit(Request::new(a, prompt_of_tokens(4)).with_params(params(20, Sampling::Greedy)));
    eng.step().unwrap();
    let ev = eng.take_events();
    // Admission step: the prefill-sampled first token plus the same
    // step's decode round.
    assert_eq!(tokens_of(&ev, a).len(), 2, "A's first tokens out of prefill");

    let b = eng.fresh_id();
    eng.submit(Request::new(b, prompt_of_tokens(33)).with_params(params(6, Sampling::Greedy)));
    // 33 tokens / 8-token chunks = 4 full rounds + the final round of 1.
    for round in 0..4 {
        eng.step().unwrap();
        let ev = eng.take_events();
        assert_eq!(
            tokens_of(&ev, a).len(),
            1,
            "A stalled during B's chunk round {round}"
        );
        assert_eq!(
            tokens_of(&ev, b).len(),
            0,
            "B emitted before its prefill finished (round {round})"
        );
    }
    eng.step().unwrap();
    let ev = eng.take_events();
    assert_eq!(tokens_of(&ev, a).len(), 1, "A stalled on B's final chunk");
    // B's prefill-sampled first token plus its first decode-round token.
    assert_eq!(tokens_of(&ev, b).len(), 2, "B streams as soon as its last chunk lands");

    // Chunk accounting: A's single-chunk prefill + B's five.
    assert_eq!(eng.metrics.prefill_chunks, 6);
    assert_eq!(eng.metrics.prefill_tokens, 4 + 33);
    eng.run_to_completion().unwrap();
    assert_eq!(eng.kv_utilization(), 0.0);
}

/// The deterministic overflow-probe model from the serving suite: a
/// positional query spike at `P_STAR` drives the FA16-32 score row past
/// the FP16 boundary (PASA's shift absorbs it); K/V stay benign, and
/// token 100 carries a logit margin so greedy decoding is stable.
const P_STAR: usize = 12;
const AMP: f32 = 30_000.0;

fn probe_model() -> LabModel {
    let d = dims(1, 32, 2);
    let mut m = LabModel::synthetic(d, 0xBEEF);
    m.norm = NormMode::Identity;
    let mut rng = Pcg64::new(1234, 0);
    for v in &mut m.tok_emb.data {
        *v = rng.normal(0.0, 0.01) as f32;
    }
    for j in 0..8 {
        let old = m.tok_emb.at(100, j);
        m.tok_emb.set(100, j, old + 0.3);
    }
    for v in &mut m.pos_emb.data {
        *v = 0.5;
    }
    for j in 8..16 {
        m.pos_emb.set(P_STAR, j, AMP);
    }
    let lw = &mut m.layers[0];
    lw.wq = Matrix::zeros(16, 16);
    lw.wk = Matrix::zeros(16, 16);
    for j in 0..8 {
        lw.wq.set(8 + j, j, 1.0);
        lw.wq.set(8 + j, 8 + j, 1.0);
        lw.wk.set(j, j, 1.0);
        lw.wk.set(j, 8 + j, 1.0);
    }
    lw.wv = lw.wk.clone();
    let mut wo = Matrix::zeros(16, 16);
    for i in 0..16 {
        wo.set(i, i, 0.1);
    }
    lw.wo = wo;
    lw.w1 = Matrix::zeros(16, 32);
    lw.b1 = vec![0.0; 32];
    lw.w2 = Matrix::zeros(32, 16);
    lw.b2 = vec![0.0; 16];
    m
}

#[test]
fn guard_replay_in_a_dynamic_batch_leaves_cobatched_streams_untouched() {
    // Request 1 crosses P_STAR and gets its round replayed under PASA;
    // request 2 shares every one of those decode rounds. Under dynamic
    // batching the co-batched stream must be bit-identical to its solo
    // run — a neighbour's guard trip is that neighbour's problem only.
    let cfg = || {
        let mut c = EngineConfig::default();
        c.policy = GuardPolicy::Adaptive;
        c.kv_pages = 64;
        c.page_tokens = 8;
        c.max_queue = 16;
        c
    };
    let mut both = Engine::from_lab(probe_model(), cfg());
    let arrivals = vec![
        (0, Request::new(1, "aaaaaaa").with_params(params(20, Sampling::Greedy))),
        (0, Request::new(2, "zz").with_params(params(8, Sampling::Greedy))),
    ];
    let (comps, events) = drive(&mut both, &arrivals);
    assert_eq!(both.metrics.guard_switches, 1, "the trip must have fired");
    let tripped = comps.iter().find(|c| c.id == 1).unwrap();
    assert_eq!(tripped.allocation, "pasa");
    let clean = comps.iter().find(|c| c.id == 2).unwrap();
    assert_eq!(clean.allocation, "fa16_32", "clean stream must not be pinned");

    let mut solo = Engine::from_lab(probe_model(), cfg());
    let (sc, _) =
        drive(&mut solo, &[(0, Request::new(2, "zz").with_params(params(8, Sampling::Greedy)))]);
    assert_eq!(solo.metrics.guard_switches, 0, "solo clean run must not trip");
    assert_eq!(
        sc[0].tokens, clean.tokens,
        "co-batched stream perturbed by its neighbour's guard replay"
    );
    assert_eq!(tokens_of(&events, 2), clean.tokens);
}

#[test]
fn starvation_bound_serves_batch_work_through_an_interactive_flood() {
    // One slot, six interactive requests and one batch request, all
    // queued up front. Strict priority (FIFO-compat) finishes the batch
    // request dead last; waiting_served_ratio = 2 must force it through
    // after exactly two interactive services.
    use pasa::coordinator::Priority;
    let run = |sched: SchedulerConfig| {
        let mut cfg = EngineConfig::default();
        cfg.policy = GuardPolicy::AlwaysPasa;
        cfg.kv_pages = 64;
        cfg.page_tokens = 8;
        cfg.max_queue = 16;
        cfg.sched = sched;
        let mut eng = Engine::from_lab(LabModel::synthetic(dims(1, 32, 2), 3), cfg);
        let mut arrivals = Vec::new();
        for i in 0..6u64 {
            arrivals.push((
                0usize,
                Request::new(i + 1, "a")
                    .with_params(params(2, Sampling::Greedy))
                    .with_priority(Priority::Interactive),
            ));
        }
        arrivals.push((
            0usize,
            Request::new(7, "b")
                .with_params(params(2, Sampling::Greedy))
                .with_priority(Priority::Batch),
        ));
        let (comps, _) = drive(&mut eng, &arrivals);
        assert_eq!(comps.len(), 7);
        let deferrals = eng.metrics.deferrals.slots;
        (
            comps.iter().position(|c| c.id == 7).unwrap(),
            deferrals,
        )
    };

    let strict = SchedulerConfig {
        max_batch_size: 1,
        ..SchedulerConfig::fifo_compat()
    };
    let (pos, _) = run(strict);
    assert_eq!(pos, 6, "strict priority starves batch to the very end");

    let bounded = SchedulerConfig {
        max_batch_size: 1,
        waiting_served_ratio: 2.0,
        ..SchedulerConfig::default()
    };
    let (pos, defer_slots) = run(bounded);
    assert_eq!(
        pos, 2,
        "ratio 2.0 must force the batch request through after 2 bypasses"
    );
    assert!(defer_slots > 0, "the single slot must have caused deferrals");
}

#[test]
fn multibyte_prompt_serves_end_to_end_on_token_admission() {
    // Engine-level regression for byte-vs-token admission: 40 'é' chars
    // are 80 bytes — past the old byte-derived limit (prefill_seq * 4 =
    // 64) — but 81 tokens, comfortably inside a 96-token context. The
    // request must admit AND actually serve (chunked prefill handles a
    // prompt longer than prefill_seq).
    let prompt = "é".repeat(40);
    assert_eq!(prompt.len(), 80);
    assert!(prompt.len() > 16 * 4, "premise: the old byte rule rejected this");

    let mut cfg = EngineConfig::default();
    cfg.policy = GuardPolicy::AlwaysPasa;
    cfg.kv_pages = 64;
    cfg.page_tokens = 8;
    cfg.sched.max_batch_prefill_tokens = 16;
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(1, 96, 2), 11), cfg);
    let id = eng.fresh_id();
    assert_eq!(
        eng.submit(Request::new(id, prompt).with_params(params(4, Sampling::Greedy))),
        Admission::Queued
    );
    let comps = eng.run_to_completion().unwrap();
    assert_eq!(comps.len(), 1);
    let c = &comps[0];
    assert_eq!(c.prompt_tokens, 81, "BOS + 80 bytes");
    assert_eq!(c.reason, FinishReason::MaxTokens);
    assert_eq!(c.tokens.len(), 4);
    // 81 tokens / 16-token chunks = 6 prefill rounds.
    assert_eq!(eng.metrics.prefill_chunks, 6);
    assert_eq!(eng.kv_utilization(), 0.0);
}

#[test]
fn shared_prefix_fleet_saves_prefill_and_keeps_streams_bit_identical() {
    // The prefix-cache acceptance pin: a fleet of 6 sharing a 64-token
    // system prompt (4 pages at page_tokens = 16). The leader's prefill
    // populates the radix cache; every follower must seed its cache from
    // the shared pages and skip that whole page-aligned span — and every
    // token stream must be bit-identical to a prefix-cache-off run.
    const PREFIX: usize = 64;
    const FLEET: usize = 6;
    let cfg = |cache_pages: usize| {
        let mut c = EngineConfig::default();
        c.policy = GuardPolicy::Adaptive;
        c.kv_pages = 256;
        c.page_tokens = 16;
        c.max_queue = 16;
        c.prefix_cache_pages = cache_pages;
        c.sched.max_batch_prefill_tokens = 128;
        c
    };
    // Leader at step 0 (its prefill completion inserts the prefix);
    // followers two steps later, with per-request distinct tails and a
    // mix of sampling modes so the per-request RNG contract is live.
    let arrivals: Vec<(usize, Request)> = (0..FLEET)
        .map(|i| {
            let s = match i % 3 {
                0 => Sampling::Greedy,
                1 => Sampling::Temperature(0.8),
                _ => Sampling::TopK { k: 8, temperature: 0.9 },
            };
            let r = Request::new(i as u64 + 1, shared_prefix_prompt(PREFIX, 70 + i, i))
                .with_params(params(4, s));
            (if i == 0 { 0 } else { 2 }, r)
        })
        .collect();

    let mut eng = Engine::from_lab(LabModel::synthetic(dims(2, 128, 4), 42), cfg(128));
    let (comps, _) = drive(&mut eng, &arrivals);
    assert_eq!(comps.len(), FLEET);

    // Every follower hit the full page-aligned prefix: ≥ (fleet − 1) × 64
    // prompt tokens never re-prefilled.
    let pm = eng.metrics.prefix;
    assert!(
        pm.tokens_saved >= ((FLEET - 1) * PREFIX) as u64,
        "saved only {} prefill tokens (hits {})",
        pm.tokens_saved,
        pm.hits
    );
    assert!(pm.hits >= (FLEET - 1) as u64, "hits = {}", pm.hits);
    assert_eq!(
        eng.metrics.prefill_tokens as usize,
        arrivals.iter().map(|(_, r)| 70 + r.id as usize - 1).sum::<usize>()
            - (FLEET - 1) * PREFIX,
        "prefill work must shrink by exactly the shared spans"
    );

    // The cache keeps the prefix resident after the fleet drains; a
    // flush returns the pool to empty — no leaked references.
    assert!(eng.idle());
    assert!(eng.prefix_pages_held() > 0, "prefix must stay resident");
    assert!(eng.kv_utilization() > 0.0);
    assert!(eng.flush_prefix_cache() > 0);
    assert_eq!(eng.kv_utilization(), 0.0, "pages leaked past the flush");

    // Bit-identity: the same trace with the cache disabled must produce
    // the very same stream for every request — page sharing introduces
    // zero new error sites.
    let mut off = Engine::from_lab(LabModel::synthetic(dims(2, 128, 4), 42), cfg(0));
    let (comps_off, _) = drive(&mut off, &arrivals);
    assert_eq!(off.metrics.prefix.hits, 0);
    for c in &comps {
        let o = comps_off.iter().find(|o| o.id == c.id).unwrap();
        assert_eq!(
            c.tokens, o.tokens,
            "request {}: prefix-cache run diverged from the cold run",
            c.id
        );
        assert_eq!(c.reason, o.reason);
    }
}

#[test]
fn shared_pages_are_charged_once_at_admission() {
    // Engine-level regression for the scheduler over-count bugfix: a
    // pool too small for a follower's *full* KV price must still admit
    // it when the shared prefix pages are already resident — the
    // feasibility check may charge radix-shared pages only once.
    const PREFIX: usize = 32; // 4 pages at page_tokens = 8
    let mut cfg = EngineConfig::default();
    cfg.policy = GuardPolicy::AlwaysPasa;
    cfg.kv_pages = 12;
    cfg.page_tokens = 8;
    cfg.prefix_cache_pages = 8;
    cfg.sched.max_batch_prefill_tokens = 64;
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(1, 64, 2), 7), cfg);

    // Leader: 36-token prompt, full price 2 × ceil(38/8) = 10 ≤ 12 pages.
    let a = eng.fresh_id();
    eng.submit(
        Request::new(a, shared_prefix_prompt(PREFIX, 36, 0)).with_params(params(2, Sampling::Greedy)),
    );
    let comps = eng.run_to_completion().unwrap();
    assert_eq!(comps[0].reason, FinishReason::MaxTokens);
    // The radix cache keeps the 4-page prefix (K + V) resident: 8 pages
    // held, 4 free — a cold follower (full price 10 pages for 36 + 4
    // tokens) could never fit.
    assert_eq!(eng.prefix_pages_held(), 8);

    let b = eng.fresh_id();
    eng.submit(
        Request::new(b, shared_prefix_prompt(PREFIX, 36, 1)).with_params(params(4, Sampling::Greedy)),
    );
    let comps = eng.run_to_completion().unwrap();
    assert_eq!(comps.len(), 1);
    assert_eq!(
        comps[0].reason,
        FinishReason::MaxTokens,
        "follower must serve out of the shared pages"
    );
    assert_eq!(comps[0].tokens.len(), 4);
    assert_eq!(
        eng.metrics.deferrals.kv_pages, 0,
        "shared pages were double-charged at admission"
    );
    assert_eq!(eng.metrics.prefix.hits, 1);
    assert_eq!(eng.metrics.prefix.tokens_saved, PREFIX as u64);
    eng.flush_prefix_cache();
    assert_eq!(eng.kv_utilization(), 0.0);
}

#[test]
fn best_of_fan_out_streams_match_independent_runs() {
    // One prefill fans out into n decode slots over CoW forks. The pin:
    // each stream — primary and siblings — is bit-identical to an
    // independent engine run submitting the same (id, prompt, params)
    // normally, under temperature sampling (so the per-request RNG is
    // doing real work and any fork-path perturbation shows up).
    let cfg = || {
        let mut c = EngineConfig::default();
        c.policy = GuardPolicy::Adaptive;
        c.kv_pages = 128;
        c.page_tokens = 8;
        c.prefix_cache_pages = 32;
        c
    };
    let prompt = prompt_of_tokens(21);
    let gp = params(6, Sampling::Temperature(0.9));

    let mut eng = Engine::from_lab(LabModel::synthetic(dims(2, 64, 4), 13), cfg());
    let primary = eng.fresh_id();
    let (adm, ids) = eng
        .submit_best_of(Request::new(primary, prompt.clone()).with_params(gp), 3)
        .unwrap();
    assert_eq!(adm, Admission::Queued);
    assert_eq!(ids.len(), 3);
    assert_eq!(ids[0], primary);
    let comps = eng.run_to_completion().unwrap();
    assert_eq!(comps.len(), 3, "primary + 2 forked siblings must complete");
    assert_eq!(eng.metrics.prefix.fanout_forks, 2);
    // One prefill for the whole fan: the prompt was processed once.
    assert_eq!(eng.metrics.prefill_tokens, 21);
    for id in &ids {
        let c = comps.iter().find(|c| c.id == *id).unwrap();
        assert_eq!(c.tokens.len(), 6);
        assert_eq!(c.reason, FinishReason::MaxTokens);
    }
    // Distinct RNG streams actually diverge under temperature sampling.
    let streams: Vec<&Vec<u32>> = ids
        .iter()
        .map(|id| &comps.iter().find(|c| c.id == *id).unwrap().tokens)
        .collect();
    assert!(
        streams[0] != streams[1] || streams[0] != streams[2],
        "sibling RNGs are aliased — every fan decoded the same tokens"
    );
    eng.flush_prefix_cache();
    assert_eq!(eng.kv_utilization(), 0.0);

    // Certification: each fan ≡ its independent run.
    for id in ids {
        let mut solo = Engine::from_lab(LabModel::synthetic(dims(2, 64, 4), 13), cfg());
        let (sc, _) = drive(
            &mut solo,
            &[(0, Request::new(id, prompt.clone()).with_params(gp))],
        );
        assert_eq!(sc.len(), 1);
        let fanned = comps.iter().find(|c| c.id == id).unwrap();
        assert_eq!(
            sc[0].tokens, fanned.tokens,
            "fan {id}: forked stream diverged from its independent run"
        );
    }
}

#[test]
fn oversized_commitment_is_rejected_not_spun_on() {
    // A request whose KV commitment can never fit the pool must come
    // back as an Evicted completion — the engine may not spin forever
    // retrying it, and later work must still be served.
    let mut cfg = EngineConfig::default();
    cfg.policy = GuardPolicy::AlwaysPasa;
    cfg.kv_pages = 4; // pathologically small pool
    cfg.page_tokens = 8;
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(2, 64, 2), 5), cfg);
    let a = eng.fresh_id();
    eng.submit(Request::new(a, prompt_of_tokens(40)).with_params(params(8, Sampling::Greedy)));
    let comps = eng.run_to_completion().unwrap();
    assert_eq!(comps.len(), 1);
    assert_eq!(comps[0].reason, FinishReason::Evicted);
    assert!(eng.idle());
}
