//! Differential fuzz harness (S18): ~200 seed-deterministic random cases
//! per registry allocation — all six — checked against the naive-f32
//! oracle and across execution paths.
//!
//! Per case (drawn by `pasa::testkit::fuzz_case` — shapes, GQA splits,
//! masks with zero-length heads, paged-vs-dense views, β policies, and
//! the paper's Eq. 17/18 bias/amplitude regimes):
//!
//! 1. **finite-or-reported-overflow** — a non-finite output element is
//!    only legal when the kernel telemetry reported the overflow (events
//!    at the store boundary, or a pre-store |S| past it). Silent NaN is
//!    the paper's failure mode; the guard can only rescue what is
//!    reported.
//! 2. **RMSE bound per allocation** vs the naive-f32 oracle, gated to
//!    the regime where the allocation's envelope is meaningful (every
//!    case for FA32; benign-regime cases for the FP16 rows; benign cases
//!    with a small stored-score peak for the E4M3 rows, whose eps 2⁻⁴
//!    makes large exponents legitimately unstable). Coverage counters
//!    assert the gates never go vacuous.
//! 3. **paged ≡ dense bitwise** — the same case through NaN-tail-poisoned
//!    `KvView::Paged` fixtures must reproduce the dense bits and
//!    telemetry exactly.
//! 4. **pooled ≡ sequential bitwise** — the worker-pool fan-out against
//!    the in-order fallback (`pool::set_parallel(false)`).
//!
//! Every assertion message carries the case's **replay seed**: rebuild
//! the exact failing case with `pasa::testkit::fuzz_case(seed)`.
//!
//! V is always drawn benign (mirroring the resonance generator, whose V
//! is N(0, 1)): the overflow mechanism under test is the score GEMM, and
//! a huge V would instead overflow the PV store — a different, unguarded
//! site the 8-bit rows make trivially reachable.

use pasa::attention::{
    Allocation, AttentionOutput, AttentionRequest, KernelRegistry, KvPair, KvView, PageId,
};
use pasa::coordinator::{KvPool, KvStore, SeqCache};
use pasa::model::{sample, ModelDims, Sampling};
use pasa::numerics::relative_rmse;
use pasa::pool;
use pasa::runtime::LabModel;
use pasa::testkit::{fuzz_case, matrix_bits, paged_fixture, FixturePool, FuzzRegime};
use pasa::workloads::Pcg64;

/// Cases per allocation (the acceptance count).
const CASES: u64 = 200;

/// Page size chosen to not divide the typical KV length or block sizes,
/// so block gathers straddle page boundaries.
const PAGE_TOKENS: usize = 7;

fn assert_bit_equal(a: &AttentionOutput, b: &AttentionOutput, what: &str, seed: u64) {
    for h in 0..a.heads.len() {
        assert_eq!(
            matrix_bits(&a.heads[h]),
            matrix_bits(&b.heads[h]),
            "{what} diverged on head {h} — replay seed {seed:#018x}"
        );
        assert_eq!(
            a.stats[h].overflow_events, b.stats[h].overflow_events,
            "{what} telemetry (events) diverged on head {h} — replay seed {seed:#018x}"
        );
        assert_eq!(
            a.stats[h].max_abs_score.to_bits(),
            b.stats[h].max_abs_score.to_bits(),
            "{what} telemetry (max|S|) diverged on head {h} — replay seed {seed:#018x}"
        );
    }
}

/// The per-allocation RMSE envelope and its gate. FA32 tracks the oracle
/// to f32 accuracy everywhere. The FP16 rows hold a loose low-precision
/// envelope on benign-regime data (the tight per-regime envelopes live in
/// `experiments/rmse_sweep.rs`). The E4M3 rows additionally require a
/// small stored-score peak: at eps 2⁻⁴ a large softmax exponent is
/// legitimately unstable, so only the small-exponent regime is a fair
/// oracle comparison.
fn rmse_gate(alloc: Allocation, regime: FuzzRegime, out: &AttentionOutput) -> Option<f64> {
    let clean = out.overflow_events() == 0 && out.nonfinite_outputs() == 0;
    match alloc {
        Allocation::Fa32 => Some(1e-4),
        Allocation::Fa16_32 | Allocation::Fa16 | Allocation::Pasa16 => {
            (regime == FuzzRegime::Benign && clean).then_some(0.25)
        }
        Allocation::Fp8 | Allocation::Pasa8 => {
            // Stored peaks ≤ 16 keep the E4M3 quantization of the softmax
            // exponent bounded (abs error ≤ 1 ⇒ weight factor ≤ e). The
            // loose 1.0 bound is a sanity floor — it catches mask leaks,
            // wrong-row selection and sign flips, while the calibrated
            // E4M3 envelopes live in the seeded rmse_sweep tests.
            (regime == FuzzRegime::Benign && clean && out.max_abs_score() <= 16.0).then_some(1.0)
        }
    }
}

/// Minimum number of cases (of [`CASES`]) whose RMSE gate must open, per
/// allocation — keeps the oracle comparison from going silently vacuous.
fn min_rmse_coverage(alloc: Allocation) -> usize {
    match alloc {
        Allocation::Fa32 => 190,
        Allocation::Fa16_32 | Allocation::Fa16 | Allocation::Pasa16 => 80,
        Allocation::Fp8 | Allocation::Pasa8 => 5,
    }
}

/// The harness body: 200 seeded cases through one allocation.
fn fuzz_allocation(alloc: Allocation, stream: u64) {
    // The parallel/sequential toggle is process-global; serialize with
    // every other toggling test for the whole sweep.
    let _mode = pool::test_mode_guard();
    let mut rmse_checked = 0usize;
    let mut overflow_cases = 0usize;
    for i in 0..CASES {
        let seed = (stream << 32) | i;
        let fc = fuzz_case(seed);
        let req = fc.req.clone().with_alloc(alloc);
        req.validate().unwrap_or_else(|e| {
            panic!("invalid generated request ({e}) — replay seed {seed:#018x}")
        });

        let out = req.run();

        // 1. finite-or-reported-overflow.
        if out.nonfinite_outputs() > 0 {
            overflow_cases += 1;
            assert!(
                out.overflow_events() > 0 || out.max_abs_score() > out.score_boundary,
                "{}: silent NaN — {} non-finite outputs with clean telemetry \
                 (max|S| {} vs boundary {}) — replay seed {seed:#018x}",
                alloc.name(),
                out.nonfinite_outputs(),
                out.max_abs_score(),
                out.score_boundary,
            );
        }

        // 2. RMSE vs the naive-f32 oracle, where the gate opens.
        if let Some(bound) = rmse_gate(alloc, fc.regime, &out) {
            let golden = KernelRegistry::naive().forward(&req);
            rmse_checked += 1;
            for h in 0..out.heads.len() {
                let e = relative_rmse(&out.heads[h].data, &golden.heads[h].data);
                assert!(
                    e < bound,
                    "{}: head {h} rmse {e} past the {bound} envelope \
                     (regime {:?}, max|S| {}) — replay seed {seed:#018x}",
                    alloc.name(),
                    fc.regime,
                    out.max_abs_score(),
                );
            }
        }

        // 3. paged ≡ dense, bitwise (NaN-poisoned page tails).
        type Fixture = (FixturePool, Vec<PageId>);
        let fixtures: Vec<(Fixture, Fixture)> = (0..fc.n_kv_heads)
            .map(|kvh| {
                (
                    paged_fixture(&req.k[kvh], PAGE_TOKENS),
                    paged_fixture(&req.v[kvh], PAGE_TOKENS),
                )
            })
            .collect();
        let pairs: Vec<KvPair<'_>> = fixtures
            .iter()
            .map(|((kp, kids), (vp, vids))| KvPair {
                k: KvView::paged(kids, kp, fc.s2),
                v: KvView::paged(vids, vp, fc.s2),
            })
            .collect();
        let paged = req.run_with_kv(&pairs);
        assert_bit_equal(&out, &paged, &format!("{}: paged vs dense", alloc.name()), seed);

        // 4. pooled ≡ sequential, bitwise.
        pool::set_parallel(false);
        let sequential = req.run();
        pool::set_parallel(true);
        assert_bit_equal(
            &out,
            &sequential,
            &format!("{}: pooled vs sequential", alloc.name()),
            seed,
        );
    }
    assert!(
        rmse_checked >= min_rmse_coverage(alloc),
        "{}: RMSE gate opened on only {rmse_checked}/{CASES} cases — the oracle \
         comparison went vacuous (stream {stream})",
        alloc.name()
    );
    // The 8-bit rows must actually see reported overflows in the hot
    // regime — otherwise property 1 never fired.
    if matches!(alloc, Allocation::Fp8 | Allocation::Pasa8) {
        assert!(
            overflow_cases >= 1,
            "{}: no case ever overflowed — the hot regime is not reaching 448",
            alloc.name()
        );
    }
}

#[test]
fn fuzz_fa32() {
    fuzz_allocation(Allocation::Fa32, 0xa1);
}

#[test]
fn fuzz_fa16_32() {
    fuzz_allocation(Allocation::Fa16_32, 0xa2);
}

#[test]
fn fuzz_fa16() {
    fuzz_allocation(Allocation::Fa16, 0xa3);
}

#[test]
fn fuzz_pasa16() {
    fuzz_allocation(Allocation::Pasa16, 0xa4);
}

#[test]
fn fuzz_fp8() {
    fuzz_allocation(Allocation::Fp8, 0xa5);
}

#[test]
fn fuzz_pasa8() {
    fuzz_allocation(Allocation::Pasa8, 0xa6);
}

#[test]
fn fuzz_covers_every_registry_row() {
    // The six fuzz streams above must stay in lockstep with the registry:
    // adding a seventh allocation without a fuzz stream fails here.
    assert_eq!(Allocation::all_extended().len(), 6);
}

// ---------------------------------------------------------------------------
// E4M3-quantized KV storage (PR 8): the same request served out of a
// byte-backed serving pool — quantize-on-write, LUT-dequantize-on-gather —
// priced against the f32-pool oracle by per-allocation RMSE gates.
// ---------------------------------------------------------------------------

/// Cases per allocation for the quantized-KV sweep.
const KV_CASES: u64 = 200;

/// Run a fuzz request with its K/V served from a real [`KvPool`] in the
/// given storage format: every row goes through `SeqCache::write_row`
/// (the engine's quantizing write seam) and comes back through the
/// paged-view gather, exactly the serving decode path.
fn run_from_pool(req: &AttentionRequest, store: KvStore) -> AttentionOutput {
    let d = req.k[0].cols;
    let s2 = req.k[0].rows;
    let pages = 2 * req.k.len() * s2.div_ceil(PAGE_TOKENS);
    let mut pool = KvPool::new_with_store(pages, PAGE_TOKENS, d, store);
    let mut caches: Vec<SeqCache> = Vec::new();
    for kvh in 0..req.k.len() {
        let mut s = SeqCache::new(1);
        s.ensure_capacity(&mut pool, s2).unwrap();
        for pos in 0..s2 {
            s.write_row(&mut pool, 0, pos, req.k[kvh].row(pos), req.v[kvh].row(pos))
                .unwrap();
        }
        caches.push(s);
    }
    let pairs: Vec<KvPair<'_>> = caches
        .iter()
        .map(|s| {
            let (k, v) = s.kv_views(&pool, 0);
            KvPair { k, v }
        })
        .collect();
    req.run_with_kv(&pairs)
}

/// Gate and envelope for the quantized-KV comparison. E4M3 KV is *lossy*
/// (eps 2⁻⁴ on every cached element), so the gate mirrors the 8-bit
/// stored-score gate above: benign regime, both runs clean, and an oracle
/// score peak ≤ 16 so the K-side quantization perturbs the softmax
/// exponent by at most ~1 (weight factor ≤ e). Within that regime the
/// envelopes are sanity floors — they catch wrong-row gathers, mask
/// leaks, sign flips and byte/LUT mismatches, while the *accuracy* story
/// of E4M3 storage is the paper's shifting analysis, not bit-equality.
fn kv_quant_gate(
    alloc: Allocation,
    regime: FuzzRegime,
    oracle: &AttentionOutput,
    quant: &AttentionOutput,
) -> Option<f64> {
    let clean =
        |o: &AttentionOutput| o.overflow_events() == 0 && o.nonfinite_outputs() == 0;
    if regime != FuzzRegime::Benign
        || !clean(oracle)
        || !clean(quant)
        || oracle.max_abs_score() > 16.0
    {
        return None;
    }
    Some(match alloc {
        // ≥16-bit compute: the only error source is the KV quantization
        // itself (~6% per element on V, ≤ e-factor weight distortion).
        Allocation::Fa32 | Allocation::Fa16_32 | Allocation::Fa16 | Allocation::Pasa16 => 0.75,
        // 8-bit compute stacks its own stored-score quantization on top.
        Allocation::Fp8 | Allocation::Pasa8 => 1.0,
    })
}

// ---------------------------------------------------------------------------
// Forked ≡ fresh (PR 10): a CoW prefix fork of a prefilled sequence must
// decode bit-identically to a from-scratch twin prefilled over the same
// prefix — and must never write through the donor's pages. This is the
// numerics contract of the radix prefix cache and best-of-n fan-out: page
// sharing introduces ZERO new error sites, so a cache hit can never
// perturb a PASA token stream.
// ---------------------------------------------------------------------------

/// Cases per KV store for the fork sweep (full lab forwards per case, so
/// far fewer than the kernel-level streams).
const FORK_CASES: u64 = 12;

/// Decode steps compared after the cut.
const FORK_DECODE_STEPS: usize = 4;

fn fork_dims() -> ModelDims {
    ModelDims {
        vocab_size: 259,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_head: 8,
        d_ff: 32,
        max_seq: 48,
        prefill_seq: 16,
        decode_batch: 2,
        pad: 256,
        bos: 257,
        eos: 258,
    }
}

/// Snapshot every valid row of a cache as bits (all layers, K and V).
fn cache_bits(cache: &SeqCache, pool: &KvPool, n_layers: usize, width: usize) -> Vec<u32> {
    let mut dense = vec![0.0f32; fork_dims().max_seq * width];
    let mut out = Vec::new();
    for l in 0..n_layers {
        for want_v in [false, true] {
            cache.fill_dense(pool, l, want_v, &mut dense).unwrap();
            out.extend(dense[..cache.len_tokens * width].iter().map(|x| x.to_bits()));
        }
    }
    out
}

/// The fork sweep body: random prompts prefilled into a donor cache,
/// forked at a random page-aligned cut, decoded against a from-scratch
/// twin under the engine's per-request RNG contract.
fn fuzz_forked_equals_fresh(store: KvStore, stream: u64) {
    let _mode = pool::test_mode_guard();
    let dims = fork_dims();
    let width = dims.head_width();
    let alloc = Allocation::Pasa16;
    let model = LabModel::synthetic(dims, 0xF08C);
    for i in 0..FORK_CASES {
        let seed = (stream << 32) | i;
        // Alternate the worker-pool mode so sharing is pinned under both
        // execution paths, not just the pooled fan-out.
        pool::set_parallel(i % 2 == 0);
        let mut rng = Pcg64::new(seed, 0xF08C);
        let n = 2 * PAGE_TOKENS + 2 + rng.below(12); // 16..=27 prompt rows
        let ids: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
        // Page-aligned cut strictly inside the prompt: ≥ 1 page, and the
        // donor keeps rows past the cut so "later pages untouched" is a
        // real assertion.
        let max_pages = (n - 1) / PAGE_TOKENS;
        let cut = PAGE_TOKENS * (1 + rng.below(max_pages));
        assert!(cut < n);

        let mut p = KvPool::new_with_store(96, PAGE_TOKENS, width, store);
        let mut donor = SeqCache::new(dims.n_layers);
        model
            .prefill_chunk(alloc, &ids, 0, n, &mut donor, &mut p)
            .unwrap_or_else(|e| panic!("donor prefill failed ({e}) — replay seed {seed:#018x}"));
        let donor_before = cache_bits(&donor, &p, dims.n_layers, width);

        // Forked: share the donor's aligned prefix pages (zero copies).
        let mut forked = donor
            .fork_prefix(&mut p, cut)
            .unwrap_or_else(|e| panic!("fork_prefix failed ({e}) — replay seed {seed:#018x}"));
        assert_eq!(forked.len_tokens, cut, "replay seed {seed:#018x}");

        // Fresh: an independent twin prefilled over prompt[..cut].
        let mut fresh = SeqCache::new(dims.n_layers);
        model
            .prefill_chunk(alloc, &ids, 0, cut, &mut fresh, &mut p)
            .unwrap_or_else(|e| panic!("twin prefill failed ({e}) — replay seed {seed:#018x}"));
        assert_eq!(
            cache_bits(&forked, &p, dims.n_layers, width),
            cache_bits(&fresh, &p, dims.n_layers, width),
            "shared prefix rows differ from recomputed rows — replay seed {seed:#018x}"
        );

        // Decode both under the engine's per-request RNG contract
        // (`request_rng(id) = Pcg64::new(0xe61e ^ id, id)`): same id on
        // both sides, so any token divergence is a numerics difference.
        let policy = Sampling::TopK { k: 8, temperature: 0.8 };
        let mut rng_forked = Pcg64::new(0xe61e ^ seed, seed);
        let mut rng_fresh = Pcg64::new(0xe61e ^ seed, seed);
        let mut tok_forked = ids[cut];
        let mut tok_fresh = ids[cut];
        for step in 0..FORK_DECODE_STEPS {
            let pos = cut + step;
            let (lf, _) = model
                .decode_step(alloc, tok_forked, pos, &mut forked, &mut p)
                .unwrap_or_else(|e| {
                    panic!("forked decode failed ({e}) — replay seed {seed:#018x}")
                });
            let (lg, _) = model
                .decode_step(alloc, tok_fresh, pos, &mut fresh, &mut p)
                .unwrap_or_else(|e| {
                    panic!("fresh decode failed ({e}) — replay seed {seed:#018x}")
                });
            assert_eq!(
                lf.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                lg.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "forked vs fresh logits diverged at step {step} — replay seed {seed:#018x}"
            );
            tok_forked = sample(&lf, policy, &mut rng_forked);
            tok_fresh = sample(&lg, policy, &mut rng_fresh);
            assert_eq!(
                tok_forked, tok_fresh,
                "forked vs fresh token streams diverged at step {step} — \
                 replay seed {seed:#018x}"
            );
        }

        // The donor never observed the fork: the shared pages and the
        // pages past the cut (which the prefix fork never referenced) are
        // all bit-intact.
        assert_eq!(
            donor_before,
            cache_bits(&donor, &p, dims.n_layers, width),
            "fork decode disturbed the donor's pages — replay seed {seed:#018x}"
        );
        forked.release(&mut p);
        fresh.release(&mut p);

        // Full fork (the best-of-n fan-out path): the donor's partially
        // filled tail page IS shared here, so the first decode write must
        // CoW-privatize it — the donor row bits still must not move, and
        // the fork must decode bit-identically to a from-scratch twin
        // prefilled over the whole prompt.
        let mut fanned = donor
            .fork(&mut p)
            .unwrap_or_else(|e| panic!("full fork failed ({e}) — replay seed {seed:#018x}"));
        let mut twin = SeqCache::new(dims.n_layers);
        model
            .prefill_chunk(alloc, &ids, 0, n, &mut twin, &mut p)
            .unwrap_or_else(|e| panic!("full twin prefill failed ({e}) — replay seed {seed:#018x}"));
        let mut tok_a = ids[0];
        let mut tok_b = ids[0];
        for step in 0..FORK_DECODE_STEPS {
            let pos = n + step;
            let (la, _) = model
                .decode_step(alloc, tok_a, pos, &mut fanned, &mut p)
                .unwrap_or_else(|e| {
                    panic!("fan-out decode failed ({e}) — replay seed {seed:#018x}")
                });
            let (lb, _) = model
                .decode_step(alloc, tok_b, pos, &mut twin, &mut p)
                .unwrap_or_else(|e| {
                    panic!("full-twin decode failed ({e}) — replay seed {seed:#018x}")
                });
            assert_eq!(
                la.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                lb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "fan-out vs fresh logits diverged at step {step} — replay seed {seed:#018x}"
            );
            tok_a = sample(&la, policy, &mut rng_forked);
            tok_b = sample(&lb, policy, &mut rng_fresh);
            assert_eq!(
                tok_a, tok_b,
                "fan-out vs fresh token streams diverged at step {step} — \
                 replay seed {seed:#018x}"
            );
        }
        assert_eq!(
            donor_before,
            cache_bits(&donor, &p, dims.n_layers, width),
            "fan-out decode wrote through a shared page — replay seed {seed:#018x}"
        );

        fanned.release(&mut p);
        twin.release(&mut p);
        donor.release(&mut p);
        assert_eq!(p.used_pages(), 0, "page leak — replay seed {seed:#018x}");
    }
    pool::set_parallel(true);
}

#[test]
fn fuzz_forked_equals_fresh_f32_pool() {
    fuzz_forked_equals_fresh(KvStore::F32, 0xc1);
}

#[test]
fn fuzz_forked_equals_fresh_e4m3_pool() {
    fuzz_forked_equals_fresh(KvStore::E4m3, 0xc2);
}

#[test]
fn fuzz_e4m3_kv_pages_hold_the_rmse_gates_vs_f32_pool_oracle() {
    let _mode = pool::test_mode_guard();
    for (alloc, stream) in [
        (Allocation::Fa32, 0xb1u64),
        (Allocation::Fa16_32, 0xb2),
        (Allocation::Fa16, 0xb3),
        (Allocation::Pasa16, 0xb4),
        (Allocation::Fp8, 0xb5),
        (Allocation::Pasa8, 0xb6),
    ] {
        let mut gated = 0usize;
        for i in 0..KV_CASES {
            let seed = (stream << 32) | i;
            let fc = fuzz_case(seed);
            let req = fc.req.clone().with_alloc(alloc);
            let oracle = run_from_pool(&req, KvStore::F32);
            let quant = run_from_pool(&req, KvStore::E4m3);

            // The finite-or-reported-overflow property holds on the
            // quantized path too: lossy storage must not create NaN the
            // telemetry never saw.
            if quant.nonfinite_outputs() > 0 {
                assert!(
                    quant.overflow_events() > 0 || quant.max_abs_score() > quant.score_boundary,
                    "{}: silent NaN on E4M3 KV — {} non-finite outputs with clean \
                     telemetry (max|S| {} vs boundary {}) — replay seed {seed:#018x}",
                    alloc.name(),
                    quant.nonfinite_outputs(),
                    quant.max_abs_score(),
                    quant.score_boundary,
                );
            }

            if let Some(bound) = kv_quant_gate(alloc, fc.regime, &oracle, &quant) {
                gated += 1;
                for h in 0..quant.heads.len() {
                    let e = relative_rmse(&quant.heads[h].data, &oracle.heads[h].data);
                    assert!(
                        e < bound,
                        "{}: head {h} E4M3-KV rmse {e} past the {bound} envelope \
                         (regime {:?}, oracle max|S| {}) — replay seed {seed:#018x}",
                        alloc.name(),
                        fc.regime,
                        oracle.max_abs_score(),
                    );
                }
            }
        }
        assert!(
            gated >= 3,
            "{}: E4M3-KV RMSE gate opened on only {gated}/{KV_CASES} cases — \
             the quantization pricing went vacuous (stream {stream:#x})",
            alloc.name()
        );
    }
}
