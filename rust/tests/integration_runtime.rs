//! Integration tests over the AOT runtime + serving engine. These require
//! `make artifacts` to have been run; they skip (pass trivially) when the
//! artifacts are absent so `cargo test` stays green pre-build.

use pasa::coordinator::{Engine, EngineConfig, FinishReason, GenParams, GuardPolicy, Request};
use pasa::model::Sampling;
use pasa::numerics::relative_rmse;
use pasa::runtime::ModelRuntime;
use std::path::{Path, PathBuf};

/// The PJRT client holds Rc internals (not Sync), so each test loads its
/// own runtime; executables compile lazily, so a test only pays for the
/// modules it actually runs.
fn artifacts() -> Option<ModelRuntime> {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.txt").exists() || !dir.join("weights.bin").exists() {
        eprintln!("artifacts/ missing — skipping runtime integration tests");
        return None;
    }
    ModelRuntime::load(Path::new("artifacts")).ok()
}

#[test]
fn head_kernels_agree_across_allocations() {
    let Some(rt) = artifacts() else { return };
    let n = 512 * 128;
    let q: Vec<f32> = (0..n).map(|i| ((i % 97) as f32) * 0.01 - 0.5).collect();
    let k: Vec<f32> = (0..n).map(|i| ((i % 89) as f32) * 0.01 - 0.4).collect();
    let v: Vec<f32> = (0..n).map(|i| ((i % 83) as f32) * 0.01 - 0.3).collect();
    let o32 = rt.head("fa32", &q, &k, &v).unwrap();
    for alloc in ["pasa", "fa16_32"] {
        let o = rt.head(alloc, &q, &k, &v).unwrap();
        let e = relative_rmse(&o, &o32);
        assert!(e < 2e-2, "{alloc} vs fa32 rmse {e}");
    }
}

#[test]
fn prefill_decode_consistency() {
    // Decoding the token that prefill predicted must be consistent with a
    // longer prefill (the KV-cache path is exact).
    let Some(rt) = artifacts() else { return };
    let d = rt.dims;
    let (ids, n) = pasa::model::tokenizer::encode("count up: one", d.prefill_seq, Default::default());
    let out = rt.prefill("fa32", &ids, n).unwrap();
    let v = d.vocab_size;
    let row = &out.logits[(n - 1) * v..n * v];
    assert!(row.iter().all(|x| x.is_finite()));

    // decode at pos n with slot 0
    let b = d.decode_batch;
    let sf = d.max_seq * d.head_width();
    let mut kb = vec![0f32; d.n_layers * b * sf];
    let mut vb = vec![0f32; d.n_layers * b * sf];
    for l in 0..d.n_layers {
        let src = l * sf;
        let dst = (l * b) * sf;
        kb[dst..dst + sf].copy_from_slice(&out.cache.k[src..src + sf]);
        vb[dst..dst + sf].copy_from_slice(&out.cache.v[src..src + sf]);
    }
    let first = row
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as i32;
    let mut toks = vec![d.pad as i32; b];
    toks[0] = first;
    let mut pos = vec![0i32; b];
    pos[0] = n as i32;
    let (lg, ko, vo) = rt.decode("fa32", &toks, &pos, &kb, &vb).unwrap();
    assert!(lg[..v].iter().all(|x| x.is_finite()));
    // The new KV rows come back as (L, B, W); slot 0's row is non-zero.
    assert_eq!(ko.len(), d.n_layers * b * d.head_width());
    assert!(ko[..4].iter().any(|&x| x != 0.0));
    assert!(vo[..4].iter().any(|&x| x != 0.0));
}

#[test]
fn serving_engine_completes_batch_with_all_policies() {
    let Some(rt) = artifacts() else { return };
    for policy in [GuardPolicy::AlwaysPasa, GuardPolicy::AlwaysFa16, GuardPolicy::Adaptive] {
        let mut cfg = EngineConfig::default();
        cfg.policy = policy;
        let mut eng = Engine::new(&rt, cfg);
        for i in 0..6 {
            let id = eng.fresh_id();
            eng.submit(
                Request::new(id, format!("math: {} plus 1 equals", i % 4)).with_params(GenParams {
                    max_new_tokens: 8,
                    sampling: Sampling::Greedy,
                    stop_at_eos: true,
                }),
            );
        }
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 6, "{policy:?}");
        for c in &comps {
            assert!(
                matches!(c.reason, FinishReason::Eos | FinishReason::MaxTokens),
                "{policy:?}: {:?}",
                c.reason
            );
            assert!(!c.tokens.is_empty());
        }
        assert!(eng.idle());
        assert_eq!(eng.kv_utilization(), 0.0, "pages leaked after completion");
    }
}

#[test]
fn pasa_and_fa32_greedy_outputs_match() {
    // Fig. 8 / Appendix G parity at the serving level.
    let Some(rt) = artifacts() else { return };
    let prompts = ["count up: two", "math: 3 plus 1 equals"];
    let mut texts = Vec::new();
    for policy in [GuardPolicy::AlwaysPasa, GuardPolicy::AlwaysFa32] {
        let mut cfg = EngineConfig::default();
        cfg.policy = policy;
        let mut eng = Engine::new(&rt, cfg);
        for p in prompts {
            let id = eng.fresh_id();
            eng.submit(Request::new(id, p).with_params(GenParams {
                max_new_tokens: 12,
                sampling: Sampling::Greedy,
                stop_at_eos: true,
            }));
        }
        let mut comps = eng.run_to_completion().unwrap();
        comps.sort_by_key(|c| c.id);
        texts.push(comps.into_iter().map(|c| c.text).collect::<Vec<_>>());
    }
    let same = texts[0]
        .iter()
        .zip(&texts[1])
        .filter(|(a, b)| a == b)
        .count();
    assert!(
        same >= 1,
        "PASA vs FA32 greedy outputs fully diverged: {:?} vs {:?}",
        texts[0],
        texts[1]
    );
}

#[test]
fn queue_backpressure_under_load() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = EngineConfig::default();
    cfg.max_queue = 3;
    cfg.policy = GuardPolicy::AlwaysFa16;
    let mut eng = Engine::new(&rt, cfg);
    let mut rejected = 0;
    for i in 0..8 {
        let id = eng.fresh_id();
        let adm = eng.submit(Request::new(id, format!("p{i}")).with_params(GenParams {
            max_new_tokens: 2,
            sampling: Sampling::Greedy,
            stop_at_eos: false,
        }));
        if matches!(adm, pasa::coordinator::Admission::Rejected(_)) {
            rejected += 1;
        }
    }
    assert!(rejected > 0, "expected backpressure rejections");
    let comps = eng.run_to_completion().unwrap();
    assert_eq!(comps.len(), 8 - rejected);
}
